//! Compute offload: dispatch GEMMs to a "DPU" worker — the paper's §1
//! vision ("dispatch user functions from a host CPU to a SmartNIC (DPU),
//! computational storage drive (CSD), or remote servers").
//!
//! Two strategies over the same simulated fabric:
//!   * **data-to-compute**: the host pulls both operands from the device's
//!     store (two GETs over the wire), multiplies locally, pushes C back;
//!   * **compute-to-data** (ifunc): the host injects a `gemm256` ifunc
//!     whose payload is only the *non-resident* operand; the multiply runs
//!     where the resident operand lives.
//!
//! With one operand resident on the device, moving the code beats moving
//! the data — the crossover logic the paper's introduction argues for.
//!
//! Run: `(cd python && python -m compile.aot)` then
//! `cargo run --release --example compute_offload`

use std::sync::Arc;
use std::time::Instant;

use two_chains::fabric::{Fabric, MemPerm, WireConfig};
use two_chains::ifunc::{
    CodeImage, IfuncLibrary, IfuncRing, SenderCursor, SourceArgs, TargetArgs,
};
use two_chains::runtime::with_runtime;
use two_chains::ucp::{Context, ContextConfig, Worker};
use two_chains::util::XorShift;
use two_chains::vm::Assembler;

const N: usize = 256;
const ELEMS: usize = N * N;

/// GEMM ifunc: payload = [A' (input) f32[N*N]]; the resident operand B is
/// already on the device (reachable through `load_resident`); output C
/// overwrites the payload. Code: load_resident copies B after A in
/// scratch? — simpler: the device symbol `gemm_resident` performs
/// C = payload_A @ B_resident via PJRT and writes C into the payload.
struct OffloadGemm {
    hlo: Vec<u8>,
}

impl IfuncLibrary for OffloadGemm {
    fn name(&self) -> &str {
        "gemm256"
    }

    fn payload_get_max_size(&self, _a: &SourceArgs) -> usize {
        2 * ELEMS * 4 // room for [A | B] — B is appended on the device
    }

    fn payload_init(&self, payload: &mut [u8], a: &SourceArgs) -> two_chains::Result<usize> {
        payload[..a.len()].copy_from_slice(a.as_bytes());
        Ok(2 * ELEMS * 4)
    }

    fn code(&self) -> CodeImage {
        let mut asm = Assembler::new();
        // append_resident(dst_off = ELEMS*4): device copies its B operand
        // into the payload right after A.
        asm.ldi(1, (ELEMS * 4) as u32);
        asm.call("append_resident");
        // xla_exec(in_off=0, n=2*ELEMS, out_off=0, max_out=ELEMS)
        asm.ldi(1, 0);
        asm.ldi(2, (2 * ELEMS) as u32);
        asm.ldi(3, 0);
        asm.ldi(4, ELEMS as u32);
        asm.call("xla_exec");
        asm.halt();
        let (vm_code, imports) = asm.assemble();
        CodeImage { imports, vm_code, hlo: self.hlo.clone() }
    }
}

fn mat(seed: u64) -> Vec<f32> {
    XorShift::new(seed).f32s(ELEMS)
}

fn main() -> two_chains::Result<()> {
    if !two_chains::runtime::pjrt_available() {
        eprintln!("compute_offload needs a real PJRT backend (stubbed; see rust/src/xla.rs)");
        return Ok(());
    }
    let artifacts = std::path::PathBuf::from("artifacts");
    let hlo = std::fs::read(artifacts.join("gemm256.hlo.txt"))
        .map_err(|e| two_chains::Error::Other(format!("run `python -m compile.aot` first: {e}")))?;

    // Host (node 0) and DPU (node 1), CX-6-like wire.
    let fabric = Fabric::new(2, WireConfig::connectx6());
    let host = Context::new(fabric.node(0), ContextConfig::default())?;
    let dpu = Context::new(fabric.node(1), ContextConfig::default())?;
    let wh = Worker::new(&host);
    let wd = Worker::new(&dpu);
    let ep = wh.connect(&wd)?;

    // The resident operand lives on the DPU (e.g. a model weight matrix).
    let b_resident: Arc<Vec<f32>> = Arc::new(mat(42));
    // Expose it to injected code and to remote GETs.
    let b_mr = dpu.mem_map(ELEMS * 4, MemPerm::RWX);
    for (i, v) in b_resident.iter().enumerate() {
        b_mr.local_slice_mut()[i * 4..i * 4 + 4].copy_from_slice(&v.to_le_bytes());
    }
    let b2 = b_resident.clone();
    dpu.symbols().install_fn("append_resident", move |ctx, [dst_off, _, _, _]| {
        let dst = dst_off as usize;
        let need = b2.len() * 4;
        if dst + need > ctx.payload.len() {
            return Err("append_resident: payload too small".into());
        }
        for (i, v) in b2.iter().enumerate() {
            ctx.payload[dst + i * 4..dst + i * 4 + 4].copy_from_slice(&v.to_le_bytes());
        }
        Ok(b2.len() as u64)
    });

    host.library_dir().install(Box::new(OffloadGemm { hlo }));
    let h = host.register_ifunc("gemm256")?;
    let mut ring = IfuncRing::new(&dpu, 8 << 20)?;
    let mut cursor = SenderCursor::new(ring.size());

    let reps = 8usize;
    println!("== GEMM offload: {N}x{N}, {reps} reps, CX-6 wire model ==\n");

    // Strategy 1: data-to-compute. Pull B from the device, compute at the
    // host, push C back (A is host-resident in both strategies).
    with_runtime(|rt| rt.ensure_compiled_file("gemm256", &artifacts.join("gemm256.hlo.txt")))?;
    let c_back = host.mem_map(ELEMS * 4, MemPerm::RWX); // host-side C landing
    let _ = c_back;
    let a_host = mat(7);
    let t0 = Instant::now();
    let mut pull_checksum = 0.0f64;
    for _ in 0..reps {
        let raw = ep.qp().get_blocking(b_mr.rkey(), 0, ELEMS * 4)?;
        let b: Vec<f32> =
            raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect();
        let mut input = a_host.clone();
        input.extend_from_slice(&b);
        let c = with_runtime(|rt| rt.execute_f32("gemm256", &input, &[2 * ELEMS as i64]))?;
        // Push the result back to the device store.
        let bytes: Vec<u8> = c.iter().flat_map(|v| v.to_le_bytes()).collect();
        ep.put_nbi(b_mr.rkey(), 0, &bytes[..ELEMS * 4])?;
        ep.flush()?;
        pull_checksum += c[0] as f64;
    }
    let data_to_compute = t0.elapsed();
    // Restore B on the device (strategy 1 overwrote it with C).
    for (i, v) in b_resident.iter().enumerate() {
        b_mr.local_slice_mut()[i * 4..i * 4 + 4].copy_from_slice(&v.to_le_bytes());
    }

    // Strategy 2: compute-to-data. Inject the GEMM; only A crosses the
    // wire (plus the ~KB code+HLO section).
    let mut args = TargetArgs::none();
    let t1 = Instant::now();
    let mut push_checksum = 0.0f64;
    for _ in 0..reps {
        let msg = h.msg_create(&SourceArgs::f32s(&a_host))?;
        ep.ifunc_msg_send_cursor(&msg, &mut cursor, ring.rkey())?;
        ep.flush()?;
        dpu.poll_ifunc_blocking(&mut ring, &mut args)?;
        push_checksum += 1.0; // result stays resident; count completions
    }
    let compute_to_data = t1.elapsed();

    let d2c = data_to_compute.as_secs_f64() / reps as f64;
    let c2d = compute_to_data.as_secs_f64() / reps as f64;
    println!("data-to-compute (GET B, local GEMM, PUT C): {:8.2} ms/op", d2c * 1e3);
    println!("compute-to-data (inject gemm256 ifunc):     {:8.2} ms/op", c2d * 1e3);
    println!(
        "\nwire bytes per op: d2c = {} KiB (B down + C up), c2d = {} KiB (A + code)",
        2 * ELEMS * 4 / 1024,
        (ELEMS * 4 + 2048) / 1024,
    );
    println!(
        "compute-to-data moves {:.1}x fewer bytes; measured speedup {:.2}x",
        (2.0 * ELEMS as f64 * 4.0) / (ELEMS as f64 * 4.0 + 2048.0),
        d2c / c2d
    );
    let _ = (pull_checksum, push_checksum);
    Ok(())
}
