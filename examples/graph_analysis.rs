//! Irregular graph analytics with compute-to-data ifuncs — the paper's §1
//! motivating workload: "large-scale irregular applications (such as
//! semantic graph analysis), composed of many coordinating tasks
//! operating on a data set so big that it has to be stored on many
//! physical devices ... it may be more efficient to dynamically choose
//! where code runs as the application progresses."
//!
//! A random graph is vertex-partitioned across workers. Each PageRank-ish
//! iteration:
//!   1. every worker computes its partition's outgoing contributions
//!      (host symbol `push_contrib`, driven by an injected function),
//!   2. the leader forwards accumulated cross-partition contributions to
//!      the owning workers (ifuncs again — the code travels to the data),
//!   3. every worker combines damped contributions into new ranks using
//!      the `graphcmb` JAX/Pallas artifact via `xla_exec`.
//!
//! The run verifies against a single-machine reference and reports
//! per-iteration timing.
//!
//! Run: `(cd python && python -m compile.aot)` then
//! `cargo run --release --example graph_analysis`

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use two_chains::coordinator::{Cluster, ClusterConfig, FilterIfunc, Target};
use two_chains::ifunc::{CodeImage, IfuncLibrary, SourceArgs};
use two_chains::util::XorShift;
use two_chains::vm::Assembler;

const VERTS_PER_WORKER: usize = 8192; // graphcmb artifact length
const WORKERS: usize = 3;
const AVG_DEG: usize = 8;
const ITERS: usize = 10;
const DAMPING: f32 = 0.85;

type Edge = (usize, usize); // global vertex ids

/// Worker-local graph state, owned by the worker's TargetArgs-visible
/// store-side struct (installed as symbols below).
struct Partition {
    /// ranks[v] for local vertices.
    ranks: Vec<f32>,
    /// Incoming contribution accumulator.
    contrib: Vec<f32>,
    /// Local adjacency: local src -> global dsts.
    adj: Vec<Vec<usize>>,
    out_degree: Vec<usize>,
}

/// The combine ifunc: payload = [contrib f32[N] | ranks f32[N]] is built
/// *on the worker* by `load_state`, xla_exec runs graphcmb, and
/// `store_ranks` writes the result back. Only code crosses the wire.
struct CombineIfunc {
    hlo: Vec<u8>,
}

impl IfuncLibrary for CombineIfunc {
    fn name(&self) -> &str {
        "graphcmb"
    }
    fn payload_get_max_size(&self, _a: &SourceArgs) -> usize {
        2 * VERTS_PER_WORKER * 4
    }
    fn payload_init(&self, _p: &mut [u8], _a: &SourceArgs) -> two_chains::Result<usize> {
        // Payload is filled on the *target* from device-resident state.
        Ok(2 * VERTS_PER_WORKER * 4)
    }
    fn code(&self) -> CodeImage {
        let mut a = Assembler::new();
        a.call("load_state"); // packs [contrib | ranks] into the payload
        a.ldi(1, 0);
        a.ldi(2, (2 * VERTS_PER_WORKER) as u32);
        a.ldi(3, 0);
        a.ldi(4, VERTS_PER_WORKER as u32);
        a.call("xla_exec"); // new_ranks = 0.85*contrib + 0.15*ranks
        a.call("store_ranks"); // writes payload[0..N] back + clears contrib
        a.halt();
        let (vm_code, imports) = a.assemble();
        CodeImage { imports, vm_code, hlo: self.hlo.clone() }
    }
}

/// The contribution-push ifunc: payload = [(global_dst u32, value f32)...]
/// pairs routed to this worker; `add_contrib` scatters them.
struct PushIfunc;

impl IfuncLibrary for PushIfunc {
    fn name(&self) -> &str {
        "push"
    }
    fn payload_get_max_size(&self, a: &SourceArgs) -> usize {
        a.len()
    }
    fn payload_init(&self, p: &mut [u8], a: &SourceArgs) -> two_chains::Result<usize> {
        p[..a.len()].copy_from_slice(a.as_bytes());
        Ok(a.len())
    }
    fn code(&self) -> CodeImage {
        let mut a = Assembler::new();
        a.paylen(1);
        a.call("add_contrib");
        a.halt();
        let (vm_code, imports) = a.assemble();
        CodeImage { imports, vm_code, hlo: vec![] }
    }
}

fn owner(v: usize) -> usize {
    v / VERTS_PER_WORKER
}

/// The collective-invocation demo (needs no PJRT backend): each worker's
/// store is seeded with shard-local records, one `invoke_all` injects the
/// `FilterIfunc` query on every worker simultaneously, and the leader
/// merges the per-worker match lists with worker attribution — a
/// full-cluster scan where only the query and the matches cross the
/// fabric.
fn scatter_gather_demo() -> two_chains::Result<()> {
    println!("== scatter-gather: shard-local filter on every worker ==");
    let cluster = Cluster::launch(
        ClusterConfig::builder().workers(WORKERS).build()?,
        |i, _, store| {
            // Worker i owns keys 1000i..1000i+99; the first element is a
            // pseudo-random score the injected filter thresholds on.
            let mut rng = XorShift::new(42 + i as u64);
            for j in 0..100u64 {
                store.insert(1000 * i as u64 + j, vec![rng.below(1000) as f32 / 1000.0]);
            }
        },
    )?;
    cluster.leader.library_dir().install(Box::new(FilterIfunc));
    let d = cluster.dispatcher();
    let h = d.register("filter")?;
    let threshold = 0.9f32;
    let msg = h.msg_create(&FilterIfunc::args(threshold))?;
    let t0 = Instant::now();
    let merged = d.invoke_all(&msg)?.wait()?;
    let us = t0.elapsed().as_secs_f64() * 1e6;
    let mut total = 0usize;
    for (worker, reply) in merged.replies() {
        let matches = FilterIfunc::matches(&reply.payload);
        println!("  worker {worker}: {} of 100 records >= {threshold}", matches.len());
        total += matches.len();
    }
    println!("  {total} matches merged from {} shards in {us:.0} us\n", merged.len());
    cluster.shutdown()
}

/// The mesh-forwarding demo (needs no PJRT backend): the multi-hop
/// pipeline shape the paper's compute-to-data motivation ends at — a
/// stage chain (think shard-local filter → owner-side join → reduce)
/// where each stage `forward`s the frame straight to the next worker
/// over the worker↔worker mesh. The leader injects once into the head
/// and collects the final stage's reply; the intermediate results never
/// bounce through it.
fn mesh_pipeline_demo() -> two_chains::Result<()> {
    use two_chains::ifunc::builtin::HopIfunc;
    println!("== mesh forwarding: w0 -> w1 -> w2 stage chain, no leader relay ==");
    let cluster = Cluster::launch(
        ClusterConfig::builder().workers(WORKERS).mesh(true).build()?,
        |_, ctx, _| {
            ctx.library_dir().install(Box::new(HopIfunc));
        },
    )?;
    cluster.leader.library_dir().install(Box::new(HopIfunc));
    let d = cluster.dispatcher();
    let h = d.register("hop")?;
    let data: Vec<u8> = (0..64u8).collect();
    // Visit workers 1 and 2 after the injection target (worker 0).
    let msg = h.msg_create(&SourceArgs::bytes(HopIfunc::payload(&[1, 2], &data)))?;
    let t0 = Instant::now();
    let reply = d.invoke_begin(Target::Worker(0), &msg)?.wait()?;
    let us = t0.elapsed().as_secs_f64() * 1e6;
    assert!(reply.ok() && reply.payload == data);
    let frames: u64 = (0..WORKERS).map(|w| d.debug_frames_sent(w).unwrap()).sum();
    let hops: u64 = cluster.workers.iter().map(|w| w.forwarded()).sum();
    println!(
        "  3-stage chain in {us:.0} us: {frames} leader frame(s), {hops} mesh hop(s)\n"
    );
    cluster.shutdown()
}

fn main() -> two_chains::Result<()> {
    scatter_gather_demo()?;
    mesh_pipeline_demo()?;
    if !two_chains::runtime::pjrt_available() {
        eprintln!("graph_analysis needs a real PJRT backend (stubbed; see rust/src/xla.rs)");
        return Ok(());
    }
    let artifacts = std::path::PathBuf::from("artifacts");
    let hlo = std::fs::read(artifacts.join("graphcmb.hlo.txt"))
        .map_err(|e| two_chains::Error::Other(format!("run `python -m compile.aot` first: {e}")))?;

    let n = WORKERS * VERTS_PER_WORKER;
    println!("== distributed graph analysis: {n} vertices, {WORKERS} workers ==");

    // Random graph.
    let mut rng = XorShift::new(2024);
    let mut edges: Vec<Edge> = Vec::with_capacity(n * AVG_DEG);
    for src in 0..n {
        for _ in 0..rng.range(1, 2 * AVG_DEG as u64) {
            edges.push((src, rng.below(n as u64) as usize));
        }
    }
    println!("{} edges, avg degree {:.1}", edges.len(), edges.len() as f64 / n as f64);

    // Partition state shared with worker symbols.
    let partitions: Vec<Arc<Mutex<Partition>>> = (0..WORKERS)
        .map(|w| {
            let mut adj = vec![Vec::new(); VERTS_PER_WORKER];
            for &(s, d) in &edges {
                if owner(s) == w {
                    adj[s % VERTS_PER_WORKER].push(d);
                }
            }
            let out_degree = adj.iter().map(|a| a.len()).collect();
            Arc::new(Mutex::new(Partition {
                ranks: vec![1.0 / n as f32; VERTS_PER_WORKER],
                contrib: vec![0.0; VERTS_PER_WORKER],
                adj,
                out_degree,
            }))
        })
        .collect();

    let parts2 = partitions.clone();
    let cluster = Cluster::launch(
        ClusterConfig::builder().workers(WORKERS).ring_bytes(16 << 20).build()?,
        move |i, ctx, _| {
            let part = parts2[i].clone();
            // load_state: pack [contrib | ranks] into the ifunc payload.
            let p1 = part.clone();
            ctx.symbols().install_fn("load_state", move |c, _| {
                let p = p1.lock().unwrap();
                for (i, v) in p.contrib.iter().chain(p.ranks.iter()).enumerate() {
                    c.payload[i * 4..i * 4 + 4].copy_from_slice(&v.to_le_bytes());
                }
                Ok(0)
            });
            // store_ranks: payload[0..N] -> ranks; zero the accumulator.
            let p2 = part.clone();
            ctx.symbols().install_fn("store_ranks", move |c, _| {
                let mut p = p2.lock().unwrap();
                for i in 0..VERTS_PER_WORKER {
                    p.ranks[i] =
                        f32::from_le_bytes(c.payload[i * 4..i * 4 + 4].try_into().unwrap());
                }
                p.contrib.iter_mut().for_each(|x| *x = 0.0);
                Ok(0)
            });
            // add_contrib: scatter (dst, value) pairs into the accumulator.
            let p3 = part.clone();
            ctx.symbols().install_fn("add_contrib", move |c, [len, ..]| {
                let mut p = p3.lock().unwrap();
                for pair in c.payload[..len as usize].chunks_exact(8) {
                    let dst = u32::from_le_bytes(pair[..4].try_into().unwrap()) as usize;
                    let val = f32::from_le_bytes(pair[4..].try_into().unwrap());
                    p.contrib[dst % VERTS_PER_WORKER] += val;
                }
                Ok(0)
            });
        },
    )?;
    cluster.leader.library_dir().install(Box::new(CombineIfunc { hlo }));
    cluster.leader.library_dir().install(Box::new(PushIfunc));
    let d = cluster.dispatcher();
    let h_combine = d.register("graphcmb")?;
    let h_push = d.register("push")?;

    let t_all = Instant::now();
    for iter in 0..ITERS {
        let t0 = Instant::now();
        // 1) compute contributions locally (host orchestrates, data stays).
        let mut outbound: Vec<HashMap<usize, f32>> = (0..WORKERS).map(|_| HashMap::new()).collect();
        for (w, part) in partitions.iter().enumerate() {
            let p = part.lock().unwrap();
            for v in 0..VERTS_PER_WORKER {
                if p.out_degree[v] == 0 {
                    continue;
                }
                let share = p.ranks[v] / p.out_degree[v] as f32;
                for &dst in &p.adj[v] {
                    *outbound[owner(dst)].entry(dst).or_insert(0.0) += share;
                }
            }
            let _ = w;
        }
        // 2) push contributions to owning workers as ifunc payloads.
        for (w, contribs) in outbound.iter().enumerate() {
            let mut bytes = Vec::with_capacity(contribs.len() * 8);
            for (&dst, &val) in contribs {
                bytes.extend_from_slice(&(dst as u32).to_le_bytes());
                bytes.extend_from_slice(&val.to_le_bytes());
            }
            // Chunk below the ring frame limit.
            for chunk in bytes.chunks(1 << 20) {
                let msg = h_push.msg_create(&SourceArgs::bytes(chunk.to_vec()))?;
                d.send(Target::Worker(w), &msg)?;
            }
        }
        d.barrier()?;
        // 3) combine on-device via the graphcmb artifact: one collective
        // fan-out, every link posted before the flush pass, the merged
        // wait standing in for the old send-per-worker + barrier.
        let msg = h_combine.msg_create(&SourceArgs::none())?;
        d.invoke_all(&msg)?.wait()?;
        let total: f32 =
            partitions.iter().map(|p| p.lock().unwrap().ranks.iter().sum::<f32>()).sum();
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        println!("iter {iter:2}: {ms:6.1} ms, total rank mass {total:.4}");
    }
    println!("\n{} iterations in {:.2?}", ITERS, t_all.elapsed());

    // Reference check: run the same update single-machine.
    let mut ref_ranks = vec![1.0 / n as f32; n];
    let mut adj = vec![Vec::new(); n];
    for &(s, d2) in &edges {
        adj[s].push(d2);
    }
    for _ in 0..ITERS {
        let mut contrib = vec![0.0f32; n];
        for v in 0..n {
            if adj[v].is_empty() {
                continue;
            }
            let share = ref_ranks[v] / adj[v].len() as f32;
            for &dst in &adj[v] {
                contrib[dst] += share;
            }
        }
        for v in 0..n {
            ref_ranks[v] = DAMPING * contrib[v] + (1.0 - DAMPING) * ref_ranks[v];
        }
    }
    let mut max_err = 0.0f32;
    for v in 0..n {
        let got = partitions[owner(v)].lock().unwrap().ranks[v % VERTS_PER_WORKER];
        max_err = max_err.max((got - ref_ranks[v]).abs());
    }
    println!("verification vs single-machine reference: max |err| = {max_err:.3e}");
    // f32 scatter-add order differs between the distributed run (HashMap
    // iteration, per-partition accumulation) and the reference loop.
    if max_err >= 2e-3 {
        return Err(two_chains::Error::Other(format!("distributed result diverged: {max_err}")));
    }
    println!("graph analysis OK");
    cluster.shutdown()?;
    Ok(())
}
