//! Quickstart: the paper's Listing 1.4 flow, end to end.
//!
//! Registers an ifunc on the *source*, creates a message (payload sized +
//! initialized by the library's two routines), PUTs it into the target's
//! mapped ring, and polls on the target until it executes — then shows
//! what makes ifuncs different from active messages: the target never
//! registered anything, and shipping a brand-new function under a new
//! name changes what runs *without restarting anything*.
//!
//! Run: `cargo run --release --example quickstart`

use two_chains::ifunc::builtin::{ChecksumIfunc, CounterIfunc};
use two_chains::ifunc::SenderCursor;
use two_chains::prelude::*;

fn main() -> two_chains::Result<()> {
    // §4.2 testbed: two machines, back-to-back (wire model off for demo).
    let fabric = Fabric::new(2, WireConfig::off());
    let src = Context::new(fabric.node(0), ContextConfig::default())?;
    let dst = Context::new(fabric.node(1), ContextConfig::default())?;

    // Target side: map an RWX ring and (that's all) — no handler table.
    let mut ring = IfuncRing::new(&dst, 1 << 20)?;
    println!("target: mapped {} KiB ring, rkey {:#010x}", ring.size() >> 10, ring.rkey());

    // Wireup.
    let ws = Worker::new(&src);
    let wd = Worker::new(&dst);
    let ep = ws.connect(&wd)?;

    // Source side: "dlopen" the counter library and send 3 messages.
    src.library_dir().install(Box::new(CounterIfunc::default()));
    let h = src.register_ifunc("counter")?;
    let mut cursor = SenderCursor::new(ring.size());
    let mut args = TargetArgs::none();
    for i in 0..3 {
        let msg = h.msg_create(&SourceArgs::bytes(format!("payload #{i}").into_bytes()))?;
        ep.ifunc_msg_send_cursor(&msg, &mut cursor, ring.rkey())?;
        ep.flush()?;
        dst.poll_ifunc_blocking(&mut ring, &mut args)?;
        println!("source: injected #{i}; target counter = {}", dst.symbols().counter_value());
    }

    // The ifunc difference: ship a brand-new function at runtime — the
    // target auto-registers it on first sight (§3.4), no recompile, no
    // restart, no target-side registration call.
    src.library_dir().install(Box::new(ChecksumIfunc));
    let h2 = src.register_ifunc("checksum")?;
    let msg = h2.msg_create(&SourceArgs::bytes(vec![1u8; 1000]))?;
    ep.ifunc_msg_send_cursor(&msg, &mut cursor, ring.rkey())?;
    ep.flush()?;
    dst.poll_ifunc_blocking(&mut ring, &mut args)?;
    println!(
        "source: injected brand-new 'checksum' ifunc; target computed {} (expected 1000)",
        dst.symbols().last_result()
    );

    let hits = dst.ifunc_cache().hits.load(std::sync::atomic::Ordering::Relaxed);
    let misses = dst.ifunc_cache().misses.load(std::sync::atomic::Ordering::Relaxed);
    println!("target auto-registration cache: {hits} hits, {misses} misses (one per type)");
    Ok(())
}
