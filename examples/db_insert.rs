//! End-to-end driver — the paper's §3.2 database scenario on a real
//! (synthetic) workload. **This is the repo's headline E2E run** (see
//! EXPERIMENTS.md §E2E).
//!
//! A host ingests a corpus of synthetic "voice recordings" (band-limited
//! waveforms, 4096 samples each). For every record it:
//!   1. compresses it with the `delta_enc` JAX/Pallas artifact (source-side
//!      `payload_init`, Listing 1.3),
//!   2. injects a `dbdec` ifunc to the worker that owns the key — the
//!      message carries the decode+checksum HLO itself,
//!   3. the worker compiles the artifact on first sight (auto-registration),
//!      decodes in place via PJRT, verifies and inserts into its store.
//!
//! The run reports ingest throughput, per-worker placement, PJRT compile
//! counts, and full-corpus verification against the originals.
//!
//! Run: `(cd python && python -m compile.aot)` then
//! `cargo run --release --example db_insert [n_records] [workers]`

use std::time::Instant;

use two_chains::coordinator::{
    apps::{DecodeInsertIfunc, SIGNAL_N},
    Cluster, ClusterConfig, GetIfunc, Target, GET_MISSING,
};
use two_chains::fabric::WireConfig;
use two_chains::{Error, Result};

/// Synthetic "voice": a sum of a few low-frequency harmonics plus noise —
/// band-limited like speech, so delta coding actually shrinks dynamic
/// range (the property the paper's paq8px example banks on).
fn synth_recording(seed: u64) -> Vec<f32> {
    let mut rng = two_chains::util::XorShift::new(seed + 1);
    let f0 = 80.0 + rng.f32() * 160.0; // fundamental 80-240 Hz
    let harmonics: Vec<(f32, f32)> =
        (1..=4).map(|h| (f0 * h as f32, rng.f32() / h as f32)).collect();
    (0..SIGNAL_N)
        .map(|i| {
            let t = i as f32 / 16_000.0; // 16 kHz sample rate
            let mut s = 0.0;
            for &(f, a) in &harmonics {
                s += a * (2.0 * std::f32::consts::PI * f * t).sin();
            }
            s + (rng.f32() - 0.5) * 0.01
        })
        .collect()
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let parse = |s: &String| s.parse::<usize>().map_err(|e| Error::Other(format!("{s}: {e}")));
    let n_records: usize = args.get(1).map(parse).transpose()?.unwrap_or(256);
    let n_workers: usize = args.get(2).map(parse).transpose()?.unwrap_or(3);
    let artifacts = std::path::PathBuf::from("artifacts");
    if !two_chains::runtime::pjrt_available() {
        eprintln!("db_insert needs a real PJRT backend (stubbed; see rust/src/xla.rs)");
        return Ok(());
    }

    println!("== Two-Chains record-ingestion E2E ==");
    println!("corpus: {n_records} recordings x {SIGNAL_N} samples, {n_workers} workers\n");

    let cluster = Cluster::launch(
        ClusterConfig::builder().workers(n_workers).wire(WireConfig::connectx6()).build()?,
        |_, _, _| {},
    )?;
    cluster.leader.library_dir().install(Box::new(DecodeInsertIfunc::load(&artifacts)?));
    let d = cluster.dispatcher();
    let handle = d.register("dbdec")?;

    // Generate the corpus up front (generation is not what we measure).
    let corpus: Vec<(u64, Vec<f32>)> =
        (0..n_records as u64).map(|k| (k, synth_recording(k))).collect();

    let t0 = Instant::now();
    for (key, record) in &corpus {
        let msg = handle.msg_create(&DecodeInsertIfunc::args(*key, record))?;
        d.send(Target::Key(*key), &msg)?;
    }
    d.barrier()?;
    let dt = t0.elapsed();

    let bytes = n_records * SIGNAL_N * 4;
    println!("ingested {n_records} records in {:.2?}", dt);
    println!(
        "  throughput: {:.0} records/s, {:.1} MB/s of raw samples",
        n_records as f64 / dt.as_secs_f64(),
        bytes as f64 / dt.as_secs_f64() / 1e6
    );
    for w in &cluster.workers {
        println!(
            "  worker {}: {} executed, {} records stored, {} failed",
            w.index,
            w.executed(),
            w.store.len(),
            w.stats.failed.load(std::sync::atomic::Ordering::Relaxed)
        );
    }

    // Verify the entire corpus decoded correctly.
    let t1 = Instant::now();
    let mut max_err = 0.0f32;
    for (key, record) in &corpus {
        let w = d.route_key(*key);
        let stored = cluster.workers[w]
            .store
            .get(*key)
            .ok_or_else(|| Error::Other(format!("record {key} missing on worker {w}")))?;
        for (a, b) in stored.iter().zip(record) {
            max_err = max_err.max((a - b).abs());
        }
    }
    println!(
        "\nverified {} records in {:.2?}; max |err| = {:.2e}",
        corpus.len(),
        t1.elapsed(),
        max_err
    );
    if max_err >= 1e-2 {
        return Err(Error::Other(format!("decode error too large: {max_err}")));
    }

    // Spot-check through the reply path too: a GetIfunc invocation makes
    // the *worker* push the record back inline in the reply frame and
    // return its length in r0 — no leader-side store access involved.
    cluster.leader.library_dir().install(Box::new(GetIfunc));
    let h_get = d.register("get")?;
    for key in [0u64, n_records as u64 / 2, n_records as u64 - 1] {
        let w = d.route_key(key);
        let (reply, fetched) = d.fetch(Target::Key(key), &h_get.msg_create(&GetIfunc::args(key))?)?;
        if !reply.ok() || reply.r0 == GET_MISSING {
            return Err(Error::Other(format!("get({key}) failed on worker {w}")));
        }
        println!("  get({key}) via invoke -> {} samples from worker {w}", fetched.len());
    }
    println!("E2E OK: encode (Pallas delta) -> inject (RDMA put) -> decode+insert (PJRT)");
    cluster.shutdown()?;
    Ok(())
}
