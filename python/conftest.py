"""Make the `compile` package importable no matter where pytest is invoked
from (`pytest python/tests` at the repo root, or `pytest tests` in here)."""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
