"""L1 Pallas kernel: blocked elementwise a*x + b*y combine.

The graph-analytics example's rank update: new_rank = a*rank + b*contrib,
the elementwise combine step of damped iterative propagation (PageRank
style). Purely memory-bound; blocks are 1-D VMEM tiles.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 2048


def _axpb_kernel(a, b, x_ref, y_ref, o_ref):
    o_ref[...] = a * x_ref[...] + b * y_ref[...]


def combine(x, y, a=0.85, b=0.15):
    """o = a*x + b*y over 1-D f32 arrays (length multiple of BLOCK)."""
    if x.shape != y.shape or x.ndim != 1 or x.shape[0] % BLOCK != 0:
        raise ValueError(f"bad shapes {x.shape} / {y.shape}")
    n = x.shape[0] // BLOCK
    import functools

    # a/b are baked in as *python* floats: static constants in the kernel,
    # not captured tracers.
    return pl.pallas_call(
        functools.partial(_axpb_kernel, float(a), float(b)),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,
    )(x, y)
