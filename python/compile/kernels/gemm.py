"""L1 Pallas kernel: tiled matmul — the compute-offload workload.

The paper's vision (§1) dispatches user compute to DPUs/CSDs; the
canonical dense payload is a GEMM. Classic three-axis tiling: grid
(M/bm, N/bn, K/bk), A tiles (bm, bk), B tiles (bk, bn), accumulation into
a revisited (bm, bn) output tile.

TPU mapping (DESIGN.md §Hardware-Adaptation): 128x128 tiles are exactly
MXU-systolic-array shaped; VMEM per step = (bm*bk + bk*bn + bm*bn) * 4 B
= 192 KiB at 128³ — comfortably resident, leaving room for double
buffering. On real hardware the dtype would be bf16 into an f32
accumulator; interpret-mode keeps f32 throughout for exactness against
the reference.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BM = BN = BK = 128


def _matmul_kernel(a_ref, b_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += a_ref[...] @ b_ref[...]


@functools.partial(jax.jit, static_argnames=())
def matmul(a, b):
    """C = A @ B for f32 matrices with dims divisible by 128."""
    m, k = a.shape
    k2, n = b.shape
    if k != k2 or m % BM or n % BN or k % BK:
        raise ValueError(f"shapes {a.shape} @ {b.shape} must tile by {BM}")
    return pl.pallas_call(
        _matmul_kernel,
        grid=(m // BM, n // BN, k // BK),
        in_specs=[
            pl.BlockSpec((BM, BK), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((BK, BN), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((BM, BN), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=True,
    )(a, b)
