"""L1 Pallas kernels: frame-local delta codec.

The paper's usage example (§3.2) ships compressed voice recordings and
decodes them on the target. Our codec is a frame-local delta transform —
the standard first stage of waveform compressors: the signal is split into
independent FRAME-sample frames; within a frame, sample i stores the
difference from sample i-1. Frames are independent, so the Pallas grid
parallelizes over them and each block is a clean VMEM tile.

VMEM budget per grid step: in-block + out-block = 2 * FRAME * 4 B = 8 KiB,
far under the ~16 MiB VMEM of a TPU core; FRAME=1024 keeps the lane
dimension a multiple of 128 for the VPU (DESIGN.md §10).

All kernels run under interpret=True: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and correctness (vs `ref.py`) is what the pytest
suite asserts.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Samples per codec frame (and per Pallas block).
FRAME = 1024


def _encode_kernel(x_ref, o_ref):
    x = x_ref[...]
    shifted = jnp.concatenate([jnp.zeros((1,), x.dtype), x[:-1]])
    o_ref[...] = x - shifted


def _decode_kernel(y_ref, o_ref):
    # Inverse of the delta transform: prefix sum within the frame.
    o_ref[...] = jnp.cumsum(y_ref[...])


def _frames_call(kernel, x):
    if x.ndim != 1 or x.shape[0] % FRAME != 0:
        raise ValueError(f"signal length must be a multiple of {FRAME}, got {x.shape}")
    n_frames = x.shape[0] // FRAME
    return pl.pallas_call(
        kernel,
        grid=(n_frames,),
        in_specs=[pl.BlockSpec((FRAME,), lambda i: (i,))],
        out_specs=pl.BlockSpec((FRAME,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,
    )(x)


def encode_frames(x):
    """Delta-encode a 1-D f32 signal, frame by frame."""
    return _frames_call(_encode_kernel, x)


def decode_frames(y):
    """Invert :func:`encode_frames`."""
    return _frames_call(_decode_kernel, y)
