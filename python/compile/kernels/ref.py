"""Pure-jnp oracles for every Pallas kernel.

The pytest suite asserts `assert_allclose(kernel(x), ref(x))` — this file
is the correctness ground truth (no Pallas, no grids, just math).
"""

import jax.numpy as jnp

from . import delta as _delta


def delta_encode(x):
    """Frame-local delta: within each FRAME chunk, y[0]=x[0], y[i]=x[i]-x[i-1]."""
    f = _delta.FRAME
    xs = x.reshape(-1, f)
    shifted = jnp.concatenate([jnp.zeros((xs.shape[0], 1), x.dtype), xs[:, :-1]], axis=1)
    return (xs - shifted).reshape(-1)


def delta_decode(y):
    """Frame-local inverse: per-frame prefix sum."""
    f = _delta.FRAME
    return jnp.cumsum(y.reshape(-1, f), axis=1).reshape(-1)


def fletcher(x):
    """[sum(x), sum((i+1) * x[i])] as f32[2]."""
    idx = jnp.arange(1, x.shape[0] + 1, dtype=jnp.float32)
    return jnp.stack([jnp.sum(x), jnp.sum(idx * x)])


def matmul(a, b):
    return a @ b


def mulaw_encode(x, mu=255.0):
    return jnp.sign(x) * jnp.log1p(mu * jnp.abs(x)) / jnp.log1p(mu)


def mulaw_decode(y, mu=255.0):
    return jnp.sign(y) * (jnp.exp(jnp.abs(y) * jnp.log1p(mu)) - 1.0) / mu


def combine(x, y, a=0.85, b=0.15):
    # Same weak-typed python-float semantics as the kernel (which bakes
    # a/b in as static python floats).
    return float(a) * x + float(b) * y
