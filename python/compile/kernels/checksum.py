"""L1 Pallas kernel: Fletcher-style f32 checksum.

Produces [s1, s2] with s1 = sum(x) and s2 = sum((i+1) * x[i]) — the float
analog of a Fletcher checksum, position-sensitive so reorderings are
caught. The grid walks BLOCK-sized VMEM tiles and accumulates into a
2-element output block that every grid step revisits (the standard Pallas
reduction pattern; the paper's db example would run this after decode to
validate the record).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 1024


def _fletcher_kernel(x_ref, ramp_ref, o_ref):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    base = (step * BLOCK).astype(jnp.float32)
    # The 1..BLOCK ramp rides in as an input (every grid step maps to the
    # same block): a kernel may not capture constant arrays from the
    # enclosing trace, and an in-kernel arange would be one.
    idx = base + ramp_ref[...]
    o_ref[...] += jnp.array(
        [jnp.sum(x), jnp.sum(idx * x)], dtype=o_ref.dtype
    )


def fletcher(x):
    """Checksum a 1-D f32 signal; returns f32[2] = [s1, s2]."""
    if x.ndim != 1 or x.shape[0] % BLOCK != 0:
        raise ValueError(f"length must be a multiple of {BLOCK}, got {x.shape}")
    n = x.shape[0] // BLOCK
    ramp = jnp.arange(1, BLOCK + 1, dtype=jnp.float32)
    return pl.pallas_call(
        _fletcher_kernel,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((2,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((2,), x.dtype),
        interpret=True,
    )(x, ramp)
