"""L1 Pallas kernels: mu-law companding (G.711-style).

The actual lossy stage of the voice-record codec: mu-law compresses the
dynamic range of each sample (the classic telephony companding curve),
which is what makes the delta-coded record quantizable. Elementwise and
memory-bound; blocked over 1-D VMEM tiles.

    encode:  y = sign(x) * ln(1 + mu*|x|) / ln(1 + mu)      x in [-1, 1]
    decode:  x = sign(y) * ((1 + mu)^|y| - 1) / mu
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 1024
MU = 255.0


def _encode_kernel(x_ref, o_ref):
    x = x_ref[...]
    o_ref[...] = jnp.sign(x) * jnp.log1p(MU * jnp.abs(x)) / jnp.log1p(MU)


def _decode_kernel(y_ref, o_ref):
    y = y_ref[...]
    o_ref[...] = jnp.sign(y) * (jnp.exp(jnp.abs(y) * jnp.log1p(MU)) - 1.0) / MU


def _call(kernel, x):
    if x.ndim != 1 or x.shape[0] % BLOCK != 0:
        raise ValueError(f"length must be a multiple of {BLOCK}, got {x.shape}")
    return pl.pallas_call(
        kernel,
        grid=(x.shape[0] // BLOCK,),
        in_specs=[pl.BlockSpec((BLOCK,), lambda i: (i,))],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,
    )(x)


def encode(x):
    """Compand a [-1, 1] signal to mu-law domain."""
    return _call(_encode_kernel, x)


def decode(y):
    """Expand a mu-law signal back to linear."""
    return _call(_decode_kernel, y)
