"""L2 — the JAX compute graphs shipped inside ifunc messages.

Every function here takes ONE flat f32 vector and returns a 1-tuple of one
flat f32 vector: the calling convention the rust runtime's `xla_exec` host
symbol implements (`runtime/mod.rs`). Internal reshapes (e.g. packing two
matrices into one flat payload) happen here, so the rust side never needs
shape metadata beyond the manifest's element counts.

These graphs call the L1 Pallas kernels; `aot.py` lowers each to HLO text
once at build time. Python never runs at request time.
"""

import jax.numpy as jnp

from .kernels import axpb, checksum, delta, gemm, mulaw

# Canonical record length for the codec/db workloads (4 codec frames).
SIGNAL_N = 4096
# GEMM offload matrix edge (two 256x256 operands in one payload).
GEMM_N = 256
# Graph-combine vector length.
GRAPH_N = 8192


def delta_enc(x):
    """Encode a SIGNAL_N-sample record (source side of Listing 1.3)."""
    return (delta.encode_frames(x),)


def delta_dec(x):
    """Decode a SIGNAL_N-sample record (target side of Listing 1.3)."""
    return (delta.decode_frames(x),)


def fletcher(x):
    """Checksum a SIGNAL_N-sample record → f32[2]."""
    return (checksum.fletcher(x),)


def decode_insert(x):
    """The full target-side pipeline of the paper's db example: decode the
    delta-coded record, then append its Fletcher checksum.

    Output layout: f32[SIGNAL_N + 2] = [decoded..., s1, s2]. One fused HLO
    module — XLA fuses the codec and checksum so the record is read once.
    """
    decoded = delta.decode_frames(x)
    chk = checksum.fletcher(decoded)
    return (jnp.concatenate([decoded, chk]),)


def voice_enc(x):
    """Full voice-codec source pipeline: mu-law compand, then frame-local
    delta — the lossy + decorrelation stages of the paper's paq8px analog,
    fused into one HLO module."""
    return (delta.encode_frames(mulaw.encode(x)),)


def voice_dec(x):
    """Inverse pipeline: delta decode, then mu-law expand."""
    return (mulaw.decode(delta.decode_frames(x)),)


def gemm256(x):
    """Offloaded GEMM: payload packs A then B (each GEMM_N x GEMM_N)."""
    n = GEMM_N
    a = x[: n * n].reshape(n, n)
    b = x[n * n :].reshape(n, n)
    return (gemm.matmul(a, b).reshape(-1),)


def graph_combine(x):
    """Damped rank update: payload packs rank then contrib (GRAPH_N each);
    output = 0.85*contrib + 0.15*rank (PageRank-style combine)."""
    rank = x[:GRAPH_N]
    contrib = x[GRAPH_N:]
    return (axpb.combine(contrib, rank, a=0.85, b=0.15),)


# Artifact registry: name -> (fn, input_elems, output_elems, description).
ARTIFACTS = {
    "delta_enc": (delta_enc, SIGNAL_N, SIGNAL_N, "frame-local delta encode"),
    "delta_dec": (delta_dec, SIGNAL_N, SIGNAL_N, "frame-local delta decode"),
    "fletcher": (fletcher, SIGNAL_N, 2, "Fletcher-style checksum"),
    "dbdec": (
        decode_insert,
        SIGNAL_N,
        SIGNAL_N + 2,
        "decode + checksum pipeline (paper db example)",
    ),
    "gemm256": (gemm256, 2 * GEMM_N * GEMM_N, GEMM_N * GEMM_N, "tiled 256^2 GEMM offload"),
    "voice_enc": (voice_enc, SIGNAL_N, SIGNAL_N, "mu-law + delta voice encoder"),
    "voice_dec": (voice_dec, SIGNAL_N, SIGNAL_N, "delta + mu-law voice decoder"),
    "graphcmb": (
        graph_combine,
        2 * GRAPH_N,
        GRAPH_N,
        "damped rank combine for graph analytics",
    ),
}
