"""AOT lowering: JAX/Pallas (L2+L1) → HLO text artifacts for the rust
runtime.

For every entry in `model.ARTIFACTS`, emits
  artifacts/<name>.hlo.txt   — HLO text of the jitted function
  artifacts/<name>.json      — manifest (shapes, dtype, description)

HLO *text*, not `lowered.compile().serialize()`: jax >= 0.5 emits protos
with 64-bit instruction ids which the xla crate's xla_extension 0.5.1
rejects (`proto.id() <= INT_MAX`); the text parser on the rust side
reassigns ids, so text round-trips cleanly (see /opt/xla-example/README).

Run via `make artifacts` (a no-op when outputs are newer than inputs).

Usage:
    python -m compile.aot [--out-dir DIR] [--only NAME[,NAME...]]
"""

import argparse
import json
import pathlib

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR → XlaComputation → HLO text (return_tuple=True, so
    the rust side always unwraps a 1-tuple)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_artifact(name: str, out_dir: pathlib.Path) -> dict:
    fn, n_in, n_out, desc = model.ARTIFACTS[name]
    spec = jax.ShapeDtypeStruct((n_in,), jnp.float32)
    lowered = jax.jit(fn).lower(spec)
    text = to_hlo_text(lowered)
    (out_dir / f"{name}.hlo.txt").write_text(text)
    manifest = {
        "name": name,
        "input_shape": [n_in],
        "output_shape": [n_out],
        "dtype": "f32",
        "description": desc,
    }
    (out_dir / f"{name}.json").write_text(json.dumps(manifest, indent=1))
    return {"name": name, "hlo_bytes": len(text), **manifest}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts", help="artifact output directory")
    ap.add_argument("--only", default="", help="comma-separated artifact names")
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    names = [n for n in args.only.split(",") if n] or list(model.ARTIFACTS)
    for name in names:
        info = lower_artifact(name, out_dir)
        print(
            f"  {name:10s}  f32[{info['input_shape'][0]}] -> "
            f"f32[{info['output_shape'][0]}]  ({info['hlo_bytes']} bytes HLO)"
        )
    print(f"wrote {len(names)} artifacts to {out_dir.resolve()}")


if __name__ == "__main__":
    main()
