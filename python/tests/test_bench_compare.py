"""Unit tests for scripts/bench_compare.py: delta math, missing-baseline
tolerance, and the regression-threshold exit path."""

import importlib.util
import json
import sys
from pathlib import Path

import pytest

REPO_ROOT = Path(__file__).resolve().parents[2]
SCRIPT = REPO_ROOT / "scripts" / "bench_compare.py"


@pytest.fixture(scope="module")
def bench_compare():
    spec = importlib.util.spec_from_file_location("bench_compare", SCRIPT)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def write_report(path, rows):
    path.write_text(json.dumps({"series": "micro", "rows": rows}))
    return path


def row(name, median, best=None):
    return {"name": name, "median_ns": median, "best_ns": best or median}


def run_main(bench_compare, monkeypatch, argv):
    monkeypatch.setattr(sys, "argv", ["bench_compare.py", *argv])
    return bench_compare.main()


class TestLoadRows:
    def test_roundtrip_keys_by_name(self, bench_compare, tmp_path):
        p = write_report(tmp_path / "r.json", [row("decode", 12.5), row("verify", 80.0)])
        rows = bench_compare.load_rows(p)
        assert rows["decode"]["median_ns"] == 12.5
        assert set(rows) == {"decode", "verify"}

    def test_rejects_non_micro_report(self, bench_compare, tmp_path):
        p = tmp_path / "bad.json"
        p.write_text(json.dumps({"series": "fig3", "points": []}))
        with pytest.raises(ValueError, match="not a micro bench report"):
            bench_compare.load_rows(p)


class TestMissingBaseline:
    def test_absent_baseline_is_tolerated(self, bench_compare, tmp_path, monkeypatch, capsys):
        cur = write_report(tmp_path / "cur.json", [row("decode", 10.0)])
        rc = run_main(
            bench_compare,
            monkeypatch,
            [str(cur), "--baseline", str(tmp_path / "nope.json")],
        )
        assert rc == 0
        out = capsys.readouterr().out
        assert "no baseline" in out
        assert "skipping comparison" in out

    def test_absent_baseline_skips_even_with_threshold(
        self, bench_compare, tmp_path, monkeypatch
    ):
        # The advisory CI step passes a threshold only in strict local
        # runs, but a missing baseline must never trip it.
        cur = write_report(tmp_path / "cur.json", [row("decode", 10.0)])
        rc = run_main(
            bench_compare,
            monkeypatch,
            [str(cur), "--baseline", str(tmp_path / "nope.json"), "--threshold", "1"],
        )
        assert rc == 0


class TestDeltaMath:
    def test_regression_percent_is_printed(self, bench_compare, tmp_path, monkeypatch, capsys):
        base = write_report(tmp_path / "base.json", [row("decode", 100.0)])
        cur = write_report(tmp_path / "cur.json", [row("decode", 110.0)])
        rc = run_main(bench_compare, monkeypatch, [str(cur), "--baseline", str(base)])
        assert rc == 0
        assert "+10.0%" in capsys.readouterr().out

    def test_improvement_percent_is_negative(
        self, bench_compare, tmp_path, monkeypatch, capsys
    ):
        base = write_report(tmp_path / "base.json", [row("verify", 200.0)])
        cur = write_report(tmp_path / "cur.json", [row("verify", 150.0)])
        rc = run_main(bench_compare, monkeypatch, [str(cur), "--baseline", str(base)])
        assert rc == 0
        assert "-25.0%" in capsys.readouterr().out

    def test_new_and_gone_metrics_are_marked(self, bench_compare, tmp_path, monkeypatch, capsys):
        base = write_report(tmp_path / "base.json", [row("old-stage", 50.0)])
        cur = write_report(tmp_path / "cur.json", [row("new-stage", 60.0)])
        rc = run_main(bench_compare, monkeypatch, [str(cur), "--baseline", str(base)])
        assert rc == 0
        out = capsys.readouterr().out
        assert "new" in out
        assert "gone" in out

    def test_new_rows_never_trip_the_threshold(
        self, bench_compare, tmp_path, monkeypatch, capsys
    ):
        # A new transport adds rows the committed baseline predates (the
        # shm rows of PR 5). However slow those rows are, they are
        # informational: only metrics present in BOTH reports feed the
        # regression threshold.
        base = write_report(tmp_path / "base.json", [row("decode", 100.0)])
        cur = write_report(
            tmp_path / "cur.json",
            [
                row("decode", 101.0),  # within threshold
                row("ifunc shm memcpy+poll+execute (64B)", 9_999_999.0),
                row("invoke_get 1MiB record (streamed, shm)", 9_999_999.0),
            ],
        )
        rc = run_main(
            bench_compare,
            monkeypatch,
            [str(cur), "--baseline", str(base), "--threshold", "5"],
        )
        assert rc == 0
        out = capsys.readouterr().out
        assert "2 new metric(s)" in out
        assert "not a failure" in out

    def test_collective_row_is_new_not_a_regression(
        self, bench_compare, tmp_path, monkeypatch, capsys
    ):
        # The collective-invocation micro row (PR 6) postdates any
        # committed baseline: it must report as "new" and never feed the
        # threshold, exactly like the PR 5 shm rows.
        base = write_report(tmp_path / "base.json", [row("decode", 100.0)])
        cur = write_report(
            tmp_path / "cur.json",
            [
                row("decode", 100.0),
                row("invoke_all (4 workers, 64B)", 9_999_999.0),
            ],
        )
        rc = run_main(
            bench_compare,
            monkeypatch,
            [str(cur), "--baseline", str(base), "--threshold", "5"],
        )
        assert rc == 0
        out = capsys.readouterr().out
        assert "1 new metric(s)" in out
        assert "invoke_all (4 workers, 64B)" in out

    def test_serve_row_is_new_not_a_regression(
        self, bench_compare, tmp_path, monkeypatch, capsys
    ):
        # The concurrent serve front-end micro row (PR 8) postdates any
        # committed baseline: it must report as "new" and never feed the
        # threshold, exactly like the PR 5 shm and PR 6 collective rows.
        base = write_report(tmp_path / "base.json", [row("decode", 100.0)])
        cur = write_report(
            tmp_path / "cur.json",
            [
                row("decode", 100.0),
                row("serve insert (coalesced, 16 clients)", 9_999_999.0),
            ],
        )
        rc = run_main(
            bench_compare,
            monkeypatch,
            [str(cur), "--baseline", str(base), "--threshold", "5"],
        )
        assert rc == 0
        out = capsys.readouterr().out
        assert "1 new metric(s)" in out
        assert "serve insert (coalesced, 16 clients)" in out

    def test_forward_hop_row_is_new_not_a_regression(
        self, bench_compare, tmp_path, monkeypatch, capsys
    ):
        # The mesh forward-hop micro row (PR 9) postdates any committed
        # baseline: it must report as "new" and never feed the threshold,
        # exactly like the PR 5 shm, PR 6 collective, and PR 8 serve rows.
        base = write_report(tmp_path / "base.json", [row("decode", 100.0)])
        cur = write_report(
            tmp_path / "cur.json",
            [
                row("decode", 100.0),
                row("forward hop (64B, mesh)", 9_999_999.0),
            ],
        )
        rc = run_main(
            bench_compare,
            monkeypatch,
            [str(cur), "--baseline", str(base), "--threshold", "5"],
        )
        assert rc == 0
        out = capsys.readouterr().out
        assert "1 new metric(s)" in out
        assert "forward hop (64B, mesh)" in out


class TestThresholdExit:
    def test_regression_beyond_threshold_exits_2(
        self, bench_compare, tmp_path, monkeypatch, capsys
    ):
        base = write_report(tmp_path / "base.json", [row("decode", 100.0)])
        cur = write_report(tmp_path / "cur.json", [row("decode", 120.0)])
        rc = run_main(
            bench_compare,
            monkeypatch,
            [str(cur), "--baseline", str(base), "--threshold", "10"],
        )
        assert rc == 2
        assert "exceeds" in capsys.readouterr().err

    def test_regression_within_threshold_passes(self, bench_compare, tmp_path, monkeypatch):
        base = write_report(tmp_path / "base.json", [row("decode", 100.0)])
        cur = write_report(tmp_path / "cur.json", [row("decode", 104.0)])
        rc = run_main(
            bench_compare,
            monkeypatch,
            [str(cur), "--baseline", str(base), "--threshold", "5"],
        )
        assert rc == 0

    def test_worst_metric_governs(self, bench_compare, tmp_path, monkeypatch):
        # One improving metric must not mask another one regressing.
        base = write_report(
            tmp_path / "base.json", [row("decode", 100.0), row("verify", 100.0)]
        )
        cur = write_report(
            tmp_path / "cur.json", [row("decode", 50.0), row("verify", 130.0)]
        )
        rc = run_main(
            bench_compare,
            monkeypatch,
            [str(cur), "--baseline", str(base), "--threshold", "20"],
        )
        assert rc == 2


class TestRequireBaselineRows:
    def test_gone_row_without_flag_stays_advisory(
        self, bench_compare, tmp_path, monkeypatch, capsys
    ):
        base = write_report(
            tmp_path / "base.json", [row("decode", 100.0), row("dropped-stage", 50.0)]
        )
        cur = write_report(tmp_path / "cur.json", [row("decode", 100.0)])
        rc = run_main(bench_compare, monkeypatch, [str(cur), "--baseline", str(base)])
        assert rc == 0
        assert "gone" in capsys.readouterr().out

    def test_gone_row_with_flag_exits_3(self, bench_compare, tmp_path, monkeypatch, capsys):
        # The CI guard: a row present in the committed baseline but absent
        # from the fresh report (renamed or silently dropped bench) fails.
        base = write_report(
            tmp_path / "base.json",
            [row("decode", 100.0), row("VM run (counter body, compiled)", 40.0)],
        )
        cur = write_report(tmp_path / "cur.json", [row("decode", 100.0)])
        rc = run_main(
            bench_compare,
            monkeypatch,
            [str(cur), "--baseline", str(base), "--require-baseline-rows"],
        )
        assert rc == 3
        err = capsys.readouterr().err
        assert "missing from the current report" in err
        assert "VM run (counter body, compiled)" in err

    def test_new_rows_do_not_trip_the_flag(self, bench_compare, tmp_path, monkeypatch):
        # Extra rows in the current report are fine — the flag only guards
        # against *losing* coverage the baseline already tracks.
        base = write_report(tmp_path / "base.json", [row("decode", 100.0)])
        cur = write_report(
            tmp_path / "cur.json",
            [row("decode", 100.0), row("AM send+flush+progress (64B eager, zero-copy)", 900.0)],
        )
        rc = run_main(
            bench_compare,
            monkeypatch,
            [str(cur), "--baseline", str(base), "--require-baseline-rows"],
        )
        assert rc == 0

    def test_absent_baseline_with_flag_still_skips(
        self, bench_compare, tmp_path, monkeypatch, capsys
    ):
        # No baseline committed yet: nothing to require rows against.
        cur = write_report(tmp_path / "cur.json", [row("decode", 100.0)])
        rc = run_main(
            bench_compare,
            monkeypatch,
            [
                str(cur),
                "--baseline",
                str(tmp_path / "nope.json"),
                "--require-baseline-rows",
            ],
        )
        assert rc == 0
        assert "skipping comparison" in capsys.readouterr().out


class TestMalformedInput:
    def test_malformed_current_exits_1(self, bench_compare, tmp_path, monkeypatch, capsys):
        base = write_report(tmp_path / "base.json", [row("decode", 100.0)])
        cur = tmp_path / "cur.json"
        cur.write_text("{not json")
        rc = run_main(bench_compare, monkeypatch, [str(cur), "--baseline", str(base)])
        assert rc == 1
        assert "bench_compare:" in capsys.readouterr().err

    def test_wrong_series_current_exits_1(self, bench_compare, tmp_path, monkeypatch):
        base = write_report(tmp_path / "base.json", [row("decode", 100.0)])
        cur = tmp_path / "cur.json"
        cur.write_text(json.dumps({"series": "other", "rows": []}))
        rc = run_main(bench_compare, monkeypatch, [str(cur), "--baseline", str(base)])
        assert rc == 1
