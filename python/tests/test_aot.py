"""AOT path: lowering emits loadable HLO text + consistent manifests."""

import json

import pytest

pytest.importorskip("jax", reason="JAX toolchain not installed")

from compile import aot, model


@pytest.fixture(scope="module")
def artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    infos = {name: aot.lower_artifact(name, out) for name in model.ARTIFACTS}
    return out, infos


def test_all_artifacts_lower(artifacts):
    out, infos = artifacts
    for name in model.ARTIFACTS:
        hlo = (out / f"{name}.hlo.txt").read_text()
        assert hlo.startswith("HloModule"), f"{name}: not HLO text"
        assert "ENTRY" in hlo
        manifest = json.loads((out / f"{name}.json").read_text())
        assert manifest["name"] == name
        assert manifest["dtype"] == "f32"


def test_manifest_matches_registry(artifacts):
    _, infos = artifacts
    for name, (_, n_in, n_out, _) in model.ARTIFACTS.items():
        assert infos[name]["input_shape"] == [n_in]
        assert infos[name]["output_shape"] == [n_out]


def test_hlo_entry_signature_is_flat_f32(artifacts):
    out, _ = artifacts
    for name, (_, n_in, n_out, _) in model.ARTIFACTS.items():
        hlo = (out / f"{name}.hlo.txt").read_text()
        # Entry takes f32[n_in] and returns a tuple containing f32[n_out].
        assert f"f32[{n_in}]" in hlo, name
        assert f"f32[{n_out}]" in hlo, name


def test_pallas_lowering_is_interpreted(artifacts):
    # interpret=True must leave no Mosaic/TPU custom-calls in the HLO —
    # the rust CPU PJRT client could not execute those.
    out, _ = artifacts
    for name in model.ARTIFACTS:
        hlo = (out / f"{name}.hlo.txt").read_text()
        assert "tpu_custom_call" not in hlo, name
        assert "mosaic" not in hlo.lower(), name
