"""L2 correctness: the flat-f32 model graphs behave and compose."""

import pytest

pytest.importorskip("numpy", reason="numpy not installed")
pytest.importorskip("jax", reason="JAX toolchain not installed")
pytest.importorskip("hypothesis", reason="hypothesis not installed")

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def _vec(n, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(n, dtype=np.float32))


def test_artifact_registry_shapes():
    for name, (fn, n_in, n_out, _) in model.ARTIFACTS.items():
        out = fn(_vec(n_in, 42))
        assert isinstance(out, tuple) and len(out) == 1, name
        assert out[0].shape == (n_out,), f"{name}: {out[0].shape} != ({n_out},)"
        assert out[0].dtype == jnp.float32, name


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**32 - 1))
def test_enc_then_dbdec_recovers_record(seed):
    x = _vec(model.SIGNAL_N, seed)
    (encoded,) = model.delta_enc(x)
    (out,) = model.decode_insert(encoded)
    decoded, chk = out[: model.SIGNAL_N], out[model.SIGNAL_N :]
    np.testing.assert_allclose(decoded, x, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(chk, ref.fletcher(decoded), rtol=2e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**32 - 1))
def test_voice_codec_roundtrip(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(-0.95, 0.95, model.SIGNAL_N).astype(np.float32))
    (enc,) = model.voice_enc(x)
    (dec,) = model.voice_dec(enc)
    np.testing.assert_allclose(dec, x, rtol=5e-3, atol=5e-4)


def test_gemm256_packing():
    n = model.GEMM_N
    rng = np.random.default_rng(0)
    a = rng.standard_normal((n, n), dtype=np.float32)
    b = rng.standard_normal((n, n), dtype=np.float32)
    flat = jnp.asarray(np.concatenate([a.reshape(-1), b.reshape(-1)]))
    (out,) = model.gemm256(flat)
    np.testing.assert_allclose(out.reshape(n, n), a @ b, rtol=1e-4, atol=1e-3)


def test_graph_combine_damping():
    n = model.GRAPH_N
    rank = jnp.ones(n, jnp.float32)
    contrib = jnp.full((n,), 2.0, jnp.float32)
    flat = jnp.concatenate([rank, contrib])
    (out,) = model.graph_combine(flat)
    np.testing.assert_allclose(out, 0.85 * 2.0 + 0.15 * 1.0, rtol=1e-6)
