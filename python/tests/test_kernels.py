"""L1 correctness: every Pallas kernel against its pure-jnp oracle.

Hypothesis sweeps shapes (multiples of the block sizes) and value
distributions; fixed examples pin the edge cases.
"""

import pytest

pytest.importorskip("numpy", reason="numpy not installed")
pytest.importorskip("jax", reason="JAX toolchain not installed")
pytest.importorskip("hypothesis", reason="hypothesis not installed")

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import axpb, checksum, delta, gemm, mulaw, ref

SETTINGS = dict(max_examples=25, deadline=None)


def _signal(n, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(n, dtype=np.float32))


# ---------------------------------------------------------------- delta

@settings(**SETTINGS)
@given(frames=st.integers(1, 8), seed=st.integers(0, 2**32 - 1))
def test_delta_encode_matches_ref(frames, seed):
    x = _signal(frames * delta.FRAME, seed)
    np.testing.assert_allclose(delta.encode_frames(x), ref.delta_encode(x), rtol=1e-6)


@settings(**SETTINGS)
@given(frames=st.integers(1, 8), seed=st.integers(0, 2**32 - 1))
def test_delta_decode_matches_ref(frames, seed):
    y = _signal(frames * delta.FRAME, seed)
    np.testing.assert_allclose(delta.decode_frames(y), ref.delta_decode(y), rtol=1e-5, atol=1e-4)


@settings(**SETTINGS)
@given(frames=st.integers(1, 4), seed=st.integers(0, 2**32 - 1))
def test_delta_roundtrip_is_identity(frames, seed):
    x = _signal(frames * delta.FRAME, seed)
    back = delta.decode_frames(delta.encode_frames(x))
    np.testing.assert_allclose(back, x, rtol=1e-4, atol=1e-4)


def test_delta_frames_are_independent():
    # Changing frame 1 must not affect frame 0's encoding.
    x = _signal(2 * delta.FRAME, 1)
    y = x.at[delta.FRAME + 7].add(100.0)
    ex, ey = delta.encode_frames(x), delta.encode_frames(y)
    np.testing.assert_array_equal(ex[: delta.FRAME], ey[: delta.FRAME])


def test_delta_rejects_ragged_length():
    with pytest.raises(ValueError):
        delta.encode_frames(jnp.zeros(delta.FRAME + 1, jnp.float32))


def test_delta_constant_signal():
    x = jnp.full((delta.FRAME,), 3.0, jnp.float32)
    e = delta.encode_frames(x)
    assert float(e[0]) == 3.0
    np.testing.assert_allclose(e[1:], 0.0)


# ------------------------------------------------------------- checksum

@settings(**SETTINGS)
@given(blocks=st.integers(1, 8), seed=st.integers(0, 2**32 - 1))
def test_fletcher_matches_ref(blocks, seed):
    x = _signal(blocks * checksum.BLOCK, seed)
    np.testing.assert_allclose(checksum.fletcher(x), ref.fletcher(x), rtol=2e-4)


def test_fletcher_detects_reorder():
    x = _signal(checksum.BLOCK, 3)
    y = jnp.concatenate([x[1:], x[:1]])
    assert not np.allclose(checksum.fletcher(x)[1], checksum.fletcher(y)[1])


def test_fletcher_zero_signal():
    np.testing.assert_array_equal(
        checksum.fletcher(jnp.zeros(checksum.BLOCK, jnp.float32)), jnp.zeros(2)
    )


# ----------------------------------------------------------------- gemm

@settings(max_examples=8, deadline=None)
@given(
    m=st.sampled_from([128, 256]),
    n=st.sampled_from([128, 256]),
    k=st.sampled_from([128, 256, 384]),
    seed=st.integers(0, 2**32 - 1),
)
def test_gemm_matches_ref(m, n, k, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal((m, k), dtype=np.float32))
    b = jnp.asarray(rng.standard_normal((k, n), dtype=np.float32))
    np.testing.assert_allclose(gemm.matmul(a, b), ref.matmul(a, b), rtol=1e-4, atol=1e-3)


def test_gemm_identity():
    eye = jnp.eye(128, dtype=jnp.float32)
    a = _signal(128 * 128, 9).reshape(128, 128)
    np.testing.assert_allclose(gemm.matmul(a, eye), a, rtol=1e-6)


def test_gemm_rejects_untiled_shapes():
    with pytest.raises(ValueError):
        gemm.matmul(jnp.zeros((100, 128), jnp.float32), jnp.zeros((128, 128), jnp.float32))


# ----------------------------------------------------------------- axpb

@settings(**SETTINGS)
@given(
    blocks=st.integers(1, 4),
    a=st.floats(0.0, 1.0, width=32),
    seed=st.integers(0, 2**32 - 1),
)
def test_combine_matches_ref(blocks, a, seed):
    x = _signal(blocks * axpb.BLOCK, seed)
    y = _signal(blocks * axpb.BLOCK, seed ^ 0xFFFF)
    np.testing.assert_allclose(
        axpb.combine(x, y, a=a, b=1.0 - a),
        ref.combine(x, y, a=a, b=1.0 - a),
        rtol=1e-5,
        atol=1e-5,
    )


# ---------------------------------------------------------------- mulaw

@settings(**SETTINGS)
@given(blocks=st.integers(1, 4), seed=st.integers(0, 2**32 - 1))
def test_mulaw_encode_matches_ref(blocks, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(-1, 1, blocks * mulaw.BLOCK).astype(np.float32))
    np.testing.assert_allclose(mulaw.encode(x), ref.mulaw_encode(x), rtol=1e-5, atol=1e-6)


@settings(**SETTINGS)
@given(blocks=st.integers(1, 4), seed=st.integers(0, 2**32 - 1))
def test_mulaw_roundtrip(blocks, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(-1, 1, blocks * mulaw.BLOCK).astype(np.float32))
    np.testing.assert_allclose(mulaw.decode(mulaw.encode(x)), x, rtol=1e-3, atol=1e-4)


def test_mulaw_compands_dynamic_range():
    # Small amplitudes are expanded relative to large ones: |enc(0.01)| /
    # 0.01 must exceed |enc(0.9)| / 0.9.
    x = jnp.zeros(mulaw.BLOCK, jnp.float32).at[0].set(0.01).at[1].set(0.9)
    y = mulaw.encode(x)
    assert float(y[0]) / 0.01 > float(y[1]) / 0.9


def test_mulaw_odd_symmetry():
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.uniform(0, 1, mulaw.BLOCK).astype(np.float32))
    np.testing.assert_allclose(mulaw.encode(-x), -mulaw.encode(x), rtol=1e-6)


def test_combine_rejects_mismatched_shapes():
    with pytest.raises(ValueError):
        axpb.combine(
            jnp.zeros(axpb.BLOCK, jnp.float32), jnp.zeros(2 * axpb.BLOCK, jnp.float32)
        )
