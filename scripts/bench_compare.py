#!/usr/bin/env python3
"""Diff a micro-benchmark JSON report against a committed baseline.

The ``micro`` bench (``cargo bench --bench micro -- --json PATH``) emits
``{"series":"micro","rows":[{"name":..,"median_ns":..,"best_ns":..},..]}``.
This script prints per-metric deltas between a current report and a
baseline so perf regressions are visible in PRs.

Usage:
    python scripts/bench_compare.py CURRENT.json [--baseline PATH]
                                    [--threshold PCT] [--require-baseline-rows]

Exit codes: 0 on success or when the baseline is absent (the comparison is
advisory — CI runs it as a non-blocking step); 1 on malformed input; 2 when
``--threshold`` is given and some metric regressed beyond it (for local,
opt-in strict runs); 3 when ``--require-baseline-rows`` is given and a row
present in the committed baseline is missing from the current report (a
renamed or silently dropped benchmark — CI runs this as a blocking guard so
the perf history can't lose coverage unnoticed).

To (re)seed the baseline, download ``micro-report.json`` from a trusted CI
run's artifacts and commit it at the default baseline path.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

DEFAULT_BASELINE = Path("benches/baseline/micro-baseline.json")


def load_rows(path: Path) -> dict[str, dict[str, float]]:
    report = json.loads(path.read_text())
    if report.get("series") != "micro" or "rows" not in report:
        raise ValueError(f"{path}: not a micro bench report")
    return {r["name"]: r for r in report["rows"]}


def fmt_ns(ns: float) -> str:
    return f"{ns:,.0f}"


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("current", type=Path, help="micro-report.json from this run")
    ap.add_argument("--baseline", type=Path, default=DEFAULT_BASELINE)
    ap.add_argument(
        "--threshold",
        type=float,
        default=None,
        metavar="PCT",
        help="exit 2 if any median regresses more than PCT percent",
    )
    ap.add_argument(
        "--require-baseline-rows",
        action="store_true",
        help="exit 3 if any baseline row is missing from the current report",
    )
    args = ap.parse_args()

    if not args.baseline.exists():
        print(
            f"bench_compare: no baseline at {args.baseline} — skipping comparison.\n"
            "  Seed one by committing a micro-report.json from a trusted CI run."
        )
        return 0

    try:
        base = load_rows(args.baseline)
        cur = load_rows(args.current)
    except (OSError, ValueError, KeyError, json.JSONDecodeError) as e:
        print(f"bench_compare: {e}", file=sys.stderr)
        return 1

    width = max((len(n) for n in cur), default=20)
    print(f"{'metric':<{width}}  {'baseline':>12}  {'current':>12}  {'delta':>8}")
    worst = 0.0
    new_rows = []
    for name, row in cur.items():
        b = base.get(name)
        if b is None:
            # A metric the baseline predates (e.g. the shm transport
            # rows): informational only. New rows never feed `worst`, so
            # they can never trip --threshold — only rows present in BOTH
            # reports are compared.
            print(f"{name:<{width}}  {'—':>12}  {fmt_ns(row['median_ns']):>12}  {'new':>8}")
            new_rows.append(name)
            continue
        delta = (row["median_ns"] - b["median_ns"]) / b["median_ns"] * 100.0
        worst = max(worst, delta)
        print(
            f"{name:<{width}}  {fmt_ns(b['median_ns']):>12}  "
            f"{fmt_ns(row['median_ns']):>12}  {delta:>+7.1f}%"
        )
    gone_rows = []
    for name in base:
        if name not in cur:
            print(f"{name:<{width}}  {fmt_ns(base[name]['median_ns']):>12}  "
                  f"{'—':>12}  {'gone':>8}")
            gone_rows.append(name)
    if new_rows:
        print(
            f"\nbench_compare: {len(new_rows)} new metric(s) with no baseline row "
            "(informational, not a failure) — refresh the baseline from a trusted "
            "CI run to start tracking them."
        )

    if args.require_baseline_rows and gone_rows:
        print(
            f"\nbench_compare: {len(gone_rows)} baseline row(s) missing from the "
            f"current report: {', '.join(sorted(gone_rows))}.\n"
            "  A benchmark was renamed or dropped — restore the row or refresh "
            "the committed baseline deliberately.",
            file=sys.stderr,
        )
        return 3

    if args.threshold is not None and worst > args.threshold:
        print(f"\nbench_compare: worst regression {worst:+.1f}% exceeds "
              f"threshold {args.threshold:.1f}%", file=sys.stderr)
        return 2
    return 0


if __name__ == "__main__":
    sys.exit(main())
