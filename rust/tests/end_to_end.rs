//! Integration tests: the full three-layer stack.
//!
//! These require `artifacts/` (emit with `python -m compile.aot` from
//! `python/`). They exercise: JAX/Pallas AOT artifacts → PJRT runtime →
//! HLO-carrying ifuncs over the fabric → target-side compile + GOT link +
//! invoke → record store.

use std::path::PathBuf;

use two_chains::coordinator::{
    apps::{DecodeInsertIfunc, DEC_OUT, SIGNAL_N},
    Cluster, ClusterConfig, Target,
};
use two_chains::fabric::{Fabric, WireConfig};
use two_chains::ifunc::{HloIfuncLibrary, IfuncRing, SourceArgs, TargetArgs};
use two_chains::runtime::{with_runtime, ArtifactManifest};
use two_chains::ucp::{Context, ContextConfig, Worker};
use two_chains::util::XorShift;

/// The AOT path needs two things a clean checkout may not have: the
/// artifacts (`python -m compile.aot`, which needs JAX) and a real PJRT
/// backend (the offline build links the xla stub — see `rust/src/xla.rs`).
/// The seed hard-asserted on the artifacts, which broke `cargo test` from
/// a clean checkout; per the paper these runs exercise the §3.2 / §5.1
/// *applications* of the ifunc mechanism, not the mechanism itself (which
/// the rest of the suite covers), so absence downgrades to a skip.
fn artifacts_dir() -> Option<PathBuf> {
    let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !two_chains::runtime::pjrt_available() {
        eprintln!("skipping: PJRT backend is stubbed in this build (rust/src/xla.rs)");
        return None;
    }
    if !d.join("delta_enc.hlo.txt").exists() {
        eprintln!("skipping: artifacts missing — run `python -m compile.aot` first");
        return None;
    }
    Some(d)
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => return,
        }
    };
}

fn ctx_pair(dir: PathBuf) -> (std::sync::Arc<Context>, std::sync::Arc<Context>) {
    let fabric = Fabric::new(2, WireConfig::off());
    let cfg = ContextConfig { lib_dir: Some(dir), ..Default::default() };
    let src = Context::new(fabric.node(0), cfg.clone()).unwrap();
    let dst = Context::new(fabric.node(1), cfg).unwrap();
    (src, dst)
}

/// The artifacts load and execute correctly straight through PJRT.
#[test]
fn runtime_executes_delta_roundtrip() {
    let dir = require_artifacts!();
    let mut rng = XorShift::new(7);
    let record = rng.f32s(SIGNAL_N);
    let (enc, dec) = with_runtime(|rt| {
        rt.ensure_compiled_file("delta_enc", &dir.join("delta_enc.hlo.txt"))?;
        rt.ensure_compiled_file("delta_dec", &dir.join("delta_dec.hlo.txt"))?;
        let enc = rt.execute_f32("delta_enc", &record, &[SIGNAL_N as i64])?;
        let dec = rt.execute_f32("delta_dec", &enc, &[SIGNAL_N as i64])?;
        Ok((enc, dec))
    })
    .unwrap();
    assert_eq!(enc.len(), SIGNAL_N);
    for (a, b) in dec.iter().zip(&record) {
        assert!((a - b).abs() < 1e-3, "decode mismatch: {a} vs {b}");
    }
    // The encoding is not the identity.
    assert!(enc.iter().zip(&record).any(|(a, b)| (a - b).abs() > 1e-6));
}

/// An HLO-backed ifunc registered from the library dir executes on the
/// target, compiling the artifact *from the message bytes*.
#[test]
fn hlo_ifunc_over_fabric() {
    let (src, dst) = ctx_pair(require_artifacts!());
    let mut ring = IfuncRing::new(&dst, 1 << 20).unwrap();
    let ws = Worker::new(&src);
    let wd = Worker::new(&dst);
    let ep = ws.connect(&wd).unwrap();

    // `delta_dec` resolved from artifacts/ via UCX_IFUNC_LIB_DIR analog.
    let h = src.register_ifunc("delta_dec").unwrap();
    let mut rng = XorShift::new(3);
    let encoded = rng.f32s(SIGNAL_N);
    let msg = h.msg_create(&SourceArgs::f32s(&encoded)).unwrap();
    ep.ifunc_msg_send_nbix(&msg, ring.remote_addr(), ring.rkey()).unwrap();
    ep.flush().unwrap();

    let mut args = TargetArgs::none();
    dst.poll_ifunc_blocking(&mut ring, &mut args).unwrap();
    // The ifunc decoded the payload in place in the ring: executions
    // happened on the target's thread-local PJRT runtime.
    assert_eq!(args.last_return, Some(SIGNAL_N as u64));
    assert_eq!(dst.ifunc_cache().len(), 1);
}

/// Repeated sends of the same type hit the auto-registration cache and
/// compile PJRT exactly once.
#[test]
fn hlo_compile_happens_once() {
    let (src, dst) = ctx_pair(require_artifacts!());
    let mut ring = IfuncRing::new(&dst, 1 << 20).unwrap();
    let ws = Worker::new(&src);
    let wd = Worker::new(&dst);
    let ep = ws.connect(&wd).unwrap();
    let mut cursor = two_chains::ifunc::SenderCursor::new(ring.size());

    let h = src.register_ifunc("fletcher").unwrap();
    let msg = h.msg_create(&SourceArgs::f32s(&[1.0; SIGNAL_N])).unwrap();
    let mut args = TargetArgs::none();
    for _ in 0..5 {
        ep.ifunc_msg_send_cursor(&msg, &mut cursor, ring.rkey()).unwrap();
        ep.flush().unwrap();
        dst.poll_ifunc_blocking(&mut ring, &mut args).unwrap();
    }
    use std::sync::atomic::Ordering;
    assert_eq!(dst.ifunc_cache().misses.load(Ordering::Relaxed), 1);
    assert_eq!(dst.ifunc_cache().hits.load(Ordering::Relaxed), 4);
    // s1 = sum of 4096 ones = 4096; record_result-free check via return:
    // fletcher output is 2 elems.
    assert_eq!(args.last_return, Some(2));
}

/// The paper's §3.2 example end-to-end on a cluster: encode at the host,
/// inject, decode + checksum + insert on the data-owning worker.
#[test]
fn decode_insert_cluster_end_to_end() {
    let dir = require_artifacts!();
    let cluster =
        Cluster::launch(ClusterConfig::builder().workers(2).build().unwrap(), |_, _, _| {})
            .unwrap();
    cluster
        .leader
        .library_dir()
        .install(Box::new(DecodeInsertIfunc::load(&dir).unwrap()));

    let d = cluster.dispatcher();
    let h = d.register("dbdec").unwrap();
    let mut rng = XorShift::new(11);
    let mut records = Vec::new();
    for key in 0..10u64 {
        let record = rng.f32s(SIGNAL_N);
        let msg = h.msg_create(&DecodeInsertIfunc::args(key, &record)).unwrap();
        d.send(Target::Key(key), &msg).unwrap();
        records.push((key, record));
    }
    d.barrier().unwrap();
    assert_eq!(d.total_executed(), 10);

    for (key, record) in records {
        let w = d.route_key(key);
        let stored = cluster.workers[w]
            .store
            .get(key)
            .unwrap_or_else(|| panic!("record {key} missing on worker {w}"));
        assert_eq!(stored.len(), SIGNAL_N);
        for (a, b) in stored.iter().zip(&record) {
            assert!((a - b).abs() < 1e-3, "key {key}: {a} vs {b}");
        }
    }
    cluster.shutdown().unwrap();
}

/// The decode output layout includes the checksum words (DEC_OUT).
#[test]
fn dbdec_manifest_matches_layout() {
    let dir = require_artifacts!();
    let manifest =
        ArtifactManifest::from_json(&std::fs::read_to_string(dir.join("dbdec.json")).unwrap())
            .unwrap();
    assert_eq!(manifest.input_elems(), SIGNAL_N);
    assert_eq!(manifest.output_elems(), DEC_OUT);
}

/// HloIfuncLibrary built from parts works without any files.
#[test]
fn hlo_library_from_parts() {
    let dir = require_artifacts!();
    let manifest = ArtifactManifest::from_json(
        &std::fs::read_to_string(dir.join("graphcmb.json")).unwrap(),
    )
    .unwrap();
    let hlo = std::fs::read(dir.join("graphcmb.hlo.txt")).unwrap();
    let lib = HloIfuncLibrary::from_parts("graphcmb", manifest, hlo);

    let (src, dst) = ctx_pair(dir);
    src.library_dir().install(Box::new(lib));
    let mut ring = IfuncRing::new(&dst, 1 << 20).unwrap();
    let ws = Worker::new(&src);
    let wd = Worker::new(&dst);
    let ep = ws.connect(&wd).unwrap();

    let n = 8192;
    let mut input = vec![1.0f32; n]; // rank
    input.extend(vec![2.0f32; n]); // contrib
    let h = src.register_ifunc("graphcmb").unwrap();
    let msg = h.msg_create(&SourceArgs::f32s(&input)).unwrap();
    ep.ifunc_msg_send_nbix(&msg, ring.remote_addr(), ring.rkey()).unwrap();
    ep.flush().unwrap();
    let mut args = TargetArgs::none();
    dst.poll_ifunc_blocking(&mut ring, &mut args).unwrap();
    assert_eq!(args.last_return, Some(n as u64));
}
