//! Security tests — §3.5 of the paper plus the verifier the bytecode
//! substrate adds on top.
//!
//! "If the process accesses the memory with an invalid RKEY, the request
//! gets rejected at the hardware level" — and beyond the paper: hostile
//! *code* (out-of-bounds access, runaway loops, unresolved symbols,
//! ill-formed frames) is contained by the verifier/interpreter and never
//! takes the target down.

use std::sync::atomic::Ordering;

use two_chains::fabric::{Fabric, MemPerm, WireConfig};
use two_chains::ifunc::builtin::{CounterIfunc, OutOfBoundsIfunc};
use two_chains::ifunc::message::CodeImage;
use two_chains::ifunc::{IfuncRing, PollResult, SenderCursor, SourceArgs, TargetArgs};
use two_chains::ucp::{Context, ContextConfig, Worker};
use two_chains::vm::Assembler;

type Pair =
    (std::sync::Arc<Context>, std::sync::Arc<Context>, std::sync::Arc<two_chains::ucp::Endpoint>);

fn pair() -> Pair {
    let fabric = Fabric::new(2, WireConfig::off());
    let src = Context::new(fabric.node(0), ContextConfig::default()).unwrap();
    let dst = Context::new(fabric.node(1), ContextConfig::default()).unwrap();
    let ws = Worker::new(&src);
    let wd = Worker::new(&dst);
    let ep = ws.connect(&wd).unwrap();
    (src, dst, ep)
}

#[test]
fn guessed_rkey_cannot_write_ring() {
    let (_src, dst, ep) = pair();
    let ring = IfuncRing::new(&dst, 1 << 16).unwrap();
    // Attacker guesses rkeys near the real one.
    for delta in [1u32, 2, 0x100, 0xDEAD] {
        ep.put_nbi(ring.rkey().wrapping_add(delta), 0, b"evil").unwrap();
        assert!(ep.qp().flush().is_err(), "guessed rkey must be rejected");
    }
    assert!(dst.node().stats.rejected.load(Ordering::Relaxed) >= 4);
}

#[test]
fn read_only_region_rejects_ifunc_injection() {
    let (src, dst, ep) = pair();
    let mr = dst.mem_map(1 << 16, MemPerm::REMOTE_READ);
    src.library_dir().install(Box::new(CounterIfunc::default()));
    let h = src.register_ifunc("counter").unwrap();
    let msg = h.msg_create(&SourceArgs::bytes(vec![0; 8])).unwrap();
    ep.ifunc_msg_send_nbix(&msg, 0, mr.rkey()).unwrap();
    assert!(ep.qp().flush().is_err());
    // Nothing landed.
    assert!(mr.local_slice().iter().all(|&b| b == 0));
}

#[test]
fn hostile_oob_code_is_contained() {
    let (src, dst, ep) = pair();
    let mut ring = IfuncRing::new(&dst, 1 << 16).unwrap();
    src.library_dir().install(Box::new(OutOfBoundsIfunc));
    let h = src.register_ifunc("oob").unwrap();
    let msg = h.msg_create(&SourceArgs::bytes(vec![0; 16])).unwrap();
    ep.ifunc_msg_send_nbix(&msg, 0, ring.rkey()).unwrap();
    ep.flush().unwrap();

    let mut args = TargetArgs::none();
    let err = dst.poll_ifunc_blocking(&mut ring, &mut args).unwrap_err();
    assert!(err.to_string().contains("oob"), "{err}");

    // The target keeps serving: a good message afterwards executes.
    src.library_dir().install(Box::new(CounterIfunc::default()));
    let h2 = src.register_ifunc("counter").unwrap();
    let msg2 = h2.msg_create(&SourceArgs::bytes(vec![0; 8])).unwrap();
    let mut cursor = SenderCursor::new(ring.size());
    cursor.place(msg.len()).unwrap(); // account for the consumed bad frame
    ep.ifunc_msg_send_cursor(&msg2, &mut cursor, ring.rkey()).unwrap();
    ep.flush().unwrap();
    dst.poll_ifunc_blocking(&mut ring, &mut args).unwrap();
    assert_eq!(dst.symbols().counter_value(), 1);
}

#[test]
fn runaway_loop_exhausts_fuel_not_the_host() {
    let (src, dst, ep) = pair();
    let mut ring = IfuncRing::new(&dst, 1 << 16).unwrap();

    struct SpinIfunc;
    impl two_chains::ifunc::IfuncLibrary for SpinIfunc {
        fn name(&self) -> &str {
            "spin"
        }
        fn payload_get_max_size(&self, a: &SourceArgs) -> usize {
            a.len()
        }
        fn payload_init(&self, p: &mut [u8], a: &SourceArgs) -> two_chains::Result<usize> {
            p[..a.len()].copy_from_slice(a.as_bytes());
            Ok(a.len())
        }
        fn code(&self) -> CodeImage {
            let mut a = Assembler::new();
            let top = a.label();
            a.bind(top);
            a.jmp(top);
            let (vm_code, imports) = a.assemble();
            CodeImage { imports, vm_code, hlo: vec![] }
        }
    }
    src.library_dir().install(Box::new(SpinIfunc));
    let h = src.register_ifunc("spin").unwrap();
    let msg = h.msg_create(&SourceArgs::bytes(vec![0; 8])).unwrap();
    ep.ifunc_msg_send_nbix(&msg, 0, ring.rkey()).unwrap();
    ep.flush().unwrap();
    let mut args = TargetArgs::none();
    let err = dst.poll_ifunc_blocking(&mut ring, &mut args).unwrap_err();
    assert!(err.to_string().contains("fuel"), "{err}");
}

/// Capability gating (the analysis pass's third consumer): a target whose
/// context restricts the host-call allowlist refuses injected code whose
/// *reachable* call surface strays outside it — at link time, before a
/// single instruction runs. The denial is counted, the hostile frame is
/// consumed, and code within the envelope still executes afterwards.
#[test]
fn capability_gate_contains_unauthorized_host_calls() {
    use two_chains::vm::CapabilityPolicy;

    let fabric = Fabric::new(2, WireConfig::off());
    let src = Context::new(fabric.node(0), ContextConfig::default()).unwrap();
    let dst = Context::new(
        fabric.node(1),
        ContextConfig { caps: CapabilityPolicy::only(["log"]), ..Default::default() },
    )
    .unwrap();
    let ws = Worker::new(&src);
    let wd = Worker::new(&dst);
    let ep = ws.connect(&wd).unwrap();
    let mut ring = IfuncRing::new(&dst, 1 << 16).unwrap();

    src.library_dir().install(Box::new(CounterIfunc::default()));
    let h = src.register_ifunc("counter").unwrap();
    let msg = h.msg_create(&SourceArgs::bytes(vec![0; 8])).unwrap();
    ep.ifunc_msg_send_nbix(&msg, 0, ring.rkey()).unwrap();
    ep.flush().unwrap();

    let mut args = TargetArgs::none();
    let err = dst.poll_ifunc_blocking(&mut ring, &mut args).unwrap_err();
    assert!(err.to_string().contains("capability denied"), "{err}");
    assert!(err.to_string().contains("counter_add"), "{err}");
    assert_eq!(dst.symbols().counter_value(), 0, "denied code must never run");
    assert_eq!(dst.analysis_stats().snapshot().1, 1, "denial is counted");
    assert_eq!(ring.consumed, 1, "denied frame must be consumed");

    // The target keeps serving code inside its envelope: a pure-compute
    // ifunc with no reachable host calls executes fine.
    struct PureIfunc;
    impl two_chains::ifunc::IfuncLibrary for PureIfunc {
        fn name(&self) -> &str {
            "pure"
        }
        fn payload_get_max_size(&self, a: &SourceArgs) -> usize {
            a.len()
        }
        fn payload_init(&self, p: &mut [u8], a: &SourceArgs) -> two_chains::Result<usize> {
            p[..a.len()].copy_from_slice(a.as_bytes());
            Ok(a.len())
        }
        fn code(&self) -> CodeImage {
            let mut a = Assembler::new();
            a.ldi(0, 7).halt();
            let (vm_code, imports) = a.assemble();
            CodeImage { imports, vm_code, hlo: vec![] }
        }
    }
    src.library_dir().install(Box::new(PureIfunc));
    let h2 = src.register_ifunc("pure").unwrap();
    let msg2 = h2.msg_create(&SourceArgs::bytes(vec![0; 8])).unwrap();
    let mut cursor = SenderCursor::new(ring.size());
    cursor.place(msg.len()).unwrap();
    ep.ifunc_msg_send_cursor(&msg2, &mut cursor, ring.rkey()).unwrap();
    ep.flush().unwrap();
    assert!(matches!(
        dst.poll_ifunc(&mut ring, &mut args).unwrap(),
        PollResult::Executed(_)
    ));
    assert_eq!(dst.analysis_stats().snapshot().1, 1, "no further denials");
}

#[test]
fn unresolved_import_is_a_link_error() {
    let (src, dst, ep) = pair();
    let mut ring = IfuncRing::new(&dst, 1 << 16).unwrap();

    struct NeedsMissing;
    impl two_chains::ifunc::IfuncLibrary for NeedsMissing {
        fn name(&self) -> &str {
            "missing"
        }
        fn payload_get_max_size(&self, a: &SourceArgs) -> usize {
            a.len()
        }
        fn payload_init(&self, p: &mut [u8], a: &SourceArgs) -> two_chains::Result<usize> {
            p[..a.len()].copy_from_slice(a.as_bytes());
            Ok(a.len())
        }
        fn code(&self) -> CodeImage {
            let mut a = Assembler::new();
            a.call("not_a_real_symbol");
            a.halt();
            let (vm_code, imports) = a.assemble();
            CodeImage { imports, vm_code, hlo: vec![] }
        }
    }
    src.library_dir().install(Box::new(NeedsMissing));
    let h = src.register_ifunc("missing").unwrap();
    let msg = h.msg_create(&SourceArgs::bytes(vec![])).unwrap();
    ep.ifunc_msg_send_nbix(&msg, 0, ring.rkey()).unwrap();
    ep.flush().unwrap();
    let mut args = TargetArgs::none();
    let err = dst.poll_ifunc_blocking(&mut ring, &mut args).unwrap_err();
    assert!(err.to_string().contains("unresolved symbol"), "{err}");
}

#[test]
fn hostile_frame_is_consumed_not_spun_on() {
    // Consume-on-reject (ROADMAP item): a frame with a *valid* header
    // whose code fails before invoke — undecodable bytecode here, an
    // unresolved import below — must be consumed by the poll loop, not
    // left at the cursor where a worker would spin on it forever.
    let (src, dst, ep) = pair();
    let mut ring = IfuncRing::new(&dst, 1 << 16).unwrap();

    let evil = CodeImage { imports: vec![], vm_code: vec![0xFF; 16], hlo: vec![] };
    let msg = two_chains::ifunc::IfuncMsg::assemble("evil", &evil, &[0u8; 8], Default::default())
        .unwrap();
    ep.ifunc_msg_send_nbix(&msg, 0, ring.rkey()).unwrap();
    ep.flush().unwrap();

    let mut args = TargetArgs::none();
    let err = dst.poll_ifunc(&mut ring, &mut args).unwrap_err();
    assert!(err.to_string().contains("verification"), "{err}");
    assert_eq!(ring.consumed, 1, "rejected frame must be consumed");

    // A second hostile frame failing at *link* time (unresolved import)
    // is consumed the same way.
    let unlinked = CodeImage {
        imports: vec!["no_such_sym".into()],
        vm_code: evil.vm_code.clone(),
        hlo: vec![],
    };
    let msg2 =
        two_chains::ifunc::IfuncMsg::assemble("nolink", &unlinked, &[0u8; 8], Default::default())
            .unwrap();
    let mut cursor = SenderCursor::new(ring.size());
    cursor.place(msg.len()).unwrap();
    ep.ifunc_msg_send_cursor(&msg2, &mut cursor, ring.rkey()).unwrap();
    ep.flush().unwrap();
    let err = dst.poll_ifunc(&mut ring, &mut args).unwrap_err();
    assert!(err.to_string().contains("unresolved symbol"), "{err}");
    assert_eq!(ring.consumed, 2);

    // The stream keeps flowing: a good frame behind the hostile ones
    // executes without any resend or cursor surgery.
    src.library_dir().install(Box::new(CounterIfunc::default()));
    let h = src.register_ifunc("counter").unwrap();
    let good = h.msg_create(&SourceArgs::bytes(vec![0; 8])).unwrap();
    ep.ifunc_msg_send_cursor(&good, &mut cursor, ring.rkey()).unwrap();
    ep.flush().unwrap();
    assert!(matches!(
        dst.poll_ifunc(&mut ring, &mut args).unwrap(),
        PollResult::Executed(_)
    ));
    assert_eq!(dst.symbols().counter_value(), 1);
}

/// Worker-liveness regression: a frame whose *header* fails validation
/// cannot be consumed (its length is untrusted), so it parks at the poll
/// cursor and `poll_ifunc` errors on every iteration with `no_message ==
/// false`. The receive loop used to skip both the shutdown check and the
/// backoff on that path — `WorkerHandle::stop()` / `Cluster::shutdown()`
/// would join forever while the thread hot-spun at 100% CPU. Now a
/// non-consuming error is treated like an idle spin: reported once,
/// backed off, and shutdown-aware.
#[test]
fn corrupt_header_frame_does_not_hang_shutdown() {
    use two_chains::coordinator::{Cluster, ClusterConfig, TransportKind};

    // Both ring-protocol transports share the poll loop (and the
    // `debug_put_raw` fault hook): the liveness property must hold on the
    // fabric ring and the intra-node shm ring alike.
    for transport in [TransportKind::Ring, TransportKind::Shm] {
        let cluster = Cluster::launch(
            ClusterConfig::builder().workers(1).transport(transport).build().unwrap(),
            |_, _, _| {},
        )
        .unwrap();
        let d = cluster.dispatcher();
        // Hostile write straight into the worker's ring at the poll cursor:
        // nonzero, not MAGIC, not WRAP — permanently unconsumable.
        d.debug_corrupt_ring(0, 0, &0xDEAD_BEEF_u64.to_le_bytes()).unwrap();
        // Let the worker thread meet the poisoned word.
        std::thread::sleep(std::time::Duration::from_millis(50));

        let t = std::thread::spawn(move || cluster.shutdown());
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        while !t.is_finished() {
            assert!(
                std::time::Instant::now() < deadline,
                "Cluster::shutdown() hung on a header-invalid frame parked at the \
                 cursor ({transport:?})"
            );
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        t.join().unwrap().unwrap();
    }
}

/// Flow-control liveness regression (the PR 5 headline bugfix):
/// `RingTransport::wait_capacity` was the one wait in the codebase with
/// no deadline — a worker that died with a full ring left every sender
/// spinning forever (and a deregistered credit word would have *panicked*
/// the sender via `load_u64_acquire(0).unwrap()`). Injecting into a dead
/// worker whose ring is saturated must now surface `Error::Transport`
/// naming the worker and the stalled credit, on the fabric ring and the
/// shm ring alike. This test hangs on the old `wait_capacity` and passes
/// on the bounded one.
#[test]
fn dead_worker_with_full_ring_errors_instead_of_hanging() {
    use two_chains::coordinator::{Cluster, ClusterConfig, Target, TransportKind};

    for transport in [TransportKind::Ring, TransportKind::Shm] {
        let mut cluster = Cluster::launch(
            ClusterConfig::builder()
                .workers(1)
                .transport(transport)
                .ring_bytes(4096)
                .reply_timeout(std::time::Duration::from_millis(200))
                .build()
                .unwrap(),
            |_, ctx, _| {
                ctx.library_dir().install(Box::new(CounterIfunc::default()));
            },
        )
        .unwrap();
        cluster.leader.library_dir().install(Box::new(CounterIfunc::default()));
        // Fault injection: kill the worker's receive loop. Its byte
        // credit is frozen at whatever it last pushed, so a few sends
        // fill the 4 KiB ring and the next one needs credit that will
        // never come.
        cluster.workers[0].stop().unwrap();

        let d = cluster.dispatcher();
        let h = d.register("counter").unwrap();
        let msg = h.msg_create(&SourceArgs::bytes(vec![0u8; 512])).unwrap();
        let err = (0..64)
            .find_map(|_| d.send(Target::Worker(0), &msg).err())
            .expect("injecting into a dead worker's full ring must error, not hang");
        assert!(
            err.to_string().contains("no ring credit progress"),
            "{transport:?}: {err}"
        );
        assert!(err.to_string().contains("worker 0"), "{transport:?}: {err}");
        cluster.shutdown().unwrap();
    }
}

#[test]
fn garbage_in_ring_is_rejected_not_executed() {
    let (_src, dst, ep) = pair();
    let mut ring = IfuncRing::new(&dst, 1 << 16).unwrap();
    // Write plausible-looking garbage (nonzero magic word, junk after).
    let mut junk = vec![0u8; 128];
    junk[0..4].copy_from_slice(&0xDEAD_BEEFu32.to_le_bytes());
    ep.put_nbi(ring.rkey(), 0, &junk).unwrap();
    ep.qp().flush().unwrap();
    let mut args = TargetArgs::none();
    let err = dst.poll_ifunc(&mut ring, &mut args).unwrap_err();
    assert!(err.to_string().contains("bad header word"), "{err}");
}

#[test]
fn truncated_frame_times_out_or_rejects() {
    let (src, dst, ep) = pair();
    let mut ring = IfuncRing::new(&dst, 1 << 16).unwrap();
    src.library_dir().install(Box::new(CounterIfunc::default()));
    let h = src.register_ifunc("counter").unwrap();
    let msg = h.msg_create(&SourceArgs::bytes(vec![0; 64])).unwrap();
    // Deliver the header but corrupt the trailer signal position by
    // truncating the frame: poll must not execute it.
    let frame = msg.frame().to_vec();
    let rkey = ring.rkey();
    ep.put_nbi(rkey, 0, &frame[..frame.len() - 8]).unwrap();
    ep.qp().flush().unwrap();
    let mut args = TargetArgs::none();
    // The header is valid, so poll spins for the trailer; send the *rest*
    // from a second put (completing the frame) and poll succeeds — this is
    // exactly the paper's streaming arrival scenario (Fig. 2).
    let t = std::thread::spawn(move || {
        std::thread::sleep(std::time::Duration::from_millis(20));
        ep.put_nbi(rkey, frame.len() - 8, &frame[frame.len() - 8..]).unwrap();
        ep.qp().flush().unwrap();
    });
    assert!(matches!(
        dst.poll_ifunc(&mut ring, &mut args).unwrap(),
        PollResult::Executed(_)
    ));
    t.join().unwrap();
    assert_eq!(dst.symbols().counter_value(), 1);
}
