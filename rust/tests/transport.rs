//! Transport-level integration: fabric + UCP + ifunc interplay, with and
//! without the wire-cost model; multi-node topologies; the AM-transport
//! ifunc extension next to the PUT transport.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use two_chains::fabric::{Fabric, WireConfig};
use two_chains::ifunc::am_transport::{ifunc_msg_send_am, install_am_ifunc};
use two_chains::ifunc::builtin::{ChecksumIfunc, CounterIfunc};
use two_chains::ifunc::{IfuncRing, SenderCursor, SourceArgs, TargetArgs};
use two_chains::ucp::{Context, ContextConfig, Worker};

/// Both transports deliver the same ifunc; target state agrees.
#[test]
fn put_and_am_transports_agree() {
    let fabric = Fabric::new(2, WireConfig::off());
    let src = Context::new(fabric.node(0), ContextConfig::default()).unwrap();
    let dst = Context::new(fabric.node(1), ContextConfig::default()).unwrap();
    src.library_dir().install(Box::new(CounterIfunc::default()));
    let ws = Worker::new(&src);
    let wd = Worker::new(&dst);
    let ep = ws.connect(&wd).unwrap();
    install_am_ifunc(&wd, Arc::new(Mutex::new(TargetArgs::none())));

    let mut ring = IfuncRing::new(&dst, 1 << 18).unwrap();
    let mut cursor = SenderCursor::new(ring.size());
    let h = src.register_ifunc("counter").unwrap();
    let msg = h.msg_create(&SourceArgs::bytes(vec![1; 100])).unwrap();

    // 5 over PUT + poll, 5 over AM + progress.
    let mut args = TargetArgs::none();
    for _ in 0..5 {
        ep.ifunc_msg_send_cursor(&msg, &mut cursor, ring.rkey()).unwrap();
        ep.flush().unwrap();
        dst.poll_ifunc_blocking(&mut ring, &mut args).unwrap();
    }
    for _ in 0..5 {
        ifunc_msg_send_am(&ep, &msg).unwrap();
    }
    ep.flush().unwrap();
    wd.progress_until(|| dst.symbols().counter_value() == 10);
}

/// The wire model changes timing, never outcomes.
#[test]
fn wire_model_preserves_semantics() {
    for wire in [WireConfig::off(), WireConfig::connectx6()] {
        let fabric = Fabric::new(2, wire);
        let src = Context::new(fabric.node(0), ContextConfig::default()).unwrap();
        let dst = Context::new(fabric.node(1), ContextConfig::default()).unwrap();
        src.library_dir().install(Box::new(ChecksumIfunc));
        let ws = Worker::new(&src);
        let wd = Worker::new(&dst);
        let ep = ws.connect(&wd).unwrap();
        let mut ring = IfuncRing::new(&dst, 1 << 18).unwrap();
        let mut cursor = SenderCursor::new(ring.size());
        let h = src.register_ifunc("checksum").unwrap();
        let payload: Vec<u8> = (0..=255u8).collect();
        let msg = h.msg_create(&SourceArgs::bytes(payload)).unwrap();
        let mut args = TargetArgs::none();
        ep.ifunc_msg_send_cursor(&msg, &mut cursor, ring.rkey()).unwrap();
        ep.flush().unwrap();
        dst.poll_ifunc_blocking(&mut ring, &mut args).unwrap();
        assert_eq!(dst.symbols().last_result(), (0..=255u64).sum::<u64>());
    }
}

/// One source fans ifuncs out to several targets (the DPU/CSD picture);
/// each target executes its own stream.
#[test]
fn one_to_many_fanout() {
    const TARGETS: usize = 4;
    let fabric = Fabric::new(TARGETS + 1, WireConfig::off());
    let src = Context::new(fabric.node(0), ContextConfig::default()).unwrap();
    src.library_dir().install(Box::new(CounterIfunc::default()));
    let ws = Worker::new(&src);
    let h = src.register_ifunc("counter").unwrap();
    let msg = h.msg_create(&SourceArgs::bytes(vec![0; 64])).unwrap();

    let mut targets = Vec::new();
    for t in 0..TARGETS {
        let ctx = Context::new(fabric.node(t + 1), ContextConfig::default()).unwrap();
        let wd = Worker::new(&ctx);
        let ep = ws.connect(&wd).unwrap();
        let ring = IfuncRing::new(&ctx, 1 << 18).unwrap();
        targets.push((ctx, ep, ring));
    }
    // Interleave sends.
    let mut cursors: Vec<SenderCursor> =
        targets.iter().map(|(_, _, r)| SenderCursor::new(r.size())).collect();
    for round in 0..8 {
        for (t, (_, ep, ring)) in targets.iter().enumerate() {
            if (round + t) % 2 == 0 {
                ep.ifunc_msg_send_cursor(&msg, &mut cursors[t], ring.rkey()).unwrap();
            }
        }
    }
    for (_, ep, _) in &targets {
        ep.flush().unwrap();
    }
    // Each target drains its ring.
    for (t, (ctx, _, ring)) in targets.iter_mut().enumerate() {
        let expect = (0..8).filter(|r| (r + t) % 2 == 0).count() as u64;
        let mut args = TargetArgs::none();
        for _ in 0..expect {
            ctx.poll_ifunc_blocking(ring, &mut args).unwrap();
        }
        assert_eq!(ctx.symbols().counter_value(), expect, "target {t}");
    }
}

/// Two contexts injecting at each other simultaneously (full duplex).
#[test]
fn full_duplex_injection() {
    let fabric = Fabric::new(2, WireConfig::off());
    let a = Context::new(fabric.node(0), ContextConfig::default()).unwrap();
    let b = Context::new(fabric.node(1), ContextConfig::default()).unwrap();
    for c in [&a, &b] {
        c.library_dir().install(Box::new(CounterIfunc::default()));
    }
    let wa = Worker::new(&a);
    let wb = Worker::new(&b);
    let ab = wa.connect(&wb).unwrap();
    let ba = wb.connect(&wa).unwrap();
    let ring_a = IfuncRing::new(&a, 1 << 18).unwrap();
    let ring_b = IfuncRing::new(&b, 1 << 18).unwrap();
    let (rkey_a, rkey_b) = (ring_a.rkey(), ring_b.rkey());
    let (size_a, size_b) = (ring_a.size(), ring_b.size());

    const N: u64 = 200;
    let counter_b = b.symbols().counter();
    let t = std::thread::spawn(move || {
        let mut ring_b = ring_b;
        let h = b.register_ifunc("counter").unwrap();
        let msg = h.msg_create(&SourceArgs::bytes(vec![0; 32])).unwrap();
        let mut cursor = SenderCursor::new(size_a);
        let mut args = TargetArgs::none();
        for _ in 0..N {
            ba.ifunc_msg_send_cursor(&msg, &mut cursor, rkey_a).unwrap();
            ba.flush().unwrap();
            b.poll_ifunc_blocking(&mut ring_b, &mut args).unwrap();
        }
    });
    let mut ring_a = ring_a;
    let h = a.register_ifunc("counter").unwrap();
    let msg = h.msg_create(&SourceArgs::bytes(vec![0; 32])).unwrap();
    let mut cursor = SenderCursor::new(size_b);
    let mut args = TargetArgs::none();
    for _ in 0..N {
        ab.ifunc_msg_send_cursor(&msg, &mut cursor, rkey_b).unwrap();
        ab.flush().unwrap();
        a.poll_ifunc_blocking(&mut ring_a, &mut args).unwrap();
    }
    t.join().unwrap();
    assert_eq!(a.symbols().counter_value(), N);
    assert_eq!(counter_b.load(Ordering::Acquire), N);
}

/// Atomic counters over the fabric (remote fetch-add used by rndv acks
/// and available to applications).
#[test]
fn remote_atomics_accumulate_across_threads() {
    let fabric = Fabric::new(3, WireConfig::off());
    let target = fabric.node(2);
    let mr = target.register(64, two_chains::fabric::MemPerm::RWX);
    let total = Arc::new(AtomicU64::new(0));
    let mut handles = Vec::new();
    for src in 0..2 {
        let qp = fabric.connect(src, 2);
        let rkey = mr.rkey();
        let total = total.clone();
        handles.push(std::thread::spawn(move || {
            for i in 1..=100u64 {
                qp.atomic_add(rkey, 0, i).unwrap();
                total.fetch_add(i, Ordering::Relaxed);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(mr.load_u64_acquire(0).unwrap(), total.load(Ordering::Relaxed));
}
