//! Property-style randomized tests (seeded XorShift; proptest is not
//! available offline). Each test sweeps hundreds of random cases over a
//! crate invariant; seeds are fixed so failures reproduce exactly.

use two_chains::fabric::{Fabric, WireConfig};
use two_chains::ifunc::builtin::{ChecksumIfunc, CounterIfunc, XorIfunc};
use two_chains::ifunc::message::{CodeImage, Header, IfuncMsg, IfuncMsgParams};
use two_chains::ifunc::reply::{
    ReplyCollector, ReplyRing, ReplyWriter, REPLY_INLINE_CAP, REPLY_SLOTS, STATUS_FAILED,
    STATUS_OK, STATUS_OVERFLOW,
};
use two_chains::ifunc::IfuncLibrary;
use two_chains::ifunc::{IfuncRing, SenderCursor, SourceArgs, TargetArgs};
use two_chains::ucp::{AmParams, Context, ContextConfig, Worker};
use two_chains::util::XorShift;
use two_chains::vm;

/// Frame round-trip: any (name, imports, code, hlo, payload, align)
/// encodes to a frame whose header + code image decode back identically.
#[test]
fn prop_frame_roundtrip() {
    let mut rng = XorShift::new(0xF00D);
    for case in 0..300 {
        let name: String =
            (0..rng.range(1, 16)).map(|_| (b'a' + rng.below(26) as u8) as char).collect();
        let n_imports = rng.below(5);
        let imports: Vec<String> = (0..n_imports)
            .map(|i| {
                let salt = rng.below(100);
                format!("sym_{i}_{salt}")
            })
            .collect();
        let vm_len = (rng.range(1, 64) * 8) as usize;
        let hlo_len = rng.below(200) as usize;
        let code = CodeImage {
            imports: imports.clone(),
            vm_code: rng.bytes(vm_len),
            hlo: rng.bytes(hlo_len),
        };
        let pay_len = rng.below(4096) as usize;
        let payload = rng.bytes(pay_len);
        let align = 1usize << rng.below(7);
        let msg = IfuncMsg::assemble(
            &name,
            &code,
            &payload,
            IfuncMsgParams { payload_align: align },
        )
        .unwrap_or_else(|e| panic!("case {case}: assemble failed: {e}"));

        let h = Header::decode(msg.frame()).unwrap().unwrap();
        assert_eq!(h.name, name, "case {case}");
        assert_eq!(h.payload_len as usize, payload.len());
        assert_eq!(h.payload_offset as usize % align, 0);
        assert_eq!(msg.payload(), &payload[..]);
        let code_range = h.code_offset as usize..(h.code_offset + h.code_len) as usize;
        let (_, decoded) = CodeImage::decode(&msg.frame()[code_range]).unwrap();
        assert_eq!(decoded, code, "case {case}");
    }
}

/// Header corruption: flipping any single byte of an encoded header is
/// either detected (error) or leaves an identical decode (flip hit a
/// padding byte). It must never decode to *different* valid fields.
#[test]
fn prop_header_corruption_detected() {
    let mut rng = XorShift::new(0xBEEF);
    let code = CounterIfunc::default().code();
    for _ in 0..200 {
        let pay_len = rng.below(512) as usize;
        let payload = rng.bytes(pay_len);
        let msg = IfuncMsg::assemble("bench", &code, &payload, Default::default()).unwrap();
        let clean = Header::decode(msg.frame()).unwrap().unwrap();
        let mut bytes = msg.frame().to_vec();
        let at = rng.below(two_chains::ifunc::message::HEADER_BYTES as u64) as usize;
        let bit = 1u8 << rng.below(8);
        bytes[at] ^= bit;
        match Header::decode(&bytes) {
            Err(_) => {}       // rejected: good
            Ok(None) => {}     // magic became zero: reads as empty slot
            Ok(Some(h)) => assert_eq!(h, clean, "undetected corruption at byte {at} bit {bit}"),
        }
    }
}

/// The verifier never panics on arbitrary bytes, and anything it accepts
/// runs to *some* defined outcome (halt or clean fault) under fuel.
#[test]
fn prop_verifier_total_on_garbage() {
    let mut rng = XorShift::new(0xCAFE);
    let got = two_chains::vm::GotTable::empty();
    let cfg = vm::VmConfig { fuel: 10_000, scratch_bytes: 1024 };
    let mut accepted = 0;
    for _ in 0..2000 {
        let code_len = (rng.range(1, 32) * 8) as usize;
        let code = rng.bytes(code_len);
        if let Ok(prog) = vm::verify(&code, 0) {
            accepted += 1;
            let mut payload = rng.bytes(64);
            // Must not panic; faults are fine. Both engines get a go —
            // compile() must be total on anything verify() accepts.
            let _ = vm::run_reference(&prog, &got, &mut payload, &mut (), &cfg);
            let mut payload2 = rng.bytes(64);
            let _ = vm::compile(prog).run(&got, &mut payload2, &mut (), &cfg);
        }
    }
    // Sanity: random bytes occasionally verify (opcode space is dense
    // enough), otherwise this test proves nothing.
    assert!(accepted > 0, "no random program ever verified");
}

/// Differential conformance: the compiled engine (fused, unfused, and
/// **analyzed** — bounds checks elided where the abstract interpretation
/// proved them redundant, per-block fuel checks skipped on proven-bound
/// programs) is equivalent to the reference interpreter on random
/// *verified* programs — same return value, same retired-step count,
/// same payload bytes on success; same fault kind (fuel / fell-off-end /
/// div0 / oob / GOT / host) and same payload bytes on failure — across
/// tiny fuel budgets (mid-block exhaustion) and moderate ones (loops
/// that halt). This is the soundness lock for check elision: an unsound
/// `ProgramFacts` would surface here as a missing fault or a diverged
/// payload.
#[test]
fn prop_compiled_engine_matches_reference() {
    use two_chains::vm::{Instr, Op, SymbolTable, VmConfig};

    fn reg(rng: &mut XorShift) -> u8 {
        rng.below(16) as u8
    }
    fn space(rng: &mut XorShift) -> u8 {
        rng.below(2) as u8
    }
    /// Mem offsets straddling the bounds of a ≤64-byte payload and a
    /// 256-byte scratch, so in-bounds and oob paths both occur.
    fn off(rng: &mut XorShift) -> u32 {
        if rng.bool() { rng.below(48) as u32 } else { rng.below(300) as u32 }
    }
    /// Collapse a fault to its kind; host faults keep their (deterministic)
    /// message. Exact pc equality is pinned by the compile.rs unit tests.
    fn fault_kind(e: &two_chains::Error) -> String {
        let s = e.to_string();
        for k in
            ["fuel exhausted", "fell off code end", "divide by zero", "oob load", "oob store",
             "GOT slot"]
        {
            if s.contains(k) {
                return (*k).to_string();
            }
        }
        s
    }
    fn single(rng: &mut XorShift, n: usize, n_imports: u64) -> Instr {
        let (a, b) = (reg(rng), reg(rng));
        let mut c = reg(rng);
        let mut imm = rng.below(64) as u32;
        let op = match rng.below(26) {
            0 => Op::Halt,
            1 => {
                imm = rng.next_u64() as u32;
                Op::Ldi
            }
            2 => {
                imm = rng.next_u64() as u32;
                Op::Ldih
            }
            3 => Op::Mov,
            4 => Op::Add,
            5 => Op::Sub,
            6 => Op::Mul,
            7 => Op::Divu,
            8 => Op::And,
            9 => Op::Or,
            10 => Op::Xor,
            11 => Op::Shl,
            12 => Op::Shr,
            13 => Op::Addi,
            14 => Op::Sltu,
            15 => Op::Eq,
            16 => {
                imm = rng.below(n as u64) as u32;
                Op::Jmp
            }
            17 => {
                imm = rng.below(n as u64) as u32;
                Op::Jz
            }
            18 => {
                imm = rng.below(n as u64) as u32;
                Op::Jnz
            }
            19 => {
                imm = rng.below(n_imports) as u32;
                Op::Call
            }
            20 => {
                c = space(rng);
                imm = off(rng);
                Op::Ldb
            }
            21 => {
                c = space(rng);
                imm = off(rng);
                Op::Ldw
            }
            22 => {
                c = space(rng);
                imm = off(rng);
                Op::Stb
            }
            23 => {
                c = space(rng);
                imm = off(rng);
                Op::Stw
            }
            24 => Op::Paylen,
            _ => Op::Nop,
        };
        Instr { op, a, b, c, imm }
    }

    // Three deterministic pure host imports so Call is exercised end to
    // end, including the host-fault path (h2 rejects odd arguments).
    let syms = SymbolTable::new();
    syms.install_fn("h0", |_, [a, _, _, _]| Ok(a.wrapping_add(1)));
    syms.install_fn("h1", |_, [a, b, c, d]| {
        Ok(a.wrapping_add(b).wrapping_add(c).wrapping_add(d))
    });
    syms.install_fn("h2", |_, [a, _, _, _]| {
        if a % 2 == 1 { Err("odd argument rejected".into()) } else { Ok(a / 2) }
    });
    let imports = ["h0".to_string(), "h1".to_string(), "h2".to_string()];
    let got = syms.resolve(&imports).unwrap();

    let mut rng = XorShift::new(0xD1FF);
    let mut halted = 0u64;
    for case in 0..1200u64 {
        // Structurally valid by construction: every reg < 16, every space
        // in {payload, scratch}, every jump target < n, every Call slot
        // < n_imports — so verify() must accept it (asserted below).
        let n = rng.range(4, 40) as usize;
        let mut prog: Vec<Instr> = Vec::with_capacity(n);
        while prog.len() < n {
            let room = n - prog.len();
            if room >= 2 && rng.below(100) < 30 {
                // Seed a fusible pair so every superinstruction gets
                // differential coverage (sltu+jz, ldb+add, addi+jmp,
                // ldi+ldih-same-reg).
                match rng.below(4) {
                    0 => {
                        prog.push(Instr {
                            op: Op::Sltu,
                            a: reg(&mut rng),
                            b: reg(&mut rng),
                            c: reg(&mut rng),
                            imm: 0,
                        });
                        prog.push(Instr {
                            op: Op::Jz,
                            a: reg(&mut rng),
                            b: 0,
                            c: 0,
                            imm: rng.below(n as u64) as u32,
                        });
                    }
                    1 => {
                        prog.push(Instr {
                            op: Op::Ldb,
                            a: reg(&mut rng),
                            b: reg(&mut rng),
                            c: space(&mut rng),
                            imm: off(&mut rng),
                        });
                        prog.push(Instr {
                            op: Op::Add,
                            a: reg(&mut rng),
                            b: reg(&mut rng),
                            c: reg(&mut rng),
                            imm: 0,
                        });
                    }
                    2 => {
                        prog.push(Instr {
                            op: Op::Addi,
                            a: reg(&mut rng),
                            b: reg(&mut rng),
                            c: 0,
                            imm: rng.below(16) as u32,
                        });
                        prog.push(Instr {
                            op: Op::Jmp,
                            a: 0,
                            b: 0,
                            c: 0,
                            imm: rng.below(n as u64) as u32,
                        });
                    }
                    _ => {
                        let a = reg(&mut rng);
                        prog.push(Instr { op: Op::Ldi, a, b: 0, c: 0, imm: rng.next_u64() as u32 });
                        prog.push(Instr {
                            op: Op::Ldih,
                            a,
                            b: 0,
                            c: 0,
                            imm: rng.next_u64() as u32,
                        });
                    }
                }
            } else {
                let i = single(&mut rng, n, imports.len() as u64);
                prog.push(i);
            }
        }
        let bytes: Vec<u8> = prog.iter().flat_map(|i| i.encode()).collect();
        let decoded = vm::verify(&bytes, imports.len()).unwrap_or_else(|e| {
            panic!("case {case}: generator produced an unverifiable program: {e}")
        });
        let fused = vm::compile(decoded.clone());
        let unfused = vm::compile_unfused(decoded.clone());
        let analyzed = vm::compile_analyzed(decoded.clone(), &vm::analyze(&decoded));
        let base_payload = rng.bytes(rng.below(64) as usize);

        for fuel in [rng.below(64), rng.range(1_000, 5_000)] {
            let cfg = VmConfig { fuel, scratch_bytes: 256 };
            let mut p_ref = base_payload.clone();
            let mut p_fus = base_payload.clone();
            let mut p_unf = base_payload.clone();
            let mut p_ana = base_payload.clone();
            let r_ref = vm::run_reference(&decoded, &got, &mut p_ref, &mut (), &cfg);
            let r_fus = fused.run(&got, &mut p_fus, &mut (), &cfg);
            let r_unf = unfused.run(&got, &mut p_unf, &mut (), &cfg);
            let r_ana = analyzed.run(&got, &mut p_ana, &mut (), &cfg);
            for (label, r_cmp, p_cmp) in [
                ("fused", &r_fus, &p_fus),
                ("unfused", &r_unf, &p_unf),
                ("analyzed", &r_ana, &p_ana),
            ] {
                match (&r_ref, r_cmp) {
                    (Ok(a), Ok(b)) => {
                        assert_eq!(a, b, "case {case} fuel {fuel}: {label} outcome diverged");
                        halted += 1;
                    }
                    (Err(ea), Err(eb)) => assert_eq!(
                        fault_kind(ea),
                        fault_kind(eb),
                        "case {case} fuel {fuel}: {label} fault diverged: `{ea}` vs `{eb}`"
                    ),
                    _ => panic!(
                        "case {case} fuel {fuel}: {label} ok/err divergence: \
                         {r_ref:?} vs {r_cmp:?}"
                    ),
                }
                assert_eq!(&p_ref, p_cmp, "case {case} fuel {fuel}: {label} payload diverged");
            }
        }
    }
    // Sanity: a healthy share of runs must actually halt cleanly, or the
    // generator degenerated into fault-only coverage.
    assert!(halted > 100, "only {halted} runs halted cleanly — generator too fault-heavy");
}

/// Disassembler/parser round trip: for any decodable instruction, the
/// listing parses back, the reparse is canonical (unused operand fields
/// zeroed) and byte-stable, and the listing text is a fixpoint —
/// `disasm(parse(disasm(i))) == disasm(i)`.
#[test]
fn prop_disasm_parse_roundtrip() {
    use two_chains::vm::isa::{Instr, Op};
    use two_chains::vm::{disasm_instr, parse_instr};
    let mut rng = XorShift::new(0xD15A);
    for case in 0..800 {
        let op = Op::from_u8(rng.below(26) as u8).unwrap();
        let mem = matches!(op, Op::Ldb | Op::Ldw | Op::Stb | Op::Stw);
        let i = Instr {
            op,
            a: rng.below(16) as u8,
            b: rng.below(16) as u8,
            c: if mem { rng.below(2) as u8 } else { rng.below(16) as u8 },
            imm: rng.next_u64() as u32,
        };
        let text = disasm_instr(&i, None);
        let parsed = parse_instr(&text)
            .unwrap_or_else(|| panic!("case {case}: `{text}` did not parse"));
        assert_eq!(parsed.op, i.op, "case {case}: `{text}`");
        assert_eq!(disasm_instr(&parsed, None), text, "case {case}: text not a fixpoint");
        // The reparse is canonical, so it round-trips byte-exactly.
        let again = parse_instr(&text).unwrap();
        assert_eq!(again.encode(), parsed.encode(), "case {case}: `{text}`");
    }
}

/// Adversarial elision soundness: programs *designed* to look elidable
/// while being out of bounds must keep their dynamic checks (or hit the
/// entry-guard fallback) and fault byte-identically to the reference
/// interpreter. A missing fault here means the abstract interpretation
/// proved something false.
#[test]
fn prop_adversarial_elision_stays_checked() {
    use two_chains::vm::isa::{SPACE_PAYLOAD, SPACE_SCRATCH};
    use two_chains::vm::{Assembler, VmConfig};

    let got = two_chains::vm::GotTable::empty();
    let cfg = VmConfig { fuel: 1_000, scratch_bytes: 64 };

    // Run `code` through reference and analyzed engines over several
    // payload lengths; outcomes (including exact fault text) must match.
    let check = |label: &str, code: &[u8], must_fault_at: &[usize]| {
        let prog = vm::verify(code, 0).unwrap();
        let facts = vm::analyze(&prog);
        let analyzed = vm::compile_analyzed(prog.clone(), &facts);
        for len in [0usize, 1, 8, 16, 63, 64, 256] {
            let mut p_ref = vec![0xABu8; len];
            let mut p_ana = p_ref.clone();
            let r_ref = vm::run_reference(&prog, &got, &mut p_ref, &mut (), &cfg);
            let r_ana = analyzed.run(&got, &mut p_ana, &mut (), &cfg);
            match (&r_ref, &r_ana) {
                (Ok(a), Ok(b)) => assert_eq!(a, b, "{label} len {len}"),
                (Err(a), Err(b)) => {
                    assert_eq!(a.to_string(), b.to_string(), "{label} len {len}")
                }
                _ => panic!("{label} len {len}: {r_ref:?} vs {r_ana:?}"),
            }
            assert_eq!(p_ref, p_ana, "{label} len {len}: payload diverged");
            if must_fault_at.contains(&len) {
                assert!(r_ref.is_err(), "{label} len {len}: expected a fault");
            }
        }
    };

    // Paylen-derived index: addr == payload length is out of bounds for
    // *every* payload. TOP interval → never elidable, always faults.
    let mut a = Assembler::new();
    a.paylen(1).ldb(2, 1, SPACE_PAYLOAD, 0).halt();
    let (code, _) = a.assemble();
    assert!(
        !vm::analyze(&vm::verify(&code, 0).unwrap()).elidable[1],
        "paylen-derived load must not be elided"
    );
    check("paylen-derived", &code, &[0, 1, 8, 16, 63, 64, 256]);

    // Wrapping address arithmetic: base u64::MAX + imm 1 wraps to 0 at
    // run time (defined ISA behavior), which the interval transfer must
    // not prove in-bounds — the op stays checked and both engines agree
    // on the wrapped semantics (fault only on the empty payload).
    let mut a = Assembler::new();
    a.ldi64(1, u64::MAX).ldb(2, 1, SPACE_PAYLOAD, 1).halt();
    let (code, _) = a.assemble();
    assert!(
        !vm::analyze(&vm::verify(&code, 0).unwrap()).elidable.iter().any(|&e| e),
        "wrapping address must not be elided"
    );
    check("wrapping-address", &code, &[0]);

    // Guard fallback: a genuinely elidable 8-byte load at offset 8 needs
    // a 16-byte payload; shorter payloads take the reference fallback
    // and fault with the reference's exact message.
    let mut a = Assembler::new();
    a.ldw(0, 0, SPACE_PAYLOAD, 8).halt();
    let (code, _) = a.assemble();
    let facts = vm::analyze(&vm::verify(&code, 0).unwrap());
    assert!(facts.elidable[0] && facts.pay_bound == 16, "expected an elided load");
    check("guard-fallback", &code, &[0, 1, 8]);

    // Scratch bound vs *configured* scratch: the analysis assumes the ISA
    // scratch size; the entry guard must catch a smaller configured one
    // (cfg.scratch_bytes = 64, store at offset 100).
    let mut a = Assembler::new();
    a.stb(0, 0, SPACE_SCRATCH, 100).halt();
    let (code, _) = a.assemble();
    check("small-scratch", &code, &[0, 1, 8, 16, 63, 64, 256]);
}

/// XOR ifunc: applying the injected transform twice restores any payload
/// (executed through the full fabric + ring + poll path).
#[test]
fn prop_xor_ifunc_involution() {
    let fabric = Fabric::new(2, WireConfig::off());
    let src = Context::new(fabric.node(0), ContextConfig::default()).unwrap();
    let dst = Context::new(fabric.node(1), ContextConfig::default()).unwrap();
    let ws = Worker::new(&src);
    let wd = Worker::new(&dst);
    let ep = ws.connect(&wd).unwrap();
    let mut ring = IfuncRing::new(&dst, 1 << 20).unwrap();
    let mut cursor = SenderCursor::new(ring.size());
    let mut rng = XorShift::new(0x50F7);

    for round in 0..50 {
        let key = rng.below(256) as u8;
        src.library_dir().install(Box::new(XorIfunc { key }));
        let pay_len = rng.range(1, 2000) as usize;
        let payload = rng.bytes(pay_len);
        let h = src.register_ifunc("xor").unwrap();
        let msg = h.msg_create(&SourceArgs::bytes(payload.clone())).unwrap();
        let mut args = TargetArgs::none();
        ep.ifunc_msg_send_cursor(&msg, &mut cursor, ring.rkey()).unwrap();
        ep.flush().unwrap();
        dst.poll_ifunc_blocking(&mut ring, &mut args).unwrap();
        // XOR twice = identity; emulate by xoring expectation locally.
        let expect: Vec<u8> = payload.iter().map(|b| b ^ key).collect();
        // Verify through a checksum ifunc of the same data.
        src.library_dir().install(Box::new(ChecksumIfunc));
        let h2 = src.register_ifunc("checksum").unwrap();
        let msg2 = h2.msg_create(&SourceArgs::bytes(expect.clone())).unwrap();
        ep.ifunc_msg_send_cursor(&msg2, &mut cursor, ring.rkey()).unwrap();
        ep.flush().unwrap();
        dst.poll_ifunc_blocking(&mut ring, &mut args).unwrap();
        let want: u64 = expect.iter().map(|&b| b as u64).sum();
        assert_eq!(dst.symbols().last_result(), want, "round {round}");
    }
}

/// Sender cursor vs. poll cursor: for any random frame-length sequence,
/// the target consumes exactly what the source placed, in order, across
/// arbitrary wraps.
#[test]
fn prop_ring_wrap_sequences() {
    let mut rng = XorShift::new(0x21C5);
    for case in 0..20 {
        let fabric = Fabric::new(2, WireConfig::off());
        let src = Context::new(fabric.node(0), ContextConfig::default()).unwrap();
        let dst = Context::new(fabric.node(1), ContextConfig::default()).unwrap();
        src.library_dir().install(Box::new(ChecksumIfunc));
        let ws = Worker::new(&src);
        let wd = Worker::new(&dst);
        let ep = ws.connect(&wd).unwrap();
        let ring_size = 8192usize;
        let mut ring = IfuncRing::new(&dst, ring_size).unwrap();
        let mut cursor = SenderCursor::new(ring_size);
        let h = src.register_ifunc("checksum").unwrap();
        let mut args = TargetArgs::none();

        let mut expected_sum = 0u64;
        for _ in 0..rng.range(5, 60) {
            let pay_len = rng.range(0, 1500) as usize;
            let payload = rng.bytes(pay_len);
            expected_sum = payload.iter().map(|&b| b as u64).sum();
            let msg = h.msg_create(&SourceArgs::bytes(payload)).unwrap();
            // One-at-a-time: send, flush, consume (keeps occupancy = 1
            // frame, so wraps are the only complication).
            ep.ifunc_msg_send_cursor(&msg, &mut cursor, ring.rkey()).unwrap();
            ep.flush().unwrap();
            dst.poll_ifunc_blocking(&mut ring, &mut args).unwrap();
            assert_eq!(dst.symbols().last_result(), expected_sum, "case {case}");
        }
    }
}

/// Stand up a leader-side reply ring and a worker-side writer on a fresh
/// two-node fabric (the reply-frame wire-format harness).
fn reply_pair() -> (ReplyRing, ReplyWriter) {
    let f = Fabric::new(2, WireConfig::off());
    let leader = Context::new(f.node(0), ContextConfig::default()).unwrap();
    let worker = Context::new(f.node(1), ContextConfig::default()).unwrap();
    let wl = Worker::new(&leader);
    let ww = Worker::new(&worker);
    let ring = ReplyRing::new(&leader, None);
    let ep = ww.connect(&wl).unwrap();
    let rkey = ring.rkey();
    (ring, ReplyWriter::new(ep, rkey))
}

/// Reply-frame round trip: any (ok, r0, payload ≤ cap) encodes to a frame
/// that decodes back identically — status, r0, and every payload byte.
#[test]
fn prop_reply_frame_roundtrip() {
    let mut rng = XorShift::new(0x5EC0);
    let (ring, mut w) = reply_pair();
    for case in 0..200u64 {
        let len = rng.below(REPLY_INLINE_CAP as u64 + 1) as usize;
        let payload = rng.bytes(len);
        let ok = rng.below(8) != 0;
        let r0 = rng.next_u64();
        let seq = w.push(case + 1, ok, r0, &payload).unwrap();
        w.flush().unwrap();
        let reply = ring.wait(seq).unwrap();
        assert_eq!(reply.seq, seq, "case {case}");
        assert_eq!(reply.r0, r0, "case {case}");
        if ok {
            assert_eq!(reply.status, STATUS_OK, "case {case}");
            assert_eq!(reply.payload, payload, "case {case} (len {len})");
        } else {
            assert_eq!(reply.status, STATUS_FAILED, "case {case}");
            assert!(reply.payload.is_empty(), "case {case}");
        }
    }
}

/// The legacy (`stream_replies: false`) overflow boundary is exact: a
/// payload of REPLY_INLINE_CAP bytes rides inline; one byte more ships
/// STATUS_OVERFLOW with an empty payload and r0 (the old r0-as-length
/// channel) intact.
#[test]
fn prop_reply_overflow_boundary() {
    let (ring, mut w) = reply_pair();
    let mut rng = XorShift::new(0x0F10);
    for (i, &len) in [
        REPLY_INLINE_CAP - 1,
        REPLY_INLINE_CAP,
        REPLY_INLINE_CAP + 1,
        REPLY_INLINE_CAP + rng.range(2, 4096) as usize,
    ]
    .iter()
    .enumerate()
    {
        let payload = rng.bytes(len);
        let seq = w.push(i as u64 + 1, true, len as u64, &payload).unwrap();
        w.flush().unwrap();
        let reply = ring.wait(seq).unwrap();
        assert_eq!(reply.r0, len as u64, "len {len}");
        if len <= REPLY_INLINE_CAP {
            assert_eq!(reply.status, STATUS_OK, "len {len}");
            assert_eq!(reply.payload, payload, "len {len}");
        } else {
            assert_eq!(reply.status, STATUS_OVERFLOW, "len {len}");
            assert!(reply.payload.is_empty(), "len {len}");
        }
    }
}

/// Lap/overwrite detection under the frame layout: after a random number
/// of extra laps, any seq more than REPLY_SLOTS behind the newest must
/// error (never yield a later lap's payload), while every seq within the
/// last ring of frames still reads back its own payload.
#[test]
fn prop_reply_lap_overwrite_detected() {
    let mut rng = XorShift::new(0x1A95);
    for case in 0..5 {
        let (ring, mut w) = reply_pair();
        let total = REPLY_SLOTS as u64 + rng.range(1, 3 * REPLY_SLOTS as u64);
        for seq in 1..=total {
            // Payload stamps the seq so a cross-lap mixup is detectable.
            w.push(seq, true, seq, &seq.to_le_bytes()).unwrap();
        }
        w.flush().unwrap();
        // Everything still within the newest ring of slots reads back.
        for _ in 0..20 {
            let seq = rng.range(total - REPLY_SLOTS as u64 + 1, total);
            let reply = ring.wait(seq).unwrap();
            assert_eq!(reply.r0, seq, "case {case}");
            assert_eq!(reply.payload, seq.to_le_bytes(), "case {case}");
        }
        // Anything older was lapped: error, not a later lap's bytes.
        for _ in 0..20 {
            let seq = rng.range(1, total - REPLY_SLOTS as u64);
            assert!(ring.wait(seq).is_err(), "case {case}: seq {seq} of {total}");
        }
    }
}

/// Streamed-reply wire-format harness: leader-side ring + collector,
/// worker-side chunking writer gated on a test-visible credit word.
struct StreamHarness {
    collector: std::sync::Arc<ReplyCollector>,
    writer: ReplyWriter,
    /// The writer's slot-recycling gate (worker-local word the collector
    /// normally advances; tests can poke it to simulate rogue credit).
    credit: std::sync::Arc<two_chains::fabric::MemoryRegion>,
    /// Absorbs the collector's watermark puts when the test drives the
    /// writer's gate by hand.
    _sink: std::sync::Arc<two_chains::fabric::MemoryRegion>,
}

fn stream_harness(collector_feeds_credit: bool) -> StreamHarness {
    use two_chains::fabric::MemPerm;
    let f = Fabric::new(2, WireConfig::off());
    let leader = Context::new(f.node(0), ContextConfig::default()).unwrap();
    let worker = Context::new(f.node(1), ContextConfig::default()).unwrap();
    let wl = Worker::new(&leader);
    let ww = Worker::new(&worker);
    let ring = ReplyRing::new(&leader, None);
    let credit = worker.mem_map(64, MemPerm::RW);
    let sink = worker.mem_map(64, MemPerm::RW);
    let back_ep = ww.connect(&wl).unwrap();
    let fwd_ep = wl.connect(&ww).unwrap();
    // With `collector_feeds_credit` the collector's watermark puts land
    // in the writer's gate word (the production wiring); otherwise they
    // land in a sink and the *test* owns the gate (lap injection).
    let collector_rkey = if collector_feeds_credit { credit.rkey() } else { sink.rkey() };
    let collector =
        std::sync::Arc::new(ReplyCollector::new(ring.clone(), fwd_ep, collector_rkey));
    let writer = ReplyWriter::with_mode(back_ep, ring.rkey(), true, Some(credit.clone()));
    StreamHarness { collector, writer, credit, _sink: sink }
}

/// Chunk-boundary exactness: payloads of exactly k * REPLY_INLINE_CAP,
/// the empty payload, and off-by-one sizes all reassemble bit-identical
/// with the expected chunk count (no empty tail chunk at exact
/// multiples).
#[test]
fn prop_chunked_reply_boundaries_reassemble_exactly() {
    let mut h = stream_harness(true);
    let mut rng = XorShift::new(0xC4C4);
    let cases: Vec<(usize, u64)> = vec![
        (0, 1),
        (1, 1),
        (REPLY_INLINE_CAP - 1, 1),
        (REPLY_INLINE_CAP, 1),
        (REPLY_INLINE_CAP + 1, 2),
        (2 * REPLY_INLINE_CAP, 2),
        (2 * REPLY_INLINE_CAP + 1, 3),
        (3 * REPLY_INLINE_CAP, 3),
        (3 * REPLY_INLINE_CAP + rng.range(1, 1000) as usize, 4),
    ];
    let mut expected_last = 0u64;
    for (frame, (len, chunks)) in cases.into_iter().enumerate() {
        let frame_seq = frame as u64 + 1;
        let payload = rng.bytes(len);
        let r0 = rng.next_u64();
        h.collector.register(frame_seq);
        let last = h.writer.push(frame_seq, true, r0, &payload).unwrap();
        expected_last += chunks;
        assert_eq!(last, expected_last, "len {len}: wrong chunk count");
        h.writer.flush().unwrap();
        let reply = h.collector.collect(frame_seq).unwrap();
        assert_eq!(reply.seq, frame_seq, "len {len}");
        assert_eq!(reply.status, STATUS_OK, "len {len}");
        assert_eq!(reply.r0, r0, "len {len}");
        assert_eq!(reply.payload, payload, "len {len}");
    }
}

/// Random payload sizes spanning 0 to several chunks, with random
/// ok/failed outcomes, all round-trip through the collector in order.
#[test]
fn prop_streamed_replies_roundtrip_random_sizes() {
    let mut h = stream_harness(true);
    let mut rng = XorShift::new(0x57E4);
    for frame_seq in 1..=60u64 {
        let len = rng.below(3 * REPLY_INLINE_CAP as u64) as usize;
        let ok = rng.below(10) != 0;
        let payload = rng.bytes(len);
        let r0 = rng.next_u64();
        h.collector.register(frame_seq);
        h.writer.push(frame_seq, ok, r0, &payload).unwrap();
        // The slot-recycling credit from earlier collects arrives
        // asynchronously; pump until this push's chunks are all placed.
        while h.writer.pending() > 0 {
            h.writer.pump().unwrap();
            std::thread::yield_now();
        }
        h.writer.flush().unwrap();
        let reply = h.collector.collect(frame_seq).unwrap();
        assert_eq!(reply.r0, r0, "frame {frame_seq}");
        if ok {
            assert_eq!(reply.payload, payload, "frame {frame_seq} (len {len})");
        } else {
            assert_eq!(reply.status, STATUS_FAILED, "frame {frame_seq}");
            assert!(reply.payload.is_empty(), "frame {frame_seq}");
        }
    }
}

/// The shm flavor of the streamed-reply harness: writer, credit word,
/// and collector all share mappings directly (no endpoints anywhere).
/// Random payload sizes spanning 0 to several chunks must round-trip
/// identically to the fabric pair — same seqlock slots, same watermark
/// credit, different delivery.
#[test]
fn prop_shm_streamed_replies_roundtrip_random_sizes() {
    use two_chains::fabric::MemPerm;
    let f = Fabric::new(1, WireConfig::off());
    let leader = Context::new(f.node(0), ContextConfig::default()).unwrap();
    let ring = ReplyRing::new(&leader, None);
    let credit = leader.mem_map(64, MemPerm::RW);
    let collector = ReplyCollector::shm(ring.clone(), credit.clone());
    let mut writer = ReplyWriter::shm(&ring, true, Some(credit));
    let mut rng = XorShift::new(0x54A1);
    for frame_seq in 1..=60u64 {
        let len = rng.below(3 * REPLY_INLINE_CAP as u64) as usize;
        let ok = rng.below(10) != 0;
        let payload = rng.bytes(len);
        let r0 = rng.next_u64();
        collector.register(frame_seq);
        writer.push(frame_seq, ok, r0, &payload).unwrap();
        while writer.pending() > 0 {
            writer.pump().unwrap();
            std::thread::yield_now();
        }
        writer.flush().unwrap();
        let reply = collector.collect(frame_seq).unwrap();
        assert_eq!(reply.r0, r0, "frame {frame_seq}");
        if ok {
            assert_eq!(reply.payload, payload, "frame {frame_seq} (len {len})");
        } else {
            assert_eq!(reply.status, STATUS_FAILED, "frame {frame_seq}");
            assert!(reply.payload.is_empty(), "frame {frame_seq}");
        }
    }
}

/// Full-stack transport equivalence: random-size echo invocations (0 to
/// past the chunk boundary) return bit-identical payloads over the ring,
/// AM, and shm transports — the scenario matrix's property-test arm.
#[test]
fn prop_invoke_echo_roundtrips_on_every_transport() {
    use two_chains::coordinator::{Cluster, ClusterConfig, Target, TransportKind};
    use two_chains::ifunc::builtin::EchoIfunc;
    for transport in TransportKind::ALL {
        let cluster = Cluster::launch(
            ClusterConfig::builder().workers(1).transport(transport).build().unwrap(),
            |_, ctx, _| {
                ctx.library_dir().install(Box::new(EchoIfunc));
            },
        )
        .unwrap();
        cluster.leader.library_dir().install(Box::new(EchoIfunc));
        let d = cluster.dispatcher();
        let h = d.register("echo").unwrap();
        let mut rng = XorShift::new(0xEC40);
        for case in 0..25 {
            // Sizes straddling 0, sub-frame, and multi-chunk replies.
            let len = *rng.pick(&[0usize, 1, 64, 4096, 70_000, 150_000]);
            let payload = rng.bytes(len);
            let reply = d
                .invoke_one(
                    Target::Worker(0),
                    &h.msg_create(&SourceArgs::bytes(payload.clone())).unwrap(),
                )
                .unwrap();
            assert!(reply.ok(), "{transport:?} case {case}");
            assert_eq!(reply.r0 as usize, len, "{transport:?} case {case}");
            assert_eq!(reply.payload, payload, "{transport:?} case {case} (len {len})");
        }
        cluster.shutdown().unwrap();
    }
}

/// Collective merge attribution under interleaved fire-and-forget floods:
/// random bursts of counter frames share every link with random-subset
/// `invoke_multi` collectives, and each merged reply must still carry the
/// record seeded on *its* worker — over ring, AM, and shm. A crossed wire
/// (reply credited to the wrong worker) shows up as the wrong f32.
#[test]
fn prop_multi_reply_attribution_under_interleaved_floods() {
    use two_chains::coordinator::{Cluster, ClusterConfig, GetIfunc, Target, TransportKind};
    for transport in TransportKind::ALL {
        let cluster = Cluster::launch(
            ClusterConfig::builder().workers(4).transport(transport).build().unwrap(),
            |i, ctx, store| {
                ctx.library_dir().install(Box::new(CounterIfunc::default()));
                store.insert(7, vec![i as f32]);
            },
        )
        .unwrap();
        cluster.leader.library_dir().install(Box::new(CounterIfunc::default()));
        cluster.leader.library_dir().install(Box::new(GetIfunc));
        let d = cluster.dispatcher();
        let h_cnt = d.register("counter").unwrap();
        let h_get = d.register("get").unwrap();
        let cnt = h_cnt.msg_create(&SourceArgs::bytes(vec![0u8; 32])).unwrap();
        let get = h_get.msg_create(&GetIfunc::args(7)).unwrap();
        let sets: [&[usize]; 4] = [&[0, 1, 2, 3], &[3, 1], &[2], &[1, 0, 3]];
        let mut rng = XorShift::new(0xFA2);
        for round in 0..20 {
            for _ in 0..rng.below(24) {
                d.send(Target::All, &cnt).unwrap();
            }
            let set = sets[rng.below(sets.len() as u64) as usize];
            let merged =
                d.invoke_multi(Target::Set(set), &get).unwrap().wait().unwrap();
            assert_eq!(merged.len(), set.len(), "{transport:?} round {round}");
            for (worker, reply) in merged.replies() {
                assert!(reply.ok(), "{transport:?} round {round} worker {worker}");
                assert_eq!(
                    reply.payload_f32s(),
                    vec![*worker as f32],
                    "{transport:?} round {round}: reply misattributed to worker {worker}"
                );
            }
        }
        d.barrier().unwrap();
        cluster.shutdown().unwrap();
    }
}

/// Partial collective failure: with one worker killed mid-cluster, a
/// collective over all workers reports *which* worker failed and that the
/// live ones replied — and the dispatcher stays usable for the survivors.
#[test]
fn prop_collective_partial_failure_names_the_dead_worker() {
    use two_chains::coordinator::{Cluster, ClusterConfig, Target, TransportKind};
    use two_chains::ifunc::builtin::EchoIfunc;
    for transport in TransportKind::ALL {
        let mut cluster = Cluster::launch(
            ClusterConfig::builder()
                .workers(3)
                .transport(transport)
                .reply_timeout(std::time::Duration::from_millis(200))
                .build()
                .unwrap(),
            |_, ctx, _| {
                ctx.library_dir().install(Box::new(EchoIfunc));
            },
        )
        .unwrap();
        cluster.leader.library_dir().install(Box::new(EchoIfunc));
        cluster.workers[1].stop().unwrap();

        let d = cluster.dispatcher();
        let h = d.register("echo").unwrap();
        let msg = h.msg_create(&SourceArgs::bytes(vec![9u8; 16])).unwrap();
        let err = d
            .invoke_all(&msg)
            .unwrap()
            .wait()
            .expect_err("a dead member must fail the collective");
        let s = err.to_string();
        assert!(s.contains("worker 1"), "{transport:?}: {s}");
        assert!(s.contains("replied"), "{transport:?}: {s}");

        // The survivors' links are unharmed: unicast and a collective over
        // the live subset both still complete.
        assert!(d.invoke_one(Target::Worker(0), &msg).unwrap().ok(), "{transport:?}");
        let merged =
            d.invoke_multi(Target::Set(&[0, 2]), &msg).unwrap().wait().unwrap();
        assert!(merged.all_ok(), "{transport:?}");
        cluster.shutdown().unwrap();
    }
}

/// A `MultiPendingReply` dropped without `wait()` must leave no stale
/// collector waiters and no leaked invoke-window slots behind — repeated
/// drop cycles neither accumulate state nor break later collectives.
#[test]
fn prop_dropped_multi_pending_leaves_no_stale_waiters() {
    use two_chains::coordinator::{Cluster, ClusterConfig, TransportKind};
    use two_chains::ifunc::builtin::EchoIfunc;
    for transport in TransportKind::ALL {
        let cluster = Cluster::launch(
            ClusterConfig::builder().workers(3).transport(transport).build().unwrap(),
            |_, ctx, _| {
                ctx.library_dir().install(Box::new(EchoIfunc));
            },
        )
        .unwrap();
        cluster.leader.library_dir().install(Box::new(EchoIfunc));
        let d = cluster.dispatcher();
        let h = d.register("echo").unwrap();
        let msg = h.msg_create(&SourceArgs::bytes(vec![3u8; 48])).unwrap();
        for round in 0..10 {
            let multi = d.invoke_all(&msg).unwrap();
            assert_eq!(multi.len(), 3, "{transport:?} round {round}");
            drop(multi);
            for w in 0..3 {
                assert_eq!(
                    d.debug_awaited(w).unwrap(),
                    0,
                    "{transport:?} round {round}: stale waiter on worker {w}"
                );
            }
        }
        // Abandoned collectives released their window slots: a fresh
        // collective (and its replies) still round-trips.
        let merged = d.invoke_all(&msg).unwrap().wait().unwrap();
        assert!(merged.all_ok(), "{transport:?}");
        assert_eq!(merged.len(), 3, "{transport:?}");
        d.barrier().unwrap();
        cluster.shutdown().unwrap();
    }
}

/// A lap arriving mid-stream must error, never splice chunks from
/// different laps into one payload: with rogue credit the writer laps the
/// unread head of its own 70-chunk stream, and the collector refuses.
#[test]
fn prop_reply_lap_mid_stream_errors_not_splices() {
    let mut h = stream_harness(false);
    h.collector.register(1);
    let chunks = REPLY_SLOTS + 6;
    let payload = vec![0xEEu8; chunks * REPLY_INLINE_CAP];
    h.writer.push(1, true, 0, &payload).unwrap();
    // The credit gate held back the chunks past the ring...
    assert_eq!(h.writer.pending(), 6);
    // ...until rogue credit releases them over the unread head.
    h.credit.store_u64_release(0, chunks as u64).unwrap();
    h.writer.pump().unwrap();
    h.writer.flush().unwrap();
    let err = h.collector.collect(1).unwrap_err();
    assert!(
        err.to_string().contains("overwritten") || err.to_string().contains("lapped"),
        "{err}"
    );
}

/// AM transport: any random sequence of payload sizes (spanning all three
/// protocols) delivers every byte, in order.
#[test]
fn prop_am_delivers_all_sizes_in_order() {
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::{Arc, Mutex};
    let mut rng = XorShift::new(0xA77);
    for _case in 0..10 {
        let fabric = Fabric::new(2, WireConfig::off());
        let a = Context::new(fabric.node(0), ContextConfig::default()).unwrap();
        let b = Context::new(fabric.node(1), ContextConfig::default()).unwrap();
        let wa = Worker::new(&a);
        let wb = Worker::new(&b);
        let ep = wa.connect(&wb).unwrap();

        let seen: Arc<Mutex<Vec<(usize, u8)>>> = Arc::new(Mutex::new(Vec::new()));
        let count = Arc::new(AtomicU64::new(0));
        let (s2, c2) = (seen.clone(), count.clone());
        wb.set_am_handler(5, move |_, data| {
            s2.lock().unwrap().push((data.len(), data.first().copied().unwrap_or(0)));
            c2.fetch_add(1, Ordering::SeqCst);
        });

        let n = rng.range(10, 80);
        let mut sent = Vec::new();
        let params = AmParams::default();
        let wb2 = wb.clone();
        let c3 = count.clone();
        let progress = std::thread::spawn(move || {
            wb2.progress_until(|| c3.load(Ordering::SeqCst) >= n);
        });
        for i in 0..n {
            // Sizes straddling short/bcopy/rndv boundaries.
            let size = *rng.pick(&[
                0usize, 1, 255, 256, 257, 1024, 1999, 2000, 2048, 4096, 9000, 100_000,
            ]);
            let byte = (i & 0xFF) as u8;
            let data = vec![byte; size];
            ep.am_send(5, &data).unwrap();
            sent.push((size, if size == 0 { 0 } else { byte }));
            let _ = params;
        }
        ep.flush().unwrap();
        progress.join().unwrap();
        assert_eq!(*seen.lock().unwrap(), sent);
    }
}

/// Mesh forwarding property: chains of 1/2/4 hops with random no-self
/// itineraries, injected at random heads and interleaved with
/// fire-and-forget floods on the same leader links, return exactly their
/// data payload under the seq the leader registered — payload integrity
/// *and* seq attribution survive concurrent leader traffic, mesh
/// traffic, and relayed replies pushed into the reply stream out of
/// order — on every transport.
#[test]
fn prop_mesh_multi_hop_echo_under_interleaved_floods() {
    use two_chains::coordinator::{Cluster, ClusterConfig, Target, TransportKind};
    use two_chains::ifunc::builtin::HopIfunc;
    for transport in TransportKind::ALL {
        let n = 4usize;
        let cluster = Cluster::launch(
            ClusterConfig::builder()
                .workers(n)
                .transport(transport)
                .mesh(true)
                .build()
                .unwrap(),
            |_, ctx, _| {
                ctx.library_dir().install(Box::new(HopIfunc));
                ctx.library_dir().install(Box::new(CounterIfunc::default()));
            },
        )
        .unwrap();
        cluster.leader.library_dir().install(Box::new(HopIfunc));
        cluster.leader.library_dir().install(Box::new(CounterIfunc::default()));
        let d = cluster.dispatcher();
        let h_hop = d.register("hop").unwrap();
        let h_noise = d.register("counter").unwrap();
        let noise = h_noise.msg_create(&SourceArgs::bytes(vec![0u8; 32])).unwrap();

        let mut rng = XorShift::new(0xF0F0);
        let mut floods = 0u64;
        let mut mesh_hops = 0u64;
        let rounds = 30usize;
        for round in 0..rounds {
            let hops = [1usize, 2, 4][round % 3];
            let head = rng.below(n as u64) as usize;
            // Random itinerary with no self-hops (forward-to-self is an
            // error by contract).
            let mut peers = Vec::with_capacity(hops);
            let mut at = head;
            for _ in 0..hops {
                let mut next = rng.below(n as u64) as usize;
                if next == at {
                    next = (next + 1) % n;
                }
                peers.push(next);
                at = next;
            }
            mesh_hops += hops as u64;
            // Unique per-round data so a misattributed reply is caught.
            let data: Vec<u8> =
                (0..48u64).map(|i| ((round as u64 * 31 + i) ^ 0x5A) as u8).collect();
            let msg = h_hop
                .msg_create(&SourceArgs::bytes(HopIfunc::payload(&peers, &data)))
                .unwrap();
            // Fire-and-forget floods straddling the chain injection on
            // the same links.
            for _ in 0..rng.below(8) {
                d.send(Target::Worker(rng.below(n as u64) as usize), &noise).unwrap();
                floods += 1;
            }
            let pending = d.invoke_begin(Target::Worker(head), &msg).unwrap();
            for _ in 0..rng.below(8) {
                d.send(Target::Worker(rng.below(n as u64) as usize), &noise).unwrap();
                floods += 1;
            }
            let reply = pending.wait().unwrap();
            assert!(reply.ok(), "{transport:?} round {round} ({hops} hops)");
            assert_eq!(
                reply.payload, data,
                "{transport:?} round {round} ({hops} hops): wrong chain reply"
            );
            assert_eq!(reply.r0, data.len() as u64, "{transport:?} round {round}");
        }
        d.barrier().unwrap();
        // Every execution accounted for: floods + chain heads at the
        // leader links, plus one execution per mesh hop.
        let executed: u64 = cluster.workers.iter().map(|w| w.executed()).sum();
        assert_eq!(executed, floods + rounds as u64 + mesh_hops, "{transport:?}");
        let forwarded: u64 = cluster.workers.iter().map(|w| w.forwarded()).sum();
        assert_eq!(forwarded, mesh_hops, "{transport:?}");
        cluster.shutdown().unwrap();
    }
}
