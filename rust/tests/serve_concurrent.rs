//! Concurrent serve front-end integration: N in-process client threads
//! drive pipelined sessions through the cross-client coalescer over
//! every transport, asserting per-key final consistency, response-id
//! matching, and that shed responses are the only permitted failures —
//! plus the admission-control and non-blocking-window regressions.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use two_chains::coordinator::{
    route_key, Cluster, ClusterConfig, Frontend, FrontendConfig, Target, TransportKind,
};
use two_chains::ifunc::SourceArgs;
use two_chains::util::Json;

/// An ifunc whose injected body parks the executing worker until the
/// test opens the gate — the deterministic way to saturate queues and
/// invoke windows.
struct GateIfunc;
impl two_chains::ifunc::IfuncLibrary for GateIfunc {
    fn name(&self) -> &str {
        "gate"
    }
    fn payload_get_max_size(&self, a: &SourceArgs) -> usize {
        a.len()
    }
    fn payload_init(&self, p: &mut [u8], a: &SourceArgs) -> two_chains::Result<usize> {
        p[..a.len()].copy_from_slice(a.as_bytes());
        Ok(a.len())
    }
    fn code(&self) -> two_chains::ifunc::CodeImage {
        let mut a = two_chains::vm::Assembler::new();
        a.call("gate_wait");
        a.halt();
        let (vm_code, imports) = a.assemble();
        two_chains::ifunc::CodeImage { imports, vm_code, hlo: vec![] }
    }
}

fn gated_cluster(workers: usize, transport: TransportKind, max_inflight: usize) -> (Arc<Cluster>, Arc<AtomicBool>) {
    let gate = Arc::new(AtomicBool::new(false));
    let g = gate.clone();
    let cluster = Cluster::launch(
        ClusterConfig::builder()
            .workers(workers)
            .transport(transport)
            .max_inflight(max_inflight)
            .build()
            .unwrap(),
        move |_, ctx, _| {
            let g = g.clone();
            ctx.symbols().install_fn("gate_wait", move |_, _| {
                while !g.load(Ordering::Acquire) {
                    std::thread::yield_now();
                }
                Ok(0)
            });
        },
    )
    .unwrap();
    cluster.leader.library_dir().install(Box::new(GateIfunc));
    (Arc::new(cluster), gate)
}

/// What one submitted op owes its client.
enum Expect {
    Insert { worker: usize },
    Get { data: Option<Vec<f32>> },
}

const WORKERS: usize = 3;
const CLIENTS: u64 = 6;
const OPS: usize = 40;
const BIG_N: usize = 20_000; // 80 KB of f32s — a streamed (>64 KiB) reply

fn big_data() -> Vec<f32> {
    (0..BIG_N).map(|i| (i % 17) as f32).collect()
}

/// One client's scripted op stream: mostly small inserts with
/// interleaved gets — some hitting fresh writes, some hitting
/// overwritten keys (the "latest wins" check), some deliberate misses —
/// and for client 0 a big-record insert + streamed get in the middle.
/// Get expectations come from `latest`, the client's view of its own
/// prior submissions: per-key ordering through the per-worker FIFO
/// lanes makes that the correct prediction even under pipelining.
fn op_for(
    client: u64,
    i: usize,
    latest: &HashMap<u64, Vec<f32>>,
) -> (String, Expect, Option<(u64, Vec<f32>)>) {
    let base = client * 1000;
    if client == 0 && i == 20 {
        let data = big_data();
        let body: Vec<String> = data.iter().map(|v| format!("{v}")).collect();
        let key = base + 999;
        return (
            format!("{{\"id\":{i},\"cmd\":\"insert\",\"key\":{key},\"data\":[{}]}}", body.join(",")),
            Expect::Insert { worker: route_key(key, WORKERS) },
            Some((key, data)),
        );
    }
    if client == 0 && i == 24 {
        let key = base + 999;
        return (
            format!("{{\"id\":{i},\"cmd\":\"get\",\"key\":{key}}}"),
            Expect::Get { data: latest.get(&key).cloned() },
            None,
        );
    }
    if i % 4 == 3 {
        // Walks keys 0..8 across the run; inserts never touch keys 3
        // and 7, so those probes stay misses while the rest observe the
        // newest prior write.
        let key = base + (i as u64 / 4) % 8;
        return (
            format!("{{\"id\":{i},\"cmd\":\"get\",\"key\":{key}}}"),
            Expect::Get { data: latest.get(&key).cloned() },
            None,
        );
    }
    let key = base + (i as u64 % 8);
    let data: Vec<f32> = vec![(client * 1000 + i as u64) as f32; 1 + (i % 13) * 3];
    let body: Vec<String> = data.iter().map(|v| format!("{v}")).collect();
    (
        format!("{{\"id\":{i},\"cmd\":\"insert\",\"key\":{key},\"data\":[{}]}}", body.join(",")),
        Expect::Insert { worker: route_key(key, WORKERS) },
        Some((key, data)),
    )
}

fn check_response(client: u64, resp: &Json, expect: &Expect) {
    // Sheds are the only permitted failure shape — and this scenario's
    // queues are provisioned so none occur.
    assert_ne!(
        resp.get("error").and_then(|e| e.as_str()),
        Some("overloaded"),
        "client {client}: unexpected shed {resp}"
    );
    match expect {
        Expect::Insert { worker } => {
            assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "client {client}: {resp}");
            assert_eq!(
                resp.get("worker").and_then(|w| w.as_u64()),
                Some(*worker as u64),
                "client {client}: {resp}"
            );
        }
        Expect::Get { data: Some(want) } => {
            assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "client {client}: {resp}");
            let got = resp.get("data").and_then(|d| d.as_f32_vec()).unwrap();
            assert_eq!(&got, want, "client {client}");
        }
        Expect::Get { data: None } => {
            assert_eq!(resp.get("ok"), Some(&Json::Bool(false)), "client {client}: {resp}");
            assert_eq!(
                resp.get("error").and_then(|e| e.as_str()),
                Some("not found"),
                "client {client}: {resp}"
            );
        }
    }
}

/// The tentpole scenario: 6 concurrent clients × 40 interleaved
/// insert/get ops through one coalescing front-end, on all three
/// transports. Every response matches its request by `id`; every get
/// observes exactly the client's latest prior insert of that key
/// (per-key ordering through the per-worker FIFO lanes); the big record
/// streams back intact; and the stores' final contents equal each
/// client's last writes.
#[test]
fn concurrent_clients_stay_consistent_over_all_transports() {
    for transport in TransportKind::ALL {
        let cluster = Arc::new(
            Cluster::launch(
                ClusterConfig::builder().workers(WORKERS).transport(transport).build().unwrap(),
                |_, _, _| {},
            )
            .unwrap(),
        );
        let frontend = Arc::new(
            Frontend::launch(
                cluster.clone(),
                FrontendConfig {
                    // Provisioned so nothing sheds: consistency failures
                    // must not hide behind overload responses.
                    queue_high_water: 100_000,
                    session_window: 8,
                    ..Default::default()
                },
            )
            .unwrap(),
        );

        let mut latest_by_client: Vec<HashMap<u64, Vec<f32>>> = Vec::new();
        let threads: Vec<_> = (0..CLIENTS)
            .map(|client| {
                let fe = frontend.clone();
                std::thread::spawn(move || {
                    let (session, responses) = fe.session().unwrap();
                    let mut latest: HashMap<u64, Vec<f32>> = HashMap::new();
                    let mut owed: HashMap<usize, Expect> = HashMap::new();
                    let mut sent = 0usize;
                    let mut got = 0usize;
                    for i in 0..OPS {
                        // Self-regulated pipelining: stay under the
                        // session window so submit never blocks this
                        // (single) client thread.
                        while sent - got >= 6 {
                            let resp =
                                responses.recv_timeout(Duration::from_secs(30)).unwrap();
                            let id =
                                resp.get("id").and_then(|v| v.as_u64()).unwrap() as usize;
                            check_response(client, &resp, &owed.remove(&id).unwrap());
                            got += 1;
                        }
                        let (line, expect, write) = op_for(client, i, &latest);
                        assert!(session.submit(&line));
                        owed.insert(i, expect);
                        if let Some((key, data)) = write {
                            latest.insert(key, data);
                        }
                        sent += 1;
                    }
                    while got < sent {
                        let resp = responses.recv_timeout(Duration::from_secs(30)).unwrap();
                        let id = resp.get("id").and_then(|v| v.as_u64()).unwrap() as usize;
                        check_response(client, &resp, &owed.remove(&id).unwrap());
                        got += 1;
                    }
                    assert!(owed.is_empty(), "client {client}: ids never answered");
                    latest
                })
            })
            .collect();
        for t in threads {
            latest_by_client.push(t.join().unwrap());
        }

        // Final per-key consistency, store-side: each worker's record
        // store holds exactly the client's last write for every key.
        for (client, latest) in latest_by_client.iter().enumerate() {
            for (key, want) in latest {
                let w = route_key(*key, WORKERS);
                let stored = cluster.workers[w].store.get(*key);
                assert_eq!(
                    stored.as_ref(),
                    Some(want),
                    "{transport:?}: client {client} key {key} on worker {w}"
                );
            }
        }
        let snap = Arc::try_unwrap(frontend).ok().expect("all sessions closed").snapshot();
        assert_eq!(snap.shed, 0, "{transport:?}: nothing may shed in this scenario");
        assert_eq!(snap.submitted, snap.responded, "{transport:?}");
        assert!(snap.batches > 0, "{transport:?}: the coalescer must have shipped");
    }
}

/// Admission control under a parked worker: a burst past the queue
/// high-water mark sheds immediately with the retry-able overload
/// response — it never blocks, never times out — and once the worker
/// revives, every non-shed request completes and new traffic serves
/// normally.
#[test]
fn overload_sheds_then_recovers() {
    let (cluster, gate) = gated_cluster(1, TransportKind::Ring, 16);
    let frontend = Frontend::launch(
        cluster.clone(),
        FrontendConfig {
            queue_high_water: 4,
            batch_max: 4,
            session_window: 64,
            ..Default::default()
        },
    )
    .unwrap();
    // Park the one worker inside an injected gate function.
    let d = cluster.dispatcher();
    let h_gate = d.register("gate").unwrap();
    d.send(Target::Worker(0), &h_gate.msg_create(&SourceArgs::bytes(vec![0u8; 8])).unwrap())
        .unwrap();

    let (session, responses) = frontend.session().unwrap();
    let burst = 64usize;
    for i in 0..burst {
        assert!(session.submit(&format!(
            "{{\"id\":{i},\"cmd\":\"insert\",\"key\":{i},\"data\":[{i}.0]}}"
        )));
    }
    // Capacity while parked is bounded by window (16) + drainer batch in
    // hand (4) + queue (4): the rest of the burst must shed.
    gate.store(true, Ordering::Release);
    let mut shed = 0usize;
    let mut ok = 0usize;
    let mut seen = vec![false; burst];
    for _ in 0..burst {
        let resp = responses.recv_timeout(Duration::from_secs(30)).unwrap();
        let id = resp.get("id").and_then(|v| v.as_u64()).unwrap() as usize;
        assert!(!seen[id], "duplicate response for id {id}");
        seen[id] = true;
        if resp.get("error").and_then(|e| e.as_str()) == Some("overloaded") {
            assert_eq!(resp.get("retry"), Some(&Json::Bool(true)), "{resp}");
            assert_eq!(resp.get("ok"), Some(&Json::Bool(false)), "{resp}");
            shed += 1;
        } else {
            assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "shed is the only allowed failure: {resp}");
            ok += 1;
        }
    }
    assert!(shed >= 1, "a 64-op burst into capacity 24 must shed");
    assert_eq!(shed + ok, burst);
    assert_eq!(frontend.snapshot().shed as usize, shed);

    // Recovery: the revived worker serves new traffic normally.
    assert!(session.submit("{\"id\":\"after\",\"cmd\":\"insert\",\"key\":500,\"data\":[5.0]}"));
    let resp = responses.recv_timeout(Duration::from_secs(30)).unwrap();
    assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp}");
    assert_eq!(resp.get("id").and_then(|i| i.as_str()), Some("after"));
    drop(session);
    frontend.shutdown();
}

/// The non-blocking window regression: with every slot of a saturated
/// window held by parked invocations, `try_invoke_begin` /
/// `try_invoke_batch` return the shed path (None / empty) immediately —
/// no deadlock, no timeout — and admit exactly the freed capacity once
/// replies are collected. Ring + shm: the gate parks the worker after
/// delivery completes, so the begins themselves never block.
#[test]
fn saturated_window_takes_the_shed_path_and_never_deadlocks() {
    for transport in [TransportKind::Ring, TransportKind::Shm] {
        let (cluster, gate) = gated_cluster(1, transport, 2);
        let d = cluster.dispatcher();
        let h = d.register("gate").unwrap();
        let msg = h.msg_create(&SourceArgs::bytes(vec![0u8; 8])).unwrap();
        let msgs = vec![msg.clone(), msg.clone(), msg.clone(), msg.clone()];

        // Two parked invocations hold the whole window.
        let p1 = d.invoke_begin(Target::Worker(0), &msg).unwrap();
        let p2 = d.invoke_begin(Target::Worker(0), &msg).unwrap();
        let start = std::time::Instant::now();
        assert!(
            d.try_invoke_begin(Target::Worker(0), &msg).unwrap().is_none(),
            "{transport:?}"
        );
        assert!(
            d.try_invoke_batch(Target::Worker(0), &msgs).unwrap().is_empty(),
            "{transport:?}"
        );
        assert!(
            start.elapsed() < Duration::from_secs(5),
            "{transport:?}: try variants must not park"
        );

        gate.store(true, Ordering::Release);
        assert!(p1.wait().unwrap().ok(), "{transport:?}");
        assert!(p2.wait().unwrap().ok(), "{transport:?}");

        // Freed window: a 4-frame batch admits exactly max_inflight = 2.
        let pending = d.try_invoke_batch(Target::Worker(0), &msgs).unwrap();
        assert_eq!(pending.len(), 2, "{transport:?}");
        for p in pending {
            assert!(p.wait().unwrap().ok(), "{transport:?}");
        }
    }
}
