//! Coordinator integration: failure injection, mixed workloads, placement
//! invariants, telemetry accounting, reply-path invocation, and collective
//! scatter-gather invocations — each traffic scenario driven over *every*
//! delivery transport (RDMA-PUT ring, AM send-receive, and intra-node
//! shared memory) through the identical cluster harness.

use two_chains::coordinator::{
    decode_forward_failure, Cluster, ClusterConfig, ClusterSnapshot, FilterIfunc, GetIfunc,
    InsertIfunc, Target, TransportKind, GET_MISSING,
};
use two_chains::ifunc::builtin::{
    ChecksumIfunc, CounterIfunc, EchoIfunc, HopIfunc, OutOfBoundsIfunc,
};
use two_chains::ifunc::{SourceArgs, DEFAULT_TTL};
use two_chains::util::XorShift;

/// Run `scenario` once per transport, so every assertion below holds for
/// the ring, AM, and intra-node shm delivery paths alike.
fn for_each_transport(scenario: impl Fn(TransportKind)) {
    for transport in TransportKind::ALL {
        scenario(transport);
    }
}

fn counter_cluster(workers: usize, transport: TransportKind) -> Cluster {
    let cluster = Cluster::launch(
        ClusterConfig::builder().workers(workers).transport(transport).build().unwrap(),
        |_, ctx, _| {
            ctx.library_dir().install(Box::new(CounterIfunc::default()));
        },
    )
    .unwrap();
    for lib in [
        Box::new(CounterIfunc::default()) as Box<dyn two_chains::ifunc::IfuncLibrary>,
        Box::new(ChecksumIfunc),
        Box::new(OutOfBoundsIfunc),
    ] {
        cluster.leader.library_dir().install(lib);
    }
    cluster
}

/// Faulty ifuncs interleaved with good ones: failures are contained,
/// counted, and never corrupt the stream.
#[test]
fn failure_injection_does_not_stall_the_stream() {
    for_each_transport(|transport| {
        let cluster = counter_cluster(2, transport);
        let d = cluster.dispatcher();
        let h_good = d.register("counter").unwrap();
        let h_bad = d.register("oob").unwrap();
        let args = SourceArgs::bytes(vec![0u8; 64]);
        let msg_good = h_good.msg_create(&args).unwrap();
        let msg_bad = h_bad.msg_create(&args).unwrap();

        let mut good = 0u64;
        let mut bad = 0u64;
        let mut rng = XorShift::new(99);
        for key in 0..200u64 {
            if rng.below(4) == 0 {
                d.send(Target::Key(key), &msg_bad).unwrap();
                bad += 1;
            } else {
                d.send(Target::Key(key), &msg_good).unwrap();
                good += 1;
            }
        }
        d.barrier().unwrap();

        let executed: u64 = cluster.workers.iter().map(|w| w.executed()).sum();
        let failed: u64 = cluster
            .workers
            .iter()
            .map(|w| w.stats.failed.load(std::sync::atomic::Ordering::Relaxed))
            .sum();
        assert_eq!(executed, good, "{transport:?}");
        assert_eq!(failed, bad, "{transport:?}");
        // Every good message actually ran (counter proves execution).
        let counted: u64 =
            cluster.workers.iter().map(|w| w.ctx.symbols().counter_value()).sum();
        assert_eq!(counted, good, "{transport:?}");
        cluster.shutdown().unwrap();
    });
}

/// Mixed ifunc types through one link: per-name auto-registration, both
/// execute correctly interleaved, and repeats hit the verified-program
/// cache.
#[test]
fn mixed_types_share_a_link() {
    for_each_transport(|transport| {
        let cluster = counter_cluster(1, transport);
        let d = cluster.dispatcher();
        let h_counter = d.register("counter").unwrap();
        let h_checksum = d.register("checksum").unwrap();

        for i in 0..50u64 {
            let payload = vec![1u8; 100 + (i as usize % 32) * 8];
            if i % 2 == 0 {
                d.send(
                    Target::Worker(0),
                    &h_counter.msg_create(&SourceArgs::bytes(payload)).unwrap(),
                )
                .unwrap();
            } else {
                d.send(
                    Target::Worker(0),
                    &h_checksum.msg_create(&SourceArgs::bytes(payload)).unwrap(),
                )
                .unwrap();
            }
        }
        d.barrier().unwrap();
        assert_eq!(cluster.workers[0].executed(), 50, "{transport:?}");
        // Two types -> exactly two auto-registration misses on the worker;
        // every later frame skips link + verify via the cached program.
        let snap = ClusterSnapshot::capture(&cluster);
        assert_eq!(snap.workers[0].ctx.cache_misses, 2, "{transport:?}");
        assert_eq!(snap.workers[0].ctx.cache_hits, 48, "{transport:?}");
        cluster.shutdown().unwrap();
    });
}

/// Placement is stable and total across cluster sizes.
#[test]
fn placement_is_total_and_balanced() {
    for workers in [1usize, 2, 5, 8] {
        let cluster = counter_cluster(workers, TransportKind::Ring);
        let d = cluster.dispatcher();
        let mut counts = vec![0usize; workers];
        for key in 0..4000u64 {
            counts[d.route_key(key)] += 1;
        }
        let min = *counts.iter().min().unwrap();
        let max = *counts.iter().max().unwrap();
        assert!(min > 0, "{workers} workers: empty shard");
        assert!(
            (max - min) as f64 / (4000.0 / workers as f64) < 0.5,
            "{workers} workers: imbalance {counts:?}"
        );
        cluster.shutdown().unwrap();
    }
}

/// Telemetry accounting matches ground truth after a burst.
#[test]
fn telemetry_matches_ground_truth() {
    for_each_transport(|transport| {
        let cluster = counter_cluster(3, transport);
        let d = cluster.dispatcher();
        let h = d.register("counter").unwrap();
        let msg = h.msg_create(&SourceArgs::bytes(vec![7u8; 48])).unwrap();
        for key in 0..120u64 {
            d.send(Target::Key(key), &msg).unwrap();
        }
        d.barrier().unwrap();
        let snap = ClusterSnapshot::capture(&cluster);
        let executed: u64 = snap.workers.iter().map(|w| w.executed).sum();
        assert_eq!(executed, 120, "{transport:?}");
        let flushes: u64 = snap.workers.iter().map(|w| w.ctx.icache_flushes).sum();
        assert_eq!(flushes, 120, "{transport:?}");
        // JSON renders and parses back.
        let parsed = two_chains::util::Json::parse(&snap.to_json().to_string()).unwrap();
        assert!(parsed.get("workers").is_some());
        cluster.shutdown().unwrap();
    });
}

/// `Dispatcher::invoke_one` returns the injected function's `r0` through
/// the reply ring — and a rejected frame comes back as a failed reply
/// without desynchronizing later invocations.
#[test]
fn invoke_returns_injected_r0() {
    for_each_transport(|transport| {
        let cluster = counter_cluster(2, transport);
        let d = cluster.dispatcher();
        let h = d.register("counter").unwrap();
        let msg = h.msg_create(&SourceArgs::bytes(vec![0u8; 16])).unwrap();

        // counter_add(1) returns the post-increment counter value in r0.
        let r1 = d.invoke_one(Target::Worker(0), &msg).unwrap();
        assert!(r1.ok(), "{transport:?}");
        assert_eq!(r1.r0, 1, "{transport:?}");
        let r2 = d.invoke_one(Target::Worker(0), &msg).unwrap();
        assert_eq!(r2.r0, 2, "{transport:?}");
        assert!(r2.seq > r1.seq, "{transport:?}");

        // A hostile frame is consumed and answered as failed...
        let h_bad = d.register("oob").unwrap();
        let bad = h_bad.msg_create(&SourceArgs::bytes(vec![0u8; 16])).unwrap();
        let rf = d.invoke_one(Target::Worker(0), &bad).unwrap();
        assert!(!rf.ok(), "{transport:?}");
        // ...and the link keeps working afterwards.
        let r3 = d.invoke_one(Target::Worker(0), &msg).unwrap();
        assert_eq!(r3.r0, 3, "{transport:?}");
        cluster.shutdown().unwrap();
    });
}

/// The serve-mode ingestion flow (no TCP): InsertIfunc routes each record
/// to the key's owner, decodes the key + f32 data from the payload in
/// bytecode, and inserts via the `db_insert` GOT symbol.
#[test]
fn insert_ifunc_ingestion_and_lookup() {
    let cluster = Cluster::launch(
        ClusterConfig::builder().workers(3).build().unwrap(),
        |_, _, _| {},
    )
    .unwrap();
    cluster.leader.library_dir().install(Box::new(InsertIfunc));
    let d = cluster.dispatcher();
    let h = d.register("insert").unwrap();

    let mut rng = XorShift::new(7);
    let mut expect = Vec::new();
    for key in 0..40u64 {
        let len = rng.range(1, 64) as usize;
        let data = rng.f32s(len);
        let msg = h.msg_create(&InsertIfunc::args(key, &data)).unwrap();
        d.send(Target::Key(key), &msg).unwrap();
        expect.push((key, data));
    }
    d.barrier().unwrap();

    for (key, data) in expect {
        let w = d.route_key(key);
        let got = cluster.workers[w].store.get(key).expect("record present");
        assert_eq!(got, data, "key {key}");
    }
    assert_eq!(d.total_executed(), 40);
    cluster.shutdown().unwrap();
}

/// The full serve `get` path, minus the socket: insert by injection, then
/// look up by injection — the injected `GetIfunc` calls `db_get`, which
/// pushes the record bytes into the reply frame, and the reply carries the
/// element count in r0 plus the record itself inline in its payload.
#[test]
fn get_ifunc_returns_worker_computed_data() {
    for_each_transport(|transport| {
        let cluster = Cluster::launch(
            ClusterConfig::builder().workers(3).transport(transport).build().unwrap(),
            |_, _, _| {},
        )
        .unwrap();
        cluster.leader.library_dir().install(Box::new(InsertIfunc));
        cluster.leader.library_dir().install(Box::new(GetIfunc));
        let d = cluster.dispatcher();
        let h_ins = d.register("insert").unwrap();
        let h_get = d.register("get").unwrap();

        let mut rng = XorShift::new(21);
        let mut expect = Vec::new();
        for key in 0..20u64 {
            let len = rng.range(1, 48) as usize;
            let data = rng.f32s(len);
            let msg = h_ins.msg_create(&InsertIfunc::args(key, &data)).unwrap();
            d.send(Target::Key(key), &msg).unwrap();
            expect.push((key, data));
        }
        d.barrier().unwrap();

        for (key, data) in expect {
            let msg = h_get.msg_create(&GetIfunc::args(key)).unwrap();
            let (reply, fetched) = d.fetch(Target::Key(key), &msg).unwrap();
            assert!(reply.ok(), "{transport:?} key {key}");
            assert_eq!(reply.r0 as usize, data.len(), "{transport:?} key {key}");
            assert_eq!(fetched, data, "{transport:?} key {key}");
        }

        // Absent key: the injected function reports MISSING in r0.
        let absent = 999_999u64;
        let msg = h_get.msg_create(&GetIfunc::args(absent)).unwrap();
        let (reply, fetched) = d.fetch(Target::Key(absent), &msg).unwrap();
        assert!(reply.ok(), "{transport:?}");
        assert_eq!(reply.r0, GET_MISSING, "{transport:?}");
        assert!(fetched.is_empty(), "{transport:?}");
        cluster.shutdown().unwrap();
    });
}

/// ≥ 4 invocations in flight against one worker at once (window > 1),
/// each carrying a distinct payload — replies collected out of order must
/// still match their seq's payload.
#[test]
fn pipelined_invocations_carry_per_seq_payloads() {
    for_each_transport(|transport| {
        let cluster = Cluster::launch(
            ClusterConfig::builder()
                .workers(1)
                .transport(transport)
                .max_inflight(8)
                .build()
                .unwrap(),
            |_, ctx, _| {
                ctx.library_dir().install(Box::new(EchoIfunc));
            },
        )
        .unwrap();
        cluster.leader.library_dir().install(Box::new(EchoIfunc));
        let d = cluster.dispatcher();
        let h = d.register("echo").unwrap();

        let payloads: Vec<Vec<u8>> =
            (0..6u8).map(|i| vec![i + 1; 64 + i as usize * 13]).collect();
        // Issue every invocation before collecting any reply: all six are
        // in flight concurrently (the window admits 8).
        let pending: Vec<_> = payloads
            .iter()
            .map(|p| {
                d.invoke_begin(
                    Target::Worker(0),
                    &h.msg_create(&SourceArgs::bytes(p.clone())).unwrap(),
                )
                .unwrap()
            })
            .collect();
        assert!(pending.len() >= 4, "need ≥ 4 concurrent in-flight invocations");
        // Collect newest-first: out-of-order waits must not cross wires.
        for (i, p) in pending.into_iter().enumerate().rev() {
            let seq = p.seq();
            let reply = p.wait().unwrap();
            assert!(reply.ok(), "{transport:?} seq {seq}");
            assert_eq!(reply.seq, seq, "{transport:?}");
            assert_eq!(reply.payload, payloads[i], "{transport:?} seq {seq}");
            assert_eq!(reply.r0 as usize, payloads[i].len(), "{transport:?} seq {seq}");
        }
        assert_eq!(d.total_executed(), payloads.len() as u64, "{transport:?}");
        cluster.shutdown().unwrap();
    });
}

/// An uncollected invocation reply survives a fire-and-forget flood far
/// larger than the reply ring: sends stall at the lap boundary until a
/// concurrent thread collects the reply, then the flood proceeds — the
/// payload is never overwritten.
#[test]
fn pending_reply_survives_fire_and_forget_flood() {
    for_each_transport(|transport| {
        let cluster = Cluster::launch(
            ClusterConfig::builder().workers(1).transport(transport).build().unwrap(),
            |_, ctx, _| {
                ctx.library_dir().install(Box::new(EchoIfunc));
                ctx.library_dir().install(Box::new(CounterIfunc::default()));
            },
        )
        .unwrap();
        cluster.leader.library_dir().install(Box::new(EchoIfunc));
        cluster.leader.library_dir().install(Box::new(CounterIfunc::default()));
        let d = cluster.dispatcher();
        let h_echo = d.register("echo").unwrap();
        let h_cnt = d.register("counter").unwrap();

        let body = b"survivor".to_vec();
        let pending = d
            .invoke_begin(
                Target::Worker(0),
                &h_echo.msg_create(&SourceArgs::bytes(body.clone())).unwrap(),
            )
            .unwrap();
        // Collect the reply concurrently; the flood below stalls at the
        // reply-ring lap boundary until this thread has read it.
        let collector = std::thread::spawn(move || pending.wait().unwrap());
        let cnt = h_cnt.msg_create(&SourceArgs::bytes(vec![0u8; 32])).unwrap();
        let flood = 3 * two_chains::ifunc::REPLY_SLOTS;
        for _ in 0..flood {
            d.send(Target::Worker(0), &cnt).unwrap();
        }
        let reply = collector.join().unwrap();
        assert!(reply.ok(), "{transport:?}");
        assert_eq!(reply.payload, body, "{transport:?}");
        d.barrier().unwrap();
        assert_eq!(d.total_executed(), 1 + flood as u64, "{transport:?}");
        cluster.shutdown().unwrap();
    });
}

/// Legacy-mode (`stream_replies: false`) regression: a single-threaded
/// caller that interleaves a ring's worth of sends behind an uncollected
/// reply gets a clear transport error at the lap boundary (instead of
/// silent reply corruption) — and the pending reply itself is still
/// collectible afterwards. (A streamed link has no lap boundary: the
/// collector parks the reply in leader memory and the flood proceeds —
/// see `pending_reply_survives_fire_and_forget_flood`.)
#[test]
fn lap_guard_errors_instead_of_corrupting_reply() {
    let cluster = Cluster::launch(
        ClusterConfig::builder()
            .workers(1)
            .stream_replies(false)
            .reply_timeout(std::time::Duration::from_millis(50))
            .build()
            .unwrap(),
        |_, ctx, _| {
            ctx.library_dir().install(Box::new(EchoIfunc));
            ctx.library_dir().install(Box::new(CounterIfunc::default()));
        },
    )
    .unwrap();
    cluster.leader.library_dir().install(Box::new(EchoIfunc));
    cluster.leader.library_dir().install(Box::new(CounterIfunc::default()));
    let d = cluster.dispatcher();
    let h_echo = d.register("echo").unwrap();
    let h_cnt = d.register("counter").unwrap();

    let body = b"still here".to_vec();
    let pending = d
        .invoke_begin(
            Target::Worker(0),
            &h_echo.msg_create(&SourceArgs::bytes(body.clone())).unwrap(),
        )
        .unwrap();
    let cnt = h_cnt.msg_create(&SourceArgs::bytes(vec![0u8; 32])).unwrap();
    let mut lap_error = None;
    for _ in 0..2 * two_chains::ifunc::REPLY_SLOTS {
        if let Err(e) = d.send(Target::Worker(0), &cnt) {
            lap_error = Some(e);
            break;
        }
    }
    let err = lap_error.expect("send past the lap boundary must error, not corrupt");
    assert!(err.to_string().contains("lap"), "{err}");
    // The guarded reply is intact.
    let reply = pending.wait().unwrap();
    assert!(reply.ok());
    assert_eq!(reply.payload, body);
    d.barrier().unwrap();
    cluster.shutdown().unwrap();
}

/// Over-issuing invocations past `max_inflight` without collecting any
/// errors out (naming the full window) instead of deadlocking a
/// single-threaded caller — and the link recovers once replies are
/// collected.
#[test]
fn full_invoke_window_errors_instead_of_deadlocking() {
    let cluster = Cluster::launch(
        ClusterConfig::builder()
            .workers(1)
            .max_inflight(2)
            .reply_timeout(std::time::Duration::from_millis(50))
            .build()
            .unwrap(),
        |_, ctx, _| {
            ctx.library_dir().install(Box::new(EchoIfunc));
        },
    )
    .unwrap();
    cluster.leader.library_dir().install(Box::new(EchoIfunc));
    let d = cluster.dispatcher();
    let h = d.register("echo").unwrap();
    let msg = h.msg_create(&SourceArgs::bytes(b"w".to_vec())).unwrap();

    let p1 = d.invoke_begin(Target::Worker(0), &msg).unwrap();
    let p2 = d.invoke_begin(Target::Worker(0), &msg).unwrap();
    let err = d
        .invoke_begin(Target::Worker(0), &msg)
        .expect_err("third begin must error, not hang");
    assert!(err.to_string().contains("window full"), "{err}");
    // Collecting the outstanding replies frees the window.
    assert!(p1.wait().unwrap().ok());
    assert!(p2.wait().unwrap().ok());
    assert!(d.invoke_one(Target::Worker(0), &msg).unwrap().ok());
    cluster.shutdown().unwrap();
}

/// A 1 MiB record — 16× the reply frame's chunk size — round-trips
/// through `insert` + `fetch` on every transport (ring, AM, and shm). The
/// reply streams as 16 chunk frames through a 64-slot ring and
/// reassembles bit-exact.
#[test]
fn get_streams_a_1mib_record_over_all_transports() {
    for_each_transport(|transport| {
        let cluster = Cluster::launch(
            ClusterConfig::builder().workers(2).transport(transport).build().unwrap(),
            |_, _, _| {},
        )
        .unwrap();
        cluster.leader.library_dir().install(Box::new(InsertIfunc));
        cluster.leader.library_dir().install(Box::new(GetIfunc));
        let d = cluster.dispatcher();
        let h_ins = d.register("insert").unwrap();
        let h_get = d.register("get").unwrap();

        let n = (1usize << 20) / 4; // 262144 f32 elements = 1 MiB
        let data: Vec<f32> = (0..n).map(|i| (i % 1009) as f32).collect();
        let key = 0xB16_DA7A;
        let msg = h_ins.msg_create(&InsertIfunc::args(key, &data)).unwrap();
        d.send(Target::Key(key), &msg).unwrap();
        d.barrier().unwrap();

        let msg = h_get.msg_create(&GetIfunc::args(key)).unwrap();
        let (reply, fetched) = d.fetch(Target::Key(key), &msg).unwrap();
        assert!(reply.ok(), "{transport:?}: {:?}", reply.status);
        assert!(!reply.overflowed(), "{transport:?}: streamed links never overflow");
        assert_eq!(reply.r0 as usize, n, "{transport:?}");
        assert_eq!(fetched.len(), n, "{transport:?}");
        assert_eq!(fetched, data, "{transport:?}");
        cluster.shutdown().unwrap();
    });
}

/// Chunked reply streams interleaved with fire-and-forget floods bigger
/// than the whole reply ring, on both transports: every chunk of every
/// stream reassembles intact — the flood's replies recycle slots around
/// the parked invocation reply without ever splicing into it.
#[test]
fn chunked_replies_interleave_with_fire_and_forget_floods() {
    for_each_transport(|transport| {
        let cluster = Cluster::launch(
            ClusterConfig::builder()
                .workers(1)
                .transport(transport)
                .max_inflight(4)
                .build()
                .unwrap(),
            |_, ctx, _| {
                ctx.library_dir().install(Box::new(EchoIfunc));
                ctx.library_dir().install(Box::new(CounterIfunc::default()));
            },
        )
        .unwrap();
        cluster.leader.library_dir().install(Box::new(EchoIfunc));
        cluster.leader.library_dir().install(Box::new(CounterIfunc::default()));
        let d = cluster.dispatcher();
        let h_echo = d.register("echo").unwrap();
        let h_cnt = d.register("counter").unwrap();
        let cnt = h_cnt.msg_create(&SourceArgs::bytes(vec![0u8; 32])).unwrap();

        let flood = 2 * two_chains::ifunc::REPLY_SLOTS;
        let rounds = 4u64;
        for round in 0..rounds {
            // ~3 chunks of reply payload, stamped per round.
            let body: Vec<u8> = (0..200_000usize)
                .map(|i| ((i as u64 + round) % 251) as u8)
                .collect();
            let pending = d
                .invoke_begin(
                    Target::Worker(0),
                    &h_echo.msg_create(&SourceArgs::bytes(body.clone())).unwrap(),
                )
                .unwrap();
            for _ in 0..flood {
                d.send(Target::Worker(0), &cnt).unwrap();
            }
            let reply = pending.wait().unwrap();
            assert!(reply.ok(), "{transport:?} round {round}");
            assert_eq!(reply.payload, body, "{transport:?} round {round}");
            assert_eq!(reply.r0 as usize, body.len(), "{transport:?} round {round}");
        }
        d.barrier().unwrap();
        assert_eq!(d.total_executed(), rounds * (1 + flood as u64), "{transport:?}");
        cluster.shutdown().unwrap();
    });
}

/// The serve-path fix: an insert is an invocation on the *owning* worker
/// only. A sibling worker parked inside a long-running injected function
/// (gated on a host symbol this test controls) must not delay it — the
/// old insert-then-cluster-barrier flow would hang here until the gate
/// opened. Runs over every transport: the independence property is about
/// link isolation, which each delivery path must preserve.
#[test]
fn inserts_do_not_wait_on_other_workers_consumption() {
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;

    struct GateIfunc;
    impl two_chains::ifunc::IfuncLibrary for GateIfunc {
        fn name(&self) -> &str {
            "gate"
        }
        fn payload_get_max_size(&self, a: &SourceArgs) -> usize {
            a.len()
        }
        fn payload_init(&self, p: &mut [u8], a: &SourceArgs) -> two_chains::Result<usize> {
            p[..a.len()].copy_from_slice(a.as_bytes());
            Ok(a.len())
        }
        fn code(&self) -> two_chains::ifunc::CodeImage {
            let mut a = two_chains::vm::Assembler::new();
            a.call("gate_wait");
            a.halt();
            let (vm_code, imports) = a.assemble();
            two_chains::ifunc::CodeImage { imports, vm_code, hlo: vec![] }
        }
    }

    for_each_transport(|transport| {
        let gate = Arc::new(AtomicBool::new(false));
        let g = gate.clone();
        let cluster = Cluster::launch(
            ClusterConfig::builder().workers(2).transport(transport).build().unwrap(),
            move |_, ctx, _| {
                let g = g.clone();
                ctx.symbols().install_fn("gate_wait", move |_, _| {
                    while !g.load(Ordering::Acquire) {
                        std::thread::yield_now();
                    }
                    Ok(0)
                });
            },
        )
        .unwrap();
        cluster.leader.library_dir().install(Box::new(GateIfunc));
        cluster.leader.library_dir().install(Box::new(InsertIfunc));
        let d = cluster.dispatcher();
        let h_gate = d.register("gate").unwrap();
        let h_ins = d.register("insert").unwrap();

        let key0 = (0u64..).find(|k| d.route_key(*k) == 0).unwrap();

        // Park worker 1 inside the gated function (its receive loop is now
        // busy; its consumed counter will not move).
        d.send(
            Target::Worker(1),
            &h_gate.msg_create(&SourceArgs::bytes(vec![0u8; 8])).unwrap(),
        )
        .unwrap();

        // Serve-style insert to worker 0: an invocation on its own link —
        // completes while worker 1 is still parked.
        let reply = d
            .invoke_one(
                Target::Worker(0),
                &h_ins.msg_create(&InsertIfunc::args(key0, &[1.0, 2.0, 3.0])).unwrap(),
            )
            .unwrap();
        assert!(reply.ok(), "{transport:?}");
        assert_eq!(
            cluster.workers[0].store.get(key0),
            Some(vec![1.0, 2.0, 3.0]),
            "{transport:?}"
        );
        assert_eq!(
            cluster.workers[1].executed(),
            0,
            "{transport:?}: worker 1 must still be parked"
        );

        gate.store(true, Ordering::Release);
        d.barrier().unwrap();
        assert_eq!(cluster.workers[1].executed(), 1, "{transport:?}");
        cluster.shutdown().unwrap();
    });
}

/// Mixed traffic: pipelined echo invocations interleaved with batched
/// fire-and-forget counters on the same link stay correctly sequenced.
#[test]
fn pipelined_invokes_interleave_with_batched_sends() {
    for_each_transport(|transport| {
        let cluster = Cluster::launch(
            ClusterConfig::builder()
                .workers(1)
                .transport(transport)
                .max_inflight(4)
                .build()
                .unwrap(),
            |_, ctx, _| {
                ctx.library_dir().install(Box::new(EchoIfunc));
                ctx.library_dir().install(Box::new(CounterIfunc::default()));
            },
        )
        .unwrap();
        cluster.leader.library_dir().install(Box::new(EchoIfunc));
        cluster.leader.library_dir().install(Box::new(CounterIfunc::default()));
        let d = cluster.dispatcher();
        let h_echo = d.register("echo").unwrap();
        let h_cnt = d.register("counter").unwrap();
        let counters: Vec<_> = (0..5)
            .map(|_| h_cnt.msg_create(&SourceArgs::bytes(vec![0u8; 32])).unwrap())
            .collect();

        for round in 0..10u64 {
            let body = round.to_le_bytes().to_vec();
            let pending = d
                .invoke_begin(
                    Target::Worker(0),
                    &h_echo.msg_create(&SourceArgs::bytes(body.clone())).unwrap(),
                )
                .unwrap();
            d.send_batch(Target::Worker(0), &counters).unwrap();
            let reply = pending.wait().unwrap();
            assert!(reply.ok(), "{transport:?} round {round}");
            assert_eq!(reply.payload, body, "{transport:?} round {round}");
        }
        d.barrier().unwrap();
        assert_eq!(d.total_executed(), 10 + 50, "{transport:?}");
        cluster.shutdown().unwrap();
    });
}

/// The collective acceptance scenario: `invoke_all` injects one program,
/// fans it out, and merges every worker's reply with correct per-worker
/// attribution — over ring, AM, and shm. Each worker's store is seeded
/// with a shard-distinct record, so a crossed wire (reply attributed to
/// the wrong worker) is detectable, not silent.
#[test]
fn invoke_all_merges_attributed_replies() {
    for_each_transport(|transport| {
        let cluster = Cluster::launch(
            ClusterConfig::builder().workers(3).transport(transport).build().unwrap(),
            |i, _, store| {
                store.insert(7, vec![i as f32, 100.0 + i as f32]);
            },
        )
        .unwrap();
        cluster.leader.library_dir().install(Box::new(GetIfunc));
        let d = cluster.dispatcher();
        let h = d.register("get").unwrap();
        let msg = h.msg_create(&GetIfunc::args(7)).unwrap();

        let multi = d.invoke_all(&msg).unwrap();
        assert_eq!(multi.workers(), vec![0, 1, 2], "{transport:?}");
        let merged = multi.wait().unwrap();
        assert!(merged.all_ok(), "{transport:?}");
        assert_eq!(merged.len(), 3, "{transport:?}");
        for w in 0..3usize {
            let reply = merged.reply_for(w).unwrap();
            assert_eq!(reply.r0, 2, "{transport:?} worker {w}");
            assert_eq!(
                reply.payload_f32s(),
                vec![w as f32, 100.0 + w as f32],
                "{transport:?} worker {w}: reply attributed to the wrong worker"
            );
        }

        // An explicit Set preserves its order and hits only its members.
        let merged = d.invoke_multi(Target::Set(&[2, 0]), &msg).unwrap().wait().unwrap();
        let got: Vec<usize> = merged.replies().iter().map(|(w, _)| *w).collect();
        assert_eq!(got, vec![2, 0], "{transport:?}");
        assert_eq!(merged.reply_for(1), None, "{transport:?}");
        cluster.shutdown().unwrap();
    });
}

/// The scatter-gather demo workload end-to-end: a shard-local filter
/// (`FilterIfunc` → `db_filter`) injected on every worker with one
/// `invoke_all`, each shard scanning only its own records, the leader
/// merging the per-worker match lists.
#[test]
fn invoke_all_filter_scans_every_shard() {
    for_each_transport(|transport| {
        let cluster = Cluster::launch(
            ClusterConfig::builder().workers(3).transport(transport).build().unwrap(),
            |i, _, store| {
                // Worker i owns records keyed 100i..100i+5 whose first
                // element is the record index 0..5.
                for j in 0..5u64 {
                    store.insert(100 * i as u64 + j, vec![j as f32, -1.0]);
                }
            },
        )
        .unwrap();
        cluster.leader.library_dir().install(Box::new(FilterIfunc));
        let d = cluster.dispatcher();
        let h = d.register("filter").unwrap();
        let msg = h.msg_create(&FilterIfunc::args(3.0)).unwrap();

        let merged = d.invoke_all(&msg).unwrap().wait().unwrap();
        assert!(merged.all_ok(), "{transport:?}");
        let mut all_matches = Vec::new();
        for (worker, reply) in merged.replies() {
            let matches = FilterIfunc::matches(&reply.payload);
            // Each shard matched exactly its records with first ≥ 3.0
            // (indices 3 and 4), and r0 agrees with the payload.
            assert_eq!(reply.r0, 2, "{transport:?} worker {worker}");
            assert_eq!(matches.len(), 2, "{transport:?} worker {worker}");
            for (key, v) in &matches {
                assert_eq!(key / 100, *worker as u64, "{transport:?}: foreign shard key");
                assert!(*v >= 3.0, "{transport:?}");
            }
            all_matches.extend(matches);
        }
        assert_eq!(all_matches.len(), 6, "{transport:?}");
        cluster.shutdown().unwrap();
    });
}

/// `ClusterConfig::builder()` rejects the configurations the raw struct
/// literal silently accepts or repairs.
#[test]
fn cluster_config_builder_validates() {
    use two_chains::ifunc::REPLY_SLOTS;
    assert!(ClusterConfig::builder().workers(0).build().is_err());
    assert!(ClusterConfig::builder().max_inflight(0).build().is_err());
    let err = ClusterConfig::builder()
        .max_inflight(REPLY_SLOTS + 1)
        .build()
        .expect_err("over-window max_inflight must be surfaced, not clamped");
    assert!(err.to_string().contains("REPLY_SLOTS"), "{err}");
    assert!(ClusterConfig::builder()
        .reply_timeout(std::time::Duration::ZERO)
        .build()
        .is_err());

    let c = ClusterConfig::builder()
        .workers(4)
        .ring_bytes(8192)
        .transport(TransportKind::Shm)
        .max_inflight(REPLY_SLOTS)
        .reply_timeout(std::time::Duration::from_secs(1))
        .stream_replies(false)
        .build()
        .unwrap();
    assert_eq!(c.workers, 4);
    assert_eq!(c.ring_bytes, 8192);
    assert_eq!(c.transport, TransportKind::Shm);
    assert_eq!(c.max_inflight, REPLY_SLOTS);
    assert!(!c.stream_replies);
    assert!(ClusterConfig::builder().no_reply_timeout().build().unwrap().reply_timeout.is_none());
    // Mesh forwarding needs the streamed-reply collector: relayed chain
    // replies land out of order.
    assert!(ClusterConfig::builder().mesh(true).stream_replies(false).build().is_err());
    assert!(ClusterConfig::builder().mesh(true).build().unwrap().mesh);
}

/// A cluster with the worker↔worker mesh wired and the multi-hop `hop`
/// pipeline ifunc installed everywhere.
fn mesh_cluster(workers: usize, transport: TransportKind) -> Cluster {
    let cluster = Cluster::launch(
        ClusterConfig::builder().workers(workers).transport(transport).mesh(true).build().unwrap(),
        |_, ctx, _| {
            ctx.library_dir().install(Box::new(HopIfunc));
        },
    )
    .unwrap();
    cluster.leader.library_dir().install(Box::new(HopIfunc));
    cluster
}

/// The tentpole acceptance path: a two-hop `forward` pipeline
/// (leader → w0 → w1 → w2, the graph_analysis-style stage chain) returns
/// its result to the leader over every transport with **zero
/// leader-relay frames** — the leader sends exactly one frame, to the
/// chain's head, and the intermediate stage results travel
/// worker→worker over the mesh. The final hop's reply relays back to
/// the origin and is collected under the seq the leader registered at
/// injection, like any local invocation.
#[test]
fn mesh_two_hop_pipeline_replies_without_leader_relay() {
    for_each_transport(|transport| {
        let cluster = mesh_cluster(3, transport);
        let d = cluster.dispatcher();
        let h = d.register("hop").unwrap();
        let data: Vec<u8> = (0..64u8).map(|b| b.wrapping_mul(7)).collect();
        let payload = HopIfunc::payload(&[1, 2], &data);
        let before: Vec<u64> = (0..3).map(|w| d.debug_frames_sent(w).unwrap()).collect();
        let msg = h.msg_create(&SourceArgs::bytes(payload)).unwrap();
        let reply = d.invoke_begin(Target::Worker(0), &msg).unwrap().wait().unwrap();
        assert!(reply.ok(), "{transport:?}: {:#x}", reply.r0);
        assert_eq!(reply.payload, data, "{transport:?}");
        // Zero leader-relay frames: one frame to the chain's head, none
        // to the downstream stages.
        let after: Vec<u64> = (0..3).map(|w| d.debug_frames_sent(w).unwrap()).collect();
        assert_eq!(after[0] - before[0], 1, "{transport:?}");
        assert_eq!(after[1], before[1], "{transport:?}");
        assert_eq!(after[2], before[2], "{transport:?}");
        // The intermediate results moved over the mesh instead, and every
        // hop executed at its worker.
        let forwarded: Vec<u64> = cluster.workers.iter().map(|w| w.forwarded()).collect();
        assert_eq!(forwarded, vec![1, 1, 0], "{transport:?}");
        for w in &cluster.workers {
            assert_eq!(w.executed(), 1, "{transport:?} worker {}", w.index);
            assert_eq!(w.forward_failed(), 0, "{transport:?} worker {}", w.index);
        }
        cluster.shutdown().unwrap();
    });
}

/// An itinerary longer than the TTL dies *cleanly* at hop `DEFAULT_TTL`:
/// the leader gets a FAILED reply whose `r0` names the worker the chain
/// died on and the hop count — never a hang.
#[test]
fn mesh_ttl_exhaustion_fails_cleanly() {
    for_each_transport(|transport| {
        let cluster = mesh_cluster(3, transport);
        let d = cluster.dispatcher();
        let h = d.register("hop").unwrap();
        // Ring itinerary 1,2,0,1,2,0,… one entry past the TTL.
        let peers: Vec<usize> =
            (0..DEFAULT_TTL as usize + 1).map(|i| (i + 1) % 3).collect();
        let msg = h
            .msg_create(&SourceArgs::bytes(HopIfunc::payload(&peers, b"doomed")))
            .unwrap();
        let reply = d.invoke_begin(Target::Worker(0), &msg).unwrap().wait().unwrap();
        assert!(!reply.ok(), "{transport:?}");
        let (worker, hops) = decode_forward_failure(reply.r0);
        assert_eq!(hops, DEFAULT_TTL, "{transport:?}");
        // Forward k targets peers[k-1]; the TTL dies on the 8th hop's
        // receiver, peers[7] = (7 + 1) % 3 = 2.
        assert_eq!(worker, 2, "{transport:?}");
        let failed: u64 = cluster.workers.iter().map(|w| w.forward_failed()).sum();
        assert_eq!(failed, 1, "{transport:?}");
        cluster.shutdown().unwrap();
    });
}

/// A two-worker A→B→A→… forwarding cycle is cut by the TTL, not spun
/// forever: the loop executes exactly `DEFAULT_TTL` mesh hops and then
/// reports the worker it was cut on.
#[test]
fn mesh_two_cycle_loop_cut_by_ttl() {
    for_each_transport(|transport| {
        let cluster = mesh_cluster(2, transport);
        let d = cluster.dispatcher();
        let h = d.register("hop").unwrap();
        // Ping-pong itinerary 1,0,1,0,… longer than the TTL.
        let peers: Vec<usize> =
            (0..DEFAULT_TTL as usize + 4).map(|i| (i + 1) % 2).collect();
        let msg = h
            .msg_create(&SourceArgs::bytes(HopIfunc::payload(&peers, b"loop")))
            .unwrap();
        let reply = d.invoke_begin(Target::Worker(0), &msg).unwrap().wait().unwrap();
        assert!(!reply.ok(), "{transport:?}");
        let (worker, hops) = decode_forward_failure(reply.r0);
        assert_eq!(hops, DEFAULT_TTL, "{transport:?}");
        // Hop k lands on worker k % 2; hop 8 lands back on A (worker 0).
        assert_eq!(worker, 0, "{transport:?}");
        // The loop ran exactly TTL hop executions on the mesh (plus the
        // leader-ingress execution at the head).
        let executed: u64 = cluster.workers.iter().map(|w| w.executed()).sum();
        assert_eq!(executed, 1 + DEFAULT_TTL as u64, "{transport:?}");
        cluster.shutdown().unwrap();
    });
}

/// `forward` on a cluster whose mesh is disabled fails the invocation
/// cleanly at the ingress worker (hop 0) instead of hanging or crashing.
#[test]
fn forward_without_mesh_fails_cleanly() {
    for_each_transport(|transport| {
        let cluster = Cluster::launch(
            ClusterConfig::builder().workers(2).transport(transport).build().unwrap(),
            |_, ctx, _| {
                ctx.library_dir().install(Box::new(HopIfunc));
            },
        )
        .unwrap();
        cluster.leader.library_dir().install(Box::new(HopIfunc));
        let d = cluster.dispatcher();
        let h = d.register("hop").unwrap();
        let msg =
            h.msg_create(&SourceArgs::bytes(HopIfunc::payload(&[1], b"nope"))).unwrap();
        let reply = d.invoke_begin(Target::Worker(0), &msg).unwrap().wait().unwrap();
        assert!(!reply.ok(), "{transport:?}");
        assert_eq!(decode_forward_failure(reply.r0), (0, 0), "{transport:?}");
        assert_eq!(cluster.workers[0].forward_failed(), 1, "{transport:?}");
        cluster.shutdown().unwrap();
    });
}
