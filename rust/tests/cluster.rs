//! Coordinator integration: failure injection, mixed workloads, placement
//! invariants, telemetry accounting.

use two_chains::coordinator::{Cluster, ClusterConfig, ClusterSnapshot};
use two_chains::ifunc::builtin::{ChecksumIfunc, CounterIfunc, OutOfBoundsIfunc};
use two_chains::ifunc::SourceArgs;
use two_chains::util::XorShift;

fn counter_cluster(workers: usize) -> Cluster {
    let cluster = Cluster::launch(
        ClusterConfig { workers, ..Default::default() },
        |_, ctx, _| {
            ctx.library_dir().install(Box::new(CounterIfunc::default()));
        },
    )
    .unwrap();
    for lib in [
        Box::new(CounterIfunc::default()) as Box<dyn two_chains::ifunc::IfuncLibrary>,
        Box::new(ChecksumIfunc),
        Box::new(OutOfBoundsIfunc),
    ] {
        cluster.leader.library_dir().install(lib);
    }
    cluster
}

/// Faulty ifuncs interleaved with good ones: failures are contained,
/// counted, and never corrupt the stream.
#[test]
fn failure_injection_does_not_stall_the_stream() {
    let cluster = counter_cluster(2);
    let d = cluster.dispatcher();
    let h_good = d.register("counter").unwrap();
    let h_bad = d.register("oob").unwrap();
    let args = SourceArgs::bytes(vec![0u8; 64]);

    let mut good = 0u64;
    let mut bad = 0u64;
    let mut rng = XorShift::new(99);
    for key in 0..200u64 {
        if rng.below(4) == 0 {
            d.inject_by_key(&h_bad, key, &args).unwrap();
            bad += 1;
        } else {
            d.inject_by_key(&h_good, key, &args).unwrap();
            good += 1;
        }
    }
    d.barrier().unwrap();

    let executed: u64 = cluster.workers.iter().map(|w| w.executed()).sum();
    let failed: u64 = cluster
        .workers
        .iter()
        .map(|w| w.stats.failed.load(std::sync::atomic::Ordering::Relaxed))
        .sum();
    assert_eq!(executed, good);
    assert_eq!(failed, bad);
    // Every good message actually ran (counter proves execution).
    let counted: u64 = cluster.workers.iter().map(|w| w.ctx.symbols().counter_value()).sum();
    assert_eq!(counted, good);
    cluster.shutdown().unwrap();
}

/// Mixed ifunc types through one ring: per-name auto-registration, both
/// execute correctly interleaved.
#[test]
fn mixed_types_share_a_ring() {
    let cluster = counter_cluster(1);
    let d = cluster.dispatcher();
    let h_counter = d.register("counter").unwrap();
    let h_checksum = d.register("checksum").unwrap();

    for i in 0..50u64 {
        let payload = vec![1u8; 100 + (i as usize % 32) * 8];
        if i % 2 == 0 {
            d.send_to(0, &h_counter.msg_create(&SourceArgs::bytes(payload)).unwrap()).unwrap();
        } else {
            d.send_to(0, &h_checksum.msg_create(&SourceArgs::bytes(payload)).unwrap()).unwrap();
        }
    }
    d.barrier().unwrap();
    assert_eq!(cluster.workers[0].executed(), 50);
    // Two types -> exactly two auto-registration misses on the worker.
    let snap = ClusterSnapshot::capture(&cluster);
    assert_eq!(snap.workers[0].0.cache_misses, 2);
    assert_eq!(snap.workers[0].0.cache_hits, 48);
    cluster.shutdown().unwrap();
}

/// Placement is stable and total across cluster sizes.
#[test]
fn placement_is_total_and_balanced() {
    for workers in [1usize, 2, 5, 8] {
        let cluster = counter_cluster(workers);
        let d = cluster.dispatcher();
        let mut counts = vec![0usize; workers];
        for key in 0..4000u64 {
            counts[d.route_key(key)] += 1;
        }
        let min = *counts.iter().min().unwrap();
        let max = *counts.iter().max().unwrap();
        assert!(min > 0, "{workers} workers: empty shard");
        assert!(
            (max - min) as f64 / (4000.0 / workers as f64) < 0.5,
            "{workers} workers: imbalance {counts:?}"
        );
        cluster.shutdown().unwrap();
    }
}

/// Telemetry accounting matches ground truth after a burst.
#[test]
fn telemetry_matches_ground_truth() {
    let cluster = counter_cluster(3);
    let d = cluster.dispatcher();
    let h = d.register("counter").unwrap();
    for key in 0..120u64 {
        d.inject_by_key(&h, key, &SourceArgs::bytes(vec![7u8; 48])).unwrap();
    }
    d.barrier().unwrap();
    let snap = ClusterSnapshot::capture(&cluster);
    let executed: u64 = snap.workers.iter().map(|(_, e, _, _)| *e).sum();
    assert_eq!(executed, 120);
    let flushes: u64 = snap.workers.iter().map(|(c, ..)| c.icache_flushes).sum();
    assert_eq!(flushes, 120);
    // JSON renders and parses back.
    let parsed = two_chains::util::Json::parse(&snap.to_json().to_string()).unwrap();
    assert!(parsed.get("workers").is_some());
    cluster.shutdown().unwrap();
}

/// The serve-mode ingestion flow (no TCP): InsertIfunc routes each record
/// to the key's owner, decodes the key + f32 data from the payload in
/// bytecode, and inserts via the `db_insert` GOT symbol.
#[test]
fn insert_ifunc_ingestion_and_lookup() {
    use two_chains::coordinator::InsertIfunc;
    let cluster = Cluster::launch(
        ClusterConfig { workers: 3, ..Default::default() },
        |_, _, _| {},
    )
    .unwrap();
    cluster.leader.library_dir().install(Box::new(InsertIfunc));
    let d = cluster.dispatcher();
    let h = d.register("insert").unwrap();

    let mut rng = XorShift::new(7);
    let mut expect = Vec::new();
    for key in 0..40u64 {
        let len = rng.range(1, 64) as usize;
        let data = rng.f32s(len);
        d.inject_by_key(&h, key, &InsertIfunc::args(key, &data)).unwrap();
        expect.push((key, data));
    }
    d.barrier().unwrap();

    for (key, data) in expect {
        let w = d.route_key(key);
        let got = cluster.workers[w].store.get(key).expect("record present");
        assert_eq!(got, data, "key {key}");
    }
    assert_eq!(d.total_executed(), 40);
    cluster.shutdown().unwrap();
}
