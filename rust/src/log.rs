//! Minimal in-tree `log`-crate facade (the offline build environment has
//! no crates.io access). API-compatible with the subset of `log` 0.4 this
//! project uses — `error!`/`warn!`/`info!`/`debug!`/`trace!` macros, the
//! [`Log`] trait, [`set_logger`] / [`set_max_level`] — so swapping the
//! real crate back in is a one-line Cargo.toml change plus deleting this
//! module.
//!
//! Call sites import it explicitly (`use crate::log;`), which is also the
//! only difference from the extern-prelude crate.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Message severity, most severe first (mirrors `log::Level`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

/// Verbosity ceiling (mirrors `log::LevelFilter`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

/// Target + level of a record, checked before formatting.
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl Metadata<'_> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &str {
        self.target
    }
}

/// One log event: level, originating module path, preformatted arguments.
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// A log sink (mirrors `log::Log`).
pub trait Log: Send + Sync {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);

/// Returned when a logger was already installed.
#[derive(Debug)]
pub struct SetLoggerError;

/// Install the process-wide logger; errors if one is already set.
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError)
}

pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

pub fn max_level() -> usize {
    MAX_LEVEL.load(Ordering::Relaxed)
}

/// Macro back-end: filter, then hand the record to the installed logger.
/// With no logger installed, records are dropped (same as the real crate).
#[doc(hidden)]
pub fn __log(level: Level, target: &str, args: fmt::Arguments) {
    if (level as usize) > max_level() {
        return;
    }
    if let Some(logger) = LOGGER.get() {
        let metadata = Metadata { level, target };
        if logger.enabled(&metadata) {
            logger.log(&Record { metadata, args });
        }
    }
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::log::__log($crate::log::Level::Error, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::log::__log($crate::log::Level::Warn, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::log::__log($crate::log::Level::Info, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::log::__log($crate::log::Level::Debug, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_trace {
    ($($arg:tt)*) => {
        $crate::log::__log($crate::log::Level::Trace, module_path!(), format_args!($($arg)*))
    };
}

// Re-export the macros under the names call sites expect (`log::error!`).
pub use crate::{
    log_debug as debug, log_error as error, log_info as info, log_trace as trace,
    log_warn as warn,
};

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    static HITS: AtomicU64 = AtomicU64::new(0);

    struct CountingLogger;

    impl Log for CountingLogger {
        fn enabled(&self, _m: &Metadata) -> bool {
            true
        }

        fn log(&self, _r: &Record) {
            HITS.fetch_add(1, Ordering::SeqCst);
        }

        fn flush(&self) {}
    }

    #[test]
    fn filtered_and_delivered() {
        use crate::log;
        // No other lib test installs a logger, so this install wins; the
        // guard keeps the test meaningful if that ever changes.
        let installed = set_logger(&CountingLogger).is_ok();
        set_max_level(LevelFilter::Warn);
        let before = HITS.load(Ordering::SeqCst);
        log::error!("delivered {}", 1);
        log::warn!("delivered");
        log::debug!("filtered out");
        if installed {
            // Exactly the two records at or above the ceiling arrive.
            assert_eq!(HITS.load(Ordering::SeqCst), before + 2);
        }
        set_max_level(LevelFilter::Off);
    }
}
