//! `ucp_context` analog: per-process communication state.
//!
//! A [`Context`] binds a fabric node ("this machine + HCA") to the ifunc
//! machinery: the source-side **library directory** (`UCX_IFUNC_LIB_DIR`),
//! the target-side **symbol table** injected code links against, the
//! **auto-registration cache** (§3.4's hash table), and the I-cache model.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::fabric::{MemPerm, MemoryRegion, Node};
use crate::ifunc::cache::CodeCache;
use crate::ifunc::icache::{IcacheConfig, IcacheStats};
use crate::ifunc::library::LibraryDir;
use crate::ifunc::Symbols;
use crate::vm::interp::VmConfig;
use crate::vm::CapabilityPolicy;
use crate::Result;

use super::am::AmParams;

/// Context-wide configuration (the analog of `ucp_params_t` + env vars).
#[derive(Clone, Debug)]
pub struct ContextConfig {
    /// Active-message transport tuning.
    pub am: AmParams,
    /// Instruction-cache model (paper §4.3: the testbed's I-cache is not
    /// coherent, so every ifunc arrival pays a `clear_cache`).
    pub icache: IcacheConfig,
    /// TCVM execution limits.
    pub vm: VmConfig,
    /// Where `register_ifunc` looks for ifunc libraries — the analog of
    /// `UCX_IFUNC_LIB_DIR`. HLO-backed libraries (`<name>.hlo.txt` +
    /// `<name>.json`) are loaded from here; if unset, the env var of the
    /// same name is honored, then `./artifacts`.
    pub lib_dir: Option<PathBuf>,
    /// Which host symbols injected code may *reach* (statically, per the
    /// analysis pass). The default allows everything the symbol table
    /// exports; a restricted policy makes link-time a capability check:
    /// frames whose reachable CALL set strays outside the allowlist are
    /// rejected before compilation.
    pub caps: CapabilityPolicy,
}

impl Default for ContextConfig {
    fn default() -> Self {
        ContextConfig {
            am: AmParams::default(),
            icache: IcacheConfig::non_coherent(),
            vm: VmConfig::default(),
            lib_dir: None,
            caps: CapabilityPolicy::allow_all(),
        }
    }
}

impl ContextConfig {
    /// Resolve the ifunc library directory (explicit → env → ./artifacts).
    pub fn resolve_lib_dir(&self) -> PathBuf {
        if let Some(d) = &self.lib_dir {
            return d.clone();
        }
        if let Ok(d) = std::env::var("UCX_IFUNC_LIB_DIR") {
            return PathBuf::from(d);
        }
        PathBuf::from("artifacts")
    }
}

/// Counters for the static-analysis pass (telemetry surface). All relaxed:
/// they are monotonic tallies, never synchronization.
#[derive(Debug, Default)]
pub struct AnalysisStats {
    /// Dynamic bounds checks removed from compiled programs (summed over
    /// cache inserts — each elided op is counted once per link, not per
    /// executed instruction).
    pub elided_checks: AtomicU64,
    /// Frames rejected at link time because their reachable CALL surface
    /// strayed outside the configured [`CapabilityPolicy`].
    pub cap_denials: AtomicU64,
    /// Invocations refused by *dispatcher* admission (fuel floor above the
    /// target budget, or capability mismatch) before any fan-out.
    pub static_rejections: AtomicU64,
}

impl AnalysisStats {
    pub fn snapshot(&self) -> (u64, u64, u64) {
        (
            self.elided_checks.load(Ordering::Relaxed),
            self.cap_denials.load(Ordering::Relaxed),
            self.static_rejections.load(Ordering::Relaxed),
        )
    }
}

/// Per-process UCP state. Cheap to share (`Arc`); one per simulated
/// machine in tests and benchmarks.
pub struct Context {
    node: Arc<Node>,
    config: ContextConfig,
    libs: LibraryDir,
    symbols: Symbols,
    pub(crate) cache: CodeCache,
    icache_stats: IcacheStats,
    analysis_stats: AnalysisStats,
}

impl Context {
    pub fn new(node: Arc<Node>, config: ContextConfig) -> Result<Arc<Self>> {
        config.am.validate()?;
        let libs = LibraryDir::new(config.resolve_lib_dir());
        Ok(Arc::new(Context {
            node,
            config,
            libs,
            symbols: Symbols::with_builtins(),
            cache: CodeCache::new(),
            icache_stats: IcacheStats::default(),
            analysis_stats: AnalysisStats::default(),
        }))
    }

    /// The fabric node this context is bound to.
    pub fn node(&self) -> &Arc<Node> {
        &self.node
    }

    pub fn config(&self) -> &ContextConfig {
        &self.config
    }

    /// Source-side ifunc library directory (install/compile libraries here
    /// before calling [`Context::register_ifunc`]).
    pub fn library_dir(&self) -> &LibraryDir {
        &self.libs
    }

    /// Target-side symbol table: what injected code may link against.
    pub fn symbols(&self) -> &Symbols {
        &self.symbols
    }

    /// Auto-registration code cache (hits/misses/verified programs;
    /// Abl B toggles it).
    pub fn ifunc_cache(&self) -> &CodeCache {
        &self.cache
    }

    /// Simulated I-cache flush counters.
    pub fn icache_stats(&self) -> &IcacheStats {
        &self.icache_stats
    }

    /// Static-analysis counters (elided checks, capability denials,
    /// admission rejections).
    pub fn analysis_stats(&self) -> &AnalysisStats {
        &self.analysis_stats
    }

    /// `ucp_mem_map` analog: register a length of memory for remote access.
    /// ifunc rings require `MemPerm::RWX` (the paper's future work notes
    /// the user "would not have to worry about setting up a RWX-enabled
    /// buffer" once AM transport lands — see `ifunc::am_transport`).
    pub fn mem_map(&self, len: usize, perm: MemPerm) -> Arc<MemoryRegion> {
        self.node.register(len, perm)
    }

    /// Unmap a region; in-flight remote accesses will be rejected.
    pub fn mem_unmap(&self, mr: &MemoryRegion) {
        self.node.deregister(mr.rkey());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{Fabric, WireConfig};

    #[test]
    fn context_binds_node() {
        let f = Fabric::new(2, WireConfig::off());
        let ctx = Context::new(f.node(1), ContextConfig::default()).unwrap();
        assert_eq!(ctx.node().id(), 1);
    }

    #[test]
    fn invalid_am_params_rejected() {
        let f = Fabric::new(1, WireConfig::off());
        let cfg = ContextConfig {
            am: AmParams { num_slots: 1, ..Default::default() },
            ..Default::default()
        };
        assert!(Context::new(f.node(0), cfg).is_err());
    }

    #[test]
    fn mem_map_grants_remote_access() {
        let f = Fabric::new(2, WireConfig::off());
        let ctx = Context::new(f.node(1), ContextConfig::default()).unwrap();
        let mr = ctx.mem_map(4096, MemPerm::RWX);
        let qp = f.connect(0, 1);
        qp.put_nbi(mr.rkey(), 0, b"hi").unwrap();
        qp.flush().unwrap();
        ctx.mem_unmap(&mr);
        qp.put_nbi(mr.rkey(), 0, b"hi").unwrap();
        assert!(qp.flush().is_err());
    }
}
