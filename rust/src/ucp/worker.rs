//! `ucp_worker` analog: the progress engine.
//!
//! A [`Worker`] owns the receive side of every endpoint targeting it: AM
//! receive rings, the AM handler table (ID → handler, registered at the
//! *target* like UCX AMs — the coupling ifuncs remove), and rendezvous
//! progression. `Worker::progress()` drains arrived messages, exactly like
//! `ucp_worker_progress`.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::fabric::{MemPerm, MemoryRegion, Qp, RKey};
use crate::log;
use crate::{Error, Result};

use super::am::{
    unpack_rndv_desc, unpack_signal, AmParams, AmProto, CREDIT_CONSUMED_OFF, CREDIT_REGION_BYTES,
    CREDIT_RNDV_ACK_OFF, SIGNAL_BYTES,
};
use super::context::Context;
use super::endpoint::Endpoint;

/// An active-message handler. Receives `(am_id, payload)`.
pub type AmHandler = Arc<dyn Fn(u16, &[u8]) + Send + Sync>;

/// An active-message handler that takes the delivery buffer *mutably* —
/// the zero-copy execute-in-place path. Eager deliveries hand the ring
/// slot itself (exclusively owned between signal acquire and slot
/// release); rendezvous deliveries hand the owned fetch buffer. Either
/// way the handler runs without a per-frame copy.
pub type AmHandlerMut = Arc<dyn Fn(u16, &mut [u8]) + Send + Sync>;

/// Registered callback: shared (immutable payload view) or exclusive
/// (mutable, in-place).
#[derive(Clone)]
enum AmCallback {
    Shared(AmHandler),
    Exclusive(AmHandlerMut),
}

static WORKER_IDS: AtomicU64 = AtomicU64::new(0);

/// Receive-side state for one inbound endpoint.
struct AmRx {
    ring: Arc<MemoryRegion>,
    params: AmParams,
    /// Next expected sequence number (1-based; 0 is "slot empty").
    next_seq: u64,
    /// Messages consumed; mirrored to the sender every `credit_interval`.
    consumed: u64,
    /// QP back to the sender: credit updates, rendezvous GETs, acks.
    back_qp: Qp,
    /// The sender's credit region.
    credit_rkey: RKey,
}

pub struct Worker {
    ctx: Arc<Context>,
    id: u64,
    handlers: RwLock<HashMap<u16, AmCallback>>,
    rx: Mutex<Vec<AmRx>>,
    /// Messages processed over the worker lifetime (telemetry).
    pub am_processed: AtomicU64,
}

impl Worker {
    pub fn new(ctx: &Arc<Context>) -> Arc<Self> {
        Arc::new(Worker {
            ctx: ctx.clone(),
            id: WORKER_IDS.fetch_add(1, Ordering::Relaxed),
            handlers: RwLock::new(HashMap::new()),
            rx: Mutex::new(Vec::new()),
            am_processed: AtomicU64::new(0),
        })
    }

    pub fn id(&self) -> u64 {
        self.id
    }

    pub fn context(&self) -> &Arc<Context> {
        &self.ctx
    }

    /// Register an AM handler for `id` — `ucp_worker_set_am_recv_handler`.
    /// Note the contrast with ifuncs (§3.3): this must happen *at the
    /// target, before* any sender may use `id`.
    pub fn set_am_handler<F>(&self, id: u16, f: F)
    where
        F: Fn(u16, &[u8]) + Send + Sync + 'static,
    {
        self.handlers.write().unwrap().insert(id, AmCallback::Shared(Arc::new(f)));
    }

    /// Register a *mutable* AM handler for `id` — the zero-copy variant:
    /// eager frames execute in place in the ring slot, rendezvous frames
    /// in the owned fetch buffer. This is what the ifunc AM adapter uses
    /// so the TCVM can mutate the payload where it landed (the same
    /// in-place contract the RDMA-PUT ring path has always had).
    pub fn set_am_handler_mut<F>(&self, id: u16, f: F)
    where
        F: Fn(u16, &mut [u8]) + Send + Sync + 'static,
    {
        self.handlers.write().unwrap().insert(id, AmCallback::Exclusive(Arc::new(f)));
    }

    /// Connect this worker to `peer`, returning the endpoint. Wireup
    /// mirrors UCX: the receiver allocates the ring, the sender allocates
    /// its credit region, and rkeys are exchanged out-of-band (here: the
    /// in-process rendezvous the simulated fabric provides).
    pub fn connect(self: &Arc<Self>, peer: &Arc<Worker>) -> Result<Arc<Endpoint>> {
        // Receiver owns ring geometry.
        let params = peer.ctx.config().am;
        params.validate()?;
        let ring = peer
            .ctx
            .node()
            .register(params.slot_size * params.num_slots, MemPerm::RWX);
        // Sender-side credit region: consumed count + rndv acks.
        let credit = self.ctx.node().register(
            CREDIT_REGION_BYTES,
            MemPerm::REMOTE_WRITE | MemPerm::REMOTE_ATOMIC,
        );
        let qp = Qp::new(self.ctx.node().clone(), peer.ctx.node().clone());
        let back_qp = Qp::new(peer.ctx.node().clone(), self.ctx.node().clone());
        peer.rx.lock().unwrap().push(AmRx {
            ring: ring.clone(),
            params,
            next_seq: 1,
            consumed: 0,
            back_qp,
            credit_rkey: credit.rkey(),
        });
        Ok(Endpoint::new(self.ctx.clone(), qp, params, ring.rkey(), credit))
    }

    /// Progress all inbound endpoints; returns the number of AM messages
    /// processed. Rendezvous payloads are pulled (fragmented GETs) and
    /// acked inside this call, so senders blocked in `flush` advance.
    pub fn progress(&self) -> usize {
        let mut n = 0;
        let mut rings = self.rx.lock().unwrap();
        for rx in rings.iter_mut() {
            n += self.progress_one(rx);
        }
        n
    }

    fn progress_one(&self, rx: &mut AmRx) -> usize {
        let mut n = 0;
        loop {
            let slot = ((rx.next_seq - 1) % rx.params.num_slots as u64) as usize;
            let slot_end = (slot + 1) * rx.params.slot_size;
            let sig_off = slot_end - SIGNAL_BYTES;
            let sig = rx.ring.load_u64_acquire(sig_off).expect("ring signal aligned");
            if sig == 0 {
                break;
            }
            let Some((seq16, len, am_id, proto)) = unpack_signal(sig) else {
                log::error!("am: undecodable signal {sig:#x}; dropping ring");
                break;
            };
            if seq16 != (rx.next_seq & 0xFFFF) as u16 {
                // Flow control makes this unreachable; a mismatch means a
                // protocol bug, not a slow sender.
                log::error!("am: signal seq {seq16} != expected {}", rx.next_seq & 0xFFFF);
                break;
            }
            let data_off = sig_off - len;
            let handler = self.handlers.read().unwrap().get(&am_id).cloned();
            match proto {
                // Eager: the slot is exclusively this receiver's between
                // the signal acquire above and the release store below,
                // so an Exclusive handler executes *in place* in the ring
                // slot — no per-frame copy on the default ifunc path.
                AmProto::EagerShort | AmProto::EagerBcopy => match &handler {
                    Some(AmCallback::Shared(h)) => {
                        h(am_id, &rx.ring.local_slice()[data_off..sig_off]);
                    }
                    Some(AmCallback::Exclusive(h)) => {
                        h(am_id, &mut rx.ring.local_slice_mut()[data_off..sig_off]);
                    }
                    None => {}
                },
                AmProto::Rndv => {
                    // Pull the payload from the sender's registered
                    // buffer in `rndv_frag` pieces (UCX rndv pipeline),
                    // then ack so the sender can release it. The fetch
                    // buffer is owned, so the mutable path is free.
                    let fetched = {
                        let desc = &rx.ring.local_slice()[data_off..sig_off];
                        self.rndv_fetch(rx, desc)
                    };
                    match fetched {
                        Ok(mut buf) => {
                            match &handler {
                                Some(AmCallback::Shared(h)) => h(am_id, &buf),
                                Some(AmCallback::Exclusive(h)) => h(am_id, &mut buf),
                                None => {}
                            }
                            let _ = rx.back_qp.atomic_add_nbi(
                                rx.credit_rkey,
                                CREDIT_RNDV_ACK_OFF,
                                1,
                            );
                        }
                        Err(e) => log::error!("am rndv fetch failed: {e}"),
                    }
                }
            }
            // Release the slot and advance.
            rx.ring.store_u64_release(sig_off, 0).unwrap();
            rx.next_seq += 1;
            rx.consumed += 1;
            n += 1;
            if rx.consumed % rx.params.credit_interval == 0 {
                let _ = rx.back_qp.put_signal(rx.credit_rkey, CREDIT_CONSUMED_OFF, rx.consumed);
            }
        }
        self.am_processed.fetch_add(n as u64, Ordering::Relaxed);
        n
    }

    fn rndv_fetch(&self, rx: &AmRx, desc: &[u8]) -> Result<Vec<u8>> {
        let (rkey, total) = unpack_rndv_desc(desc)?;
        let total = total as usize;
        if total <= rx.params.rndv_frag {
            // Single-fragment fast path: hand the GET buffer through
            // without re-copying (UCX rndv lands directly in the
            // receive buffer).
            return Ok(rx.back_qp.get_blocking(rkey, 0, total)?.into_vec());
        }
        let mut buf = Vec::with_capacity(total);
        let mut off = 0;
        while off < total {
            let chunk = rx.params.rndv_frag.min(total - off);
            let part = rx.back_qp.get_blocking(rkey, off, chunk)?;
            buf.extend_from_slice(&part);
            off += chunk;
        }
        Ok(buf)
    }

    /// Spin-progress until `pred()` holds (test/bench helper).
    pub fn progress_until(&self, mut pred: impl FnMut() -> bool) {
        let mut i = 0u32;
        while !pred() {
            if self.progress() == 0 {
                crate::fabric::wire::backoff(i);
                i += 1;
            } else {
                i = 0;
            }
        }
    }

    /// Number of inbound endpoints (rings) attached.
    pub fn num_rx(&self) -> usize {
        self.rx.lock().unwrap().len()
    }
}

/// Convenience: drain `worker` until it has processed `n` more messages.
pub fn progress_n(worker: &Worker, n: usize) -> Result<()> {
    let mut got = 0;
    let mut idle_spins = 0u64;
    while got < n {
        let k = worker.progress();
        got += k;
        if k == 0 {
            idle_spins += 1;
            if idle_spins > 10_000_000_000 {
                return Err(Error::Transport("progress_n stalled".into()));
            }
            crate::fabric::wire::backoff(idle_spins.min(u32::MAX as u64) as u32);
        }
    }
    Ok(())
}
