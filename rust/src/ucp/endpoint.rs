//! `ucp_ep` analog: the sending side of a connection.
//!
//! Carries both transports the paper compares:
//! * [`Endpoint::am_send`] — active messages (eager short/bcopy or
//!   rendezvous; see [`super::am`]),
//! * raw one-sided access ([`Endpoint::put_nbi`]) — what
//!   `ucp_ifunc_msg_send_nbix` is built on (see `ifunc::send`).

use std::sync::{Arc, Mutex};

use crate::fabric::{MemPerm, MemoryRegion, Qp, RKey};
use crate::{Error, Result};

use super::am::{
    pack_rndv_desc, pack_signal, AmParams, AmProto, CREDIT_CONSUMED_OFF, CREDIT_RNDV_ACK_OFF,
    MAX_SIGNAL_LEN,
};
use super::context::Context;

struct TxState {
    /// Sequence of the next message (1-based).
    next_seq: u64,
    /// Reusable frame build buffer (bcopy staging + signal).
    frame: Vec<u8>,
    /// Extra staging buffer charged to the eager-bcopy protocol.
    staging: Vec<u8>,
    /// Rendezvous messages sent (acked via the credit region).
    rndv_sent: u64,
    /// Source buffers registered for in-flight rendezvous transfers.
    rndv_pending: Vec<RKey>,
}

pub struct Endpoint {
    ctx: Arc<Context>,
    qp: Qp,
    params: AmParams,
    ring_rkey: RKey,
    credit: Arc<MemoryRegion>,
    tx: Mutex<TxState>,
}

impl Endpoint {
    pub(crate) fn new(
        ctx: Arc<Context>,
        qp: Qp,
        params: AmParams,
        ring_rkey: RKey,
        credit: Arc<MemoryRegion>,
    ) -> Arc<Self> {
        Arc::new(Endpoint {
            ctx,
            qp,
            params,
            ring_rkey,
            credit,
            tx: Mutex::new(TxState {
                next_seq: 1,
                frame: Vec::new(),
                staging: Vec::new(),
                rndv_sent: 0,
                rndv_pending: Vec::new(),
            }),
        })
    }

    pub fn context(&self) -> &Arc<Context> {
        &self.ctx
    }

    /// The underlying queue pair (ifunc sends and tests use it directly).
    pub fn qp(&self) -> &Qp {
        &self.qp
    }

    pub fn am_params(&self) -> &AmParams {
        &self.params
    }

    /// Non-blocking one-sided put — `ucp_put_nbi`.
    pub fn put_nbi(&self, rkey: RKey, offset: usize, data: &[u8]) -> Result<()> {
        self.qp.put_nbi(rkey, offset, data)
    }

    /// `ucp_am_send_nbx` analog: send `payload` to the AM handler
    /// registered under `id` on the peer worker. Non-blocking: local
    /// completion via [`Endpoint::flush`].
    pub fn am_send(&self, id: u16, payload: &[u8]) -> Result<()> {
        let mut tx = self.tx.lock().unwrap();
        let tx = &mut *tx;
        let seq = tx.next_seq;
        let proto = self.params.select(payload.len());
        match proto {
            AmProto::EagerShort => {
                let frame = Self::build_frame(&mut tx.frame, payload, seq, id, proto);
                self.post_slot(seq, frame)?;
            }
            AmProto::EagerBcopy => {
                // The extra internal-buffer copy that defines bcopy.
                tx.staging.clear();
                tx.staging.extend_from_slice(payload);
                let frame = Self::build_frame(&mut tx.frame, &tx.staging, seq, id, proto);
                self.post_slot(seq, frame)?;
            }
            AmProto::Rndv => {
                // Register (and fill) a source buffer the receiver will GET
                // from, then ship only the RTS descriptor eagerly.
                let mr = self.ctx.node().register(payload.len(), MemPerm::REMOTE_READ);
                mr.local_slice_mut()[..payload.len()].copy_from_slice(payload);
                let desc = pack_rndv_desc(mr.rkey(), payload.len() as u64);
                let frame = Self::build_frame(&mut tx.frame, &desc, seq, id, proto);
                self.post_slot(seq, frame)?;
                tx.rndv_sent += 1;
                tx.rndv_pending.push(mr.rkey());
            }
        }
        tx.next_seq += 1;
        Ok(())
    }

    /// Build the right-aligned slot frame: `[payload][signal]`.
    fn build_frame<'a>(
        frame: &'a mut Vec<u8>,
        payload: &[u8],
        seq: u64,
        id: u16,
        proto: AmProto,
    ) -> &'a [u8] {
        assert!(payload.len() <= MAX_SIGNAL_LEN, "AM payload too large for signal encoding");
        frame.clear();
        frame.extend_from_slice(payload);
        frame.extend_from_slice(&pack_signal(seq, payload.len(), id, proto).to_le_bytes());
        frame
    }

    /// Flow-control, then put the frame so it ends exactly at the slot
    /// boundary (the trailing 8 bytes become the release-stored signal).
    fn post_slot(&self, seq: u64, frame: &[u8]) -> Result<()> {
        if frame.len() > self.params.slot_size {
            return Err(Error::NoResource(format!(
                "AM frame of {} bytes exceeds slot size {}",
                frame.len(),
                self.params.slot_size
            )));
        }
        // Wait for ring credit: the receiver's consumed count is pushed
        // into our credit region.
        let mut i = 0u32;
        while seq - self.consumed() > self.params.num_slots as u64 {
            crate::fabric::wire::backoff(i);
            i += 1;
        }
        let slot = ((seq - 1) % self.params.num_slots as u64) as usize;
        let offset = (slot + 1) * self.params.slot_size - frame.len();
        self.qp.put_nbi(self.ring_rkey, offset, frame)
    }

    fn consumed(&self) -> u64 {
        self.credit.load_u64_acquire(CREDIT_CONSUMED_OFF).unwrap()
    }

    fn rndv_acked(&self) -> u64 {
        self.credit.load_u64_acquire(CREDIT_RNDV_ACK_OFF).unwrap()
    }

    /// `ucp_ep_flush`: wait until every posted operation is remotely
    /// complete *and* every rendezvous source buffer has been pulled and
    /// acked (then release those buffers).
    pub fn flush(&self) -> Result<()> {
        self.qp.flush()?;
        let mut tx = self.tx.lock().unwrap();
        let mut i = 0u32;
        while self.rndv_acked() < tx.rndv_sent {
            crate::fabric::wire::backoff(i);
            i += 1;
        }
        for rkey in tx.rndv_pending.drain(..) {
            self.ctx.node().deregister(rkey);
        }
        Ok(())
    }

    /// Messages sent so far (telemetry).
    pub fn sent(&self) -> u64 {
        self.tx.lock().unwrap().next_seq - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{Fabric, WireConfig};
    use crate::ucp::{Context, ContextConfig, Worker};
    use std::sync::atomic::{AtomicU64, Ordering};

    fn pair() -> (Arc<Worker>, Arc<Worker>, Arc<Endpoint>) {
        let f = Fabric::new(2, WireConfig::off());
        let a = Context::new(f.node(0), ContextConfig::default()).unwrap();
        let b = Context::new(f.node(1), ContextConfig::default()).unwrap();
        let wa = Worker::new(&a);
        let wb = Worker::new(&b);
        let ep = wa.connect(&wb).unwrap();
        (wa, wb, ep)
    }

    #[test]
    fn eager_short_delivery() {
        let (_wa, wb, ep) = pair();
        let hits = Arc::new(AtomicU64::new(0));
        let h = hits.clone();
        wb.set_am_handler(7, move |id, data| {
            assert_eq!(id, 7);
            assert_eq!(data, b"ping");
            h.fetch_add(1, Ordering::SeqCst);
        });
        ep.am_send(7, b"ping").unwrap();
        ep.flush().unwrap();
        wb.progress_until(|| hits.load(Ordering::SeqCst) == 1);
    }

    #[test]
    fn bcopy_and_rndv_delivery() {
        let (_wa, wb, ep) = pair();
        let total = Arc::new(AtomicU64::new(0));
        let t = total.clone();
        wb.set_am_handler(1, move |_, data| {
            t.fetch_add(data.len() as u64, Ordering::SeqCst);
        });
        let bcopy = vec![0xAB; 1024]; // > short_max, <= rndv_threshold
        let rndv = vec![0xCD; 128 * 1024]; // > rndv_threshold
        ep.am_send(1, &bcopy).unwrap();
        ep.am_send(1, &rndv).unwrap();
        // Rendezvous completes only when the receiver progresses.
        let wb2 = wb.clone();
        let t2 = std::thread::spawn(move || {
            wb2.progress_until(|| wb2.am_processed.load(Ordering::SeqCst) >= 2);
        });
        ep.flush().unwrap();
        t2.join().unwrap();
        assert_eq!(total.load(Ordering::SeqCst), 1024 + 128 * 1024);
    }

    #[test]
    fn rndv_content_integrity() {
        let (_wa, wb, ep) = pair();
        let ok = Arc::new(AtomicU64::new(0));
        let k = ok.clone();
        let payload: Vec<u8> = (0..100_000u32).map(|i| (i * 7) as u8).collect();
        let expect = payload.clone();
        wb.set_am_handler(2, move |_, data| {
            assert_eq!(data, &expect[..]);
            k.store(1, Ordering::SeqCst);
        });
        ep.am_send(2, &payload).unwrap();
        let wb2 = wb.clone();
        let t = std::thread::spawn(move || {
            wb2.progress_until(|| wb2.am_processed.load(Ordering::SeqCst) >= 1)
        });
        ep.flush().unwrap();
        t.join().unwrap();
        assert_eq!(ok.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn ring_wraps_with_flow_control() {
        let (_wa, wb, ep) = pair();
        let hits = Arc::new(AtomicU64::new(0));
        let h = hits.clone();
        wb.set_am_handler(3, move |_, _| {
            h.fetch_add(1, Ordering::SeqCst);
        });
        let n = 500u64; // ~8x the default ring
        let wb2 = wb.clone();
        let h2 = hits.clone();
        let t = std::thread::spawn(move || {
            wb2.progress_until(|| h2.load(Ordering::SeqCst) == n);
        });
        for i in 0..n {
            ep.am_send(3, &i.to_le_bytes()).unwrap();
        }
        ep.flush().unwrap();
        t.join().unwrap();
        assert_eq!(hits.load(Ordering::SeqCst), n);
    }

    #[test]
    fn unregistered_handler_drops_message() {
        let (_wa, wb, ep) = pair();
        ep.am_send(99, b"nobody home").unwrap();
        ep.flush().unwrap();
        // Progress consumes the message without a handler; no panic.
        wb.progress_until(|| wb.am_processed.load(Ordering::SeqCst) >= 1);
    }

    #[test]
    fn pingpong_two_directions() {
        let f = Fabric::new(2, WireConfig::off());
        let a = Context::new(f.node(0), ContextConfig::default()).unwrap();
        let b = Context::new(f.node(1), ContextConfig::default()).unwrap();
        let wa = Worker::new(&a);
        let wb = Worker::new(&b);
        let ab = wa.connect(&wb).unwrap();
        let ba = wb.connect(&wa).unwrap();
        let pongs = Arc::new(AtomicU64::new(0));

        let ba2 = ba.clone();
        wb.set_am_handler(1, move |_, data| {
            ba2.am_send(2, data).unwrap();
        });
        let p = pongs.clone();
        wa.set_am_handler(2, move |_, _| {
            p.fetch_add(1, Ordering::SeqCst);
        });

        for _ in 0..32 {
            ab.am_send(1, b"ball").unwrap();
            loop {
                wb.progress();
                if wa.progress() > 0 {
                    break;
                }
            }
        }
        assert_eq!(pongs.load(Ordering::SeqCst), 32);
        ab.flush().unwrap();
        ba.flush().unwrap();
    }
}
