//! UCP-like communication layer over the simulated fabric.
//!
//! The subset of UCX the paper's API is expressed in: contexts, workers,
//! endpoints, mapped memory with packable rkeys, non-blocking one-sided
//! puts with flush, and Active Messages (the evaluation baseline, §3.3).

pub mod am;
pub mod context;
pub mod endpoint;
pub mod worker;

pub use am::{AmParams, AmProto};
pub use context::{AnalysisStats, Context, ContextConfig};
pub use endpoint::Endpoint;
pub use worker::{progress_n, AmHandler, Worker};
