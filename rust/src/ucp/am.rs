//! UCX Active Messages — the baseline the paper compares ifuncs against
//! (§3.3, §4).
//!
//! Semantics modeled after `ucp_am_send_nbx` / `ucp_worker_set_am_recv_handler`:
//! handlers are registered **by numeric ID at the target, at startup** —
//! precisely the compile-time coupling the ifunc API removes — and the
//! transport picks one of three protocols by payload size:
//!
//! * **eager-short** — payload rides inline in a single one-sided write,
//! * **eager-bcopy** — payload is staged through an internal bounce buffer
//!   (one extra copy) before the write,
//! * **rendezvous** — an RTS descriptor is written; the receiver pulls the
//!   payload with (possibly fragmented) one-sided GETs from the sender's
//!   registered buffer and acks so the sender can release it.
//!
//! The protocol switch points produce the characteristic *stepping* of the
//! AM curves in the paper's Fig. 4 ("These steps are likely due to the
//! change is underlying protocol for moving the active messages") and are
//! configurable via [`AmParams`] — ablation Abl C sweeps them.
//!
//! ## Ring wire format
//!
//! Receive rings are slot-arrays. A message is a single put that
//! *right-aligns* inside its slot so the last 8 bytes — delivered with
//! release ordering by the fabric — are the **signal word**:
//!
//! ```text
//!  | ... empty ... | payload (len bytes) | signal u64 |   <- one slot
//!                                        ^ slot end
//!  signal = seq(16) | len(24) | am_id(16) | proto(8)     (nonzero: seq >= 1)
//! ```
//!
//! The receiver spins on the signal word of the next expected slot
//! (`wait_mem`), consumes, zeroes the signal, and periodically writes its
//! consumed count back into the sender's credit region (flow control).

use crate::{Error, Result};

/// AM protocol selector carried in the signal word.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum AmProto {
    EagerShort = 1,
    EagerBcopy = 2,
    Rndv = 3,
}

impl AmProto {
    pub fn from_u8(v: u8) -> Option<Self> {
        Some(match v {
            1 => AmProto::EagerShort,
            2 => AmProto::EagerBcopy,
            3 => AmProto::Rndv,
            _ => return None,
        })
    }
}

/// Transport tuning — the knobs behind the AM curve's steps.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AmParams {
    /// Bytes per receive-ring slot (incl. the 8-byte signal word).
    pub slot_size: usize,
    /// Slots per receive ring.
    pub num_slots: usize,
    /// Largest payload sent eager-short (inline, no staging copy).
    /// Default 1 KiB: the paper's AM message-rate curve steps sharply as
    /// payload goes 1 KB → 2 KB (§4.3) — the short→bcopy switch.
    pub short_max: usize,
    /// Largest payload sent eager at all; above this, rendezvous.
    /// UCX's `UCX_RNDV_THRESH`; default 8 KiB (IB-class UCX default),
    /// which puts the latency crossover in the paper's 8–16 KB band.
    pub rndv_threshold: usize,
    /// Fragment size for rendezvous GETs (UCX rndv pipelining). Each
    /// fragment pays per-message wire overhead.
    pub rndv_frag: usize,
    /// Receiver writes its consumed count back every N messages.
    pub credit_interval: u64,
}

impl Default for AmParams {
    fn default() -> Self {
        AmParams {
            slot_size: 16384,
            num_slots: 64,
            short_max: 1024,
            rndv_threshold: 8192,
            rndv_frag: 64 * 1024,
            credit_interval: 16,
        }
    }
}

impl AmParams {
    /// Eager capacity of a slot: everything but the signal word.
    pub fn eager_capacity(&self) -> usize {
        self.slot_size - SIGNAL_BYTES
    }

    /// Protocol selection for a payload of `len` bytes.
    pub fn select(&self, len: usize) -> AmProto {
        if len <= self.short_max {
            AmProto::EagerShort
        } else if len <= self.rndv_threshold && len <= self.eager_capacity() {
            AmProto::EagerBcopy
        } else {
            AmProto::Rndv
        }
    }

    pub fn validate(&self) -> Result<()> {
        if self.slot_size < 64 || !self.slot_size.is_power_of_two() {
            return Err(Error::Other("slot_size must be a power of two >= 64".into()));
        }
        if self.num_slots < 2 {
            return Err(Error::Other("num_slots must be >= 2".into()));
        }
        if self.credit_interval == 0 || self.credit_interval >= self.num_slots as u64 {
            return Err(Error::Other(
                "credit_interval must be in [1, num_slots) to avoid flow-control deadlock".into(),
            ));
        }
        if self.rndv_frag == 0 {
            return Err(Error::Other("rndv_frag must be nonzero".into()));
        }
        // RTS descriptor must fit eager path.
        if RNDV_DESC_BYTES > self.eager_capacity() {
            return Err(Error::Other("slot too small for rendezvous descriptor".into()));
        }
        Ok(())
    }
}

pub const SIGNAL_BYTES: usize = 8;

/// Max payload length encodable in the signal word (24 bits).
pub const MAX_SIGNAL_LEN: usize = (1 << 24) - 1;

/// Pack the signal word. `seq` is truncated to 16 bits; with `num_slots`
/// ≪ 2^16 a stale slot can never alias the expected sequence number.
pub fn pack_signal(seq: u64, len: usize, am_id: u16, proto: AmProto) -> u64 {
    debug_assert!(len <= MAX_SIGNAL_LEN);
    ((seq & 0xFFFF) << 48) | ((len as u64 & 0xFF_FFFF) << 24) | ((am_id as u64) << 8) | proto as u64
}

/// Unpack `(seq16, len, am_id, proto)`.
pub fn unpack_signal(sig: u64) -> Option<(u16, usize, u16, AmProto)> {
    let proto = AmProto::from_u8((sig & 0xFF) as u8)?;
    let am_id = ((sig >> 8) & 0xFFFF) as u16;
    let len = ((sig >> 24) & 0xFF_FFFF) as usize;
    let seq = ((sig >> 48) & 0xFFFF) as u16;
    Some((seq, len, am_id, proto))
}

/// Rendezvous RTS descriptor, shipped as the eager "payload" of an
/// `AmProto::Rndv` message: the sender-side registered buffer to GET from.
pub const RNDV_DESC_BYTES: usize = 4 + 8;

pub fn pack_rndv_desc(rkey: u32, len: u64) -> [u8; RNDV_DESC_BYTES] {
    let mut out = [0u8; RNDV_DESC_BYTES];
    out[..4].copy_from_slice(&rkey.to_le_bytes());
    out[4..12].copy_from_slice(&len.to_le_bytes());
    out
}

pub fn unpack_rndv_desc(data: &[u8]) -> Result<(u32, u64)> {
    if data.len() < RNDV_DESC_BYTES {
        return Err(Error::Transport("short rendezvous descriptor".into()));
    }
    let rkey = u32::from_le_bytes(data[..4].try_into().unwrap());
    let len = u64::from_le_bytes(data[4..12].try_into().unwrap());
    Ok((rkey, len))
}

/// Offsets of the two flow-control words in an endpoint's credit region.
pub const CREDIT_CONSUMED_OFF: usize = 0;
pub const CREDIT_RNDV_ACK_OFF: usize = 8;
pub const CREDIT_REGION_BYTES: usize = 16;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn signal_roundtrip() {
        let sig = pack_signal(7, 1234, 42, AmProto::EagerBcopy);
        assert_eq!(unpack_signal(sig), Some((7, 1234, 42, AmProto::EagerBcopy)));
    }

    #[test]
    fn signal_is_nonzero_for_seq_ge_1() {
        // A zero signal means "slot empty"; any valid message must differ.
        let sig = pack_signal(1, 0, 0, AmProto::EagerShort);
        assert_ne!(sig, 0);
    }

    #[test]
    fn protocol_selection_thresholds() {
        let p = AmParams::default();
        assert_eq!(p.select(1), AmProto::EagerShort);
        assert_eq!(p.select(1024), AmProto::EagerShort);
        assert_eq!(p.select(1025), AmProto::EagerBcopy);
        assert_eq!(p.select(8192), AmProto::EagerBcopy);
        assert_eq!(p.select(8193), AmProto::Rndv);
        assert_eq!(p.select(1 << 20), AmProto::Rndv);
    }

    #[test]
    fn rndv_desc_roundtrip() {
        let d = pack_rndv_desc(0xABCD_1234, 1 << 20);
        assert_eq!(unpack_rndv_desc(&d).unwrap(), (0xABCD_1234, 1 << 20));
    }

    #[test]
    fn params_validation() {
        assert!(AmParams::default().validate().is_ok());
        assert!(AmParams { slot_size: 100, ..Default::default() }.validate().is_err());
        assert!(AmParams { credit_interval: 64, ..Default::default() }.validate().is_err());
        assert!(AmParams { num_slots: 1, ..Default::default() }.validate().is_err());
    }

    #[test]
    fn seq_wraps_at_16_bits_without_alias() {
        let p = AmParams::default();
        // Two messages num_slots apart must have different 16-bit seqs.
        let a = pack_signal(1, 0, 0, AmProto::EagerShort);
        let b = pack_signal(1 + p.num_slots as u64, 0, 0, AmProto::EagerShort);
        assert_ne!(a, b);
    }
}
