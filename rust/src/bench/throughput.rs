//! Fig. 4 — message throughput, ifunc vs UCX AM.
//!
//! ifunc protocol (§4.1): "a ring buffer is allocated using the
//! `ucp_mem_map` routine ... The source process fills the buffer with
//! ifunc messages of a certain size, flushes the UCP endpoint used to send
//! the messages, then waits on the target process's notification
//! indicating that it has finished consuming all the messages before
//! continuing to send the next round of messages."
//!
//! AM protocol: "the source process simply sends all the messages in a
//! loop and flushes the endpoint at the end."
//!
//! Reported metric: messages per second.

use std::sync::atomic::Ordering;
use std::time::Instant;

use crate::ifunc::{IfuncRing, SenderCursor, SourceArgs, TargetArgs};
use crate::Result;

use super::harness::BenchPair;

/// ifunc message rate (msgs/sec) for `payload`-byte messages.
pub fn ifunc_throughput(pair: &BenchPair, payload: usize, total_msgs: usize) -> Result<f64> {
    let ring = IfuncRing::new(&pair.dst, pair.config.ring_bytes)?;
    let rkey = ring.rkey();
    let ring_size = ring.size();

    let h = pair.src.register_ifunc("counter")?;
    let msg = h.msg_create(&SourceArgs::bytes(vec![0x77; payload]))?;
    let frame_len = msg.len();
    // Messages per round: fill the ring, leaving one frame of slack so a
    // wrap marker plus the wasted tail can never overlap an unconsumed
    // frame from the same round.
    let per_round = (((ring_size - 8) / frame_len).saturating_sub(1)).max(1).min(total_msgs);
    let rounds = total_msgs.div_ceil(per_round);
    let total = rounds * per_round;

    // Target consumes `per_round` messages then writes the round number
    // into the source's notification word.
    let dst = pair.dst.clone();
    let ep_back = pair.ep_back.clone();
    let notify_rkey = pair.notify.rkey();
    let mut ring = ring;
    let b = std::thread::spawn(move || -> Result<()> {
        let mut args = TargetArgs::none();
        for round in 0..rounds {
            for _ in 0..per_round {
                dst.poll_ifunc_blocking(&mut ring, &mut args)?;
            }
            ep_back.qp().put_signal(notify_rkey, 0, round as u64 + 1)?;
        }
        ep_back.flush()?;
        Ok(())
    });

    let t0 = Instant::now();
    let mut cursor = SenderCursor::new(ring_size);
    for round in 0..rounds {
        for _ in 0..per_round {
            pair.ep.ifunc_msg_send_cursor(&msg, &mut cursor, rkey)?;
        }
        pair.ep.flush()?;
        // Wait for the target's "all consumed" notification. "This leads
        // to some overhead but is not significant when the number of
        // messages is large." (§4.1)
        let mut i = 0u32;
        while pair.notify.load_u64_acquire(0)? < round as u64 + 1 {
            crate::fabric::wire::backoff(i);
            i += 1;
        }
    }
    let dt = t0.elapsed();
    b.join().expect("ifunc throughput target")?;
    pair.notify.store_u64_release(0, 0)?;
    Ok(total as f64 / dt.as_secs_f64())
}

/// AM message rate (msgs/sec) for `payload`-byte messages.
pub fn am_throughput(pair: &BenchPair, payload: usize, total_msgs: usize) -> Result<f64> {
    const ID: u16 = 21;
    let before = pair.w_dst.am_processed.load(Ordering::Relaxed);
    // Counter handler, like the ifunc side's injected counter.
    pair.w_dst.set_am_handler(ID, |_, _| {});

    let w_dst = pair.w_dst.clone();
    let expect = before + total_msgs as u64;
    let b = std::thread::spawn(move || {
        w_dst.progress_until(|| w_dst.am_processed.load(Ordering::Relaxed) >= expect);
    });

    let data = vec![0x55u8; payload];
    let t0 = Instant::now();
    for _ in 0..total_msgs {
        pair.ep.am_send(ID, &data)?;
    }
    pair.ep.flush()?;
    b.join().expect("am throughput target");
    let dt = t0.elapsed();
    Ok(total_msgs as f64 / dt.as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::harness::BenchConfig;

    #[test]
    fn ifunc_throughput_counts_every_message() {
        let pair = BenchPair::new(BenchConfig::quick()).unwrap();
        let before = pair.dst.symbols().counter_value();
        let rate = ifunc_throughput(&pair, 128, 100).unwrap();
        assert!(rate > 0.0);
        assert!(pair.dst.symbols().counter_value() >= before + 100);
    }

    #[test]
    fn am_throughput_runs() {
        let pair = BenchPair::new(BenchConfig::quick()).unwrap();
        for size in [1usize, 4096] {
            assert!(am_throughput(&pair, size, 64).unwrap() > 0.0);
        }
    }
}
