//! Fig. 3 — ping-pong one-way latency, ifunc vs UCX AM.
//!
//! "The ping-pong benchmark is implemented using the classical approach:
//! each process sends a message, flushes the endpoint and waits for the
//! other process to reply before continuing this process." (§4.1)
//!
//! In a ping-pong only one side is ever active, so both "processes" run
//! on one thread here — on the single-core bench box this removes
//! scheduler noise entirely; the measured time is the software path plus
//! the modeled wire/I-cache costs. One-way latency = round-trip / 2.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::ifunc::{IfuncRing, SenderCursor, SourceArgs, TargetArgs};
use crate::Result;

use super::harness::BenchPair;

/// Median of the round-trip samples — robust against single-core
/// scheduler outliers that a mean would smear across the series.
fn median(samples: &mut [f64]) -> f64 {
    samples.sort_by(f64::total_cmp);
    samples[samples.len() / 2]
}

/// One-way ifunc latency for `payload` bytes, in nanoseconds.
pub fn ifunc_pingpong(pair: &BenchPair, payload: usize, iters: usize) -> Result<f64> {
    let warmup = (iters / 10).max(2);
    let mut ring_a = IfuncRing::new(&pair.src, pair.config.ring_bytes)?;
    let mut ring_b = IfuncRing::new(&pair.dst, pair.config.ring_bytes)?;

    let h_a = pair.src.register_ifunc("counter")?;
    let h_b = pair.dst.register_ifunc("counter")?;
    let msg_a = h_a.msg_create(&SourceArgs::bytes(vec![0x5A; payload]))?;
    let msg_b = h_b.msg_create(&SourceArgs::bytes(vec![0xA5; payload]))?;

    let mut cursor_b = SenderCursor::new(ring_b.size()); // A writes into B
    let mut cursor_a = SenderCursor::new(ring_a.size()); // B writes into A
    let mut args_a = TargetArgs::none();
    let mut args_b = TargetArgs::none();

    let mut samples = Vec::with_capacity(iters);
    for i in 0..(warmup + iters) {
        let t0 = Instant::now();
        // A: ping.
        pair.ep.ifunc_msg_send_cursor(&msg_a, &mut cursor_b, ring_b.rkey())?;
        pair.ep.flush()?;
        // B: receive + execute, then pong.
        pair.dst.poll_ifunc_blocking(&mut ring_b, &mut args_b)?;
        pair.ep_back.ifunc_msg_send_cursor(&msg_b, &mut cursor_a, ring_a.rkey())?;
        pair.ep_back.flush()?;
        // A: receive + execute.
        pair.src.poll_ifunc_blocking(&mut ring_a, &mut args_a)?;
        if i >= warmup {
            samples.push(t0.elapsed().as_nanos() as f64);
        }
    }
    Ok(median(&mut samples) / 2.0)
}

/// One-way AM latency for `payload` bytes, in nanoseconds.
pub fn am_pingpong(pair: &BenchPair, payload: usize, iters: usize) -> Result<f64> {
    let warmup = (iters / 10).max(2);
    const PING: u16 = 11;
    const PONG: u16 = 12;

    // B echoes every ping (handler registered at the target — the AM
    // coupling the paper contrasts with).
    let ep_back = pair.ep_back.clone();
    pair.w_dst.set_am_handler(PING, move |_, data| {
        ep_back.am_send(PONG, data).expect("pong send");
    });
    let pongs = Arc::new(AtomicU64::new(0));
    let p = pongs.clone();
    pair.w_src.set_am_handler(PONG, move |_, _| {
        p.fetch_add(1, Ordering::Relaxed);
    });

    let ball = vec![0x42u8; payload];
    let mut samples = Vec::with_capacity(iters);
    for i in 0..(warmup + iters) {
        let t0 = Instant::now();
        let before = pongs.load(Ordering::Relaxed);
        pair.ep.am_send(PING, &ball)?;
        // B progresses (executes the echo handler), then A collects the
        // pong; loop covers the engine-mode case where delivery lags.
        while pongs.load(Ordering::Relaxed) == before {
            pair.w_dst.progress();
            pair.w_src.progress();
        }
        if i >= warmup {
            samples.push(t0.elapsed().as_nanos() as f64);
        }
    }
    pair.ep.flush()?;
    pair.ep_back.flush()?;
    Ok(median(&mut samples) / 2.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::harness::BenchConfig;

    #[test]
    fn ifunc_pingpong_runs() {
        let pair = BenchPair::new(BenchConfig::quick()).unwrap();
        let ns = ifunc_pingpong(&pair, 64, 10).unwrap();
        assert!(ns > 0.0);
        // Both sides executed ifuncs.
        assert!(pair.src.symbols().counter_value() > 0);
        assert!(pair.dst.symbols().counter_value() > 0);
    }

    #[test]
    fn am_pingpong_runs_all_protocols() {
        let pair = BenchPair::new(BenchConfig::quick()).unwrap();
        for size in [1usize, 1024, 65536] {
            let ns = am_pingpong(&pair, size, 8).unwrap();
            assert!(ns > 0.0, "size {size}");
        }
    }
}
