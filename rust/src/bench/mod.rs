//! Benchmark harness regenerating the paper's evaluation (§4).

pub mod harness;
pub mod latency;
pub mod report;
pub mod throughput;

pub use harness::{BenchConfig, BenchMode, BenchPair};
pub use report::{micro_json, print_series, Crossover, MicroRow, SeriesPoint};
