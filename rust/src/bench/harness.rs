//! Shared benchmark scaffolding: the simulated two-server testbed.
//!
//! `BenchPair` stands in for the paper's §4.2 platform: two machines
//! (fabric nodes) connected back-to-back, each with a context + worker,
//! endpoints in both directions, and — for the ifunc transport — an
//! RWX ring on each side with the counter ifunc installed.

use std::sync::Arc;

use crate::fabric::{Fabric, MemPerm, MemoryRegion, WireConfig};
use crate::ifunc::builtin::CounterIfunc;
use crate::ifunc::icache::IcacheConfig;
use crate::ucp::{AmParams, Context, ContextConfig, Endpoint, Worker};
use crate::Result;

/// Which transport a run exercises.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BenchMode {
    /// Injected functions over one-sided puts (the paper's contribution).
    Ifunc,
    /// UCX-style active messages (the baseline).
    Am,
    /// ifuncs over the AM transport (§5.1 future work, ablation).
    IfuncAm,
}

/// Bench-wide configuration (the knobs the ablations sweep).
#[derive(Debug, Clone)]
pub struct BenchConfig {
    pub wire: WireConfig,
    pub am: AmParams,
    pub icache: IcacheConfig,
    /// Auto-registration cache on (paper) or off (Abl B).
    pub cache_enabled: bool,
    /// Extra padding instructions in the counter ifunc's code section.
    pub code_pad: usize,
    /// ifunc ring bytes per direction.
    pub ring_bytes: usize,
    /// Payload sizes to sweep (bytes).
    pub sizes: Vec<usize>,
    /// Ping-pong iterations per size (plus warmup).
    pub pingpong_iters: usize,
    /// Messages per throughput measurement at each size.
    pub msgs_per_size: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            wire: WireConfig::connectx6(),
            am: AmParams::default(),
            icache: IcacheConfig::non_coherent(),
            cache_enabled: true,
            code_pad: 0,
            ring_bytes: 8 << 20,
            // The paper sweeps 1 B .. 1 MB in powers of two.
            sizes: (0..=20).map(|p| 1usize << p).collect(),
            pingpong_iters: 200,
            msgs_per_size: 1000,
        }
    }
}

impl BenchConfig {
    /// A fast configuration for CI / tests (no wire model, short sweeps).
    pub fn quick() -> Self {
        BenchConfig {
            wire: WireConfig::off(),
            sizes: vec![1, 1024, 65536],
            pingpong_iters: 20,
            msgs_per_size: 50,
            ..Default::default()
        }
    }

    fn context_config(&self) -> ContextConfig {
        ContextConfig { am: self.am, icache: self.icache, ..Default::default() }
    }
}

/// The two-server testbed.
pub struct BenchPair {
    pub fabric: Arc<Fabric>,
    pub src: Arc<Context>,
    pub dst: Arc<Context>,
    pub w_src: Arc<Worker>,
    pub w_dst: Arc<Worker>,
    /// src → dst endpoint.
    pub ep: Arc<Endpoint>,
    /// dst → src endpoint (pong direction, notifications).
    pub ep_back: Arc<Endpoint>,
    /// Source-side notification word the target writes round completions to.
    pub notify: Arc<MemoryRegion>,
    pub config: BenchConfig,
}

impl BenchPair {
    pub fn new(config: BenchConfig) -> Result<Self> {
        let fabric = Fabric::new(2, config.wire);
        let src = Context::new(fabric.node(0), config.context_config())?;
        let dst = Context::new(fabric.node(1), config.context_config())?;
        src.ifunc_cache().set_enabled(config.cache_enabled);
        dst.ifunc_cache().set_enabled(config.cache_enabled);
        // Both sides can send the counter ifunc (ping-pong needs both).
        src.library_dir().install(Box::new(CounterIfunc::with_code_padding(config.code_pad)));
        dst.library_dir().install(Box::new(CounterIfunc::with_code_padding(config.code_pad)));
        let w_src = Worker::new(&src);
        let w_dst = Worker::new(&dst);
        let ep = w_src.connect(&w_dst)?;
        let ep_back = w_dst.connect(&w_src)?;
        let notify = src.mem_map(64, MemPerm::RWX);
        Ok(BenchPair { fabric, src, dst, w_src, w_dst, ep, ep_back, notify, config })
    }
}
