//! Paper-style series reporting: the rows behind Fig. 3 / Fig. 4.

/// One swept payload size: the two transports' measurements.
#[derive(Debug, Clone, Copy)]
pub struct SeriesPoint {
    pub size: usize,
    /// ifunc measurement (ns for latency, msg/s for throughput).
    pub ifunc: f64,
    /// UCX AM measurement.
    pub am: f64,
}

impl SeriesPoint {
    /// ifunc improvement relative to AM, in percent. For latency
    /// (lower=better) pass `lower_is_better = true`: +35 means "35%
    /// latency reduction" as the paper phrases it.
    pub fn ifunc_gain_pct(&self, lower_is_better: bool) -> f64 {
        if lower_is_better {
            (self.am - self.ifunc) / self.am * 100.0
        } else {
            (self.ifunc - self.am) / self.am * 100.0
        }
    }
}

/// Where the ifunc series overtakes the AM series.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Crossover {
    /// Last size where AM still wins.
    pub below: usize,
    /// First size where ifunc wins.
    pub at: usize,
}

/// Find the first crossover (ifunc starts winning) in a sweep.
pub fn find_crossover(series: &[SeriesPoint], lower_is_better: bool) -> Option<Crossover> {
    let wins = |p: &SeriesPoint| if lower_is_better { p.ifunc < p.am } else { p.ifunc > p.am };
    for w in series.windows(2) {
        if !wins(&w[0]) && wins(&w[1]) {
            return Some(Crossover { below: w[0].size, at: w[1].size });
        }
    }
    None
}

fn human_size(bytes: usize) -> String {
    if bytes >= 1 << 20 {
        format!("{}MB", bytes >> 20)
    } else if bytes >= 1 << 10 {
        format!("{}KB", bytes >> 10)
    } else {
        format!("{bytes}B")
    }
}

/// Print a Fig.3/Fig.4-style table: payload, ifunc, AM, ifunc-vs-AM %.
pub fn print_series(title: &str, unit: &str, series: &[SeriesPoint], lower_is_better: bool) {
    println!("\n=== {title} ===");
    println!(
        "{:>8}  {:>14}  {:>14}  {:>12}",
        "payload",
        format!("ifunc ({unit})"),
        format!("UCX AM ({unit})"),
        "ifunc vs AM"
    );
    for p in series {
        println!(
            "{:>8}  {:>14.1}  {:>14.1}  {:>+11.1}%",
            human_size(p.size),
            p.ifunc,
            p.am,
            p.ifunc_gain_pct(lower_is_better)
        );
    }
    match find_crossover(series, lower_is_better) {
        Some(c) => println!(
            "--> crossover: ifunc overtakes AM between {} and {}",
            human_size(c.below),
            human_size(c.at)
        ),
        None => println!("--> no crossover in the swept range"),
    }
}

/// One microbenchmark measurement (`benches/micro.rs`).
#[derive(Debug, Clone, PartialEq)]
pub struct MicroRow {
    pub name: String,
    pub median_ns: f64,
    pub best_ns: f64,
}

/// Render the micro rows as the JSON report CI uploads as an artifact, so
/// successive runs give a perf trajectory for the hot-path stages.
pub fn micro_json(rows: &[MicroRow]) -> String {
    let body: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "{{\"name\":{},\"median_ns\":{:.1},\"best_ns\":{:.1}}}",
                json_str(&r.name),
                r.median_ns,
                r.best_ns
            )
        })
        .collect();
    format!("{{\"series\":\"micro\",\"rows\":[{}]}}", body.join(","))
}

/// Escape an arbitrary label as a JSON string (the report rows are caller
/// supplied, so quotes/backslashes in a name must not corrupt the report).
fn json_str(s: &str) -> String {
    crate::util::Json::Str(s.to_string()).to_string()
}

/// Render a series as a machine-readable JSON line (EXPERIMENTS.md data).
pub fn series_json(name: &str, series: &[SeriesPoint]) -> String {
    let rows: Vec<String> = series
        .iter()
        .map(|p| format!("{{\"size\":{},\"ifunc\":{:.2},\"am\":{:.2}}}", p.size, p.ifunc, p.am))
        .collect();
    format!("{{\"series\":{},\"points\":[{}]}}", json_str(name), rows.join(","))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(points: &[(usize, f64, f64)]) -> Vec<SeriesPoint> {
        points.iter().map(|&(size, ifunc, am)| SeriesPoint { size, ifunc, am }).collect()
    }

    #[test]
    fn crossover_latency_lower_wins() {
        // AM faster (lower) until 8KB, ifunc faster at 16KB: the paper.
        let s = mk(&[(4096, 3.0, 2.0), (8192, 2.5, 2.2), (16384, 2.4, 3.0)]);
        let c = find_crossover(&s, true).unwrap();
        assert_eq!(c, Crossover { below: 8192, at: 16384 });
    }

    #[test]
    fn crossover_throughput_higher_wins() {
        let s = mk(&[(1024, 1.0e6, 2.0e6), (2048, 9.0e5, 4.0e5)]);
        let c = find_crossover(&s, false).unwrap();
        assert_eq!(c.at, 2048);
    }

    #[test]
    fn no_crossover_is_none() {
        let s = mk(&[(1, 3.0, 2.0), (2, 3.0, 2.0)]);
        assert!(find_crossover(&s, true).is_none());
    }

    #[test]
    fn gain_pct_signs() {
        let p = SeriesPoint { size: 1 << 20, ifunc: 65.0, am: 100.0 };
        // 35% latency reduction — the paper's 1MB point.
        assert!((p.ifunc_gain_pct(true) - 35.0).abs() < 1e-9);
        let q = SeriesPoint { size: 1, ifunc: 0.19e6, am: 1.0e6 };
        // 81% lower message rate — the paper's 1B point.
        assert!((q.ifunc_gain_pct(false) + 81.0).abs() < 1e-9);
    }

    #[test]
    fn json_shape() {
        let s = mk(&[(1, 1.0, 2.0)]);
        let j = series_json("fig3", &s);
        assert!(j.contains("\"series\":\"fig3\""));
        assert!(j.contains("\"size\":1"));
    }

    #[test]
    fn micro_json_parses_back() {
        let rows = vec![
            MicroRow { name: "header decode".into(), median_ns: 12.5, best_ns: 11.0 },
            // Quotes/backslashes in a label must be escaped, not corrupt
            // the report.
            MicroRow { name: "vm \"run\" \\ fast".into(), median_ns: 80.0, best_ns: 75.25 },
        ];
        let j = micro_json(&rows);
        let parsed = crate::util::Json::parse(&j).expect("report must be valid JSON");
        assert_eq!(parsed.get("series").and_then(|s| s.as_str()), Some("micro"));
        assert_eq!(parsed.get("rows").and_then(|r| r.as_arr()).map(|r| r.len()), Some(2));
    }
}
