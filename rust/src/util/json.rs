//! Minimal JSON — parser + emitter.
//!
//! The offline build environment has no `serde_json`, so the small amount
//! of JSON this project speaks (artifact manifests written by
//! `python/compile/aot.py`, the `repro serve` wire protocol, bench series
//! dumps) goes through this ~200-line implementation. It supports the
//! full JSON grammar except `\uXXXX` surrogate pairs beyond the BMP.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing garbage at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// `[1, 2, 3]` → `vec![1, 2, 3]` (i64).
    pub fn as_i64_vec(&self) -> Option<Vec<i64>> {
        self.as_arr()?.iter().map(|v| v.as_f64().map(|f| f as i64)).collect()
    }

    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        self.as_arr()?.iter().map(|v| v.as_f64().map(|f| f as f32)).collect()
    }

    /// Build an object from pairs (emit-side sugar).
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f32(v: &[f32]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Num(*x as f64)).collect())
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}

impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}

impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\r' => write!(f, "\\r")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or("unterminated string")?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or("unterminated escape")?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or("short \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u")?,
                                16,
                            )
                            .map_err(|_| "bad \\u hex")?;
                            self.i += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                }
                _ => {
                    // Collect the full UTF-8 sequence.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    let s = self.b.get(start..self.i).ok_or("truncated utf8")?;
                    out.push_str(std::str::from_utf8(s).map_err(|_| "bad utf8")?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_shape() {
        let j = Json::parse(
            r#"{"name":"delta","input_shape":[4096],"output_shape":[4096],"dtype":"f32"}"#,
        )
        .unwrap();
        assert_eq!(j.get("name").unwrap().as_str(), Some("delta"));
        assert_eq!(j.get("input_shape").unwrap().as_i64_vec(), Some(vec![4096]));
    }

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a":[1,2.5,-3],"b":{"c":true,"d":null},"s":"x\"y\n"}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-12.5e2").unwrap().as_f64(), Some(-1250.0));
        assert_eq!(Json::parse("42").unwrap().as_u64(), Some(42));
        assert_eq!(Json::parse("-1").unwrap().as_u64(), None);
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café ☕""#).unwrap();
        assert_eq!(v.as_str(), Some("café ☕"));
    }

    #[test]
    fn display_escapes_control_chars() {
        let s = Json::Str("a\nb\"".into()).to_string();
        assert_eq!(s, "\"a\\nb\\\"\"");
    }
}
