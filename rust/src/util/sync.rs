//! Poison-tolerant locking.
//!
//! `std`'s mutexes poison when a holder panics, and `lock().unwrap()`
//! then turns *one* panicking thread into a panic in **every** other
//! thread that touches the same lock — on a shared dispatcher link that
//! cascade takes down every client of the worker, which is strictly worse
//! than the original failure. The shared state guarded by the
//! coordinator's locks (transport cursors, window counters) is updated in
//! small all-or-nothing steps, so recovering the guard is sound; the
//! helpers below do that, logging the first recovery so the underlying
//! panic still gets surfaced somewhere. State that is *not* all-or-nothing
//! — the reply collector's multi-step chunk reassembly — deliberately
//! keeps std's poisoning semantics instead of using these.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Condvar, Mutex, MutexGuard};
use std::time::Duration;

use crate::log;

static POISON_SEEN: AtomicBool = AtomicBool::new(false);

fn note_poison() {
    // Log once per process: the interesting event is the panic that
    // poisoned the lock (reported by the panicking thread itself);
    // repeating a warning per recovering caller would just be noise.
    if !POISON_SEEN.swap(true, Ordering::Relaxed) {
        log::warn!(
            "recovered a poisoned lock (another thread panicked while holding it); \
             continuing — further recoveries will be silent"
        );
    }
}

/// `m.lock()` that recovers the guard from a poisoned mutex instead of
/// propagating the panic to this (innocent) thread.
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| {
        note_poison();
        poisoned.into_inner()
    })
}

/// [`Condvar::wait_timeout`] with the same recovery (the reacquired lock
/// may have been poisoned while this thread slept).
pub fn wait_timeout_recover<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    timeout: Duration,
) -> MutexGuard<'a, T> {
    match cv.wait_timeout(guard, timeout) {
        Ok((g, _)) => g,
        Err(poisoned) => {
            note_poison();
            poisoned.into_inner().0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    #[test]
    fn lock_recover_survives_a_poisoning_panic() {
        let m = Arc::new(Mutex::new(7u64));
        let m2 = m.clone();
        // Poison the mutex: panic while holding the guard.
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.lock().is_err(), "mutex must actually be poisoned");
        // An innocent thread still gets the guard — and the state.
        let mut g = lock_recover(&m);
        assert_eq!(*g, 7);
        *g += 1;
        drop(g);
        assert_eq!(*lock_recover(&m), 8);
    }

    #[test]
    fn wait_timeout_recover_returns_the_guard() {
        let m = Mutex::new(1u32);
        let cv = Condvar::new();
        let g = lock_recover(&m);
        let g = wait_timeout_recover(&cv, g, Duration::from_millis(1));
        assert_eq!(*g, 1);
    }
}
