//! Deterministic xorshift RNG for the randomized / property-style tests
//! (proptest is unavailable offline). Seeded explicitly so every failure
//! reproduces.

#[derive(Clone)]
pub struct XorShift {
    state: u64,
}

impl XorShift {
    pub fn new(seed: u64) -> Self {
        XorShift { state: seed.max(1) }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.state = x;
        x
    }

    /// Uniform in `[0, n)`.
    pub fn below(&mut self, n: u64) -> u64 {
        self.next_u64() % n.max(1)
    }

    /// Uniform in `[lo, hi]`.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo + 1)
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    pub fn bytes(&mut self, len: usize) -> Vec<u8> {
        (0..len).map(|_| self.next_u64() as u8).collect()
    }

    pub fn f32s(&mut self, len: usize) -> Vec<f32> {
        (0..len).map(|_| self.f32() * 2.0 - 1.0).collect()
    }

    /// Pick one element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = XorShift::new(42);
        let mut b = XorShift::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = XorShift::new(7);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
            let v = r.range(5, 9);
            assert!((5..=9).contains(&v));
        }
    }

    #[test]
    fn f32_unit_interval() {
        let mut r = XorShift::new(3);
        for _ in 0..1000 {
            let f = r.f32();
            assert!((0.0..1.0).contains(&f));
        }
    }
}
