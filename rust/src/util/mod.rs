//! Small in-tree utilities replacing unavailable crates (offline build):
//! [`json`] for serde_json, [`logger`] for env_logger, [`rng`] for the
//! randomized/property tests.

pub mod json;
pub mod logger;
pub mod rng;
pub mod sync;

pub use json::Json;
pub use rng::XorShift;
pub use sync::{lock_recover, wait_timeout_recover};
