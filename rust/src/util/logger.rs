//! Minimal stderr logger (env_logger stand-in). Level from `RUST_LOG`
//! (error/warn/info/debug/trace; default warn).

use crate::log::{self, Level, LevelFilter, Metadata, Record};

struct StderrLogger;

static LOGGER: StderrLogger = StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, _m: &Metadata) -> bool {
        true
    }

    fn log(&self, record: &Record) {
        if self.enabled(record.metadata()) {
            let tag = match record.level() {
                Level::Error => "ERROR",
                Level::Warn => "WARN ",
                Level::Info => "INFO ",
                Level::Debug => "DEBUG",
                Level::Trace => "TRACE",
            };
            eprintln!("[{tag} {}] {}", record.target(), record.args());
        }
    }

    fn flush(&self) {}
}

/// Install the logger (idempotent).
pub fn init() {
    let level = match std::env::var("RUST_LOG").as_deref() {
        Ok("error") => LevelFilter::Error,
        Ok("info") => LevelFilter::Info,
        Ok("debug") => LevelFilter::Debug,
        Ok("trace") => LevelFilter::Trace,
        Ok("off") => LevelFilter::Off,
        _ => LevelFilter::Warn,
    };
    if log::set_logger(&LOGGER).is_ok() {
        log::set_max_level(level);
    }
}
