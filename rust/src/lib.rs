//! # two_chains — UCX-style remote function injection and invocation
//!
//! Reproduction of *"UCX Programming Interface for Remote Function Injection
//! and Invocation"* (Peña, Lu, Shamis, Poole — 2021). The paper introduces
//! the **ifunc API**: messages that carry *executable code together with
//! data*, delivered with one-sided RDMA PUTs into a ring buffer on the
//! target, where a polling loop validates the frame, performs GOT-style
//! runtime relocation, flushes the instruction cache, and invokes the
//! shipped function — in contrast to classical active messages, which ship
//! only a pre-registered handler ID.
//!
//! Because the paper's testbed (two Arm servers, ConnectX-6 InfiniBand,
//! non-coherent I-cache, native `.text` injection) is hardware we do not
//! have, every hardware gate is **simulated** — see `DESIGN.md §2` for the
//! substitution table. The layering mirrors UCX:
//!
//! ```text
//!   ifunc/        the paper's contribution: ucp_register_ifunc,
//!                 ucp_ifunc_msg_create, ucp_ifunc_msg_send_nbix,
//!                 ucp_poll_ifunc — split into one execution engine
//!                 (decode/cache/link/verify/invoke), pluggable delivery
//!                 transports (RDMA-PUT ring, AM send-receive, intra-node
//!                 shared memory), a reply ring, the verified-program
//!                 cache, the I-cache model
//!   ucp/          UCP-like mid layer: Context/Worker/Endpoint, mem_map,
//!                 rkey pack/unpack, put_nbi, flush, Active Messages
//!                 (the baseline), eager + rendezvous protocols
//!   vm/           TCVM — portable register bytecode standing in for native
//!                 `.text`: assembler, verifier, interpreter, GOT tables
//!   fabric/       simulated RDMA fabric: registered memory regions with
//!                 32-bit rkeys, queue pairs, one-sided PUT/GET/atomics,
//!                 completion counting, calibrated wire-cost model
//!   runtime/      PJRT executor: loads AOT-compiled HLO artifacts (from
//!                 JAX + Pallas, see python/compile) and runs them — the
//!                 compute engine behind HLO-carrying ifuncs
//!   coordinator/  host → DPU/CSD-style worker pool: dispatcher, locality
//!                 routing, poll loops, the in-memory record store used by
//!                 the paper's database-insert example
//!   bench/        harness regenerating the paper's Fig. 3 and Fig. 4
//! ```
//!
//! ## Quickstart
//!
//! ```no_run
//! use two_chains::prelude::*;
//!
//! // Two "machines" connected back-to-back (paper §4.2).
//! let fabric = Fabric::new(2, WireConfig::off());
//! let src = Context::new(fabric.node(0), ContextConfig::default()).unwrap();
//! let dst = Context::new(fabric.node(1), ContextConfig::default()).unwrap();
//! src.library_dir().install(Box::new(CounterIfunc::default()));
//! dst.symbols().install_counter();
//!
//! let mut ring = IfuncRing::new(&dst, 1 << 20).unwrap();
//! let worker_s = Worker::new(&src);
//! let worker_d = Worker::new(&dst);
//! let ep = worker_s.connect(&worker_d).unwrap();
//!
//! let h = src.register_ifunc("counter").unwrap();
//! let msg = h.msg_create(&SourceArgs::bytes(vec![0u8; 64])).unwrap();
//! ep.ifunc_msg_send_nbix(&msg, ring.remote_addr(), ring.rkey()).unwrap();
//! ep.flush().unwrap();
//! let mut args = TargetArgs::none();
//! while !matches!(
//!     dst.poll_ifunc(&mut ring, &mut args).unwrap(),
//!     PollResult::Executed(_)
//! ) {}
//! ```

pub mod bench;
pub mod coordinator;
pub mod fabric;
pub mod ifunc;
pub mod log;
pub mod runtime;
pub mod ucp;
pub mod util;
pub mod vm;
pub mod xla;

/// Crate-wide error type. Mirrors `ucs_status_t`: every fallible public API
/// returns `Result<T, Error>` where the error enumerates the UCX-style
/// status codes the paper's API surfaces.
///
/// (`Display`/`Error` are hand-implemented: the offline build has no
/// `thiserror`.)
#[derive(Debug)]
pub enum Error {
    /// Remote key not known to the target HCA, or permissions insufficient.
    /// The paper (§3.5): "If the process accesses the memory with an invalid
    /// RKEY, the request gets rejected at the hardware level."
    RemoteAccess(String),
    /// Frame failed header-signal or bounds validation (§3.4: "messages that
    /// are ill-formed or too long will be rejected").
    InvalidMessage(String),
    /// Named ifunc library was not found in `UCX_IFUNC_LIB_DIR`.
    NoSuchLibrary(String),
    /// TCVM bytecode failed the security verifier (§3.5).
    Verify(String),
    /// TCVM runtime fault (out-of-bounds access, fuel exhausted, bad GOT slot).
    VmFault(String),
    /// Destination ring buffer cannot accept the frame.
    NoResource(String),
    /// PJRT / XLA error while compiling or executing an HLO-carrying ifunc.
    Xla(String),
    /// Endpoint / transport failure.
    Transport(String),
    Io(std::io::Error),
    Other(String),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::RemoteAccess(m) => write!(f, "remote access error: {m}"),
            Error::InvalidMessage(m) => write!(f, "invalid ifunc message: {m}"),
            Error::NoSuchLibrary(m) => write!(f, "no such ifunc library: {m}"),
            Error::Verify(m) => write!(f, "code verification failed: {m}"),
            Error::VmFault(m) => write!(f, "injected function fault: {m}"),
            Error::NoResource(m) => write!(f, "no resource: {m}"),
            Error::Xla(m) => write!(f, "xla runtime error: {m}"),
            Error::Transport(m) => write!(f, "transport error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Other(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Defaulted error parameter: `Result<T>` is the UCX-style status result;
/// a handful of call sites (CLI parsing) substitute their own error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Convenience re-exports covering the whole public API surface.
pub mod prelude {
    pub use crate::bench::{BenchConfig, BenchMode};
    pub use crate::coordinator::{
        Cluster, ClusterConfig, ClusterConfigBuilder, Dispatcher, MultiPendingReply, MultiReply,
        PendingReply, RecordStore, Target,
    };
    pub use crate::fabric::{Fabric, MemPerm, WireConfig};
    pub use crate::ifunc::{
        builtin::CounterIfunc, CodeImage, ExecOutcome, IfuncHandle, IfuncMsg, IfuncRing,
        PollResult, Reply, SourceArgs, TargetArgs, TransportKind,
    };
    pub use crate::ucp::{AmParams, Context, ContextConfig, Endpoint, Worker};
    pub use crate::vm::{Assembler, Op};
    pub use crate::{Error, Result};
}
