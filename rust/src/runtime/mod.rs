//! PJRT runtime — executes AOT-compiled JAX/Pallas artifacts.
//!
//! This is the rust end of the three-layer AOT bridge: `python/compile`
//! lowers JAX functions (which call Pallas kernels) to **HLO text**
//! (`artifacts/<name>.hlo.txt`, see `aot.py`); this module loads that text
//! with `HloModuleProto`, compiles it on the PJRT CPU client, caches the
//! executable, and runs it from the coordinator / poll hot path. Python is
//! never on the request path.
//!
//! PJRT wrapper types are not `Send`, so each polling/executing thread
//! owns its own [`XlaRuntime`] via [`with_runtime`]. Compilation happens
//! once per (thread, ifunc name) — this is the PJRT analog of the paper's
//! auto-registration: the first-seen ifunc type pays the "dynamic linking"
//! cost, subsequent messages hit the cache (§3.4).

use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::Arc;

use crate::vm::HostFn;
use crate::xla;
use crate::{Error, Result};

/// Whether a real PJRT backend is linked into this build. `false` with the
/// in-tree xla stub: HLO-carrying ifuncs then fail to compile (and the
/// AOT-artifact tests/examples skip), while everything else runs. See
/// `rust/src/xla.rs` for how to link the real backend.
pub const fn pjrt_available() -> bool {
    xla::available()
}

/// Manifest describing one AOT artifact, written by `python/compile/aot.py`
/// next to the HLO text. All artifacts use the flat-`f32` calling
/// convention: input `f32[input_elems]`, output a 1-tuple of
/// `f32[output_elems]` (the JAX side reshapes internally).
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactManifest {
    pub name: String,
    pub input_shape: Vec<i64>,
    pub output_shape: Vec<i64>,
    pub dtype: String,
    pub description: String,
}

impl ArtifactManifest {
    pub fn input_elems(&self) -> usize {
        self.input_shape.iter().product::<i64>() as usize
    }

    pub fn output_elems(&self) -> usize {
        self.output_shape.iter().product::<i64>() as usize
    }

    /// Parse the JSON written by `aot.py`.
    pub fn from_json(text: &str) -> Result<Self> {
        let j = crate::util::Json::parse(text)
            .map_err(|e| Error::Other(format!("bad manifest json: {e}")))?;
        let field = |k: &str| {
            j.get(k).ok_or_else(|| Error::Other(format!("manifest missing field {k}")))
        };
        Ok(ArtifactManifest {
            name: field("name")?
                .as_str()
                .ok_or_else(|| Error::Other("manifest name not a string".into()))?
                .to_string(),
            input_shape: field("input_shape")?
                .as_i64_vec()
                .ok_or_else(|| Error::Other("bad input_shape".into()))?,
            output_shape: field("output_shape")?
                .as_i64_vec()
                .ok_or_else(|| Error::Other("bad output_shape".into()))?,
            dtype: j.get("dtype").and_then(|v| v.as_str()).unwrap_or("f32").to_string(),
            description: j
                .get("description")
                .and_then(|v| v.as_str())
                .unwrap_or("")
                .to_string(),
        })
    }

    pub fn to_json(&self) -> String {
        use crate::util::Json;
        let dims = |v: &[i64]| Json::Arr(v.iter().map(|&i| Json::Num(i as f64)).collect());
        Json::obj(vec![
            ("name", Json::from(self.name.as_str())),
            ("input_shape", dims(&self.input_shape)),
            ("output_shape", dims(&self.output_shape)),
            ("dtype", Json::from(self.dtype.as_str())),
            ("description", Json::from(self.description.as_str())),
        ])
        .to_string()
    }
}

/// A per-thread PJRT client + executable cache.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    execs: RefCell<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
    pub compilations: std::cell::Cell<u64>,
    pub executions: std::cell::Cell<u64>,
}

impl XlaRuntime {
    pub fn new() -> Result<Self> {
        Ok(XlaRuntime {
            client: xla::PjRtClient::cpu()?,
            execs: RefCell::new(HashMap::new()),
            compilations: std::cell::Cell::new(0),
            executions: std::cell::Cell::new(0),
        })
    }

    pub fn is_compiled(&self, name: &str) -> bool {
        self.execs.borrow().contains_key(name)
    }

    /// Compile `hlo_text` under `name` if not already cached. This is the
    /// expensive "first-seen ifunc type" path.
    pub fn ensure_compiled(&self, name: &str, hlo_text: &[u8]) -> Result<()> {
        if self.is_compiled(name) {
            return Ok(());
        }
        let proto = xla::HloModuleProto::parse_and_return_unverified_module(hlo_text)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.execs.borrow_mut().insert(name.to_string(), Arc::new(exe));
        self.compilations.set(self.compilations.get() + 1);
        Ok(())
    }

    /// Compile from an artifact file on disk (examples, coordinator boot).
    pub fn ensure_compiled_file(&self, name: &str, path: &std::path::Path) -> Result<()> {
        if self.is_compiled(name) {
            return Ok(());
        }
        let text = std::fs::read(path)?;
        self.ensure_compiled(name, &text)
    }

    /// Execute artifact `name` on a flat `f32` input of shape `dims`;
    /// returns the flat `f32` output (first tuple element).
    pub fn execute_f32(&self, name: &str, input: &[f32], dims: &[i64]) -> Result<Vec<f32>> {
        let exe = self
            .execs
            .borrow()
            .get(name)
            .cloned()
            .ok_or_else(|| Error::Xla(format!("artifact {name} not compiled")))?;
        let lit = xla::Literal::vec1(input).reshape(dims)?;
        let result = exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
        self.executions.set(self.executions.get() + 1);
        // aot.py lowers with return_tuple=True → 1-tuple output.
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Number of cached executables.
    pub fn num_cached(&self) -> usize {
        self.execs.borrow().len()
    }
}

thread_local! {
    static RUNTIME: RefCell<Option<XlaRuntime>> = const { RefCell::new(None) };
}

/// Run `f` against this thread's runtime, creating it on first use.
pub fn with_runtime<R>(f: impl FnOnce(&XlaRuntime) -> Result<R>) -> Result<R> {
    RUNTIME.with(|cell| {
        let mut slot = cell.borrow_mut();
        if slot.is_none() {
            *slot = Some(XlaRuntime::new()?);
        }
        f(slot.as_ref().unwrap())
    })
}

/// The `xla_exec` host symbol injected code calls through its GOT
/// (Listing 1.3's compute step, with PJRT as the engine).
///
/// Register ABI: `r1` = input byte offset in payload, `r2` = input length
/// in f32 elements, `r3` = output byte offset in payload, `r4` = max output
/// elements. Returns the number of f32 elements written.
///
/// The artifact is looked up by the *current ifunc's name*, which
/// `ucp_poll_ifunc` stamps into [`crate::ifunc::TargetArgs`] before
/// invocation; `poll` has already ensured the artifact shipped in the
/// message is compiled on this thread.
pub fn xla_exec_hostfn() -> HostFn {
    Arc::new(|ctx, [in_off, n_elems, out_off, max_out]| {
        let ta = ctx
            .user
            .downcast_mut::<crate::ifunc::TargetArgs>()
            .ok_or("xla_exec: target args are not ifunc TargetArgs")?;
        let name = ta
            .hlo_name
            .clone()
            .ok_or("xla_exec: no HLO artifact bound to this invocation")?;
        let in_off = in_off as usize;
        let n = n_elems as usize;
        let out_off = out_off as usize;
        let in_end = in_off + n * 4;
        if in_end > ctx.payload.len() {
            return Err(format!("xla_exec: input [{in_off}, {in_end}) outside payload"));
        }
        let input: Vec<f32> = ctx.payload[in_off..in_end]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        let out = with_runtime(|rt| rt.execute_f32(&name, &input, &[n as i64]))
            .map_err(|e| e.to_string())?;
        if out.len() > max_out as usize {
            return Err(format!(
                "xla_exec: output of {} elems exceeds caller max {max_out}",
                out.len()
            ));
        }
        let out_end = out_off + out.len() * 4;
        if out_end > ctx.payload.len() {
            return Err(format!("xla_exec: output [{out_off}, {out_end}) outside payload"));
        }
        for (i, v) in out.iter().enumerate() {
            ctx.payload[out_off + i * 4..out_off + i * 4 + 4]
                .copy_from_slice(&v.to_le_bytes());
        }
        Ok(out.len() as u64)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_elem_counts() {
        let m = ArtifactManifest {
            name: "t".into(),
            input_shape: vec![4, 8],
            output_shape: vec![32],
            dtype: "f32".into(),
            description: String::new(),
        };
        assert_eq!(m.input_elems(), 32);
        assert_eq!(m.output_elems(), 32);
    }

    #[test]
    fn manifest_json_roundtrip() {
        let m = ArtifactManifest {
            name: "delta".into(),
            input_shape: vec![4096],
            output_shape: vec![4096],
            dtype: "f32".into(),
            description: "delta codec".into(),
        };
        assert_eq!(ArtifactManifest::from_json(&m.to_json()).unwrap(), m);
    }

    #[test]
    fn manifest_defaults_dtype() {
        let m = ArtifactManifest::from_json(
            r#"{"name":"x","input_shape":[2,3],"output_shape":[6]}"#,
        )
        .unwrap();
        assert_eq!(m.dtype, "f32");
        assert_eq!(m.input_elems(), 6);
    }

    #[test]
    fn execute_uncompiled_artifact_errors() {
        let r = with_runtime(|rt| rt.execute_f32("missing", &[1.0], &[1]));
        assert!(r.is_err());
    }
}
