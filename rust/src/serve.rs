//! `repro serve` — the record-ingestion service.
//!
//! A host (leader) process accepts line-delimited JSON over TCP and turns
//! each request into an ifunc injection to the worker pool — the paper's
//! §3.2 database scenario as a running service. One OS thread per client
//! (the offline environment has no tokio; the request path itself is the
//! fabric's, not the socket's).
//!
//! Protocol (one JSON object per line):
//! ```json
//! {"cmd":"insert","key":7,"data":[0.1,0.2]}  -> {"ok":true,"worker":1}
//! {"cmd":"get","key":7}                      -> {"ok":true,"data":[...]}
//! {"cmd":"stats"}                            -> {"ok":true,"executed":N}
//! ```
//!
//! `get` is served by injection too: a `GetIfunc` frame travels to the
//! key's owner, the injected code calls `db_get` (which pushes the record
//! into the invocation's reply payload), and the reply frame carries the
//! record bytes back inline — the data in the response is computed by the
//! injected function on the worker, not read from the store by the
//! leader.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use two_chains::coordinator::{Cluster, ClusterConfig, GetIfunc, InsertIfunc, GET_MISSING};
use two_chains::ifunc::{IfuncHandle, TransportKind};
use two_chains::log;
use two_chains::util::Json;
use two_chains::Result;

/// The leader-side handles a serve deployment works with.
pub struct ServeHandles {
    pub insert: IfuncHandle,
    pub get: IfuncHandle,
}

pub fn serve(workers: usize, listen: &str, transport: TransportKind) -> Result<()> {
    let cluster = Arc::new(Cluster::launch(
        ClusterConfig { workers, transport, ..Default::default() },
        |_, _, _| {},
    )?);
    cluster.leader.library_dir().install(Box::new(InsertIfunc));
    cluster.leader.library_dir().install(Box::new(GetIfunc));
    let handles = Arc::new(ServeHandles {
        insert: cluster.leader.register_ifunc("insert")?,
        get: cluster.leader.register_ifunc("get")?,
    });

    let listener = TcpListener::bind(listen)?;
    println!(
        "listening on {listen} ({workers} workers, {} transport); JSON lines: insert/get/stats",
        transport.label()
    );
    for stream in listener.incoming() {
        let stream = stream?;
        let cluster = cluster.clone();
        let handles = handles.clone();
        std::thread::spawn(move || {
            let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_default();
            if let Err(e) = client_loop(stream, &cluster, &handles) {
                log::warn!("client {peer}: {e}");
            }
        });
    }
    Ok(())
}

fn client_loop(stream: TcpStream, cluster: &Cluster, handles: &ServeHandles) -> Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let resp = handle_line(cluster, handles, &line);
        writer.write_all(resp.to_string().as_bytes())?;
        writer.write_all(b"\n")?;
    }
    Ok(())
}

fn err_json(msg: &str) -> Json {
    Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::from(msg))])
}

pub fn handle_line(cluster: &Cluster, handles: &ServeHandles, line: &str) -> Json {
    let req = match Json::parse(line) {
        Ok(j) => j,
        Err(e) => return err_json(&format!("bad request: {e}")),
    };
    let d = cluster.dispatcher();
    match req.get("cmd").and_then(|c| c.as_str()) {
        Some("insert") => {
            let Some(key) = req.get("key").and_then(|k| k.as_u64()) else {
                return err_json("insert needs numeric key");
            };
            let Some(data) = req.get("data").and_then(|v| v.as_f32_vec()) else {
                return err_json("insert needs data array");
            };
            match d
                .inject_by_key(&handles.insert, key, &InsertIfunc::args(key, &data))
                .and_then(|w| d.barrier().map(|_| w))
            {
                Ok(worker) => {
                    Json::obj(vec![("ok", Json::Bool(true)), ("worker", Json::from(worker))])
                }
                Err(e) => err_json(&e.to_string()),
            }
        }
        Some("get") => {
            let Some(key) = req.get("key").and_then(|k| k.as_u64()) else {
                return err_json("get needs numeric key");
            };
            let worker = d.route_key(key);
            let msg = match handles.get.msg_create(&GetIfunc::args(key)) {
                Ok(m) => m,
                Err(e) => return err_json(&e.to_string()),
            };
            // Inject the lookup and wait for the reply frame: the record
            // bytes ride inline in the reply payload, pushed by the
            // injected function on the worker — concurrent gets each
            // carry their own frame, so nothing can clobber anything.
            match d.invoke_get(worker, &msg) {
                Ok((reply, data)) if reply.ok() && reply.r0 != GET_MISSING => Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("worker", Json::from(worker)),
                    ("data", Json::arr_f32(&data)),
                ]),
                Ok((reply, _)) if reply.overflowed() => err_json(&format!(
                    "record of {} elems exceeds the inline reply cap",
                    reply.r0
                )),
                Ok((reply, _)) if reply.ok() => err_json("not found"),
                Ok(_) => err_json("get ifunc rejected on worker"),
                Err(e) => err_json(&e.to_string()),
            }
        }
        Some("stats") => Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("executed", Json::from(d.total_executed())),
            (
                "per_worker",
                Json::Arr(cluster.workers.iter().map(|w| Json::from(w.executed())).collect()),
            ),
            (
                "records",
                Json::from(cluster.workers.iter().map(|w| w.store.len()).sum::<usize>()),
            ),
        ]),
        _ => err_json("unknown cmd (insert/get/stats)"),
    }
}
