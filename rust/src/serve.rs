//! `repro serve` — the record-ingestion service.
//!
//! A host (leader) process accepts line-delimited JSON over TCP and turns
//! each request into an ifunc injection to the worker pool — the paper's
//! §3.2 database scenario as a running service. One OS thread per client
//! (the offline environment has no tokio; the request path itself is the
//! fabric's, not the socket's).
//!
//! Protocol (one JSON object per line):
//! ```json
//! {"cmd":"insert","key":7,"data":[0.1,0.2]}  -> {"ok":true,"worker":1}
//! {"cmd":"get","key":7}                      -> {"ok":true,"data":[...]}
//! {"cmd":"stats"}                            -> {"ok":true,"executed":N}
//! ```

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use two_chains::coordinator::{Cluster, ClusterConfig, InsertIfunc};
use two_chains::ifunc::IfuncHandle;
use two_chains::log;
use two_chains::util::Json;
use two_chains::Result;

pub fn serve(workers: usize, listen: &str) -> Result<()> {
    let cluster = Arc::new(Cluster::launch(
        ClusterConfig { workers, ..Default::default() },
        |_, _, _| {},
    )?);
    cluster.leader.library_dir().install(Box::new(InsertIfunc));
    let handle: Arc<IfuncHandle> = Arc::new(cluster.leader.register_ifunc("insert")?);

    let listener = TcpListener::bind(listen)?;
    println!("listening on {listen} ({workers} workers); JSON lines: insert/get/stats");
    for stream in listener.incoming() {
        let stream = stream?;
        let cluster = cluster.clone();
        let handle = handle.clone();
        std::thread::spawn(move || {
            let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_default();
            if let Err(e) = client_loop(stream, &cluster, &handle) {
                log::warn!("client {peer}: {e}");
            }
        });
    }
    Ok(())
}

fn client_loop(stream: TcpStream, cluster: &Cluster, handle: &IfuncHandle) -> Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let resp = handle_line(cluster, handle, &line);
        writer.write_all(resp.to_string().as_bytes())?;
        writer.write_all(b"\n")?;
    }
    Ok(())
}

fn err_json(msg: &str) -> Json {
    Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::from(msg))])
}

pub fn handle_line(cluster: &Cluster, handle: &IfuncHandle, line: &str) -> Json {
    let req = match Json::parse(line) {
        Ok(j) => j,
        Err(e) => return err_json(&format!("bad request: {e}")),
    };
    let d = cluster.dispatcher();
    match req.get("cmd").and_then(|c| c.as_str()) {
        Some("insert") => {
            let Some(key) = req.get("key").and_then(|k| k.as_u64()) else {
                return err_json("insert needs numeric key");
            };
            let Some(data) = req.get("data").and_then(|v| v.as_f32_vec()) else {
                return err_json("insert needs data array");
            };
            match d
                .inject_by_key(handle, key, &InsertIfunc::args(key, &data))
                .and_then(|w| d.barrier().map(|_| w))
            {
                Ok(worker) => {
                    Json::obj(vec![("ok", Json::Bool(true)), ("worker", Json::from(worker))])
                }
                Err(e) => err_json(&e.to_string()),
            }
        }
        Some("get") => {
            let Some(key) = req.get("key").and_then(|k| k.as_u64()) else {
                return err_json("get needs numeric key");
            };
            let worker = d.route_key(key);
            match cluster.workers[worker].store.get(key) {
                Some(data) => Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("worker", Json::from(worker)),
                    ("data", Json::arr_f32(&data)),
                ]),
                None => err_json("not found"),
            }
        }
        Some("stats") => Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("executed", Json::from(d.total_executed())),
            (
                "per_worker",
                Json::Arr(cluster.workers.iter().map(|w| Json::from(w.executed())).collect()),
            ),
            (
                "records",
                Json::from(cluster.workers.iter().map(|w| w.store.len()).sum::<usize>()),
            ),
        ]),
        _ => err_json("unknown cmd (insert/get/stats)"),
    }
}
