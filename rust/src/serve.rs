//! `repro serve` — the record-ingestion service.
//!
//! A host (leader) process accepts line-delimited JSON over TCP and turns
//! each request into an ifunc injection to the worker pool — the paper's
//! §3.2 database scenario as a running service. One OS thread per client
//! (the offline environment has no tokio; the request path itself is the
//! fabric's, not the socket's).
//!
//! Protocol (one JSON object per line):
//! ```json
//! {"cmd":"insert","key":7,"data":[0.1,0.2]}  -> {"ok":true,"worker":1}
//! {"cmd":"get","key":7}                      -> {"ok":true,"data":[...]}
//! {"cmd":"stats"}                            -> {"ok":true,"executed":N}
//! ```
//!
//! Both commands are **invocations on the record's owning worker** —
//! nothing touches any other link, so concurrent clients hitting
//! different shards never serialize on each other:
//!
//! * `insert` injects an `InsertIfunc` frame to the key's owner and waits
//!   for *that worker's* reply (not a full-cluster barrier — one slow or
//!   busy worker cannot stall inserts bound elsewhere),
//! * `get` injects a `GetIfunc` frame; the injected code calls `db_get`,
//!   which pushes the record into the invocation's reply payload, and the
//!   reply carries the record back — chunk-streamed when it exceeds one
//!   reply frame, so records of any size round-trip. The data in the
//!   response is computed by the injected function on the worker, not
//!   read from the store by the leader.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use two_chains::coordinator::{Cluster, ClusterConfig, GetIfunc, InsertIfunc, Target, GET_MISSING};
use two_chains::ifunc::{IfuncHandle, TransportKind};
use two_chains::log;
use two_chains::util::Json;
use two_chains::Result;

/// The leader-side handles a serve deployment works with.
pub struct ServeHandles {
    pub insert: IfuncHandle,
    pub get: IfuncHandle,
}

/// Boot the worker pool and register the serve ifuncs (shared by the TCP
/// entry point and the in-process tests).
pub fn launch(workers: usize, transport: TransportKind) -> Result<(Arc<Cluster>, ServeHandles)> {
    let cluster = Arc::new(Cluster::launch(
        ClusterConfig::builder().workers(workers).transport(transport).build()?,
        |_, _, _| {},
    )?);
    cluster.leader.library_dir().install(Box::new(InsertIfunc));
    cluster.leader.library_dir().install(Box::new(GetIfunc));
    let handles = ServeHandles {
        insert: cluster.leader.register_ifunc("insert")?,
        get: cluster.leader.register_ifunc("get")?,
    };
    Ok((cluster, handles))
}

pub fn serve(workers: usize, listen: &str, transport: TransportKind) -> Result<()> {
    let (cluster, handles) = launch(workers, transport)?;
    let handles = Arc::new(handles);

    let listener = TcpListener::bind(listen)?;
    println!(
        "listening on {listen} ({workers} workers, {} transport); JSON lines: insert/get/stats",
        transport.label()
    );
    for stream in listener.incoming() {
        let stream = stream?;
        let cluster = cluster.clone();
        let handles = handles.clone();
        std::thread::spawn(move || {
            let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_default();
            if let Err(e) = client_loop(stream, &cluster, &handles) {
                log::warn!("client {peer}: {e}");
            }
        });
    }
    Ok(())
}

fn client_loop(stream: TcpStream, cluster: &Cluster, handles: &ServeHandles) -> Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let resp = handle_line(cluster, handles, &line);
        writer.write_all(resp.to_string().as_bytes())?;
        writer.write_all(b"\n")?;
    }
    Ok(())
}

fn err_json(msg: &str) -> Json {
    Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::from(msg))])
}

pub fn handle_line(cluster: &Cluster, handles: &ServeHandles, line: &str) -> Json {
    let req = match Json::parse(line) {
        Ok(j) => j,
        Err(e) => return err_json(&format!("bad request: {e}")),
    };
    let d = cluster.dispatcher();
    match req.get("cmd").and_then(|c| c.as_str()) {
        Some("insert") => {
            let Some(key) = req.get("key").and_then(|k| k.as_u64()) else {
                return err_json("insert needs numeric key");
            };
            let Some(data) = req.get("data").and_then(|v| v.as_f32_vec()) else {
                return err_json("insert needs data array");
            };
            // An invocation on the owning worker alone: wait for *its*
            // reply, not a full-cluster barrier — a barrier here would
            // flush and wait on every link, so one client inserting to
            // worker 0 would serialize behind unrelated traffic (or a
            // parked frame) on worker N.
            let worker = d.route_key(key);
            let msg = match handles.insert.msg_create(&InsertIfunc::args(key, &data)) {
                Ok(m) => m,
                Err(e) => return err_json(&e.to_string()),
            };
            match d.invoke_one(Target::Worker(worker), &msg) {
                Ok(reply) if reply.ok() => {
                    Json::obj(vec![("ok", Json::Bool(true)), ("worker", Json::from(worker))])
                }
                Ok(_) => err_json("insert ifunc rejected on worker"),
                Err(e) => err_json(&e.to_string()),
            }
        }
        Some("get") => {
            let Some(key) = req.get("key").and_then(|k| k.as_u64()) else {
                return err_json("get needs numeric key");
            };
            let worker = d.route_key(key);
            let msg = match handles.get.msg_create(&GetIfunc::args(key)) {
                Ok(m) => m,
                Err(e) => return err_json(&e.to_string()),
            };
            // Inject the lookup and wait for the reply: the record bytes
            // ride in the reply payload — streamed across chunk frames
            // when the record exceeds one — pushed by the injected
            // function on the worker. Concurrent gets each carry their
            // own frame, so nothing can clobber anything, and record
            // size never changes the protocol.
            match d.fetch(Target::Worker(worker), &msg) {
                Ok((reply, data)) if reply.ok() && reply.r0 != GET_MISSING => Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("worker", Json::from(worker)),
                    ("data", Json::arr_f32(&data)),
                ]),
                Ok((reply, _)) if reply.overflowed() => {
                    // Only reachable on a stream_replies: false cluster
                    // (serve always streams); kept for wire compat.
                    err_json("record too large for this link (reply streaming disabled)")
                }
                Ok((reply, _)) if reply.ok() => err_json("not found"),
                Ok(_) => err_json("get ifunc rejected on worker"),
                Err(e) => err_json(&e.to_string()),
            }
        }
        Some("stats") => Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("executed", Json::from(d.total_executed())),
            (
                "per_worker",
                Json::Arr(cluster.workers.iter().map(|w| Json::from(w.executed())).collect()),
            ),
            (
                "records",
                Json::from(cluster.workers.iter().map(|w| w.store.len()).sum::<usize>()),
            ),
        ]),
        _ => err_json("unknown cmd (insert/get/stats)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The full JSON protocol in-process (no socket): a record well past
    /// one reply frame (80 KB > 64 KiB) inserts to its owning worker and
    /// streams back intact through `get` — over every serve transport,
    /// including the colocated shm pool.
    #[test]
    fn json_insert_then_get_streams_a_big_record() {
        for transport in TransportKind::ALL {
            json_roundtrip_on(transport);
        }
    }

    fn json_roundtrip_on(transport: TransportKind) {
        let (cluster, handles) = launch(2, transport).unwrap();
        let n = 20_000usize; // 80 KB of f32s — past the old inline cap
        let data: String = (0..n).map(|i| format!("{}", i % 17)).collect::<Vec<_>>().join(",");
        let resp = handle_line(
            &cluster,
            &handles,
            &format!("{{\"cmd\":\"insert\",\"key\":7,\"data\":[{data}]}}"),
        );
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp}");

        let resp = handle_line(&cluster, &handles, "{\"cmd\":\"get\",\"key\":7}");
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp}");
        let got = resp.get("data").unwrap().as_f32_vec().unwrap();
        assert_eq!(got.len(), n);
        let want: Vec<f32> = (0..n).map(|i| (i % 17) as f32).collect();
        assert_eq!(got, want);

        let resp = handle_line(&cluster, &handles, "{\"cmd\":\"get\",\"key\":999}");
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)), "{resp}");
    }
}
