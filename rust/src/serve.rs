//! `repro serve` — the record-ingestion service.
//!
//! A host (leader) process accepts line-delimited JSON over TCP and turns
//! each request into an ifunc injection to the worker pool — the paper's
//! §3.2 database scenario as a running service **under concurrent
//! multi-client load**. This file is only the socket glue: sessions,
//! pipelining, cross-client coalescing, and admission control live in
//! `two_chains::coordinator::frontend`, so the in-process tests and
//! benches drive the identical pipeline without a socket.
//!
//! Protocol (one JSON object per line; `id` is any client-chosen JSON
//! value, echoed back on the matching response):
//! ```json
//! {"id":1,"cmd":"insert","key":7,"data":[0.1]} -> {"ok":true,"worker":1,"id":1}
//! {"id":2,"cmd":"get","key":7}                 -> {"ok":true,"data":[...],"id":2}
//! {"cmd":"stats"}                              -> {"ok":true,"executed":N,"frontend":{...}}
//! ```
//!
//! A connection is **pipelined**: the client may write many requests
//! before reading any response, and responses complete out of order
//! (match them by `id`). Per connection, one OS thread reads + submits
//! while a second drains responses back to the socket (the offline
//! environment has no tokio; the request path itself is the fabric's,
//! not the socket's). Under overload, requests are refused *before* any
//! blocking wait with `{"ok":false,"error":"overloaded","retry":true}`;
//! past `--max-clients`, new connections get one JSON error line and are
//! closed.

use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use two_chains::coordinator::{
    Cluster, ClusterConfig, Frontend, FrontendConfig, Session, SessionReceiver,
};
use two_chains::ifunc::TransportKind;
use two_chains::log;
use two_chains::util::Json;
use two_chains::Result;

/// Everything `repro serve` needs beyond the listen address.
pub struct ServeOpts {
    pub workers: usize,
    pub transport: TransportKind,
    /// Wire the worker↔worker mesh (`--mesh`): enables the `forward`
    /// host symbol for injected code and the `mesh` stats block.
    pub mesh: bool,
    pub frontend: FrontendConfig,
}

fn err_line(msg: &str) -> String {
    let mut s = Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::from(msg))])
        .to_string();
    s.push('\n');
    s
}

/// Bind and serve until the process dies (the CLI entry point).
pub fn serve(opts: &ServeOpts, listen: &str) -> Result<()> {
    let listener = TcpListener::bind(listen)?;
    println!(
        "listening on {listen} ({} workers, {} transport); JSON lines: insert/get/stats \
         (pipelined; echo field: id)",
        opts.workers,
        opts.transport.label()
    );
    run(listener, opts, &Arc::new(AtomicBool::new(false)))
}

/// Accept loop over an already-bound listener, honoring a shutdown
/// signal (`stop`) so in-process tests can tear the server down. Accept
/// errors are logged and survived — one bad handshake must not kill the
/// service — and connections past `max_clients` are refused with a JSON
/// error line instead of an unbounded thread.
pub fn run(listener: TcpListener, opts: &ServeOpts, stop: &Arc<AtomicBool>) -> Result<()> {
    let cluster = Arc::new(Cluster::launch(
        ClusterConfig::builder()
            .workers(opts.workers)
            .transport(opts.transport)
            .mesh(opts.mesh)
            .build()?,
        |_, _, _| {},
    )?);
    let frontend = Frontend::launch(cluster.clone(), opts.frontend.clone())?;

    listener.set_nonblocking(true)?;
    let mut clients: Vec<std::thread::JoinHandle<()>> = Vec::new();
    while !stop.load(Ordering::Acquire) {
        match listener.accept() {
            Ok((stream, peer)) => {
                clients.retain(|h| !h.is_finished());
                match frontend.session() {
                    Ok((session, responses)) => {
                        let stop = stop.clone();
                        clients.push(std::thread::spawn(move || {
                            if let Err(e) = client_loop(stream, session, responses, &stop) {
                                log::warn!("client {peer}: {e}");
                            }
                        }));
                    }
                    Err(e) => {
                        // At capacity: one JSON error line, then close —
                        // never an unbounded client thread.
                        let mut stream = stream;
                        let _ = stream.write_all(err_line(&e.to_string()).as_bytes());
                        log::warn!("client {peer} refused: {e}");
                    }
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => {
                // Log-and-continue: a single failed accept (refused
                // handshake, transient resource exhaustion) must not
                // bring the whole server down.
                log::warn!("accept: {e}");
                std::thread::sleep(Duration::from_millis(10));
            }
        }
    }
    for h in clients {
        let _ = h.join();
    }
    frontend.shutdown();
    Ok(())
}

/// One connection: this thread reads + submits; a paired writer thread
/// drains session responses back to the socket. The writer owes exactly
/// one response line per submitted request and exits once the reader
/// hit EOF and every owed response has been written.
fn client_loop(
    stream: TcpStream,
    session: Session,
    responses: SessionReceiver,
    stop: &Arc<AtomicBool>,
) -> std::io::Result<()> {
    stream.set_nodelay(true).ok();
    // Bounded reads so a connected-but-idle client cannot pin this
    // thread past a server shutdown.
    stream.set_read_timeout(Some(Duration::from_millis(100)))?;
    let writer_stream = stream.try_clone()?;
    let expected = Arc::new(AtomicUsize::new(0));
    let done = Arc::new(AtomicBool::new(false));

    let writer = {
        let expected = expected.clone();
        let done = done.clone();
        let stop = stop.clone();
        std::thread::spawn(move || {
            let mut out = writer_stream;
            let mut written = 0usize;
            loop {
                match responses.recv_timeout(Duration::from_millis(50)) {
                    Some(resp) => {
                        let mut line = resp.to_string();
                        line.push('\n');
                        if out.write_all(line.as_bytes()).is_err() {
                            return; // client gone; reader will see EOF
                        }
                        written += 1;
                    }
                    None => {
                        let finished =
                            done.load(Ordering::Acquire) && written >= expected.load(Ordering::Acquire);
                        if finished || stop.load(Ordering::Acquire) {
                            // Best-effort drain of responses that raced in.
                            while let Some(resp) = responses.try_recv() {
                                let mut line = resp.to_string();
                                line.push('\n');
                                let _ = out.write_all(line.as_bytes());
                            }
                            return;
                        }
                    }
                }
            }
        })
    };

    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        match reader.read_line(&mut line) {
            Ok(0) => break, // EOF — client closed its write side
            Ok(_) => {
                if session.submit(line.trim_end()) {
                    expected.fetch_add(1, Ordering::Release);
                }
                line.clear();
            }
            Err(e)
                if matches!(
                    e.kind(),
                    ErrorKind::WouldBlock | ErrorKind::TimedOut | ErrorKind::Interrupted
                ) =>
            {
                // Idle poll tick (a partial line, if any, stays
                // accumulated in `line`); only a shutdown ends the
                // connection early.
                if stop.load(Ordering::Acquire) {
                    break;
                }
            }
            Err(_) => break,
        }
    }
    done.store(true, Ordering::Release);
    drop(session); // frees the client slot; in-flight responses still drain
    let _ = writer.join();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::SocketAddr;

    fn start_server(
        workers: usize,
        transport: TransportKind,
        frontend: FrontendConfig,
    ) -> (SocketAddr, Arc<AtomicBool>, std::thread::JoinHandle<()>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let stop = Arc::new(AtomicBool::new(false));
        let opts = ServeOpts { workers, transport, mesh: false, frontend };
        let server = {
            let stop = stop.clone();
            std::thread::spawn(move || run(listener, &opts, &stop).unwrap())
        };
        (addr, stop, server)
    }

    fn read_json_line(reader: &mut BufReader<TcpStream>) -> Json {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        Json::parse(line.trim_end()).unwrap()
    }

    /// Two concurrent TCP clients each write a pipelined burst (no
    /// interleaved reads), then collect their responses and match them
    /// by `id`: out-of-order completion is allowed, lost or duplicated
    /// responses are not.
    #[test]
    fn tcp_pipelined_burst_matches_ids() {
        let (addr, stop, server) =
            start_server(2, TransportKind::Ring, FrontendConfig::default());
        let n = 10usize;
        let clients: Vec<_> = (0..2u64)
            .map(|c| {
                std::thread::spawn(move || {
                    let mut conn = TcpStream::connect(addr).unwrap();
                    conn.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
                    let mut reader = BufReader::new(conn.try_clone().unwrap());
                    for i in 0..n {
                        let key = c * 1000 + i as u64;
                        writeln!(
                            conn,
                            "{{\"id\":{i},\"cmd\":\"insert\",\"key\":{key},\"data\":[{c}.0,{i}.0]}}"
                        )
                        .unwrap();
                    }
                    let mut seen = vec![false; n];
                    for _ in 0..n {
                        let resp = read_json_line(&mut reader);
                        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp}");
                        let id = resp.get("id").and_then(|i| i.as_u64()).unwrap() as usize;
                        assert!(!seen[id], "duplicate response for id {id}");
                        seen[id] = true;
                    }
                    assert!(seen.iter().all(|&s| s), "client {c} missing responses");
                    // Read-back through the same pipe: every inserted key
                    // is visible with its exact record.
                    for i in 0..n {
                        let key = c * 1000 + i as u64;
                        writeln!(conn, "{{\"id\":{i},\"cmd\":\"get\",\"key\":{key}}}").unwrap();
                    }
                    let mut got = vec![None; n];
                    for _ in 0..n {
                        let resp = read_json_line(&mut reader);
                        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp}");
                        let id = resp.get("id").and_then(|i| i.as_u64()).unwrap() as usize;
                        got[id] = resp.get("data").and_then(|d| d.as_f32_vec());
                    }
                    for (i, data) in got.into_iter().enumerate() {
                        assert_eq!(data.unwrap(), vec![c as f32, i as f32]);
                    }
                })
            })
            .collect();
        for c in clients {
            c.join().unwrap();
        }
        stop.store(true, Ordering::Release);
        server.join().unwrap();
    }

    /// `stats` over the socket includes the front-end telemetry block.
    #[test]
    fn tcp_stats_exposes_frontend_block() {
        let (addr, stop, server) =
            start_server(1, TransportKind::Shm, FrontendConfig::default());
        let mut conn = TcpStream::connect(addr).unwrap();
        conn.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        writeln!(conn, "{{\"cmd\":\"insert\",\"key\":1,\"data\":[4.0]}}").unwrap();
        let resp = read_json_line(&mut reader);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp}");
        writeln!(conn, "{{\"cmd\":\"stats\"}}").unwrap();
        let stats = read_json_line(&mut reader);
        assert_eq!(stats.get("ok"), Some(&Json::Bool(true)), "{stats}");
        let fe = stats.get("frontend").expect("frontend telemetry block");
        assert_eq!(fe.get("submitted").and_then(|v| v.as_u64()), Some(1), "{stats}");
        assert_eq!(fe.get("clients").and_then(|v| v.as_u64()), Some(1), "{stats}");
        let mesh = stats.get("mesh").expect("mesh telemetry block");
        assert_eq!(mesh.get("enabled"), Some(&Json::Bool(false)), "{stats}");
        drop(conn);
        stop.store(true, Ordering::Release);
        server.join().unwrap();
    }

    /// Past `max_clients`, a new connection gets one JSON error line and
    /// is closed; once a slot frees, new connections serve normally.
    #[test]
    fn tcp_refuses_past_max_clients_then_recovers() {
        let (addr, stop, server) = start_server(
            1,
            TransportKind::Ring,
            FrontendConfig { max_clients: 1, ..Default::default() },
        );
        let mut first = TcpStream::connect(addr).unwrap();
        first.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let mut first_reader = BufReader::new(first.try_clone().unwrap());
        // A served round-trip proves `first` holds the one client slot
        // before any refusal is asserted.
        writeln!(first, "{{\"cmd\":\"insert\",\"key\":1,\"data\":[1.0]}}").unwrap();
        let resp = read_json_line(&mut first_reader);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp}");
        let mut refused_reader = loop {
            let conn = TcpStream::connect(addr).unwrap();
            conn.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
            let mut reader = BufReader::new(conn);
            let mut line = String::new();
            if reader.read_line(&mut line).unwrap_or(0) > 0 {
                let resp = Json::parse(line.trim_end()).unwrap();
                if resp.get("error").and_then(|e| e.as_str()).is_some_and(|e| e.contains("capacity"))
                {
                    break reader;
                }
            }
            std::thread::sleep(Duration::from_millis(20));
        };
        // The refused connection was closed server-side: EOF, not a hang.
        let mut rest = String::new();
        assert_eq!(refused_reader.read_line(&mut rest).unwrap_or(0), 0);
        // Freeing the slot readmits: the server notices the first
        // client's EOF within its read-poll tick.
        drop(first_reader);
        drop(first);
        let mut served = false;
        for _ in 0..100 {
            let mut conn = TcpStream::connect(addr).unwrap();
            conn.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
            let mut reader = BufReader::new(conn.try_clone().unwrap());
            writeln!(conn, "{{\"cmd\":\"insert\",\"key\":9,\"data\":[1.0]}}").unwrap();
            let mut line = String::new();
            if reader.read_line(&mut line).unwrap_or(0) > 0 {
                let resp = Json::parse(line.trim_end()).unwrap();
                if resp.get("ok") == Some(&Json::Bool(true)) {
                    served = true;
                    break;
                }
            }
            std::thread::sleep(Duration::from_millis(20));
        }
        assert!(served, "freed client slot never readmitted");
        stop.store(true, Ordering::Release);
        server.join().unwrap();
    }
}
