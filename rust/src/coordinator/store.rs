//! The target-side record store — the "database that stores voice
//! recordings" of the paper's §3.2 usage example.
//!
//! Injected code reaches it through the `db_insert` GOT symbol (the
//! `db_handler dbh = target_args` of Listing 1.3): after the ifunc's
//! compute step decodes the payload in place, it calls
//! `db_insert(key, payload_f32_offset, n_elems)`.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::ifunc::{Symbols, TargetArgs};

use super::worker::GET_MISSING;

/// Concurrent keyed store of f32 records.
#[derive(Default)]
pub struct RecordStore {
    records: RwLock<HashMap<u64, Vec<f32>>>,
    pub inserts: AtomicU64,
}

impl RecordStore {
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    pub fn insert(&self, key: u64, data: Vec<f32>) {
        self.records.write().unwrap().insert(key, data);
        self.inserts.fetch_add(1, Ordering::Relaxed);
    }

    pub fn get(&self, key: u64) -> Option<Vec<f32>> {
        self.records.read().unwrap().get(&key).cloned()
    }

    pub fn len(&self) -> usize {
        self.records.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn keys(&self) -> Vec<u64> {
        self.records.read().unwrap().keys().copied().collect()
    }

    /// Fold over a record without cloning (worker-local analytics).
    pub fn with_record<R>(&self, key: u64, f: impl FnOnce(&[f32]) -> R) -> Option<R> {
        self.records.read().unwrap().get(&key).map(|v| f(v))
    }
}

/// Install the store-backed symbols on a context's symbol table:
///
/// * `db_insert(key, off, n)` — decode `n` f32s at payload byte offset
///   `off` and insert them under `key`,
/// * `db_get(key)` — look `key` up and push the record's bytes into the
///   current invocation's **reply payload** — whatever its size: the
///   reply path chunks payloads past one frame, so a record is never too
///   big to return — with the element count in `r0`, or [`GET_MISSING`]
///   when the key is absent. The record the sender reads back is produced
///   *by the injected function on the worker*; there is no leader-side
///   store access and no shared result region,
/// * `db_filter(threshold_bits)` — **shard-local analytics** for the
///   collective invocation path: scan every record this worker owns and,
///   for each whose first element is ≥ the f32 threshold (passed as its
///   raw bit pattern), push `[key u64][first f32]` (12 bytes) into the
///   reply payload, key-ordered; `r0` = match count. Injected on every
///   worker via `invoke_all`, each shard filters only its own records
///   and the leader merges the per-worker matches — scatter-gather where
///   the filter moves to the data.
pub fn install_db_symbols(symbols: &Symbols, store: Arc<RecordStore>) {
    let s = store.clone();
    let f = store.clone();
    symbols.install_fn("db_insert", move |ctx, [key, off, n, _]| {
        let off = off as usize;
        let n = n as usize;
        let end = off + n * 4;
        if end > ctx.payload.len() {
            return Err(format!("db_insert: f32[{n}] at {off} outside payload"));
        }
        let data: Vec<f32> = ctx.payload[off..end]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        s.insert(key, data);
        Ok(0)
    });
    symbols.install_fn("db_get", move |ctx, [key, _, _, _]| {
        match store.get(key) {
            None => Ok(GET_MISSING),
            Some(data) => {
                let ta = ctx.user.downcast_mut::<TargetArgs>().ok_or_else(|| {
                    "db_get: target args are not ifunc TargetArgs".to_string()
                })?;
                let mut bytes = Vec::with_capacity(data.len() * 4);
                for v in &data {
                    bytes.extend_from_slice(&v.to_le_bytes());
                }
                ta.push_reply(&bytes);
                Ok(data.len() as u64)
            }
        }
    });
    symbols.install_fn("db_filter", move |ctx, [threshold_bits, _, _, _]| {
        let threshold = f32::from_bits(threshold_bits as u32);
        let ta = ctx.user.downcast_mut::<TargetArgs>().ok_or_else(|| {
            "db_filter: target args are not ifunc TargetArgs".to_string()
        })?;
        // Key order makes the shard's match list deterministic, so the
        // leader-side merge (and the tests) never depend on hash-map
        // iteration order.
        let mut keys = f.keys();
        keys.sort_unstable();
        let mut matches = 0u64;
        let mut bytes = Vec::new();
        for key in keys {
            let hit = f.with_record(key, |r| r.first().is_some_and(|v| *v >= threshold));
            if hit == Some(true) {
                let first = f.with_record(key, |r| r[0]).unwrap_or_default();
                bytes.extend_from_slice(&key.to_le_bytes());
                bytes.extend_from_slice(&first.to_le_bytes());
                matches += 1;
            }
        }
        ta.push_reply(&bytes);
        Ok(matches)
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_get_roundtrip() {
        let s = RecordStore::new();
        s.insert(7, vec![1.0, 2.0]);
        assert_eq!(s.get(7), Some(vec![1.0, 2.0]));
        assert_eq!(s.len(), 1);
        assert!(s.get(8).is_none());
    }

    #[test]
    fn with_record_avoids_clone() {
        let s = RecordStore::new();
        s.insert(1, vec![2.0; 10]);
        let sum = s.with_record(1, |r| r.iter().sum::<f32>()).unwrap();
        assert_eq!(sum, 20.0);
    }
}
