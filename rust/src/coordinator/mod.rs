//! L3 coordinator — host-to-device compute dispatch.
//!
//! The paper's motivation (§1): "we envision the API being used to
//! dispatch user functions from a host CPU to a SmartNIC (DPU),
//! computational storage drive (CSD), or remote servers ... it may be
//! more efficient to dynamically choose where code runs as the
//! application progresses."
//!
//! A [`Cluster`] is a leader (host) plus N workers (the DPU/CSD
//! processes), all on the simulated fabric. Each worker owns a
//! [`RecordStore`] and a receive thread; the leader's [`Dispatcher`]
//! routes messages *to where the data lives* (hash placement by record
//! key) over a per-worker [`crate::ifunc::IfuncTransport`] link selected
//! by [`ClusterConfig::transport`] — RDMA-PUT rings (§3), AM
//! send-receive (§5.1), or intra-node shared memory for colocated
//! workers (§1's DPU/CSD on the host: same ring protocol, delivered by
//! memcpy, signalled by process-shared atomics). Each link carries a
//! payload-carrying reply frame
//! ring with **no reply-size cap**: payloads past one frame stream as
//! chunked frame sequences reassembled leader-side
//! ([`ClusterConfig::stream_replies`]). [`Dispatcher::invoke_begin`]
//! pipelines up to [`ClusterConfig::max_inflight`] invocations per worker
//! and [`PendingReply::wait`] collects `(status, r0, payload)`; batched
//! fire-and-forget delivery goes through
//! [`Dispatcher::inject_batch_by_key`]; [`Dispatcher::barrier`] waits on
//! per-worker consumed-frame counters.

pub mod apps;
pub mod dispatcher;
pub mod store;
pub mod telemetry;
pub mod worker;

pub use apps::{DecodeInsertIfunc, GetIfunc, InsertIfunc};
pub use dispatcher::{route_key, Dispatcher, PendingReply};
pub use store::{install_db_symbols, RecordStore};
pub use telemetry::{ClusterSnapshot, ContextSnapshot};
pub use worker::{WorkerHandle, WorkerStats, GET_MISSING};

pub use crate::ifunc::TransportKind;

use std::sync::Arc;

use crate::fabric::{Fabric, WireConfig};
use crate::ucp::{Context, ContextConfig, Worker as UcpWorker};
use crate::Result;

/// Cluster-wide configuration.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// Number of device-side workers (the paper's DPUs/CSDs).
    pub workers: usize,
    /// ifunc ring bytes per worker (ring transport only).
    pub ring_bytes: usize,
    /// How frames travel leader → worker.
    pub transport: TransportKind,
    /// Max outstanding invocations per worker link
    /// ([`Dispatcher::invoke_begin`] blocks past this). Clamped to
    /// `1..=REPLY_SLOTS` so reply-frame laps can never outrun readers.
    pub max_inflight: usize,
    /// How long a reply wait (`invoke`, `PendingReply::wait`, `barrier`)
    /// spins before surfacing `Error::Transport` with the worker index —
    /// a dead worker mid-invoke fails the leader instead of hanging it.
    /// `None` waits forever.
    pub reply_timeout: Option<std::time::Duration>,
    /// Stream reply payloads larger than one reply frame as chunked
    /// multi-frame sequences (default). When off, the link runs the
    /// legacy one-frame-per-reply protocol: big payloads come back as
    /// `STATUS_OVERFLOW` with only `r0`, and every send is lap-guarded
    /// against uncollected replies — kept so the ablation benches can
    /// measure old vs new.
    pub stream_replies: bool,
    pub wire: WireConfig,
    pub ctx: ContextConfig,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            workers: 2,
            ring_bytes: 4 << 20,
            transport: TransportKind::Ring,
            max_inflight: 16,
            reply_timeout: Some(std::time::Duration::from_secs(10)),
            stream_replies: true,
            wire: WireConfig::off(),
            ctx: ContextConfig::default(),
        }
    }
}

/// A running leader + worker-pool deployment.
pub struct Cluster {
    pub fabric: Arc<Fabric>,
    pub leader: Arc<Context>,
    pub leader_worker: Arc<UcpWorker>,
    pub workers: Vec<WorkerHandle>,
}

impl Cluster {
    /// Boot the cluster. `setup` runs once per worker before its poll loop
    /// starts: install application symbols on the worker's context and
    /// return the application state its `target_args` will carry
    /// (the worker's [`RecordStore`] is always installed and passed in).
    pub fn launch(
        config: ClusterConfig,
        setup: impl Fn(usize, &Arc<Context>, &Arc<RecordStore>),
    ) -> Result<Cluster> {
        // Node 0 = leader/host; nodes 1..=N = device workers.
        let fabric = Fabric::new(config.workers + 1, config.wire);
        let leader = Context::new(fabric.node(0), config.ctx.clone())?;
        let leader_worker = UcpWorker::new(&leader);
        let mut workers = Vec::with_capacity(config.workers);
        for i in 0..config.workers {
            let ctx = Context::new(fabric.node(i + 1), config.ctx.clone())?;
            let store = RecordStore::new();
            install_db_symbols(ctx.symbols(), store.clone());
            setup(i, &ctx, &store);
            workers.push(WorkerHandle::spawn(
                i,
                ctx,
                store,
                &leader,
                &leader_worker,
                &config,
            )?);
        }
        Ok(Cluster { fabric, leader, leader_worker, workers })
    }

    /// Create a dispatcher bound to this cluster's workers.
    pub fn dispatcher(&self) -> Dispatcher<'_> {
        Dispatcher::new(self)
    }

    /// Stop all poll loops and join worker threads.
    pub fn shutdown(mut self) -> Result<()> {
        for w in &mut self.workers {
            w.stop()?;
        }
        Ok(())
    }

    pub fn num_workers(&self) -> usize {
        self.workers.len()
    }
}
