//! L3 coordinator — host-to-device compute dispatch.
//!
//! The paper's motivation (§1): "we envision the API being used to
//! dispatch user functions from a host CPU to a SmartNIC (DPU),
//! computational storage drive (CSD), or remote servers ... it may be
//! more efficient to dynamically choose where code runs as the
//! application progresses."
//!
//! A [`Cluster`] is a leader (host) plus N workers (the DPU/CSD
//! processes), all on the simulated fabric. Each worker owns a
//! [`RecordStore`] and a receive thread; the leader's [`Dispatcher`]
//! routes messages *to where the data lives* (hash placement by record
//! key) over a per-worker [`crate::ifunc::IfuncTransport`] link selected
//! by [`ClusterConfig::transport`] — RDMA-PUT rings (§3), AM
//! send-receive (§5.1), or intra-node shared memory for colocated
//! workers (§1's DPU/CSD on the host: same ring protocol, delivered by
//! memcpy, signalled by process-shared atomics). Each link carries a
//! payload-carrying reply frame
//! ring with **no reply-size cap**: payloads past one frame stream as
//! chunked frame sequences reassembled leader-side
//! ([`ClusterConfig::stream_replies`]). [`Dispatcher::invoke_begin`]
//! pipelines up to [`ClusterConfig::max_inflight`] invocations per worker
//! and [`PendingReply::wait`] collects `(status, r0, payload)`; batched
//! fire-and-forget delivery goes through [`Dispatcher::scatter`];
//! [`Dispatcher::barrier`] waits on per-worker consumed-frame counters.
//!
//! Every entry point routes through one [`Target`] vocabulary —
//! `Worker(n)` / `Key(u64)` / `Set(&[usize])` / `All` — and the
//! collective targets realize the paper's **closing motivation** ("data
//! set so big that it has to be stored on many physical devices"):
//! [`Dispatcher::invoke_multi`] / [`Dispatcher::invoke_all`] inject one
//! program, fan the frame out across the worker set with one flush pass
//! (per-link transfers overlapping), and merge the per-worker replies
//! through [`MultiPendingReply`] — scatter-gather where the code moves
//! to every shard of the data and only results travel back.
//!
//! The per-worker outbound machinery — transport, invocation window,
//! reply ring/collector, consumed counter — lives in the peer-generic
//! [`link`] layer: a [`PeerLink`] is *one node's sending half of a
//! channel to one peer*, and the [`Dispatcher`] is only a routing and
//! collective facade over the leader's links. The same [`PeerLink`] type
//! wires the optional worker↔worker **mesh** ([`ClusterConfig::mesh`]):
//! every worker owns outbound links to its peers, and the `forward`
//! host symbol lets a running invocation continue on another worker —
//! the paper's "dynamically choose where code runs as the application
//! progresses" realized *device-side*, without bouncing intermediate
//! results through the host. Hop metadata in the frame header (origin
//! seq/worker, hop count, TTL) routes the chain's final reply back to
//! the origin's leader-facing reply stream under the seq the leader
//! registered at injection, so a multi-hop chain collects like a local
//! invocation; a broken chain (TTL out, dead peer) degrades to a FAILED
//! reply whose `r0` names the failure site
//! ([`link::decode_forward_failure`]) instead of a hang.
//!
//! On top of the dispatcher sits the concurrent serve front-end
//! ([`frontend::Frontend`]) — the §3.2 database scenario under
//! concurrent multi-client load: pipelined per-client sessions (bounded
//! in-flight windows, out-of-order completion keyed by a client `id`),
//! cross-client coalescing of same-worker operations into
//! [`Dispatcher::try_invoke_batch`] batches (one credit reservation +
//! one flush amortized across clients), and admission control that
//! sheds with a `retry: true` overload response *before* any blocking
//! wait, with round-robin draining so no client starves another.

pub mod apps;
pub mod dispatcher;
pub mod frontend;
pub mod link;
pub mod store;
pub mod telemetry;
pub mod worker;

pub use apps::{DecodeInsertIfunc, FilterIfunc, GetIfunc, InsertIfunc};
pub use dispatcher::{route_key, Dispatcher, MultiPendingReply, MultiReply, Target};
pub use link::{decode_forward_failure, encode_forward_failure, PeerLink, PendingReply};
pub use frontend::{Frontend, FrontendConfig, FrontendStats, Session, SessionReceiver};
pub use store::{install_db_symbols, RecordStore};
pub use telemetry::{ClusterSnapshot, ContextSnapshot, FrontendSnapshot, WorkerSnapshot};
pub use worker::{WorkerHandle, WorkerStats, GET_MISSING};

pub use crate::ifunc::TransportKind;

use std::sync::Arc;
use std::time::Duration;

use crate::fabric::{Fabric, WireConfig};
use crate::ifunc::REPLY_SLOTS;
use crate::ucp::{Context, ContextConfig, Worker as UcpWorker};
use crate::{Error, Result};

/// Cluster-wide configuration.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// Number of device-side workers (the paper's DPUs/CSDs).
    pub workers: usize,
    /// ifunc ring bytes per worker (ring transport only).
    pub ring_bytes: usize,
    /// How frames travel leader → worker.
    pub transport: TransportKind,
    /// Max outstanding invocations per worker link
    /// ([`Dispatcher::invoke_begin`] blocks past this). Clamped to
    /// `1..=REPLY_SLOTS` so reply-frame laps can never outrun readers.
    pub max_inflight: usize,
    /// How long a reply wait (`invoke`, `PendingReply::wait`, `barrier`)
    /// spins before surfacing `Error::Transport` with the worker index —
    /// a dead worker mid-invoke fails the leader instead of hanging it.
    /// `None` waits forever.
    pub reply_timeout: Option<std::time::Duration>,
    /// Stream reply payloads larger than one reply frame as chunked
    /// multi-frame sequences (default). When off, the link runs the
    /// legacy one-frame-per-reply protocol: big payloads come back as
    /// `STATUS_OVERFLOW` with only `r0`, and every send is lap-guarded
    /// against uncollected replies — kept so the ablation benches can
    /// measure old vs new.
    pub stream_replies: bool,
    /// Wire a worker↔worker mesh (one [`PeerLink`] per ordered worker
    /// pair over the cluster's transport kind) and start a mesh receive
    /// thread per worker, enabling the `forward` host symbol. Requires
    /// `stream_replies`: relayed chain replies land in the origin's
    /// leader-facing stream out of order, which only the streamed
    /// collector protocol reassembles.
    pub mesh: bool,
    pub wire: WireConfig,
    pub ctx: ContextConfig,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            workers: 2,
            ring_bytes: 4 << 20,
            transport: TransportKind::Ring,
            max_inflight: 16,
            reply_timeout: Some(std::time::Duration::from_secs(10)),
            stream_replies: true,
            mesh: false,
            wire: WireConfig::off(),
            ctx: ContextConfig::default(),
        }
    }
}

impl ClusterConfig {
    /// A validating builder seeded from [`ClusterConfig::default`].
    /// Prefer it over struct literals: `build()` rejects configurations
    /// the literal form silently accepts (or silently *repairs* — the
    /// worker spawn clamps `max_inflight` into `1..=REPLY_SLOTS`, which
    /// the builder surfaces as an error instead).
    pub fn builder() -> ClusterConfigBuilder {
        ClusterConfigBuilder { config: ClusterConfig::default() }
    }
}

/// Builder for [`ClusterConfig`] — see [`ClusterConfig::builder`].
#[derive(Clone, Debug)]
pub struct ClusterConfigBuilder {
    config: ClusterConfig,
}

impl ClusterConfigBuilder {
    /// Number of device-side workers. Zero is rejected by `build()`.
    pub fn workers(mut self, n: usize) -> Self {
        self.config.workers = n;
        self
    }

    /// ifunc ring bytes per worker (ring/shm transports).
    pub fn ring_bytes(mut self, bytes: usize) -> Self {
        self.config.ring_bytes = bytes;
        self
    }

    /// How frames travel leader → worker.
    pub fn transport(mut self, t: TransportKind) -> Self {
        self.config.transport = t;
        self
    }

    /// Max outstanding invocations per worker link. Must stay within
    /// `1..=REPLY_SLOTS`; out-of-range values are rejected by `build()`
    /// rather than clamped.
    pub fn max_inflight(mut self, n: usize) -> Self {
        self.config.max_inflight = n;
        self
    }

    /// Progress timeout for reply/barrier/credit waits. Must be
    /// non-zero; use [`ClusterConfigBuilder::no_reply_timeout`] to wait
    /// forever.
    pub fn reply_timeout(mut self, d: Duration) -> Self {
        self.config.reply_timeout = Some(d);
        self
    }

    /// Wait forever on replies, barriers, and ring credit (no deadline).
    pub fn no_reply_timeout(mut self) -> Self {
        self.config.reply_timeout = None;
        self
    }

    /// Stream reply payloads larger than one reply frame (default on).
    pub fn stream_replies(mut self, on: bool) -> Self {
        self.config.stream_replies = on;
        self
    }

    /// Wire the worker↔worker mesh and enable the `forward` host symbol
    /// (default off). Requires streamed replies; `build()` rejects
    /// `mesh(true)` + `stream_replies(false)`.
    pub fn mesh(mut self, on: bool) -> Self {
        self.config.mesh = on;
        self
    }

    /// Wire-cost model for the emulated fabric.
    pub fn wire(mut self, wire: WireConfig) -> Self {
        self.config.wire = wire;
        self
    }

    /// Per-context configuration (library dir, icache, caches).
    pub fn ctx(mut self, ctx: ContextConfig) -> Self {
        self.config.ctx = ctx;
        self
    }

    /// Validate and produce the config.
    pub fn build(self) -> Result<ClusterConfig> {
        let c = self.config;
        if c.workers == 0 {
            return Err(Error::Other(
                "ClusterConfig: zero workers — a cluster needs at least one device worker"
                    .into(),
            ));
        }
        if c.max_inflight == 0 {
            return Err(Error::Other(
                "ClusterConfig: max_inflight 0 would deadlock every invocation; use 1+"
                    .into(),
            ));
        }
        if c.max_inflight > REPLY_SLOTS {
            return Err(Error::Other(format!(
                "ClusterConfig: max_inflight {} exceeds REPLY_SLOTS {REPLY_SLOTS} — the \
                 reply ring cannot hold that many uncollected replies (the struct-literal \
                 path silently clamps; the builder refuses)",
                c.max_inflight
            )));
        }
        if c.mesh && !c.stream_replies {
            return Err(Error::Other(
                "ClusterConfig: mesh requires stream_replies — relayed chain replies \
                 arrive out of order and only the streamed collector reassembles them"
                    .into(),
            ));
        }
        if c.reply_timeout == Some(Duration::ZERO) {
            return Err(Error::Other(
                "ClusterConfig: zero reply_timeout would expire every wait immediately; \
                 use no_reply_timeout() to wait forever"
                    .into(),
            ));
        }
        Ok(c)
    }
}

/// A running leader + worker-pool deployment.
pub struct Cluster {
    pub fabric: Arc<Fabric>,
    pub leader: Arc<Context>,
    pub leader_worker: Arc<UcpWorker>,
    pub workers: Vec<WorkerHandle>,
    /// Whether the worker↔worker mesh is wired ([`ClusterConfig::mesh`]).
    pub mesh: bool,
}

impl Cluster {
    /// Boot the cluster. `setup` runs once per worker before its poll loop
    /// starts: install application symbols on the worker's context and
    /// return the application state its `target_args` will carry
    /// (the worker's [`RecordStore`] is always installed and passed in).
    pub fn launch(
        config: ClusterConfig,
        setup: impl Fn(usize, &Arc<Context>, &Arc<RecordStore>),
    ) -> Result<Cluster> {
        if config.mesh && !config.stream_replies {
            return Err(Error::Other(
                "ClusterConfig: mesh requires stream_replies (see ClusterConfig::builder)"
                    .into(),
            ));
        }
        // Node 0 = leader/host; nodes 1..=N = device workers.
        let fabric = Fabric::new(config.workers + 1, config.wire);
        let leader = Context::new(fabric.node(0), config.ctx.clone())?;
        let leader_worker = UcpWorker::new(&leader);
        // Phase 1: build every worker's context + leader link (no threads
        // yet — the receive loops must know their mesh links first).
        let mut boots = Vec::with_capacity(config.workers);
        for i in 0..config.workers {
            let ctx = Context::new(fabric.node(i + 1), config.ctx.clone())?;
            let store = RecordStore::new();
            install_db_symbols(ctx.symbols(), store.clone());
            setup(i, &ctx, &store);
            boots.push(worker::WorkerBoot::build(
                i,
                ctx,
                store,
                &leader,
                &leader_worker,
                &config,
            )?);
        }
        // Phase 2: with all contexts alive, wire the worker↔worker mesh
        // pairwise (the same PeerLink/channel shape as the leader links).
        let mut mesh = if config.mesh {
            worker::build_mesh(&boots, &config)?.into_iter().map(Some).collect()
        } else {
            (0..config.workers).map(|_| None).collect::<Vec<_>>()
        };
        // Phase 3: start receive threads, each holding its mesh half.
        let mut workers = Vec::with_capacity(config.workers);
        for (i, boot) in boots.into_iter().enumerate() {
            workers.push(boot.start(mesh[i].take())?);
        }
        Ok(Cluster { fabric, leader, leader_worker, workers, mesh: config.mesh })
    }

    /// Create a dispatcher bound to this cluster's workers.
    pub fn dispatcher(&self) -> Dispatcher<'_> {
        Dispatcher::new(self)
    }

    /// Stop all poll loops and join worker threads.
    pub fn shutdown(mut self) -> Result<()> {
        for w in &mut self.workers {
            w.stop()?;
        }
        Ok(())
    }

    pub fn num_workers(&self) -> usize {
        self.workers.len()
    }
}
