//! Leader-side dispatcher: moves compute to data.
//!
//! Implements the paper's §1 use case for "large-scale irregular
//! applications ... operating on a data set so big that it has to be
//! stored on many physical devices": records are placed on workers by key
//! hash, and every injected function targeting a key is routed to the
//! worker that owns it — the code moves, the data does not.

use crate::ifunc::{IfuncHandle, IfuncMsg, SourceArgs};
use crate::{Error, Result};

use super::Cluster;

pub struct Dispatcher<'c> {
    cluster: &'c Cluster,
}

impl<'c> Dispatcher<'c> {
    pub(crate) fn new(cluster: &'c Cluster) -> Self {
        Dispatcher { cluster }
    }

    /// Deterministic key → worker placement (the locality map).
    pub fn route_key(&self, key: u64) -> usize {
        // Fibonacci hashing: uniform over workers, stable across runs.
        (key.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 32) as usize
            % self.cluster.workers.len()
    }

    /// Register an ifunc on the leader (source side).
    pub fn register(&self, name: &str) -> Result<IfuncHandle> {
        self.cluster.leader.register_ifunc(name)
    }

    /// Inject a prebuilt message to a specific worker (flow-controlled,
    /// non-blocking delivery; completion via [`Dispatcher::flush`]).
    pub fn send_to(&self, worker: usize, msg: &IfuncMsg) -> Result<()> {
        let w = self
            .cluster
            .workers
            .get(worker)
            .ok_or_else(|| Error::Other(format!("no worker {worker}")))?;
        let mut link = w.link.lock().unwrap();
        link.wait_capacity(msg.len());
        let placement = link.cursor.place(msg.len())?;
        if let Some(at) = placement.wrap_marker_at {
            // The wrap consumes the ring tail through the marker.
            link.ep.put_nbi(
                link.ring_rkey,
                at,
                &crate::ifunc::ring::wrap_marker_word().to_le_bytes(),
            )?;
            link.sent_bytes += (link.ring_bytes - at) as u64;
        }
        link.ep.put_nbi(link.ring_rkey, placement.offset, msg.frame())?;
        link.sent_bytes += msg.len() as u64;
        Ok(())
    }

    /// Create + route + send in one call: the payload goes to the worker
    /// owning `key`.
    pub fn inject_by_key(
        &self,
        handle: &IfuncHandle,
        key: u64,
        args: &SourceArgs,
    ) -> Result<usize> {
        let worker = self.route_key(key);
        let msg = handle.msg_create(args)?;
        self.send_to(worker, &msg)?;
        Ok(worker)
    }

    /// Flush delivery to every worker.
    pub fn flush(&self) -> Result<()> {
        for w in &self.cluster.workers {
            w.link.lock().unwrap().ep.flush()?;
        }
        Ok(())
    }

    /// Block until every worker has consumed everything sent so far.
    pub fn barrier(&self) -> Result<()> {
        self.flush()?;
        for w in &self.cluster.workers {
            let link = w.link.lock().unwrap();
            let sent = link.sent_bytes;
            let mut i = 0u32;
            while link.credit.load_u64_acquire(0)? < sent {
                crate::fabric::wire::backoff(i);
                i += 1;
            }
        }
        Ok(())
    }

    /// Total messages executed across workers.
    pub fn total_executed(&self) -> u64 {
        self.cluster.workers.iter().map(|w| w.executed()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::super::{Cluster, ClusterConfig};
    use crate::ifunc::builtin::CounterIfunc;
    use crate::ifunc::SourceArgs;

    #[test]
    fn dispatch_counter_to_all_workers() {
        let cluster = Cluster::launch(
            ClusterConfig { workers: 3, ..Default::default() },
            |_, ctx, _| {
                ctx.library_dir().install(Box::new(CounterIfunc::default()));
            },
        )
        .unwrap();
        // The leader is the source: its library dir needs the ifunc too.
        cluster.leader.library_dir().install(Box::new(CounterIfunc::default()));
        let d = cluster.dispatcher();
        let h = d.register("counter").unwrap();
        let args = SourceArgs::bytes(vec![0u8; 32]);
        for key in 0..60u64 {
            d.inject_by_key(&h, key, &args).unwrap();
        }
        d.barrier().unwrap();
        assert_eq!(d.total_executed(), 60);
        // Fibonacci hashing spreads keys across all 3 workers.
        for w in &cluster.workers {
            assert!(w.executed() > 0, "worker {} got nothing", w.index);
        }
        cluster.shutdown().unwrap();
    }

    #[test]
    fn routing_is_deterministic() {
        let cluster = Cluster::launch(
            ClusterConfig { workers: 4, ..Default::default() },
            |_, _, _| {},
        )
        .unwrap();
        let d = cluster.dispatcher();
        for key in 0..100 {
            assert_eq!(d.route_key(key), d.route_key(key));
            assert!(d.route_key(key) < 4);
        }
        cluster.shutdown().unwrap();
    }

    #[test]
    fn ring_flow_control_survives_overload() {
        // Tiny rings force constant wrap + credit waits.
        let cluster = Cluster::launch(
            ClusterConfig { workers: 1, ring_bytes: 4096, ..Default::default() },
            |_, ctx, _| {
                ctx.library_dir().install(Box::new(CounterIfunc::default()));
            },
        )
        .unwrap();
        cluster.leader.library_dir().install(Box::new(CounterIfunc::default()));
        let d = cluster.dispatcher();
        let h = d.register("counter").unwrap();
        let args = SourceArgs::bytes(vec![0u8; 512]);
        for key in 0..500u64 {
            d.inject_by_key(&h, key, &args).unwrap();
        }
        d.barrier().unwrap();
        assert_eq!(d.total_executed(), 500);
        cluster.shutdown().unwrap();
    }
}
