//! Leader-side dispatcher: moves compute to data.
//!
//! Implements the paper's §1 use case for "large-scale irregular
//! applications ... operating on a data set so big that it has to be
//! stored on many physical devices": records are placed on workers by key
//! hash, and every injected function targeting a key is routed to the
//! worker that owns it — the code moves, the data does not.
//!
//! Delivery is transport-generic: each worker link is an
//! [`crate::ifunc::IfuncTransport`] chosen by `ClusterConfig::transport`
//! (RDMA-PUT ring, AM send-receive, or intra-node shared memory), and
//! every link carries a reply frame ring. Alongside fire-and-forget
//! [`Dispatcher::send_to`] (and its
//! batched forms [`Dispatcher::send_batch_to`] /
//! [`Dispatcher::inject_batch_by_key`]) sits the invocation API:
//! [`Dispatcher::invoke_begin`] injects a frame and returns a
//! [`PendingReply`] handle *without* holding the link across the wait, so
//! up to `ClusterConfig::max_inflight` invocations pipeline per worker;
//! [`PendingReply::wait`] collects `(status, r0, payload)` — the payload
//! pushed by the injected function through `reply_put` / `db_get`, of
//! **any size**: one reply frame when it fits, a reassembled chunk
//! stream when it does not.

use std::collections::BTreeSet;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::ifunc::{
    IfuncHandle, IfuncMsg, Reply, ReplyCollector, ReplyRing, SourceArgs, REPLY_SLOTS,
};
use crate::util::sync::{lock_recover, wait_timeout_recover};
use crate::{Error, Result};

use super::worker::GET_MISSING;
use super::Cluster;

/// Prefix a transport error with the worker it came from — delivery
/// errors (a dead worker's full ring, a lapped reply) surface from deep
/// inside the link, which has no idea which worker index it is.
fn tag_worker(worker: usize, e: Error) -> Error {
    match e {
        Error::Transport(m) => Error::Transport(format!("worker {worker}: {m}")),
        other => other,
    }
}

/// Deterministic key → worker placement (the locality map), as a free
/// function so it can be tested — and reasoned about — without standing up
/// a cluster. Fibonacci hashing: uniform over workers, stable across runs
/// and platforms (no per-process seed).
pub fn route_key(key: u64, n_workers: usize) -> usize {
    (key.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 32) as usize % n_workers.max(1)
}

/// Per-worker-link invocation window.
///
/// On every link it enforces the **count** window: at most `max`
/// invocations outstanding ([`InvokeWindow::acquire`] blocks past it,
/// bounded by `ClusterConfig::reply_timeout`).
///
/// On a **legacy** (non-streamed) link it additionally runs the
/// **seq-distance** admission check on every frame sent — invoke or
/// fire-and-forget — ([`InvokeWindow::admit`]): with one reply frame per
/// ingress frame, reply `T` laps reply `S`'s slot iff `T >= S +
/// REPLY_SLOTS`, so delivery stalls while any uncollected invocation's
/// reply slot would be overwritten. Pure fire-and-forget traffic pays
/// only one relaxed atomic load per send (the `admit` fast path).
///
/// On a **streamed** link that static arithmetic is meaningless — a
/// k-chunk reply occupies k reply seqs, with k data-dependent — so lap
/// protection moves to the reply layer itself: the `ReplyCollector`
/// consumes reply frames in order (sends drive it via drain) and the
/// worker's writer only recycles slots the collector has consumed. An
/// uncollected invocation reply is parked in leader memory, never
/// overwritten in the ring.
pub(crate) struct InvokeWindow {
    max: usize,
    /// `awaiting.len()` mirror for the lock-free admit fast path. Reads
    /// under the link lock are exact: `track` runs before the link lock
    /// is released, so the lock's synchronizes-with edge publishes it.
    awaiting_count: std::sync::atomic::AtomicUsize,
    state: Mutex<WindowState>,
    freed: Condvar,
}

#[derive(Default)]
struct WindowState {
    /// Invocations begun but not yet collected (count window).
    inflight: usize,
    /// Total releases ever — progress evidence for starved `acquire`
    /// waiters (under contention `inflight` can read as pinned at `max`
    /// at every wakeup even while slots turn over continuously).
    releases: u64,
    /// Reply seqs of sent-but-uncollected invocations (lap guard).
    awaiting: BTreeSet<u64>,
}

impl InvokeWindow {
    pub(crate) fn new(max: usize) -> Self {
        InvokeWindow {
            max,
            awaiting_count: std::sync::atomic::AtomicUsize::new(0),
            state: Mutex::new(WindowState::default()),
            freed: Condvar::new(),
        }
    }

    /// Claim an invocation slot; blocks while `max` are outstanding and
    /// errors after `timeout` without progress. Progress is the release
    /// *generation*, not the observed count — under contention the count
    /// can read as pinned at `max` at every wakeup even while slots turn
    /// over, and churn must not be mistaken for a stuck window.
    fn acquire(&self, timeout: Option<Duration>) -> std::result::Result<(), String> {
        let mut st = lock_recover(&self.state);
        let mut deadline = timeout.map(|d| Instant::now() + d);
        let mut last_releases = st.releases;
        loop {
            if st.inflight < self.max {
                st.inflight += 1;
                return Ok(());
            }
            if last_releases != st.releases {
                last_releases = st.releases;
                deadline = timeout.map(|d| Instant::now() + d);
            }
            if let Some(d) = deadline {
                if Instant::now() > d {
                    return Err(format!(
                        "invocation window full ({} outstanding, max_inflight {}); \
                         wait on or drop a PendingReply",
                        st.inflight, self.max
                    ));
                }
            }
            st = wait_timeout_recover(&self.freed, st, Duration::from_millis(1));
        }
    }

    /// Record a begun invocation's reply seq (after its frame was sent).
    fn track(&self, seq: u64) {
        let mut st = lock_recover(&self.state);
        st.awaiting.insert(seq);
        self.awaiting_count.store(st.awaiting.len(), std::sync::atomic::Ordering::Relaxed);
    }

    /// Release one invocation slot; `seq` is its tracked reply seq (None
    /// when the frame never went out).
    fn release(&self, seq: Option<u64>) {
        let mut st = lock_recover(&self.state);
        st.inflight -= 1;
        st.releases += 1;
        if let Some(s) = seq {
            st.awaiting.remove(&s);
            self.awaiting_count.store(st.awaiting.len(), std::sync::atomic::Ordering::Relaxed);
        }
        drop(st);
        self.freed.notify_all();
    }

    /// Block until frames through `end_seq` can be delivered without
    /// lapping any awaited reply (reply `T` overwrites reply `S`'s slot
    /// iff `T >= S + REPLY_SLOTS`). The deadline resets whenever the
    /// oldest awaited seq changes (progress), and expires with a message
    /// naming the blocking invocation. With nothing awaited — all
    /// fire-and-forget traffic — this is one relaxed load, no lock.
    fn admit(&self, end_seq: u64, timeout: Option<Duration>) -> std::result::Result<(), String> {
        if self.awaiting_count.load(std::sync::atomic::Ordering::Relaxed) == 0 {
            return Ok(());
        }
        let mut st = lock_recover(&self.state);
        let mut deadline = timeout.map(|d| Instant::now() + d);
        let mut last_oldest = None;
        loop {
            let Some(&oldest) = st.awaiting.iter().next() else { return Ok(()) };
            if end_seq.saturating_sub(oldest) < REPLY_SLOTS as u64 {
                return Ok(());
            }
            if last_oldest != Some(oldest) {
                last_oldest = Some(oldest);
                deadline = timeout.map(|d| Instant::now() + d);
            }
            if let Some(d) = deadline {
                if Instant::now() > d {
                    return Err(format!(
                        "delivering frame seq {end_seq} would lap the unread reply for \
                         invocation seq {oldest}; wait on or drop its PendingReply"
                    ));
                }
            }
            st = wait_timeout_recover(&self.freed, st, Duration::from_millis(1));
        }
    }
}

/// How a [`PendingReply`] collects its reply: directly off its seq's slot
/// (legacy one-frame-per-reply links) or through the link's shared
/// [`ReplyCollector`] (streamed links, where a reply may span several
/// chunk frames at unpredictable reply seqs).
enum Collect {
    Slot(ReplyRing),
    Stream(Arc<ReplyCollector>),
}

/// A not-yet-collected invocation: records the ingress frame seq at send
/// time and waits for its reply without the link lock, so other
/// invocations (and fire-and-forget sends) proceed concurrently on the
/// same worker. Dropping the handle without waiting releases its window
/// slot (the reply, when it arrives, is simply discarded).
pub struct PendingReply {
    how: Collect,
    seq: u64,
    worker: usize,
    window: Arc<InvokeWindow>,
    released: bool,
}

impl PendingReply {
    /// The frame sequence number this handle waits for (1-based, per link).
    pub fn seq(&self) -> u64 {
        self.seq
    }

    /// The worker index the invocation targeted.
    pub fn worker(&self) -> usize {
        self.worker
    }

    /// Block for the reply — reassembled across chunk frames when the
    /// injected function pushed more than one frame's worth of payload.
    /// A worker that died mid-invoke surfaces as [`Error::Transport`]
    /// naming this worker once `ClusterConfig::reply_timeout` expires
    /// without progress.
    pub fn wait(mut self) -> Result<Reply> {
        let out = match &self.how {
            Collect::Slot(ring) => ring.wait(self.seq),
            Collect::Stream(c) => c.collect(self.seq),
        }
        .map_err(|e| tag_worker(self.worker, e));
        if out.is_err() {
            // A successful collect deregisters; a failed one must not
            // leave the frame awaited forever (its reply — if it ever
            // lands — would be parked with no one to claim it).
            if let Collect::Stream(c) = &self.how {
                c.unregister(self.seq);
            }
        }
        self.released = true;
        self.window.release(Some(self.seq));
        out
    }
}

impl Drop for PendingReply {
    fn drop(&mut self) {
        if !self.released {
            if let Collect::Stream(c) = &self.how {
                c.unregister(self.seq);
            }
            self.window.release(Some(self.seq));
        }
    }
}

pub struct Dispatcher<'c> {
    cluster: &'c Cluster,
}

impl<'c> Dispatcher<'c> {
    pub(crate) fn new(cluster: &'c Cluster) -> Self {
        Dispatcher { cluster }
    }

    /// Deterministic key → worker placement (the locality map).
    pub fn route_key(&self, key: u64) -> usize {
        route_key(key, self.cluster.workers.len())
    }

    /// Register an ifunc on the leader (source side).
    pub fn register(&self, name: &str) -> Result<IfuncHandle> {
        self.cluster.leader.register_ifunc(name)
    }

    fn worker(&self, worker: usize) -> Result<&super::WorkerHandle> {
        self.cluster
            .workers
            .get(worker)
            .ok_or_else(|| Error::Other(format!("no worker {worker}")))
    }

    /// Per-send reply bookkeeping (runs under the link lock). On a
    /// streamed link, drive the reply collector: consuming arrived reply
    /// frames (discarding fire-and-forget ones) is what advances the
    /// worker's slot-recycling credit, so a flood of sends can never
    /// strand an uncollected invocation reply — a k-chunk reply holds
    /// exactly its k slots until the collector has moved it into leader
    /// memory. On a legacy link, run the seq-distance lap guard instead.
    fn admit_or_drain(&self, w: &super::WorkerHandle, worker: usize, end_seq: u64) -> Result<()> {
        match &w.collector {
            Some(c) => c.drain().map_err(|e| tag_worker(worker, e)),
            None => w
                .window
                .admit(end_seq, w.reply_timeout)
                .map_err(|m| Error::Transport(format!("worker {worker}: {m}"))),
        }
    }

    /// Inject a prebuilt message to a specific worker (flow-controlled,
    /// non-blocking delivery; completion via [`Dispatcher::flush`]).
    pub fn send_to(&self, worker: usize, msg: &IfuncMsg) -> Result<()> {
        let w = self.worker(worker)?;
        let mut link = lock_recover(&w.link);
        self.admit_or_drain(w, worker, link.frames_sent() + 1)?;
        link.send_frame(msg).map_err(|e| tag_worker(worker, e))
    }

    /// Deliver a batch of frames to one worker through the transport's
    /// coalesced path (one credit reservation + one flush on the ring;
    /// back-to-back posts + one flush over AM).
    pub fn send_batch_to(&self, worker: usize, msgs: &[IfuncMsg]) -> Result<()> {
        if msgs.is_empty() {
            return Ok(());
        }
        let w = self.worker(worker)?;
        let mut link = lock_recover(&w.link);
        self.admit_or_drain(w, worker, link.frames_sent() + msgs.len() as u64)?;
        link.send_batch(msgs).map_err(|e| tag_worker(worker, e))
    }

    /// Begin an invocation: inject `msg`, record its frame seq, and
    /// release the link immediately. The returned [`PendingReply`] waits
    /// for the reply — chunk-streamed when large — without the link lock,
    /// so up to `ClusterConfig::max_inflight` invocations pipeline per
    /// worker (the call blocks while the window is full).
    pub fn invoke_begin(&self, worker: usize, msg: &IfuncMsg) -> Result<PendingReply> {
        fn send_locked(
            d: &Dispatcher<'_>,
            w: &super::WorkerHandle,
            worker: usize,
            msg: &IfuncMsg,
        ) -> Result<(u64, Collect)> {
            // The link lock covers only delivery; it is released before
            // the reply wait, which is what lets invocations pipeline.
            let mut link = lock_recover(&w.link);
            let seq = link.frames_sent() + 1;
            d.admit_or_drain(w, worker, seq)?;
            match &w.collector {
                Some(c) => {
                    // Register *before* the frame goes out: once it is on
                    // the wire a concurrent drain may meet the reply, and
                    // only registered replies are parked rather than
                    // dropped.
                    c.register(seq);
                    if let Err(e) = link.send_frame(msg).and_then(|()| link.flush()) {
                        c.unregister(seq);
                        return Err(tag_worker(worker, e));
                    }
                    debug_assert_eq!(link.frames_sent(), seq);
                    Ok((seq, Collect::Stream(c.clone())))
                }
                None => {
                    link.send_frame(msg).map_err(|e| tag_worker(worker, e))?;
                    link.flush().map_err(|e| tag_worker(worker, e))?;
                    let seq = link.frames_sent();
                    // Legacy lap guard: remember the awaited reply slot.
                    w.window.track(seq);
                    Ok((seq, Collect::Slot(w.replies.clone())))
                }
            }
        }
        let w = self.worker(worker)?;
        w.window
            .acquire(w.reply_timeout)
            .map_err(|m| Error::Transport(format!("worker {worker}: {m}")))?;
        match send_locked(self, w, worker, msg) {
            Ok((seq, how)) => Ok(PendingReply {
                how,
                seq,
                worker,
                window: w.window.clone(),
                released: false,
            }),
            Err(e) => {
                w.window.release(None);
                Err(e)
            }
        }
    }

    /// Inject a message and block for the injected function's reply frame
    /// — [`Dispatcher::invoke_begin`] + [`PendingReply::wait`] in one
    /// call. `reply.payload` carries whatever the function pushed through
    /// `reply_put` / `db_get`.
    pub fn invoke(&self, worker: usize, msg: &IfuncMsg) -> Result<Reply> {
        self.invoke_begin(worker, msg)?.wait()
    }

    /// [`Dispatcher::invoke`] for record-returning ifuncs (`GetIfunc`):
    /// decodes the reply payload as f32 record elements. The data vec is
    /// empty unless the reply is ok and `r0` is a length (not
    /// [`GET_MISSING`]). Record size does not matter on a streamed link —
    /// big records arrive as reassembled chunk streams; only a
    /// `stream_replies: false` link still reports oversized records as
    /// overflowed replies ([`Reply::overflowed`]) with `r0` = the element
    /// count it could not ship.
    pub fn invoke_get(&self, worker: usize, msg: &IfuncMsg) -> Result<(Reply, Vec<f32>)> {
        let reply = self.invoke(worker, msg)?;
        let data = if reply.ok() && reply.r0 != GET_MISSING {
            reply.payload_f32s()
        } else {
            Vec::new()
        };
        Ok((reply, data))
    }

    /// Create + route + send in one call: the payload goes to the worker
    /// owning `key`.
    pub fn inject_by_key(
        &self,
        handle: &IfuncHandle,
        key: u64,
        args: &SourceArgs,
    ) -> Result<usize> {
        let worker = self.route_key(key);
        let msg = handle.msg_create(args)?;
        self.send_to(worker, &msg)?;
        Ok(worker)
    }

    /// Batched [`Dispatcher::inject_by_key`]: bucket the requests by owner
    /// worker, post each bucket through the link's coalesced
    /// [`crate::ifunc::IfuncTransport::post_batch`] — *without* waiting —
    /// then flush every touched link once, so the per-worker transfers
    /// overlap instead of paying one completion round-trip per bucket.
    /// Returns each request's placement, in input order.
    pub fn inject_batch_by_key(
        &self,
        handle: &IfuncHandle,
        reqs: &[(u64, SourceArgs)],
    ) -> Result<Vec<usize>> {
        let n = self.cluster.workers.len();
        let mut buckets: Vec<Vec<IfuncMsg>> = (0..n).map(|_| Vec::new()).collect();
        let mut placed = Vec::with_capacity(reqs.len());
        for (key, args) in reqs {
            let worker = route_key(*key, n);
            buckets[worker].push(handle.msg_create(args)?);
            placed.push(worker);
        }
        for (worker, msgs) in buckets.iter().enumerate() {
            if msgs.is_empty() {
                continue;
            }
            let w = self.worker(worker)?;
            let mut link = lock_recover(&w.link);
            self.admit_or_drain(w, worker, link.frames_sent() + msgs.len() as u64)?;
            link.post_batch(msgs).map_err(|e| tag_worker(worker, e))?;
        }
        for (worker, msgs) in buckets.iter().enumerate() {
            if !msgs.is_empty() {
                lock_recover(&self.worker(worker)?.link)
                    .flush()
                    .map_err(|e| tag_worker(worker, e))?;
            }
        }
        Ok(placed)
    }

    /// Flush delivery to every worker.
    pub fn flush(&self) -> Result<()> {
        for (i, w) in self.cluster.workers.iter().enumerate() {
            lock_recover(&w.link).flush().map_err(|e| tag_worker(i, e))?;
        }
        Ok(())
    }

    /// Block until every worker has consumed everything sent so far.
    /// Waits on each link's consumed-frame counter (one tick per ingress
    /// frame — reply seqs are useless as a frame count once replies
    /// chunk), draining the reply collector meanwhile so reply-slot
    /// credit keeps flowing while the barrier spins.
    pub fn barrier(&self) -> Result<()> {
        self.flush()?;
        for (i, w) in self.cluster.workers.iter().enumerate() {
            let sent = lock_recover(&w.link).frames_sent();
            w.consumed
                .wait(sent, || match &w.collector {
                    Some(c) => c.drain(),
                    None => Ok(()),
                })
                .map_err(|e| tag_worker(i, e))?;
        }
        Ok(())
    }

    /// Fault-injection hook for the security suite: write raw bytes into
    /// a worker's delivery ring, bypassing all framing (hostile-sender
    /// simulation). Ring-protocol transports only (fabric ring and shm).
    #[doc(hidden)]
    pub fn debug_corrupt_ring(&self, worker: usize, offset: usize, data: &[u8]) -> Result<()> {
        lock_recover(&self.worker(worker)?.link).debug_put_raw(offset, data)
    }

    /// Total messages executed across workers.
    pub fn total_executed(&self) -> u64 {
        self.cluster.workers.iter().map(|w| w.executed()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::super::{Cluster, ClusterConfig};
    use super::route_key;
    use crate::ifunc::builtin::CounterIfunc;
    use crate::ifunc::SourceArgs;

    #[test]
    fn route_key_is_stable_across_runs() {
        // The hash has no per-process seed: a fixed golden vector pins the
        // placement so a record written in one run is found in the next.
        let golden: Vec<usize> = (0..16u64).map(|k| route_key(k, 4)).collect();
        assert_eq!(golden, vec![0, 1, 2, 0, 1, 3, 0, 2, 3, 1, 2, 0, 1, 3, 0, 2]);
        for k in 0..1000u64 {
            assert_eq!(route_key(k, 7), route_key(k, 7));
        }
    }

    #[test]
    fn route_key_is_uniform_across_worker_counts() {
        for workers in [2usize, 3, 5, 8, 16] {
            let mut counts = vec![0usize; workers];
            let n_keys = 10_000u64;
            for k in 0..n_keys {
                let w = route_key(k, workers);
                assert!(w < workers);
                counts[w] += 1;
            }
            let ideal = n_keys as f64 / workers as f64;
            for (w, &c) in counts.iter().enumerate() {
                let skew = (c as f64 - ideal).abs() / ideal;
                assert!(skew < 0.25, "{workers} workers: shard {w} has {c} keys (skew {skew:.2})");
            }
        }
    }

    #[test]
    fn route_key_single_worker_never_panics() {
        for k in [0u64, 1, u64::MAX, 0x9E37_79B9_7F4A_7C15] {
            assert_eq!(route_key(k, 1), 0);
        }
        // Degenerate zero-worker call clamps rather than dividing by zero.
        assert_eq!(route_key(42, 0), 0);
    }

    #[test]
    fn dispatch_counter_to_all_workers() {
        let cluster = Cluster::launch(
            ClusterConfig { workers: 3, ..Default::default() },
            |_, ctx, _| {
                ctx.library_dir().install(Box::new(CounterIfunc::default()));
            },
        )
        .unwrap();
        // The leader is the source: its library dir needs the ifunc too.
        cluster.leader.library_dir().install(Box::new(CounterIfunc::default()));
        let d = cluster.dispatcher();
        let h = d.register("counter").unwrap();
        let args = SourceArgs::bytes(vec![0u8; 32]);
        for key in 0..60u64 {
            d.inject_by_key(&h, key, &args).unwrap();
        }
        d.barrier().unwrap();
        assert_eq!(d.total_executed(), 60);
        // Fibonacci hashing spreads keys across all 3 workers.
        for w in &cluster.workers {
            assert!(w.executed() > 0, "worker {} got nothing", w.index);
        }
        cluster.shutdown().unwrap();
    }

    #[test]
    fn batch_injection_buckets_match_routing() {
        let cluster = Cluster::launch(
            ClusterConfig { workers: 3, ..Default::default() },
            |_, ctx, _| {
                ctx.library_dir().install(Box::new(CounterIfunc::default()));
            },
        )
        .unwrap();
        cluster.leader.library_dir().install(Box::new(CounterIfunc::default()));
        let d = cluster.dispatcher();
        let h = d.register("counter").unwrap();
        let reqs: Vec<(u64, SourceArgs)> =
            (0..90u64).map(|k| (k, SourceArgs::bytes(vec![0u8; 32]))).collect();
        let placed = d.inject_batch_by_key(&h, &reqs).unwrap();
        d.barrier().unwrap();
        assert_eq!(d.total_executed(), 90);
        for (i, (key, _)) in reqs.iter().enumerate() {
            assert_eq!(placed[i], d.route_key(*key));
        }
        cluster.shutdown().unwrap();
    }

    #[test]
    fn routing_is_deterministic() {
        let cluster = Cluster::launch(
            ClusterConfig { workers: 4, ..Default::default() },
            |_, _, _| {},
        )
        .unwrap();
        let d = cluster.dispatcher();
        for key in 0..100 {
            assert_eq!(d.route_key(key), d.route_key(key));
            assert!(d.route_key(key) < 4);
        }
        cluster.shutdown().unwrap();
    }

    #[test]
    fn oversized_wrap_does_not_clobber_marker() {
        // A frame longer than the current ring offset forces the
        // drain-then-marker path: tail + frame exceed the ring, so the
        // frame at offset 0 would overwrite the wrap marker unless the
        // sender waits for the poller's rewind credit first.
        let cluster = Cluster::launch(
            ClusterConfig { workers: 1, ring_bytes: 4096, ..Default::default() },
            |_, ctx, _| {
                ctx.library_dir().install(Box::new(CounterIfunc::default()));
            },
        )
        .unwrap();
        cluster.leader.library_dir().install(Box::new(CounterIfunc::default()));
        let d = cluster.dispatcher();
        let h = d.register("counter").unwrap();
        // Small frame, then a frame > ring/2 (wraps with tail + frame >
        // ring), repeated so the stream must survive several such wraps.
        // Zeroed payloads: stale frame interiors from earlier laps must
        // read as "empty" at future cursor positions.
        let small = h.msg_create(&SourceArgs::bytes(vec![0u8; 900])).unwrap();
        let big = h.msg_create(&SourceArgs::bytes(vec![0u8; 3300])).unwrap();
        for _ in 0..20 {
            d.send_to(0, &small).unwrap();
            d.send_to(0, &big).unwrap();
        }
        d.barrier().unwrap();
        assert_eq!(d.total_executed(), 40);
        cluster.shutdown().unwrap();
    }

    #[test]
    fn batched_send_survives_tiny_ring_wraps() {
        // send_batch must fall back to frame-at-a-time (and stay correct)
        // when a batch cannot be coalesced into one reservation.
        let cluster = Cluster::launch(
            ClusterConfig { workers: 1, ring_bytes: 4096, ..Default::default() },
            |_, ctx, _| {
                ctx.library_dir().install(Box::new(CounterIfunc::default()));
            },
        )
        .unwrap();
        cluster.leader.library_dir().install(Box::new(CounterIfunc::default()));
        let d = cluster.dispatcher();
        let h = d.register("counter").unwrap();
        let batch: Vec<_> = (0..8)
            .map(|i| h.msg_create(&SourceArgs::bytes(vec![0u8; 400 + i * 100])).unwrap())
            .collect();
        for _ in 0..25 {
            d.send_batch_to(0, &batch).unwrap();
        }
        d.barrier().unwrap();
        assert_eq!(d.total_executed(), 200);
        cluster.shutdown().unwrap();
    }

    #[test]
    fn ring_flow_control_survives_overload() {
        // Tiny rings force constant wrap + credit waits.
        let cluster = Cluster::launch(
            ClusterConfig { workers: 1, ring_bytes: 4096, ..Default::default() },
            |_, ctx, _| {
                ctx.library_dir().install(Box::new(CounterIfunc::default()));
            },
        )
        .unwrap();
        cluster.leader.library_dir().install(Box::new(CounterIfunc::default()));
        let d = cluster.dispatcher();
        let h = d.register("counter").unwrap();
        let args = SourceArgs::bytes(vec![0u8; 512]);
        for key in 0..500u64 {
            d.inject_by_key(&h, key, &args).unwrap();
        }
        d.barrier().unwrap();
        assert_eq!(d.total_executed(), 500);
        cluster.shutdown().unwrap();
    }
}
