//! Leader-side dispatcher: moves compute to data.
//!
//! Implements the paper's §1 use case for "large-scale irregular
//! applications ... operating on a data set so big that it has to be
//! stored on many physical devices": records are placed on workers by key
//! hash, and every injected function targeting a key is routed to the
//! worker that owns it — the code moves, the data does not.
//!
//! Delivery is transport-generic: each worker link is an
//! [`crate::ifunc::IfuncTransport`] chosen by `ClusterConfig::transport`
//! (RDMA-PUT ring or AM send-receive), and every link carries a reply
//! ring, so alongside fire-and-forget [`Dispatcher::send_to`] there is
//! [`Dispatcher::invoke`], which blocks for the injected function's
//! `(status, r0)` reply.

use crate::ifunc::{IfuncHandle, IfuncMsg, Reply, SourceArgs};
use crate::{Error, Result};

use super::worker::GET_MISSING;
use super::Cluster;

/// Deterministic key → worker placement (the locality map), as a free
/// function so it can be tested — and reasoned about — without standing up
/// a cluster. Fibonacci hashing: uniform over workers, stable across runs
/// and platforms (no per-process seed).
pub fn route_key(key: u64, n_workers: usize) -> usize {
    (key.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 32) as usize % n_workers.max(1)
}

pub struct Dispatcher<'c> {
    cluster: &'c Cluster,
}

impl<'c> Dispatcher<'c> {
    pub(crate) fn new(cluster: &'c Cluster) -> Self {
        Dispatcher { cluster }
    }

    /// Deterministic key → worker placement (the locality map).
    pub fn route_key(&self, key: u64) -> usize {
        route_key(key, self.cluster.workers.len())
    }

    /// Register an ifunc on the leader (source side).
    pub fn register(&self, name: &str) -> Result<IfuncHandle> {
        self.cluster.leader.register_ifunc(name)
    }

    /// Inject a prebuilt message to a specific worker (flow-controlled,
    /// non-blocking delivery; completion via [`Dispatcher::flush`]).
    pub fn send_to(&self, worker: usize, msg: &IfuncMsg) -> Result<()> {
        let w = self
            .cluster
            .workers
            .get(worker)
            .ok_or_else(|| Error::Other(format!("no worker {worker}")))?;
        w.link.lock().unwrap().send_frame(msg)
    }

    /// Inject a message and block for the injected function's reply: the
    /// `(seq, status, r0)` slot the worker writes after executing (or
    /// rejecting) the frame. Holding the link across the wait serializes
    /// invocations per worker. For invocations whose injected code writes
    /// the worker's result region (`db_get`), use
    /// [`Dispatcher::invoke_get`] — the region must be read under the
    /// same lock.
    pub fn invoke(&self, worker: usize, msg: &IfuncMsg) -> Result<Reply> {
        let w = self
            .cluster
            .workers
            .get(worker)
            .ok_or_else(|| Error::Other(format!("no worker {worker}")))?;
        let mut link = w.link.lock().unwrap();
        link.send_frame(msg)?;
        link.flush()?;
        let seq = link.frames_sent();
        link.replies().wait(seq)
    }

    /// [`Dispatcher::invoke`] for record-returning ifuncs (`GetIfunc`):
    /// waits for the reply and copies the worker's result region *before
    /// releasing the link lock*, so a concurrent invocation to the same
    /// worker cannot overwrite the region between the reply and the read.
    /// The data vec is empty unless the reply is ok and `r0` is a length
    /// (not [`GET_MISSING`]).
    pub fn invoke_get(&self, worker: usize, msg: &IfuncMsg) -> Result<(Reply, Vec<f32>)> {
        let w = self
            .cluster
            .workers
            .get(worker)
            .ok_or_else(|| Error::Other(format!("no worker {worker}")))?;
        let mut link = w.link.lock().unwrap();
        link.send_frame(msg)?;
        link.flush()?;
        let seq = link.frames_sent();
        let reply = link.replies().wait(seq)?;
        let data = if reply.ok && reply.r0 != GET_MISSING {
            w.result_f32s(reply.r0 as usize)
        } else {
            Vec::new()
        };
        Ok((reply, data))
    }

    /// Create + route + send in one call: the payload goes to the worker
    /// owning `key`.
    pub fn inject_by_key(
        &self,
        handle: &IfuncHandle,
        key: u64,
        args: &SourceArgs,
    ) -> Result<usize> {
        let worker = self.route_key(key);
        let msg = handle.msg_create(args)?;
        self.send_to(worker, &msg)?;
        Ok(worker)
    }

    /// Flush delivery to every worker.
    pub fn flush(&self) -> Result<()> {
        for w in &self.cluster.workers {
            w.link.lock().unwrap().flush()?;
        }
        Ok(())
    }

    /// Block until every worker has consumed everything sent so far.
    pub fn barrier(&self) -> Result<()> {
        self.flush()?;
        for w in &self.cluster.workers {
            w.link.lock().unwrap().wait_consumed()?;
        }
        Ok(())
    }

    /// Total messages executed across workers.
    pub fn total_executed(&self) -> u64 {
        self.cluster.workers.iter().map(|w| w.executed()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::super::{Cluster, ClusterConfig};
    use super::route_key;
    use crate::ifunc::builtin::CounterIfunc;
    use crate::ifunc::SourceArgs;

    #[test]
    fn route_key_is_stable_across_runs() {
        // The hash has no per-process seed: a fixed golden vector pins the
        // placement so a record written in one run is found in the next.
        let golden: Vec<usize> = (0..16u64).map(|k| route_key(k, 4)).collect();
        assert_eq!(golden, vec![0, 1, 2, 0, 1, 3, 0, 2, 3, 1, 2, 0, 1, 3, 0, 2]);
        for k in 0..1000u64 {
            assert_eq!(route_key(k, 7), route_key(k, 7));
        }
    }

    #[test]
    fn route_key_is_uniform_across_worker_counts() {
        for workers in [2usize, 3, 5, 8, 16] {
            let mut counts = vec![0usize; workers];
            let n_keys = 10_000u64;
            for k in 0..n_keys {
                let w = route_key(k, workers);
                assert!(w < workers);
                counts[w] += 1;
            }
            let ideal = n_keys as f64 / workers as f64;
            for (w, &c) in counts.iter().enumerate() {
                let skew = (c as f64 - ideal).abs() / ideal;
                assert!(skew < 0.25, "{workers} workers: shard {w} has {c} keys (skew {skew:.2})");
            }
        }
    }

    #[test]
    fn route_key_single_worker_never_panics() {
        for k in [0u64, 1, u64::MAX, 0x9E37_79B9_7F4A_7C15] {
            assert_eq!(route_key(k, 1), 0);
        }
        // Degenerate zero-worker call clamps rather than dividing by zero.
        assert_eq!(route_key(42, 0), 0);
    }

    #[test]
    fn dispatch_counter_to_all_workers() {
        let cluster = Cluster::launch(
            ClusterConfig { workers: 3, ..Default::default() },
            |_, ctx, _| {
                ctx.library_dir().install(Box::new(CounterIfunc::default()));
            },
        )
        .unwrap();
        // The leader is the source: its library dir needs the ifunc too.
        cluster.leader.library_dir().install(Box::new(CounterIfunc::default()));
        let d = cluster.dispatcher();
        let h = d.register("counter").unwrap();
        let args = SourceArgs::bytes(vec![0u8; 32]);
        for key in 0..60u64 {
            d.inject_by_key(&h, key, &args).unwrap();
        }
        d.barrier().unwrap();
        assert_eq!(d.total_executed(), 60);
        // Fibonacci hashing spreads keys across all 3 workers.
        for w in &cluster.workers {
            assert!(w.executed() > 0, "worker {} got nothing", w.index);
        }
        cluster.shutdown().unwrap();
    }

    #[test]
    fn routing_is_deterministic() {
        let cluster = Cluster::launch(
            ClusterConfig { workers: 4, ..Default::default() },
            |_, _, _| {},
        )
        .unwrap();
        let d = cluster.dispatcher();
        for key in 0..100 {
            assert_eq!(d.route_key(key), d.route_key(key));
            assert!(d.route_key(key) < 4);
        }
        cluster.shutdown().unwrap();
    }

    #[test]
    fn oversized_wrap_does_not_clobber_marker() {
        // A frame longer than the current ring offset forces the
        // drain-then-marker path: tail + frame exceed the ring, so the
        // frame at offset 0 would overwrite the wrap marker unless the
        // sender waits for the poller's rewind credit first.
        let cluster = Cluster::launch(
            ClusterConfig { workers: 1, ring_bytes: 4096, ..Default::default() },
            |_, ctx, _| {
                ctx.library_dir().install(Box::new(CounterIfunc::default()));
            },
        )
        .unwrap();
        cluster.leader.library_dir().install(Box::new(CounterIfunc::default()));
        let d = cluster.dispatcher();
        let h = d.register("counter").unwrap();
        // Small frame, then a frame > ring/2 (wraps with tail + frame >
        // ring), repeated so the stream must survive several such wraps.
        // Zeroed payloads: stale frame interiors from earlier laps must
        // read as "empty" at future cursor positions.
        let small = h.msg_create(&SourceArgs::bytes(vec![0u8; 900])).unwrap();
        let big = h.msg_create(&SourceArgs::bytes(vec![0u8; 3300])).unwrap();
        for _ in 0..20 {
            d.send_to(0, &small).unwrap();
            d.send_to(0, &big).unwrap();
        }
        d.barrier().unwrap();
        assert_eq!(d.total_executed(), 40);
        cluster.shutdown().unwrap();
    }

    #[test]
    fn ring_flow_control_survives_overload() {
        // Tiny rings force constant wrap + credit waits.
        let cluster = Cluster::launch(
            ClusterConfig { workers: 1, ring_bytes: 4096, ..Default::default() },
            |_, ctx, _| {
                ctx.library_dir().install(Box::new(CounterIfunc::default()));
            },
        )
        .unwrap();
        cluster.leader.library_dir().install(Box::new(CounterIfunc::default()));
        let d = cluster.dispatcher();
        let h = d.register("counter").unwrap();
        let args = SourceArgs::bytes(vec![0u8; 512]);
        for key in 0..500u64 {
            d.inject_by_key(&h, key, &args).unwrap();
        }
        d.barrier().unwrap();
        assert_eq!(d.total_executed(), 500);
        cluster.shutdown().unwrap();
    }
}
