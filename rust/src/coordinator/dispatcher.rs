//! Leader-side dispatcher: moves compute to data.
//!
//! Implements the paper's §1 use case for "large-scale irregular
//! applications ... operating on a data set so big that it has to be
//! stored on many physical devices": records are placed on workers by key
//! hash, and every injected function targeting a key is routed to the
//! worker that owns it — the code moves, the data does not.
//!
//! Routing is expressed once, through [`Target`]: a destination is a
//! single worker ([`Target::Worker`]), the owner of a key
//! ([`Target::Key`]), an explicit worker set ([`Target::Set`]), or the
//! whole cluster ([`Target::All`]). Every entry point takes a `Target`,
//! so unicast, keyed, and collective paths share one call surface:
//!
//! * [`Dispatcher::send`] / [`Dispatcher::send_batch`] — fire-and-forget
//!   delivery (flow-controlled, non-blocking; completion via
//!   [`Dispatcher::flush`]), fanned out per resolved worker,
//! * [`Dispatcher::invoke_begin`] / [`Dispatcher::invoke_one`] /
//!   [`Dispatcher::fetch`] — unicast invocation: inject a frame, get a
//!   [`PendingReply`] (or block for the [`Reply`] / decoded record),
//! * [`Dispatcher::invoke_multi`] / [`Dispatcher::invoke_all`] —
//!   **collective** invocation (the paper's closing motivation): inject
//!   one program, fan the frame out across the worker set through the
//!   transports' post/flush seam (frames posted per link without
//!   waiting, then one flush pass, so per-link transfers overlap), and
//!   merge the replies through [`MultiPendingReply`] with per-worker
//!   attribution and partial-failure reporting,
//! * [`Dispatcher::scatter`] — batched keyed delivery: bucket requests by
//!   owner worker, post each bucket coalesced, flush every touched link
//!   once.
//!
//! The dispatcher is a pure routing/collective **facade**: every
//! per-worker mechanism — transport, reply ring, collector, invocation
//! window — lives behind [`super::link::PeerLink`], the peer-generic
//! link layer that the worker↔worker mesh reuses verbatim. The
//! dispatcher resolves `Target`s to worker indices and calls link
//! methods; it never touches a transport, window, or collector directly.
//! Invocations pipeline up to `ClusterConfig::max_inflight` per worker;
//! [`PendingReply::wait`] collects `(status, r0, payload)` — the payload
//! pushed by the injected function through `reply_put` / `db_get`, of
//! **any size**: one reply frame when it fits, a reassembled chunk
//! stream when it does not.

use crate::ifunc::{IfuncHandle, IfuncMsg, Reply, SourceArgs};
use crate::{Error, Result};

use super::link::{PeerLink, PendingReply};
use super::worker::GET_MISSING;
use super::Cluster;

/// Deterministic key → worker placement (the locality map), as a free
/// function so it can be tested — and reasoned about — without standing up
/// a cluster. Fibonacci hashing: uniform over workers, stable across runs
/// and platforms (no per-process seed).
pub fn route_key(key: u64, n_workers: usize) -> usize {
    (key.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 32) as usize % n_workers.max(1)
}

/// Where an injection goes: the dispatcher's single routing vocabulary.
///
/// Unicast targets ([`Target::Worker`], [`Target::Key`]) resolve to one
/// worker and are accepted everywhere. Collective targets
/// ([`Target::Set`], [`Target::All`]) resolve to an ordered worker set
/// and are accepted by the fire-and-forget and collective entry points
/// ([`Dispatcher::send`], [`Dispatcher::send_batch`],
/// [`Dispatcher::invoke_multi`]); the single-reply entry points
/// ([`Dispatcher::invoke_begin`], [`Dispatcher::invoke_one`],
/// [`Dispatcher::fetch`]) reject them, since one `PendingReply` cannot
/// carry many workers' replies.
///
/// A `Set` is validated against the cluster (unknown indices error) and
/// deduplicated preserving first occurrence; an empty set is an error,
/// never a silent no-op.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Target<'a> {
    /// One specific worker by index.
    Worker(usize),
    /// The worker owning `key` under the cluster's hash placement
    /// ([`route_key`]).
    Key(u64),
    /// An explicit set of worker indices (order preserved, duplicates
    /// ignored).
    Set(&'a [usize]),
    /// Every worker in the cluster.
    All,
}

/// The merged result of a collective invocation: every targeted worker's
/// [`Reply`], attributed by worker index, in target-resolution order.
pub struct MultiReply {
    replies: Vec<(usize, Reply)>,
}

impl MultiReply {
    /// `(worker, reply)` pairs in the order the target resolved.
    pub fn replies(&self) -> &[(usize, Reply)] {
        &self.replies
    }

    /// The reply a specific worker sent, if it was targeted.
    pub fn reply_for(&self, worker: usize) -> Option<&Reply> {
        self.replies.iter().find(|(w, _)| *w == worker).map(|(_, r)| r)
    }

    /// Whether every worker's injected function reported success
    /// (delivery succeeded on all of them by construction — a delivery
    /// or timeout failure surfaces as `Err` from
    /// [`MultiPendingReply::wait`], never as a present-but-failed entry).
    pub fn all_ok(&self) -> bool {
        self.replies.iter().all(|(_, r)| r.ok())
    }

    pub fn len(&self) -> usize {
        self.replies.len()
    }

    pub fn is_empty(&self) -> bool {
        self.replies.is_empty()
    }

    /// Consume into the raw `(worker, reply)` pairs.
    pub fn into_replies(self) -> Vec<(usize, Reply)> {
        self.replies
    }
}

/// The in-flight half of a collective invocation: one [`PendingReply`]
/// per targeted worker, all injected before a single flush pass so the
/// per-link transfers overlap. [`MultiPendingReply::wait`] merges them;
/// dropping the handle without waiting releases every per-worker window
/// slot and collector registration (no stale waiters), exactly like
/// dropping the individual [`PendingReply`]s.
pub struct MultiPendingReply {
    pending: Vec<PendingReply>,
}

impl MultiPendingReply {
    /// The targeted workers, in resolution order.
    pub fn workers(&self) -> Vec<usize> {
        self.pending.iter().map(|p| p.worker()).collect()
    }

    pub fn len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Collect every worker's reply. All-or-error: `Ok` only when **all**
    /// targeted workers replied (their replies merged into a
    /// [`MultiReply`] with per-worker attribution); any delivery failure
    /// or reply timeout waits out the *rest* of the set first, then
    /// surfaces as [`Error::Transport`] reporting which workers failed,
    /// which replied, and the first failure's cause — so a partial
    /// failure names the dead workers instead of discarding the evidence.
    pub fn wait(self) -> Result<MultiReply> {
        let mut replies = Vec::with_capacity(self.pending.len());
        let mut failures: Vec<(usize, Error)> = Vec::new();
        for p in self.pending {
            let worker = p.worker();
            match p.wait() {
                Ok(r) => replies.push((worker, r)),
                Err(e) => failures.push((worker, e)),
            }
        }
        if failures.is_empty() {
            return Ok(MultiReply { replies });
        }
        let failed: Vec<String> = failures.iter().map(|(w, _)| w.to_string()).collect();
        let replied: Vec<String> = replies.iter().map(|(w, _)| w.to_string()).collect();
        let (first_worker, first_err) = &failures[0];
        Err(Error::Transport(format!(
            "collective invocation: worker(s) [{}] failed, worker(s) [{}] replied; \
             first failure on worker {first_worker}: {first_err}",
            failed.join(", "),
            replied.join(", "),
        )))
    }
}

pub struct Dispatcher<'c> {
    cluster: &'c Cluster,
}

impl<'c> Dispatcher<'c> {
    pub(crate) fn new(cluster: &'c Cluster) -> Self {
        Dispatcher { cluster }
    }

    /// Deterministic key → worker placement (the locality map).
    pub fn route_key(&self, key: u64) -> usize {
        route_key(key, self.cluster.workers.len())
    }

    /// Register an ifunc on the leader (source side).
    pub fn register(&self, name: &str) -> Result<IfuncHandle> {
        self.cluster.leader.register_ifunc(name)
    }

    /// Static admission: refuse an invocation the analysis already proved
    /// doomed, before any frame leaves the leader. Two checks, both
    /// *sound* (they only reject programs that could never succeed on the
    /// target):
    ///
    /// * **fuel floor** — the minimum instructions any halting execution
    ///   retires exceeds the workers' fuel budget (a never-halting
    ///   program has floor `u64::MAX`), so every worker would burn its
    ///   whole budget and fault;
    /// * **capabilities** — a reachable host call is outside the
    ///   configured [`crate::vm::CapabilityPolicy`], so every worker's
    ///   link-time gate would refuse the frame anyway.
    ///
    /// Messages without [`IfuncMsg::admission_facts`] (hand-assembled
    /// frames, relays) pass through untouched — admission is an
    /// optimization over the workers' authoritative checks, never a
    /// substitute for them.
    fn admit(&self, msg: &IfuncMsg) -> Result<()> {
        let Some(facts) = msg.admission_facts() else { return Ok(()) };
        let cfg = self.cluster.leader.config();
        let reject = |why: String| {
            self.cluster
                .leader
                .analysis_stats()
                .static_rejections
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            Err(Error::Verify(format!("static admission: {why}")))
        };
        if facts.fuel_floor > cfg.vm.fuel {
            return reject(if facts.may_loop && facts.fuel_floor == u64::MAX {
                format!(
                    "`{}` can never halt (no reachable HALT); \
                     it would exhaust any fuel budget",
                    msg.name()
                )
            } else {
                format!(
                    "`{}` needs at least {} instructions to halt but workers \
                     grant {} fuel",
                    msg.name(),
                    facts.fuel_floor,
                    cfg.vm.fuel
                )
            });
        }
        let syms: Vec<&str> = facts.reachable_syms.iter().map(String::as_str).collect();
        if let Some(denied) = cfg.caps.first_denied(&syms) {
            return reject(format!(
                "`{}` reaches host call `{denied}`, outside the capability allowlist",
                msg.name()
            ));
        }
        Ok(())
    }

    /// The leader's outbound link to `worker` — everything per-worker
    /// goes through this.
    fn link(&self, worker: usize) -> Result<&PeerLink> {
        self.cluster
            .workers
            .get(worker)
            .map(|w| w.link.as_ref())
            .ok_or_else(|| Error::Other(format!("no worker {worker}")))
    }

    /// Resolve a unicast target to its one worker. Collective targets are
    /// rejected: one [`PendingReply`] cannot carry many workers' replies.
    fn resolve_one(&self, target: Target<'_>) -> Result<usize> {
        match target {
            Target::Worker(w) => {
                self.link(w)?;
                Ok(w)
            }
            Target::Key(k) => Ok(self.route_key(k)),
            Target::Set(_) | Target::All => Err(Error::Other(format!(
                "collective target {target:?} has no single reply; \
                 use invoke_multi / invoke_all"
            ))),
        }
    }

    /// Resolve any target to its ordered worker set: validated against
    /// the cluster, deduplicated preserving first occurrence, never
    /// empty.
    fn resolve_set(&self, target: Target<'_>) -> Result<Vec<usize>> {
        let n = self.cluster.workers.len();
        match target {
            Target::Worker(w) => {
                self.link(w)?;
                Ok(vec![w])
            }
            Target::Key(k) => Ok(vec![self.route_key(k)]),
            Target::All => Ok((0..n).collect()),
            Target::Set(set) => {
                if set.is_empty() {
                    return Err(Error::Other(
                        "empty Target::Set — a collective over no workers is a bug, \
                         not a no-op"
                            .into(),
                    ));
                }
                let mut seen = vec![false; n];
                let mut out = Vec::with_capacity(set.len());
                for &w in set {
                    self.link(w)?;
                    if !seen[w] {
                        seen[w] = true;
                        out.push(w);
                    }
                }
                Ok(out)
            }
        }
    }

    /// Inject a prebuilt message to every worker the target resolves to
    /// (flow-controlled, non-blocking delivery; completion via
    /// [`Dispatcher::flush`]). For a collective target the same frame is
    /// delivered once per worker — the program is injected once and
    /// fanned out, not re-created per destination.
    pub fn send(&self, target: Target<'_>, msg: &IfuncMsg) -> Result<()> {
        self.admit(msg)?;
        for worker in self.resolve_set(target)? {
            self.link(worker)?.send(msg)?;
        }
        Ok(())
    }

    /// Deliver a batch of frames to every worker the target resolves to,
    /// through the transport's coalesced path (one credit reservation on
    /// the ring; back-to-back posts over AM). Collective targets post
    /// every link's batch first — without waiting — then flush each
    /// touched link once, so per-link transfers overlap.
    pub fn send_batch(&self, target: Target<'_>, msgs: &[IfuncMsg]) -> Result<()> {
        if msgs.is_empty() {
            return Ok(());
        }
        for msg in msgs {
            self.admit(msg)?;
        }
        let workers = self.resolve_set(target)?;
        for &worker in &workers {
            self.link(worker)?.post_batch(msgs)?;
        }
        for &worker in &workers {
            self.link(worker)?.flush()?;
        }
        Ok(())
    }

    /// Begin a unicast invocation: inject `msg` at the resolved worker,
    /// record its frame seq, and release the link immediately. The
    /// returned [`PendingReply`] waits for the reply — chunk-streamed
    /// when large — without the link lock, so up to
    /// `ClusterConfig::max_inflight` invocations pipeline per worker
    /// (the call blocks while the window is full). Collective targets
    /// are rejected; use [`Dispatcher::invoke_multi`].
    pub fn invoke_begin(&self, target: Target<'_>, msg: &IfuncMsg) -> Result<PendingReply> {
        self.admit(msg)?;
        self.link(self.resolve_one(target)?)?.invoke_begin(msg, true)
    }

    /// Inject a message and block for the injected function's reply frame
    /// — [`Dispatcher::invoke_begin`] + [`PendingReply::wait`] in one
    /// call. `reply.payload` carries whatever the function pushed through
    /// `reply_put` / `db_get`.
    pub fn invoke_one(&self, target: Target<'_>, msg: &IfuncMsg) -> Result<Reply> {
        self.invoke_begin(target, msg)?.wait()
    }

    /// Non-blocking [`Dispatcher::invoke_begin`]: returns `Ok(None)` —
    /// immediately, without parking — when the worker's invocation
    /// window is full. The admission-control primitive for the serve
    /// front-end: a caller holding live traffic sheds (or requeues)
    /// instead of timing out inside the window, so a saturated worker
    /// surfaces as back-pressure, not as a stalled thread.
    pub fn try_invoke_begin(
        &self,
        target: Target<'_>,
        msg: &IfuncMsg,
    ) -> Result<Option<PendingReply>> {
        self.admit(msg)?;
        self.link(self.resolve_one(target)?)?.try_invoke_begin(msg)
    }

    /// Non-blocking **batched** invocation begin: claim as many window
    /// slots as are free right now (up to `msgs.len()`), post that
    /// admitted prefix through the link's coalesced
    /// [`crate::ifunc::IfuncTransport::post_batch`] path — one credit
    /// reservation, one flush — and return a [`PendingReply`] per
    /// admitted frame, in order. An empty vec means the window was
    /// saturated; the call never blocks on window capacity. The serve
    /// front-end's cross-client coalescer drains its per-worker queue
    /// through this: whatever is queued when the link frees ships as one
    /// batch, amortizing flush + credit across clients.
    pub fn try_invoke_batch(
        &self,
        target: Target<'_>,
        msgs: &[IfuncMsg],
    ) -> Result<Vec<PendingReply>> {
        for msg in msgs {
            self.admit(msg)?;
        }
        self.link(self.resolve_one(target)?)?.try_invoke_batch(msgs)
    }

    /// Begin a **collective** invocation: inject the same program on
    /// every worker the target resolves to. Frames are posted per link
    /// without waiting, then one flush pass covers the whole fan-out, so
    /// the per-link transfers overlap instead of paying one completion
    /// round-trip per worker. Each worker's reply is tracked by its own
    /// [`PendingReply`]; [`MultiPendingReply::wait`] merges them with
    /// per-worker attribution and partial-failure reporting.
    ///
    /// A failure *during* the fan-out (window timeout, dead link) aborts
    /// the call; already-posted invocations are unwound — their window
    /// slots released, their collector registrations removed — by the
    /// partial handle set dropping.
    pub fn invoke_multi(&self, target: Target<'_>, msg: &IfuncMsg) -> Result<MultiPendingReply> {
        self.admit(msg)?;
        let workers = self.resolve_set(target)?;
        let mut pending = Vec::with_capacity(workers.len());
        for &worker in &workers {
            pending.push(self.link(worker)?.invoke_begin(msg, false)?);
        }
        // One flush pass for the whole fan-out: every link's transfer is
        // already posted, so the completions overlap.
        for &worker in &workers {
            self.link(worker)?.flush()?;
        }
        Ok(MultiPendingReply { pending })
    }

    /// [`Dispatcher::invoke_multi`] over [`Target::All`]: scatter one
    /// program to every worker, gather every reply.
    pub fn invoke_all(&self, msg: &IfuncMsg) -> Result<MultiPendingReply> {
        self.invoke_multi(Target::All, msg)
    }

    /// [`Dispatcher::invoke_one`] for record-returning ifuncs
    /// (`GetIfunc`): decodes the reply payload as f32 record elements.
    /// The data vec is empty unless the reply is ok and `r0` is a length
    /// (not [`GET_MISSING`]). Record size does not matter on a streamed
    /// link — big records arrive as reassembled chunk streams; only a
    /// `stream_replies: false` link still reports oversized records as
    /// overflowed replies ([`Reply::overflowed`]) with `r0` = the element
    /// count it could not ship.
    pub fn fetch(&self, target: Target<'_>, msg: &IfuncMsg) -> Result<(Reply, Vec<f32>)> {
        let reply = self.invoke_one(target, msg)?;
        let data = if reply.ok() && reply.r0 != GET_MISSING {
            reply.payload_f32s()
        } else {
            Vec::new()
        };
        Ok((reply, data))
    }

    /// Batched keyed delivery: bucket the requests by owner worker, post
    /// each bucket through the link's coalesced
    /// [`crate::ifunc::IfuncTransport::post_batch`] — *without* waiting —
    /// then flush every touched link once, so the per-worker transfers
    /// overlap instead of paying one completion round-trip per bucket.
    /// Returns each request's placement, in input order.
    pub fn scatter(
        &self,
        handle: &IfuncHandle,
        reqs: &[(u64, SourceArgs)],
    ) -> Result<Vec<usize>> {
        let n = self.cluster.workers.len();
        let mut buckets: Vec<Vec<IfuncMsg>> = (0..n).map(|_| Vec::new()).collect();
        let mut placed = Vec::with_capacity(reqs.len());
        for (key, args) in reqs {
            let worker = route_key(*key, n);
            let msg = handle.msg_create(args)?;
            self.admit(&msg)?;
            buckets[worker].push(msg);
            placed.push(worker);
        }
        for (worker, msgs) in buckets.iter().enumerate() {
            if !msgs.is_empty() {
                self.link(worker)?.post_batch(msgs)?;
            }
        }
        for (worker, msgs) in buckets.iter().enumerate() {
            if !msgs.is_empty() {
                self.link(worker)?.flush()?;
            }
        }
        Ok(placed)
    }

    /// Flush delivery to every worker.
    pub fn flush(&self) -> Result<()> {
        for w in &self.cluster.workers {
            w.link.flush()?;
        }
        Ok(())
    }

    /// Block until every worker has consumed everything sent so far.
    /// Waits on each link's consumed-frame counter (one tick per ingress
    /// frame — reply seqs are useless as a frame count once replies
    /// chunk), draining the reply collector meanwhile so reply-slot
    /// credit keeps flowing while the barrier spins.
    pub fn barrier(&self) -> Result<()> {
        self.flush()?;
        for w in &self.cluster.workers {
            w.link.wait_consumed()?;
        }
        Ok(())
    }

    /// Fault-injection hook for the security suite: write raw bytes into
    /// a worker's delivery ring, bypassing all framing (hostile-sender
    /// simulation). Ring-protocol transports only (fabric ring and shm).
    #[doc(hidden)]
    pub fn debug_corrupt_ring(&self, worker: usize, offset: usize, data: &[u8]) -> Result<()> {
        self.link(worker)?.debug_put_raw(offset, data)
    }

    /// Outstanding reply registrations on a worker's link — the
    /// stale-waiter probe for the drop-without-wait property tests.
    #[doc(hidden)]
    pub fn debug_awaited(&self, worker: usize) -> Result<usize> {
        Ok(self.link(worker)?.debug_awaited())
    }

    /// Frames the leader has sent to `worker` over its own link so far.
    /// The mesh tests' zero-leader-relay probe: a forward chain raises
    /// workers' `forwarded` counters while this number stays put.
    #[doc(hidden)]
    pub fn debug_frames_sent(&self, worker: usize) -> Result<u64> {
        Ok(self.link(worker)?.frames_sent())
    }

    /// Total messages executed across workers — every hop of a forwarded
    /// chain counts where it ran.
    pub fn total_executed(&self) -> u64 {
        self.cluster.workers.iter().map(|w| w.executed()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::super::{Cluster, ClusterConfig};
    use super::{route_key, Target};
    use crate::ifunc::builtin::{CounterIfunc, EchoIfunc};
    use crate::ifunc::SourceArgs;

    #[test]
    fn route_key_is_stable_across_runs() {
        // The hash has no per-process seed: a fixed golden vector pins the
        // placement so a record written in one run is found in the next.
        let golden: Vec<usize> = (0..16u64).map(|k| route_key(k, 4)).collect();
        assert_eq!(golden, vec![0, 1, 2, 0, 1, 3, 0, 2, 3, 1, 2, 0, 1, 3, 0, 2]);
        for k in 0..1000u64 {
            assert_eq!(route_key(k, 7), route_key(k, 7));
        }
    }

    #[test]
    fn route_key_is_uniform_across_worker_counts() {
        for workers in [2usize, 3, 5, 8, 16] {
            let mut counts = vec![0usize; workers];
            let n_keys = 10_000u64;
            for k in 0..n_keys {
                let w = route_key(k, workers);
                assert!(w < workers);
                counts[w] += 1;
            }
            let ideal = n_keys as f64 / workers as f64;
            for (w, &c) in counts.iter().enumerate() {
                let skew = (c as f64 - ideal).abs() / ideal;
                assert!(skew < 0.25, "{workers} workers: shard {w} has {c} keys (skew {skew:.2})");
            }
        }
    }

    #[test]
    fn route_key_single_worker_never_panics() {
        for k in [0u64, 1, u64::MAX, 0x9E37_79B9_7F4A_7C15] {
            assert_eq!(route_key(k, 1), 0);
        }
        // Degenerate zero-worker call clamps rather than dividing by zero.
        assert_eq!(route_key(42, 0), 0);
    }

    #[test]
    fn target_resolution_validates_and_dedups() {
        let cluster = Cluster::launch(
            ClusterConfig::builder().workers(3).build().unwrap(),
            |_, _, _| {},
        )
        .unwrap();
        let d = cluster.dispatcher();
        assert_eq!(d.resolve_set(Target::All).unwrap(), vec![0, 1, 2]);
        assert_eq!(d.resolve_set(Target::Worker(1)).unwrap(), vec![1]);
        assert_eq!(d.resolve_set(Target::Set(&[2, 0, 2, 0])).unwrap(), vec![2, 0]);
        assert_eq!(d.resolve_set(Target::Key(5)).unwrap(), vec![d.route_key(5)]);
        // Out-of-range and empty sets are errors, not silent no-ops.
        assert!(d.resolve_set(Target::Set(&[3])).is_err());
        assert!(d.resolve_set(Target::Set(&[])).is_err());
        assert!(d.resolve_one(Target::Worker(9)).is_err());
        // Single-reply entry points reject collective targets.
        assert!(d.resolve_one(Target::All).is_err());
        assert!(d.resolve_one(Target::Set(&[0, 1])).is_err());
        cluster.shutdown().unwrap();
    }

    #[test]
    fn dispatch_counter_to_all_workers() {
        let cluster = Cluster::launch(
            ClusterConfig::builder().workers(3).build().unwrap(),
            |_, ctx, _| {
                ctx.library_dir().install(Box::new(CounterIfunc::default()));
            },
        )
        .unwrap();
        // The leader is the source: its library dir needs the ifunc too.
        cluster.leader.library_dir().install(Box::new(CounterIfunc::default()));
        let d = cluster.dispatcher();
        let h = d.register("counter").unwrap();
        let msg = h.msg_create(&SourceArgs::bytes(vec![0u8; 32])).unwrap();
        for key in 0..60u64 {
            d.send(Target::Key(key), &msg).unwrap();
        }
        d.barrier().unwrap();
        assert_eq!(d.total_executed(), 60);
        // Fibonacci hashing spreads keys across all 3 workers.
        for w in &cluster.workers {
            assert!(w.executed() > 0, "worker {} got nothing", w.index);
        }
        cluster.shutdown().unwrap();
    }

    #[test]
    fn batch_injection_buckets_match_routing() {
        let cluster = Cluster::launch(
            ClusterConfig::builder().workers(3).build().unwrap(),
            |_, ctx, _| {
                ctx.library_dir().install(Box::new(CounterIfunc::default()));
            },
        )
        .unwrap();
        cluster.leader.library_dir().install(Box::new(CounterIfunc::default()));
        let d = cluster.dispatcher();
        let h = d.register("counter").unwrap();
        let reqs: Vec<(u64, SourceArgs)> =
            (0..90u64).map(|k| (k, SourceArgs::bytes(vec![0u8; 32]))).collect();
        let placed = d.scatter(&h, &reqs).unwrap();
        d.barrier().unwrap();
        assert_eq!(d.total_executed(), 90);
        for (i, (key, _)) in reqs.iter().enumerate() {
            assert_eq!(placed[i], d.route_key(*key));
        }
        cluster.shutdown().unwrap();
    }

    #[test]
    fn collective_send_reaches_every_worker() {
        let cluster = Cluster::launch(
            ClusterConfig::builder().workers(4).build().unwrap(),
            |_, ctx, _| {
                ctx.library_dir().install(Box::new(CounterIfunc::default()));
            },
        )
        .unwrap();
        cluster.leader.library_dir().install(Box::new(CounterIfunc::default()));
        let d = cluster.dispatcher();
        let h = d.register("counter").unwrap();
        let msg = h.msg_create(&SourceArgs::bytes(vec![0u8; 32])).unwrap();
        // One frame to All = one execution per worker; a Set hits exactly
        // its members.
        d.send(Target::All, &msg).unwrap();
        d.send(Target::Set(&[1, 3]), &msg).unwrap();
        d.barrier().unwrap();
        assert_eq!(d.total_executed(), 6);
        for (i, w) in cluster.workers.iter().enumerate() {
            let expect = if i == 1 || i == 3 { 2 } else { 1 };
            assert_eq!(w.executed(), expect, "worker {i}");
        }
        cluster.shutdown().unwrap();
    }

    #[test]
    fn routing_is_deterministic() {
        let cluster = Cluster::launch(
            ClusterConfig::builder().workers(4).build().unwrap(),
            |_, _, _| {},
        )
        .unwrap();
        let d = cluster.dispatcher();
        for key in 0..100 {
            assert_eq!(d.route_key(key), d.route_key(key));
            assert!(d.route_key(key) < 4);
        }
        cluster.shutdown().unwrap();
    }

    #[test]
    fn oversized_wrap_does_not_clobber_marker() {
        // A frame longer than the current ring offset forces the
        // drain-then-marker path: tail + frame exceed the ring, so the
        // frame at offset 0 would overwrite the wrap marker unless the
        // sender waits for the poller's rewind credit first.
        let cluster = Cluster::launch(
            ClusterConfig::builder().workers(1).ring_bytes(4096).build().unwrap(),
            |_, ctx, _| {
                ctx.library_dir().install(Box::new(CounterIfunc::default()));
            },
        )
        .unwrap();
        cluster.leader.library_dir().install(Box::new(CounterIfunc::default()));
        let d = cluster.dispatcher();
        let h = d.register("counter").unwrap();
        // Small frame, then a frame > ring/2 (wraps with tail + frame >
        // ring), repeated so the stream must survive several such wraps.
        // Zeroed payloads: stale frame interiors from earlier laps must
        // read as "empty" at future cursor positions.
        let small = h.msg_create(&SourceArgs::bytes(vec![0u8; 900])).unwrap();
        let big = h.msg_create(&SourceArgs::bytes(vec![0u8; 3300])).unwrap();
        for _ in 0..20 {
            d.send(Target::Worker(0), &small).unwrap();
            d.send(Target::Worker(0), &big).unwrap();
        }
        d.barrier().unwrap();
        assert_eq!(d.total_executed(), 40);
        cluster.shutdown().unwrap();
    }

    #[test]
    fn batched_send_survives_tiny_ring_wraps() {
        // send_batch must fall back to frame-at-a-time (and stay correct)
        // when a batch cannot be coalesced into one reservation.
        let cluster = Cluster::launch(
            ClusterConfig::builder().workers(1).ring_bytes(4096).build().unwrap(),
            |_, ctx, _| {
                ctx.library_dir().install(Box::new(CounterIfunc::default()));
            },
        )
        .unwrap();
        cluster.leader.library_dir().install(Box::new(CounterIfunc::default()));
        let d = cluster.dispatcher();
        let h = d.register("counter").unwrap();
        let batch: Vec<_> = (0..8)
            .map(|i| h.msg_create(&SourceArgs::bytes(vec![0u8; 400 + i * 100])).unwrap())
            .collect();
        for _ in 0..25 {
            d.send_batch(Target::Worker(0), &batch).unwrap();
        }
        d.barrier().unwrap();
        assert_eq!(d.total_executed(), 200);
        cluster.shutdown().unwrap();
    }

    #[test]
    fn try_invoke_batch_admits_only_free_window_slots() {
        // Window slots are held until a PendingReply is waited or
        // dropped, so admission arithmetic is deterministic regardless of
        // how fast the worker executes.
        let cluster = Cluster::launch(
            ClusterConfig::builder().workers(1).max_inflight(2).build().unwrap(),
            |_, ctx, _| {
                ctx.library_dir().install(Box::new(EchoIfunc));
            },
        )
        .unwrap();
        cluster.leader.library_dir().install(Box::new(EchoIfunc));
        let d = cluster.dispatcher();
        let h = d.register("echo").unwrap();
        let msgs: Vec<_> = (0..4)
            .map(|_| h.msg_create(&SourceArgs::bytes(b"x".to_vec())).unwrap())
            .collect();
        // Free window: a 4-frame batch admits exactly max_inflight = 2.
        let pending = d.try_invoke_batch(Target::Worker(0), &msgs).unwrap();
        assert_eq!(pending.len(), 2);
        // Saturated: both try variants return empty/None without blocking.
        assert!(d.try_invoke_batch(Target::Worker(0), &msgs).unwrap().is_empty());
        assert!(d.try_invoke_begin(Target::Worker(0), &msgs[0]).unwrap().is_none());
        // Collecting the admitted replies frees the window again.
        for p in pending {
            assert!(p.wait().unwrap().ok());
        }
        let p = d
            .try_invoke_begin(Target::Worker(0), &msgs[0])
            .unwrap()
            .expect("freed window must admit");
        assert!(p.wait().unwrap().ok());
        cluster.shutdown().unwrap();
    }

    /// Registered-handle messages carry [`crate::vm::AdmissionFacts`];
    /// the dispatcher refuses provably-doomed invocations at the leader,
    /// before any frame is posted.
    #[test]
    fn static_admission_rejects_doomed_invocations() {
        use crate::ifunc::library::IfuncLibrary;
        use crate::ifunc::message::CodeImage;
        use crate::vm::Assembler;

        /// `jmp @0`: no reachable HALT, so the fuel floor is `u64::MAX`.
        struct SpinIfunc;
        impl IfuncLibrary for SpinIfunc {
            fn name(&self) -> &str {
                "spin"
            }
            fn payload_get_max_size(&self, a: &SourceArgs) -> usize {
                a.len()
            }
            fn payload_init(
                &self,
                p: &mut [u8],
                a: &SourceArgs,
            ) -> crate::Result<usize> {
                p[..a.len()].copy_from_slice(a.as_bytes());
                Ok(a.len())
            }
            fn code(&self) -> CodeImage {
                let mut asm = Assembler::new();
                let top = asm.label();
                asm.bind(top);
                asm.jmp(top);
                let (vm_code, imports) = asm.assemble();
                CodeImage { imports, vm_code, hlo: vec![] }
            }
        }

        let cluster = Cluster::launch(
            ClusterConfig::builder().workers(1).build().unwrap(),
            |_, _, _| {},
        )
        .unwrap();
        cluster.leader.library_dir().install(Box::new(SpinIfunc));
        let d = cluster.dispatcher();
        let h = d.register("spin").unwrap();
        let msg = h.msg_create(&SourceArgs::bytes(vec![0u8; 8])).unwrap();
        for attempt in [
            d.send(Target::Worker(0), &msg).unwrap_err(),
            d.invoke_begin(Target::Worker(0), &msg).map(|_| ()).unwrap_err(),
            d.invoke_multi(Target::All, &msg).map(|_| ()).unwrap_err(),
        ] {
            let text = attempt.to_string();
            assert!(text.contains("static admission"), "{text}");
            assert!(text.contains("never halt"), "{text}");
        }
        assert_eq!(cluster.leader.analysis_stats().snapshot().2, 3);
        d.barrier().unwrap();
        assert_eq!(d.total_executed(), 0, "nothing reached a worker");
        cluster.shutdown().unwrap();
    }

    /// Finite-but-insufficient fuel and capability mismatches are also
    /// caught at admission, using the leader's (cluster-wide) config.
    #[test]
    fn static_admission_checks_fuel_floor_and_capabilities() {
        use crate::ucp::ContextConfig;
        use crate::vm::interp::VmConfig;
        use crate::vm::CapabilityPolicy;

        // counter's body retires 3 instructions minimum; grant only 2.
        let tight = ContextConfig {
            vm: VmConfig { fuel: 2, ..Default::default() },
            ..Default::default()
        };
        let cluster = Cluster::launch(
            ClusterConfig::builder().workers(1).ctx(tight).build().unwrap(),
            |_, _, _| {},
        )
        .unwrap();
        cluster.leader.library_dir().install(Box::new(CounterIfunc::default()));
        let d = cluster.dispatcher();
        let h = d.register("counter").unwrap();
        let msg = h.msg_create(&SourceArgs::bytes(vec![0u8; 8])).unwrap();
        let text = d.send(Target::Worker(0), &msg).unwrap_err().to_string();
        assert!(text.contains("static admission"), "{text}");
        assert!(text.contains("2 fuel"), "{text}");
        cluster.shutdown().unwrap();

        // Ample fuel, restricted capabilities: counter reaches
        // `counter_add`, which the allowlist refuses.
        let gated = ContextConfig {
            caps: CapabilityPolicy::only(["log"]),
            ..Default::default()
        };
        let cluster = Cluster::launch(
            ClusterConfig::builder().workers(1).ctx(gated).build().unwrap(),
            |_, _, _| {},
        )
        .unwrap();
        cluster.leader.library_dir().install(Box::new(CounterIfunc::default()));
        let d = cluster.dispatcher();
        let h = d.register("counter").unwrap();
        let msg = h.msg_create(&SourceArgs::bytes(vec![0u8; 8])).unwrap();
        let text = d.send(Target::All, &msg).unwrap_err().to_string();
        assert!(text.contains("counter_add"), "{text}");
        assert_eq!(cluster.leader.analysis_stats().snapshot().2, 1);
        cluster.shutdown().unwrap();
    }

    #[test]
    fn ring_flow_control_survives_overload() {
        // Tiny rings force constant wrap + credit waits.
        let cluster = Cluster::launch(
            ClusterConfig::builder().workers(1).ring_bytes(4096).build().unwrap(),
            |_, ctx, _| {
                ctx.library_dir().install(Box::new(CounterIfunc::default()));
            },
        )
        .unwrap();
        cluster.leader.library_dir().install(Box::new(CounterIfunc::default()));
        let d = cluster.dispatcher();
        let h = d.register("counter").unwrap();
        let msg = h.msg_create(&SourceArgs::bytes(vec![0u8; 512])).unwrap();
        for key in 0..500u64 {
            d.send(Target::Key(key), &msg).unwrap();
        }
        d.barrier().unwrap();
        assert_eq!(d.total_executed(), 500);
        cluster.shutdown().unwrap();
    }
}
