//! Telemetry: a point-in-time snapshot of every counter the stack keeps —
//! fabric ops, AM progress, ifunc cache, I-cache flushes, worker
//! execution — rendered for `repro serve` stats and operator debugging.

use std::sync::atomic::Ordering;

use crate::ucp::Context;
use crate::util::Json;

use super::Cluster;

/// Counters for one context (one simulated machine).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ContextSnapshot {
    pub node: usize,
    pub fabric_puts: u64,
    pub fabric_gets: u64,
    pub fabric_atomics: u64,
    pub fabric_bytes_in: u64,
    pub fabric_rejected: u64,
    pub cache_entries: usize,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub icache_flushes: u64,
    pub icache_flushed_bytes: u64,
    pub icache_flush_ns: u64,
    /// Dynamic bounds checks the analysis pass elided (per link).
    pub analysis_elided: u64,
    /// Frames refused at link time by the capability gate.
    pub analysis_cap_denials: u64,
    /// Invocations refused by static admission before fan-out (nonzero
    /// on the leader only — workers never dispatch).
    pub analysis_rejections: u64,
}

impl ContextSnapshot {
    pub fn capture(ctx: &Context) -> Self {
        let stats = &ctx.node().stats;
        let ic = ctx.icache_stats();
        let (elided, denials, rejections) = ctx.analysis_stats().snapshot();
        ContextSnapshot {
            node: ctx.node().id(),
            fabric_puts: stats.puts.load(Ordering::Relaxed),
            fabric_gets: stats.gets.load(Ordering::Relaxed),
            fabric_atomics: stats.atomics.load(Ordering::Relaxed),
            fabric_bytes_in: stats.bytes_in.load(Ordering::Relaxed),
            fabric_rejected: stats.rejected.load(Ordering::Relaxed),
            cache_entries: ctx.ifunc_cache().len(),
            cache_hits: ctx.ifunc_cache().hits.load(Ordering::Relaxed),
            cache_misses: ctx.ifunc_cache().misses.load(Ordering::Relaxed),
            icache_flushes: ic.flushes.load(Ordering::Relaxed),
            icache_flushed_bytes: ic.flushed_bytes.load(Ordering::Relaxed),
            icache_flush_ns: ic.flush_ns.load(Ordering::Relaxed),
            analysis_elided: elided,
            analysis_cap_denials: denials,
            analysis_rejections: rejections,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("node", Json::from(self.node)),
            ("puts", Json::from(self.fabric_puts)),
            ("gets", Json::from(self.fabric_gets)),
            ("atomics", Json::from(self.fabric_atomics)),
            ("bytes_in", Json::from(self.fabric_bytes_in)),
            ("rejected", Json::from(self.fabric_rejected)),
            ("cache_entries", Json::from(self.cache_entries)),
            ("cache_hits", Json::from(self.cache_hits)),
            ("cache_misses", Json::from(self.cache_misses)),
            ("icache_flushes", Json::from(self.icache_flushes)),
            ("icache_flush_ns", Json::from(self.icache_flush_ns)),
            ("analysis_elided", Json::from(self.analysis_elided)),
            ("analysis_cap_denials", Json::from(self.analysis_cap_denials)),
            ("analysis_rejections", Json::from(self.analysis_rejections)),
        ])
    }
}

/// Counters for the concurrent serve front-end (`coordinator::frontend`):
/// admission, shedding, and coalescing effectiveness under live
/// multi-client load. Captured by `Frontend::snapshot` and surfaced
/// through the serve `stats` command.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FrontendSnapshot {
    /// Requests admitted into a per-worker submission queue.
    pub submitted: u64,
    /// Responses written back to clients (excludes sheds).
    pub responded: u64,
    /// Requests refused with the overload response before queueing.
    pub shed: u64,
    /// Coalesced batches shipped through `try_invoke_batch`.
    pub batches: u64,
    /// Total operations those batches carried (`batched_ops / batches`
    /// is the mean coalescing factor).
    pub batched_ops: u64,
    /// Batch-size histogram: [1, 2–3, 4–7, 8–15, 16+] frames per batch.
    pub batch_hist: [u64; 5],
    /// Current submission-queue depth per worker.
    pub queue_depth: Vec<usize>,
    /// Currently connected sessions.
    pub clients: usize,
}

impl FrontendSnapshot {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("submitted", Json::from(self.submitted)),
            ("responded", Json::from(self.responded)),
            ("shed", Json::from(self.shed)),
            ("batches", Json::from(self.batches)),
            ("batched_ops", Json::from(self.batched_ops)),
            (
                "batch_hist",
                Json::Arr(self.batch_hist.iter().map(|&n| Json::from(n)).collect()),
            ),
            (
                "queue_depth",
                Json::Arr(self.queue_depth.iter().map(|&n| Json::from(n)).collect()),
            ),
            ("clients", Json::from(self.clients)),
        ])
    }
}

/// Per-worker execution counters in a [`ClusterSnapshot`].
#[derive(Debug, Clone, Default, PartialEq)]
pub struct WorkerSnapshot {
    pub ctx: ContextSnapshot,
    pub executed: u64,
    pub failed: u64,
    /// Frames this worker forwarded onward over the worker↔worker mesh.
    pub forwarded: u64,
    /// Forward attempts that died at this worker (TTL out, mesh
    /// disabled, dead peer).
    pub forward_failed: u64,
    pub records: usize,
}

/// Cluster-wide snapshot: leader + every worker + execution counters.
pub struct ClusterSnapshot {
    pub leader: ContextSnapshot,
    pub workers: Vec<WorkerSnapshot>,
    /// Whether the worker↔worker mesh is wired (`ClusterConfig::mesh`).
    pub mesh: bool,
}

impl ClusterSnapshot {
    pub fn capture(cluster: &Cluster) -> Self {
        ClusterSnapshot {
            leader: ContextSnapshot::capture(&cluster.leader),
            workers: cluster
                .workers
                .iter()
                .map(|w| WorkerSnapshot {
                    ctx: ContextSnapshot::capture(&w.ctx),
                    executed: w.executed(),
                    failed: w.stats.failed.load(Ordering::Relaxed),
                    forwarded: w.forwarded(),
                    forward_failed: w.forward_failed(),
                    records: w.store.len(),
                })
                .collect(),
            mesh: cluster.mesh,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("leader", self.leader.to_json()),
            (
                "workers",
                Json::Arr(
                    self.workers
                        .iter()
                        .map(|w| {
                            Json::obj(vec![
                                ("ctx", w.ctx.to_json()),
                                ("executed", Json::from(w.executed)),
                                ("failed", Json::from(w.failed)),
                                ("forwarded", Json::from(w.forwarded)),
                                ("forward_failed", Json::from(w.forward_failed)),
                                ("records", Json::from(w.records)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "mesh",
                Json::obj(vec![
                    ("enabled", Json::from(self.mesh)),
                    (
                        "forwarded",
                        Json::from(self.workers.iter().map(|w| w.forwarded).sum::<u64>()),
                    ),
                    (
                        "forward_failed",
                        Json::from(
                            self.workers.iter().map(|w| w.forward_failed).sum::<u64>(),
                        ),
                    ),
                ]),
            ),
        ])
    }

    /// Operator-facing summary table.
    pub fn render(&self) -> String {
        let mut out = String::from(
            "worker  executed  failed  fwd  fwd-fail  records  puts-in  rejected  \
             cache h/m  iflush\n",
        );
        for w in &self.workers {
            out.push_str(&format!(
                "{:>6}  {:>8}  {:>6}  {:>3}  {:>8}  {:>7}  {:>7}  {:>8}  {:>5}/{:<4} {:>6}\n",
                w.ctx.node,
                w.executed,
                w.failed,
                w.forwarded,
                w.forward_failed,
                w.records,
                w.ctx.fabric_puts,
                w.ctx.fabric_rejected,
                w.ctx.cache_hits,
                w.ctx.cache_misses,
                w.ctx.icache_flushes,
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{ClusterConfig, Target};
    use crate::ifunc::builtin::CounterIfunc;
    use crate::ifunc::SourceArgs;

    #[test]
    fn snapshot_counts_cluster_activity() {
        let cluster = super::super::Cluster::launch(
            ClusterConfig::builder().workers(2).build().unwrap(),
            |_, ctx, _| {
                ctx.library_dir().install(Box::new(CounterIfunc::default()));
            },
        )
        .unwrap();
        cluster.leader.library_dir().install(Box::new(CounterIfunc::default()));
        let d = cluster.dispatcher();
        let h = d.register("counter").unwrap();
        let msg = h.msg_create(&SourceArgs::bytes(vec![0; 16])).unwrap();
        for key in 0..20 {
            d.send(Target::Key(key), &msg).unwrap();
        }
        d.barrier().unwrap();

        let snap = ClusterSnapshot::capture(&cluster);
        let executed: u64 = snap.workers.iter().map(|w| w.executed).sum();
        assert_eq!(executed, 20);
        let flushes: u64 = snap.workers.iter().map(|w| w.ctx.icache_flushes).sum();
        assert_eq!(flushes, 20, "every arrival pays clear_cache");
        // Each worker auto-registered 'counter' exactly once.
        for w in &snap.workers {
            assert_eq!(w.ctx.cache_misses, 1);
        }
        let json = snap.to_json().to_string();
        assert!(json.contains("\"workers\""));
        assert!(json.contains("\"analysis_elided\""), "{json}");
        assert!(json.contains("\"analysis_rejections\""), "{json}");
        assert!(!snap.render().is_empty());
        cluster.shutdown().unwrap();
    }

    #[test]
    fn snapshot_reports_mesh_forwarding() {
        use crate::ifunc::builtin::HopIfunc;
        let cluster = super::super::Cluster::launch(
            ClusterConfig::builder().workers(2).mesh(true).build().unwrap(),
            |_, ctx, _| {
                ctx.library_dir().install(Box::new(HopIfunc));
            },
        )
        .unwrap();
        cluster.leader.library_dir().install(Box::new(HopIfunc));
        let d = cluster.dispatcher();
        let h = d.register("hop").unwrap();
        let msg = h
            .msg_create(&SourceArgs::bytes(HopIfunc::payload(&[1], b"x")))
            .unwrap();
        assert!(d.invoke_one(Target::Worker(0), &msg).unwrap().ok());

        let snap = ClusterSnapshot::capture(&cluster);
        assert!(snap.mesh);
        assert_eq!(snap.workers[0].forwarded, 1);
        assert_eq!(snap.workers[1].forwarded, 0);
        assert_eq!(snap.workers.iter().map(|w| w.forward_failed).sum::<u64>(), 0);
        let json = snap.to_json().to_string();
        assert!(json.contains("\"mesh\""), "{json}");
        assert!(json.contains("\"enabled\":true"), "{json}");
        assert!(json.contains("\"forwarded\":1"), "{json}");
        cluster.shutdown().unwrap();
    }
}
