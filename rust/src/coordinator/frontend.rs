//! Concurrent serve front-end: pipelined sessions, cross-client
//! coalescing, admission control.
//!
//! The paper's §3.2 database scenario under **production-shaped load**:
//! many coordinating clients hitting a store "so big that it has to be
//! stored on many physical devices" — concurrently. The TCP glue in
//! `serve.rs` is one thread per socket; everything between the socket
//! and the [`Dispatcher`] lives here, so in-process tests and benches
//! drive the identical pipeline without a socket:
//!
//! * **Sessions** ([`Frontend::session`]): each client gets a
//!   [`Session`] (submit side) and a [`SessionReceiver`] (response
//!   side). A session keeps up to `session_window` requests in flight —
//!   the reader parses and submits while a responder drains replies —
//!   so one connection pipelines instead of strict request/reply
//!   lockstep. Responses carry the client-assigned `id` echoed back;
//!   completion is out-of-order by design and the `id` makes that
//!   observable and correct.
//! * **Cross-client coalescing**: submitted operations land in a
//!   bounded per-worker queue; a per-worker drainer ships whatever is
//!   queued the moment the link frees (no fixed timer) as **one**
//!   coalesced batch through [`Dispatcher::try_invoke_batch`] — one
//!   ring-credit reservation + one flush amortized across every client
//!   whose keys hash to that worker.
//! * **Admission control and fairness**: past `queue_high_water` the
//!   submit path sheds immediately with
//!   `{"ok":false,"error":"overloaded","retry":true}` — before any
//!   blocking wait, via the dispatcher's non-blocking window admission —
//!   and the queue drains round-robin across clients, so one firehose
//!   client cannot starve the others.
//! * **Static admission**: every frame the front-end ships was created
//!   from a registered handle, so it carries
//!   [`crate::vm::AdmissionFacts`] and passes through the dispatcher's
//!   static admission gate (fuel floor, capability allowlist). A
//!   rejection surfaces to the client as a normal
//!   `{"ok":false,"error":"static admission: …"}` response — the doomed
//!   program is refused at the leader without ever reaching a worker,
//!   so a misconfigured (or hostile) client cannot burn worker fuel on
//!   invocations the analysis already proved can't succeed.
//!
//! Per-key ordering is preserved end to end: a key always routes to one
//! worker ([`route_key`]), a client's ops for that worker stay in one
//! FIFO lane, the drainer pops lanes in order, and frames post in seq
//! order on one link — so a client's `get` after its own `insert`
//! observes the insert (or a later one), never an earlier state.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::Duration;

use crate::ifunc::{IfuncHandle, IfuncMsg, Reply};
use crate::util::sync::{lock_recover, wait_timeout_recover};
use crate::util::Json;
use crate::{Error, Result};

use super::apps::{GetIfunc, InsertIfunc};
use super::dispatcher::{route_key, PendingReply, Target};
use super::telemetry::FrontendSnapshot;
use super::worker::GET_MISSING;
use super::Cluster;

/// Tuning knobs for the concurrent front-end. All limits must be >= 1
/// ([`Frontend::launch`] validates).
#[derive(Clone, Debug)]
pub struct FrontendConfig {
    /// Concurrent session cap: [`Frontend::session`] refuses past it.
    pub max_clients: usize,
    /// Per-session in-flight request window ([`Session::submit`] blocks
    /// past it — per-client backpressure, distinct from shedding).
    pub session_window: usize,
    /// Per-worker submission-queue high-water mark: submits shed with
    /// the overload response once a queue holds this many ops.
    pub queue_high_water: usize,
    /// Most frames one coalesced batch carries.
    pub batch_max: usize,
    /// Coalesce across clients (default). Off = every submit is a
    /// synchronous `invoke_one`, the pre-pipeline behavior — kept so
    /// Abl K can price exactly this delta.
    pub coalesce: bool,
}

impl Default for FrontendConfig {
    fn default() -> Self {
        FrontendConfig {
            max_clients: 64,
            session_window: 16,
            queue_high_water: 256,
            batch_max: 16,
            coalesce: true,
        }
    }
}

/// Live counters (all relaxed — monotone telemetry, not synchronization).
#[derive(Default)]
pub struct FrontendStats {
    pub submitted: AtomicU64,
    pub responded: AtomicU64,
    pub shed: AtomicU64,
    pub batches: AtomicU64,
    pub batched_ops: AtomicU64,
    /// Batch-size buckets: [1, 2–3, 4–7, 8–15, 16+].
    pub batch_hist: [AtomicU64; 5],
}

impl FrontendStats {
    fn record_batch(&self, n: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_ops.fetch_add(n as u64, Ordering::Relaxed);
        let bucket = match n {
            0 | 1 => 0,
            2..=3 => 1,
            4..=7 => 2,
            8..=15 => 3,
            _ => 4,
        };
        self.batch_hist[bucket].fetch_add(1, Ordering::Relaxed);
    }
}

/// Per-session in-flight window: bounds how far one client's reader can
/// run ahead of its responder.
struct SessionWindow {
    inflight: Mutex<usize>,
    freed: Condvar,
}

impl SessionWindow {
    fn new() -> Self {
        SessionWindow { inflight: Mutex::new(0), freed: Condvar::new() }
    }

    /// Claim a slot; blocks while `max` responses are outstanding.
    /// Returns `false` (without claiming) once `stop` is set, so a
    /// shutdown never strands a submitting reader.
    fn acquire(&self, max: usize, stop: &AtomicBool) -> bool {
        let mut n = lock_recover(&self.inflight);
        loop {
            if stop.load(Ordering::Acquire) {
                return false;
            }
            if *n < max {
                *n += 1;
                return true;
            }
            n = wait_timeout_recover(&self.freed, n, Duration::from_millis(1));
        }
    }

    fn release(&self) {
        let mut n = lock_recover(&self.inflight);
        *n = n.saturating_sub(1);
        drop(n);
        self.freed.notify_all();
    }
}

/// What a queued operation needs to produce its response.
enum OpKind {
    Insert,
    Get,
}

/// Response-routing context carried with every queued op: where the
/// response goes, which `id` to echo, and which session window slot to
/// free.
struct OpCtx {
    kind: OpKind,
    worker: usize,
    id: Option<Json>,
    resp: mpsc::Sender<Json>,
    window: Arc<SessionWindow>,
}

struct QueuedOp {
    ctx: OpCtx,
    msg: IfuncMsg,
}

/// One drained-and-shipped batch: each op paired with its in-flight
/// reply, handed from the drainer to the reaper.
type ReapBatch = Vec<(OpCtx, PendingReply)>;

/// Per-client FIFO lanes + a round-robin cursor.
#[derive(Default)]
struct Lanes {
    lanes: Vec<(u64, VecDeque<QueuedOp>)>,
    rr: usize,
}

/// Bounded per-worker submission queue: per-client lanes drained
/// round-robin (fairness), depth mirrored in an atomic for the lock-free
/// shed check.
struct WorkerQueue {
    depth: AtomicUsize,
    state: Mutex<Lanes>,
    ready: Condvar,
}

impl WorkerQueue {
    fn new() -> Self {
        WorkerQueue {
            depth: AtomicUsize::new(0),
            state: Mutex::new(Lanes::default()),
            ready: Condvar::new(),
        }
    }

    fn depth(&self) -> usize {
        self.depth.load(Ordering::Relaxed)
    }

    fn push(&self, client: u64, op: QueuedOp) {
        // Increment before the op becomes visible so a concurrent
        // pop_batch's decrement can never underflow the mirror.
        self.depth.fetch_add(1, Ordering::Relaxed);
        let mut st = lock_recover(&self.state);
        match st.lanes.iter_mut().find(|(c, _)| *c == client) {
            Some((_, lane)) => lane.push_back(op),
            None => st.lanes.push((client, VecDeque::from([op]))),
        }
        drop(st);
        self.ready.notify_all();
    }

    /// Pop up to `max` ops, one per lane per rotation — a firehose
    /// client's lane yields between every other client's, so fairness
    /// is structural, not scheduled. Emptied lanes are removed (a
    /// returning client starts a fresh lane at the back).
    fn pop_batch(&self, max: usize) -> Vec<QueuedOp> {
        let mut st = lock_recover(&self.state);
        let mut out = Vec::new();
        while out.len() < max && !st.lanes.is_empty() {
            if st.rr >= st.lanes.len() {
                st.rr = 0;
            }
            let i = st.rr;
            if let Some(op) = st.lanes[i].1.pop_front() {
                out.push(op);
            }
            if st.lanes[i].1.is_empty() {
                st.lanes.remove(i);
            } else {
                st.rr = i + 1;
            }
        }
        self.depth.fetch_sub(out.len(), Ordering::Relaxed);
        out
    }

    /// Park until a push signals (or `timeout`), if currently empty.
    fn wait_ready(&self, timeout: Duration) {
        let st = lock_recover(&self.state);
        if st.lanes.is_empty() {
            let _ = wait_timeout_recover(&self.ready, st, timeout);
        }
    }
}

/// Everything the session/drainer/reaper threads share.
struct Shared {
    cluster: Arc<Cluster>,
    insert: IfuncHandle,
    get: IfuncHandle,
    config: FrontendConfig,
    queues: Vec<WorkerQueue>,
    stats: FrontendStats,
    stop: AtomicBool,
    active: AtomicUsize,
    next_client: AtomicU64,
}

/// The running front-end: owns the per-worker drainer + reaper threads
/// and hands out sessions. Shut down (or drop) the `Frontend` *before*
/// the cluster — its threads hold `Arc<Cluster>` and need live workers
/// to collect outstanding replies.
pub struct Frontend {
    shared: Arc<Shared>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl Frontend {
    /// Install + register the serve ifuncs and start the per-worker
    /// coalescing pipeline (`coalesce: false` skips the threads — every
    /// submit then invokes synchronously).
    pub fn launch(cluster: Arc<Cluster>, config: FrontendConfig) -> Result<Frontend> {
        if config.max_clients == 0
            || config.session_window == 0
            || config.queue_high_water == 0
            || config.batch_max == 0
        {
            return Err(Error::Other(
                "FrontendConfig: max_clients / session_window / queue_high_water / \
                 batch_max must all be >= 1"
                    .into(),
            ));
        }
        cluster.leader.library_dir().install(Box::new(InsertIfunc));
        cluster.leader.library_dir().install(Box::new(GetIfunc));
        let shared = Arc::new(Shared {
            insert: cluster.leader.register_ifunc("insert")?,
            get: cluster.leader.register_ifunc("get")?,
            queues: (0..cluster.workers.len()).map(|_| WorkerQueue::new()).collect(),
            config,
            cluster,
            stats: FrontendStats::default(),
            stop: AtomicBool::new(false),
            active: AtomicUsize::new(0),
            next_client: AtomicU64::new(0),
        });
        let mut threads = Vec::new();
        if shared.config.coalesce {
            for w in 0..shared.cluster.workers.len() {
                let (tx, rx) = mpsc::channel::<ReapBatch>();
                let s = shared.clone();
                threads.push(
                    std::thread::Builder::new()
                        .name(format!("serve-drain-{w}"))
                        .spawn(move || drain_loop(&s, w, &tx))
                        .map_err(|e| Error::Other(format!("spawn drainer: {e}")))?,
                );
                let s = shared.clone();
                threads.push(
                    std::thread::Builder::new()
                        .name(format!("serve-reap-{w}"))
                        .spawn(move || reap_loop(&s, rx))
                        .map_err(|e| Error::Other(format!("spawn reaper: {e}")))?,
                );
            }
        }
        Ok(Frontend { shared, threads })
    }

    /// Open a session: the [`Session`] submits (give it to the reader),
    /// the [`SessionReceiver`] yields responses (give it to the
    /// responder). Refuses with [`Error::NoResource`] past
    /// `max_clients`.
    pub fn session(&self) -> Result<(Session, SessionReceiver)> {
        let prev = self.shared.active.fetch_add(1, Ordering::AcqRel);
        if prev >= self.shared.config.max_clients {
            self.shared.active.fetch_sub(1, Ordering::AcqRel);
            return Err(Error::NoResource(format!(
                "server at capacity ({} clients); retry later",
                self.shared.config.max_clients
            )));
        }
        let (tx, rx) = mpsc::channel();
        let session = Session {
            shared: self.shared.clone(),
            client: self.shared.next_client.fetch_add(1, Ordering::Relaxed),
            resp: tx,
            window: Arc::new(SessionWindow::new()),
        };
        Ok((session, SessionReceiver { rx }))
    }

    /// Point-in-time front-end counters (also inside the `stats`
    /// command's response, under `"frontend"`).
    pub fn snapshot(&self) -> FrontendSnapshot {
        snapshot_of(&self.shared)
    }

    /// Stop the drainer/reaper threads and join them. Ops still queued
    /// are answered with a shutdown error, never silently dropped.
    pub fn shutdown(mut self) {
        self.stop_threads();
    }

    fn stop_threads(&mut self) {
        self.shared.stop.store(true, Ordering::Release);
        for q in &self.shared.queues {
            // Lock-then-notify: a drainer between its empty-check and its
            // wait must observe the flag or the wakeup, never neither.
            drop(lock_recover(&q.state));
            q.ready.notify_all();
        }
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl Drop for Frontend {
    fn drop(&mut self) {
        self.stop_threads();
    }
}

/// The submit half of one client connection. Not `Sync` (single reader
/// thread per client); moving it to that thread is the intended use.
pub struct Session {
    shared: Arc<Shared>,
    client: u64,
    resp: mpsc::Sender<Json>,
    window: Arc<SessionWindow>,
}

impl Session {
    /// Submit one protocol line. Every non-blank line produces exactly
    /// one response on the paired [`SessionReceiver`] — possibly out of
    /// order with other submissions (match on `id`). Returns `false`
    /// only for blank lines (no response owed). Blocks only when this
    /// session already has `session_window` responses outstanding.
    pub fn submit(&self, line: &str) -> bool {
        if line.trim().is_empty() {
            return false;
        }
        let req = match Json::parse(line) {
            Ok(j) => j,
            Err(e) => {
                self.push(err_json(&format!("bad request: {e}")), &None);
                return true;
            }
        };
        let id = req.get("id").cloned();
        match req.get("cmd").and_then(|c| c.as_str()) {
            Some("insert") => {
                let Some(key) = req.get("key").and_then(|k| k.as_u64()) else {
                    self.push(err_json("insert needs numeric key"), &id);
                    return true;
                };
                let Some(data) = req.get("data").and_then(|v| v.as_f32_vec()) else {
                    self.push(err_json("insert needs data array"), &id);
                    return true;
                };
                match self.shared.insert.msg_create(&InsertIfunc::args(key, &data)) {
                    Ok(msg) => self.dispatch(OpKind::Insert, key, msg, id),
                    Err(e) => self.push(err_json(&e.to_string()), &id),
                }
            }
            Some("get") => {
                let Some(key) = req.get("key").and_then(|k| k.as_u64()) else {
                    self.push(err_json("get needs numeric key"), &id);
                    return true;
                };
                match self.shared.get.msg_create(&GetIfunc::args(key)) {
                    Ok(msg) => self.dispatch(OpKind::Get, key, msg, id),
                    Err(e) => self.push(err_json(&e.to_string()), &id),
                }
            }
            Some("stats") => self.push(stats_json(&self.shared), &id),
            _ => self.push(err_json("unknown cmd (insert/get/stats)"), &id),
        }
        true
    }

    /// Route one store op. Coalescing on: shed-or-queue (admission
    /// control happens *here*, before any blocking wait). Coalescing
    /// off: the pre-pipeline synchronous path, one blocking invocation.
    fn dispatch(&self, kind: OpKind, key: u64, msg: IfuncMsg, id: Option<Json>) {
        let shared = &self.shared;
        let worker = route_key(key, shared.cluster.workers.len());
        if !shared.config.coalesce {
            shared.stats.submitted.fetch_add(1, Ordering::Relaxed);
            let d = shared.cluster.dispatcher();
            let resp = response_for(&kind, worker, d.invoke_one(Target::Worker(worker), &msg));
            shared.stats.responded.fetch_add(1, Ordering::Relaxed);
            self.push(resp, &id);
            return;
        }
        if shared.queues[worker].depth() >= shared.config.queue_high_water {
            shared.stats.shed.fetch_add(1, Ordering::Relaxed);
            self.push(overloaded_json(), &id);
            return;
        }
        if !self.window.acquire(shared.config.session_window, &shared.stop) {
            self.push(err_json("server shutting down"), &id);
            return;
        }
        shared.stats.submitted.fetch_add(1, Ordering::Relaxed);
        shared.queues[worker].push(
            self.client,
            QueuedOp {
                ctx: OpCtx {
                    kind,
                    worker,
                    id,
                    resp: self.resp.clone(),
                    window: self.window.clone(),
                },
                msg,
            },
        );
    }

    fn push(&self, resp: Json, id: &Option<Json>) {
        // A gone receiver just discards the response; the session-level
        // error surfaces at the socket, not here.
        let _ = self.resp.send(attach_id(resp, id));
    }
}

impl Drop for Session {
    fn drop(&mut self) {
        self.shared.active.fetch_sub(1, Ordering::AcqRel);
    }
}

/// The response half of one client connection: yields responses in
/// completion order (match them to requests by `id`).
pub struct SessionReceiver {
    rx: mpsc::Receiver<Json>,
}

impl SessionReceiver {
    /// Next response, waiting up to `timeout`. `None` on timeout or a
    /// closed session.
    pub fn recv_timeout(&self, timeout: Duration) -> Option<Json> {
        self.rx.recv_timeout(timeout).ok()
    }

    /// Next already-arrived response, if any.
    pub fn try_recv(&self) -> Option<Json> {
        self.rx.try_recv().ok()
    }
}

/// Per-worker drainer: pop whatever is queued the moment the invoke
/// window has room, ship it as one coalesced batch. When the window is
/// saturated the drainer *polls* (the ops are already admitted — they
/// must not be shed, and blocking inside the window would serialize the
/// queue behind the slowest reply).
fn drain_loop(shared: &Shared, worker: usize, reaped: &mpsc::Sender<ReapBatch>) {
    let d = shared.cluster.dispatcher();
    loop {
        let ops = shared.queues[worker].pop_batch(shared.config.batch_max);
        if ops.is_empty() {
            if shared.stop.load(Ordering::Acquire) {
                return;
            }
            shared.queues[worker].wait_ready(Duration::from_millis(5));
            continue;
        }
        let mut ctxs: VecDeque<OpCtx> = VecDeque::with_capacity(ops.len());
        let mut msgs: Vec<IfuncMsg> = Vec::with_capacity(ops.len());
        for op in ops {
            ctxs.push_back(op.ctx);
            msgs.push(op.msg);
        }
        let mut idx = 0;
        while idx < msgs.len() {
            match d.try_invoke_batch(Target::Worker(worker), &msgs[idx..]) {
                Ok(pending) if pending.is_empty() => {
                    if shared.stop.load(Ordering::Acquire) {
                        fail_all(shared, ctxs, "server shutting down");
                        return;
                    }
                    // Window full: slots free as the reaper collects.
                    std::thread::sleep(Duration::from_micros(200));
                }
                Ok(pending) => {
                    let n = pending.len();
                    shared.stats.record_batch(n);
                    let batch: ReapBatch = pending
                        .into_iter()
                        .map(|p| (ctxs.pop_front().expect("ctx per pending"), p))
                        .collect();
                    if reaped.send(batch).is_err() {
                        // Reaper gone (shutdown torn the channel down).
                        fail_all(shared, ctxs, "server shutting down");
                        return;
                    }
                    idx += n;
                }
                Err(e) => {
                    // Delivery failure: answer every op of this popped
                    // batch that has not shipped, keep serving the queue.
                    fail_all(shared, std::mem::take(&mut ctxs), &e.to_string());
                    idx = msgs.len();
                }
            }
        }
    }
}

/// Reaper: waits each shipped op's reply (off the link lock — the
/// drainer keeps posting meanwhile) and writes the response back.
fn reap_loop(shared: &Shared, rx: mpsc::Receiver<ReapBatch>) {
    for batch in rx {
        for (ctx, p) in batch {
            let resp = response_for(&ctx.kind, ctx.worker, p.wait());
            respond(shared, ctx, resp);
        }
    }
}

fn fail_all(shared: &Shared, ctxs: impl IntoIterator<Item = OpCtx>, msg: &str) {
    for ctx in ctxs {
        respond(shared, ctx, err_json(msg));
    }
}

/// Deliver a response for a queued op: echo the `id`, free the session
/// window slot, count it.
fn respond(shared: &Shared, ctx: OpCtx, resp: Json) {
    // Count before sending: a client that reads its response and
    // immediately asks for `stats` must see this op as responded.
    shared.stats.responded.fetch_add(1, Ordering::Relaxed);
    let _ = ctx.resp.send(attach_id(resp, &ctx.id));
    ctx.window.release();
}

/// Build the JSON response for a completed invocation — the single
/// source of truth for the insert/get reply shapes, shared by the
/// coalesced and synchronous paths.
fn response_for(kind: &OpKind, worker: usize, result: Result<Reply>) -> Json {
    match kind {
        OpKind::Insert => match result {
            Ok(r) if r.ok() => {
                Json::obj(vec![("ok", Json::Bool(true)), ("worker", Json::from(worker))])
            }
            Ok(_) => err_json("insert ifunc rejected on worker"),
            Err(e) => err_json(&e.to_string()),
        },
        OpKind::Get => match result {
            Ok(r) if r.ok() && r.r0 != GET_MISSING => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("worker", Json::from(worker)),
                ("data", Json::arr_f32(&r.payload_f32s())),
            ]),
            Ok(r) if r.overflowed() => {
                // Only reachable on a stream_replies: false cluster
                // (serve always streams); kept for wire compat.
                err_json("record too large for this link (reply streaming disabled)")
            }
            Ok(r) if r.ok() => err_json("not found"),
            Ok(_) => err_json("get ifunc rejected on worker"),
            Err(e) => err_json(&e.to_string()),
        },
    }
}

pub(crate) fn err_json(msg: &str) -> Json {
    Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::from(msg))])
}

/// The load-shed response: `retry: true` tells a well-behaved client to
/// back off and resubmit — the request was refused *before* consuming
/// any worker resources.
fn overloaded_json() -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("error", Json::from("overloaded")),
        ("retry", Json::Bool(true)),
    ])
}

/// Echo the client-assigned request `id` (any JSON value) into a
/// response object.
fn attach_id(mut resp: Json, id: &Option<Json>) -> Json {
    if let (Json::Obj(map), Some(id)) = (&mut resp, id) {
        map.insert("id".to_string(), id.clone());
    }
    resp
}

fn snapshot_of(shared: &Shared) -> FrontendSnapshot {
    let s = &shared.stats;
    FrontendSnapshot {
        submitted: s.submitted.load(Ordering::Relaxed),
        responded: s.responded.load(Ordering::Relaxed),
        shed: s.shed.load(Ordering::Relaxed),
        batches: s.batches.load(Ordering::Relaxed),
        batched_ops: s.batched_ops.load(Ordering::Relaxed),
        batch_hist: [
            s.batch_hist[0].load(Ordering::Relaxed),
            s.batch_hist[1].load(Ordering::Relaxed),
            s.batch_hist[2].load(Ordering::Relaxed),
            s.batch_hist[3].load(Ordering::Relaxed),
            s.batch_hist[4].load(Ordering::Relaxed),
        ],
        queue_depth: shared.queues.iter().map(|q| q.depth()).collect(),
        clients: shared.active.load(Ordering::Relaxed),
    }
}

/// The `stats` command's response: cluster execution counters plus the
/// front-end's own admission/coalescing telemetry.
fn stats_json(shared: &Shared) -> Json {
    let d = shared.cluster.dispatcher();
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("executed", Json::from(d.total_executed())),
        (
            "per_worker",
            Json::Arr(shared.cluster.workers.iter().map(|w| Json::from(w.executed())).collect()),
        ),
        (
            "records",
            Json::from(shared.cluster.workers.iter().map(|w| w.store.len()).sum::<usize>()),
        ),
        (
            "mesh",
            Json::obj(vec![
                ("enabled", Json::from(shared.cluster.mesh)),
                (
                    "forwarded",
                    Json::from(
                        shared.cluster.workers.iter().map(|w| w.forwarded()).sum::<u64>(),
                    ),
                ),
                (
                    "forward_failed",
                    Json::from(
                        shared
                            .cluster
                            .workers
                            .iter()
                            .map(|w| w.forward_failed())
                            .sum::<u64>(),
                    ),
                ),
            ]),
        ),
        ("frontend", snapshot_of(shared).to_json()),
    ])
}

#[cfg(test)]
mod tests {
    use super::super::{ClusterConfig, TransportKind};
    use super::*;

    fn frontend_on(
        workers: usize,
        transport: TransportKind,
        config: FrontendConfig,
    ) -> (Arc<Cluster>, Frontend) {
        let cluster = Arc::new(
            Cluster::launch(
                ClusterConfig::builder().workers(workers).transport(transport).build().unwrap(),
                |_, _, _| {},
            )
            .unwrap(),
        );
        let fe = Frontend::launch(cluster.clone(), config).unwrap();
        (cluster, fe)
    }

    /// The full JSON protocol through a pipelined session (no socket): a
    /// record well past one reply frame (80 KB > 64 KiB) inserts to its
    /// owning worker and streams back intact through `get` — over every
    /// serve transport, with `id`s echoed back on each response.
    #[test]
    fn session_roundtrips_a_big_record_with_ids() {
        for transport in TransportKind::ALL {
            let (_cluster, fe) = frontend_on(2, transport, FrontendConfig::default());
            let (session, responses) = fe.session().unwrap();
            let n = 20_000usize; // 80 KB of f32s — past the old inline cap
            let data: String =
                (0..n).map(|i| format!("{}", i % 17)).collect::<Vec<_>>().join(",");
            assert!(session
                .submit(&format!("{{\"id\":1,\"cmd\":\"insert\",\"key\":7,\"data\":[{data}]}}")));
            let resp = responses.recv_timeout(Duration::from_secs(10)).unwrap();
            assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{transport:?}: {resp}");
            assert_eq!(resp.get("id"), Some(&Json::Num(1.0)), "{transport:?}");

            assert!(session.submit("{\"id\":\"g\",\"cmd\":\"get\",\"key\":7}"));
            let resp = responses.recv_timeout(Duration::from_secs(10)).unwrap();
            assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{transport:?}: {resp}");
            assert_eq!(resp.get("id").and_then(|i| i.as_str()), Some("g"), "{transport:?}");
            let got = resp.get("data").unwrap().as_f32_vec().unwrap();
            let want: Vec<f32> = (0..n).map(|i| (i % 17) as f32).collect();
            assert_eq!(got, want, "{transport:?}");

            assert!(session.submit("{\"cmd\":\"get\",\"key\":999}"));
            let resp = responses.recv_timeout(Duration::from_secs(10)).unwrap();
            assert_eq!(resp.get("ok"), Some(&Json::Bool(false)), "{transport:?}: {resp}");
            drop(session);
            fe.shutdown();
        }
    }

    /// `max_clients` is a hard cap: the refusal is immediate and names
    /// the limit, and closing a session frees its slot.
    #[test]
    fn session_cap_refuses_then_recovers() {
        let (_cluster, fe) =
            frontend_on(1, TransportKind::Ring, FrontendConfig { max_clients: 1, ..Default::default() });
        let first = fe.session().unwrap();
        let err = fe.session().expect_err("second session must be refused");
        assert!(err.to_string().contains("capacity"), "{err}");
        drop(first);
        let _ok = fe.session().expect("freed slot must admit");
        fe.shutdown();
    }

    /// `stats` surfaces the front-end counters alongside the cluster's.
    #[test]
    fn stats_reports_frontend_counters() {
        let (_cluster, fe) = frontend_on(2, TransportKind::Shm, FrontendConfig::default());
        let (session, responses) = fe.session().unwrap();
        assert!(session.submit("{\"cmd\":\"insert\",\"key\":3,\"data\":[1.5]}"));
        let resp = responses.recv_timeout(Duration::from_secs(10)).unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp}");
        assert!(session.submit("{\"cmd\":\"stats\"}"));
        let stats = responses.recv_timeout(Duration::from_secs(10)).unwrap();
        assert_eq!(stats.get("ok"), Some(&Json::Bool(true)), "{stats}");
        let fe_stats = stats.get("frontend").expect("frontend block");
        assert_eq!(fe_stats.get("submitted").and_then(|v| v.as_u64()), Some(1), "{stats}");
        assert_eq!(fe_stats.get("responded").and_then(|v| v.as_u64()), Some(1), "{stats}");
        assert_eq!(fe_stats.get("shed").and_then(|v| v.as_u64()), Some(0), "{stats}");
        assert!(fe_stats.get("batch_hist").is_some(), "{stats}");
        // The mesh block is always present; on a mesh-less serve cluster
        // it reports disabled with zeroed forward counters.
        let mesh = stats.get("mesh").expect("mesh block");
        assert_eq!(mesh.get("enabled"), Some(&Json::Bool(false)), "{stats}");
        assert_eq!(mesh.get("forwarded").and_then(|v| v.as_u64()), Some(0), "{stats}");
        assert_eq!(mesh.get("forward_failed").and_then(|v| v.as_u64()), Some(0), "{stats}");
        assert_eq!(fe.snapshot().submitted, 1);
        drop(session);
        fe.shutdown();
    }

    /// Blank lines owe no response; malformed and unknown requests owe
    /// exactly one error each, with the `id` echoed when parseable.
    #[test]
    fn error_paths_echo_ids_and_owe_one_response() {
        let (_cluster, fe) = frontend_on(1, TransportKind::Ring, FrontendConfig::default());
        let (session, responses) = fe.session().unwrap();
        assert!(!session.submit("   "));
        assert!(session.submit("{not json"));
        let resp = responses.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        assert!(session.submit("{\"id\":9,\"cmd\":\"frobnicate\"}"));
        let resp = responses.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)), "{resp}");
        assert_eq!(resp.get("id"), Some(&Json::Num(9.0)), "{resp}");
        assert!(session.submit("{\"id\":10,\"cmd\":\"insert\",\"key\":1}"));
        let resp = responses.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(resp.get("error").and_then(|e| e.as_str()), Some("insert needs data array"));
        assert_eq!(resp.get("id"), Some(&Json::Num(10.0)), "{resp}");
        drop(session);
        fe.shutdown();
    }
}
