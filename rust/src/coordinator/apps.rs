//! Application ifunc libraries built on the AOT artifacts — the paper's
//! §3.2 example (Listing 1.3) realized end-to-end.
//!
//! [`DecodeInsertIfunc`] is the `paq8px` library analog:
//! * source side: `payload_init` **encodes** the record with the
//!   `delta_enc` artifact (via this process's PJRT runtime) and packs
//!   `[key u64][encoded f32[4096]][spare]`,
//! * shipped code: `xla_exec` the `dbdec` artifact (decode + checksum,
//!   one fused HLO), then `db_insert` the decoded record under the key —
//!   both through the GOT,
//! * the `dbdec` HLO text itself travels **inside the message**, so the
//!   target needs no artifact files (the paper's §5.1 vision).

use std::path::Path;

use crate::ifunc::{CodeImage, IfuncLibrary, SourceArgs};
use crate::runtime::with_runtime;
use crate::vm::Assembler;
use crate::{Error, Result};

/// Record samples (must match `python/compile/model.py::SIGNAL_N`).
pub const SIGNAL_N: usize = 4096;
/// Decoded output elements: record + 2 checksum words.
pub const DEC_OUT: usize = SIGNAL_N + 2;

/// Payload layout: `[key u64][f32 x SIGNAL_N][2 spare f32]`.
const KEY_BYTES: usize = 8;
const PAYLOAD_BYTES: usize = KEY_BYTES + DEC_OUT * 4;

pub struct DecodeInsertIfunc {
    dbdec_hlo: Vec<u8>,
}

impl DecodeInsertIfunc {
    /// Load the `dbdec` artifact (and ensure `delta_enc` is compiled for
    /// the source-side encode step).
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        let dbdec_hlo = std::fs::read(artifacts_dir.join("dbdec.hlo.txt")).map_err(|e| {
            Error::Other(format!(
                "missing dbdec artifact in {artifacts_dir:?} (run `python -m compile.aot`): {e}"
            ))
        })?;
        with_runtime(|rt| {
            rt.ensure_compiled_file("delta_enc", &artifacts_dir.join("delta_enc.hlo.txt"))
        })?;
        Ok(DecodeInsertIfunc { dbdec_hlo })
    }

    /// Pack `(key, record)` into source args for `msg_create`.
    pub fn args(key: u64, record: &[f32]) -> SourceArgs {
        assert_eq!(record.len(), SIGNAL_N, "record must be {SIGNAL_N} samples");
        let mut bytes = Vec::with_capacity(KEY_BYTES + record.len() * 4);
        bytes.extend_from_slice(&key.to_le_bytes());
        for v in record {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        SourceArgs::bytes(bytes)
    }
}

/// A plain (no-HLO) store-insert ifunc: payload = `[key u64][f32 data...]`;
/// main reads the key from the payload and calls `db_insert` through the
/// GOT. Used by `repro serve` for uncompressed records.
pub struct InsertIfunc;

impl InsertIfunc {
    /// Pack an insert request payload.
    pub fn args(key: u64, data: &[f32]) -> SourceArgs {
        let mut bytes = Vec::with_capacity(8 + data.len() * 4);
        bytes.extend_from_slice(&key.to_le_bytes());
        for v in data {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        SourceArgs::bytes(bytes)
    }
}

/// Key-lookup ifunc for the serve path's `get`: payload = `[key u64]`;
/// main reads the key and calls the worker-side `db_get` GOT symbol, which
/// pushes the record's bytes into the invocation's **reply payload** and
/// returns the element count in `r0`
/// ([`crate::coordinator::GET_MISSING`] when absent). Paired with
/// `Dispatcher::invoke_one` / `fetch`, the record arrives in the reply —
/// one frame when it fits, a chunked stream when it does not, so record
/// size never changes API behavior — computed and shipped *by the
/// injected function on the worker*, with no leader-side store access and
/// no shared result region.
pub struct GetIfunc;

impl GetIfunc {
    /// Pack a lookup request payload.
    pub fn args(key: u64) -> SourceArgs {
        SourceArgs::bytes(key.to_le_bytes().to_vec())
    }
}

/// Shard-local filter ifunc — the collective-invocation demo workload
/// (the paper's closing motivation: data too big for one device, so the
/// *query* moves to every shard). Payload = `[threshold f32 bits as u64]`;
/// main reads it and calls the worker-side `db_filter` GOT symbol, which
/// scans only the records *this* worker owns and pushes each match as
/// `[key u64][first f32]` into the reply payload (`r0` = match count).
/// Injected once and fanned out with `Dispatcher::invoke_all`, the
/// per-worker replies merge at the leader with worker attribution — a
/// full-cluster scan where only matches travel the fabric.
pub struct FilterIfunc;

impl FilterIfunc {
    /// Pack a filter request payload: the f32 threshold as its raw bit
    /// pattern (widened to u64, little-endian — what `db_filter`
    /// expects in its first argument register).
    pub fn args(threshold: f32) -> SourceArgs {
        SourceArgs::bytes((threshold.to_bits() as u64).to_le_bytes().to_vec())
    }

    /// Decode one worker's reply payload into `(key, first_element)`
    /// matches (the leader-side half of the merge).
    pub fn matches(payload: &[u8]) -> Vec<(u64, f32)> {
        payload
            .chunks_exact(12)
            .map(|c| {
                let key = u64::from_le_bytes(c[..8].try_into().unwrap());
                let v = f32::from_le_bytes(c[8..].try_into().unwrap());
                (key, v)
            })
            .collect()
    }
}

impl IfuncLibrary for FilterIfunc {
    fn name(&self) -> &str {
        "filter"
    }

    fn payload_get_max_size(&self, source_args: &SourceArgs) -> usize {
        source_args.len()
    }

    fn payload_init(&self, payload: &mut [u8], source_args: &SourceArgs) -> Result<usize> {
        payload[..source_args.len()].copy_from_slice(source_args.as_bytes());
        Ok(source_args.len())
    }

    fn code(&self) -> CodeImage {
        let mut a = Assembler::new();
        a.ldi(2, 0);
        a.ldw(1, 2, 0, 0); // r1 = threshold bits (payload[0..8])
        a.call("db_filter"); // r0 = shard-local match count
        a.halt();
        let (vm_code, imports) = a.assemble();
        CodeImage { imports, vm_code, hlo: vec![] }
    }
}

impl IfuncLibrary for GetIfunc {
    fn name(&self) -> &str {
        "get"
    }

    fn payload_get_max_size(&self, source_args: &SourceArgs) -> usize {
        source_args.len()
    }

    fn payload_init(&self, payload: &mut [u8], source_args: &SourceArgs) -> Result<usize> {
        payload[..source_args.len()].copy_from_slice(source_args.as_bytes());
        Ok(source_args.len())
    }

    fn code(&self) -> CodeImage {
        let mut a = Assembler::new();
        a.ldi(2, 0);
        a.ldw(1, 2, 0, 0); // r1 = key (payload[0..8])
        a.call("db_get"); // r0 = n_elems shipped to the leader (or MISSING)
        a.halt();
        let (vm_code, imports) = a.assemble();
        CodeImage { imports, vm_code, hlo: vec![] }
    }
}

impl IfuncLibrary for InsertIfunc {
    fn name(&self) -> &str {
        "insert"
    }

    fn payload_get_max_size(&self, source_args: &SourceArgs) -> usize {
        source_args.len()
    }

    fn payload_init(&self, payload: &mut [u8], source_args: &SourceArgs) -> Result<usize> {
        payload[..source_args.len()].copy_from_slice(source_args.as_bytes());
        Ok(source_args.len())
    }

    fn code(&self) -> CodeImage {
        let mut a = Assembler::new();
        a.ldi(2, 0);
        a.ldw(1, 2, 0, 0); // r1 = key (payload[0..8])
        a.ldi(2, 8); // r2 = f32 data byte offset
        a.paylen(3);
        a.ldi(5, 8);
        a.sub(3, 3, 5);
        a.ldi(5, 4);
        a.divu(3, 3, 5); // r3 = (len-8)/4 f32 elements
        a.call("db_insert");
        a.halt();
        let (vm_code, imports) = a.assemble();
        CodeImage { imports, vm_code, hlo: vec![] }
    }
}

impl IfuncLibrary for DecodeInsertIfunc {
    fn name(&self) -> &str {
        // Registered under the artifact's name so the target's PJRT cache
        // keys the executable correctly.
        "dbdec"
    }

    fn payload_get_max_size(&self, _source_args: &SourceArgs) -> usize {
        PAYLOAD_BYTES
    }

    /// Listing 1.3's `payload_init`: **encode** the record into the frame.
    fn payload_init(&self, payload: &mut [u8], source_args: &SourceArgs) -> Result<usize> {
        let src = source_args.as_bytes();
        if src.len() != KEY_BYTES + SIGNAL_N * 4 {
            return Err(Error::InvalidMessage(format!(
                "dbdec source args must be key + {SIGNAL_N} f32 samples (got {} bytes)",
                src.len()
            )));
        }
        // Key passes through verbatim.
        payload[..KEY_BYTES].copy_from_slice(&src[..KEY_BYTES]);
        // Source-side compress (delta_enc artifact on this process's PJRT).
        let record: Vec<f32> = src[KEY_BYTES..]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        let encoded = with_runtime(|rt| rt.execute_f32("delta_enc", &record, &[SIGNAL_N as i64]))?;
        for (i, v) in encoded.iter().enumerate() {
            payload[KEY_BYTES + i * 4..KEY_BYTES + i * 4 + 4]
                .copy_from_slice(&v.to_le_bytes());
        }
        // Reserve room for the checksum words the decode step appends.
        Ok(PAYLOAD_BYTES)
    }

    /// Listing 1.3's `main`: decode + checksum (xla_exec on the shipped
    /// HLO) then insert under the key.
    fn code(&self) -> CodeImage {
        let mut a = Assembler::new();
        // r6 = key = payload[0..8]
        a.ldi(5, 0);
        a.ldw(6, 5, 0, 0);
        // xla_exec(in_off=8, n=SIGNAL_N, out_off=8, max_out=DEC_OUT)
        a.ldi(1, KEY_BYTES as u32);
        a.ldi(2, SIGNAL_N as u32);
        a.ldi(3, KEY_BYTES as u32);
        a.ldi(4, DEC_OUT as u32);
        a.call("xla_exec");
        // db_insert(key, data_off=8, n=SIGNAL_N) — checksum words excluded.
        a.mov(1, 6);
        a.ldi(2, KEY_BYTES as u32);
        a.ldi(3, SIGNAL_N as u32);
        a.call("db_insert");
        // Report s1 (first checksum word, as raw f32 bits) for diagnostics.
        a.ldi(5, (KEY_BYTES + SIGNAL_N * 4) as u32);
        a.ldw(1, 5, 0, 0);
        a.call("record_result");
        a.halt();
        let (vm_code, imports) = a.assemble();
        CodeImage { imports, vm_code, hlo: self.dbdec_hlo.clone() }
    }
}
