//! Peer-generic outbound link layer.
//!
//! A [`PeerLink`] is the complete sender half of one ifunc channel to one
//! peer: the transport (`Box<dyn IfuncTransport>` — ring, AM, or shm),
//! the reply ring + streamed-reply collector, the consumed-frame counter,
//! and the invocation window. Everything here used to be hard-wired into
//! the leader's `Dispatcher`; it is a separate layer because the paper's
//! closing vision — "dynamically choose where code runs as the
//! application progresses" — needs *workers* that can send too. The
//! leader owns one `PeerLink` per worker (the dispatch star), and with
//! `ClusterConfig::mesh` every worker owns a [`LinkSet`] of links to its
//! peers (the forwarding mesh the `forward` host symbol ships over).
//!
//! The dispatcher is a pure routing/collective facade on top: it resolves
//! `Target`s to worker indices and calls link methods — it never touches
//! a transport, window, or collector directly.

use std::collections::BTreeSet;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::ifunc::{
    ConsumedCounter, IfuncMsg, IfuncTransport, Reply, ReplyCollector, ReplyRing, REPLY_SLOTS,
};
use crate::util::sync::{lock_recover, wait_timeout_recover};
use crate::{Error, Result};

/// Prefix a transport error with the worker it came from — delivery
/// errors (a dead worker's full ring, a lapped reply) surface from deep
/// inside the link, which has no idea which worker index it is.
pub(crate) fn tag_worker(worker: usize, e: Error) -> Error {
    match e {
        Error::Transport(m) => Error::Transport(format!("worker {worker}: {m}")),
        other => other,
    }
}

/// Pack the failure site of a broken forward chain into the failure
/// reply's `r0`: upper 32 bits = the worker where the chain died, low 8
/// bits = hops completed when it died. The leader's `PendingReply` gets a
/// `STATUS_FAILED` reply carrying this instead of hanging — a TTL-cut
/// loop or an unreachable peer names where it stopped.
pub fn encode_forward_failure(worker: usize, hops: u8) -> u64 {
    ((worker as u64) << 32) | hops as u64
}

/// Inverse of [`encode_forward_failure`]: `(failing_worker, hops)`.
pub fn decode_forward_failure(r0: u64) -> (usize, u8) {
    ((r0 >> 32) as usize, (r0 & 0xFF) as u8)
}

/// Per-link invocation window.
///
/// On every link it enforces the **count** window: at most `max`
/// invocations outstanding ([`InvokeWindow::acquire`] blocks past it,
/// bounded by `ClusterConfig::reply_timeout`).
///
/// On a **legacy** (non-streamed) link it additionally runs the
/// **seq-distance** admission check on every frame sent — invoke or
/// fire-and-forget — ([`InvokeWindow::admit`]): with one reply frame per
/// ingress frame, reply `T` laps reply `S`'s slot iff `T >= S +
/// REPLY_SLOTS`, so delivery stalls while any uncollected invocation's
/// reply slot would be overwritten. Pure fire-and-forget traffic pays
/// only one relaxed atomic load per send (the `admit` fast path).
///
/// On a **streamed** link that static arithmetic is meaningless — a
/// k-chunk reply occupies k reply seqs, with k data-dependent — so lap
/// protection moves to the reply layer itself: the `ReplyCollector`
/// consumes reply frames in order (sends drive it via drain) and the
/// worker's writer only recycles slots the collector has consumed. An
/// uncollected invocation reply is parked in leader memory, never
/// overwritten in the ring.
pub(crate) struct InvokeWindow {
    max: usize,
    /// `awaiting.len()` mirror for the lock-free admit fast path. Reads
    /// under the link lock are exact: `track` runs before the link lock
    /// is released, so the lock's synchronizes-with edge publishes it.
    awaiting_count: std::sync::atomic::AtomicUsize,
    state: Mutex<WindowState>,
    freed: Condvar,
}

#[derive(Default)]
struct WindowState {
    /// Invocations begun but not yet collected (count window).
    inflight: usize,
    /// Total releases ever — progress evidence for starved `acquire`
    /// waiters (under contention `inflight` can read as pinned at `max`
    /// at every wakeup even while slots turn over continuously).
    releases: u64,
    /// Reply seqs of sent-but-uncollected invocations (lap guard).
    awaiting: BTreeSet<u64>,
}

impl InvokeWindow {
    pub(crate) fn new(max: usize) -> Self {
        InvokeWindow {
            max,
            awaiting_count: std::sync::atomic::AtomicUsize::new(0),
            state: Mutex::new(WindowState::default()),
            freed: Condvar::new(),
        }
    }

    /// Claim an invocation slot; blocks while `max` are outstanding and
    /// errors after `timeout` without progress. Progress is the release
    /// *generation*, not the observed count — under contention the count
    /// can read as pinned at `max` at every wakeup even while slots turn
    /// over, and churn must not be mistaken for a stuck window.
    fn acquire(&self, timeout: Option<Duration>) -> std::result::Result<(), String> {
        let mut st = lock_recover(&self.state);
        let mut deadline = timeout.map(|d| Instant::now() + d);
        let mut last_releases = st.releases;
        loop {
            if st.inflight < self.max {
                st.inflight += 1;
                return Ok(());
            }
            if last_releases != st.releases {
                last_releases = st.releases;
                deadline = timeout.map(|d| Instant::now() + d);
            }
            if let Some(d) = deadline {
                if Instant::now() > d {
                    return Err(format!(
                        "invocation window full ({} outstanding, max_inflight {}); \
                         wait on or drop a PendingReply",
                        st.inflight, self.max
                    ));
                }
            }
            st = wait_timeout_recover(&self.freed, st, Duration::from_millis(1));
        }
    }

    /// Claim up to `want` invocation slots without blocking: takes
    /// `min(want, max - inflight)` and returns how many were claimed
    /// (possibly zero). The shed-before-block primitive for the serve
    /// front-end's coalescer — admission control decides *before* any
    /// wait whether work can go out now.
    fn try_acquire_many(&self, want: usize) -> usize {
        if want == 0 {
            return 0;
        }
        let mut st = lock_recover(&self.state);
        let free = self.max.saturating_sub(st.inflight);
        let take = want.min(free);
        st.inflight += take;
        take
    }

    /// Record a begun invocation's reply seq (after its frame was sent).
    fn track(&self, seq: u64) {
        let mut st = lock_recover(&self.state);
        st.awaiting.insert(seq);
        self.awaiting_count.store(st.awaiting.len(), std::sync::atomic::Ordering::Relaxed);
    }

    /// Release one invocation slot; `seq` is its tracked reply seq (None
    /// when the frame never went out).
    fn release(&self, seq: Option<u64>) {
        let mut st = lock_recover(&self.state);
        st.inflight -= 1;
        st.releases += 1;
        if let Some(s) = seq {
            st.awaiting.remove(&s);
            self.awaiting_count.store(st.awaiting.len(), std::sync::atomic::Ordering::Relaxed);
        }
        drop(st);
        self.freed.notify_all();
    }

    /// Sent-but-uncollected invocation count (legacy lap-guard set size) —
    /// the stale-waiter probe for tests.
    pub(crate) fn awaiting_len(&self) -> usize {
        self.awaiting_count.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Block until frames through `end_seq` can be delivered without
    /// lapping any awaited reply (reply `T` overwrites reply `S`'s slot
    /// iff `T >= S + REPLY_SLOTS`). The deadline resets whenever the
    /// oldest awaited seq changes (progress), and expires with a message
    /// naming the blocking invocation. With nothing awaited — all
    /// fire-and-forget traffic — this is one relaxed load, no lock.
    fn admit(&self, end_seq: u64, timeout: Option<Duration>) -> std::result::Result<(), String> {
        if self.awaiting_count.load(std::sync::atomic::Ordering::Relaxed) == 0 {
            return Ok(());
        }
        let mut st = lock_recover(&self.state);
        let mut deadline = timeout.map(|d| Instant::now() + d);
        let mut last_oldest = None;
        loop {
            let Some(&oldest) = st.awaiting.iter().next() else { return Ok(()) };
            if end_seq.saturating_sub(oldest) < REPLY_SLOTS as u64 {
                return Ok(());
            }
            if last_oldest != Some(oldest) {
                last_oldest = Some(oldest);
                deadline = timeout.map(|d| Instant::now() + d);
            }
            if let Some(d) = deadline {
                if Instant::now() > d {
                    return Err(format!(
                        "delivering frame seq {end_seq} would lap the unread reply for \
                         invocation seq {oldest}; wait on or drop its PendingReply"
                    ));
                }
            }
            st = wait_timeout_recover(&self.freed, st, Duration::from_millis(1));
        }
    }
}

/// How a [`PendingReply`] collects its reply: directly off its seq's slot
/// (legacy one-frame-per-reply links) or through the link's shared
/// [`ReplyCollector`] (streamed links, where a reply may span several
/// chunk frames at unpredictable reply seqs).
enum Collect {
    Slot(ReplyRing),
    Stream(Arc<ReplyCollector>),
}

/// A not-yet-collected invocation: records the ingress frame seq at send
/// time and waits for its reply without the link lock, so other
/// invocations (and fire-and-forget sends) proceed concurrently on the
/// same worker. Dropping the handle without waiting releases its window
/// slot (the reply, when it arrives, is simply discarded).
pub struct PendingReply {
    how: Collect,
    seq: u64,
    worker: usize,
    window: Arc<InvokeWindow>,
    released: bool,
}

impl PendingReply {
    /// The frame sequence number this handle waits for (1-based, per link).
    pub fn seq(&self) -> u64 {
        self.seq
    }

    /// The worker index the invocation targeted.
    pub fn worker(&self) -> usize {
        self.worker
    }

    /// Block for the reply — reassembled across chunk frames when the
    /// injected function pushed more than one frame's worth of payload.
    /// A worker that died mid-invoke surfaces as [`Error::Transport`]
    /// naming this worker once `ClusterConfig::reply_timeout` expires
    /// without progress.
    pub fn wait(mut self) -> Result<Reply> {
        let out = match &self.how {
            Collect::Slot(ring) => ring.wait(self.seq),
            Collect::Stream(c) => c.collect(self.seq),
        }
        .map_err(|e| tag_worker(self.worker, e));
        if out.is_err() {
            // A successful collect deregisters; a failed one must not
            // leave the frame awaited forever (its reply — if it ever
            // lands — would be parked with no one to claim it).
            if let Collect::Stream(c) = &self.how {
                c.unregister(self.seq);
            }
        }
        self.released = true;
        self.window.release(Some(self.seq));
        out
    }
}

impl Drop for PendingReply {
    fn drop(&mut self) {
        if !self.released {
            if let Collect::Stream(c) = &self.how {
                c.unregister(self.seq);
            }
            self.window.release(Some(self.seq));
        }
    }
}

/// The full sender half of one ifunc channel to one peer, ownable by any
/// node — the leader's dispatch star and the worker↔worker mesh are both
/// sets of these. Bundles the transport with its reply ring, streamed
/// reply collector, consumed-frame counter, and invocation window; every
/// method pre-tags errors with the peer index.
pub struct PeerLink {
    peer: usize,
    transport: Mutex<Box<dyn IfuncTransport>>,
    /// Sender-side view of the link's reply ring, shared with the
    /// transport so `PendingReply::wait` runs without the link lock.
    replies: ReplyRing,
    /// Sender-side view of the link's consumed-frame counter — the
    /// barrier credit (one tick per ingress frame, however many reply
    /// frames it produced).
    consumed: ConsumedCounter,
    /// Streamed-reply reassembler (`None` when `stream_replies` is off
    /// and the legacy one-frame-per-reply slot protocol runs instead —
    /// and on mesh links, which carry only fire-and-forget traffic).
    collector: Option<Arc<ReplyCollector>>,
    /// Caps outstanding invocations (`max_inflight`) and — in legacy
    /// mode — guards every send against lapping an uncollected reply.
    window: Arc<InvokeWindow>,
    /// `ClusterConfig::reply_timeout`, for the window's admission check.
    reply_timeout: Option<Duration>,
}

impl PeerLink {
    pub(crate) fn new(
        peer: usize,
        transport: Box<dyn IfuncTransport>,
        replies: ReplyRing,
        consumed: ConsumedCounter,
        collector: Option<Arc<ReplyCollector>>,
        max_inflight: usize,
        reply_timeout: Option<Duration>,
    ) -> Self {
        PeerLink {
            peer,
            transport: Mutex::new(transport),
            replies,
            consumed,
            collector,
            window: Arc::new(InvokeWindow::new(max_inflight.clamp(1, REPLY_SLOTS))),
            reply_timeout,
        }
    }

    /// The peer (worker index) this link delivers to.
    pub fn peer(&self) -> usize {
        self.peer
    }

    /// Per-send reply bookkeeping (runs under the link lock). On a
    /// streamed link, drive the reply collector: consuming arrived reply
    /// frames (discarding fire-and-forget ones) is what advances the
    /// worker's slot-recycling credit, so a flood of sends can never
    /// strand an uncollected invocation reply. On a legacy link, run the
    /// seq-distance lap guard instead.
    fn admit_or_drain(&self, end_seq: u64) -> Result<()> {
        match &self.collector {
            Some(c) => c.drain().map_err(|e| tag_worker(self.peer, e)),
            None => self
                .window
                .admit(end_seq, self.reply_timeout)
                .map_err(|m| Error::Transport(format!("worker {}: {m}", self.peer))),
        }
    }

    /// Fire-and-forget delivery of one frame (flow-controlled,
    /// non-blocking; completion via [`PeerLink::flush`]).
    pub fn send(&self, msg: &IfuncMsg) -> Result<()> {
        let mut link = lock_recover(&self.transport);
        self.admit_or_drain(link.frames_sent() + 1)?;
        link.send_frame(msg).map_err(|e| tag_worker(self.peer, e))
    }

    /// Post a batch of frames through the transport's coalesced path (one
    /// credit reservation on the ring; back-to-back posts over AM)
    /// without flushing — so batches to different links can overlap
    /// before one flush pass covers them all.
    pub fn post_batch(&self, msgs: &[IfuncMsg]) -> Result<()> {
        if msgs.is_empty() {
            return Ok(());
        }
        let mut link = lock_recover(&self.transport);
        self.admit_or_drain(link.frames_sent() + msgs.len() as u64)?;
        link.post_batch(msgs).map_err(|e| tag_worker(self.peer, e))
    }

    /// Deliver a batch with one flush at the end.
    pub fn send_batch(&self, msgs: &[IfuncMsg]) -> Result<()> {
        self.post_batch(msgs)?;
        self.flush()
    }

    /// Wait for completion of every posted send on this link.
    pub fn flush(&self) -> Result<()> {
        lock_recover(&self.transport).flush().map_err(|e| tag_worker(self.peer, e))
    }

    /// Frames sent over this link so far (the seq of the last frame).
    pub fn frames_sent(&self) -> u64 {
        lock_recover(&self.transport).frames_sent()
    }

    /// Post one invocation frame and wire up its reply collection. Runs
    /// under the link lock, which covers only delivery — it is released
    /// before any reply wait, which is what lets invocations pipeline.
    /// With `flush_now` the frame's completion is awaited before
    /// returning (the unicast path); the collective path passes `false`
    /// and runs one flush pass after the whole fan-out has been posted,
    /// so the per-link transfers overlap.
    fn post_invoke_locked(&self, msg: &IfuncMsg, flush_now: bool) -> Result<(u64, Collect)> {
        let mut link = lock_recover(&self.transport);
        let seq = link.frames_sent() + 1;
        self.admit_or_drain(seq)?;
        match &self.collector {
            Some(c) => {
                // Register *before* the frame goes out: once it is on
                // the wire a concurrent drain may meet the reply, and
                // only registered replies are parked rather than
                // dropped.
                c.register(seq);
                let posted = link
                    .post_frame(msg)
                    .and_then(|()| if flush_now { link.flush() } else { Ok(()) });
                if let Err(e) = posted {
                    c.unregister(seq);
                    return Err(tag_worker(self.peer, e));
                }
                debug_assert_eq!(link.frames_sent(), seq);
                Ok((seq, Collect::Stream(c.clone())))
            }
            None => {
                link.post_frame(msg).map_err(|e| tag_worker(self.peer, e))?;
                if flush_now {
                    link.flush().map_err(|e| tag_worker(self.peer, e))?;
                }
                let seq = link.frames_sent();
                // Legacy lap guard: remember the awaited reply slot.
                self.window.track(seq);
                Ok((seq, Collect::Slot(self.replies.clone())))
            }
        }
    }

    fn pending(&self, seq: u64, how: Collect) -> PendingReply {
        PendingReply {
            how,
            seq,
            worker: self.peer,
            window: self.window.clone(),
            released: false,
        }
    }

    /// Claim a window slot and post one invocation frame; the slot is
    /// released on any error so a failed begin never leaks window
    /// capacity. The returned [`PendingReply`] waits for the reply
    /// without the link lock, so up to `max_inflight` invocations
    /// pipeline per peer.
    pub fn invoke_begin(&self, msg: &IfuncMsg, flush_now: bool) -> Result<PendingReply> {
        self.window
            .acquire(self.reply_timeout)
            .map_err(|m| Error::Transport(format!("worker {}: {m}", self.peer)))?;
        match self.post_invoke_locked(msg, flush_now) {
            Ok((seq, how)) => Ok(self.pending(seq, how)),
            Err(e) => {
                self.window.release(None);
                Err(e)
            }
        }
    }

    /// Non-blocking [`PeerLink::invoke_begin`]: returns `Ok(None)` —
    /// immediately, without parking — when the invocation window is full.
    pub fn try_invoke_begin(&self, msg: &IfuncMsg) -> Result<Option<PendingReply>> {
        if self.window.try_acquire_many(1) == 0 {
            return Ok(None);
        }
        match self.post_invoke_locked(msg, true) {
            Ok((seq, how)) => Ok(Some(self.pending(seq, how))),
            Err(e) => {
                self.window.release(None);
                Err(e)
            }
        }
    }

    /// Non-blocking **batched** invocation begin: claim as many window
    /// slots as are free right now (up to `msgs.len()`), post that
    /// admitted prefix through the transport's coalesced batch path —
    /// one credit reservation, one flush — and return a [`PendingReply`]
    /// per admitted frame, in order. An empty vec means the window was
    /// saturated; the call never blocks on window capacity.
    pub fn try_invoke_batch(&self, msgs: &[IfuncMsg]) -> Result<Vec<PendingReply>> {
        if msgs.is_empty() {
            return Ok(Vec::new());
        }
        let admitted = self.window.try_acquire_many(msgs.len());
        if admitted == 0 {
            return Ok(Vec::new());
        }
        match self.post_invoke_batch_locked(&msgs[..admitted]) {
            Ok(pending) => Ok(pending),
            Err(e) => {
                for _ in 0..admitted {
                    self.window.release(None);
                }
                Err(e)
            }
        }
    }

    /// Post `msgs` as one coalesced batch and wire up per-frame reply
    /// collection. Window slots (`msgs.len()` of them) must already be
    /// claimed; on error the *caller* releases them — this function only
    /// unwinds its collector registrations.
    fn post_invoke_batch_locked(&self, msgs: &[IfuncMsg]) -> Result<Vec<PendingReply>> {
        let mut link = lock_recover(&self.transport);
        let first = link.frames_sent() + 1;
        let end = link.frames_sent() + msgs.len() as u64;
        self.admit_or_drain(end)?;
        let mut pending = Vec::with_capacity(msgs.len());
        match &self.collector {
            Some(c) => {
                // Register every frame before any goes out (same ordering
                // contract as the unicast path: a concurrent drain may
                // meet a reply the instant its frame lands).
                for seq in first..=end {
                    c.register(seq);
                }
                let posted = link.post_batch(msgs).and_then(|()| link.flush());
                if let Err(e) = posted {
                    for seq in first..=end {
                        c.unregister(seq);
                    }
                    return Err(tag_worker(self.peer, e));
                }
                debug_assert_eq!(link.frames_sent(), end);
                for seq in first..=end {
                    pending.push(self.pending(seq, Collect::Stream(c.clone())));
                }
            }
            None => {
                link.post_batch(msgs).map_err(|e| tag_worker(self.peer, e))?;
                link.flush().map_err(|e| tag_worker(self.peer, e))?;
                for seq in first..=end {
                    self.window.track(seq);
                    pending.push(self.pending(seq, Collect::Slot(self.replies.clone())));
                }
            }
        }
        Ok(pending)
    }

    /// Block until the peer has consumed everything sent on this link so
    /// far (one consumed-counter tick per ingress frame), draining the
    /// reply collector meanwhile so reply-slot credit keeps flowing while
    /// the wait spins. The barrier primitive.
    pub fn wait_consumed(&self) -> Result<()> {
        let sent = lock_recover(&self.transport).frames_sent();
        self.consumed
            .wait(sent, || match &self.collector {
                Some(c) => c.drain(),
                None => Ok(()),
            })
            .map_err(|e| tag_worker(self.peer, e))
    }

    /// Fault-injection hook for the security suite: write raw bytes into
    /// the peer's delivery ring, bypassing all framing (hostile-sender
    /// simulation). Ring-protocol transports only (fabric ring and shm).
    #[doc(hidden)]
    pub fn debug_put_raw(&self, offset: usize, data: &[u8]) -> Result<()> {
        lock_recover(&self.transport).debug_put_raw(offset, data)
    }

    /// Outstanding reply registrations on this link — the stale-waiter
    /// probe for the drop-without-wait property tests: collector-awaited
    /// seqs on a streamed link, the window's lap-guard set size on a
    /// legacy one.
    #[doc(hidden)]
    pub fn debug_awaited(&self) -> usize {
        match &self.collector {
            Some(c) => c.debug_awaited(),
            None => self.window.awaiting_len(),
        }
    }
}

/// A node's outbound links, indexed by peer worker. `None` marks peers
/// with no channel (a worker has no mesh link to itself).
pub struct LinkSet {
    links: Vec<Option<Arc<PeerLink>>>,
}

impl LinkSet {
    pub(crate) fn new(links: Vec<Option<Arc<PeerLink>>>) -> Self {
        LinkSet { links }
    }

    /// The link to `peer`, or an error naming the hole (unknown index,
    /// or a peer this node holds no channel to).
    pub fn get(&self, peer: usize) -> Result<&Arc<PeerLink>> {
        self.links
            .get(peer)
            .and_then(|l| l.as_ref())
            .ok_or_else(|| Error::Other(format!("no outbound link to worker {peer}")))
    }

    pub fn len(&self) -> usize {
        self.links.len()
    }

    pub fn is_empty(&self) -> bool {
        self.links.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_failure_encoding_roundtrips() {
        for (worker, hops) in [(0usize, 0u8), (3, 7), (1500, 255)] {
            let r0 = encode_forward_failure(worker, hops);
            assert_eq!(decode_forward_failure(r0), (worker, hops));
        }
    }

    #[test]
    fn window_blocks_at_capacity_and_releases() {
        let w = InvokeWindow::new(2);
        w.acquire(None).unwrap();
        w.acquire(None).unwrap();
        assert!(w.acquire(Some(Duration::from_millis(20))).is_err());
        w.release(None);
        w.acquire(Some(Duration::from_millis(20))).unwrap();
    }

    #[test]
    fn window_try_acquire_takes_only_free_slots() {
        let w = InvokeWindow::new(3);
        assert_eq!(w.try_acquire_many(2), 2);
        assert_eq!(w.try_acquire_many(5), 1);
        assert_eq!(w.try_acquire_many(1), 0);
        w.release(None);
        assert_eq!(w.try_acquire_many(1), 1);
    }

    #[test]
    fn window_admit_guards_lap_distance() {
        let w = InvokeWindow::new(4);
        w.acquire(None).unwrap();
        w.track(1);
        // Within a lap: fine. One full lap past seq 1: must stall.
        w.admit(REPLY_SLOTS as u64, None).unwrap();
        assert!(w.admit(1 + REPLY_SLOTS as u64, Some(Duration::from_millis(20))).is_err());
        w.release(Some(1));
        w.admit(1 + REPLY_SLOTS as u64, None).unwrap();
    }
}
