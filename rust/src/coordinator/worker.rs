//! Device-side worker: a polling "DPU/CSD process".
//!
//! Each worker executes whatever the host injects — over either transport:
//!
//! * **ring** ([`TransportKind::Ring`]): a dedicated thread runs
//!   `ucp_poll_ifunc` against the worker's RWX ring and pushes a
//!   consumed-bytes credit word back to the leader so the dispatcher can
//!   flow-control without ever overwriting an unconsumed frame,
//! * **am** ([`TransportKind::Am`]): frames arrive as active messages and
//!   the thread simply progresses the UCP worker (§5.1's "ifuncs will be
//!   progressed with other UCX operations").
//!
//! Both paths run the same execution engine and answer every consumed
//! frame — executed or rejected — through the link's reply ring, which is
//! what `Dispatcher::invoke` and `Dispatcher::barrier` wait on.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::fabric::{MemPerm, MemoryRegion};
use crate::ifunc::am_transport::{execute_am_frame, IFUNC_AM_ID};
use crate::ifunc::{
    AmTransport, IfuncRing, IfuncTransport, ReplyRing, ReplyWriter, RingTransport, TargetArgs,
    TransportKind,
};
use crate::log;
use crate::ucp::{Context, Worker as UcpWorker};
use crate::{Error, Result};

use super::store::RecordStore;
use super::ClusterConfig;

/// Bytes of the per-worker leader-side result region the `db_get` symbol
/// writes records into (see `install_result_symbols`).
pub const RESULT_REGION_BYTES: usize = 64 << 10;
/// Largest record (in f32 elements) `db_get` can return.
pub const RESULT_MAX_ELEMS: usize = RESULT_REGION_BYTES / 4;
/// `db_get`'s r0 when the key is absent.
pub const GET_MISSING: u64 = u64::MAX;

/// Worker-side execution counters.
#[derive(Default)]
pub struct WorkerStats {
    pub executed: AtomicU64,
    pub failed: AtomicU64,
}

/// A spawned worker: context + store + receive thread + leader link.
pub struct WorkerHandle {
    pub index: usize,
    pub ctx: Arc<Context>,
    pub store: Arc<RecordStore>,
    pub stats: Arc<WorkerStats>,
    /// Leader-side delivery channel (transport-generic).
    pub(crate) link: Mutex<Box<dyn IfuncTransport>>,
    /// Leader-side region this worker's `db_get` writes records into.
    result: Arc<MemoryRegion>,
    shutdown: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<Result<()>>>,
}

/// Install the worker-side `db_get` symbol: looks `r1` up in `store` and,
/// when present, ships the record's f32s over the fabric into the leader's
/// result region, returning the element count (or [`GET_MISSING`]). The
/// record the sender reads back is produced *by the injected function on
/// the worker* — the reply path's answer to leader-side store access.
fn install_result_symbols(
    ctx: &Arc<Context>,
    store: Arc<RecordStore>,
    ep_back: Arc<crate::ucp::Endpoint>,
    result_rkey: crate::fabric::RKey,
) {
    ctx.symbols().install_fn("db_get", move |_, [key, _, _, _]| {
        match store.get(key) {
            None => Ok(GET_MISSING),
            Some(data) => {
                if data.len() > RESULT_MAX_ELEMS {
                    return Err(format!(
                        "db_get: record of {} elems exceeds result region ({RESULT_MAX_ELEMS})",
                        data.len()
                    ));
                }
                let mut bytes = Vec::with_capacity(data.len() * 4);
                for v in &data {
                    bytes.extend_from_slice(&v.to_le_bytes());
                }
                // Same QP as the reply that will follow this frame: RC
                // ordering guarantees the data lands before the reply's
                // seq word, so a sender that saw the reply may read it.
                ep_back.put_nbi(result_rkey, 0, &bytes).map_err(|e| e.to_string())?;
                Ok(data.len() as u64)
            }
        }
    });
}

impl WorkerHandle {
    pub(crate) fn spawn(
        index: usize,
        ctx: Arc<Context>,
        store: Arc<RecordStore>,
        leader: &Arc<Context>,
        leader_worker: &Arc<UcpWorker>,
        config: &ClusterConfig,
    ) -> Result<WorkerHandle> {
        // Leader-side reply + result regions; worker-side back endpoint.
        let replies = ReplyRing::new(leader);
        let reply_rkey = replies.rkey();
        let result = leader.mem_map(RESULT_REGION_BYTES, MemPerm::RWX);
        let ucp_worker = UcpWorker::new(&ctx);
        let ep = leader_worker.connect(&ucp_worker)?;
        let ep_back = ucp_worker.connect(leader_worker)?;
        install_result_symbols(&ctx, store.clone(), ep_back.clone(), result.rkey());

        let shutdown = Arc::new(AtomicBool::new(false));
        let stats = Arc::new(WorkerStats::default());

        let (transport, thread): (Box<dyn IfuncTransport>, _) = match config.transport {
            TransportKind::Ring => {
                let ring = IfuncRing::new(&ctx, config.ring_bytes)?;
                let ring_rkey = ring.rkey();
                // Leader-side credit word; worker puts consumed-bytes into it.
                let credit = leader.mem_map(64, MemPerm::RWX);
                let credit_rkey = credit.rkey();
                let transport = Box::new(RingTransport::new(
                    ep,
                    ring_rkey,
                    config.ring_bytes,
                    credit,
                    replies,
                ));
                let (ctx2, store2, stop2, stats2) =
                    (ctx.clone(), store.clone(), shutdown.clone(), stats.clone());
                let ep_back2 = ep_back.clone();
                let thread = std::thread::Builder::new()
                    .name(format!("ifunc-worker-{index}"))
                    .spawn(move || -> Result<()> {
                        let mut ring = ring;
                        let mut args = TargetArgs::new(Box::new(store2));
                        let mut replies = ReplyWriter::new(ep_back2.clone(), reply_rkey);
                        let mut idle = 0u32;
                        let mut last_credit = 0u64;
                        loop {
                            let frames_before = ring.consumed;
                            let polled = ctx2.poll_ifunc(&mut ring, &mut args);
                            match &polled {
                                Ok(crate::ifunc::PollResult::Executed) => {
                                    stats2.executed.fetch_add(1, Ordering::Relaxed);
                                    idle = 0;
                                }
                                Ok(crate::ifunc::PollResult::NoMessage) => {}
                                Err(e) => {
                                    // A faulty ifunc is consumed and
                                    // reported, but must not take the
                                    // device down.
                                    stats2.failed.fetch_add(1, Ordering::Relaxed);
                                    log::error!("worker {index}: ifunc failed: {e}");
                                    idle = 0;
                                }
                            }
                            // Push the credit word whenever consumption
                            // advanced — including marker-only polls (a
                            // wrap rewind reports NoMessage but consumes
                            // the ring tail, and the oversized-wrap send
                            // path waits on exactly that credit).
                            if ring.consumed_bytes != last_credit {
                                ep_back2
                                    .qp()
                                    .put_signal(credit_rkey, 0, ring.consumed_bytes)?;
                                last_credit = ring.consumed_bytes;
                            }
                            // One reply per consumed *frame* (not markers),
                            // whether it executed or was rejected.
                            if ring.consumed > frames_before {
                                let ok =
                                    matches!(polled, Ok(crate::ifunc::PollResult::Executed));
                                let r0 = if ok { args.last_return.unwrap_or(0) } else { 0 };
                                replies.push(ok, r0)?;
                            }
                            if matches!(polled, Ok(crate::ifunc::PollResult::NoMessage)) {
                                if stop2.load(Ordering::Acquire) {
                                    ep_back2.qp().flush()?;
                                    return Ok(());
                                }
                                crate::fabric::wire::backoff(idle);
                                idle += 1;
                            }
                        }
                    })
                    .expect("spawn worker thread");
                (transport, thread)
            }
            TransportKind::Am => {
                let transport = Box::new(AmTransport::new(ep, replies));
                // The AM handler owns the reply writer and target args;
                // it runs on the progress thread below.
                let target_args =
                    Arc::new(Mutex::new(TargetArgs::new(Box::new(store.clone()))));
                let reply_writer =
                    Arc::new(Mutex::new(ReplyWriter::new(ep_back.clone(), reply_rkey)));
                let (ctx2, stats2) = (ctx.clone(), stats.clone());
                let rw = reply_writer.clone();
                ucp_worker.set_am_handler(IFUNC_AM_ID, move |_, frame| {
                    let (ok, r0) = match execute_am_frame(&ctx2, frame, &target_args) {
                        Ok(out) => {
                            stats2.executed.fetch_add(1, Ordering::Relaxed);
                            (true, out.ret)
                        }
                        Err(e) => {
                            stats2.failed.fetch_add(1, Ordering::Relaxed);
                            log::error!("worker {index}: ifunc failed: {e}");
                            (false, 0)
                        }
                    };
                    if let Err(e) = rw.lock().unwrap().push(ok, r0) {
                        log::error!("worker {index}: reply push failed: {e}");
                    }
                });
                let (stop2, ep_back2) = (shutdown.clone(), ep_back.clone());
                let uw = ucp_worker.clone();
                let thread = std::thread::Builder::new()
                    .name(format!("ifunc-worker-{index}"))
                    .spawn(move || -> Result<()> {
                        let mut idle = 0u32;
                        loop {
                            if uw.progress() == 0 {
                                if stop2.load(Ordering::Acquire) {
                                    ep_back2.qp().flush()?;
                                    return Ok(());
                                }
                                crate::fabric::wire::backoff(idle);
                                idle += 1;
                            } else {
                                idle = 0;
                            }
                        }
                    })
                    .expect("spawn worker thread");
                (transport, thread)
            }
        };

        Ok(WorkerHandle {
            index,
            ctx,
            store,
            stats,
            link: Mutex::new(transport),
            result,
            shutdown,
            thread: Some(thread),
        })
    }

    /// Executed-message count (leader-visible).
    pub fn executed(&self) -> u64 {
        self.stats.executed.load(Ordering::Acquire)
    }

    /// Read the first `n` f32s of this worker's leader-side result region
    /// (valid after an `invoke` whose injected code called `db_get`).
    pub fn result_f32s(&self, n: usize) -> Vec<f32> {
        let n = n.min(RESULT_MAX_ELEMS);
        self.result.local_slice()[..n * 4]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect()
    }

    /// Signal shutdown and join the receive thread.
    pub fn stop(&mut self) -> Result<()> {
        self.shutdown.store(true, Ordering::Release);
        if let Some(t) = self.thread.take() {
            t.join().map_err(|_| Error::Other("worker thread panicked".into()))??;
        }
        Ok(())
    }
}

impl Drop for WorkerHandle {
    fn drop(&mut self) {
        let _ = self.stop();
    }
}
