//! Device-side worker: a polling "DPU/CSD process".
//!
//! Each worker runs `ucp_poll_ifunc` in a dedicated thread against its own
//! ring, executes whatever the host injects, and pushes a consumed-bytes
//! credit word back to the leader so the dispatcher can flow-control
//! without ever overwriting an unconsumed frame.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::fabric::{MemPerm, MemoryRegion, RKey};
use crate::ifunc::{IfuncRing, SenderCursor, TargetArgs};
use crate::log;
use crate::ucp::{Context, Endpoint, Worker as UcpWorker};
use crate::{Error, Result};

use super::store::RecordStore;

/// Worker-side execution counters.
#[derive(Default)]
pub struct WorkerStats {
    pub executed: AtomicU64,
    pub failed: AtomicU64,
}

/// Leader-side view of the link to one worker.
pub(crate) struct WorkerLink {
    /// Leader → worker endpoint (ifunc puts).
    pub ep: Arc<Endpoint>,
    /// Worker ring placement cursor.
    pub cursor: SenderCursor,
    pub ring_rkey: RKey,
    pub ring_bytes: usize,
    /// Bytes sent (frames + wrap markers).
    pub sent_bytes: u64,
    /// Leader-local word the worker writes its consumed-bytes count into.
    pub credit: Arc<MemoryRegion>,
}

impl WorkerLink {
    /// Block until the ring can absorb `needed` more bytes. `needed` must
    /// count the *whole* cost of the upcoming send — on a wrap that is the
    /// skipped ring tail plus the frame, not just the frame (the tail is
    /// credited back by the worker's `rewind`). `needed` may not exceed
    /// the ring: when tail + frame would (a frame longer than the current
    /// ring offset), the frame at offset 0 overlaps the wrap marker, so
    /// the dispatcher drains the ring and publishes the marker *before*
    /// the frame (see `Dispatcher::send_to`).
    pub fn wait_capacity(&self, needed: usize) {
        let budget = self.ring_bytes.saturating_sub(needed) as u64;
        let mut i = 0u32;
        loop {
            let consumed = self.credit.load_u64_acquire(0).unwrap();
            if self.sent_bytes.saturating_sub(consumed) <= budget {
                return;
            }
            crate::fabric::wire::backoff(i);
            i += 1;
        }
    }
}

/// A spawned worker: context + store + poll thread + leader link.
pub struct WorkerHandle {
    pub index: usize,
    pub ctx: Arc<Context>,
    pub store: Arc<RecordStore>,
    pub stats: Arc<WorkerStats>,
    pub(crate) link: Mutex<WorkerLink>,
    shutdown: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<Result<()>>>,
}

impl WorkerHandle {
    pub(crate) fn spawn(
        index: usize,
        ctx: Arc<Context>,
        store: Arc<RecordStore>,
        leader: &Arc<Context>,
        leader_worker: &Arc<UcpWorker>,
        ring_bytes: usize,
    ) -> Result<WorkerHandle> {
        let ring = IfuncRing::new(&ctx, ring_bytes)?;
        let ring_rkey = ring.rkey();
        // Leader-side credit word; worker puts consumed-bytes into it.
        let credit = leader.mem_map(64, MemPerm::RWX);
        let credit_rkey = credit.rkey();
        // Endpoints: leader → worker for frames; worker → leader for credits.
        let ucp_worker = UcpWorker::new(&ctx);
        let ep = leader_worker.connect(&ucp_worker)?;
        let ep_credit = ucp_worker.connect(leader_worker)?;

        let shutdown = Arc::new(AtomicBool::new(false));
        let stats = Arc::new(WorkerStats::default());
        let (ctx2, store2, stop2, stats2) =
            (ctx.clone(), store.clone(), shutdown.clone(), stats.clone());
        let thread = std::thread::Builder::new()
            .name(format!("ifunc-worker-{index}"))
            .spawn(move || -> Result<()> {
                let mut ring = ring;
                let mut args = TargetArgs::new(Box::new(store2));
                let mut idle = 0u32;
                let mut last_credit = 0u64;
                loop {
                    let polled = ctx2.poll_ifunc(&mut ring, &mut args);
                    match &polled {
                        Ok(crate::ifunc::PollResult::Executed) => {
                            stats2.executed.fetch_add(1, Ordering::Relaxed);
                            idle = 0;
                        }
                        Ok(crate::ifunc::PollResult::NoMessage) => {}
                        Err(e) => {
                            // A faulty ifunc is consumed and reported, but
                            // must not take the device down.
                            stats2.failed.fetch_add(1, Ordering::Relaxed);
                            log::error!("worker {index}: ifunc failed: {e}");
                            idle = 0;
                        }
                    }
                    // Push the credit word whenever consumption advanced —
                    // including marker-only polls (a wrap rewind reports
                    // NoMessage but consumes the ring tail, and the
                    // dispatcher's oversized-wrap path waits on exactly
                    // that credit).
                    if ring.consumed_bytes != last_credit {
                        ep_credit.qp().put_signal(credit_rkey, 0, ring.consumed_bytes)?;
                        last_credit = ring.consumed_bytes;
                    }
                    if matches!(polled, Ok(crate::ifunc::PollResult::NoMessage)) {
                        if stop2.load(Ordering::Acquire) {
                            ep_credit.flush()?;
                            return Ok(());
                        }
                        crate::fabric::wire::backoff(idle);
                        idle += 1;
                    }
                }
            })
            .expect("spawn worker thread");

        Ok(WorkerHandle {
            index,
            ctx,
            store,
            stats,
            link: Mutex::new(WorkerLink {
                ep,
                cursor: SenderCursor::new(ring_bytes),
                ring_rkey,
                ring_bytes,
                sent_bytes: 0,
                credit,
            }),
            shutdown,
            thread: Some(thread),
        })
    }

    /// Executed-message count (leader-visible).
    pub fn executed(&self) -> u64 {
        self.stats.executed.load(Ordering::Acquire)
    }

    /// Signal shutdown and join the poll thread.
    pub fn stop(&mut self) -> Result<()> {
        self.shutdown.store(true, Ordering::Release);
        if let Some(t) = self.thread.take() {
            t.join().map_err(|_| Error::Other("worker thread panicked".into()))??;
        }
        Ok(())
    }
}

impl Drop for WorkerHandle {
    fn drop(&mut self) {
        let _ = self.stop();
    }
}
