//! Device-side worker: a polling "DPU/CSD process".
//!
//! Each worker executes whatever the host injects — over any transport:
//!
//! * **ring** ([`TransportKind::Ring`]): a dedicated thread runs
//!   `ucp_poll_ifunc` against the worker's RWX ring and pushes a
//!   consumed-bytes credit word back to the leader so the dispatcher can
//!   flow-control without ever overwriting an unconsumed frame,
//! * **am** ([`TransportKind::Am`]): frames arrive as active messages and
//!   the thread simply progresses the UCP worker (§5.1's "ifuncs will be
//!   progressed with other UCX operations"),
//! * **shm** ([`TransportKind::Shm`]): the *same* poll loop as ring — the
//!   frames were memcpy'd into the shared ring mapping by the colocated
//!   leader — but every return signal (byte credit, reply frames,
//!   consumed counter) is a plain release-store into the shared words
//!   instead of a fabric put; no endpoint exists on the link at all.
//!
//! All paths run the same execution engine and answer every consumed
//! frame — executed or rejected — with one or more payload-carrying reply
//! frames: whatever the injected function pushed through `reply_put` /
//! `db_get` travels back, chunked into `STATUS_MORE` frames when it
//! exceeds one slot (see `ifunc::reply`), which is what
//! `Dispatcher::invoke` and `PendingReply` wait on. `Dispatcher::barrier`
//! waits on a separate per-ingress-frame **consumed counter** the worker
//! advances once per frame (a chunked reply occupies several reply seqs,
//! so reply seqs are no longer a frame count). There is no leader-side
//! result region: invocation results are messages, not shared memory.
//!
//! With [`ClusterConfig::mesh`] each worker additionally owns a
//! [`super::link::LinkSet`] of outbound [`super::link::PeerLink`]s to its
//! peers — the same link type the leader dispatches over — plus a mesh
//! receive thread. An invocation that calls the `forward` host symbol
//! does **not** reply: its rebuilt frame continues on the named peer over
//! the mesh (the leader-ingress hop stamps the origin seq/worker into the
//! hop header first), each hop decrements the TTL, and the *final* hop's
//! reply travels back to the origin worker as a relay frame, from where
//! it is pushed into the origin's leader-facing reply stream under the
//! seq the leader registered at injection — so `PendingReply::wait`
//! collects a multi-hop chain's result exactly like a local one. A chain
//! that dies (TTL out, unreachable peer, failed hop) produces a FAILED
//! reply whose `r0` encodes the failure site
//! ([`super::link::encode_forward_failure`]) instead of a hang. Heavily
//! *cyclic* forwarding can transiently exhaust mesh ring credit in both
//! directions at once; the per-link credit waits are bounded by
//! `ClusterConfig::reply_timeout`, so the worst case degrades to a
//! failure relay naming the wedged hop, never a silent deadlock.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::fabric::{MemPerm, RKey};
use crate::ifunc::am_transport::{execute_am_frame_in_place, IFUNC_AM_ID};
use crate::ifunc::message::{Header, HEADER_BYTES, HOP_KIND_RELAY};
use crate::ifunc::transport::PutSink;
use crate::ifunc::{
    AmTransport, ConsumedCounter, ExecOutcome, ForwardOutcome, Hop, IfuncMsg, IfuncRing,
    IfuncTransport, MeshPollResult, PollResult, ReplyCollector, ReplyRing, ReplyWriter,
    RingTransport, ShmTransport, TargetArgs, TransportKind, NO_ORIGIN_WORKER,
};
use crate::log;
use crate::ucp::{Context, Endpoint, Worker as UcpWorker};
use crate::util::sync::lock_recover;
use crate::{Error, Result};

use super::link::{encode_forward_failure, LinkSet, PeerLink};
use super::store::RecordStore;
use super::ClusterConfig;

/// `db_get`'s r0 when the key is absent.
pub const GET_MISSING: u64 = u64::MAX;

/// Mesh delivery rings are capped well below the leader-link ring:
/// forwards are single invocation continuations, not bulk scatter
/// traffic, and an N-worker mesh holds N·(N−1) of these.
const MESH_RING_BYTES_MAX: usize = 256 << 10;

/// Worker-side execution counters.
#[derive(Default)]
pub struct WorkerStats {
    pub executed: AtomicU64,
    pub failed: AtomicU64,
    /// Frames this worker forwarded onward over the mesh (each successful
    /// `forward` hop counts once, at the hop that sent it).
    pub forwarded: AtomicU64,
    /// Forward attempts that died here: TTL exhausted, mesh disabled, or
    /// an unreachable/failed peer link.
    pub forward_failed: AtomicU64,
}

/// A spawned worker: context + store + receive thread(s) + leader link.
pub struct WorkerHandle {
    pub index: usize,
    pub ctx: Arc<Context>,
    pub store: Arc<RecordStore>,
    pub stats: Arc<WorkerStats>,
    /// The leader's outbound link to this worker — transport, reply ring,
    /// collector, and invocation window, bundled peer-generically (the
    /// same [`PeerLink`] type mesh links use).
    pub(crate) link: Arc<PeerLink>,
    shutdown: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<Result<()>>>,
    mesh_thread: Option<std::thread::JoinHandle<Result<()>>>,
}

/// What a leader-ingress frame owes the leader after execution.
enum LeaderReplyAction {
    /// Push a reply stream under the frame's seq, as always.
    Reply { ok: bool, r0: u64, payload: Vec<u8> },
    /// The invocation continued over the mesh: this hop replies nothing —
    /// the chain's final hop relays the reply back under the origin seq.
    Deferred,
}

/// Route an executed leader-ingress frame's outcome: no forward → reply
/// locally; forward requested → stamp the origin (seq + this worker) into
/// the hop header if unset and ship the rebuilt frame over the mesh. A
/// forward that cannot go out — mesh disabled, TTL exhausted, dead peer —
/// degrades to a FAILED reply whose `r0` names the failure site, so the
/// leader's `PendingReply` errors instead of hanging.
fn route_leader_outcome(
    index: usize,
    mesh: Option<&MeshNode>,
    stats: &WorkerStats,
    frame_seq: u64,
    out: ExecOutcome,
) -> LeaderReplyAction {
    let Some(fwd) = out.forward else {
        return LeaderReplyAction::Reply { ok: true, r0: out.ret, payload: out.reply };
    };
    let fail = |hops: u8| {
        stats.forward_failed.fetch_add(1, Ordering::Relaxed);
        LeaderReplyAction::Reply {
            ok: false,
            r0: encode_forward_failure(index, hops),
            payload: Vec::new(),
        }
    };
    let Some(mesh) = mesh else {
        log::error!(
            "worker {index}: forward requested but the worker mesh is disabled \
             (ClusterConfig::mesh)"
        );
        return fail(0);
    };
    match fwd {
        ForwardOutcome::TtlExhausted { worker } => {
            log::error!("worker {index}: forward to worker {worker} rejected: TTL exhausted");
            fail(0)
        }
        ForwardOutcome::Forward { worker, mut msg } => {
            let mut hop = msg.hop();
            if hop.origin_worker == NO_ORIGIN_WORKER {
                // First hop of the chain: the reply must come back to
                // *this* worker's leader stream under *this* frame's seq.
                hop.origin_seq = frame_seq;
                hop.origin_worker = index as u16;
                msg.set_hop(hop);
            }
            match mesh.send_to(worker, &msg) {
                Ok(()) => {
                    stats.forwarded.fetch_add(1, Ordering::Relaxed);
                    LeaderReplyAction::Deferred
                }
                Err(e) => {
                    log::error!("worker {index}: forward to worker {worker} failed: {e}");
                    fail(hop.hops.saturating_sub(1))
                }
            }
        }
    }
}

/// A worker's half of the worker↔worker mesh: outbound links to every
/// peer plus the plumbing to route chain replies back to the leader.
pub(crate) struct MeshNode {
    self_index: usize,
    links: LinkSet,
    /// This worker's leader-facing reply writer, shared with the leader
    /// receive path: a chain that originated here pushes its finished
    /// reply into it under the origin seq, and the leader's collector
    /// picks it up like any other (possibly out-of-order) reply.
    leader_writer: Arc<Mutex<ReplyWriter>>,
    stats: Arc<WorkerStats>,
}

impl MeshNode {
    /// Ship one frame to `peer` over the mesh. Self-forwarding is an
    /// error by contract (there is no loopback link; an ifunc that wants
    /// to continue locally simply computes on).
    fn send_to(&self, peer: usize, msg: &IfuncMsg) -> Result<()> {
        if peer == self.self_index {
            return Err(Error::Other(format!("forward targets self (worker {peer})")));
        }
        let link = self.links.get(peer)?;
        link.send(msg)?;
        link.flush()
    }

    /// Deliver a finished chain's reply to its origin: push straight into
    /// our own leader-facing stream when we are the origin, else ship a
    /// relay frame over the mesh. A relay that cannot go out is logged —
    /// the leader's `PendingReply` then times out naming the worker,
    /// which is the best a wedged relay path can offer.
    fn deliver_reply(&self, hop: Hop, ok: bool, r0: u64, reply: &[u8]) {
        let origin = hop.origin_worker as usize;
        let delivered = if origin == self.self_index {
            lock_recover(&self.leader_writer).push(hop.origin_seq, ok, r0, reply).map(|_| ())
        } else {
            IfuncMsg::relay(ok, r0, reply, hop).and_then(|m| self.send_to(origin, &m))
        };
        if let Err(e) = delivered {
            log::error!(
                "worker {}: reply relay to origin worker {origin} failed: {e}",
                self.self_index
            );
        }
    }

    /// One invoke-kind mesh frame was consumed (and executed, or died
    /// trying): continue the chain, or deliver its reply to the origin.
    fn handle_executed(&self, hop: Hop, outcome: Result<ExecOutcome>) {
        let me = self.self_index;
        let out = match outcome {
            Ok(out) => {
                self.stats.executed.fetch_add(1, Ordering::Relaxed);
                out
            }
            Err(e) => {
                self.stats.failed.fetch_add(1, Ordering::Relaxed);
                log::error!("worker {me}: mesh ifunc failed: {e}");
                self.deliver_reply(hop, false, encode_forward_failure(me, hop.hops), &[]);
                return;
            }
        };
        match out.forward {
            None => self.deliver_reply(hop, true, out.ret, &out.reply),
            Some(ForwardOutcome::Forward { worker, msg }) => match self.send_to(worker, &msg) {
                Ok(()) => {
                    self.stats.forwarded.fetch_add(1, Ordering::Relaxed);
                }
                Err(e) => {
                    self.stats.forward_failed.fetch_add(1, Ordering::Relaxed);
                    log::error!("worker {me}: forward to worker {worker} failed: {e}");
                    self.deliver_reply(hop, false, encode_forward_failure(me, hop.hops), &[]);
                }
            },
            Some(ForwardOutcome::TtlExhausted { worker }) => {
                self.stats.forward_failed.fetch_add(1, Ordering::Relaxed);
                log::error!(
                    "worker {me}: forward to worker {worker} rejected: TTL exhausted \
                     after {} hops",
                    hop.hops
                );
                self.deliver_reply(hop, false, encode_forward_failure(me, hop.hops), &[]);
            }
        }
    }

    /// A relay-kind frame arrived: we should be the chain's origin —
    /// unwrap the carried reply and push it into our leader-facing stream
    /// under the origin seq the leader registered at injection time.
    fn handle_relay(&self, hop: Hop, payload: &[u8]) {
        let me = self.self_index;
        if hop.origin_worker as usize != me {
            self.stats.failed.fetch_add(1, Ordering::Relaxed);
            log::error!(
                "worker {me}: relay for origin worker {} landed here",
                hop.origin_worker
            );
            return;
        }
        match IfuncMsg::decode_relay_payload(payload) {
            Ok((ok, r0, reply)) => {
                if let Err(e) = lock_recover(&self.leader_writer).push(hop.origin_seq, ok, r0, reply)
                {
                    log::error!("worker {me}: relayed reply push failed: {e}");
                }
            }
            Err(e) => {
                self.stats.failed.fetch_add(1, Ordering::Relaxed);
                log::error!("worker {me}: bad relay payload: {e}");
            }
        }
    }
}

/// One peer's inbound mesh ring (ring/shm transports): the delivery ring
/// this node polls plus the byte-credit sink pointing back at the
/// sender's flow-control word.
pub(crate) struct MeshIngressRing {
    peer: usize,
    ring: IfuncRing,
    credit: PutSink,
    last_credit: u64,
    stuck_reported_at: Option<u64>,
}

/// How mesh frames reach this worker: polled delivery rings (ring/shm) or
/// a dedicated AM ucp worker the mesh thread progresses.
pub(crate) enum MeshIngress {
    Rings(Vec<MeshIngressRing>),
    Am(Arc<UcpWorker>),
}

/// A worker's fully-wired mesh half, handed to [`WorkerBoot::start`].
pub(crate) struct MeshParts {
    node: Arc<MeshNode>,
    ingress: MeshIngress,
}

/// Build one ring-protocol delivery channel sender → receiver: the
/// receiver-side delivery ring, the sender-side transport writing into
/// it, and the byte-credit return sink targeting the sender's credit
/// word. `eps` carries the fabric endpoint pair `(sender→receiver,
/// receiver→sender)`; `None` selects the colocated shm wiring (shared
/// mappings, no endpoints). Shared by the leader links and every mesh
/// pair — the channel shape is identical, only who owns each end moves.
fn ring_channel(
    sender: &Arc<Context>,
    receiver: &Arc<Context>,
    ring_bytes: usize,
    replies: ReplyRing,
    consumed: ConsumedCounter,
    eps: Option<(Arc<Endpoint>, Arc<Endpoint>)>,
) -> Result<(Box<dyn IfuncTransport>, IfuncRing, PutSink)> {
    let ring = IfuncRing::new(receiver, ring_bytes)?;
    // Sender-side credit word; the receiver puts consumed-bytes into it.
    let credit = sender.mem_map(64, MemPerm::RW);
    Ok(match eps {
        Some((fwd, back)) => (
            Box::new(RingTransport::new(
                fwd,
                ring.rkey(),
                ring_bytes,
                credit.clone(),
                replies,
                consumed,
            )),
            ring,
            PutSink::Fabric { ep: back, rkey: credit.rkey() },
        ),
        None => (
            Box::new(ShmTransport::new(ring.region(), credit.clone(), replies, consumed)),
            ring,
            PutSink::Shm(credit),
        ),
    })
}

/// The ring-delivery receive loop, shared verbatim by the fabric ring and
/// shm transports — only where the return signals land differs (`credit`
/// and `consumed` sinks; the reply writer carries its own sink). Per
/// iteration: poll the ring, push byte credit on any consumption
/// (including wrap rewinds), answer each consumed frame with a reply
/// stream plus a consumed-counter tick — unless the invocation forwarded
/// itself over the mesh, in which case the reply is deferred to the
/// chain's final hop and only the credit/consumed signals fire — and
/// pump reply chunks parked on collector credit.
#[allow(clippy::too_many_arguments)]
fn ring_receive_loop(
    index: usize,
    ctx: Arc<Context>,
    mut ring: IfuncRing,
    store: Arc<RecordStore>,
    replies: Arc<Mutex<ReplyWriter>>,
    credit: PutSink,
    consumed: PutSink,
    stats: Arc<WorkerStats>,
    stop: Arc<AtomicBool>,
    mesh: Option<Arc<MeshNode>>,
) -> Result<()> {
    let mut args = TargetArgs::new(Box::new(store));
    let mut idle = 0u32;
    let mut last_credit = 0u64;
    // Cursor position of the last *non-consuming* error already reported
    // (a header-invalid frame parks at the cursor; report it once, not
    // per spin).
    let mut stuck_reported_at: Option<u64> = None;
    loop {
        let frames_before = ring.consumed;
        let polled = ctx.poll_ifunc(&mut ring, &mut args);
        let no_message = matches!(&polled, Ok(PollResult::NoMessage));
        let consumed_frame = ring.consumed > frames_before;
        let mut stuck = false;
        match &polled {
            Ok(PollResult::Executed(_)) => {
                stats.executed.fetch_add(1, Ordering::Relaxed);
                idle = 0;
            }
            Ok(PollResult::NoMessage) => {}
            Err(e) if consumed_frame => {
                // A faulty ifunc is consumed and reported, but must not
                // take the device down.
                stats.failed.fetch_add(1, Ordering::Relaxed);
                log::error!("worker {index}: ifunc failed: {e}");
                idle = 0;
            }
            Err(e) => {
                // The frame did NOT advance `ring.consumed`
                // (header-integrity failure — the length is untrusted, so
                // poll cannot skip it). It parks at the cursor and this
                // error repeats every poll: treat it like an idle spin —
                // back off and honor shutdown — instead of hot-looping
                // forever with `stop()` unreachable.
                if stuck_reported_at != Some(ring.consumed_bytes) {
                    stuck_reported_at = Some(ring.consumed_bytes);
                    stats.failed.fetch_add(1, Ordering::Relaxed);
                    log::error!(
                        "worker {index}: unconsumable frame parked at the ring cursor: {e}"
                    );
                }
                stuck = true;
            }
        }
        // Push the credit word whenever consumption advanced — including
        // marker-only polls (a wrap rewind reports NoMessage but consumes
        // the ring tail, and the oversized-wrap send path waits on
        // exactly that credit).
        if ring.consumed_bytes != last_credit {
            credit.signal(0, ring.consumed_bytes)?;
            last_credit = ring.consumed_bytes;
        }
        // One reply stream per consumed *frame* (not markers), whether it
        // executed or was rejected — except frames whose invocation
        // continued over the mesh: those reply from the chain's last hop
        // instead, but still tick the credit/consumed signals here so
        // flow control and barriers never depend on the chain's fate. A
        // reply-path error is logged and counted — never fatal to the
        // worker thread (the leader sees it as a reply timeout, not a
        // dead link).
        if consumed_frame {
            let frame_seq = ring.consumed;
            let action = match polled {
                Ok(PollResult::Executed(out)) => {
                    route_leader_outcome(index, mesh.as_deref(), &stats, frame_seq, out)
                }
                _ => LeaderReplyAction::Reply { ok: false, r0: 0, payload: Vec::new() },
            };
            if let LeaderReplyAction::Reply { ok, r0, payload } = action {
                if let Err(e) = lock_recover(&replies).push(frame_seq, ok, r0, &payload) {
                    stats.failed.fetch_add(1, Ordering::Relaxed);
                    log::error!("worker {index}: reply push failed: {e}");
                }
            }
            // Barrier credit: one tick per ingress frame, independent of
            // how many reply frames the stream needed. Like every
            // reply-path error: log, never die — a failed put degrades to
            // a barrier timeout, not a dead link.
            if let Err(e) = consumed.signal(0, frame_seq) {
                log::error!("worker {index}: consumed-credit put failed: {e}");
            }
        }
        // Drain reply chunks parked on collector credit (including
        // relayed chain replies the mesh thread queued concurrently).
        if let Err(e) = lock_recover(&replies).pump() {
            log::error!("worker {index}: reply pump failed: {e}");
        }
        if no_message || stuck {
            if stop.load(Ordering::Acquire) {
                let mut w = lock_recover(&replies);
                let _ = w.pump();
                w.flush()?;
                drop(w);
                credit.flush()?;
                consumed.flush()?;
                return Ok(());
            }
            crate::fabric::wire::backoff(idle);
            idle += 1;
        }
    }
}

/// The mesh receive loop (ring/shm transports): round-robin poll every
/// peer's inbound ring, execute invoke frames / unwrap relay frames, and
/// push byte credit back to each sender. One thread per worker serves all
/// its inbound mesh channels.
fn mesh_receive_loop(
    index: usize,
    ctx: Arc<Context>,
    mut rings: Vec<MeshIngressRing>,
    node: Arc<MeshNode>,
    store: Arc<RecordStore>,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    let mut args = TargetArgs::new(Box::new(store));
    let mut idle = 0u32;
    loop {
        let mut progressed = false;
        for ing in &mut rings {
            match ctx.poll_ifunc_mesh(&mut ing.ring, &mut args) {
                Ok(MeshPollResult::NoMessage) => {}
                Ok(MeshPollResult::Executed { hop, outcome }) => {
                    node.handle_executed(hop, outcome);
                    progressed = true;
                }
                Ok(MeshPollResult::Relay { hop, payload }) => {
                    node.handle_relay(hop, &payload);
                    progressed = true;
                }
                Err(e) => {
                    // Header-integrity failure: parks at the cursor
                    // (length untrusted, cannot skip) and repeats every
                    // poll — report once per cursor position, keep
                    // serving the other peers' rings.
                    if ing.stuck_reported_at != Some(ing.ring.consumed_bytes) {
                        ing.stuck_reported_at = Some(ing.ring.consumed_bytes);
                        node.stats.failed.fetch_add(1, Ordering::Relaxed);
                        log::error!(
                            "worker {index}: unconsumable mesh frame from worker {} \
                             parked at the ring cursor: {e}",
                            ing.peer
                        );
                    }
                }
            }
            // Byte credit back to the sending peer on any consumption
            // (frames and wrap rewinds both advance the sender's window).
            if ing.ring.consumed_bytes != ing.last_credit {
                ing.credit.signal(0, ing.ring.consumed_bytes)?;
                ing.last_credit = ing.ring.consumed_bytes;
            }
        }
        if !progressed {
            if stop.load(Ordering::Acquire) {
                for ing in &rings {
                    ing.credit.flush()?;
                }
                return Ok(());
            }
            crate::fabric::wire::backoff(idle);
            idle += 1;
        } else {
            idle = 0;
        }
    }
}

/// Fabric-link streamed-reply wiring, shared by the ring and AM build
/// paths: a worker-local watermark word the leader-side collector
/// advances as it consumes reply frames (the writer's slot-recycling
/// gate), plus the collector itself on a dedicated leader → worker
/// endpoint. Both `None` when `stream_replies` is off (the shm branch
/// wires its collector over shared mappings instead).
#[allow(clippy::type_complexity)]
fn fabric_reply_collector(
    ctx: &Arc<Context>,
    leader_worker: &Arc<UcpWorker>,
    ucp_worker: &Arc<UcpWorker>,
    replies: &ReplyRing,
    stream: bool,
) -> Result<(Option<Arc<ReplyCollector>>, Option<Arc<crate::fabric::MemoryRegion>>)> {
    if !stream {
        return Ok((None, None));
    }
    let credit_mr = ctx.mem_map(64, MemPerm::RW);
    let credit_ep = leader_worker.connect(ucp_worker)?;
    let collector = Arc::new(ReplyCollector::new(replies.clone(), credit_ep, credit_mr.rkey()));
    Ok((Some(collector), Some(credit_mr)))
}

/// How leader-injected frames reach this worker's receive thread.
enum LeaderIngress {
    /// Poll a delivery ring (fabric ring and shm transports — the same
    /// loop, different signal sinks).
    Ring { ring: IfuncRing, credit: PutSink, consumed: PutSink },
    /// Progress a UCP worker whose AM handler executes frames in place.
    Am { ucp_worker: Arc<UcpWorker>, ep_back: Arc<Endpoint>, consumed_rkey: RKey },
}

/// A fully-wired worker that has not started its receive threads yet.
///
/// `Cluster::launch` is multi-phase: every worker's leader link is built
/// first ([`WorkerBoot::build`]), then — with all contexts alive — the
/// worker↔worker mesh is wired pairwise ([`build_mesh`]), and only then
/// do threads start ([`WorkerBoot::start`]), each holding its mesh node.
/// Threads cannot start earlier: a receive loop must know its mesh links
/// before the first frame can ask to forward.
pub(crate) struct WorkerBoot {
    index: usize,
    ctx: Arc<Context>,
    store: Arc<RecordStore>,
    stats: Arc<WorkerStats>,
    shutdown: Arc<AtomicBool>,
    link: Arc<PeerLink>,
    /// The worker's leader-facing reply writer. Shared (mutex-wrapped)
    /// between the leader receive path and the mesh node: chain replies
    /// relayed back to this origin push into the same stream.
    leader_writer: Arc<Mutex<ReplyWriter>>,
    ingress: LeaderIngress,
}

impl WorkerBoot {
    /// Build the worker's context-side state and its leader link —
    /// transport, reply ring, collector, consumed counter — without
    /// spawning anything.
    pub(crate) fn build(
        index: usize,
        ctx: Arc<Context>,
        store: Arc<RecordStore>,
        leader: &Arc<Context>,
        leader_worker: &Arc<UcpWorker>,
        config: &ClusterConfig,
    ) -> Result<WorkerBoot> {
        // Leader-side reply region + consumed counter (transport-shared).
        let replies = ReplyRing::new(leader, config.reply_timeout);
        let reply_rkey = replies.rkey();
        let consumed = ConsumedCounter::new(leader, config.reply_timeout);
        let consumed_rkey = consumed.rkey();
        let stream = config.stream_replies;

        type Built = (
            Box<dyn IfuncTransport>,
            Option<Arc<ReplyCollector>>,
            Arc<Mutex<ReplyWriter>>,
            LeaderIngress,
        );
        let (transport, collector, leader_writer, ingress): Built = match config.transport {
            TransportKind::Ring => {
                let ucp_worker = UcpWorker::new(&ctx);
                let ep = leader_worker.connect(&ucp_worker)?;
                let ep_back = ucp_worker.connect(leader_worker)?;
                let (collector, reply_credit) =
                    fabric_reply_collector(&ctx, leader_worker, &ucp_worker, &replies, stream)?;
                let (transport, ring, credit_sink) = ring_channel(
                    leader,
                    &ctx,
                    config.ring_bytes,
                    replies.clone(),
                    consumed.clone(),
                    Some((ep, ep_back.clone())),
                )?;
                let writer = Arc::new(Mutex::new(ReplyWriter::with_mode(
                    ep_back.clone(),
                    reply_rkey,
                    stream,
                    reply_credit,
                )));
                let consumed_sink = PutSink::Fabric { ep: ep_back, rkey: consumed_rkey };
                (
                    transport,
                    collector,
                    writer,
                    LeaderIngress::Ring { ring, credit: credit_sink, consumed: consumed_sink },
                )
            }
            TransportKind::Shm => {
                // Colocated worker: no UCP worker, no endpoints — every
                // channel on the link is a shared mapping. The delivery
                // ring keeps its RWX grant (it holds code); all the
                // counter/reply words are plain RW.
                let (collector, reply_credit) = if stream {
                    let credit_mr = ctx.mem_map(64, MemPerm::RW);
                    let collector =
                        Arc::new(ReplyCollector::shm(replies.clone(), credit_mr.clone()));
                    (Some(collector), Some(credit_mr))
                } else {
                    (None, None)
                };
                let (transport, ring, credit_sink) = ring_channel(
                    leader,
                    &ctx,
                    config.ring_bytes,
                    replies.clone(),
                    consumed.clone(),
                    None,
                )?;
                let writer = Arc::new(Mutex::new(ReplyWriter::shm(&replies, stream, reply_credit)));
                let consumed_sink = PutSink::Shm(consumed.region());
                (
                    transport,
                    collector,
                    writer,
                    LeaderIngress::Ring { ring, credit: credit_sink, consumed: consumed_sink },
                )
            }
            TransportKind::Am => {
                let ucp_worker = UcpWorker::new(&ctx);
                let ep = leader_worker.connect(&ucp_worker)?;
                let ep_back = ucp_worker.connect(leader_worker)?;
                let (collector, reply_credit) =
                    fabric_reply_collector(&ctx, leader_worker, &ucp_worker, &replies, stream)?;
                let transport: Box<dyn IfuncTransport> =
                    Box::new(AmTransport::new(ep, replies.clone(), consumed.clone()));
                let writer = Arc::new(Mutex::new(ReplyWriter::with_mode(
                    ep_back.clone(),
                    reply_rkey,
                    stream,
                    reply_credit,
                )));
                (
                    transport,
                    collector,
                    writer,
                    LeaderIngress::Am { ucp_worker, ep_back, consumed_rkey },
                )
            }
        };

        let link = Arc::new(PeerLink::new(
            index,
            transport,
            replies,
            consumed,
            collector,
            config.max_inflight,
            config.reply_timeout,
        ));
        Ok(WorkerBoot {
            index,
            ctx,
            store,
            stats: Arc::new(WorkerStats::default()),
            shutdown: Arc::new(AtomicBool::new(false)),
            link,
            leader_writer,
            ingress,
        })
    }

    /// Start the receive thread(s) — the single spawn site for the
    /// ring-protocol loop (fabric ring and shm both land here) and, with
    /// a mesh, the per-worker mesh thread.
    pub(crate) fn start(self, mesh: Option<MeshParts>) -> Result<WorkerHandle> {
        let WorkerBoot { index, ctx, store, stats, shutdown, link, leader_writer, ingress } = self;
        let (node, mesh_ingress) = match mesh {
            Some(p) => (Some(p.node), Some(p.ingress)),
            None => (None, None),
        };

        let thread = match ingress {
            LeaderIngress::Ring { ring, credit, consumed } => {
                let (ctx2, store2, stop2, stats2) =
                    (ctx.clone(), store.clone(), shutdown.clone(), stats.clone());
                let (writer2, node2) = (leader_writer.clone(), node.clone());
                std::thread::Builder::new()
                    .name(format!("ifunc-worker-{index}"))
                    .spawn(move || {
                        ring_receive_loop(
                            index, ctx2, ring, store2, writer2, credit, consumed, stats2,
                            stop2, node2,
                        )
                    })
                    .expect("spawn worker thread")
            }
            LeaderIngress::Am { ucp_worker, ep_back, consumed_rkey } => {
                // The AM handler owns the target args; it runs on the
                // progress thread below.
                let target_args =
                    Arc::new(Mutex::new(TargetArgs::new(Box::new(store.clone()))));
                let frames = Arc::new(AtomicU64::new(0));
                let (ctx2, stats2, node2) = (ctx.clone(), stats.clone(), node.clone());
                let rw = leader_writer.clone();
                let ep_back3 = ep_back.clone();
                ucp_worker.set_am_handler_mut(IFUNC_AM_ID, move |_, frame| {
                    // Ingress frame seq: handlers run serially on the
                    // progress thread, so this matches delivery order.
                    let frame_seq = frames.fetch_add(1, Ordering::Relaxed) + 1;
                    let action = match execute_am_frame_in_place(&ctx2, frame, &target_args) {
                        Ok(out) => {
                            stats2.executed.fetch_add(1, Ordering::Relaxed);
                            route_leader_outcome(index, node2.as_deref(), &stats2, frame_seq, out)
                        }
                        Err(e) => {
                            stats2.failed.fetch_add(1, Ordering::Relaxed);
                            log::error!("worker {index}: ifunc failed: {e}");
                            LeaderReplyAction::Reply { ok: false, r0: 0, payload: Vec::new() }
                        }
                    };
                    if let LeaderReplyAction::Reply { ok, r0, payload } = action {
                        if let Err(e) = lock_recover(&rw).push(frame_seq, ok, r0, &payload) {
                            log::error!("worker {index}: reply push failed: {e}");
                        }
                    }
                    if let Err(e) = ep_back3.qp().put_signal(consumed_rkey, 0, frame_seq) {
                        log::error!("worker {index}: consumed-credit put failed: {e}");
                    }
                });
                let (stop2, ep_back2) = (shutdown.clone(), ep_back);
                let rw2 = leader_writer.clone();
                let uw = ucp_worker;
                std::thread::Builder::new()
                    .name(format!("ifunc-worker-{index}"))
                    .spawn(move || -> Result<()> {
                        let mut idle = 0u32;
                        loop {
                            let progressed = uw.progress();
                            // Drain reply chunks parked on collector
                            // credit (the handler must never block inside
                            // `progress`, so queued chunks are pumped
                            // from here).
                            if let Err(e) = lock_recover(&rw2).pump() {
                                log::error!("worker {index}: reply pump failed: {e}");
                            }
                            if progressed == 0 {
                                if stop2.load(Ordering::Acquire) {
                                    let _ = lock_recover(&rw2).pump();
                                    ep_back2.qp().flush()?;
                                    return Ok(());
                                }
                                crate::fabric::wire::backoff(idle);
                                idle += 1;
                            } else {
                                idle = 0;
                            }
                        }
                    })
                    .expect("spawn worker thread")
            }
        };

        let mesh_thread = match mesh_ingress {
            None => None,
            Some(MeshIngress::Rings(rings)) => {
                let node = node.expect("mesh ingress without mesh node");
                let (ctx2, store2, stop2) = (ctx.clone(), store.clone(), shutdown.clone());
                Some(
                    std::thread::Builder::new()
                        .name(format!("ifunc-mesh-{index}"))
                        .spawn(move || mesh_receive_loop(index, ctx2, rings, node, store2, stop2))
                        .expect("spawn mesh thread"),
                )
            }
            Some(MeshIngress::Am(uw)) => {
                let node = node.expect("mesh ingress without mesh node");
                // Mesh frames execute with their own target args — the
                // leader-link handler owns the other set, on a different
                // ucp worker/thread.
                let target_args =
                    Arc::new(Mutex::new(TargetArgs::new(Box::new(store.clone()))));
                let (ctx2, node2) = (ctx.clone(), node);
                uw.set_am_handler_mut(IFUNC_AM_ID, move |_, frame| {
                    if frame.len() < HEADER_BYTES {
                        node2.stats.failed.fetch_add(1, Ordering::Relaxed);
                        log::error!("worker {index}: runt mesh frame ({} bytes)", frame.len());
                        return;
                    }
                    let header = match Header::decode(&frame[..HEADER_BYTES]) {
                        Ok(Some(h)) => h,
                        _ => {
                            node2.stats.failed.fetch_add(1, Ordering::Relaxed);
                            log::error!("worker {index}: bad mesh frame header");
                            return;
                        }
                    };
                    let hop = header.hop;
                    if hop.kind == HOP_KIND_RELAY {
                        let s = header.payload_offset as usize;
                        match frame.get(s..s + header.payload_len as usize) {
                            Some(pay) => node2.handle_relay(hop, pay),
                            None => {
                                node2.stats.failed.fetch_add(1, Ordering::Relaxed);
                                log::error!("worker {index}: truncated relay frame");
                            }
                        }
                    } else {
                        let outcome = execute_am_frame_in_place(&ctx2, frame, &target_args);
                        node2.handle_executed(hop, outcome);
                    }
                });
                let stop2 = shutdown.clone();
                Some(
                    std::thread::Builder::new()
                        .name(format!("ifunc-mesh-{index}"))
                        .spawn(move || -> Result<()> {
                            let mut idle = 0u32;
                            loop {
                                if uw.progress() == 0 {
                                    if stop2.load(Ordering::Acquire) {
                                        return Ok(());
                                    }
                                    crate::fabric::wire::backoff(idle);
                                    idle += 1;
                                } else {
                                    idle = 0;
                                }
                            }
                        })
                        .expect("spawn mesh thread"),
                )
            }
        };

        Ok(WorkerHandle {
            index,
            ctx,
            store,
            stats,
            link,
            shutdown,
            thread: Some(thread),
            mesh_thread,
        })
    }
}

/// Wire the worker↔worker mesh: one [`PeerLink`] per ordered pair (i, j),
/// i ≠ j, over the cluster's transport kind — the exact channel shape the
/// leader links use ([`ring_channel`] / [`AmTransport`]), just owned by a
/// worker instead of the leader. Returns each worker's [`MeshParts`] for
/// [`WorkerBoot::start`].
pub(crate) fn build_mesh(boots: &[WorkerBoot], config: &ClusterConfig) -> Result<Vec<MeshParts>> {
    let n = boots.len();
    let mesh_ring_bytes = config.ring_bytes.min(MESH_RING_BYTES_MAX);
    // Fabric transports get a dedicated per-worker UCP worker for the
    // mesh (the leader-link ucp workers belong to their receive paths).
    let mesh_uws: Vec<Option<Arc<UcpWorker>>> = boots
        .iter()
        .map(|b| match config.transport {
            TransportKind::Shm => None,
            _ => Some(UcpWorker::new(&b.ctx)),
        })
        .collect();
    // One idle reply ring + consumed counter per *sender*, shared by all
    // its mesh links: the transport contract wires both, but mesh
    // traffic is fire-and-forget (replies travel as relay frames and
    // barriers do not span the mesh), so nothing ever writes them —
    // per-pair regions would be pure waste.
    let mut links: Vec<Vec<Option<Arc<PeerLink>>>> =
        (0..n).map(|_| (0..n).map(|_| None).collect()).collect();
    let mut ring_ingress: Vec<Vec<MeshIngressRing>> = (0..n).map(|_| Vec::new()).collect();
    for i in 0..n {
        let replies = ReplyRing::new(&boots[i].ctx, config.reply_timeout);
        let consumed = ConsumedCounter::new(&boots[i].ctx, config.reply_timeout);
        for j in 0..n {
            if i == j {
                continue;
            }
            let transport: Box<dyn IfuncTransport> = match config.transport {
                TransportKind::Ring | TransportKind::Shm => {
                    let eps = match (&mesh_uws[i], &mesh_uws[j]) {
                        (Some(wi), Some(wj)) => Some((wi.connect(wj)?, wj.connect(wi)?)),
                        _ => None,
                    };
                    let (transport, ring, credit) = ring_channel(
                        &boots[i].ctx,
                        &boots[j].ctx,
                        mesh_ring_bytes,
                        replies.clone(),
                        consumed.clone(),
                        eps,
                    )?;
                    ring_ingress[j].push(MeshIngressRing {
                        peer: i,
                        ring,
                        credit,
                        last_credit: 0,
                        stuck_reported_at: None,
                    });
                    transport
                }
                TransportKind::Am => {
                    let wi = mesh_uws[i].as_ref().expect("am mesh has ucp workers");
                    let wj = mesh_uws[j].as_ref().expect("am mesh has ucp workers");
                    Box::new(AmTransport::new(wi.connect(wj)?, replies.clone(), consumed.clone()))
                }
            };
            links[i][j] = Some(Arc::new(PeerLink::new(
                j,
                transport,
                replies.clone(),
                consumed.clone(),
                None,
                config.max_inflight,
                config.reply_timeout,
            )));
        }
    }
    let mut parts = Vec::with_capacity(n);
    for (i, boot) in boots.iter().enumerate() {
        let node = Arc::new(MeshNode {
            self_index: i,
            links: LinkSet::new(std::mem::take(&mut links[i])),
            leader_writer: boot.leader_writer.clone(),
            stats: boot.stats.clone(),
        });
        let ingress = match config.transport {
            TransportKind::Am => {
                MeshIngress::Am(mesh_uws[i].clone().expect("am mesh has ucp workers"))
            }
            _ => MeshIngress::Rings(std::mem::take(&mut ring_ingress[i])),
        };
        parts.push(MeshParts { node, ingress });
    }
    Ok(parts)
}

impl WorkerHandle {
    /// Executed-message count (leader-visible). Every hop of a forwarded
    /// chain counts at the worker where it ran.
    pub fn executed(&self) -> u64 {
        self.stats.executed.load(Ordering::Acquire)
    }

    /// Frames this worker forwarded onward over the mesh.
    pub fn forwarded(&self) -> u64 {
        self.stats.forwarded.load(Ordering::Acquire)
    }

    /// Forward attempts that died at this worker.
    pub fn forward_failed(&self) -> u64 {
        self.stats.forward_failed.load(Ordering::Acquire)
    }

    /// Signal shutdown and join the receive thread(s).
    pub fn stop(&mut self) -> Result<()> {
        self.shutdown.store(true, Ordering::Release);
        if let Some(t) = self.thread.take() {
            t.join().map_err(|_| Error::Other("worker thread panicked".into()))??;
        }
        if let Some(t) = self.mesh_thread.take() {
            t.join().map_err(|_| Error::Other("mesh thread panicked".into()))??;
        }
        Ok(())
    }
}

impl Drop for WorkerHandle {
    fn drop(&mut self) {
        let _ = self.stop();
    }
}
