//! Device-side worker: a polling "DPU/CSD process".
//!
//! Each worker executes whatever the host injects — over any transport:
//!
//! * **ring** ([`TransportKind::Ring`]): a dedicated thread runs
//!   `ucp_poll_ifunc` against the worker's RWX ring and pushes a
//!   consumed-bytes credit word back to the leader so the dispatcher can
//!   flow-control without ever overwriting an unconsumed frame,
//! * **am** ([`TransportKind::Am`]): frames arrive as active messages and
//!   the thread simply progresses the UCP worker (§5.1's "ifuncs will be
//!   progressed with other UCX operations"),
//! * **shm** ([`TransportKind::Shm`]): the *same* poll loop as ring — the
//!   frames were memcpy'd into the shared ring mapping by the colocated
//!   leader — but every return signal (byte credit, reply frames,
//!   consumed counter) is a plain release-store into the shared words
//!   instead of a fabric put; no endpoint exists on the link at all.
//!
//! All paths run the same execution engine and answer every consumed
//! frame — executed or rejected — with one or more payload-carrying reply
//! frames: whatever the injected function pushed through `reply_put` /
//! `db_get` travels back, chunked into `STATUS_MORE` frames when it
//! exceeds one slot (see `ifunc::reply`), which is what
//! `Dispatcher::invoke` and `PendingReply` wait on. `Dispatcher::barrier`
//! waits on a separate per-ingress-frame **consumed counter** the worker
//! advances once per frame (a chunked reply occupies several reply seqs,
//! so reply seqs are no longer a frame count). There is no leader-side
//! result region: invocation results are messages, not shared memory.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::ifunc::am_transport::{execute_am_frame_in_place, IFUNC_AM_ID};
use crate::ifunc::transport::PutSink;
use crate::ifunc::{
    AmTransport, ConsumedCounter, IfuncRing, IfuncTransport, PollResult, ReplyCollector,
    ReplyRing, ReplyWriter, RingTransport, ShmTransport, TargetArgs, TransportKind,
    REPLY_SLOTS,
};
use crate::log;
use crate::ucp::{Context, Worker as UcpWorker};
use crate::util::sync::lock_recover;
use crate::{Error, Result};

use super::dispatcher::InvokeWindow;
use super::store::RecordStore;
use super::ClusterConfig;

/// `db_get`'s r0 when the key is absent.
pub const GET_MISSING: u64 = u64::MAX;

/// Worker-side execution counters.
#[derive(Default)]
pub struct WorkerStats {
    pub executed: AtomicU64,
    pub failed: AtomicU64,
}

/// A spawned worker: context + store + receive thread + leader link.
pub struct WorkerHandle {
    pub index: usize,
    pub ctx: Arc<Context>,
    pub store: Arc<RecordStore>,
    pub stats: Arc<WorkerStats>,
    /// Leader-side delivery channel (transport-generic).
    pub(crate) link: Mutex<Box<dyn IfuncTransport>>,
    /// Leader-side view of the link's reply ring, shared with the
    /// transport so `PendingReply::wait` runs without the link lock.
    pub(crate) replies: ReplyRing,
    /// Leader-side view of the link's consumed-frame counter — the
    /// barrier credit (one tick per ingress frame, however many reply
    /// frames it produced).
    pub(crate) consumed: ConsumedCounter,
    /// Streamed-reply reassembler (`None` when
    /// `ClusterConfig::stream_replies` is off and the legacy
    /// one-frame-per-reply slot protocol runs instead).
    pub(crate) collector: Option<Arc<ReplyCollector>>,
    /// Caps outstanding invocations on this link (`max_inflight`) and —
    /// in legacy mode — guards every send against lapping an uncollected
    /// reply.
    pub(crate) window: Arc<InvokeWindow>,
    /// `ClusterConfig::reply_timeout`, for the window's admission check.
    pub(crate) reply_timeout: Option<std::time::Duration>,
    shutdown: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<Result<()>>>,
}

/// The ring-delivery receive loop, shared verbatim by the fabric ring and
/// shm transports — only where the return signals land differs (`credit`
/// and `consumed` sinks; the reply writer carries its own sink). Per
/// iteration: poll the ring, push byte credit on any consumption
/// (including wrap rewinds), answer each consumed frame with a reply
/// stream plus a consumed-counter tick, and pump reply chunks parked on
/// collector credit.
#[allow(clippy::too_many_arguments)]
fn ring_receive_loop(
    index: usize,
    ctx: Arc<Context>,
    mut ring: IfuncRing,
    store: Arc<RecordStore>,
    mut replies: ReplyWriter,
    credit: PutSink,
    consumed: PutSink,
    stats: Arc<WorkerStats>,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    let mut args = TargetArgs::new(Box::new(store));
    let mut idle = 0u32;
    let mut last_credit = 0u64;
    // Cursor position of the last *non-consuming* error already reported
    // (a header-invalid frame parks at the cursor; report it once, not
    // per spin).
    let mut stuck_reported_at: Option<u64> = None;
    loop {
        let frames_before = ring.consumed;
        let polled = ctx.poll_ifunc(&mut ring, &mut args);
        let no_message = matches!(&polled, Ok(PollResult::NoMessage));
        let consumed_frame = ring.consumed > frames_before;
        let mut stuck = false;
        match &polled {
            Ok(PollResult::Executed(_)) => {
                stats.executed.fetch_add(1, Ordering::Relaxed);
                idle = 0;
            }
            Ok(PollResult::NoMessage) => {}
            Err(e) if consumed_frame => {
                // A faulty ifunc is consumed and reported, but must not
                // take the device down.
                stats.failed.fetch_add(1, Ordering::Relaxed);
                log::error!("worker {index}: ifunc failed: {e}");
                idle = 0;
            }
            Err(e) => {
                // The frame did NOT advance `ring.consumed`
                // (header-integrity failure — the length is untrusted, so
                // poll cannot skip it). It parks at the cursor and this
                // error repeats every poll: treat it like an idle spin —
                // back off and honor shutdown — instead of hot-looping
                // forever with `stop()` unreachable.
                if stuck_reported_at != Some(ring.consumed_bytes) {
                    stuck_reported_at = Some(ring.consumed_bytes);
                    stats.failed.fetch_add(1, Ordering::Relaxed);
                    log::error!(
                        "worker {index}: unconsumable frame parked at the ring cursor: {e}"
                    );
                }
                stuck = true;
            }
        }
        // Push the credit word whenever consumption advanced — including
        // marker-only polls (a wrap rewind reports NoMessage but consumes
        // the ring tail, and the oversized-wrap send path waits on
        // exactly that credit).
        if ring.consumed_bytes != last_credit {
            credit.signal(0, ring.consumed_bytes)?;
            last_credit = ring.consumed_bytes;
        }
        // One reply stream per consumed *frame* (not markers), whether it
        // executed or was rejected; executed frames carry the bytes the
        // injected function pushed, chunked when they exceed one reply
        // slot. A reply-path error is logged and counted — never fatal to
        // the worker thread (the leader sees it as a reply timeout, not a
        // dead link).
        if consumed_frame {
            let pushed = match polled {
                Ok(PollResult::Executed(out)) => {
                    replies.push(ring.consumed, true, out.ret, &out.reply)
                }
                _ => replies.push(ring.consumed, false, 0, &[]),
            };
            if let Err(e) = pushed {
                stats.failed.fetch_add(1, Ordering::Relaxed);
                log::error!("worker {index}: reply push failed: {e}");
            }
            // Barrier credit: one tick per ingress frame, independent of
            // how many reply frames the stream needed. Like every
            // reply-path error: log, never die — a failed put degrades to
            // a barrier timeout, not a dead link.
            if let Err(e) = consumed.signal(0, ring.consumed) {
                log::error!("worker {index}: consumed-credit put failed: {e}");
            }
        }
        // Drain reply chunks parked on collector credit.
        if let Err(e) = replies.pump() {
            log::error!("worker {index}: reply pump failed: {e}");
        }
        if no_message || stuck {
            if stop.load(Ordering::Acquire) {
                let _ = replies.pump();
                replies.flush()?;
                credit.flush()?;
                consumed.flush()?;
                return Ok(());
            }
            crate::fabric::wire::backoff(idle);
            idle += 1;
        }
    }
}

/// Fabric-link streamed-reply wiring, shared by the ring and AM spawn
/// paths: a worker-local watermark word the leader-side collector
/// advances as it consumes reply frames (the writer's slot-recycling
/// gate), plus the collector itself on a dedicated leader → worker
/// endpoint. Both `None` when `stream_replies` is off (the shm branch
/// wires its collector over shared mappings instead).
#[allow(clippy::type_complexity)]
fn fabric_reply_collector(
    ctx: &Arc<Context>,
    leader_worker: &Arc<UcpWorker>,
    ucp_worker: &Arc<UcpWorker>,
    replies: &ReplyRing,
    stream: bool,
) -> Result<(Option<Arc<ReplyCollector>>, Option<Arc<crate::fabric::MemoryRegion>>)> {
    if !stream {
        return Ok((None, None));
    }
    let credit_mr = ctx.mem_map(64, crate::fabric::MemPerm::RW);
    let credit_ep = leader_worker.connect(ucp_worker)?;
    let collector = Arc::new(ReplyCollector::new(replies.clone(), credit_ep, credit_mr.rkey()));
    Ok((Some(collector), Some(credit_mr)))
}

impl WorkerHandle {
    pub(crate) fn spawn(
        index: usize,
        ctx: Arc<Context>,
        store: Arc<RecordStore>,
        leader: &Arc<Context>,
        leader_worker: &Arc<UcpWorker>,
        config: &ClusterConfig,
    ) -> Result<WorkerHandle> {
        // Leader-side reply region + consumed counter (transport-shared).
        let replies = ReplyRing::new(leader, config.reply_timeout);
        let reply_rkey = replies.rkey();
        let consumed = ConsumedCounter::new(leader, config.reply_timeout);
        let consumed_rkey = consumed.rkey();
        let window = Arc::new(InvokeWindow::new(config.max_inflight.clamp(1, REPLY_SLOTS)));
        let shutdown = Arc::new(AtomicBool::new(false));
        let stats = Arc::new(WorkerStats::default());
        let stream = config.stream_replies;

        type Spawned = (
            Box<dyn IfuncTransport>,
            Option<Arc<ReplyCollector>>,
            std::thread::JoinHandle<Result<()>>,
        );
        let (transport, collector, thread): Spawned = match config.transport {
            TransportKind::Ring => {
                let ucp_worker = UcpWorker::new(&ctx);
                let ep = leader_worker.connect(&ucp_worker)?;
                let ep_back = ucp_worker.connect(leader_worker)?;
                let (collector, reply_credit) =
                    fabric_reply_collector(&ctx, leader_worker, &ucp_worker, &replies, stream)?;
                let ring = IfuncRing::new(&ctx, config.ring_bytes)?;
                // Leader-side credit word; worker puts consumed-bytes into it.
                let credit = leader.mem_map(64, crate::fabric::MemPerm::RW);
                let transport = Box::new(RingTransport::new(
                    ep,
                    ring.rkey(),
                    config.ring_bytes,
                    credit.clone(),
                    replies.clone(),
                    consumed.clone(),
                ));
                let writer =
                    ReplyWriter::with_mode(ep_back.clone(), reply_rkey, stream, reply_credit);
                let credit_sink = PutSink::Fabric { ep: ep_back.clone(), rkey: credit.rkey() };
                let consumed_sink = PutSink::Fabric { ep: ep_back, rkey: consumed_rkey };
                let (ctx2, store2, stop2, stats2) =
                    (ctx.clone(), store.clone(), shutdown.clone(), stats.clone());
                let thread = std::thread::Builder::new()
                    .name(format!("ifunc-worker-{index}"))
                    .spawn(move || {
                        ring_receive_loop(
                            index,
                            ctx2,
                            ring,
                            store2,
                            writer,
                            credit_sink,
                            consumed_sink,
                            stats2,
                            stop2,
                        )
                    })
                    .expect("spawn worker thread");
                (transport, collector, thread)
            }
            TransportKind::Shm => {
                // Colocated worker: no UCP worker, no endpoints — every
                // channel on the link is a shared mapping. The delivery
                // ring keeps its RWX grant (it holds code); all the
                // counter/reply words are plain RW.
                let (collector, reply_credit) = if stream {
                    let credit_mr = ctx.mem_map(64, crate::fabric::MemPerm::RW);
                    let collector =
                        Arc::new(ReplyCollector::shm(replies.clone(), credit_mr.clone()));
                    (Some(collector), Some(credit_mr))
                } else {
                    (None, None)
                };
                let ring = IfuncRing::new(&ctx, config.ring_bytes)?;
                let credit = leader.mem_map(64, crate::fabric::MemPerm::RW);
                let transport = Box::new(ShmTransport::new(
                    ring.region(),
                    credit.clone(),
                    replies.clone(),
                    consumed.clone(),
                ));
                let writer = ReplyWriter::shm(&replies, stream, reply_credit);
                let credit_sink = PutSink::Shm(credit);
                let consumed_sink = PutSink::Shm(consumed.region());
                let (ctx2, store2, stop2, stats2) =
                    (ctx.clone(), store.clone(), shutdown.clone(), stats.clone());
                let thread = std::thread::Builder::new()
                    .name(format!("ifunc-worker-{index}"))
                    .spawn(move || {
                        ring_receive_loop(
                            index,
                            ctx2,
                            ring,
                            store2,
                            writer,
                            credit_sink,
                            consumed_sink,
                            stats2,
                            stop2,
                        )
                    })
                    .expect("spawn worker thread");
                (transport, collector, thread)
            }
            TransportKind::Am => {
                let ucp_worker = UcpWorker::new(&ctx);
                let ep = leader_worker.connect(&ucp_worker)?;
                let ep_back = ucp_worker.connect(leader_worker)?;
                let (collector, reply_credit) =
                    fabric_reply_collector(&ctx, leader_worker, &ucp_worker, &replies, stream)?;
                let transport =
                    Box::new(AmTransport::new(ep, replies.clone(), consumed.clone()));
                // The AM handler owns the reply writer and target args;
                // it runs on the progress thread below.
                let target_args =
                    Arc::new(Mutex::new(TargetArgs::new(Box::new(store.clone()))));
                let reply_writer = Arc::new(Mutex::new(ReplyWriter::with_mode(
                    ep_back.clone(),
                    reply_rkey,
                    stream,
                    reply_credit,
                )));
                let frames = Arc::new(AtomicU64::new(0));
                let (ctx2, stats2) = (ctx.clone(), stats.clone());
                let rw = reply_writer.clone();
                let (frames2, ep_back3) = (frames.clone(), ep_back.clone());
                ucp_worker.set_am_handler_mut(IFUNC_AM_ID, move |_, frame| {
                    // Ingress frame seq: handlers run serially on the
                    // progress thread, so this matches delivery order.
                    let frame_seq = frames2.fetch_add(1, Ordering::Relaxed) + 1;
                    let (ok, r0, payload) =
                        match execute_am_frame_in_place(&ctx2, frame, &target_args) {
                            Ok(out) => {
                                stats2.executed.fetch_add(1, Ordering::Relaxed);
                                (true, out.ret, out.reply)
                            }
                            Err(e) => {
                                stats2.failed.fetch_add(1, Ordering::Relaxed);
                                log::error!("worker {index}: ifunc failed: {e}");
                                (false, 0, Vec::new())
                            }
                        };
                    if let Err(e) = lock_recover(&rw).push(frame_seq, ok, r0, &payload) {
                        log::error!("worker {index}: reply push failed: {e}");
                    }
                    if let Err(e) = ep_back3.qp().put_signal(consumed_rkey, 0, frame_seq) {
                        log::error!("worker {index}: consumed-credit put failed: {e}");
                    }
                });
                let (stop2, ep_back2) = (shutdown.clone(), ep_back.clone());
                let rw2 = reply_writer.clone();
                let uw = ucp_worker.clone();
                let thread = std::thread::Builder::new()
                    .name(format!("ifunc-worker-{index}"))
                    .spawn(move || -> Result<()> {
                        let mut idle = 0u32;
                        loop {
                            let progressed = uw.progress();
                            // Drain reply chunks parked on collector
                            // credit (the handler must never block inside
                            // `progress`, so queued chunks are pumped
                            // from here).
                            if let Err(e) = lock_recover(&rw2).pump() {
                                log::error!("worker {index}: reply pump failed: {e}");
                            }
                            if progressed == 0 {
                                if stop2.load(Ordering::Acquire) {
                                    let _ = lock_recover(&rw2).pump();
                                    ep_back2.qp().flush()?;
                                    return Ok(());
                                }
                                crate::fabric::wire::backoff(idle);
                                idle += 1;
                            } else {
                                idle = 0;
                            }
                        }
                    })
                    .expect("spawn worker thread");
                (transport, collector, thread)
            }
        };

        Ok(WorkerHandle {
            index,
            ctx,
            store,
            stats,
            link: Mutex::new(transport),
            replies,
            consumed,
            collector,
            window,
            reply_timeout: config.reply_timeout,
            shutdown,
            thread: Some(thread),
        })
    }

    /// Executed-message count (leader-visible).
    pub fn executed(&self) -> u64 {
        self.stats.executed.load(Ordering::Acquire)
    }

    /// Signal shutdown and join the receive thread.
    pub fn stop(&mut self) -> Result<()> {
        self.shutdown.store(true, Ordering::Release);
        if let Some(t) = self.thread.take() {
            t.join().map_err(|_| Error::Other("worker thread panicked".into()))??;
        }
        Ok(())
    }
}

impl Drop for WorkerHandle {
    fn drop(&mut self) {
        let _ = self.stop();
    }
}
