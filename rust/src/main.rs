//! `repro` — CLI for the Two-Chains / ifunc reproduction.
//!
//! ```text
//! repro bench fig3|fig4|ablations [--quick] [--icache coherent] [--no-cache]
//!                                 [--rndv-thresh N] [--code-pad N]
//!                                 [--msgs N] [--iters N] [--sizes a,b,c]
//! repro demo                      # Listing 1.3/1.4 flow on the fabric
//! repro serve [--workers N] [--listen ADDR] [--transport ring|am|shm]
//! repro info
//! ```
//!
//! (Argument parsing is hand-rolled: the offline build environment has no
//! clap.)

use two_chains::bench::{
    harness::{BenchConfig, BenchPair},
    latency, report, throughput,
};
use two_chains::fabric::WireConfig;
use two_chains::ifunc::icache::IcacheConfig;
use two_chains::ucp::AmParams;
use two_chains::{Error, Result};

mod serve;

const USAGE: &str = "\
repro — Two-Chains / UCX ifunc reproduction

USAGE:
  repro bench fig3        regenerate Fig. 3 (ping-pong latency sweep)
  repro bench fig4        regenerate Fig. 4 (message-throughput sweep)
  repro bench ablations   Abl A (icache) / B (cache) / C (rndv) / D (code size)
  repro demo              quickstart: inject the counter ifunc
  repro serve             record-ingestion cluster over TCP (text protocol)
  repro info              print configuration + artifact inventory

BENCH OPTIONS:
  --quick                 small sweep, no wire model (CI smoke)
  --icache <non-coherent|coherent>
  --no-cache              disable target auto-registration cache (Abl B)
  --rndv-thresh <bytes>   AM rendezvous threshold (UCX_RNDV_THRESH, Abl C)
  --code-pad <instrs>     pad the counter ifunc's code section
  --msgs <n>              messages per size (fig4)
  --iters <n>             ping-pong iterations per size (fig3)
  --sizes <a,b,c>         explicit payload sizes in bytes

SERVE OPTIONS:
  --workers <n>           device workers (default 2)
  --listen <addr>         TCP listen address (default 127.0.0.1:7100)
  --transport <ring|am|shm>  frame delivery transport (default ring; shm =
                          colocated workers over intra-node shared memory)
  --mesh                  wire the worker-to-worker mesh so injected code
                          can continue on a peer via the forward symbol
  --max-clients <n>       concurrent connection cap (default 64; over-cap
                          connections get one JSON error line, then close)
  --session-window <n>    per-client pipelined requests in flight (default 16)
  --queue-depth <n>       per-worker submission high-water mark; past it
                          requests shed with {\"error\":\"overloaded\",
                          \"retry\":true} (default 256)
  --batch-max <n>         max frames per coalesced cross-client batch
                          (default 16)
  --no-coalesce           synchronous one-invocation-per-request dispatch
                          (the pre-pipeline behavior; for comparison)
";

#[derive(Default, Clone)]
struct Opts {
    quick: bool,
    icache_coherent: bool,
    no_cache: bool,
    rndv_thresh: Option<usize>,
    code_pad: usize,
    msgs: Option<usize>,
    iters: Option<usize>,
    sizes: Option<Vec<usize>>,
    workers: usize,
    listen: String,
    transport: two_chains::ifunc::TransportKind,
    max_clients: Option<usize>,
    session_window: Option<usize>,
    queue_depth: Option<usize>,
    batch_max: Option<usize>,
    no_coalesce: bool,
    mesh: bool,
}

fn parse_opts(args: &[String]) -> Result<Opts, String> {
    let mut o = Opts { workers: 2, listen: "127.0.0.1:7100".into(), ..Default::default() };
    let mut i = 0;
    let take = |i: &mut usize| -> Result<&String, String> {
        *i += 1;
        args.get(*i).ok_or_else(|| format!("{} needs a value", args[*i - 1]))
    };
    while i < args.len() {
        match args[i].as_str() {
            "--quick" => o.quick = true,
            "--no-cache" => o.no_cache = true,
            "--icache" => {
                o.icache_coherent = match take(&mut i)?.as_str() {
                    "coherent" => true,
                    "non-coherent" => false,
                    v => return Err(format!("bad --icache value: {v}")),
                }
            }
            "--rndv-thresh" => o.rndv_thresh = Some(parse_num(take(&mut i)?)?),
            "--code-pad" => o.code_pad = parse_num(take(&mut i)?)?,
            "--msgs" => o.msgs = Some(parse_num(take(&mut i)?)?),
            "--iters" => o.iters = Some(parse_num(take(&mut i)?)?),
            "--workers" => o.workers = parse_num(take(&mut i)?)?,
            "--listen" => o.listen = take(&mut i)?.clone(),
            "--max-clients" => o.max_clients = Some(parse_num(take(&mut i)?)?),
            "--session-window" => o.session_window = Some(parse_num(take(&mut i)?)?),
            "--queue-depth" => o.queue_depth = Some(parse_num(take(&mut i)?)?),
            "--batch-max" => o.batch_max = Some(parse_num(take(&mut i)?)?),
            "--no-coalesce" => o.no_coalesce = true,
            "--mesh" => o.mesh = true,
            "--transport" => {
                o.transport = take(&mut i)?.parse().map_err(|e| format!("{e}"))?
            }
            "--sizes" => {
                o.sizes = Some(
                    take(&mut i)?
                        .split(',')
                        .map(parse_num)
                        .collect::<Result<Vec<_>, _>>()?,
                )
            }
            other => return Err(format!("unknown option {other}")),
        }
        i += 1;
    }
    Ok(o)
}

fn parse_num<S: AsRef<str>>(s: S) -> Result<usize, String> {
    s.as_ref().parse::<usize>().map_err(|e| format!("bad number {}: {e}", s.as_ref()))
}

impl Opts {
    fn config(&self) -> BenchConfig {
        let mut c = if self.quick { BenchConfig::quick() } else { BenchConfig::default() };
        c.icache = if self.icache_coherent {
            IcacheConfig::coherent()
        } else {
            IcacheConfig::non_coherent()
        };
        c.cache_enabled = !self.no_cache;
        c.code_pad = self.code_pad;
        if let Some(t) = self.rndv_thresh {
            c.am = AmParams { rndv_threshold: t, ..c.am };
        }
        if let Some(m) = self.msgs {
            c.msgs_per_size = m;
        }
        if let Some(i) = self.iters {
            c.pingpong_iters = i;
        }
        if let Some(s) = &self.sizes {
            c.sizes = s.clone();
        }
        c
    }
}

pub fn run_fig3(cfg: &BenchConfig) -> Result<Vec<report::SeriesPoint>> {
    let mut series = Vec::new();
    for &size in &cfg.sizes {
        let pair = BenchPair::new(cfg.clone())?;
        let ifunc = latency::ifunc_pingpong(&pair, size, cfg.pingpong_iters)?;
        let am = latency::am_pingpong(&pair, size, cfg.pingpong_iters)?;
        series.push(report::SeriesPoint { size, ifunc, am });
        eprint!(".");
    }
    eprintln!();
    Ok(series)
}

pub fn run_fig4(cfg: &BenchConfig) -> Result<Vec<report::SeriesPoint>> {
    let mut series = Vec::new();
    for &size in &cfg.sizes {
        // Bound total bytes so 1MB payloads don't take minutes.
        let msgs = cfg.msgs_per_size.min((256 << 20) / size.max(1)).max(50);
        let pair = BenchPair::new(cfg.clone())?;
        let ifunc = throughput::ifunc_throughput(&pair, size, msgs)?;
        let am = throughput::am_throughput(&pair, size, msgs)?;
        series.push(report::SeriesPoint { size, ifunc, am });
        eprint!(".");
    }
    eprintln!();
    Ok(series)
}

fn run_ablations(base: BenchConfig) -> Result<()> {
    let sizes = if base.sizes.len() > 6 {
        vec![64, 1024, 8192, 65536, 1 << 20]
    } else {
        base.sizes.clone()
    };

    // Abl A: coherent vs non-coherent I-cache (latency).
    for (label, icache) in [
        ("non-coherent I-cache (paper testbed)", IcacheConfig::non_coherent()),
        ("coherent I-cache (paper §5.1 future work)", IcacheConfig::coherent()),
    ] {
        let cfg = BenchConfig { icache, sizes: sizes.clone(), ..base.clone() };
        let series = run_fig3(&cfg)?;
        report::print_series(&format!("Abl A — one-way latency, {label}"), "ns", &series, true);
    }

    // Abl B: auto-registration cache on/off.
    for (label, cache) in [("cache on (paper)", true), ("cache off", false)] {
        let cfg = BenchConfig { cache_enabled: cache, sizes: sizes.clone(), ..base.clone() };
        let series = run_fig3(&cfg)?;
        report::print_series(&format!("Abl B — latency, {label}"), "ns", &series, true);
    }

    // Abl C: AM rendezvous threshold sweep (throughput steps).
    for thresh in [1024usize, 2000, 8192, 16384] {
        let cfg = BenchConfig {
            am: AmParams { rndv_threshold: thresh, ..base.am },
            sizes: sizes.clone(),
            ..base.clone()
        };
        let series = run_fig4(&cfg)?;
        report::print_series(
            &format!("Abl C — throughput, UCX_RNDV_THRESH={thresh}"),
            "msg/s",
            &series,
            false,
        );
    }

    // Abl D: code-section size (GOT patch + verify + flush scale with it).
    for pad in [0usize, 64, 512] {
        let cfg = BenchConfig { code_pad: pad, sizes: sizes.clone(), ..base.clone() };
        let series = run_fig3(&cfg)?;
        report::print_series(
            &format!("Abl D — latency, +{pad} padding instrs in code section"),
            "ns",
            &series,
            true,
        );
    }
    Ok(())
}

fn demo() -> Result<()> {
    use two_chains::prelude::*;
    println!("Two-Chains quickstart: injecting the counter ifunc across the fabric");
    let fabric = Fabric::new(2, WireConfig::off());
    let src = Context::new(fabric.node(0), Default::default())?;
    let dst = Context::new(fabric.node(1), Default::default())?;
    src.library_dir().install(Box::new(CounterIfunc::default()));
    let mut ring = IfuncRing::new(&dst, 1 << 20)?;
    let ws = Worker::new(&src);
    let wd = Worker::new(&dst);
    let ep = ws.connect(&wd)?;

    let h = src.register_ifunc("counter")?;
    let msg = h.msg_create(&SourceArgs::bytes(b"hello two-chains".to_vec()))?;
    let mut args = TargetArgs::none();
    let mut cursor = two_chains::ifunc::SenderCursor::new(ring.size());
    for i in 0..5 {
        ep.ifunc_msg_send_cursor(&msg, &mut cursor, ring.rkey())?;
        ep.flush()?;
        dst.poll_ifunc_blocking(&mut ring, &mut args)?;
        println!("  sent+executed #{i}: target counter = {}", dst.symbols().counter_value());
    }
    println!(
        "done: {} executions, auto-registration cache hits {}",
        dst.symbols().counter_value(),
        dst.ifunc_cache().hits.load(std::sync::atomic::Ordering::Relaxed)
    );
    Ok(())
}

fn info() {
    println!("two-chains reproduction — configuration");
    println!("  wire model (paper testbed): {:?}", WireConfig::connectx6());
    println!("  AM params: {:?}", AmParams::default());
    println!("  icache: {:?}", IcacheConfig::non_coherent());
    let dir = std::path::Path::new("artifacts");
    println!("  artifacts in {dir:?}:");
    if let Ok(entries) = std::fs::read_dir(dir) {
        for e in entries.flatten() {
            println!("    {}", e.file_name().to_string_lossy());
        }
    } else {
        println!("    (none — run `python -m compile.aot` in python/)");
    }
}

fn main() -> Result<()> {
    two_chains::util::logger::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match args.split_first() {
        Some((c, r)) => (c.as_str(), r),
        None => {
            eprint!("{USAGE}");
            std::process::exit(2);
        }
    };
    match cmd {
        "bench" => {
            let (which, rest) = rest
                .split_first()
                .ok_or_else(|| Error::Other("bench needs fig3|fig4|ablations".into()))?;
            let opts = parse_opts(rest).map_err(Error::Other)?;
            let cfg = opts.config();
            match which.as_str() {
                "fig3" => {
                    let series = run_fig3(&cfg)?;
                    report::print_series(
                        "Fig. 3 — one-way latency, ifunc vs UCX AM",
                        "ns",
                        &series,
                        true,
                    );
                    println!("{}", report::series_json("fig3", &series));
                }
                "fig4" => {
                    let series = run_fig4(&cfg)?;
                    report::print_series(
                        "Fig. 4 — message throughput, ifunc vs UCX AM",
                        "msg/s",
                        &series,
                        false,
                    );
                    println!("{}", report::series_json("fig4", &series));
                }
                "ablations" => run_ablations(cfg)?,
                other => return Err(Error::Other(format!("unknown bench {other}"))),
            }
        }
        "demo" => demo()?,
        "serve" => {
            let opts = parse_opts(rest).map_err(Error::Other)?;
            let mut frontend = two_chains::coordinator::FrontendConfig::default();
            if let Some(n) = opts.max_clients {
                frontend.max_clients = n;
            }
            if let Some(n) = opts.session_window {
                frontend.session_window = n;
            }
            if let Some(n) = opts.queue_depth {
                frontend.queue_high_water = n;
            }
            if let Some(n) = opts.batch_max {
                frontend.batch_max = n;
            }
            frontend.coalesce = !opts.no_coalesce;
            serve::serve(
                &serve::ServeOpts {
                    workers: opts.workers,
                    transport: opts.transport,
                    mesh: opts.mesh,
                    frontend,
                },
                &opts.listen,
            )?;
        }
        "info" => info(),
        "help" | "--help" | "-h" => print!("{USAGE}"),
        other => {
            eprintln!("unknown command: {other}\n");
            eprint!("{USAGE}");
            std::process::exit(2);
        }
    }
    Ok(())
}
