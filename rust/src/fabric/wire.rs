//! Wire-cost model for the simulated fabric.
//!
//! The paper's testbed (§4.2) is two servers connected back-to-back with
//! ConnectX-6 200 Gb/s InfiniBand HCAs. We model the link LogGP-style:
//!
//! * `overhead_ns` — fixed per-message cost (NIC processing + propagation;
//!   ~0.8 µs one way for small RDMA writes on CX-6 class hardware),
//! * `ns_per_kib` — serialization cost (200 Gb/s ≈ 25 GB/s ≈ 40 ns/KiB).
//!
//! The delay is *charged in the NIC engine thread*, not on the posting CPU,
//! so posted operations pipeline exactly like hardware doorbells do: the
//! sender can keep filling a ring while earlier messages are "on the wire".
//!
//! Unit tests and most integration tests run with [`WireConfig::off`] —
//! zero modeled delay — because they assert *behaviour*, not timing. The
//! Fig. 3 / Fig. 4 benchmark harness runs with [`WireConfig::connectx6`].

use std::time::{Duration, Instant};

/// How inbound one-sided operations are executed.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum NicMode {
    /// Pick [`NicMode::Engine`] on multi-core hosts, [`NicMode::Inline`]
    /// on single-core ones (where an engine thread only adds context
    /// switches — there is no parallelism to model).
    #[default]
    Auto,
    /// A dedicated NIC engine thread per node: posted operations overlap
    /// with the posting CPU, like doorbelled hardware.
    Engine,
    /// Operations execute synchronously at post time on the caller
    /// thread (wire cost charged inline). Deterministic; preferred for
    /// latency benches and single-core machines.
    Inline,
}

impl NicMode {
    pub fn resolve(self) -> NicMode {
        match self {
            NicMode::Auto => {
                if std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1) > 1 {
                    NicMode::Engine
                } else {
                    NicMode::Inline
                }
            }
            other => other,
        }
    }
}

/// Link cost parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WireConfig {
    /// Fixed one-way per-message overhead, in nanoseconds.
    pub overhead_ns: u64,
    /// Serialization cost per KiB, in nanoseconds.
    pub ns_per_kib: u64,
    /// Master switch; `false` makes `delay()` free regardless of the rest.
    pub enabled: bool,
    /// NIC execution mode (see [`NicMode`]).
    pub nic: NicMode,
}

impl WireConfig {
    /// No modeled wire cost (unit tests, functional runs).
    pub fn off() -> Self {
        WireConfig { overhead_ns: 0, ns_per_kib: 0, enabled: false, nic: NicMode::Auto }
    }

    /// Calibrated to the paper's testbed: ConnectX-6 200 Gb/s IB,
    /// back-to-back (§4.2). 0-byte RDMA-write latency on this class of HCA
    /// is ~0.8 µs one-way; 200 Gb/s line rate is ~40 ns/KiB.
    pub fn connectx6() -> Self {
        WireConfig { overhead_ns: 800, ns_per_kib: 40, enabled: true, nic: NicMode::Auto }
    }

    /// A deliberately slow link (useful in tests that must observe
    /// in-flight states).
    pub fn slow() -> Self {
        WireConfig { overhead_ns: 200_000, ns_per_kib: 1_000, enabled: true, nic: NicMode::Engine }
    }

    /// Modeled one-way cost of a message of `bytes` bytes.
    pub fn cost(&self, bytes: usize) -> Duration {
        if !self.enabled {
            return Duration::ZERO;
        }
        Duration::from_nanos(self.overhead_ns + (bytes as u64 * self.ns_per_kib) / 1024)
    }

    /// Busy-wait for the modeled cost of `bytes`. Spinning (rather than
    /// sleeping) is required at sub-microsecond scales: OS sleep granularity
    /// would destroy the model.
    pub fn charge(&self, bytes: usize) {
        if !self.enabled {
            return;
        }
        spin_for(self.cost(bytes));
    }
}

impl Default for WireConfig {
    fn default() -> Self {
        WireConfig::off()
    }
}

/// Precise busy-wait.
pub fn spin_for(d: Duration) {
    if d.is_zero() {
        return;
    }
    let end = Instant::now() + d;
    while Instant::now() < end {
        std::hint::spin_loop();
    }
}

/// Backoff step for wait loops: brief pipeline spin first, then yield the
/// core. Critical on small machines (the CI box has one core): a raw
/// `spin_loop` wait starves the very thread it is waiting on, turning µs
/// handoffs into scheduler-quantum stalls.
#[inline]
pub fn backoff(iteration: u32) {
    if iteration < 64 {
        std::hint::spin_loop();
    } else {
        std::thread::yield_now();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn off_is_free() {
        let w = WireConfig::off();
        assert_eq!(w.cost(1 << 20), Duration::ZERO);
    }

    #[test]
    fn cost_scales_with_bytes() {
        let w = WireConfig::connectx6();
        let small = w.cost(8);
        let big = w.cost(1 << 20);
        assert!(big > small);
        // 1 MiB at 40 ns/KiB = 40 µs of serialization + overhead.
        assert_eq!(big, Duration::from_nanos(800 + 1024 * 40));
    }

    #[test]
    fn charge_spins_roughly_right() {
        let w =
            WireConfig { overhead_ns: 2_000_000, ns_per_kib: 0, enabled: true, nic: NicMode::Auto };
        let t0 = Instant::now();
        w.charge(0);
        assert!(t0.elapsed() >= Duration::from_millis(2));
    }
}
