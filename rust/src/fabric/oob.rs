//! Out-of-band exchange channel.
//!
//! RDMA rkeys must reach the peer "through an out-of-band channel" (paper
//! §3.5) before any one-sided traffic can flow — in real deployments this
//! is TCP or a job launcher. In the simulated fabric it is a simple
//! blocking key/value rendezvous shared by all nodes.

use std::collections::HashMap;
use std::sync::{Condvar, Mutex};

#[derive(Default)]
pub struct OobExchange {
    map: Mutex<HashMap<String, Vec<u8>>>,
    cv: Condvar,
}

impl OobExchange {
    pub fn new() -> Self {
        Self::default()
    }

    /// Publish a blob under `key` (e.g. a packed rkey).
    pub fn publish(&self, key: &str, value: Vec<u8>) {
        self.map.lock().unwrap().insert(key.to_string(), value);
        self.cv.notify_all();
    }

    /// Non-blocking fetch.
    pub fn try_fetch(&self, key: &str) -> Option<Vec<u8>> {
        self.map.lock().unwrap().get(key).cloned()
    }

    /// Blocking fetch: waits until some peer publishes `key`.
    pub fn fetch(&self, key: &str) -> Vec<u8> {
        let mut guard = self.map.lock().unwrap();
        loop {
            if let Some(v) = guard.get(key) {
                return v.clone();
            }
            guard = self.cv.wait(guard).unwrap();
        }
    }

}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fetch_blocks_until_published() {
        let oob = Arc::new(OobExchange::new());
        let oob2 = oob.clone();
        let t = std::thread::spawn(move || oob2.fetch("rkey/1"));
        std::thread::sleep(std::time::Duration::from_millis(5));
        oob.publish("rkey/1", vec![1, 2, 3]);
        assert_eq!(t.join().unwrap(), vec![1, 2, 3]);
    }

    #[test]
    fn try_fetch_nonblocking() {
        let oob = OobExchange::new();
        assert!(oob.try_fetch("k").is_none());
        oob.publish("k", vec![9]);
        assert_eq!(oob.try_fetch("k"), Some(vec![9]));
    }
}
