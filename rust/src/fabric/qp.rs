//! Queue pairs: the posting side of the one-sided API.
//!
//! A [`Qp`] is a reliable-connected channel from a local node to a peer
//! node. Operations posted on one QP complete in order (the peer NIC
//! engine is a single thread draining an in-order queue). `put_nbi` has
//! UCX semantics: non-blocking post, data captured at post time,
//! completion observable via [`Qp::flush`].

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};

use super::memory::{RKey, RemoteKey};
use super::node::{Completion, NetOp, Node};
use crate::{Error, Result};

pub struct Qp {
    local: Arc<Node>,
    peer: Arc<Node>,
    posted: AtomicU64,
    comp: Arc<Completion>,
}

impl Qp {
    pub(crate) fn new(local: Arc<Node>, peer: Arc<Node>) -> Self {
        Qp { local, peer, posted: AtomicU64::new(0), comp: Arc::new(Completion::default()) }
    }

    pub fn local_node(&self) -> &Arc<Node> {
        &self.local
    }

    pub fn peer_node(&self) -> &Arc<Node> {
        &self.peer
    }

    /// Non-blocking one-sided write of `data` into the peer region named by
    /// `rkey` at byte `offset` — `ucp_put_nbi`. The buffer is captured
    /// immediately (sender may reuse its buffer on return); remote
    /// completion is awaited by [`Qp::flush`].
    pub fn put_nbi(&self, rkey: RKey, offset: usize, data: &[u8]) -> Result<()> {
        self.posted.fetch_add(1, Ordering::Relaxed);
        self.peer.post(NetOp::Put {
            rkey,
            offset,
            data: data.into(),
            comp: self.comp.clone(),
        })
    }

    /// 8-byte signal put (always delivered as a release-store on the peer).
    pub fn put_signal(&self, rkey: RKey, offset: usize, value: u64) -> Result<()> {
        self.put_nbi(rkey, offset, &value.to_le_bytes())
    }

    /// Blocking one-sided read of `len` bytes from the peer region.
    pub fn get_blocking(&self, rkey: RKey, offset: usize, len: usize) -> Result<Box<[u8]>> {
        let (tx, rx) = mpsc::channel();
        self.posted.fetch_add(1, Ordering::Relaxed);
        self.peer.post(NetOp::Get { rkey, offset, len, reply: tx, comp: self.comp.clone() })?;
        rx.recv().map_err(|_| Error::Transport("get reply channel closed".into()))?
    }

    /// Remote fetch-add on an 8-byte word (requires `REMOTE_ATOMIC`).
    pub fn atomic_add(&self, rkey: RKey, offset: usize, value: u64) -> Result<u64> {
        let (tx, rx) = mpsc::channel();
        self.posted.fetch_add(1, Ordering::Relaxed);
        self.peer.post(NetOp::AtomicAdd {
            rkey,
            offset,
            value,
            reply: Some(tx),
            comp: self.comp.clone(),
        })?;
        rx.recv().map_err(|_| Error::Transport("atomic reply channel closed".into()))?
    }

    /// Fire-and-forget fetch-add (completion via flush only).
    pub fn atomic_add_nbi(&self, rkey: RKey, offset: usize, value: u64) -> Result<()> {
        self.posted.fetch_add(1, Ordering::Relaxed);
        let comp = self.comp.clone();
        self.peer.post(NetOp::AtomicAdd { rkey, offset, value, reply: None, comp })
    }

    /// Number of operations posted but not yet completed (or errored).
    pub fn in_flight(&self) -> u64 {
        let done = self.comp.completed.load(Ordering::Acquire)
            + self.comp.errored.load(Ordering::Acquire);
        self.posted.load(Ordering::Relaxed).saturating_sub(done)
    }

    /// Wait until every posted operation has completed — `ucp_ep_flush`.
    /// Returns the first error observed since the previous flush, if any.
    pub fn flush(&self) -> Result<()> {
        let mut i = 0u32;
        while self.in_flight() > 0 {
            super::wire::backoff(i);
            i += 1;
        }
        if self.comp.errored.load(Ordering::Acquire) > 0 {
            let msg = self
                .comp
                .last_error
                .lock()
                .unwrap()
                .clone()
                .unwrap_or_else(|| "unknown transport error".into());
            return Err(Error::RemoteAccess(msg));
        }
        Ok(())
    }

    /// Total errored operations over the QP lifetime.
    pub fn error_count(&self) -> u64 {
        self.comp.errored.load(Ordering::Acquire)
    }

    /// Convenience: put into a [`RemoteKey`]-described region.
    pub fn put_nbi_rk(&self, rk: &RemoteKey, offset: usize, data: &[u8]) -> Result<()> {
        self.put_nbi(rk.rkey, offset, data)
    }
}

#[cfg(test)]
mod tests {
    use super::super::{Fabric, MemPerm, WireConfig};

    #[test]
    fn put_flush_roundtrip() {
        let fabric = Fabric::new(2, WireConfig::off());
        let mr = fabric.node(1).register(4096, MemPerm::RWX);
        let qp = fabric.connect(0, 1);
        qp.put_nbi(mr.rkey(), 128, b"injected").unwrap();
        qp.flush().unwrap();
        assert_eq!(&mr.local_slice()[128..136], b"injected");
    }

    #[test]
    fn invalid_rkey_rejected_at_hardware_level() {
        let fabric = Fabric::new(2, WireConfig::off());
        let _mr = fabric.node(1).register(4096, MemPerm::RWX);
        let qp = fabric.connect(0, 1);
        qp.put_nbi(0xBAD0_BAD0, 0, b"x").unwrap();
        let err = qp.flush().unwrap_err();
        assert!(err.to_string().contains("invalid rkey"), "{err}");
        assert_eq!(fabric.node(1).stats.rejected.load(std::sync::atomic::Ordering::Relaxed), 1);
    }

    #[test]
    fn write_without_permission_rejected() {
        let fabric = Fabric::new(2, WireConfig::off());
        let mr = fabric.node(1).register(4096, MemPerm::REMOTE_READ);
        let qp = fabric.connect(0, 1);
        qp.put_nbi(mr.rkey(), 0, b"x").unwrap();
        assert!(qp.flush().is_err());
        // The byte was never written.
        assert_eq!(mr.local_slice()[0], 0);
    }

    #[test]
    fn out_of_bounds_put_rejected() {
        let fabric = Fabric::new(2, WireConfig::off());
        let mr = fabric.node(1).register(16, MemPerm::RWX);
        let qp = fabric.connect(0, 1);
        qp.put_nbi(mr.rkey(), 8, b"0123456789").unwrap();
        assert!(qp.flush().is_err());
    }

    #[test]
    fn get_roundtrip() {
        let fabric = Fabric::new(2, WireConfig::off());
        let mr = fabric.node(1).register(64, MemPerm::RWX);
        mr.local_slice_mut()[..4].copy_from_slice(b"data");
        let qp = fabric.connect(0, 1);
        let out = qp.get_blocking(mr.rkey(), 0, 4).unwrap();
        assert_eq!(&*out, b"data");
    }

    #[test]
    fn atomic_add_returns_old_value() {
        let fabric = Fabric::new(2, WireConfig::off());
        let mr = fabric.node(1).register(64, MemPerm::RWX);
        let qp = fabric.connect(0, 1);
        assert_eq!(qp.atomic_add(mr.rkey(), 8, 5).unwrap(), 0);
        assert_eq!(qp.atomic_add(mr.rkey(), 8, 7).unwrap(), 5);
        assert_eq!(mr.load_u64_acquire(8).unwrap(), 12);
    }

    #[test]
    fn puts_complete_in_order() {
        let fabric = Fabric::new(2, WireConfig::off());
        let mr = fabric.node(1).register(1 << 16, MemPerm::RWX);
        let qp = fabric.connect(0, 1);
        for i in 0..100u64 {
            qp.put_nbi(mr.rkey(), (i as usize) * 8, &i.to_le_bytes()).unwrap();
        }
        // Trailer-style signal after the batch: when it lands, all prior
        // puts on this QP have landed (in-order RC semantics).
        qp.put_signal(mr.rkey(), 100 * 8, u64::MAX).unwrap();
        mr.wait_mem(100 * 8, 0).unwrap();
        for i in 0..100u64 {
            assert_eq!(mr.load_u64_acquire((i as usize) * 8).unwrap(), i);
        }
        qp.flush().unwrap();
    }

    #[test]
    fn deregistered_mr_rejects() {
        let fabric = Fabric::new(2, WireConfig::off());
        let mr = fabric.node(1).register(64, MemPerm::RWX);
        let rkey = mr.rkey();
        let qp = fabric.connect(0, 1);
        qp.put_nbi(rkey, 0, b"ok").unwrap();
        qp.flush().unwrap();
        fabric.node(1).deregister(rkey);
        qp.put_nbi(rkey, 0, b"no").unwrap();
        assert!(qp.flush().is_err());
    }
}
