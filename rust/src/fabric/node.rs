//! A fabric node: one "server + HCA" of the paper's testbed.
//!
//! Each node owns a registration table (rkey → [`MemoryRegion`]) and a NIC
//! engine thread that executes inbound one-sided operations in order —
//! modeling an RC queue pair's in-order delivery. The engine charges the
//! wire-cost model *before* touching memory, so posted operations pipeline
//! like real doorbelled work requests.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex, RwLock};

use super::memory::{MemPerm, MemoryRegion, RKey};
use super::wire::{NicMode, WireConfig};
use crate::{Error, Result};

/// Completion tracking shared between a QP (poster) and the peer NIC engine
/// (completer). `flush()` waits for `completed + errored == posted`.
#[derive(Default)]
pub struct Completion {
    pub(crate) completed: AtomicU64,
    pub(crate) errored: AtomicU64,
    pub(crate) last_error: Mutex<Option<String>>,
}

impl Completion {
    fn ok(&self) {
        self.completed.fetch_add(1, Ordering::Release);
    }

    fn err(&self, e: &Error) {
        *self.last_error.lock().unwrap() = Some(e.to_string());
        self.errored.fetch_add(1, Ordering::Release);
    }
}

/// One-sided operations the NIC engine executes. Data is captured at post
/// time (the bcopy of a doorbelled send queue entry).
pub(crate) enum NetOp {
    Put {
        rkey: RKey,
        offset: usize,
        data: Box<[u8]>,
        comp: Arc<Completion>,
    },
    Get {
        rkey: RKey,
        offset: usize,
        len: usize,
        reply: mpsc::Sender<Result<Box<[u8]>>>,
        comp: Arc<Completion>,
    },
    AtomicAdd {
        rkey: RKey,
        offset: usize,
        value: u64,
        reply: Option<mpsc::Sender<Result<u64>>>,
        comp: Arc<Completion>,
    },
}

/// Counters exposed for telemetry and asserted on by the security tests.
#[derive(Default)]
pub struct NodeStats {
    pub puts: AtomicU64,
    pub gets: AtomicU64,
    pub atomics: AtomicU64,
    pub bytes_in: AtomicU64,
    /// Operations rejected by rkey / permission / bounds checks — the
    /// "rejected at the hardware level" path of §3.5.
    pub rejected: AtomicU64,
}

pub struct Node {
    id: usize,
    wire: WireConfig,
    nic_mode: NicMode,
    mrs: RwLock<HashMap<RKey, Arc<MemoryRegion>>>,
    tx: Mutex<Option<mpsc::Sender<NetOp>>>,
    pub stats: Arc<NodeStats>,
    engine: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl Node {
    pub(crate) fn new(id: usize, wire: WireConfig) -> Arc<Self> {
        let nic_mode = wire.nic.resolve();
        let stats = Arc::new(NodeStats::default());
        if nic_mode == NicMode::Inline {
            // No engine thread: ops execute at post time on the caller.
            return Arc::new(Node {
                id,
                wire,
                nic_mode,
                mrs: RwLock::new(HashMap::new()),
                tx: Mutex::new(None),
                stats,
                engine: Mutex::new(None),
            });
        }
        let (tx, rx) = mpsc::channel::<NetOp>();
        let node = Arc::new(Node {
            id,
            wire,
            nic_mode,
            mrs: RwLock::new(HashMap::new()),
            tx: Mutex::new(Some(tx)),
            stats: stats.clone(),
            engine: Mutex::new(None),
        });
        let weak = Arc::downgrade(&node);
        let handle = std::thread::Builder::new()
            .name(format!("nic-engine-{id}"))
            .spawn(move || {
                // Spin-then-block receive: a polling NIC engine. Blocking
                // recv costs ~5-10 µs of futex wakeup per op — far above
                // the sub-µs doorbell latency being modeled — so spin
                // briefly first (§Perf: cut put+flush from 8.8 µs to
                // sub-µs) and fall back to blocking when idle.
                'outer: loop {
                    let mut spins = 0u32;
                    let op = loop {
                        match rx.try_recv() {
                            Ok(op) => break op,
                            Err(std::sync::mpsc::TryRecvError::Disconnected) => break 'outer,
                            Err(std::sync::mpsc::TryRecvError::Empty) => {
                                spins += 1;
                                if spins > 2_000 {
                                    // Idle: block until work arrives.
                                    match rx.recv() {
                                        Ok(op) => break op,
                                        Err(_) => break 'outer,
                                    }
                                }
                                crate::fabric::wire::backoff(spins);
                            }
                        }
                    };
                    let Some(node) = weak.upgrade() else { break };
                    node.execute(op);
                }
            })
            .expect("spawn nic engine");
        *node.engine.lock().unwrap() = Some(handle);
        node
    }

    pub fn id(&self) -> usize {
        self.id
    }

    pub fn wire(&self) -> WireConfig {
        self.wire
    }

    /// Register a memory region for remote access; returns the region. The
    /// rkey must travel to peers out-of-band (paper §3.5).
    pub fn register(&self, len: usize, perm: MemPerm) -> Arc<MemoryRegion> {
        let mr = Arc::new(MemoryRegion::new(len, perm));
        self.mrs.write().unwrap().insert(mr.rkey(), mr.clone());
        mr
    }

    /// Deregister: subsequent remote accesses with this rkey are rejected.
    pub fn deregister(&self, rkey: RKey) {
        self.mrs.write().unwrap().remove(&rkey);
    }

    /// Look up + authorize an access. This is the simulated HCA check of
    /// §3.5: unknown rkey, insufficient permission, or out-of-bounds all
    /// reject *before any byte is touched*.
    fn authorize(
        &self,
        rkey: RKey,
        offset: usize,
        len: usize,
        need: MemPerm,
    ) -> Result<Arc<MemoryRegion>> {
        let mr = self
            .mrs
            .read()
            .unwrap()
            .get(&rkey)
            .cloned()
            .ok_or_else(|| Error::RemoteAccess(format!("invalid rkey {rkey:#010x}")))?;
        if !mr.perm().allows(need) {
            return Err(Error::RemoteAccess(format!(
                "rkey {rkey:#010x} lacks permission {need:?}"
            )));
        }
        mr.check_bounds(offset, len)?;
        Ok(mr)
    }

    /// Entry point for peers: enqueue an inbound op on this node's engine
    /// (or, in inline mode, execute it immediately on the calling thread).
    pub(crate) fn post(&self, op: NetOp) -> Result<()> {
        if self.nic_mode == NicMode::Inline {
            self.execute(op);
            return Ok(());
        }
        self.tx
            .lock()
            .unwrap()
            .as_ref()
            .expect("engine mode has a sender")
            .send(op)
            .map_err(|_| Error::Transport("nic engine stopped".into()))
    }

    /// Execute one inbound op (runs on the engine thread).
    fn execute(&self, op: NetOp) {
        match op {
            NetOp::Put { rkey, offset, data, comp } => {
                self.wire.charge(data.len());
                self.stats.puts.fetch_add(1, Ordering::Relaxed);
                self.stats.bytes_in.fetch_add(data.len() as u64, Ordering::Relaxed);
                match self.authorize(rkey, offset, data.len(), MemPerm::REMOTE_WRITE) {
                    Ok(mr) => {
                        self.deliver_put(&mr, offset, &data);
                        comp.ok();
                    }
                    Err(e) => {
                        self.stats.rejected.fetch_add(1, Ordering::Relaxed);
                        comp.err(&e);
                    }
                }
            }
            NetOp::Get { rkey, offset, len, reply, comp } => {
                // Request overhead now; response serialization below.
                self.wire.charge(0);
                self.stats.gets.fetch_add(1, Ordering::Relaxed);
                match self.authorize(rkey, offset, len, MemPerm::REMOTE_READ) {
                    Ok(mr) => {
                        let mut out = vec![0u8; len].into_boxed_slice();
                        let r = mr.read_bytes(offset, &mut out).map(|_| out);
                        self.wire.charge(len);
                        let _ = reply.send(r);
                        comp.ok();
                    }
                    Err(e) => {
                        self.stats.rejected.fetch_add(1, Ordering::Relaxed);
                        let _ = reply.send(Err(Error::RemoteAccess(e.to_string())));
                        comp.err(&e);
                    }
                }
            }
            NetOp::AtomicAdd { rkey, offset, value, reply, comp } => {
                self.wire.charge(8);
                self.stats.atomics.fetch_add(1, Ordering::Relaxed);
                match self
                    .authorize(rkey, offset, 8, MemPerm::REMOTE_ATOMIC)
                    .and_then(|mr| mr.fetch_add_u64(offset, value))
                {
                    Ok(old) => {
                        if let Some(reply) = reply {
                            let _ = reply.send(Ok(old));
                        }
                        comp.ok();
                    }
                    Err(e) => {
                        self.stats.rejected.fetch_add(1, Ordering::Relaxed);
                        if let Some(reply) = reply {
                            let _ = reply.send(Err(Error::RemoteAccess(e.to_string())));
                        }
                        comp.err(&e);
                    }
                }
            }
        }
    }

    /// Write a put's bytes with the data-before-signal ordering contract:
    /// if the write ends on an 8-byte boundary, the final word is stored
    /// with release ordering so a poller acquiring it observes every
    /// preceding byte — the paper's trailer-signal protocol (Fig. 2).
    /// Shared with the intra-node shm transport, which performs the same
    /// delivery without the NIC engine (`MemoryRegion::put_local`).
    fn deliver_put(&self, mr: &MemoryRegion, offset: usize, data: &[u8]) {
        mr.put_local(offset, data).expect("bounds pre-checked");
    }
}

impl Drop for Node {
    fn drop(&mut self) {
        if let Some(h) = self.engine.lock().unwrap().take() {
            // Engine exits when the weak upgrade fails or channel closes;
            // detach rather than join to avoid self-deadlock in drop.
            drop(h);
        }
    }
}
