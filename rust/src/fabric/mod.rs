//! Simulated RDMA fabric — the substrate substituting for the paper's
//! ConnectX-6 InfiniBand testbed (DESIGN.md §2).
//!
//! The fabric provides exactly the primitives the ifunc API and the UCX AM
//! baseline are built from:
//!
//! * registered **memory regions** with 32-bit rkeys and permission bits
//!   ([`memory`]),
//! * reliable-connected **queue pairs** with in-order one-sided
//!   PUT / GET / fetch-add and flush-able completions ([`qp`]),
//! * a calibrated **wire-cost model** ([`wire`]),
//! * a blocking **out-of-band channel** for rkey exchange ([`oob`]).
//!
//! A [`Fabric`] owns `n` nodes, each a "server + HCA" with its own NIC
//! engine thread; `connect(a, b)` wires a QP between two of them.

pub mod memory;
pub mod node;
pub mod oob;
pub mod qp;
pub mod wire;

pub use memory::{MemPerm, MemoryRegion, RKey, RemoteKey};
pub use node::{Node, NodeStats};
pub use oob::OobExchange;
pub use qp::Qp;
pub use wire::{backoff, spin_for, NicMode, WireConfig};

use std::sync::Arc;

/// The simulated cluster interconnect.
pub struct Fabric {
    nodes: Vec<Arc<Node>>,
    oob: Arc<OobExchange>,
}

impl Fabric {
    /// Build a fabric of `n` nodes sharing one wire-cost model. The paper's
    /// testbed is `Fabric::new(2, WireConfig::connectx6())` — two servers
    /// back-to-back, no switch.
    pub fn new(n: usize, wire: WireConfig) -> Arc<Self> {
        let nodes = (0..n).map(|i| Node::new(i, wire)).collect();
        Arc::new(Fabric { nodes, oob: Arc::new(OobExchange::new()) })
    }

    pub fn node(&self, i: usize) -> Arc<Node> {
        self.nodes[i].clone()
    }

    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// The shared out-of-band channel (rkey exchange, wireup).
    pub fn oob(&self) -> Arc<OobExchange> {
        self.oob.clone()
    }

    /// Create a queue pair from node `from` to node `to`.
    pub fn connect(&self, from: usize, to: usize) -> Qp {
        Qp::new(self.nodes[from].clone(), self.nodes[to].clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fabric_builds_n_nodes() {
        let f = Fabric::new(4, WireConfig::off());
        assert_eq!(f.num_nodes(), 4);
        for i in 0..4 {
            assert_eq!(f.node(i).id(), i);
        }
    }

    #[test]
    fn loopback_qp_works() {
        let f = Fabric::new(1, WireConfig::off());
        let mr = f.node(0).register(64, MemPerm::RWX);
        let qp = f.connect(0, 0);
        qp.put_nbi(mr.rkey(), 0, b"loop").unwrap();
        qp.flush().unwrap();
        assert_eq!(&mr.local_slice()[..4], b"loop");
    }

    #[test]
    fn wire_model_delays_delivery() {
        use std::time::Instant;
        // Engine mode explicitly: the assertion is about posting being
        // non-blocking, which only the engine-thread path provides.
        let f = Fabric::new(
            2,
            WireConfig {
                overhead_ns: 3_000_000,
                ns_per_kib: 0,
                enabled: true,
                nic: NicMode::Engine,
            },
        );
        let mr = f.node(1).register(64, MemPerm::RWX);
        let qp = f.connect(0, 1);
        let t0 = Instant::now();
        qp.put_nbi(mr.rkey(), 0, b"x").unwrap();
        let posted = t0.elapsed();
        qp.flush().unwrap();
        let flushed = t0.elapsed();
        assert!(posted < std::time::Duration::from_millis(2), "post is non-blocking: {posted:?}");
        assert!(
            flushed >= std::time::Duration::from_millis(3),
            "flush waits for wire: {flushed:?}"
        );
    }
}
