//! Registered memory regions — the simulated analog of `ibv_reg_mr`.
//!
//! A [`MemoryRegion`] is a pinned byte buffer that remote peers may read or
//! write through the fabric, authorized by a 32-bit remote key (RKEY) plus
//! permission bits, exactly as the IBTA security model the paper relies on
//! (§3.5): the RKEY is generated at registration time from the region
//! identity and the requested permissions, and every remote operation is
//! checked against it "at the hardware level" (here: in the NIC engine)
//! before any byte is touched.
//!
//! ## Concurrency model
//!
//! RDMA semantics are preserved faithfully: the fabric writes into the
//! region concurrently with local polling, and *no ordering is guaranteed
//! except through signal words*. Bulk bytes are written with plain copies;
//! 8-byte aligned signal words are accessed with real atomics
//! (release-store on delivery, acquire-load / `wait_mem` on the poller), so
//! the data-before-signal protocol of the paper's Fig. 2 is exactly the
//! synchronization that makes this sound.

use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};

use crate::{Error, Result};

/// Tiny local stand-in for the `bitflags` crate (avoids a dependency).
macro_rules! bitflags_lite {
    (
        $(#[$meta:meta])*
        pub struct $name:ident : $ty:ty {
            $(const $flag:ident = $val:expr;)*
        }
    ) => {
        $(#[$meta])*
        #[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
        pub struct $name(pub $ty);
        impl $name {
            $(pub const $flag: $name = $name($val);)*
            /// All permissions (read | write | atomic).
            pub const RWX: $name = $name($($val |)* 0);
            /// No remote permissions.
            pub const NONE: $name = $name(0);
            /// True if `self` grants every bit in `other`.
            pub fn allows(self, other: $name) -> bool {
                self.0 & other.0 == other.0
            }
        }
        impl std::ops::BitOr for $name {
            type Output = $name;
            fn bitor(self, rhs: $name) -> $name { $name(self.0 | rhs.0) }
        }
    };
}

bitflags_lite! {
    /// Remote access permissions, mirroring `IBV_ACCESS_REMOTE_*`.
    pub struct MemPerm: u8 {
        const REMOTE_READ = 0b001;
        const REMOTE_WRITE = 0b010;
        const REMOTE_ATOMIC = 0b100;
    }
}

impl MemPerm {
    /// Read + write only — the right grant for plain counter and reply
    /// words (credit, consumed-frame, reply rings): peers PUT into them
    /// and the owner loads them, but nothing ever needs the atomic bit.
    /// Full [`MemPerm::RWX`] stays reserved for the code ring, which in
    /// the paper's model additionally holds executable frames.
    pub const RW: MemPerm = MemPerm(MemPerm::REMOTE_READ.0 | MemPerm::REMOTE_WRITE.0);
}

/// A remote key: 32 bits, as defined by the IBTA standard (paper §3.5).
pub type RKey = u32;

/// A registered, remotely-accessible memory region.
///
/// Local access goes through [`MemoryRegion::local_slice`] /
/// [`MemoryRegion::local_slice_mut`]; remote access is performed by the NIC
/// engine after rkey/permission/bounds checks.
pub struct MemoryRegion {
    /// Backing storage. Allocated as `u64`s so every 8-aligned offset can be
    /// viewed as an `AtomicU64` signal word.
    buf: Box<[u64]>,
    len: usize,
    rkey: RKey,
    perm: MemPerm,
}

// SAFETY: all cross-thread access is either through atomic signal words or
// through raw byte copies that the data-before-signal protocol orders (the
// same contract real RDMA hardware gives to verbs applications).
unsafe impl Send for MemoryRegion {}
unsafe impl Sync for MemoryRegion {}

/// RKEYs are derived from a process-wide counter mixed with a multiplicative
/// hash so that stale/guessed keys are unlikely to collide with live ones —
/// mirroring how HCAs derive keys from the MR index plus a variant bits.
static RKEY_SALT: AtomicU32 = AtomicU32::new(0x9E37_79B9);

impl MemoryRegion {
    /// Register a fresh zeroed region of `len` bytes with permissions `perm`.
    pub fn new(len: usize, perm: MemPerm) -> Self {
        let words = len.div_ceil(8);
        let salt = RKEY_SALT.fetch_add(0x61C8_8647, Ordering::Relaxed);
        // Fold the permission bits into the key like an HCA folds access
        // flags into the MR context the key names.
        let rkey = salt.rotate_left(7) ^ ((perm.0 as u32) << 13) ^ 0x5851_F42D;
        MemoryRegion { buf: vec![0u64; words].into_boxed_slice(), len, rkey, perm }
    }

    /// The 32-bit remote key for this region.
    pub fn rkey(&self) -> RKey {
        self.rkey
    }

    /// Registered length in bytes.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Permissions granted at registration.
    pub fn perm(&self) -> MemPerm {
        self.perm
    }

    fn base_ptr(&self) -> *mut u8 {
        self.buf.as_ptr() as *mut u8
    }

    /// Validate that `[offset, offset+len)` lies inside the region.
    pub fn check_bounds(&self, offset: usize, len: usize) -> Result<()> {
        if offset.checked_add(len).is_none_or(|end| end > self.len) {
            return Err(Error::RemoteAccess(format!(
                "access [{offset}, {offset}+{len}) out of bounds for MR of {} bytes",
                self.len
            )));
        }
        Ok(())
    }

    /// Local (owner-side) view of the region.
    ///
    /// # Safety contract (documented, not enforced)
    /// The caller must only read bytes whose delivery has been observed
    /// through an acquire on a signal word — identical to the contract a
    /// verbs application has with its HCA.
    #[allow(clippy::mut_from_ref)]
    pub fn local_slice_mut(&self) -> &mut [u8] {
        // SAFETY: see module docs; synchronization is via signal words.
        unsafe { std::slice::from_raw_parts_mut(self.base_ptr(), self.len) }
    }

    /// Immutable local view.
    pub fn local_slice(&self) -> &[u8] {
        unsafe { std::slice::from_raw_parts(self.base_ptr(), self.len) }
    }

    /// Remote write path used by the NIC engine (bounds already rkey-checked
    /// by the caller). Plain byte copy — *not* ordered; pair with
    /// [`MemoryRegion::store_u64_release`] for the trailing signal.
    pub(crate) fn write_bytes(&self, offset: usize, data: &[u8]) -> Result<()> {
        self.check_bounds(offset, data.len())?;
        unsafe {
            std::ptr::copy_nonoverlapping(data.as_ptr(), self.base_ptr().add(offset), data.len());
        }
        Ok(())
    }

    /// Local-delivery path for colocated senders (the intra-node shm
    /// transport): write `data` under the same data-before-signal
    /// contract the NIC engine gives remote puts — when the write ends on
    /// an 8-byte boundary its final word is release-stored so a poller
    /// acquiring that word observes every preceding byte. No rkey or
    /// permission check runs: the writer shares the owner's address
    /// space, which is exactly what distinguishes this path from
    /// [`crate::fabric::Qp::put_nbi`].
    pub fn put_local(&self, offset: usize, data: &[u8]) -> Result<()> {
        self.check_bounds(offset, data.len())?;
        let len = data.len();
        if len >= 8 && (offset + len) % 8 == 0 {
            let (body, tail) = data.split_at(len - 8);
            if !body.is_empty() {
                self.write_bytes(offset, body)?;
            }
            let word = u64::from_le_bytes(tail.try_into().unwrap());
            self.store_u64_release(offset + len - 8, word)
        } else {
            self.write_bytes(offset, data)?;
            // Conservative: make the bytes visible to subsequent acquires.
            std::sync::atomic::fence(Ordering::Release);
            Ok(())
        }
    }

    /// Remote read path used by the NIC engine for GET.
    pub(crate) fn read_bytes(&self, offset: usize, out: &mut [u8]) -> Result<()> {
        self.check_bounds(offset, out.len())?;
        unsafe {
            std::ptr::copy_nonoverlapping(self.base_ptr().add(offset), out.as_mut_ptr(), out.len());
        }
        Ok(())
    }

    fn atomic_u64(&self, offset: usize) -> Result<&AtomicU64> {
        if offset % 8 != 0 {
            return Err(Error::RemoteAccess(format!("unaligned signal offset {offset}")));
        }
        self.check_bounds(offset, 8)?;
        // SAFETY: offset is 8-aligned and in-bounds; backing store is u64s.
        Ok(unsafe { AtomicU64::from_ptr(self.base_ptr().add(offset) as *mut u64) })
    }

    /// Release-store a signal word. The NIC engine uses this for the final
    /// 8 bytes of a frame (the paper's trailer signal) and for standalone
    /// 8-byte puts, making every preceding `write_bytes` visible to a poller
    /// that acquires this word.
    pub fn store_u64_release(&self, offset: usize, v: u64) -> Result<()> {
        self.atomic_u64(offset)?.store(v, Ordering::Release);
        Ok(())
    }

    /// Acquire-load a signal word (poller side).
    pub fn load_u64_acquire(&self, offset: usize) -> Result<u64> {
        Ok(self.atomic_u64(offset)?.load(Ordering::Acquire))
    }

    /// Fetch-add used by remote atomic operations.
    pub(crate) fn fetch_add_u64(&self, offset: usize, v: u64) -> Result<u64> {
        Ok(self.atomic_u64(offset)?.fetch_add(v, Ordering::AcqRel))
    }

    /// `ucs_arch_wait_mem` analog (paper §3.2 / §3.4 `WFE`): block until the
    /// signal word at `offset` differs from `current`, using a spin with
    /// `hint::spin_loop` — the portable stand-in for Arm's `WFE`, which
    /// "reduce[s] resource usage ... without incurring a heavy performance
    /// penalty".
    pub fn wait_mem(&self, offset: usize, current: u64) -> Result<u64> {
        let cell = self.atomic_u64(offset)?;
        let mut i = 0u32;
        loop {
            let v = cell.load(Ordering::Acquire);
            if v != current {
                return Ok(v);
            }
            super::wire::backoff(i);
            i += 1;
        }
    }
}

/// An unpacked remote key as shared out-of-band: enough for a peer to name
/// a region (`rkey`) and an address inside it. The paper exchanges these
/// via an out-of-band channel before any ifunc traffic flows.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RemoteKey {
    /// Target node id (stands in for the LID/GID routing information).
    pub node: usize,
    /// The 32-bit rkey.
    pub rkey: RKey,
    /// Length of the registered region (used only for client-side sanity).
    pub len: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rkeys_are_unique_per_registration() {
        let a = MemoryRegion::new(64, MemPerm::RWX);
        let b = MemoryRegion::new(64, MemPerm::RWX);
        assert_ne!(a.rkey(), b.rkey());
    }

    #[test]
    fn bounds_checking_rejects_overflow() {
        let mr = MemoryRegion::new(100, MemPerm::RWX);
        assert!(mr.check_bounds(0, 100).is_ok());
        assert!(mr.check_bounds(1, 100).is_err());
        assert!(mr.check_bounds(usize::MAX, 2).is_err());
    }

    #[test]
    fn signal_word_roundtrip() {
        let mr = MemoryRegion::new(64, MemPerm::RWX);
        mr.store_u64_release(8, 0xDEAD_BEEF).unwrap();
        assert_eq!(mr.load_u64_acquire(8).unwrap(), 0xDEAD_BEEF);
        assert!(mr.store_u64_release(4, 1).is_err(), "unaligned signal must fail");
    }

    #[test]
    fn write_then_signal_is_visible() {
        let mr = MemoryRegion::new(4096, MemPerm::RWX);
        mr.write_bytes(16, b"hello ifunc").unwrap();
        mr.store_u64_release(0, 1).unwrap();
        assert_eq!(&mr.local_slice()[16..27], b"hello ifunc");
    }

    #[test]
    fn wait_mem_returns_changed_value() {
        use std::sync::Arc;
        let mr = Arc::new(MemoryRegion::new(64, MemPerm::RWX));
        let mr2 = mr.clone();
        let t = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(5));
            mr2.store_u64_release(0, 42).unwrap();
        });
        assert_eq!(mr.wait_mem(0, 0).unwrap(), 42);
        t.join().unwrap();
    }

    #[test]
    fn put_local_delivers_with_tail_signal() {
        let mr = MemoryRegion::new(64, MemPerm::RW);
        // 16 bytes ending on an 8-byte boundary: body + release-stored tail.
        let mut frame = [0u8; 16];
        frame[..8].copy_from_slice(b"datadata");
        frame[8..].copy_from_slice(&0xFEED_F00Du64.to_le_bytes());
        mr.put_local(0, &frame).unwrap();
        assert_eq!(mr.load_u64_acquire(8).unwrap(), 0xFEED_F00D);
        assert_eq!(&mr.local_slice()[..8], b"datadata");
        // Unaligned-end writes still land (fence-ordered).
        mr.put_local(17, b"odd").unwrap();
        assert_eq!(&mr.local_slice()[17..20], b"odd");
        // Bounds are still enforced — shm skips rkey checks, not safety.
        assert!(mr.put_local(60, &[0u8; 8]).is_err());
    }

    #[test]
    fn perm_allows() {
        assert!(MemPerm::RWX.allows(MemPerm::REMOTE_WRITE));
        assert!(!MemPerm::REMOTE_READ.allows(MemPerm::REMOTE_WRITE));
        let rw = MemPerm::REMOTE_READ | MemPerm::REMOTE_WRITE;
        assert!(rw.allows(MemPerm::REMOTE_READ));
        assert!(!rw.allows(MemPerm::REMOTE_ATOMIC));
        assert_eq!(MemPerm::RW, rw);
        assert!(MemPerm::RWX.allows(MemPerm::RW));
        assert!(!MemPerm::RW.allows(MemPerm::RWX));
    }
}
