//! TCVM — the portable injected-code substrate.
//!
//! Stands in for the paper's native `.text` + GOT-rewriting toolchain
//! (DESIGN.md §2, row 2). Four pieces:
//!
//! * [`isa`] — fixed-width register ISA the code sections are encoded in,
//! * [`asm`] — source-side assembler (the "toolchain"),
//! * [`verify`] — target-side static verifier (§3.5 security),
//! * [`got`] + [`interp`] — target-side linking (symbol resolution into a
//!   GOT table) and execution.

pub mod asm;
pub mod disasm;
pub mod got;
pub mod interp;
pub mod isa;
pub mod verify;

pub use asm::{Assembler, Label};
pub use disasm::{disasm, disasm_instr};
pub use got::{GotTable, HostCtx, HostFn, SymbolTable};
pub use interp::{run, VmConfig, VmOutcome, DEFAULT_FUEL};
pub use isa::{decode_all, Instr, Op, INSTR_BYTES, MAX_INSTRS, NUM_REGS};
pub use verify::verify;
