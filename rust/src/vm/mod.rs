//! TCVM — the portable injected-code substrate.
//!
//! Stands in for the paper's native `.text` + GOT-rewriting toolchain
//! (DESIGN.md §2, row 2). Six pieces, forming the target-side pipeline
//! **verify → analyze → compile**:
//!
//! * [`isa`] — fixed-width register ISA the code sections are encoded in,
//! * [`asm`] — source-side assembler (the "toolchain"),
//! * [`verify`] — target-side static verifier (§3.5 security):
//!   structural soundness — fields decode, targets in range,
//! * [`analysis`] — abstract interpretation over the verified program
//!   (interval value ranges per register per pc). Produces a
//!   [`ProgramFacts`]: which memory ops are provably in bounds (so
//!   [`compile_analyzed`] can drop their dynamic checks behind a single
//!   entry guard), a worst-case fuel bound for loop-free programs (so
//!   the engine can skip per-block fuel checks), a fuel *floor* and
//!   may-loop verdict for dispatcher admission, the reachable host-call
//!   surface for [`CapabilityPolicy`] gating, and lints
//!   (divide-by-constant-zero, unreachable code) with disassembly,
//! * [`compile`] — target-side lowering of the verified program into a
//!   threaded [`CompiledProgram`] (pre-resolved handlers, fused
//!   superinstructions, block-level fuel, analysis-elided fast paths).
//!   This is what the §3.4 hash-table cache stores, so repeat
//!   injections skip decode, verify, analysis *and* compile,
//! * [`got`] + [`interp`] — target-side linking (symbol resolution into a
//!   GOT table) and execution. [`interp`] keeps the original match-loop
//!   as [`run_reference`], the semantic ground truth the compiled engine
//!   is differentially tested against (`rust/tests/prop.rs`) — including
//!   every analysis-elided fast path and its guard fallback.

pub mod analysis;
pub mod asm;
pub mod compile;
pub mod disasm;
pub mod got;
pub mod interp;
pub mod isa;
pub mod verify;

pub use analysis::{
    analyze, AdmissionFacts, CapabilityPolicy, Interval, Lint, LintKind, ProgramFacts,
};
pub use asm::{Assembler, Label};
pub use compile::{compile, compile_analyzed, compile_unfused, CompiledProgram};
pub use disasm::{disasm, disasm_instr, parse_instr};
pub use got::{GotTable, HostCtx, HostFn, SymbolTable};
pub use interp::{VmConfig, VmOutcome, DEFAULT_FUEL};
pub use isa::{decode_all, Instr, Op, INSTR_BYTES, MAX_INSTRS, NUM_REGS};
pub use verify::verify;

#[doc(hidden)]
pub use interp::run_reference;
