//! TCVM — the portable injected-code substrate.
//!
//! Stands in for the paper's native `.text` + GOT-rewriting toolchain
//! (DESIGN.md §2, row 2). Five pieces:
//!
//! * [`isa`] — fixed-width register ISA the code sections are encoded in,
//! * [`asm`] — source-side assembler (the "toolchain"),
//! * [`verify`] — target-side static verifier (§3.5 security),
//! * [`compile`] — target-side lowering of the verified program into a
//!   threaded [`CompiledProgram`] (pre-resolved handlers, fused
//!   superinstructions, block-level fuel). This is what the §3.4
//!   hash-table cache stores, so repeat injections skip decode, verify
//!   *and* compile,
//! * [`got`] + [`interp`] — target-side linking (symbol resolution into a
//!   GOT table) and execution. [`interp`] keeps the original match-loop
//!   as [`run_reference`], the semantic ground truth the compiled engine
//!   is differentially tested against (`rust/tests/prop.rs`).

pub mod asm;
pub mod compile;
pub mod disasm;
pub mod got;
pub mod interp;
pub mod isa;
pub mod verify;

pub use asm::{Assembler, Label};
pub use compile::{compile, compile_unfused, CompiledProgram};
pub use disasm::{disasm, disasm_instr};
pub use got::{GotTable, HostCtx, HostFn, SymbolTable};
pub use interp::{VmConfig, VmOutcome, DEFAULT_FUEL};
pub use isa::{decode_all, Instr, Op, INSTR_BYTES, MAX_INSTRS, NUM_REGS};
pub use verify::verify;

#[doc(hidden)]
pub use interp::run_reference;
