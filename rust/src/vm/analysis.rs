//! Abstract interpretation over verified TCVM programs — the static
//! layer between [`super::verify`] and [`super::compile`].
//!
//! The verifier proves *structural* properties (fields decode, targets
//! are in range); the compiled engine then still pays a bounds check on
//! every memory op and a fuel check at every block entry, and the §3.5
//! trust story stops at "it cannot escape the sandbox". This pass runs
//! once per (name, code) — at the same point as verify/compile, so the
//! [`ProgramFacts`] artifact is cached in the §3.4 code cache — and
//! computes a sound over-approximation of every register's value range
//! at every reachable pc (interval domain, widened at join points so the
//! fixpoint terminates on loops). Three consumers:
//!
//! * **Check elision** — a memory op whose address interval is provably
//!   bounded is lowered by [`super::compile::compile_analyzed`] to an
//!   unchecked fast-path handler, guarded by a single whole-program
//!   bound check at entry ([`ProgramFacts::pay_bound`] /
//!   [`ProgramFacts::scr_bound`]); a loop-free program additionally
//!   carries [`ProgramFacts::max_steps`], letting the engine skip every
//!   per-block fuel comparison when the budget covers the worst case.
//! * **Static cost & admission** — [`ProgramFacts::fuel_floor`] is a
//!   lower bound on the fuel any *successful* run must retire
//!   (`u64::MAX` when no `halt` is reachable), so a dispatcher can
//!   reject a program that can never complete under the configured
//!   budget before burning a worker; [`Lint`]s flag
//!   divide-by-constant-zero and unreachable code with disassembly.
//! * **Capability gating** — [`ProgramFacts::reachable_slots`] is the
//!   set of GOT slots a program can actually call, checked against a
//!   [`CapabilityPolicy`] allowlist at injection time.
//!
//! Soundness contract: every fact is an over-approximation of the
//! dynamic semantics of **both** engines (`run_reference` and the
//! threaded compiler), locked by the differential property harness in
//! `rust/tests/prop.rs`. Anything the domain cannot prove stays TOP and
//! keeps its dynamic check; arithmetic that may wrap is never narrowed.

use std::collections::{BTreeSet, VecDeque};

use super::disasm::disasm_instr;
use super::isa::{Instr, Op, NUM_REGS, SCRATCH_BYTES, SPACE_PAYLOAD, SPACE_SCRATCH};

/// Elision cap for payload addresses: a proven bound above this is not
/// worth eliding (the entry guard would demand an implausibly large
/// payload and force the reference fallback on every invocation).
pub const ELIDE_PAY_LIMIT: u64 = 1 << 20;

/// Join count at one pc after which intervals are widened to their
/// extremes — guarantees the fixpoint terminates on loops.
const WIDEN_AFTER: u8 = 3;

/// An unsigned value interval `[lo, hi]`, both inclusive.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Interval {
    pub lo: u64,
    pub hi: u64,
}

impl Interval {
    pub const TOP: Interval = Interval { lo: 0, hi: u64::MAX };

    pub fn exact(v: u64) -> Interval {
        Interval { lo: v, hi: v }
    }

    fn new(lo: u64, hi: u64) -> Interval {
        debug_assert!(lo <= hi);
        Interval { lo, hi }
    }

    fn join(self, other: Interval) -> Interval {
        Interval { lo: self.lo.min(other.lo), hi: self.hi.max(other.hi) }
    }

    fn is_const(&self) -> Option<u64> {
        (self.lo == self.hi).then_some(self.lo)
    }
}

/// Machine-checkable lint categories surfaced by the analysis.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LintKind {
    /// A reachable `divu` whose divisor register is provably zero —
    /// every execution reaching it faults.
    DivByConstZero,
    /// An instruction no path from the entry can reach.
    Unreachable,
}

/// One diagnostic finding, with the disassembled instruction inline.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Lint {
    pub pc: u32,
    pub kind: LintKind,
    pub message: String,
}

/// The cached artifact of one [`analyze`] run — stored alongside the
/// [`super::CompiledProgram`] in the code cache so repeat injections
/// skip the analysis too.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct ProgramFacts {
    /// Per source pc: is this memory op's address interval proven in
    /// bounds (given the entry guards below)? Always `false` for
    /// non-memory ops.
    pub elidable: Vec<bool>,
    /// Per source pc: reachable from the entry?
    pub reachable: Vec<bool>,
    /// Entry guard for elided payload ops: every elided payload access
    /// is in bounds whenever `payload.len() >= pay_bound`.
    pub pay_bound: u64,
    /// Entry guard for elided scratch ops, against the configured
    /// scratch size.
    pub scr_bound: u64,
    /// Worst-case retired-instruction bound, present only when the
    /// reachable control-flow graph is loop-free (a DAG): the sum of
    /// full block costs along the heaviest block path. A budget at or
    /// above this can skip every per-block fuel comparison.
    pub max_steps: Option<u64>,
    /// Fuel floor: the minimum instructions any run must retire to reach
    /// (and retire) a `halt`. `u64::MAX` when no `halt` is reachable —
    /// the program can never complete successfully.
    pub fuel_floor: u64,
    /// GOT slots of reachable `call` instructions, sorted and deduped —
    /// the host symbols this program can actually invoke.
    pub reachable_slots: Vec<u32>,
    pub lints: Vec<Lint>,
    /// Count of memory ops lowered to unchecked handlers.
    pub elided_ops: usize,
}

impl ProgramFacts {
    /// `true` when a cycle is reachable — the program *may* loop
    /// (fuel still bounds it dynamically).
    pub fn may_loop(&self) -> bool {
        self.max_steps.is_none()
    }

    /// Map [`ProgramFacts::reachable_slots`] through the import table.
    /// Slots past the table (unverified input) are skipped.
    pub fn reachable_syms<'a>(&self, imports: &'a [String]) -> Vec<&'a str> {
        self.reachable_slots
            .iter()
            .filter_map(|&s| imports.get(s as usize).map(String::as_str))
            .collect()
    }
}

/// Per-client / per-worker host-symbol allowlist enforced at injection
/// time against [`ProgramFacts::reachable_slots`]. The default permits
/// everything (the pre-analysis behavior); a restricted policy lists the
/// symbols injected code may call — e.g. a serve deployment that never
/// wired the mesh can refuse `forward`-capable programs outright.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct CapabilityPolicy {
    /// `None` = allow every linked symbol; `Some(set)` = only these.
    pub allow: Option<BTreeSet<String>>,
}

impl CapabilityPolicy {
    /// The permissive default.
    pub fn allow_all() -> CapabilityPolicy {
        CapabilityPolicy { allow: None }
    }

    /// Restrict injected code to exactly these host symbols.
    pub fn only<I, S>(syms: I) -> CapabilityPolicy
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        CapabilityPolicy { allow: Some(syms.into_iter().map(Into::into).collect()) }
    }

    pub fn permits(&self, sym: &str) -> bool {
        match &self.allow {
            None => true,
            Some(set) => set.contains(sym),
        }
    }

    pub fn is_restricted(&self) -> bool {
        self.allow.is_some()
    }

    /// First reachable symbol the policy refuses, if any.
    pub fn first_denied<'a>(&self, syms: &[&'a str]) -> Option<&'a str> {
        syms.iter().find(|s| !self.permits(s)).copied()
    }
}

/// Leader-side admission summary stamped onto an outgoing message by
/// `IfuncHandle::msg_create`: the slice of [`ProgramFacts`] a dispatcher
/// needs to reject a doomed injection *before* fan-out, with the slot →
/// symbol mapping already applied.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AdmissionFacts {
    pub fuel_floor: u64,
    pub may_loop: bool,
    /// Host symbols reachable `call`s can invoke (names, not slots).
    pub reachable_syms: Vec<String>,
}

impl AdmissionFacts {
    pub fn derive(facts: &ProgramFacts, imports: &[String]) -> AdmissionFacts {
        AdmissionFacts {
            fuel_floor: facts.fuel_floor,
            may_loop: facts.may_loop(),
            reachable_syms: facts
                .reachable_syms(imports)
                .into_iter()
                .map(str::to_string)
                .collect(),
        }
    }
}

/// Analyze a decoded (normally verified) program. Total on any input —
/// unverified out-of-range jump targets are treated as dead edges, an
/// empty program yields empty facts — and never panics, so the engine
/// can run it unconditionally between verify and compile.
pub fn analyze(prog: &[Instr]) -> ProgramFacts {
    let n = prog.len();
    let mut facts = ProgramFacts {
        elidable: vec![false; n],
        reachable: vec![false; n],
        fuel_floor: u64::MAX,
        ..ProgramFacts::default()
    };
    if n == 0 {
        return facts;
    }

    // ---- interval fixpoint over the instruction-level CFG ------------
    // state[pc] = register intervals *before* executing prog[pc].
    let mut state: Vec<Option<[Interval; NUM_REGS]>> = vec![None; n];
    let mut joins = vec![0u8; n];
    let mut work = VecDeque::new();
    let mut entry = [Interval::exact(0); NUM_REGS];
    entry[1] = Interval::TOP; // r1 = payload length, unknown statically
    state[0] = Some(entry);
    work.push_back(0usize);

    while let Some(pc) = work.pop_front() {
        let mut s = state[pc].expect("worklist entries have a state");
        let i = &prog[pc];
        transfer(i, &mut s);
        for succ in successors(pc, i, n) {
            let changed = match &mut state[succ] {
                slot @ None => {
                    *slot = Some(s);
                    true
                }
                Some(cur) => {
                    let mut any = false;
                    for r in 0..NUM_REGS {
                        let joined = if joins[succ] >= WIDEN_AFTER {
                            widen(cur[r], s[r])
                        } else {
                            cur[r].join(s[r])
                        };
                        if joined != cur[r] {
                            cur[r] = joined;
                            any = true;
                        }
                    }
                    if any {
                        joins[succ] = joins[succ].saturating_add(1);
                    }
                    any
                }
            };
            if changed {
                work.push_back(succ);
            }
        }
    }

    for pc in 0..n {
        facts.reachable[pc] = state[pc].is_some();
    }

    // ---- consumers over the reachable states -------------------------
    let mut slots = BTreeSet::new();
    for pc in 0..n {
        let i = &prog[pc];
        let Some(s) = &state[pc] else {
            facts.lints.push(Lint {
                pc: pc as u32,
                kind: LintKind::Unreachable,
                message: format!(
                    "pc {pc} (offset {:#x}): unreachable: {}",
                    pc * super::isa::INSTR_BYTES,
                    disasm_instr(i, None)
                ),
            });
            continue;
        };
        match i.op {
            Op::Call => {
                slots.insert(i.imm);
            }
            Op::Divu => {
                if s[i.c as usize % NUM_REGS].is_const() == Some(0) {
                    facts.lints.push(Lint {
                        pc: pc as u32,
                        kind: LintKind::DivByConstZero,
                        message: format!(
                            "pc {pc} (offset {:#x}): divisor r{} is provably zero: {}",
                            pc * super::isa::INSTR_BYTES,
                            i.c,
                            disasm_instr(i, None)
                        ),
                    });
                }
            }
            Op::Ldb | Op::Ldw | Op::Stb | Op::Stw => {
                let width: u64 = if matches!(i.op, Op::Ldb | Op::Stb) { 1 } else { 8 };
                let base = s[i.b as usize % NUM_REGS];
                // End of the access if the address arithmetic cannot
                // wrap; a possible wrap keeps the dynamic check.
                let end = base
                    .hi
                    .checked_add(i.imm as u64)
                    .and_then(|a| a.checked_add(width));
                if let Some(end) = end {
                    let (limit, bound) = match i.c {
                        SPACE_PAYLOAD => (ELIDE_PAY_LIMIT, &mut facts.pay_bound),
                        SPACE_SCRATCH => (SCRATCH_BYTES as u64, &mut facts.scr_bound),
                        _ => continue, // unverified space selector
                    };
                    if end <= limit {
                        facts.elidable[pc] = true;
                        facts.elided_ops += 1;
                        *bound = (*bound).max(end);
                    }
                }
            }
            _ => {}
        }
    }
    facts.reachable_slots = slots.into_iter().collect();

    // ---- fuel floor: BFS shortest retire-count to a reachable halt ---
    let mut dist = vec![u64::MAX; n];
    let mut q = VecDeque::new();
    dist[0] = 0;
    q.push_back(0usize);
    while let Some(pc) = q.pop_front() {
        let i = &prog[pc];
        if i.op == Op::Halt {
            facts.fuel_floor = facts.fuel_floor.min(dist[pc] + 1);
            continue;
        }
        for succ in successors(pc, i, n) {
            if dist[succ] == u64::MAX {
                dist[succ] = dist[pc] + 1;
                q.push_back(succ);
            }
        }
    }

    // ---- loop-freedom and the worst-case block-fuel bound ------------
    facts.max_steps = max_steps(prog, &facts.reachable);
    facts
}

/// Widening join: a bound that moved since the last join at this pc is
/// sent straight to its extreme, so each register can change at most
/// twice more and the fixpoint terminates on any loop nest.
fn widen(cur: Interval, incoming: Interval) -> Interval {
    Interval {
        lo: if incoming.lo < cur.lo { 0 } else { cur.lo },
        hi: if incoming.hi > cur.hi { u64::MAX } else { cur.hi },
    }
}

/// CFG successors of `pc`. Out-of-range targets (possible only on
/// unverified input) and running off the code end are dead edges — those
/// executions fault, so no abstract state flows onward.
fn successors(pc: usize, i: &Instr, n: usize) -> Vec<usize> {
    let fall = || (pc + 1 < n).then_some(pc + 1);
    let target = || ((i.imm as usize) < n).then_some(i.imm as usize);
    match i.op {
        Op::Halt => Vec::new(),
        Op::Jmp => target().into_iter().collect(),
        Op::Jz | Op::Jnz => target().into_iter().chain(fall()).collect(),
        _ => fall().into_iter().collect(),
    }
}

/// Transfer function: `s` is the state before `i`; update it to the
/// state after. Every rule over-approximates the wrapping u64 semantics
/// of both engines — any case that could wrap or is data-dependent goes
/// to TOP.
fn transfer(i: &Instr, s: &mut [Interval; NUM_REGS]) {
    // Verified programs have in-range fields; the masks keep the pass
    // total (and trivially sound) on unverified ones.
    let a = i.a as usize % NUM_REGS;
    let b = i.b as usize % NUM_REGS;
    let c = i.c as usize % NUM_REGS;
    let imm = i.imm as u64;
    match i.op {
        Op::Halt | Op::Nop | Op::Jmp | Op::Jz | Op::Jnz | Op::Stb | Op::Stw => {}
        Op::Ldi => s[a] = Interval::exact(imm),
        // High half becomes imm; the (unknown) low half survives.
        Op::Ldih => s[a] = Interval::new(imm << 32, (imm << 32) | 0xFFFF_FFFF),
        Op::Mov => s[a] = s[b],
        Op::Add => s[a] = add_iv(s[b], s[c]),
        Op::Addi => s[a] = add_iv(s[b], Interval::exact(imm)),
        Op::Sub => {
            // Borrow-free only when every minuend >= every subtrahend.
            s[a] = if s[b].lo >= s[c].hi {
                Interval::new(s[b].lo - s[c].hi, s[b].hi - s[c].lo)
            } else {
                Interval::TOP
            };
        }
        Op::Mul => {
            s[a] = match s[b].hi.checked_mul(s[c].hi) {
                Some(hi) => Interval::new(s[b].lo.wrapping_mul(s[c].lo), hi),
                None => Interval::TOP,
            };
        }
        Op::Divu => {
            // On the non-faulting continuation the divisor was >= 1, so
            // the quotient never exceeds the dividend.
            s[a] = match s[c].is_const() {
                Some(k) if k > 0 => Interval::new(s[b].lo / k, s[b].hi / k),
                _ => Interval::new(0, s[b].hi),
            };
        }
        Op::And => s[a] = Interval::new(0, s[b].hi.min(s[c].hi)),
        Op::Or => {
            // a|b keeps the operands' highest bit: bound by the mask of
            // the larger operand's bit width; never below either input.
            s[a] = Interval::new(s[b].lo.max(s[c].lo), bit_mask(s[b].hi | s[c].hi));
        }
        Op::Xor => s[a] = Interval::new(0, bit_mask(s[b].hi | s[c].hi)),
        Op::Shl => {
            s[a] = match s[c].is_const() {
                Some(k) => {
                    let k = (k & 63) as u32;
                    if k == 0 {
                        s[b]
                    } else if s[b].hi.leading_zeros() >= k {
                        Interval::new(s[b].lo << k, s[b].hi << k)
                    } else {
                        Interval::TOP // shifts bits out: wraps
                    }
                }
                None => Interval::TOP,
            };
        }
        Op::Shr => {
            s[a] = match s[c].is_const() {
                Some(k) => {
                    let k = (k & 63) as u32;
                    Interval::new(s[b].lo >> k, s[b].hi >> k)
                }
                // Any shift only shrinks the value.
                None => Interval::new(0, s[b].hi),
            };
        }
        Op::Sltu | Op::Eq => s[a] = Interval::new(0, 1),
        Op::Call => s[0] = Interval::TOP, // host result is opaque
        Op::Ldb => s[a] = Interval::new(0, 0xFF),
        Op::Ldw | Op::Paylen => s[a] = Interval::TOP,
    }
}

fn add_iv(x: Interval, y: Interval) -> Interval {
    match x.hi.checked_add(y.hi) {
        Some(hi) => Interval::new(x.lo + y.lo, hi), // lo can't overflow if hi didn't
        None => Interval::TOP,
    }
}

/// Smallest all-ones mask covering `v` — the tight upper bound for
/// bitwise or/xor of values bounded by `v`.
fn bit_mask(v: u64) -> u64 {
    if v == 0 {
        0
    } else {
        u64::MAX >> v.leading_zeros()
    }
}

/// If the reachable CFG is a DAG, the heaviest block path measured in
/// *full* block costs — the compiled engine charges a block's whole cost
/// at entry (even if it faults mid-block), so this is the exact ceiling
/// on total fuel charged by any execution.
fn max_steps(prog: &[Instr], reachable: &[bool]) -> Option<u64> {
    let n = prog.len();
    // Leaders, exactly as the compiler computes them.
    let mut leader = vec![false; n];
    leader[0] = true;
    for (pc, i) in prog.iter().enumerate() {
        match i.op {
            Op::Jmp | Op::Jz | Op::Jnz => {
                let t = i.imm as usize;
                if t < n {
                    leader[t] = true;
                }
                if pc + 1 < n {
                    leader[pc + 1] = true;
                }
            }
            Op::Halt => {
                if pc + 1 < n {
                    leader[pc + 1] = true;
                }
            }
            _ => {}
        }
    }
    let block_end = |start: usize| {
        let mut e = start;
        while e + 1 < n && !leader[e + 1] {
            e += 1;
        }
        e
    };
    // Iterative DFS from block 0: detects cycles (gray hit) and computes
    // longest-path weights in post-order. Weight of a block = its full
    // cost plus the heaviest successor.
    const WHITE: u8 = 0;
    const GRAY: u8 = 1;
    const BLACK: u8 = 2;
    let mut color = vec![WHITE; n];
    let mut weight = vec![0u64; n]; // indexed by leader pc
    let mut stack: Vec<(usize, usize)> = vec![(0, 0)]; // (leader, next succ idx)
    while let Some(frame) = stack.last_mut() {
        let (l, next) = (frame.0, frame.1);
        debug_assert!(reachable[l], "DFS only walks reachable leaders");
        if next == 0 {
            color[l] = GRAY;
        }
        let e = block_end(l);
        let succs = block_successors(prog, l, e, n);
        if let Some(&s) = succs.get(next) {
            frame.1 += 1;
            match color[s] {
                WHITE => stack.push((s, 0)),
                GRAY => return None, // back edge: reachable loop
                _ => {}
            }
        } else {
            let best = succs.iter().map(|&s| weight[s]).max().unwrap_or(0);
            weight[l] = (e - l + 1) as u64 + best;
            color[l] = BLACK;
            stack.pop();
        }
    }
    Some(weight[0])
}

/// Successor *leaders* of the block `[start, end]`.
fn block_successors(prog: &[Instr], _start: usize, end: usize, n: usize) -> Vec<usize> {
    let i = &prog[end];
    let fall = || (end + 1 < n).then_some(end + 1);
    let target = || ((i.imm as usize) < n).then_some(i.imm as usize);
    match i.op {
        Op::Halt => Vec::new(),
        Op::Jmp => target().into_iter().collect(),
        Op::Jz | Op::Jnz => target().into_iter().chain(fall()).collect(),
        _ => fall().into_iter().collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vm::verify::verify;
    use crate::vm::Assembler;

    fn analyzed(build: impl FnOnce(&mut Assembler)) -> (Vec<Instr>, Vec<String>, ProgramFacts) {
        let mut a = Assembler::new();
        build(&mut a);
        let (code, imports) = a.assemble();
        let prog = verify(&code, imports.len()).expect("test program verifies");
        let facts = analyze(&prog);
        (prog, imports, facts)
    }

    #[test]
    fn empty_program_yields_empty_facts() {
        let facts = analyze(&[]);
        assert_eq!(facts.elided_ops, 0);
        assert_eq!(facts.fuel_floor, u64::MAX);
        assert_eq!(facts.max_steps, None, "no entry, no bound");
    }

    #[test]
    fn constant_header_reads_are_elidable() {
        // The builtin shape: read two u64 header words at fixed offsets.
        let (_, _, facts) = analyzed(|a| {
            a.ldw(2, 0, 0, 0);
            a.ldw(3, 0, 0, 8);
            a.add(0, 2, 3);
            a.halt();
        });
        assert_eq!(facts.elided_ops, 2);
        assert!(facts.elidable[0] && facts.elidable[1]);
        assert_eq!(facts.pay_bound, 16);
        assert_eq!(facts.scr_bound, 0);
        assert_eq!(facts.max_steps, Some(4), "straight line: 4 instructions");
        assert_eq!(facts.fuel_floor, 4);
        assert!(!facts.may_loop());
    }

    #[test]
    fn paylen_derived_index_stays_checked() {
        // r2 = paylen - 1 is dynamic: the access must keep its check.
        let (_, _, facts) = analyzed(|a| {
            a.paylen(2);
            a.ldi(3, 1);
            a.sub(2, 2, 3);
            a.ldb(0, 2, 0, 0);
            a.halt();
        });
        assert_eq!(facts.elided_ops, 0);
        assert!(!facts.elidable[3]);
    }

    #[test]
    fn loaded_index_stays_checked_but_masked_index_does_not() {
        // An attacker-controlled byte as an index is TOP-255; a byte is
        // provably < 256, so scratch (64 KiB) accesses elide but payload
        // beyond the bound would not.
        let (_, _, facts) = analyzed(|a| {
            a.ldb(2, 0, 0, 0); // r2 = payload[0] in [0, 255]
            a.stb(2, 2, 1, 0); // scratch[r2] — bound 256 <= 64 KiB
            a.ldw(3, 2, 0, 0); // payload[r2 .. r2+8] — bound 263
            a.halt();
        });
        assert!(facts.elidable[0], "constant payload[0] read");
        assert!(facts.elidable[1], "byte-bounded scratch store");
        assert!(facts.elidable[2], "byte-bounded payload word read");
        assert_eq!(facts.scr_bound, 256);
        assert_eq!(facts.pay_bound, 263, "max addr 255 + 8-byte width");
    }

    #[test]
    fn wrapping_address_arithmetic_stays_checked() {
        // r2 = 0xFFFF_FFFF << 32 | 0xFFFF_FFFF = u64::MAX, +imm wraps.
        let (_, _, facts) = analyzed(|a| {
            a.ldi64(2, u64::MAX);
            a.ldb(0, 2, 0, 1); // addr wraps to 0 dynamically — not provable
            a.halt();
        });
        assert_eq!(facts.elided_ops, 0);
    }

    #[test]
    fn loop_has_no_max_steps_but_keeps_floor() {
        let (_, _, facts) = analyzed(|a| {
            let top = a.label();
            let done = a.label();
            a.paylen(3);
            a.ldi(2, 0);
            a.bind(top);
            a.sltu(5, 2, 3);
            a.jz(5, done);
            a.addi(2, 2, 1);
            a.jmp(top);
            a.bind(done);
            a.halt();
        });
        assert!(facts.may_loop());
        assert_eq!(facts.max_steps, None);
        // Shortest completing path: paylen, ldi, sltu, jz, halt.
        assert_eq!(facts.fuel_floor, 5);
    }

    #[test]
    fn spin_loop_can_never_halt() {
        let (_, _, facts) = analyzed(|a| {
            let top = a.label();
            a.bind(top);
            a.jmp(top);
        });
        assert_eq!(facts.fuel_floor, u64::MAX);
        assert!(facts.may_loop());
    }

    #[test]
    fn reachable_slots_skip_dead_calls() {
        let (_, imports, facts) = analyzed(|a| {
            let dead = a.label();
            let out = a.label();
            a.call("live");
            a.jmp(out);
            a.bind(dead);
            a.call("dead"); // no path reaches this
            a.bind(out);
            a.halt();
        });
        assert_eq!(imports, vec!["live".to_string(), "dead".to_string()]);
        assert_eq!(facts.reachable_slots, vec![0]);
        assert_eq!(facts.reachable_syms(&imports), vec!["live"]);
        assert!(facts
            .lints
            .iter()
            .any(|l| l.kind == LintKind::Unreachable && l.message.contains("call")));
    }

    #[test]
    fn div_by_const_zero_lints_with_disasm() {
        let (_, _, facts) = analyzed(|a| {
            a.ldi(2, 10);
            a.ldi(3, 0);
            a.divu(0, 2, 3);
            a.halt();
        });
        let lint = facts
            .lints
            .iter()
            .find(|l| l.kind == LintKind::DivByConstZero)
            .expect("lint present");
        assert_eq!(lint.pc, 2);
        assert!(lint.message.contains("divu"), "{}", lint.message);
        assert!(lint.message.contains("offset 0x10"), "{}", lint.message);
    }

    #[test]
    fn widening_terminates_on_nested_loops() {
        // r2 grows without bound through a nested loop; the fixpoint
        // must converge (widening) and the growing index stays checked.
        let (_, _, facts) = analyzed(|a| {
            let outer = a.label();
            let inner = a.label();
            let out = a.label();
            a.ldi(2, 0);
            a.bind(outer);
            a.bind(inner);
            a.addi(2, 2, 8);
            a.ldb(4, 2, 0, 0); // index grows every iteration
            a.jnz(4, inner);
            a.ldi(5, 1000);
            a.sltu(6, 2, 5);
            a.jnz(6, outer);
            a.bind(out);
            a.halt();
        });
        assert!(!facts.elidable[2], "unbounded loop index must stay checked");
        assert!(facts.may_loop());
    }

    #[test]
    fn capability_policy_defaults_open_and_restricts() {
        let open = CapabilityPolicy::default();
        assert!(open.permits("forward"));
        assert!(!open.is_restricted());
        let tight = CapabilityPolicy::only(["counter_add", "reply_put"]);
        assert!(tight.permits("reply_put"));
        assert!(!tight.permits("forward"));
        assert_eq!(tight.first_denied(&["counter_add", "forward"]), Some("forward"));
    }

    #[test]
    fn admission_facts_carry_symbol_names() {
        let (_, imports, facts) = analyzed(|a| {
            a.call("forward");
            a.halt();
        });
        let adm = AdmissionFacts::derive(&facts, &imports);
        assert_eq!(adm.reachable_syms, vec!["forward".to_string()]);
        assert_eq!(adm.fuel_floor, 2);
        assert!(!adm.may_loop);
    }

    #[test]
    fn sub_and_shift_transfer_precision() {
        // shl by a constant with headroom keeps exact bounds; the
        // elision below depends on it.
        let (_, _, facts) = analyzed(|a| {
            a.ldb(2, 0, 0, 0); // [0, 255]
            a.ldi(3, 3);
            a.shl(2, 2, 3); // [0, 2040]
            a.ldb(0, 2, 1, 0); // scratch[0..2041] ⊂ 64 KiB
            a.halt();
        });
        assert!(facts.elidable[3]);
        assert_eq!(facts.scr_bound, 2041);
    }
}
