//! Pre-compiled TCVM programs — direct-threaded dispatch for the hot path.
//!
//! The reference interpreter ([`super::interp`]) pays, per retired
//! instruction: a fuel check, a bounds-checked fetch, an opcode `match`,
//! and `as usize` casts on every operand. On the cache-hit invoke path
//! (the steady state since the §3.4 code cache) those cycles dominate
//! small-frame latency. This module lowers a *verified* program once —
//! at the same point the verifier runs, so the result is cached in
//! [`crate::ifunc::cache::CodeCache`] alongside the GOT — into a
//! [`CompiledProgram`] whose ops carry:
//!
//! * a **pre-resolved handler function pointer** (direct-threaded-style
//!   dispatch: no opcode decode per step, and memory ops are specialized
//!   per space so the payload/scratch branch is gone too),
//! * **pre-cast operand indices** and pre-extended/pre-shifted
//!   immediates (jump targets are remapped to compiled-op indices),
//! * **superinstruction fusion** over the hot pairs of the existing
//!   workloads: `sltu+jz` → compare-branch, `ldb+add` → load-accumulate,
//!   `addi+jmp` → loop tail, `ldi+ldih` (same register) → a
//!   constant-folded 64-bit load. A pair fuses only when the second half
//!   is not a jump target — a branch landing between the halves must see
//!   unfused semantics,
//! * **block-level fuel**: basic-block costs are computed at compile
//!   time and charged once at block entry instead of per instruction.
//!   Because a block either fully retires or faults, the retired-step
//!   count at `HALT` is identical to the reference. When the remaining
//!   fuel cannot cover a block, execution delegates to the reference
//!   stepper ([`super::interp::run_from`]) from the block's source pc,
//!   so fuel faults report the exact instruction — a block never
//!   over-runs the budget,
//! * a precomputed `uses_scratch` flag (the reference re-scans the whole
//!   program for scratch-space memory ops on **every** invocation).
//!
//! This is the rbpf pattern: one verifier, a fast engine and a reference
//! interpreter behind it, kept conformant by differential testing
//! (`rust/tests/prop.rs`) — fault *messages* included, byte for byte.

use std::any::Any;

use super::analysis::ProgramFacts;
use super::got::{GotTable, HostCtx};
use super::interp::{self, VmConfig, VmOutcome};
use super::isa::{Instr, Op, NUM_REGS, SPACE_PAYLOAD};
use crate::{Error, Result};

/// Sentinel "next ip" returned by the `HALT` handler.
const HALT: usize = usize::MAX;

/// Live machine state threaded through the op handlers.
struct Machine<'a> {
    regs: [u64; NUM_REGS],
    fuel: u64,
    payload: &'a mut [u8],
    scratch: &'a mut [u8],
    user: &'a mut dyn Any,
    got: &'a GotTable,
}

/// An op handler: executes one compiled op and returns the next op index
/// ([`HALT`] to stop). Faults carry the *source* pc via
/// [`CompiledOp::orig_pc`], so messages match the reference exactly.
type Handler = fn(&CompiledOp, usize, &mut Machine<'_>) -> Result<usize>;

/// One pre-decoded op: handler pointer plus pre-cast operands. `d`/`e`/
/// `f` and `imm2` carry the second half of a fused pair.
#[derive(Clone, Copy)]
pub struct CompiledOp {
    handler: Handler,
    a: usize,
    b: usize,
    c: usize,
    d: usize,
    e: usize,
    f: usize,
    /// Pre-extended immediate: value, memory offset, GOT slot, or (for
    /// jumps) the *compiled-op index* of the target.
    imm: u64,
    /// Fused-pair secondary immediate (always the branch target).
    imm2: u64,
    /// Source pc of the (first) original instruction — fault attribution.
    orig_pc: u32,
    /// Fuel for the whole basic block; nonzero only on block leaders.
    block_cost: u32,
    /// Original instructions this op retires (2 for fused pairs).
    retire: u32,
}

impl CompiledOp {
    fn new(handler: Handler, orig_pc: u32, retire: u32) -> CompiledOp {
        CompiledOp {
            handler,
            a: 0,
            b: 0,
            c: 0,
            d: 0,
            e: 0,
            f: 0,
            imm: 0,
            imm2: 0,
            orig_pc,
            block_cost: 0,
            retire,
        }
    }
}

/// A verified program lowered to threaded ops. Built once per
/// (name, code) by [`compile`] and cached; [`CompiledProgram::run`] is
/// the production execute path.
#[derive(Clone)]
pub struct CompiledProgram {
    /// Threaded ops, terminated by a trap op that raises the
    /// fell-off-code-end / fuel-exhausted fault exactly like the
    /// reference does at `pc == len`.
    ops: Vec<CompiledOp>,
    /// The verified source, kept for the precise-fuel fallback (and for
    /// differential runs against the reference interpreter).
    src: Vec<Instr>,
    uses_scratch: bool,
    fused: usize,
    blocks: usize,
    /// Entry guards for analysis-elided memory ops: the minimum payload
    /// length / scratch size under which every unchecked access is
    /// proven in bounds. A run that cannot meet them falls back to
    /// reference semantics for the whole invocation.
    guard_pay: u64,
    guard_scr: u64,
    /// Worst-case total fuel charge when the program is loop-free — a
    /// budget covering it skips every per-block fuel comparison.
    static_max_steps: Option<u64>,
    /// Memory ops lowered to unchecked fast-path handlers.
    elided: usize,
}

/// Lower a verified program with superinstruction fusion enabled (the
/// production configuration).
pub fn compile(src: Vec<Instr>) -> CompiledProgram {
    compile_with(src, true, None)
}

/// Lower without the fusion pass — the "threaded, no fusion" column of
/// Abl J, isolating what dispatch vs fusion each buy.
pub fn compile_unfused(src: Vec<Instr>) -> CompiledProgram {
    compile_with(src, false, None)
}

/// Lower with [`super::analysis`] facts applied: memory ops the interval
/// analysis proved in bounds become unchecked fast-path handlers (behind
/// the entry guards), and a loop-free program records its worst-case
/// fuel charge so a covering budget skips per-block fuel checks. `facts`
/// must come from [`super::analysis::analyze`] over the *same* verified
/// program — the engine computes both at the single verify/compile point
/// and caches them together.
pub fn compile_analyzed(src: Vec<Instr>, facts: &ProgramFacts) -> CompiledProgram {
    compile_with(src, true, Some(facts))
}

fn compile_with(src: Vec<Instr>, fuse: bool, facts: Option<&ProgramFacts>) -> CompiledProgram {
    let n = src.len();

    // Basic-block leaders: entry, every jump target, and the successor
    // of every control-flow instruction.
    let mut leader = vec![false; n];
    if n > 0 {
        leader[0] = true;
    }
    for (pc, i) in src.iter().enumerate() {
        match i.op {
            Op::Jmp | Op::Jz | Op::Jnz => {
                let t = i.imm as usize;
                if t < n {
                    leader[t] = true;
                }
                if pc + 1 < n {
                    leader[pc + 1] = true;
                }
            }
            Op::Halt => {
                if pc + 1 < n {
                    leader[pc + 1] = true;
                }
            }
            _ => {}
        }
    }

    // Fusion pass: greedy left-to-right over adjacent pairs inside a
    // block. The second half must not be a leader — a jump landing
    // between the halves has to execute it alone.
    let mut fused_with_next = vec![false; n];
    let mut fused = 0usize;
    if fuse {
        let mut pc = 0;
        while pc + 1 < n {
            if !leader[pc + 1] && fusible(&src[pc], &src[pc + 1]) {
                fused_with_next[pc] = true;
                fused += 1;
                pc += 2;
            } else {
                pc += 1;
            }
        }
    }

    // Source pc → compiled-op index (fusion shifts indices). `map[n]` is
    // the trailing trap op, where a fall off the code end lands.
    let mut map = vec![0u32; n + 1];
    let mut idx = 0u32;
    let mut pc = 0;
    while pc < n {
        map[pc] = idx;
        if fused_with_next[pc] {
            map[pc + 1] = idx;
            pc += 2;
        } else {
            pc += 1;
        }
        idx += 1;
    }
    map[n] = idx;

    // Emit. `elidable` marks memory ops the analysis proved in bounds
    // (given the entry guards) — they get unchecked handlers.
    let elidable =
        |pc: usize| facts.is_some_and(|f| f.elidable.get(pc).copied().unwrap_or(false));
    let mut ops = Vec::with_capacity(idx as usize + 1);
    let mut pc = 0;
    while pc < n {
        if fused_with_next[pc] {
            ops.push(emit_fused(&src[pc], &src[pc + 1], pc as u32, &map, n, elidable(pc)));
            pc += 2;
        } else {
            ops.push(emit_one(&src[pc], pc as u32, &map, n, elidable(pc)));
            pc += 1;
        }
    }
    ops.push(CompiledOp::new(op_trap, n as u32, 0));

    // Block fuel: each leader op carries the retired-instruction count of
    // its whole block (the trap op is unreachable fall-through, cost 0).
    let last = ops.len() - 1;
    let mut blocks = 0usize;
    let mut k = 0;
    while k < last {
        let start = k;
        let mut cost = 0u32;
        loop {
            cost += ops[k].retire;
            k += 1;
            if k >= last || leader[ops[k].orig_pc as usize] {
                break;
            }
        }
        ops[start].block_cost = cost;
        blocks += 1;
    }

    let uses_scratch = src.iter().any(Instr::touches_scratch);
    let (guard_pay, guard_scr, static_max_steps, elided) = match facts {
        Some(f) => (f.pay_bound, f.scr_bound, f.max_steps, f.elided_ops),
        None => (0, 0, None, 0),
    };
    CompiledProgram {
        ops,
        src,
        uses_scratch,
        fused,
        blocks,
        guard_pay,
        guard_scr,
        static_max_steps,
        elided,
    }
}

fn fusible(first: &Instr, second: &Instr) -> bool {
    match (first.op, second.op) {
        (Op::Sltu, Op::Jz) | (Op::Ldb, Op::Add) | (Op::Addi, Op::Jmp) => true,
        // ldi64: only when both halves write the same register — the
        // pair constant-folds to one 64-bit load.
        (Op::Ldi, Op::Ldih) => first.a == second.a,
        _ => false,
    }
}

/// Remap a source jump target to its compiled-op index. Verified targets
/// are `< n`; the clamp keeps `compile` total on unverified input (a
/// clamped jump lands on the trap op — the same fell-off-end fault the
/// reference raises at `pc == len`).
fn target(imm: u32, map: &[u32], n: usize) -> u64 {
    map[(imm as usize).min(n)] as u64
}

fn emit_one(i: &Instr, pc: u32, map: &[u32], n: usize, elide: bool) -> CompiledOp {
    let (a, b, c) = (i.a as usize, i.b as usize, i.c as usize);
    let imm = i.imm as u64;
    let base = |h: Handler| CompiledOp::new(h, pc, 1);
    // Memory handler: (space, checked/unchecked) → specialized fn.
    let mem = |pay: Handler, pay_fast: Handler, scr: Handler, scr_fast: Handler| match (
        i.c == SPACE_PAYLOAD,
        elide,
    ) {
        (true, false) => pay,
        (true, true) => pay_fast,
        (false, false) => scr,
        (false, true) => scr_fast,
    };
    match i.op {
        Op::Halt => base(op_halt),
        Op::Nop => base(op_nop),
        Op::Ldi => CompiledOp { a, imm, ..base(op_ldi) },
        Op::Ldih => CompiledOp { a, imm: imm << 32, ..base(op_ldih) },
        Op::Mov => CompiledOp { a, b, ..base(op_mov) },
        Op::Add => CompiledOp { a, b, c, ..base(op_add) },
        Op::Sub => CompiledOp { a, b, c, ..base(op_sub) },
        Op::Mul => CompiledOp { a, b, c, ..base(op_mul) },
        Op::Divu => CompiledOp { a, b, c, ..base(op_divu) },
        Op::And => CompiledOp { a, b, c, ..base(op_and) },
        Op::Or => CompiledOp { a, b, c, ..base(op_or) },
        Op::Xor => CompiledOp { a, b, c, ..base(op_xor) },
        Op::Shl => CompiledOp { a, b, c, ..base(op_shl) },
        Op::Shr => CompiledOp { a, b, c, ..base(op_shr) },
        Op::Addi => CompiledOp { a, b, imm, ..base(op_addi) },
        Op::Sltu => CompiledOp { a, b, c, ..base(op_sltu) },
        Op::Eq => CompiledOp { a, b, c, ..base(op_eq) },
        Op::Jmp => CompiledOp { imm: target(i.imm, map, n), ..base(op_jmp) },
        Op::Jz => CompiledOp { a, imm: target(i.imm, map, n), ..base(op_jz) },
        Op::Jnz => CompiledOp { a, imm: target(i.imm, map, n), ..base(op_jnz) },
        Op::Call => CompiledOp { imm, ..base(op_call) },
        Op::Ldb => CompiledOp {
            a,
            b,
            c,
            imm,
            ..base(mem(op_ldb_pay, op_ldb_pay_fast, op_ldb_scr, op_ldb_scr_fast))
        },
        Op::Ldw => CompiledOp {
            a,
            b,
            c,
            imm,
            ..base(mem(op_ldw_pay, op_ldw_pay_fast, op_ldw_scr, op_ldw_scr_fast))
        },
        Op::Stb => CompiledOp {
            a,
            b,
            c,
            imm,
            ..base(mem(op_stb_pay, op_stb_pay_fast, op_stb_scr, op_stb_scr_fast))
        },
        Op::Stw => CompiledOp {
            a,
            b,
            c,
            imm,
            ..base(mem(op_stw_pay, op_stw_pay_fast, op_stw_scr, op_stw_scr_fast))
        },
        Op::Paylen => CompiledOp { a, ..base(op_paylen) },
    }
}

fn emit_fused(
    first: &Instr,
    second: &Instr,
    pc: u32,
    map: &[u32],
    n: usize,
    elide: bool,
) -> CompiledOp {
    let base = |h: Handler| CompiledOp::new(h, pc, 2);
    match (first.op, second.op) {
        (Op::Sltu, Op::Jz) => CompiledOp {
            a: first.a as usize,
            b: first.b as usize,
            c: first.c as usize,
            d: second.a as usize,
            imm2: target(second.imm, map, n),
            ..base(op_sltu_jz)
        },
        (Op::Ldb, Op::Add) => CompiledOp {
            a: first.a as usize,
            b: first.b as usize,
            c: first.c as usize,
            imm: first.imm as u64,
            d: second.a as usize,
            e: second.b as usize,
            f: second.c as usize,
            ..base(match (first.c == SPACE_PAYLOAD, elide) {
                (true, false) => op_ldb_add_pay,
                (true, true) => op_ldb_add_pay_fast,
                (false, false) => op_ldb_add_scr,
                (false, true) => op_ldb_add_scr_fast,
            })
        },
        (Op::Addi, Op::Jmp) => CompiledOp {
            a: first.a as usize,
            b: first.b as usize,
            imm: first.imm as u64,
            imm2: target(second.imm, map, n),
            ..base(op_addi_jmp)
        },
        // Constant-folded ldi64 — reuses the plain ldi handler.
        (Op::Ldi, Op::Ldih) => CompiledOp {
            a: first.a as usize,
            imm: ((second.imm as u64) << 32) | first.imm as u64,
            ..base(op_ldi)
        },
        _ => unreachable!("fusible() admitted a non-fusible pair"),
    }
}

impl CompiledProgram {
    /// Execute against `payload` in place — the drop-in replacement for
    /// the reference interpreter's `run`, with identical outcomes
    /// (return value, retired-step count, fault kind *and* message).
    pub fn run(
        &self,
        got: &GotTable,
        payload: &mut [u8],
        user: &mut dyn Any,
        cfg: &VmConfig,
    ) -> Result<VmOutcome> {
        let mut scratch =
            if self.uses_scratch { vec![0u8; cfg.scratch_bytes] } else { Vec::new() };
        // Analysis-elision guards: every unchecked handler was proven in
        // bounds *given* at least this much payload/scratch. A run that
        // cannot meet a guard (the sender controls payload length, the
        // host configures scratch) executes under reference semantics
        // instead — checked throughout, identical outcomes.
        if self.guard_pay > payload.len() as u64 || self.guard_scr > cfg.scratch_bytes as u64
        {
            let mut regs = [0u64; NUM_REGS];
            regs[1] = payload.len() as u64;
            let (ret, steps) = interp::run_from(
                &self.src,
                got,
                payload,
                &mut scratch,
                user,
                &mut regs,
                0,
                cfg.fuel,
            )?;
            return Ok(VmOutcome { ret, steps });
        }
        let mut m = Machine {
            regs: [0u64; NUM_REGS],
            fuel: cfg.fuel,
            payload,
            scratch: &mut scratch,
            user,
            got,
        };
        // Entry convention: r1 = payload length (see interp).
        m.regs[1] = m.payload.len() as u64;
        let mut ip = 0usize;
        // Loop-free program whose worst-case charge the budget covers:
        // no block can ever run dry, so skip the per-block comparison.
        // Fuel is still decremented — the retired-step accounting and
        // the trap's exhausted-vs-fell-off choice depend on it.
        if matches!(self.static_max_steps, Some(bound) if cfg.fuel >= bound) {
            loop {
                let op = &self.ops[ip];
                m.fuel -= op.block_cost as u64;
                ip = (op.handler)(op, ip, &mut m)?;
                if ip == HALT {
                    return Ok(VmOutcome { ret: m.regs[0], steps: cfg.fuel - m.fuel });
                }
            }
        }
        loop {
            let op = &self.ops[ip];
            if op.block_cost != 0 {
                let cost = op.block_cost as u64;
                if m.fuel < cost {
                    // Fuel runs dry inside this block: delegate to the
                    // reference stepper from the block's source pc so
                    // the fault carries the exact per-instruction pc.
                    // The machine state at a block boundary is identical
                    // to the reference's (charged == retired so far).
                    let done = cfg.fuel - m.fuel;
                    let (ret, steps) = interp::run_from(
                        &self.src,
                        m.got,
                        &mut *m.payload,
                        &mut *m.scratch,
                        &mut *m.user,
                        &mut m.regs,
                        op.orig_pc as usize,
                        m.fuel,
                    )?;
                    return Ok(VmOutcome { ret, steps: done + steps });
                }
                m.fuel -= cost;
            }
            ip = (op.handler)(op, ip, &mut m)?;
            if ip == HALT {
                // Every entered block fully retired, so charged == steps.
                return Ok(VmOutcome { ret: m.regs[0], steps: cfg.fuel - m.fuel });
            }
        }
    }

    /// The verified source program this was compiled from.
    pub fn src(&self) -> &[Instr] {
        &self.src
    }

    /// Superinstruction pairs the fusion pass formed.
    pub fn fused_pairs(&self) -> usize {
        self.fused
    }

    /// Basic blocks (fuel-charge points).
    pub fn blocks(&self) -> usize {
        self.blocks
    }

    /// Whether any op touches the scratch space (precomputed at compile
    /// time; decides the per-invocation scratch allocation).
    pub fn uses_scratch(&self) -> bool {
        self.uses_scratch
    }

    /// Compiled ops, excluding the trailing trap.
    pub fn op_count(&self) -> usize {
        self.ops.len() - 1
    }

    /// Memory ops lowered to unchecked handlers (0 unless built by
    /// [`compile_analyzed`]).
    pub fn elided_ops(&self) -> usize {
        self.elided
    }

    /// The loop-free worst-case fuel charge, when proven.
    pub fn static_max_steps(&self) -> Option<u64> {
        self.static_max_steps
    }

    /// The `(payload, scratch)` entry guards for elided accesses.
    pub fn guards(&self) -> (u64, u64) {
        (self.guard_pay, self.guard_scr)
    }
}

// ---- op handlers ---------------------------------------------------------

fn op_halt(_o: &CompiledOp, _ip: usize, _m: &mut Machine<'_>) -> Result<usize> {
    Ok(HALT)
}

fn op_nop(_o: &CompiledOp, ip: usize, _m: &mut Machine<'_>) -> Result<usize> {
    Ok(ip + 1)
}

fn op_ldi(o: &CompiledOp, ip: usize, m: &mut Machine<'_>) -> Result<usize> {
    m.regs[o.a] = o.imm;
    Ok(ip + 1)
}

fn op_ldih(o: &CompiledOp, ip: usize, m: &mut Machine<'_>) -> Result<usize> {
    m.regs[o.a] = o.imm | (m.regs[o.a] & 0xFFFF_FFFF);
    Ok(ip + 1)
}

fn op_mov(o: &CompiledOp, ip: usize, m: &mut Machine<'_>) -> Result<usize> {
    m.regs[o.a] = m.regs[o.b];
    Ok(ip + 1)
}

fn op_add(o: &CompiledOp, ip: usize, m: &mut Machine<'_>) -> Result<usize> {
    m.regs[o.a] = m.regs[o.b].wrapping_add(m.regs[o.c]);
    Ok(ip + 1)
}

fn op_sub(o: &CompiledOp, ip: usize, m: &mut Machine<'_>) -> Result<usize> {
    m.regs[o.a] = m.regs[o.b].wrapping_sub(m.regs[o.c]);
    Ok(ip + 1)
}

fn op_mul(o: &CompiledOp, ip: usize, m: &mut Machine<'_>) -> Result<usize> {
    m.regs[o.a] = m.regs[o.b].wrapping_mul(m.regs[o.c]);
    Ok(ip + 1)
}

fn op_divu(o: &CompiledOp, ip: usize, m: &mut Machine<'_>) -> Result<usize> {
    let d = m.regs[o.c];
    if d == 0 {
        return Err(Error::VmFault(format!("divide by zero at pc {}", o.orig_pc)));
    }
    m.regs[o.a] = m.regs[o.b] / d;
    Ok(ip + 1)
}

fn op_and(o: &CompiledOp, ip: usize, m: &mut Machine<'_>) -> Result<usize> {
    m.regs[o.a] = m.regs[o.b] & m.regs[o.c];
    Ok(ip + 1)
}

fn op_or(o: &CompiledOp, ip: usize, m: &mut Machine<'_>) -> Result<usize> {
    m.regs[o.a] = m.regs[o.b] | m.regs[o.c];
    Ok(ip + 1)
}

fn op_xor(o: &CompiledOp, ip: usize, m: &mut Machine<'_>) -> Result<usize> {
    m.regs[o.a] = m.regs[o.b] ^ m.regs[o.c];
    Ok(ip + 1)
}

fn op_shl(o: &CompiledOp, ip: usize, m: &mut Machine<'_>) -> Result<usize> {
    m.regs[o.a] = m.regs[o.b] << (m.regs[o.c] & 63);
    Ok(ip + 1)
}

fn op_shr(o: &CompiledOp, ip: usize, m: &mut Machine<'_>) -> Result<usize> {
    m.regs[o.a] = m.regs[o.b] >> (m.regs[o.c] & 63);
    Ok(ip + 1)
}

fn op_addi(o: &CompiledOp, ip: usize, m: &mut Machine<'_>) -> Result<usize> {
    m.regs[o.a] = m.regs[o.b].wrapping_add(o.imm);
    Ok(ip + 1)
}

fn op_sltu(o: &CompiledOp, ip: usize, m: &mut Machine<'_>) -> Result<usize> {
    m.regs[o.a] = (m.regs[o.b] < m.regs[o.c]) as u64;
    Ok(ip + 1)
}

fn op_eq(o: &CompiledOp, ip: usize, m: &mut Machine<'_>) -> Result<usize> {
    m.regs[o.a] = (m.regs[o.b] == m.regs[o.c]) as u64;
    Ok(ip + 1)
}

fn op_jmp(o: &CompiledOp, _ip: usize, _m: &mut Machine<'_>) -> Result<usize> {
    Ok(o.imm as usize)
}

fn op_jz(o: &CompiledOp, ip: usize, m: &mut Machine<'_>) -> Result<usize> {
    Ok(if m.regs[o.a] == 0 { o.imm as usize } else { ip + 1 })
}

fn op_jnz(o: &CompiledOp, ip: usize, m: &mut Machine<'_>) -> Result<usize> {
    Ok(if m.regs[o.a] != 0 { o.imm as usize } else { ip + 1 })
}

fn op_call(o: &CompiledOp, ip: usize, m: &mut Machine<'_>) -> Result<usize> {
    let got = m.got;
    let f = got
        .slot(o.imm as usize)
        .ok_or_else(|| Error::VmFault(format!("GOT slot {} not linked", o.imm)))?;
    let args = [m.regs[1], m.regs[2], m.regs[3], m.regs[4]];
    let mut ctx =
        HostCtx { payload: &mut *m.payload, scratch: &mut *m.scratch, user: &mut *m.user };
    m.regs[0] = f(&mut ctx, args).map_err(Error::VmFault)?;
    Ok(ip + 1)
}

fn op_paylen(o: &CompiledOp, ip: usize, m: &mut Machine<'_>) -> Result<usize> {
    m.regs[o.a] = m.payload.len() as u64;
    Ok(ip + 1)
}

/// Fall-off-the-code-end landing pad. Fuel is checked first, matching the
/// reference's loop-top order at `pc == len`.
fn op_trap(o: &CompiledOp, _ip: usize, m: &mut Machine<'_>) -> Result<usize> {
    Err(Error::VmFault(if m.fuel == 0 {
        format!("fuel exhausted at pc {}", o.orig_pc)
    } else {
        format!("execution fell off code end at pc {}", o.orig_pc)
    }))
}

// Memory ops, specialized per space. Fault messages mirror the reference
// byte for byte (`o.c` keeps the original space selector, `o.orig_pc` the
// faulting instruction's source pc).

fn mem_fault(store: bool, addr: usize, width: usize, space: usize, len: usize, pc: u32) -> Error {
    Error::VmFault(format!(
        "oob {} access at {addr}+{width} (space {space} of {len} bytes, pc {pc})",
        if store { "store" } else { "load" },
    ))
}

#[inline(always)]
fn load_b(mem: &[u8], addr: usize, space: usize, pc: u32) -> Result<u64> {
    match mem.get(addr) {
        Some(&v) => Ok(v as u64),
        None => Err(mem_fault(false, addr, 1, space, mem.len(), pc)),
    }
}

#[inline(always)]
fn load_w(mem: &[u8], addr: usize, space: usize, pc: u32) -> Result<u64> {
    match addr.checked_add(8).and_then(|end| mem.get(addr..end)) {
        Some(bytes) => Ok(u64::from_le_bytes(bytes.try_into().unwrap())),
        None => Err(mem_fault(false, addr, 8, space, mem.len(), pc)),
    }
}

#[inline(always)]
fn store_b(mem: &mut [u8], addr: usize, v: u64, space: usize, pc: u32) -> Result<()> {
    let len = mem.len();
    match mem.get_mut(addr) {
        Some(slot) => {
            *slot = v as u8;
            Ok(())
        }
        None => Err(mem_fault(true, addr, 1, space, len, pc)),
    }
}

#[inline(always)]
fn store_w(mem: &mut [u8], addr: usize, v: u64, space: usize, pc: u32) -> Result<()> {
    let len = mem.len();
    match addr.checked_add(8).and_then(|end| mem.get_mut(addr..end)) {
        Some(bytes) => {
            bytes.copy_from_slice(&v.to_le_bytes());
            Ok(())
        }
        None => Err(mem_fault(true, addr, 8, space, len, pc)),
    }
}

fn op_ldb_pay(o: &CompiledOp, ip: usize, m: &mut Machine<'_>) -> Result<usize> {
    let addr = m.regs[o.b].wrapping_add(o.imm) as usize;
    m.regs[o.a] = load_b(m.payload, addr, o.c, o.orig_pc)?;
    Ok(ip + 1)
}

fn op_ldb_scr(o: &CompiledOp, ip: usize, m: &mut Machine<'_>) -> Result<usize> {
    let addr = m.regs[o.b].wrapping_add(o.imm) as usize;
    m.regs[o.a] = load_b(m.scratch, addr, o.c, o.orig_pc)?;
    Ok(ip + 1)
}

fn op_ldw_pay(o: &CompiledOp, ip: usize, m: &mut Machine<'_>) -> Result<usize> {
    let addr = m.regs[o.b].wrapping_add(o.imm) as usize;
    m.regs[o.a] = load_w(m.payload, addr, o.c, o.orig_pc)?;
    Ok(ip + 1)
}

fn op_ldw_scr(o: &CompiledOp, ip: usize, m: &mut Machine<'_>) -> Result<usize> {
    let addr = m.regs[o.b].wrapping_add(o.imm) as usize;
    m.regs[o.a] = load_w(m.scratch, addr, o.c, o.orig_pc)?;
    Ok(ip + 1)
}

fn op_stb_pay(o: &CompiledOp, ip: usize, m: &mut Machine<'_>) -> Result<usize> {
    let addr = m.regs[o.b].wrapping_add(o.imm) as usize;
    store_b(m.payload, addr, m.regs[o.a], o.c, o.orig_pc)?;
    Ok(ip + 1)
}

fn op_stb_scr(o: &CompiledOp, ip: usize, m: &mut Machine<'_>) -> Result<usize> {
    let addr = m.regs[o.b].wrapping_add(o.imm) as usize;
    store_b(m.scratch, addr, m.regs[o.a], o.c, o.orig_pc)?;
    Ok(ip + 1)
}

fn op_stw_pay(o: &CompiledOp, ip: usize, m: &mut Machine<'_>) -> Result<usize> {
    let addr = m.regs[o.b].wrapping_add(o.imm) as usize;
    store_w(m.payload, addr, m.regs[o.a], o.c, o.orig_pc)?;
    Ok(ip + 1)
}

fn op_stw_scr(o: &CompiledOp, ip: usize, m: &mut Machine<'_>) -> Result<usize> {
    let addr = m.regs[o.b].wrapping_add(o.imm) as usize;
    store_w(m.scratch, addr, m.regs[o.a], o.c, o.orig_pc)?;
    Ok(ip + 1)
}

// Unchecked fast-path memory handlers, selected by `compile_analyzed`
// for ops whose address interval the analysis proved in bounds (and only
// run behind the entry guards in `run`). Plain indexing, no fault
// construction: a panic here would mean the analysis mis-proved a bound,
// which the differential property harness exists to catch.

fn op_ldb_pay_fast(o: &CompiledOp, ip: usize, m: &mut Machine<'_>) -> Result<usize> {
    let addr = m.regs[o.b].wrapping_add(o.imm) as usize;
    m.regs[o.a] = m.payload[addr] as u64;
    Ok(ip + 1)
}

fn op_ldb_scr_fast(o: &CompiledOp, ip: usize, m: &mut Machine<'_>) -> Result<usize> {
    let addr = m.regs[o.b].wrapping_add(o.imm) as usize;
    m.regs[o.a] = m.scratch[addr] as u64;
    Ok(ip + 1)
}

fn op_ldw_pay_fast(o: &CompiledOp, ip: usize, m: &mut Machine<'_>) -> Result<usize> {
    let addr = m.regs[o.b].wrapping_add(o.imm) as usize;
    m.regs[o.a] = u64::from_le_bytes(m.payload[addr..addr + 8].try_into().unwrap());
    Ok(ip + 1)
}

fn op_ldw_scr_fast(o: &CompiledOp, ip: usize, m: &mut Machine<'_>) -> Result<usize> {
    let addr = m.regs[o.b].wrapping_add(o.imm) as usize;
    m.regs[o.a] = u64::from_le_bytes(m.scratch[addr..addr + 8].try_into().unwrap());
    Ok(ip + 1)
}

fn op_stb_pay_fast(o: &CompiledOp, ip: usize, m: &mut Machine<'_>) -> Result<usize> {
    let addr = m.regs[o.b].wrapping_add(o.imm) as usize;
    m.payload[addr] = m.regs[o.a] as u8;
    Ok(ip + 1)
}

fn op_stb_scr_fast(o: &CompiledOp, ip: usize, m: &mut Machine<'_>) -> Result<usize> {
    let addr = m.regs[o.b].wrapping_add(o.imm) as usize;
    m.scratch[addr] = m.regs[o.a] as u8;
    Ok(ip + 1)
}

fn op_stw_pay_fast(o: &CompiledOp, ip: usize, m: &mut Machine<'_>) -> Result<usize> {
    let addr = m.regs[o.b].wrapping_add(o.imm) as usize;
    m.payload[addr..addr + 8].copy_from_slice(&m.regs[o.a].to_le_bytes());
    Ok(ip + 1)
}

fn op_stw_scr_fast(o: &CompiledOp, ip: usize, m: &mut Machine<'_>) -> Result<usize> {
    let addr = m.regs[o.b].wrapping_add(o.imm) as usize;
    m.scratch[addr..addr + 8].copy_from_slice(&m.regs[o.a].to_le_bytes());
    Ok(ip + 1)
}

// Superinstruction handlers.

fn op_sltu_jz(o: &CompiledOp, ip: usize, m: &mut Machine<'_>) -> Result<usize> {
    m.regs[o.a] = (m.regs[o.b] < m.regs[o.c]) as u64;
    Ok(if m.regs[o.d] == 0 { o.imm2 as usize } else { ip + 1 })
}

fn op_ldb_add_pay(o: &CompiledOp, ip: usize, m: &mut Machine<'_>) -> Result<usize> {
    let addr = m.regs[o.b].wrapping_add(o.imm) as usize;
    m.regs[o.a] = load_b(m.payload, addr, o.c, o.orig_pc)?;
    m.regs[o.d] = m.regs[o.e].wrapping_add(m.regs[o.f]);
    Ok(ip + 1)
}

fn op_ldb_add_scr(o: &CompiledOp, ip: usize, m: &mut Machine<'_>) -> Result<usize> {
    let addr = m.regs[o.b].wrapping_add(o.imm) as usize;
    m.regs[o.a] = load_b(m.scratch, addr, o.c, o.orig_pc)?;
    m.regs[o.d] = m.regs[o.e].wrapping_add(m.regs[o.f]);
    Ok(ip + 1)
}

fn op_ldb_add_pay_fast(o: &CompiledOp, ip: usize, m: &mut Machine<'_>) -> Result<usize> {
    let addr = m.regs[o.b].wrapping_add(o.imm) as usize;
    m.regs[o.a] = m.payload[addr] as u64;
    m.regs[o.d] = m.regs[o.e].wrapping_add(m.regs[o.f]);
    Ok(ip + 1)
}

fn op_ldb_add_scr_fast(o: &CompiledOp, ip: usize, m: &mut Machine<'_>) -> Result<usize> {
    let addr = m.regs[o.b].wrapping_add(o.imm) as usize;
    m.regs[o.a] = m.scratch[addr] as u64;
    m.regs[o.d] = m.regs[o.e].wrapping_add(m.regs[o.f]);
    Ok(ip + 1)
}

fn op_addi_jmp(o: &CompiledOp, _ip: usize, m: &mut Machine<'_>) -> Result<usize> {
    m.regs[o.a] = m.regs[o.b].wrapping_add(o.imm);
    Ok(o.imm2 as usize)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vm::got::SymbolTable;
    use crate::vm::interp::run_reference;
    use crate::vm::verify::verify;
    use crate::vm::Assembler;
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::Arc;

    fn ins(op: Op, a: u8, b: u8, c: u8, imm: u32) -> Instr {
        Instr { op, a, b, c, imm }
    }

    /// Encode raw instructions and push them through the verifier, so the
    /// tests exercise exactly what production compiles.
    fn verified(instrs: &[Instr], n_imports: usize) -> Vec<Instr> {
        let bytes: Vec<u8> = instrs.iter().flat_map(|i| i.encode()).collect();
        verify(&bytes, n_imports).expect("test program must verify")
    }

    /// Run both engines on copies of `payload` and assert bit-identical
    /// results: outcome or full fault message, plus final payload bytes.
    fn assert_conformant(
        prog: &[Instr],
        got: &GotTable,
        payload: &[u8],
        cfg: &VmConfig,
    ) -> Option<VmOutcome> {
        let compiled = compile(prog.to_vec());
        let mut p_ref = payload.to_vec();
        let mut p_cmp = payload.to_vec();
        let r = run_reference(prog, got, &mut p_ref, &mut (), cfg);
        let c = compiled.run(got, &mut p_cmp, &mut (), cfg);
        assert_eq!(p_ref, p_cmp, "payload mutation diverged");
        match (r, c) {
            (Ok(a), Ok(b)) => {
                assert_eq!(a, b, "outcome diverged");
                Some(a)
            }
            (Err(a), Err(b)) => {
                assert_eq!(a.to_string(), b.to_string(), "fault diverged");
                None
            }
            (a, b) => panic!("engines disagree: reference {a:?} vs compiled {b:?}"),
        }
    }

    /// The checksum loop body (same shape as ChecksumIfunc / the interp
    /// loop test): all three control-flow fusion patterns in one block.
    fn checksum_prog() -> Vec<Instr> {
        verified(
            &[
                ins(Op::Paylen, 3, 0, 0, 0),
                ins(Op::Ldi, 2, 0, 0, 0),
                ins(Op::Ldi, 0, 0, 0, 0),
                ins(Op::Sltu, 5, 2, 3, 0), // top
                ins(Op::Jz, 5, 0, 0, 9),
                ins(Op::Ldb, 6, 2, 0, 0),
                ins(Op::Add, 0, 0, 6, 0),
                ins(Op::Addi, 2, 2, 0, 1),
                ins(Op::Jmp, 0, 0, 0, 3),
                ins(Op::Halt, 0, 0, 0, 0), // done
            ],
            0,
        )
    }

    #[test]
    fn checksum_loop_fuses_all_three_pairs() {
        let prog = checksum_prog();
        let compiled = compile(prog.clone());
        // sltu+jz, ldb+add, addi+jmp — and nothing else.
        assert_eq!(compiled.fused_pairs(), 3);
        assert_eq!(compiled.op_count(), 10 - 3);
        // Blocks: [0..3), [3..5) fused, [5..9) fused×2, [9].
        assert_eq!(compiled.blocks(), 4);
        let got = GotTable::empty();
        let out =
            assert_conformant(&prog, &got, &[1, 2, 3, 4, 5], &VmConfig::default()).unwrap();
        assert_eq!(out.ret, 15);
        assert_eq!(out.steps, 36, "3 entry + 5 iters of 6 + final test of 2 + halt");
    }

    #[test]
    fn unfused_compile_matches_too() {
        let prog = checksum_prog();
        let unfused = compile_unfused(prog.clone());
        assert_eq!(unfused.fused_pairs(), 0);
        assert_eq!(unfused.op_count(), 10);
        let got = GotTable::empty();
        let out = unfused
            .run(&got, &mut [9u8, 9, 9], &mut (), &VmConfig::default())
            .unwrap();
        assert_eq!(out.ret, 27);
        assert_eq!(out.steps, 3 + 3 * 6 + 2 + 1);
    }

    #[test]
    fn branch_target_between_pair_halves_blocks_fusion() {
        // pc 0 jumps straight to pc 3 — the second half of the would-be
        // ldb+add pair at (2,3). Fusion must not form, and entry at the
        // add must see r6 untouched by the ldb.
        let prog = verified(
            &[
                ins(Op::Jz, 1, 0, 0, 3), // r1 = paylen: empty payload jumps
                ins(Op::Ldi, 6, 0, 0, 5),
                ins(Op::Ldb, 6, 0, 0, 0), // r6 = payload[r0]
                ins(Op::Add, 0, 6, 6, 0), // r0 = 2 * r6
                ins(Op::Halt, 0, 0, 0, 0),
            ],
            0,
        );
        let compiled = compile(prog.clone());
        assert_eq!(compiled.fused_pairs(), 0, "pc 3 is a jump target");
        let got = GotTable::empty();
        // Fall-through path: r6 = payload[0] = 21 → r0 = 42.
        let out = assert_conformant(&prog, &got, &[21], &VmConfig::default()).unwrap();
        assert_eq!(out.ret, 42);
        // Jump path (empty payload): lands on the bare add, r6 = 0.
        let out = assert_conformant(&prog, &got, &[], &VmConfig::default()).unwrap();
        assert_eq!(out.ret, 0);

        // Control: the same body without the entry branch does fuse.
        let control = verified(
            &[
                ins(Op::Ldi, 6, 0, 0, 5),
                ins(Op::Ldb, 6, 0, 0, 0),
                ins(Op::Add, 0, 6, 6, 0),
                ins(Op::Halt, 0, 0, 0, 0),
            ],
            0,
        );
        assert_eq!(compile(control.clone()).fused_pairs(), 1);
        let out = assert_conformant(&control, &got, &[21], &VmConfig::default()).unwrap();
        assert_eq!(out.ret, 42);
    }

    #[test]
    fn ldi64_fuses_only_on_same_register() {
        // Assembler ldi64 = ldi + ldih on one register: constant-folds.
        let mut a = Assembler::new();
        a.ldi64(2, 0x1111_2222_3333_4444);
        a.mov(0, 2);
        a.halt();
        let (code, imports) = a.assemble();
        let prog = verify(&code, imports.len()).unwrap();
        let compiled = compile(prog.clone());
        assert_eq!(compiled.fused_pairs(), 1);
        let got = GotTable::empty();
        let out = assert_conformant(&prog, &got, &[], &VmConfig::default()).unwrap();
        assert_eq!(out.ret, 0x1111_2222_3333_4444);

        // Different destination registers: NOT a ldi64, must not fuse.
        let split = verified(
            &[
                ins(Op::Ldi, 1, 0, 0, 0xAAAA),
                ins(Op::Ldih, 2, 0, 0, 0xBBBB),
                ins(Op::Mov, 0, 2, 0, 0),
                ins(Op::Halt, 0, 0, 0, 0),
            ],
            0,
        );
        assert_eq!(compile(split.clone()).fused_pairs(), 0);
        let out = assert_conformant(&split, &got, &[], &VmConfig::default()).unwrap();
        assert_eq!(out.ret, 0xBBBB_u64 << 32);
    }

    /// Block-fuel boundary sweep: for every fuel value through the whole
    /// run of the checksum loop, the compiled engine must return the
    /// *identical* result — same outcome, or a fuel fault with the same
    /// per-instruction pc the reference reports (this is what the
    /// precise-fallback delegation guarantees).
    #[test]
    fn fuel_exhaustion_mid_block_reports_reference_pc() {
        let prog = checksum_prog();
        let got = GotTable::empty();
        let payload = [1u8, 2, 3, 4, 5];
        let full = assert_conformant(&prog, &got, &payload, &VmConfig::default())
            .unwrap()
            .steps;
        assert_eq!(full, 36);
        for fuel in 0..=full + 2 {
            let cfg = VmConfig { fuel, scratch_bytes: 0 };
            let out = assert_conformant(&prog, &got, &payload, &cfg);
            // Exactly the runs with the full budget (or more) succeed —
            // a block never over-runs the budget.
            assert_eq!(out.is_some(), fuel >= full, "fuel {fuel}");
        }
    }

    /// Side-effect accounting under partial fuel: a GOT call inside the
    /// loop body must have fired exactly as many times under the
    /// compiled engine as under the reference, for every budget. Blocks
    /// are charged up front, but effects only happen for instructions
    /// that actually retire.
    #[test]
    fn partial_fuel_retires_identical_side_effects() {
        let syms = SymbolTable::new();
        let n_ref = Arc::new(AtomicU64::new(0));
        let n_cmp = Arc::new(AtomicU64::new(0));
        let (a1, a2) = (n_ref.clone(), n_cmp.clone());
        syms.install_fn("tick_ref", move |_, _| Ok(a1.fetch_add(1, Ordering::Relaxed)));
        syms.install_fn("tick_cmp", move |_, _| Ok(a2.fetch_add(1, Ordering::Relaxed)));
        // top: call slot0 ; jmp top — a 2-instruction block, forever.
        let prog = verified(
            &[ins(Op::Call, 0, 0, 0, 0), ins(Op::Jmp, 0, 0, 0, 0)],
            1,
        );
        let compiled = compile(prog.clone());
        for fuel in 0..16u64 {
            let cfg = VmConfig { fuel, scratch_bytes: 0 };
            n_ref.store(0, Ordering::Relaxed);
            n_cmp.store(0, Ordering::Relaxed);
            let got_ref = syms.resolve(&["tick_ref".into()]).unwrap();
            let got_cmp = syms.resolve(&["tick_cmp".into()]).unwrap();
            let e1 = run_reference(&prog, &got_ref, &mut [], &mut (), &cfg).unwrap_err();
            let e2 = compiled.run(&got_cmp, &mut [], &mut (), &cfg).unwrap_err();
            assert_eq!(e1.to_string(), e2.to_string(), "fuel {fuel}");
            assert_eq!(
                n_ref.load(Ordering::Relaxed),
                n_cmp.load(Ordering::Relaxed),
                "fuel {fuel}: call count diverged"
            );
        }
    }

    #[test]
    fn uses_scratch_is_precomputed() {
        let scratchy = verified(
            &[
                ins(Op::Ldi, 1, 0, 0, 0xAB),
                ins(Op::Ldi, 2, 0, 0, 128),
                ins(Op::Stb, 1, 2, 1, 0),
                ins(Op::Ldb, 0, 2, 1, 0),
                ins(Op::Halt, 0, 0, 0, 0),
            ],
            0,
        );
        let compiled = compile(scratchy.clone());
        assert!(compiled.uses_scratch());
        let got = GotTable::empty();
        let out = assert_conformant(&scratchy, &got, &[], &VmConfig::default()).unwrap();
        assert_eq!(out.ret, 0xAB, "scratch is zeroed and writable");

        let plain = verified(&[ins(Op::Halt, 0, 0, 0, 0)], 0);
        assert!(!compile(plain).uses_scratch());
    }

    #[test]
    fn empty_and_fall_off_end_match_reference() {
        let got = GotTable::empty();
        // Empty program (compile() must stay total for the cache tests).
        let empty = compile(Vec::new());
        let err = empty.run(&got, &mut [], &mut (), &VmConfig::default()).unwrap_err();
        assert!(err.to_string().contains("fell off code end at pc 0"), "{err}");
        // Straight-line code without a terminator runs off the end.
        let prog = verified(&[ins(Op::Ldi, 1, 0, 0, 7)], 0);
        assert_conformant(&prog, &got, &[], &VmConfig::default());
        // ... and with fuel exactly 1, the trap reports exhaustion.
        assert_conformant(&prog, &got, &[], &VmConfig { fuel: 1, scratch_bytes: 0 });
    }

    /// Like `assert_conformant`, but against the analyzed/elided build —
    /// the fast path and its guard fallback must match the reference
    /// byte for byte too.
    fn assert_analyzed_conformant(
        prog: &[Instr],
        got: &GotTable,
        payload: &[u8],
        cfg: &VmConfig,
    ) -> Option<VmOutcome> {
        let facts = crate::vm::analysis::analyze(prog);
        let compiled = compile_analyzed(prog.to_vec(), &facts);
        let mut p_ref = payload.to_vec();
        let mut p_cmp = payload.to_vec();
        let r = run_reference(prog, got, &mut p_ref, &mut (), cfg);
        let c = compiled.run(got, &mut p_cmp, &mut (), cfg);
        assert_eq!(p_ref, p_cmp, "payload mutation diverged");
        match (r, c) {
            (Ok(a), Ok(b)) => {
                assert_eq!(a, b, "outcome diverged");
                Some(a)
            }
            (Err(a), Err(b)) => {
                assert_eq!(a.to_string(), b.to_string(), "fault diverged");
                None
            }
            (a, b) => panic!("engines disagree: reference {a:?} vs analyzed {b:?}"),
        }
    }

    #[test]
    fn analyzed_header_reader_elides_and_matches() {
        // Fixed-offset header reads — the builtin-ifunc shape the
        // elision is aimed at.
        let prog = verified(
            &[
                ins(Op::Ldw, 2, 0, 0, 0),
                ins(Op::Ldw, 3, 0, 0, 8),
                ins(Op::Add, 0, 2, 3, 0),
                ins(Op::Halt, 0, 0, 0, 0),
            ],
            0,
        );
        let facts = crate::vm::analysis::analyze(&prog);
        let compiled = compile_analyzed(prog.clone(), &facts);
        assert_eq!(compiled.elided_ops(), 2);
        assert_eq!(compiled.static_max_steps(), Some(4));
        assert_eq!(compiled.guards(), (16, 0));
        let got = GotTable::empty();
        let mut payload = [0u8; 16];
        payload[0] = 7;
        payload[8] = 35;
        let out =
            assert_analyzed_conformant(&prog, &got, &payload, &VmConfig::default()).unwrap();
        assert_eq!(out.ret, 42);
        assert_eq!(out.steps, 4);
        // Short payload: the entry guard fails and the whole run falls
        // back to reference semantics — identical oob fault message.
        assert!(assert_analyzed_conformant(&prog, &got, &[0u8; 10], &VmConfig::default())
            .is_none());
        // Fuel sweep across the static-skip threshold: accounting and
        // exhaustion messages must stay identical on both loop variants.
        for fuel in 0..6 {
            assert_analyzed_conformant(
                &prog,
                &got,
                &payload,
                &VmConfig { fuel, scratch_bytes: 0 },
            );
        }
    }

    #[test]
    fn analyzed_loop_keeps_checks_and_matches() {
        let prog = checksum_prog();
        let facts = crate::vm::analysis::analyze(&prog);
        let compiled = compile_analyzed(prog.clone(), &facts);
        assert_eq!(compiled.static_max_steps(), None, "loops keep fuel checks");
        assert_eq!(compiled.elided_ops(), 0, "loop-indexed access stays checked");
        let got = GotTable::empty();
        for fuel in 0..40 {
            assert_analyzed_conformant(
                &prog,
                &got,
                &[1, 2, 3, 4, 5],
                &VmConfig { fuel, scratch_bytes: 0 },
            );
        }
    }

    #[test]
    fn analyzed_scratch_guard_respects_configured_size() {
        // scratch[128] elides against the 64 KiB architectural cap, but
        // a smaller configured scratch must take the checked fallback
        // (and fault identically to the reference).
        let prog = verified(
            &[
                ins(Op::Ldi, 1, 0, 0, 0xAB),
                ins(Op::Ldi, 2, 0, 0, 128),
                ins(Op::Stb, 1, 2, 1, 0),
                ins(Op::Ldb, 0, 2, 1, 0),
                ins(Op::Halt, 0, 0, 0, 0),
            ],
            0,
        );
        let facts = crate::vm::analysis::analyze(&prog);
        let compiled = compile_analyzed(prog.clone(), &facts);
        assert_eq!(compiled.elided_ops(), 2);
        assert_eq!(compiled.guards().1, 129);
        let got = GotTable::empty();
        let out =
            assert_analyzed_conformant(&prog, &got, &[], &VmConfig::default()).unwrap();
        assert_eq!(out.ret, 0xAB);
        for scratch_bytes in [0usize, 64, 129] {
            assert_analyzed_conformant(
                &prog,
                &got,
                &[],
                &VmConfig { fuel: 1000, scratch_bytes },
            );
        }
    }

    #[test]
    fn oob_and_div0_faults_match_reference_messages() {
        let got = GotTable::empty();
        let oob = verified(
            &[
                ins(Op::Ldi, 2, 0, 0, 100),
                ins(Op::Ldb, 0, 2, 0, 0),
                ins(Op::Halt, 0, 0, 0, 0),
            ],
            0,
        );
        assert_conformant(&oob, &got, &[0u8; 4], &VmConfig::default());
        let div0 = verified(
            &[
                ins(Op::Ldi, 1, 0, 0, 10),
                ins(Op::Divu, 0, 1, 2, 0),
                ins(Op::Halt, 0, 0, 0, 0),
            ],
            0,
        );
        assert_conformant(&div0, &got, &[], &VmConfig::default());
    }
}
