//! TCVM instruction set.
//!
//! The paper injects native Arm64 `.text` whose GOT accesses were rewritten
//! by a toolchain script to go through an indirection table shipped in the
//! message (§3.4). Shipping raw machine code is neither safe nor portable
//! here, and the paper itself lists "make this step
//! target-process-architecture agnostic" as future work — so the code
//! section of our ifunc messages is **TCVM bytecode**: a fixed-width
//! register ISA whose only way to touch the outside world is a `CALL`
//! through a GOT slot that the *target* patches at link time. The
//! mechanism under test (code travels with the message; target performs
//! relocation before invocation) is preserved one-for-one.
//!
//! Encoding: every instruction is 8 bytes, little-endian:
//!
//! ```text
//!   byte 0   opcode
//!   byte 1   a   (register, 0..16)
//!   byte 2   b   (register)
//!   byte 3   c   (register or memory-space selector)
//!   byte 4-7 imm (u32)
//! ```

/// Number of general-purpose registers.
pub const NUM_REGS: usize = 16;

/// Instruction width in bytes.
pub const INSTR_BYTES: usize = 8;

/// Hard cap on code size (instructions) accepted by the verifier. Keeps a
/// hostile sender from shipping pathological frames (§3.5).
pub const MAX_INSTRS: usize = 1 << 14;

/// Memory-space selector values for LD/ST (the `c` field).
pub const SPACE_PAYLOAD: u8 = 0;
pub const SPACE_SCRATCH: u8 = 1;

/// Scratch memory available to each invocation, zeroed per call.
pub const SCRATCH_BYTES: usize = 1 << 16;

/// Opcodes. Arithmetic is wrapping (no traps); faults come only from
/// memory bounds, bad GOT slots, division by zero, and fuel exhaustion.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Op {
    /// Stop successfully; result value is `r0`.
    Halt = 0x00,
    /// `ra = imm` (zero-extended).
    Ldi = 0x01,
    /// `ra = (imm << 32) | (ra & 0xffff_ffff)` — load the high half.
    Ldih = 0x02,
    /// `ra = rb`.
    Mov = 0x03,
    /// `ra = rb + rc`.
    Add = 0x04,
    Sub = 0x05,
    Mul = 0x06,
    /// Unsigned divide; divide-by-zero faults.
    Divu = 0x07,
    And = 0x08,
    Or = 0x09,
    Xor = 0x0A,
    /// `ra = rb << (rc & 63)`.
    Shl = 0x0B,
    Shr = 0x0C,
    /// `ra = rb + imm` (imm zero-extended).
    Addi = 0x0D,
    /// `ra = (rb < rc) as u64` (unsigned).
    Sltu = 0x0E,
    /// `ra = (rb == rc) as u64`.
    Eq = 0x0F,
    /// Unconditional jump to instruction index `imm`.
    Jmp = 0x10,
    /// Jump to `imm` if `ra == 0`.
    Jz = 0x11,
    /// Jump to `imm` if `ra != 0`.
    Jnz = 0x12,
    /// Call GOT slot `imm` with args `r1..r4`; result in `r0`. This is the
    /// *only* escape hatch from the sandbox — the exact analog of the
    /// paper's GOT-indirected external calls.
    Call = 0x13,
    /// `ra = zx(space_c[rb + imm] : u8)`.
    Ldb = 0x14,
    /// `ra = space_c[rb + imm] : u64` (little-endian, unaligned ok).
    Ldw = 0x15,
    /// `space_c[rb + imm] = ra as u8`.
    Stb = 0x16,
    /// `space_c[rb + imm] = ra : u64`.
    Stw = 0x17,
    /// `ra = payload length in bytes`.
    Paylen = 0x18,
    Nop = 0x19,
}

impl Op {
    pub fn from_u8(v: u8) -> Option<Op> {
        Some(match v {
            0x00 => Op::Halt,
            0x01 => Op::Ldi,
            0x02 => Op::Ldih,
            0x03 => Op::Mov,
            0x04 => Op::Add,
            0x05 => Op::Sub,
            0x06 => Op::Mul,
            0x07 => Op::Divu,
            0x08 => Op::And,
            0x09 => Op::Or,
            0x0A => Op::Xor,
            0x0B => Op::Shl,
            0x0C => Op::Shr,
            0x0D => Op::Addi,
            0x0E => Op::Sltu,
            0x0F => Op::Eq,
            0x10 => Op::Jmp,
            0x11 => Op::Jz,
            0x12 => Op::Jnz,
            0x13 => Op::Call,
            0x14 => Op::Ldb,
            0x15 => Op::Ldw,
            0x16 => Op::Stb,
            0x17 => Op::Stw,
            0x18 => Op::Paylen,
            0x19 => Op::Nop,
            _ => return None,
        })
    }
}

/// A decoded instruction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Instr {
    pub op: Op,
    pub a: u8,
    pub b: u8,
    pub c: u8,
    pub imm: u32,
}

impl Instr {
    /// Does this instruction address the scratch space? (Any load/store
    /// whose space selector is not the payload.) Both the reference
    /// interpreter and the compiler use this to decide whether an
    /// invocation needs a zeroed scratch allocation at all.
    pub fn touches_scratch(&self) -> bool {
        matches!(self.op, Op::Ldb | Op::Ldw | Op::Stb | Op::Stw) && self.c != SPACE_PAYLOAD
    }

    pub fn encode(&self) -> [u8; INSTR_BYTES] {
        let mut out = [0u8; INSTR_BYTES];
        out[0] = self.op as u8;
        out[1] = self.a;
        out[2] = self.b;
        out[3] = self.c;
        out[4..8].copy_from_slice(&self.imm.to_le_bytes());
        out
    }

    pub fn decode(bytes: &[u8]) -> Option<Instr> {
        if bytes.len() < INSTR_BYTES {
            return None;
        }
        Some(Instr {
            op: Op::from_u8(bytes[0])?,
            a: bytes[1],
            b: bytes[2],
            c: bytes[3],
            imm: u32::from_le_bytes(bytes[4..8].try_into().unwrap()),
        })
    }
}

/// Decode a full code section. Returns `None` on any undecodable
/// instruction or a length that is not a multiple of the instruction width.
pub fn decode_all(code: &[u8]) -> Option<Vec<Instr>> {
    if code.len() % INSTR_BYTES != 0 {
        return None;
    }
    code.chunks_exact(INSTR_BYTES).map(Instr::decode).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_every_opcode() {
        for v in 0u8..=0x19 {
            let op = Op::from_u8(v).unwrap();
            let i = Instr { op, a: 1, b: 2, c: 3, imm: 0xDEAD_BEEF };
            assert_eq!(Instr::decode(&i.encode()), Some(i));
        }
    }

    #[test]
    fn unknown_opcode_rejected() {
        assert_eq!(Op::from_u8(0xFF), None);
        let mut bytes = [0u8; 8];
        bytes[0] = 0x7F;
        assert_eq!(Instr::decode(&bytes), None);
    }

    #[test]
    fn decode_all_requires_multiple_of_width() {
        assert!(decode_all(&[0u8; 7]).is_none());
        assert_eq!(decode_all(&[0u8; 16]).unwrap().len(), 2);
    }
}
