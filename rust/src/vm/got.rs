//! GOT tables and symbol resolution — the target side of remote linking.
//!
//! The paper's target process "should perform work similar to a dynamic
//! linker: construct a GOT that has all the relocations needed by the
//! ifunc code in the correct offsets" (§3.4). Here that is literal: the
//! shipped code image carries an ordered import-name table; the target
//! resolves each name against its local [`SymbolTable`] (the analog of the
//! process's own loaded libraries), producing a [`GotTable`] of callable
//! bindings in slot order. `CALL slot` in the bytecode indexes this table.

use std::any::Any;
use std::collections::HashMap;
use std::sync::{Arc, RwLock};

use crate::{Error, Result};

/// Execution context handed to host bindings: the message's payload (in
/// place, in the ring buffer), per-invocation scratch, and the
/// `target_args` pointer of `ucp_poll_ifunc` (type-erased).
pub struct HostCtx<'a> {
    pub payload: &'a mut [u8],
    pub scratch: &'a mut [u8],
    pub user: &'a mut dyn Any,
}

/// A resolved GOT entry: a host function callable from injected code.
/// Args are `r1..r4`; the return value lands in `r0`.
pub type HostFn =
    Arc<dyn Fn(&mut HostCtx, [u64; 4]) -> std::result::Result<u64, String> + Send + Sync>;

/// The target process's symbol table — the union of "libraries resident in
/// the target system" that injected code may link against (§2.1).
#[derive(Default, Clone)]
pub struct SymbolTable {
    syms: Arc<RwLock<HashMap<String, HostFn>>>,
}

impl SymbolTable {
    pub fn new() -> Self {
        Self::default()
    }

    /// Install (or replace) a named symbol.
    pub fn install(&self, name: &str, f: HostFn) {
        self.syms.write().unwrap().insert(name.to_string(), f);
    }

    /// Install a plain closure.
    pub fn install_fn<F>(&self, name: &str, f: F)
    where
        F: Fn(&mut HostCtx, [u64; 4]) -> std::result::Result<u64, String> + Send + Sync + 'static,
    {
        self.install(name, Arc::new(f));
    }

    pub fn lookup(&self, name: &str) -> Option<HostFn> {
        self.syms.read().unwrap().get(name).cloned()
    }

    pub fn contains(&self, name: &str) -> bool {
        self.syms.read().unwrap().contains_key(name)
    }

    pub fn names(&self) -> Vec<String> {
        self.syms.read().unwrap().keys().cloned().collect()
    }

    /// Resolve an ordered import list into a GOT. Fails with the missing
    /// symbol's name — the analog of a dynamic-linker unresolved-symbol
    /// error at ifunc link time.
    pub fn resolve(&self, imports: &[String]) -> Result<GotTable> {
        self.resolve_iter(imports.iter().map(String::as_str))
    }

    /// Borrowed-name variant used by the poll hot path.
    pub fn resolve_iter<'a>(
        &self,
        imports: impl IntoIterator<Item = &'a str>,
    ) -> Result<GotTable> {
        let syms = self.syms.read().unwrap();
        let mut entries = Vec::new();
        for name in imports {
            let f = syms
                .get(name)
                .cloned()
                .ok_or_else(|| Error::VmFault(format!("unresolved symbol: {name}")))?;
            entries.push(f);
        }
        Ok(GotTable { entries: Arc::new(entries) })
    }
}

/// A constructed GOT: slot-indexed bindings, cheap to clone, cached per
/// ifunc name by the auto-registration table (§3.4's hash table).
#[derive(Clone)]
pub struct GotTable {
    entries: Arc<Vec<HostFn>>,
}

impl std::fmt::Debug for GotTable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "GotTable({} slots)", self.entries.len())
    }
}

impl GotTable {
    pub fn empty() -> Self {
        GotTable { entries: Arc::new(Vec::new()) }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn slot(&self, i: usize) -> Option<&HostFn> {
        self.entries.get(i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolve_in_slot_order() {
        let t = SymbolTable::new();
        t.install_fn("a", |_, _| Ok(1));
        t.install_fn("b", |_, _| Ok(2));
        let got = t.resolve(&["b".into(), "a".into()]).unwrap();
        let mut scratch = [0u8; 0];
        let mut payload = [0u8; 0];
        let mut user = ();
        let mut ctx = HostCtx { payload: &mut payload, scratch: &mut scratch, user: &mut user };
        assert_eq!(got.slot(0).unwrap()(&mut ctx, [0; 4]).unwrap(), 2);
        assert_eq!(got.slot(1).unwrap()(&mut ctx, [0; 4]).unwrap(), 1);
    }

    #[test]
    fn unresolved_symbol_is_an_error() {
        let t = SymbolTable::new();
        let err = t.resolve(&["missing".into()]).unwrap_err();
        assert!(err.to_string().contains("unresolved symbol: missing"));
    }

    #[test]
    fn install_replaces_binding() {
        // The paper: "the code can be modified anytime under the same ifunc
        // name" — and equally, target symbols can be re-bound at runtime.
        let t = SymbolTable::new();
        t.install_fn("f", |_, _| Ok(1));
        t.install_fn("f", |_, _| Ok(9));
        let got = t.resolve(&["f".into()]).unwrap();
        let mut ctx = HostCtx { payload: &mut [], scratch: &mut [], user: &mut () };
        assert_eq!(got.slot(0).unwrap()(&mut ctx, [0; 4]).unwrap(), 9);
    }
}
