//! Static bytecode verifier — the §3.5 security mitigation for code that
//! crosses trust boundaries.
//!
//! The paper leans on RKEY-based transport authorization and leaves a full
//! security model to future work; because our injected code is bytecode
//! rather than native text, we can go further and *statically verify*
//! every frame before invocation:
//!
//! * every opcode decodes,
//! * every register field used by the opcode is `< NUM_REGS`,
//! * every memory-space selector is payload or scratch,
//! * every jump / branch target is inside the code section,
//! * every `CALL` slot is inside the import table,
//! * the code section is non-empty and below [`MAX_INSTRS`].
//!
//! Dynamic properties (payload bounds, fuel) are enforced by the
//! interpreter at run time.

use super::disasm::disasm_instr;
use super::isa::{
    decode_all, Instr, Op, INSTR_BYTES, MAX_INSTRS, NUM_REGS, SPACE_PAYLOAD, SPACE_SCRATCH,
};
use crate::{Error, Result};

/// Verify a raw code section against an import table of `n_imports` names.
/// Returns the decoded program on success so callers decode exactly once.
pub fn verify(code: &[u8], n_imports: usize) -> Result<Vec<Instr>> {
    if code.is_empty() {
        return Err(Error::Verify("empty code section".into()));
    }
    let instrs = decode_all(code)
        .ok_or_else(|| Error::Verify("undecodable instruction or truncated code".into()))?;
    if instrs.len() > MAX_INSTRS {
        return Err(Error::Verify(format!(
            "code too long: {} instructions (max {MAX_INSTRS})",
            instrs.len()
        )));
    }
    for (pc, i) in instrs.iter().enumerate() {
        check_instr(pc, i, instrs.len(), n_imports)?;
    }
    Ok(instrs)
}

/// Build a `Verify` error that locates the instruction (pc + byte
/// offset) and shows its disassembly next to the specific violation.
fn fail(pc: usize, i: &Instr, what: impl std::fmt::Display) -> Error {
    Error::Verify(format!(
        "pc {pc} (offset {:#x}): `{}`: {what}",
        pc * INSTR_BYTES,
        disasm_instr(i, None)
    ))
}

fn reg(pc: usize, i: &Instr, r: u8) -> Result<()> {
    if (r as usize) < NUM_REGS {
        Ok(())
    } else {
        Err(fail(pc, i, format_args!("register r{r} out of range")))
    }
}

fn space(pc: usize, i: &Instr, s: u8) -> Result<()> {
    if s == SPACE_PAYLOAD || s == SPACE_SCRATCH {
        Ok(())
    } else {
        Err(fail(pc, i, format_args!("invalid memory space {s}")))
    }
}

fn target(pc: usize, i: &Instr, imm: u32, n: usize) -> Result<()> {
    if (imm as usize) < n {
        Ok(())
    } else {
        Err(fail(pc, i, format_args!("jump target {imm} outside code of {n} instrs")))
    }
}

fn check_instr(pc: usize, i: &Instr, n: usize, n_imports: usize) -> Result<()> {
    match i.op {
        Op::Halt | Op::Nop => Ok(()),
        Op::Ldi | Op::Ldih | Op::Paylen => reg(pc, i, i.a),
        Op::Mov => reg(pc, i, i.a).and_then(|_| reg(pc, i, i.b)),
        Op::Add
        | Op::Sub
        | Op::Mul
        | Op::Divu
        | Op::And
        | Op::Or
        | Op::Xor
        | Op::Shl
        | Op::Shr
        | Op::Sltu
        | Op::Eq => {
            reg(pc, i, i.a).and_then(|_| reg(pc, i, i.b)).and_then(|_| reg(pc, i, i.c))
        }
        Op::Addi => reg(pc, i, i.a).and_then(|_| reg(pc, i, i.b)),
        Op::Jmp => target(pc, i, i.imm, n),
        Op::Jz | Op::Jnz => reg(pc, i, i.a).and_then(|_| target(pc, i, i.imm, n)),
        Op::Call => {
            if (i.imm as usize) < n_imports {
                Ok(())
            } else {
                Err(fail(
                    pc,
                    i,
                    format_args!("CALL slot {} outside GOT of {n_imports} entries", i.imm),
                ))
            }
        }
        Op::Ldb | Op::Ldw | Op::Stb | Op::Stw => {
            reg(pc, i, i.a).and_then(|_| reg(pc, i, i.b)).and_then(|_| space(pc, i, i.c))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vm::Assembler;

    #[test]
    fn valid_program_verifies() {
        let mut a = Assembler::new();
        a.ldi(1, 10).call("f").halt();
        let (code, imports) = a.assemble();
        assert_eq!(verify(&code, imports.len()).unwrap().len(), 3);
    }

    #[test]
    fn empty_code_rejected() {
        assert!(verify(&[], 0).is_err());
    }

    #[test]
    fn call_outside_got_rejected() {
        let mut a = Assembler::new();
        a.call("f").halt();
        let (code, _) = a.assemble();
        let err = verify(&code, 0).unwrap_err();
        assert!(err.to_string().contains("CALL slot"));
    }

    #[test]
    fn jump_outside_code_rejected() {
        // Hand-craft a JMP to instruction 99 in a 1-instruction program.
        let i = crate::vm::isa::Instr { op: Op::Jmp, a: 0, b: 0, c: 0, imm: 99 };
        let err = verify(&i.encode(), 0).unwrap_err();
        assert!(err.to_string().contains("jump target"));
    }

    #[test]
    fn bad_register_rejected() {
        let i = crate::vm::isa::Instr { op: Op::Mov, a: 16, b: 0, c: 0, imm: 0 };
        assert!(verify(&i.encode(), 0).is_err());
    }

    #[test]
    fn bad_space_rejected() {
        let i = crate::vm::isa::Instr { op: Op::Ldb, a: 0, b: 0, c: 7, imm: 0 };
        assert!(verify(&i.encode(), 0).is_err());
    }

    /// Every structural rejection names the offending instruction: the
    /// disassembled mnemonic and the byte offset appear in the message.
    #[test]
    fn errors_include_disasm_and_offset() {
        // Second instruction bad → pc 1, byte offset 8.
        let bad_mov = [
            crate::vm::isa::Instr { op: Op::Nop, a: 0, b: 0, c: 0, imm: 0 },
            crate::vm::isa::Instr { op: Op::Mov, a: 16, b: 0, c: 0, imm: 0 },
        ];
        let bytes: Vec<u8> = bad_mov.iter().flat_map(|i| i.encode()).collect();
        let msg = verify(&bytes, 0).unwrap_err().to_string();
        assert!(msg.contains("mov"), "mnemonic missing: {msg}");
        assert!(msg.contains("pc 1 (offset 0x8)"), "location missing: {msg}");
        assert!(msg.contains("register r16 out of range"), "{msg}");

        let i = crate::vm::isa::Instr { op: Op::Jmp, a: 0, b: 0, c: 0, imm: 99 };
        let msg = verify(&i.encode(), 0).unwrap_err().to_string();
        assert!(msg.contains("jmp"), "mnemonic missing: {msg}");
        assert!(msg.contains("offset 0x0"), "{msg}");
        assert!(msg.contains("jump target"), "{msg}");

        let mut a = Assembler::new();
        a.call("f").halt();
        let (code, _) = a.assemble();
        let msg = verify(&code, 0).unwrap_err().to_string();
        assert!(msg.contains("call"), "mnemonic missing: {msg}");
        assert!(msg.contains("CALL slot"), "{msg}");

        let i = crate::vm::isa::Instr { op: Op::Stw, a: 0, b: 0, c: 9, imm: 4 };
        let msg = verify(&i.encode(), 0).unwrap_err().to_string();
        assert!(msg.contains("stw"), "mnemonic missing: {msg}");
        assert!(msg.contains("invalid memory space 9"), "{msg}");
    }

    #[test]
    fn truncated_code_rejected() {
        let mut a = Assembler::new();
        a.halt();
        let (mut code, _) = a.assemble();
        code.pop();
        assert!(verify(&code, 0).is_err());
    }
}
