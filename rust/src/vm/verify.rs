//! Static bytecode verifier — the §3.5 security mitigation for code that
//! crosses trust boundaries.
//!
//! The paper leans on RKEY-based transport authorization and leaves a full
//! security model to future work; because our injected code is bytecode
//! rather than native text, we can go further and *statically verify*
//! every frame before invocation:
//!
//! * every opcode decodes,
//! * every register field used by the opcode is `< NUM_REGS`,
//! * every memory-space selector is payload or scratch,
//! * every jump / branch target is inside the code section,
//! * every `CALL` slot is inside the import table,
//! * the code section is non-empty and below [`MAX_INSTRS`].
//!
//! Dynamic properties (payload bounds, fuel) are enforced by the
//! interpreter at run time.

use super::isa::{decode_all, Instr, Op, MAX_INSTRS, NUM_REGS, SPACE_PAYLOAD, SPACE_SCRATCH};
use crate::{Error, Result};

/// Verify a raw code section against an import table of `n_imports` names.
/// Returns the decoded program on success so callers decode exactly once.
pub fn verify(code: &[u8], n_imports: usize) -> Result<Vec<Instr>> {
    if code.is_empty() {
        return Err(Error::Verify("empty code section".into()));
    }
    let instrs = decode_all(code)
        .ok_or_else(|| Error::Verify("undecodable instruction or truncated code".into()))?;
    if instrs.len() > MAX_INSTRS {
        return Err(Error::Verify(format!(
            "code too long: {} instructions (max {MAX_INSTRS})",
            instrs.len()
        )));
    }
    for (pc, i) in instrs.iter().enumerate() {
        check_instr(pc, i, instrs.len(), n_imports)?;
    }
    Ok(instrs)
}

fn reg(pc: usize, r: u8) -> Result<()> {
    if (r as usize) < NUM_REGS {
        Ok(())
    } else {
        Err(Error::Verify(format!("pc {pc}: register r{r} out of range")))
    }
}

fn space(pc: usize, s: u8) -> Result<()> {
    if s == SPACE_PAYLOAD || s == SPACE_SCRATCH {
        Ok(())
    } else {
        Err(Error::Verify(format!("pc {pc}: invalid memory space {s}")))
    }
}

fn target(pc: usize, imm: u32, n: usize) -> Result<()> {
    if (imm as usize) < n {
        Ok(())
    } else {
        Err(Error::Verify(format!("pc {pc}: jump target {imm} outside code of {n} instrs")))
    }
}

fn check_instr(pc: usize, i: &Instr, n: usize, n_imports: usize) -> Result<()> {
    match i.op {
        Op::Halt | Op::Nop => Ok(()),
        Op::Ldi | Op::Ldih | Op::Paylen => reg(pc, i.a),
        Op::Mov => reg(pc, i.a).and_then(|_| reg(pc, i.b)),
        Op::Add
        | Op::Sub
        | Op::Mul
        | Op::Divu
        | Op::And
        | Op::Or
        | Op::Xor
        | Op::Shl
        | Op::Shr
        | Op::Sltu
        | Op::Eq => reg(pc, i.a).and_then(|_| reg(pc, i.b)).and_then(|_| reg(pc, i.c)),
        Op::Addi => reg(pc, i.a).and_then(|_| reg(pc, i.b)),
        Op::Jmp => target(pc, i.imm, n),
        Op::Jz | Op::Jnz => reg(pc, i.a).and_then(|_| target(pc, i.imm, n)),
        Op::Call => {
            if (i.imm as usize) < n_imports {
                Ok(())
            } else {
                Err(Error::Verify(format!(
                    "pc {pc}: CALL slot {} outside GOT of {n_imports} entries",
                    i.imm
                )))
            }
        }
        Op::Ldb | Op::Ldw | Op::Stb | Op::Stw => {
            reg(pc, i.a).and_then(|_| reg(pc, i.b)).and_then(|_| space(pc, i.c))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vm::Assembler;

    #[test]
    fn valid_program_verifies() {
        let mut a = Assembler::new();
        a.ldi(1, 10).call("f").halt();
        let (code, imports) = a.assemble();
        assert_eq!(verify(&code, imports.len()).unwrap().len(), 3);
    }

    #[test]
    fn empty_code_rejected() {
        assert!(verify(&[], 0).is_err());
    }

    #[test]
    fn call_outside_got_rejected() {
        let mut a = Assembler::new();
        a.call("f").halt();
        let (code, _) = a.assemble();
        let err = verify(&code, 0).unwrap_err();
        assert!(err.to_string().contains("CALL slot"));
    }

    #[test]
    fn jump_outside_code_rejected() {
        // Hand-craft a JMP to instruction 99 in a 1-instruction program.
        let i = crate::vm::isa::Instr { op: Op::Jmp, a: 0, b: 0, c: 0, imm: 99 };
        let err = verify(&i.encode(), 0).unwrap_err();
        assert!(err.to_string().contains("jump target"));
    }

    #[test]
    fn bad_register_rejected() {
        let i = crate::vm::isa::Instr { op: Op::Mov, a: 16, b: 0, c: 0, imm: 0 };
        assert!(verify(&i.encode(), 0).is_err());
    }

    #[test]
    fn bad_space_rejected() {
        let i = crate::vm::isa::Instr { op: Op::Ldb, a: 0, b: 0, c: 7, imm: 0 };
        assert!(verify(&i.encode(), 0).is_err());
    }

    #[test]
    fn truncated_code_rejected() {
        let mut a = Assembler::new();
        a.halt();
        let (mut code, _) = a.assemble();
        code.pop();
        assert!(verify(&code, 0).is_err());
    }
}
