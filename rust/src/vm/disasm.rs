//! TCVM disassembler — human-readable listings of shipped code sections.
//!
//! Used by `repro info --disasm`, error diagnostics, and tests; the
//! round-trip property (assemble → disassemble → same semantics) is
//! asserted by the test suite.

use super::isa::{decode_all, Instr, Op, SPACE_PAYLOAD, SPACE_SCRATCH};

fn space_name(c: u8) -> &'static str {
    match c {
        SPACE_PAYLOAD => "pay",
        SPACE_SCRATCH => "scr",
        _ => "bad",
    }
}

/// Disassemble one instruction; `imports` (if provided) names CALL slots.
pub fn disasm_instr(i: &Instr, imports: Option<&[String]>) -> String {
    let Instr { op, a, b, c, imm } = *i;
    match op {
        Op::Halt => "halt".to_string(),
        Op::Nop => "nop".to_string(),
        Op::Ldi => format!("ldi   r{a}, {imm:#x}"),
        Op::Ldih => format!("ldih  r{a}, {imm:#x}"),
        Op::Mov => format!("mov   r{a}, r{b}"),
        Op::Add => format!("add   r{a}, r{b}, r{c}"),
        Op::Sub => format!("sub   r{a}, r{b}, r{c}"),
        Op::Mul => format!("mul   r{a}, r{b}, r{c}"),
        Op::Divu => format!("divu  r{a}, r{b}, r{c}"),
        Op::And => format!("and   r{a}, r{b}, r{c}"),
        Op::Or => format!("or    r{a}, r{b}, r{c}"),
        Op::Xor => format!("xor   r{a}, r{b}, r{c}"),
        Op::Shl => format!("shl   r{a}, r{b}, r{c}"),
        Op::Shr => format!("shr   r{a}, r{b}, r{c}"),
        Op::Addi => format!("addi  r{a}, r{b}, {imm:#x}"),
        Op::Sltu => format!("sltu  r{a}, r{b}, r{c}"),
        Op::Eq => format!("eq    r{a}, r{b}, r{c}"),
        Op::Jmp => format!("jmp   @{imm}"),
        Op::Jz => format!("jz    r{a}, @{imm}"),
        Op::Jnz => format!("jnz   r{a}, @{imm}"),
        Op::Call => {
            let name = imports
                .and_then(|im| im.get(imm as usize))
                .map(|s| format!(" <{s}>"))
                .unwrap_or_default();
            format!("call  got[{imm}]{name}")
        }
        Op::Ldb => format!("ldb   r{a}, {}[r{b}+{imm:#x}]", space_name(c)),
        Op::Ldw => format!("ldw   r{a}, {}[r{b}+{imm:#x}]", space_name(c)),
        Op::Stb => format!("stb   {}[r{b}+{imm:#x}], r{a}", space_name(c)),
        Op::Stw => format!("stw   {}[r{b}+{imm:#x}], r{a}", space_name(c)),
        Op::Paylen => format!("paylen r{a}"),
    }
}

/// Parse one line of [`disasm_instr`] output back into an [`Instr`] —
/// the inverse direction of the round-trip property (assemble →
/// disassemble → reparse → byte-identical, asserted in
/// `rust/tests/prop.rs`). Accepts exactly the canonical listing forms;
/// unused operand fields come back zeroed, matching what the assembler
/// emits. Returns `None` on anything else (including the `bad` space
/// marker, whose original selector the listing does not preserve).
pub fn parse_instr(text: &str) -> Option<Instr> {
    fn reg(t: &str) -> Option<u8> {
        t.strip_prefix('r')?.parse().ok()
    }
    fn num(t: &str) -> Option<u32> {
        match t.strip_prefix("0x") {
            Some(h) => u32::from_str_radix(h, 16).ok(),
            None => t.parse().ok(),
        }
    }
    fn space(t: &str) -> Option<u8> {
        match t {
            "pay" => Some(SPACE_PAYLOAD),
            "scr" => Some(SPACE_SCRATCH),
            _ => None,
        }
    }
    /// `{space}[r{b}+{imm:#x}]` → (c, b, imm).
    fn mem(t: &str) -> Option<(u8, u8, u32)> {
        let open = t.find('[')?;
        let c = space(&t[..open])?;
        let inner = t[open + 1..].strip_suffix(']')?;
        let (r, off) = inner.split_once('+')?;
        Some((c, reg(r)?, num(off)?))
    }
    let text = text.trim();
    let (mnemonic, rest) = match text.split_once(char::is_whitespace) {
        Some((m, r)) => (m, r.trim()),
        None => (text, ""),
    };
    let args: Vec<&str> =
        if rest.is_empty() { Vec::new() } else { rest.split(',').map(str::trim).collect() };
    let ins = |op, a, b, c, imm| Some(Instr { op, a, b, c, imm });
    let three = |op: Op, args: &[&str]| match args {
        [a, b, c] => ins(op, reg(a)?, reg(b)?, reg(c)?, 0),
        _ => None,
    };
    match (mnemonic, args.as_slice()) {
        ("halt", []) => ins(Op::Halt, 0, 0, 0, 0),
        ("nop", []) => ins(Op::Nop, 0, 0, 0, 0),
        ("ldi", [a, imm]) => ins(Op::Ldi, reg(a)?, 0, 0, num(imm)?),
        ("ldih", [a, imm]) => ins(Op::Ldih, reg(a)?, 0, 0, num(imm)?),
        ("mov", [a, b]) => ins(Op::Mov, reg(a)?, reg(b)?, 0, 0),
        ("add", _) => three(Op::Add, &args),
        ("sub", _) => three(Op::Sub, &args),
        ("mul", _) => three(Op::Mul, &args),
        ("divu", _) => three(Op::Divu, &args),
        ("and", _) => three(Op::And, &args),
        ("or", _) => three(Op::Or, &args),
        ("xor", _) => three(Op::Xor, &args),
        ("shl", _) => three(Op::Shl, &args),
        ("shr", _) => three(Op::Shr, &args),
        ("sltu", _) => three(Op::Sltu, &args),
        ("eq", _) => three(Op::Eq, &args),
        ("addi", [a, b, imm]) => ins(Op::Addi, reg(a)?, reg(b)?, 0, num(imm)?),
        ("jmp", [t]) => ins(Op::Jmp, 0, 0, 0, num(t.strip_prefix('@')?)?),
        ("jz", [a, t]) => ins(Op::Jz, reg(a)?, 0, 0, num(t.strip_prefix('@')?)?),
        ("jnz", [a, t]) => ins(Op::Jnz, reg(a)?, 0, 0, num(t.strip_prefix('@')?)?),
        ("call", [slot]) => {
            // `got[{imm}]`, optionally followed by ` <name>`.
            let slot = slot.split_whitespace().next()?;
            ins(Op::Call, 0, 0, 0, num(slot.strip_prefix("got[")?.strip_suffix(']')?)?)
        }
        ("ldb", [a, m]) => {
            let (c, b, imm) = mem(m)?;
            ins(Op::Ldb, reg(a)?, b, c, imm)
        }
        ("ldw", [a, m]) => {
            let (c, b, imm) = mem(m)?;
            ins(Op::Ldw, reg(a)?, b, c, imm)
        }
        ("stb", [m, a]) => {
            let (c, b, imm) = mem(m)?;
            ins(Op::Stb, reg(a)?, b, c, imm)
        }
        ("stw", [m, a]) => {
            let (c, b, imm) = mem(m)?;
            ins(Op::Stw, reg(a)?, b, c, imm)
        }
        ("paylen", [a]) => ins(Op::Paylen, reg(a)?, 0, 0, 0),
        _ => None,
    }
}

/// Disassemble a full code section. Undecodable input yields an error
/// string rather than panicking (it may be hostile bytes).
pub fn disasm(code: &[u8], imports: Option<&[String]>) -> String {
    let Some(instrs) = decode_all(code) else {
        return format!("<undecodable code section: {} bytes>", code.len());
    };
    instrs
        .iter()
        .enumerate()
        .map(|(pc, i)| format!("{pc:4}: {}", disasm_instr(i, imports)))
        .collect::<Vec<_>>()
        .join("\n")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vm::Assembler;

    #[test]
    fn counter_listing_names_imports() {
        let mut a = Assembler::new();
        a.ldi(1, 1);
        a.call("counter_add");
        a.halt();
        let (code, imports) = a.assemble();
        let text = disasm(&code, Some(&imports));
        assert!(text.contains("ldi   r1, 0x1"), "{text}");
        assert!(text.contains("call  got[0] <counter_add>"), "{text}");
        assert!(text.contains("halt"), "{text}");
    }

    #[test]
    fn every_opcode_disassembles() {
        for v in 0u8..=0x19 {
            let op = crate::vm::isa::Op::from_u8(v).unwrap();
            let i = Instr { op, a: 1, b: 2, c: 0, imm: 3 };
            let s = disasm_instr(&i, None);
            assert!(!s.is_empty());
        }
    }

    #[test]
    fn garbage_reports_instead_of_panicking() {
        let s = disasm(&[0xFF; 9], None);
        assert!(s.contains("undecodable"));
    }

    #[test]
    fn parse_inverts_disasm_for_canonical_instrs() {
        // Canonical = unused operand fields zero, exactly what the
        // assembler emits. Cover every opcode with live fields.
        let cases = [
            Instr { op: Op::Halt, a: 0, b: 0, c: 0, imm: 0 },
            Instr { op: Op::Nop, a: 0, b: 0, c: 0, imm: 0 },
            Instr { op: Op::Ldi, a: 3, b: 0, c: 0, imm: 0xDEAD },
            Instr { op: Op::Ldih, a: 15, b: 0, c: 0, imm: 0xBEEF },
            Instr { op: Op::Mov, a: 1, b: 2, c: 0, imm: 0 },
            Instr { op: Op::Add, a: 1, b: 2, c: 3, imm: 0 },
            Instr { op: Op::Divu, a: 0, b: 9, c: 10, imm: 0 },
            Instr { op: Op::Addi, a: 4, b: 4, c: 0, imm: 1 },
            Instr { op: Op::Jmp, a: 0, b: 0, c: 0, imm: 12 },
            Instr { op: Op::Jz, a: 5, b: 0, c: 0, imm: 0 },
            Instr { op: Op::Jnz, a: 5, b: 0, c: 0, imm: 9 },
            Instr { op: Op::Call, a: 0, b: 0, c: 0, imm: 2 },
            Instr { op: Op::Ldb, a: 6, b: 2, c: 0, imm: 0x10 },
            Instr { op: Op::Ldw, a: 6, b: 2, c: 1, imm: 0 },
            Instr { op: Op::Stb, a: 6, b: 2, c: 1, imm: 0xFF },
            Instr { op: Op::Stw, a: 6, b: 2, c: 0, imm: 8 },
            Instr { op: Op::Paylen, a: 7, b: 0, c: 0, imm: 0 },
        ];
        for i in cases {
            let text = disasm_instr(&i, None);
            let back = parse_instr(&text).unwrap_or_else(|| panic!("unparsable: {text}"));
            assert_eq!(back, i, "round trip of {text:?}");
            assert_eq!(back.encode(), i.encode());
        }
    }

    #[test]
    fn parse_accepts_named_call_and_rejects_garbage() {
        let i = Instr { op: Op::Call, a: 0, b: 0, c: 0, imm: 0 };
        let named = disasm_instr(&i, Some(&["counter_add".to_string()]));
        assert_eq!(parse_instr(&named), Some(i));
        assert_eq!(parse_instr(""), None);
        assert_eq!(parse_instr("frobnicate r1, r2"), None);
        assert_eq!(parse_instr("ldb   r1, bad[r2+0x0]"), None, "lossy space selector");
        assert_eq!(parse_instr("add   r1, r2"), None, "arity mismatch");
    }

    #[test]
    fn jump_targets_are_indices() {
        let mut a = Assembler::new();
        let top = a.label();
        a.bind(top);
        a.jmp(top);
        let (code, _) = a.assemble();
        assert!(disasm(&code, None).contains("jmp   @0"));
    }
}
