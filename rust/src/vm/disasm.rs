//! TCVM disassembler — human-readable listings of shipped code sections.
//!
//! Used by `repro info --disasm`, error diagnostics, and tests; the
//! round-trip property (assemble → disassemble → same semantics) is
//! asserted by the test suite.

use super::isa::{decode_all, Instr, Op, SPACE_PAYLOAD, SPACE_SCRATCH};

fn space_name(c: u8) -> &'static str {
    match c {
        SPACE_PAYLOAD => "pay",
        SPACE_SCRATCH => "scr",
        _ => "bad",
    }
}

/// Disassemble one instruction; `imports` (if provided) names CALL slots.
pub fn disasm_instr(i: &Instr, imports: Option<&[String]>) -> String {
    let Instr { op, a, b, c, imm } = *i;
    match op {
        Op::Halt => "halt".to_string(),
        Op::Nop => "nop".to_string(),
        Op::Ldi => format!("ldi   r{a}, {imm:#x}"),
        Op::Ldih => format!("ldih  r{a}, {imm:#x}"),
        Op::Mov => format!("mov   r{a}, r{b}"),
        Op::Add => format!("add   r{a}, r{b}, r{c}"),
        Op::Sub => format!("sub   r{a}, r{b}, r{c}"),
        Op::Mul => format!("mul   r{a}, r{b}, r{c}"),
        Op::Divu => format!("divu  r{a}, r{b}, r{c}"),
        Op::And => format!("and   r{a}, r{b}, r{c}"),
        Op::Or => format!("or    r{a}, r{b}, r{c}"),
        Op::Xor => format!("xor   r{a}, r{b}, r{c}"),
        Op::Shl => format!("shl   r{a}, r{b}, r{c}"),
        Op::Shr => format!("shr   r{a}, r{b}, r{c}"),
        Op::Addi => format!("addi  r{a}, r{b}, {imm:#x}"),
        Op::Sltu => format!("sltu  r{a}, r{b}, r{c}"),
        Op::Eq => format!("eq    r{a}, r{b}, r{c}"),
        Op::Jmp => format!("jmp   @{imm}"),
        Op::Jz => format!("jz    r{a}, @{imm}"),
        Op::Jnz => format!("jnz   r{a}, @{imm}"),
        Op::Call => {
            let name = imports
                .and_then(|im| im.get(imm as usize))
                .map(|s| format!(" <{s}>"))
                .unwrap_or_default();
            format!("call  got[{imm}]{name}")
        }
        Op::Ldb => format!("ldb   r{a}, {}[r{b}+{imm:#x}]", space_name(c)),
        Op::Ldw => format!("ldw   r{a}, {}[r{b}+{imm:#x}]", space_name(c)),
        Op::Stb => format!("stb   {}[r{b}+{imm:#x}], r{a}", space_name(c)),
        Op::Stw => format!("stw   {}[r{b}+{imm:#x}], r{a}", space_name(c)),
        Op::Paylen => format!("paylen r{a}"),
    }
}

/// Disassemble a full code section. Undecodable input yields an error
/// string rather than panicking (it may be hostile bytes).
pub fn disasm(code: &[u8], imports: Option<&[String]>) -> String {
    let Some(instrs) = decode_all(code) else {
        return format!("<undecodable code section: {} bytes>", code.len());
    };
    instrs
        .iter()
        .enumerate()
        .map(|(pc, i)| format!("{pc:4}: {}", disasm_instr(i, imports)))
        .collect::<Vec<_>>()
        .join("\n")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vm::Assembler;

    #[test]
    fn counter_listing_names_imports() {
        let mut a = Assembler::new();
        a.ldi(1, 1);
        a.call("counter_add");
        a.halt();
        let (code, imports) = a.assemble();
        let text = disasm(&code, Some(&imports));
        assert!(text.contains("ldi   r1, 0x1"), "{text}");
        assert!(text.contains("call  got[0] <counter_add>"), "{text}");
        assert!(text.contains("halt"), "{text}");
    }

    #[test]
    fn every_opcode_disassembles() {
        for v in 0u8..=0x19 {
            let op = crate::vm::isa::Op::from_u8(v).unwrap();
            let i = Instr { op, a: 1, b: 2, c: 0, imm: 3 };
            let s = disasm_instr(&i, None);
            assert!(!s.is_empty());
        }
    }

    #[test]
    fn garbage_reports_instead_of_panicking() {
        let s = disasm(&[0xFF; 9], None);
        assert!(s.contains("undecodable"));
    }

    #[test]
    fn jump_targets_are_indices() {
        let mut a = Assembler::new();
        let top = a.label();
        a.bind(top);
        a.jmp(top);
        let (code, _) = a.assemble();
        assert!(disasm(&code, None).contains("jmp   @0"));
    }
}
