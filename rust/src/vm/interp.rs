//! TCVM reference interpreter — the per-step match loop.
//!
//! Executes a verified program against the message payload *in place in
//! the ring buffer* (matching the paper: the main function receives a
//! pointer into the received frame, no copy), a zeroed per-invocation
//! scratch space, and a patched GOT. Runtime enforcement: payload /
//! scratch bounds on every access, divide-by-zero, and an instruction
//! budget ("fuel") so a hostile or buggy ifunc cannot wedge the poll loop.
//!
//! The hot path no longer runs this loop: [`super::compile`] lowers the
//! verified program into pre-resolved handler ops once, and the engine
//! executes those. This module stays as:
//!
//! * [`run_reference`] — the semantic ground truth the compiled form is
//!   differentially tested against (`rust/tests/prop.rs`) and the
//!   match-loop column of Abl J,
//! * [`run_from`] — the resumable per-instruction stepper the compiled
//!   form delegates to when fuel will exhaust mid-block, so fuel faults
//!   keep the exact per-instruction pc attribution of the reference.

use super::got::{GotTable, HostCtx};
use super::isa::{Instr, Op, NUM_REGS, SPACE_PAYLOAD};
use crate::{Error, Result};

/// Default instruction budget per invocation.
pub const DEFAULT_FUEL: u64 = 50_000_000;

/// Interpreter configuration.
#[derive(Clone, Copy, Debug)]
pub struct VmConfig {
    pub fuel: u64,
    pub scratch_bytes: usize,
}

impl Default for VmConfig {
    fn default() -> Self {
        VmConfig { fuel: DEFAULT_FUEL, scratch_bytes: super::isa::SCRATCH_BYTES }
    }
}

/// Outcome of a successful invocation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct VmOutcome {
    /// `r0` at `HALT` — the injected function's return value.
    pub ret: u64,
    /// Instructions retired.
    pub steps: u64,
}

/// Run a verified program through the reference match loop. `payload` is
/// the message payload *in place*; `user` is the type-erased
/// `target_args` of `ucp_poll_ifunc`.
///
/// Public only so benches and the differential property tests can pit the
/// compiled form against it — production callers go through
/// [`super::compile::CompiledProgram::run`].
#[doc(hidden)]
pub fn run_reference(
    prog: &[Instr],
    got: &GotTable,
    payload: &mut [u8],
    user: &mut dyn std::any::Any,
    cfg: &VmConfig,
) -> Result<VmOutcome> {
    let mut regs = [0u64; NUM_REGS];
    // Scratch is allocated (and zeroed) only if the bytecode can touch
    // it: zeroing 64 KiB per invocation costs ~1.7 µs, which dominated
    // the counter-ifunc hot path (§Perf). Host bindings see an empty
    // scratch when the program has no scratch-space memory ops.
    let uses_scratch = prog.iter().any(Instr::touches_scratch);
    let mut scratch = if uses_scratch { vec![0u8; cfg.scratch_bytes] } else { Vec::new() };
    // Entry convention (mirrors `[name]_main(payload, payload_size, args)`):
    // r1 = payload length; r2..r4 = 0.
    regs[1] = payload.len() as u64;
    let (ret, steps) = run_from(prog, got, payload, &mut scratch, user, &mut regs, 0, cfg.fuel)?;
    Ok(VmOutcome { ret, steps })
}

/// The per-instruction stepper behind [`run_reference`], resumable from an
/// arbitrary `(regs, pc, fuel)` machine state. Returns `(r0, steps)` at
/// `HALT`. The compiled form calls this from a basic-block boundary when
/// the remaining fuel cannot cover the block's precomputed cost, so fuel
/// exhaustion faults at the exact instruction the reference would fault
/// at.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_from(
    prog: &[Instr],
    got: &GotTable,
    payload: &mut [u8],
    scratch: &mut [u8],
    user: &mut dyn std::any::Any,
    regs: &mut [u64; NUM_REGS],
    mut pc: usize,
    mut fuel: u64,
) -> Result<(u64, u64)> {
    let fuel0 = fuel;
    loop {
        if fuel == 0 {
            return Err(Error::VmFault(format!("fuel exhausted at pc {pc}")));
        }
        fuel -= 1;
        let Some(&i) = prog.get(pc) else {
            return Err(Error::VmFault(format!("execution fell off code end at pc {pc}")));
        };
        pc += 1;
        match i.op {
            Op::Halt => {
                return Ok((regs[0], fuel0 - fuel));
            }
            Op::Nop => {}
            Op::Ldi => regs[i.a as usize] = i.imm as u64,
            Op::Ldih => {
                regs[i.a as usize] = ((i.imm as u64) << 32) | (regs[i.a as usize] & 0xFFFF_FFFF);
            }
            Op::Mov => regs[i.a as usize] = regs[i.b as usize],
            Op::Add => {
                regs[i.a as usize] = regs[i.b as usize].wrapping_add(regs[i.c as usize])
            }
            Op::Sub => {
                regs[i.a as usize] = regs[i.b as usize].wrapping_sub(regs[i.c as usize])
            }
            Op::Mul => {
                regs[i.a as usize] = regs[i.b as usize].wrapping_mul(regs[i.c as usize])
            }
            Op::Divu => {
                let d = regs[i.c as usize];
                if d == 0 {
                    return Err(Error::VmFault(format!("divide by zero at pc {}", pc - 1)));
                }
                regs[i.a as usize] = regs[i.b as usize] / d;
            }
            Op::And => regs[i.a as usize] = regs[i.b as usize] & regs[i.c as usize],
            Op::Or => regs[i.a as usize] = regs[i.b as usize] | regs[i.c as usize],
            Op::Xor => regs[i.a as usize] = regs[i.b as usize] ^ regs[i.c as usize],
            Op::Shl => {
                regs[i.a as usize] = regs[i.b as usize] << (regs[i.c as usize] & 63)
            }
            Op::Shr => {
                regs[i.a as usize] = regs[i.b as usize] >> (regs[i.c as usize] & 63)
            }
            Op::Addi => {
                regs[i.a as usize] = regs[i.b as usize].wrapping_add(i.imm as u64)
            }
            Op::Sltu => {
                regs[i.a as usize] = (regs[i.b as usize] < regs[i.c as usize]) as u64
            }
            Op::Eq => {
                regs[i.a as usize] = (regs[i.b as usize] == regs[i.c as usize]) as u64
            }
            Op::Jmp => pc = i.imm as usize,
            Op::Jz => {
                if regs[i.a as usize] == 0 {
                    pc = i.imm as usize;
                }
            }
            Op::Jnz => {
                if regs[i.a as usize] != 0 {
                    pc = i.imm as usize;
                }
            }
            Op::Call => {
                let f = got.slot(i.imm as usize).ok_or_else(|| {
                    // Verifier guarantees slot < imports; a GOT shorter than
                    // the import table is a linking bug, not a code bug.
                    Error::VmFault(format!("GOT slot {} not linked", i.imm))
                })?;
                let args = [regs[1], regs[2], regs[3], regs[4]];
                // Explicit reborrows: a struct literal would *move* the
                // `&mut` params out of the loop on the first CALL.
                let mut ctx =
                    HostCtx { payload: &mut *payload, scratch: &mut *scratch, user: &mut *user };
                regs[0] = f(&mut ctx, args).map_err(Error::VmFault)?;
            }
            Op::Ldb | Op::Ldw | Op::Stb | Op::Stw => {
                let width = if matches!(i.op, Op::Ldw | Op::Stw) { 8 } else { 1 };
                let addr = regs[i.b as usize].wrapping_add(i.imm as u64) as usize;
                let mem: &mut [u8] =
                    if i.c == SPACE_PAYLOAD { &mut *payload } else { &mut *scratch };
                if addr.checked_add(width).is_none_or(|end| end > mem.len()) {
                    return Err(Error::VmFault(format!(
                        "oob {} access at {addr}+{width} (space {} of {} bytes, pc {})",
                        if matches!(i.op, Op::Stb | Op::Stw) { "store" } else { "load" },
                        i.c,
                        mem.len(),
                        pc - 1
                    )));
                }
                match i.op {
                    Op::Ldb => regs[i.a as usize] = mem[addr] as u64,
                    Op::Ldw => {
                        regs[i.a as usize] =
                            u64::from_le_bytes(mem[addr..addr + 8].try_into().unwrap())
                    }
                    Op::Stb => mem[addr] = regs[i.a as usize] as u8,
                    Op::Stw => mem[addr..addr + 8]
                        .copy_from_slice(&regs[i.a as usize].to_le_bytes()),
                    _ => unreachable!(),
                }
            }
            Op::Paylen => regs[i.a as usize] = payload.len() as u64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vm::{got::SymbolTable, verify::verify, Assembler};

    fn exec(
        build: impl FnOnce(&mut Assembler),
        payload: &mut [u8],
        syms: &SymbolTable,
    ) -> Result<VmOutcome> {
        let mut a = Assembler::new();
        build(&mut a);
        let (code, imports) = a.assemble();
        let prog = verify(&code, imports.len())?;
        let got = syms.resolve(&imports)?;
        run_reference(&prog, &got, payload, &mut (), &VmConfig::default())
    }

    #[test]
    fn arithmetic_and_halt() {
        let out = exec(
            |a| {
                a.ldi(1, 6).ldi(2, 7).mul(0, 1, 2).halt();
            },
            &mut [],
            &SymbolTable::new(),
        )
        .unwrap();
        assert_eq!(out.ret, 42);
    }

    #[test]
    fn loop_sums_payload_bytes() {
        // r0 = sum of payload bytes — a classic checksum loop.
        let mut payload = [1u8, 2, 3, 4, 5];
        let out = exec(
            |a| {
                let top = a.label();
                let done = a.label();
                a.paylen(3); // r3 = len
                a.ldi(2, 0); // r2 = i
                a.ldi(0, 0); // r0 = acc
                a.bind(top);
                a.sltu(5, 2, 3);
                a.jz(5, done);
                a.ldb(6, 2, 0, 0);
                a.add(0, 0, 6);
                a.addi(2, 2, 1);
                a.jmp(top);
                a.bind(done);
                a.halt();
            },
            &mut payload,
            &SymbolTable::new(),
        )
        .unwrap();
        assert_eq!(out.ret, 15);
    }

    #[test]
    fn got_call_reaches_host() {
        let syms = SymbolTable::new();
        syms.install_fn("add_args", |_, args| Ok(args[0] + args[1]));
        let out = exec(
            |a| {
                a.ldi(1, 30).ldi(2, 12).call("add_args").halt();
            },
            &mut [],
            &syms,
        )
        .unwrap();
        assert_eq!(out.ret, 42);
    }

    #[test]
    fn host_can_mutate_payload_in_place() {
        let syms = SymbolTable::new();
        syms.install_fn("upcase", |ctx, _| {
            ctx.payload.make_ascii_uppercase();
            Ok(0)
        });
        let mut payload = *b"ifunc";
        exec(|a| { a.call("upcase").halt(); }, &mut payload, &syms).unwrap();
        assert_eq!(&payload, b"IFUNC");
    }

    #[test]
    fn oob_payload_access_faults() {
        let err = exec(
            |a| {
                a.ldi(2, 100).ldb(0, 2, 0, 0).halt();
            },
            &mut [0u8; 4],
            &SymbolTable::new(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("oob"), "{err}");
    }

    #[test]
    fn scratch_is_zeroed_and_writable() {
        let out = exec(
            |a| {
                a.ldi(1, 0xAB);
                a.ldi(2, 128);
                a.stb(1, 2, 1, 0);
                a.ldb(0, 2, 1, 0);
                a.halt();
            },
            &mut [],
            &SymbolTable::new(),
        )
        .unwrap();
        assert_eq!(out.ret, 0xAB);
    }

    #[test]
    fn infinite_loop_exhausts_fuel() {
        let mut a = Assembler::new();
        let top = a.label();
        a.bind(top);
        a.jmp(top);
        let (code, imports) = a.assemble();
        let prog = verify(&code, imports.len()).unwrap();
        let err = run_reference(
            &prog,
            &crate::vm::got::GotTable::empty(),
            &mut [],
            &mut (),
            &VmConfig { fuel: 1000, scratch_bytes: 0 },
        )
        .unwrap_err();
        assert!(err.to_string().contains("fuel exhausted"));
    }

    #[test]
    fn divide_by_zero_faults() {
        let err = exec(
            |a| {
                a.ldi(1, 10).ldi(2, 0).divu(0, 1, 2).halt();
            },
            &mut [],
            &SymbolTable::new(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("divide by zero"));
    }

    #[test]
    fn host_error_propagates_as_fault() {
        let syms = SymbolTable::new();
        syms.install_fn("boom", |_, _| Err("kaboom".into()));
        let err = exec(|a| { a.call("boom").halt(); }, &mut [], &syms).unwrap_err();
        assert!(err.to_string().contains("kaboom"));
    }

    #[test]
    fn ldw_stw_roundtrip_unaligned() {
        let mut payload = [0u8; 16];
        let out = exec(
            |a| {
                a.ldi64(1, 0x0102_0304_0506_0708);
                a.ldi(2, 3);
                a.stw(1, 2, 0, 0);
                a.ldw(0, 2, 0, 0);
                a.halt();
            },
            &mut payload,
            &SymbolTable::new(),
        )
        .unwrap();
        assert_eq!(out.ret, 0x0102_0304_0506_0708);
    }
}
