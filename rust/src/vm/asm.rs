//! TCVM assembler — the source-side toolchain.
//!
//! The paper's toolchain compiles user C into a dynamic library and then
//! rewrites its assembly so all GOT references indirect through a shipped
//! table (§3.4). Our analog is much simpler: ifunc authors assemble TCVM
//! code with this builder, declaring **imports by name**; each import
//! becomes a GOT slot index, and the target resolves names → local
//! bindings at link time ([`crate::vm::got`]).

use std::collections::HashMap;

use super::isa::{Instr, Op, INSTR_BYTES};

/// A forward-referencable code label.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Label(usize);

/// Assembles a TCVM code section plus its import table.
#[derive(Default)]
pub struct Assembler {
    instrs: Vec<Instr>,
    imports: Vec<String>,
    labels: Vec<Option<usize>>,
    /// (instr index, label) pairs whose imm must be patched at finish.
    fixups: Vec<(usize, Label)>,
}

impl Assembler {
    pub fn new() -> Self {
        Self::default()
    }

    /// Declare (or reuse) an import; returns its GOT slot index.
    pub fn import(&mut self, name: &str) -> u32 {
        if let Some(i) = self.imports.iter().position(|n| n == name) {
            return i as u32;
        }
        self.imports.push(name.to_string());
        (self.imports.len() - 1) as u32
    }

    /// Create an unbound label.
    pub fn label(&mut self) -> Label {
        self.labels.push(None);
        Label(self.labels.len() - 1)
    }

    /// Bind `l` to the current position.
    pub fn bind(&mut self, l: Label) {
        assert!(self.labels[l.0].is_none(), "label bound twice");
        self.labels[l.0] = Some(self.instrs.len());
    }

    fn push(&mut self, op: Op, a: u8, b: u8, c: u8, imm: u32) -> &mut Self {
        self.instrs.push(Instr { op, a, b, c, imm });
        self
    }

    fn push_jump(&mut self, op: Op, a: u8, l: Label) -> &mut Self {
        self.fixups.push((self.instrs.len(), l));
        self.push(op, a, 0, 0, 0)
    }

    pub fn halt(&mut self) -> &mut Self {
        self.push(Op::Halt, 0, 0, 0, 0)
    }

    /// Load a full 64-bit constant (1 or 2 instructions).
    pub fn ldi64(&mut self, ra: u8, v: u64) -> &mut Self {
        self.push(Op::Ldi, ra, 0, 0, v as u32);
        if v > u32::MAX as u64 {
            self.push(Op::Ldih, ra, 0, 0, (v >> 32) as u32);
        }
        self
    }

    pub fn ldi(&mut self, ra: u8, v: u32) -> &mut Self {
        self.push(Op::Ldi, ra, 0, 0, v)
    }

    pub fn mov(&mut self, ra: u8, rb: u8) -> &mut Self {
        self.push(Op::Mov, ra, rb, 0, 0)
    }

    pub fn add(&mut self, ra: u8, rb: u8, rc: u8) -> &mut Self {
        self.push(Op::Add, ra, rb, rc, 0)
    }

    pub fn sub(&mut self, ra: u8, rb: u8, rc: u8) -> &mut Self {
        self.push(Op::Sub, ra, rb, rc, 0)
    }

    pub fn mul(&mut self, ra: u8, rb: u8, rc: u8) -> &mut Self {
        self.push(Op::Mul, ra, rb, rc, 0)
    }

    pub fn divu(&mut self, ra: u8, rb: u8, rc: u8) -> &mut Self {
        self.push(Op::Divu, ra, rb, rc, 0)
    }

    pub fn and(&mut self, ra: u8, rb: u8, rc: u8) -> &mut Self {
        self.push(Op::And, ra, rb, rc, 0)
    }

    pub fn or(&mut self, ra: u8, rb: u8, rc: u8) -> &mut Self {
        self.push(Op::Or, ra, rb, rc, 0)
    }

    pub fn xor(&mut self, ra: u8, rb: u8, rc: u8) -> &mut Self {
        self.push(Op::Xor, ra, rb, rc, 0)
    }

    pub fn shl(&mut self, ra: u8, rb: u8, rc: u8) -> &mut Self {
        self.push(Op::Shl, ra, rb, rc, 0)
    }

    pub fn shr(&mut self, ra: u8, rb: u8, rc: u8) -> &mut Self {
        self.push(Op::Shr, ra, rb, rc, 0)
    }

    pub fn addi(&mut self, ra: u8, rb: u8, imm: u32) -> &mut Self {
        self.push(Op::Addi, ra, rb, 0, imm)
    }

    pub fn sltu(&mut self, ra: u8, rb: u8, rc: u8) -> &mut Self {
        self.push(Op::Sltu, ra, rb, rc, 0)
    }

    pub fn eq(&mut self, ra: u8, rb: u8, rc: u8) -> &mut Self {
        self.push(Op::Eq, ra, rb, rc, 0)
    }

    pub fn jmp(&mut self, l: Label) -> &mut Self {
        self.push_jump(Op::Jmp, 0, l)
    }

    pub fn jz(&mut self, ra: u8, l: Label) -> &mut Self {
        self.push_jump(Op::Jz, ra, l)
    }

    pub fn jnz(&mut self, ra: u8, l: Label) -> &mut Self {
        self.push_jump(Op::Jnz, ra, l)
    }

    /// Call an imported symbol (args `r1..r4`, result `r0`).
    pub fn call(&mut self, import: &str) -> &mut Self {
        let slot = self.import(import);
        self.push(Op::Call, 0, 0, 0, slot)
    }

    pub fn ldb(&mut self, ra: u8, rb: u8, space: u8, imm: u32) -> &mut Self {
        self.push(Op::Ldb, ra, rb, space, imm)
    }

    pub fn ldw(&mut self, ra: u8, rb: u8, space: u8, imm: u32) -> &mut Self {
        self.push(Op::Ldw, ra, rb, space, imm)
    }

    pub fn stb(&mut self, ra: u8, rb: u8, space: u8, imm: u32) -> &mut Self {
        self.push(Op::Stb, ra, rb, space, imm)
    }

    pub fn stw(&mut self, ra: u8, rb: u8, space: u8, imm: u32) -> &mut Self {
        self.push(Op::Stw, ra, rb, space, imm)
    }

    pub fn paylen(&mut self, ra: u8) -> &mut Self {
        self.push(Op::Paylen, ra, 0, 0, 0)
    }

    pub fn nop(&mut self) -> &mut Self {
        self.push(Op::Nop, 0, 0, 0, 0)
    }

    /// Current instruction count (useful for size assertions in tests).
    pub fn len(&self) -> usize {
        self.instrs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.instrs.is_empty()
    }

    /// Resolve fixups and emit `(code bytes, import names)`.
    ///
    /// # Panics
    /// If any referenced label was never bound — an authoring bug, caught
    /// at build time exactly like an undefined assembler label.
    pub fn assemble(mut self) -> (Vec<u8>, Vec<String>) {
        for (at, l) in std::mem::take(&mut self.fixups) {
            let target = self.labels[l.0].expect("unbound label referenced");
            self.instrs[at].imm = target as u32;
        }
        let mut bytes = Vec::with_capacity(self.instrs.len() * INSTR_BYTES);
        for i in &self.instrs {
            bytes.extend_from_slice(&i.encode());
        }
        (bytes, self.imports)
    }

    /// Assemble and wrap into a map for inspection in tests.
    pub fn import_slots(&self) -> HashMap<String, u32> {
        self.imports.iter().enumerate().map(|(i, n)| (n.clone(), i as u32)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vm::isa::decode_all;

    #[test]
    fn forward_labels_are_patched() {
        let mut a = Assembler::new();
        let done = a.label();
        a.ldi(1, 5);
        a.jz(1, done);
        a.ldi(2, 7);
        a.bind(done);
        a.halt();
        let (code, _) = a.assemble();
        let instrs = decode_all(&code).unwrap();
        assert_eq!(instrs[1].imm, 3, "jz jumps past the ldi to the halt");
    }

    #[test]
    fn imports_are_deduplicated() {
        let mut a = Assembler::new();
        a.call("counter_add");
        a.call("counter_add");
        a.call("log");
        let (_, imports) = a.assemble();
        assert_eq!(imports, vec!["counter_add".to_string(), "log".to_string()]);
    }

    #[test]
    fn ldi64_emits_high_half_when_needed() {
        let mut a = Assembler::new();
        a.ldi64(3, 0x1_0000_0000);
        assert_eq!(a.len(), 2);
        let mut b = Assembler::new();
        b.ldi64(3, 42);
        assert_eq!(b.len(), 1);
    }

    #[test]
    #[should_panic(expected = "unbound label")]
    fn unbound_label_panics() {
        let mut a = Assembler::new();
        let l = a.label();
        a.jmp(l);
        a.assemble();
    }
}
