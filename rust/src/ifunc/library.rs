//! ifunc libraries — Listing 1.2 of the paper.
//!
//! A valid ifunc library defines three routines:
//! `[name]_payload_get_max_size` and `[name]_payload_init` (run on the
//! *source* to size and fill the payload without extra copies) and
//! `[name]_main` (the code shipped in the message and run on the target).
//! Here the first two are trait methods executed natively on the source,
//! and `main` is the [`CodeImage`] the library emits — TCVM bytecode plus
//! an optional HLO artifact.
//!
//! [`LibraryDir`] is the `UCX_IFUNC_LIB_DIR` analog: `register_ifunc`
//! "dlopens" libraries from it by name. Libraries are either installed
//! programmatically (built-ins, tests) or loaded from disk as **HLO
//! artifact libraries** (`<name>.json` manifest + `<name>.hlo.txt`
//! AOT-compiled by `python/compile/aot.py`).

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, RwLock};

use crate::runtime::ArtifactManifest;
use crate::vm::Assembler;
use crate::{Error, Result};

use super::message::CodeImage;

/// Opaque source-process arguments (`void *source_args, size_t size`).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SourceArgs {
    bytes: Vec<u8>,
}

impl SourceArgs {
    pub fn none() -> Self {
        Self::default()
    }

    pub fn bytes(bytes: Vec<u8>) -> Self {
        SourceArgs { bytes }
    }

    /// Pack a `f32` slice (the numeric-workload convention used by the
    /// HLO-backed libraries).
    pub fn f32s(v: &[f32]) -> Self {
        let mut bytes = Vec::with_capacity(v.len() * 4);
        for x in v {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        SourceArgs { bytes }
    }

    pub fn as_bytes(&self) -> &[u8] {
        &self.bytes
    }

    pub fn len(&self) -> usize {
        self.bytes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bytes.is_empty()
    }

    pub fn as_f32s(&self) -> Vec<f32> {
        self.bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect()
    }
}

/// An ifunc library (Listing 1.2). Implementations provide the two
/// source-side payload routines and the code image to inject.
pub trait IfuncLibrary: Send + Sync {
    /// The library name (`[ifunc_name]`, ≤ 16 bytes).
    fn name(&self) -> &str;

    /// `[name]_payload_get_max_size`: upper bound on the payload for the
    /// given source args, so the runtime can allocate the message frame
    /// once ("we eliminate unnecessary memory copies", §3.1).
    fn payload_get_max_size(&self, source_args: &SourceArgs) -> usize;

    /// `[name]_payload_init`: populate `payload` (sized to the max) from
    /// the source args; returns the number of bytes actually used.
    fn payload_init(&self, payload: &mut [u8], source_args: &SourceArgs) -> Result<usize>;

    /// The injected `[name]_main`: TCVM code + imports (+ optional HLO).
    fn code(&self) -> CodeImage;
}

/// The `UCX_IFUNC_LIB_DIR` analog: where `ucp_register_ifunc` resolves
/// names to libraries.
pub struct LibraryDir {
    dir: PathBuf,
    installed: RwLock<HashMap<String, Arc<dyn IfuncLibrary>>>,
}

impl LibraryDir {
    pub fn new(dir: PathBuf) -> Self {
        LibraryDir { dir, installed: RwLock::new(HashMap::new()) }
    }

    pub fn dir(&self) -> &PathBuf {
        &self.dir
    }

    /// Install a library programmatically (the "compile it into
    /// `<name>.so` and drop it in the directory" step of the paper's
    /// toolchain, §2.1).
    pub fn install(&self, lib: Box<dyn IfuncLibrary>) {
        self.installed.write().unwrap().insert(lib.name().to_string(), lib.into());
    }

    /// Resolve a library by name: programmatically installed first, then
    /// HLO artifact libraries from the directory (`<name>.json` +
    /// `<name>.hlo.txt`). The dlopen/dlsym analog of §3.1.
    pub fn open(&self, name: &str) -> Result<Arc<dyn IfuncLibrary>> {
        if let Some(lib) = self.installed.read().unwrap().get(name) {
            return Ok(lib.clone());
        }
        let manifest_path = self.dir.join(format!("{name}.json"));
        let hlo_path = self.dir.join(format!("{name}.hlo.txt"));
        if manifest_path.exists() && hlo_path.exists() {
            let lib = HloIfuncLibrary::load(name, &manifest_path, &hlo_path)?;
            let lib: Arc<dyn IfuncLibrary> = Arc::new(lib);
            self.installed.write().unwrap().insert(name.to_string(), lib.clone());
            return Ok(lib);
        }
        Err(Error::NoSuchLibrary(format!("{name} (searched {:?})", self.dir)))
    }

    pub fn names(&self) -> Vec<String> {
        self.installed.read().unwrap().keys().cloned().collect()
    }
}

/// An ifunc library whose `main` runs an AOT-compiled JAX/Pallas
/// computation: the payload is the `f32` input tensor, the code section
/// carries a tiny TCVM trampoline plus the **HLO artifact itself**, and the
/// target compiles it via PJRT on first sight (then hits the
/// auto-registration cache). This realizes the paper's §5.1 vision: no
/// copy of the library on the target's filesystem is required.
pub struct HloIfuncLibrary {
    name: String,
    pub manifest: ArtifactManifest,
    hlo_text: Vec<u8>,
}

impl HloIfuncLibrary {
    pub fn load(
        name: &str,
        manifest_path: &std::path::Path,
        hlo_path: &std::path::Path,
    ) -> Result<Self> {
        let manifest = ArtifactManifest::from_json(&std::fs::read_to_string(manifest_path)?)
            .map_err(|e| Error::Other(format!("bad manifest {manifest_path:?}: {e}")))?;
        let hlo_text = std::fs::read(hlo_path)?;
        Ok(HloIfuncLibrary { name: name.to_string(), manifest, hlo_text })
    }

    pub fn from_parts(name: &str, manifest: ArtifactManifest, hlo_text: Vec<u8>) -> Self {
        HloIfuncLibrary { name: name.to_string(), manifest, hlo_text }
    }

    fn input_bytes(&self) -> usize {
        self.manifest.input_elems() * 4
    }
}

impl IfuncLibrary for HloIfuncLibrary {
    fn name(&self) -> &str {
        &self.name
    }

    fn payload_get_max_size(&self, _source_args: &SourceArgs) -> usize {
        // Payload holds the input tensor; the output overwrites it in
        // place, so reserve the max of the two.
        self.input_bytes().max(self.manifest.output_elems() * 4)
    }

    fn payload_init(&self, payload: &mut [u8], source_args: &SourceArgs) -> Result<usize> {
        let need = self.input_bytes();
        if source_args.len() != need {
            return Err(Error::InvalidMessage(format!(
                "{}: source args must be {} bytes of f32 input (got {})",
                self.name,
                need,
                source_args.len()
            )));
        }
        payload[..need].copy_from_slice(source_args.as_bytes());
        Ok(payload.len())
    }

    fn code(&self) -> CodeImage {
        // Trampoline: xla_exec(in_off=0, n_in_elems, out_off=0, n_out_max).
        let mut a = Assembler::new();
        a.ldi(1, 0);
        a.ldi(2, self.manifest.input_elems() as u32);
        a.ldi(3, 0);
        a.ldi(4, self.manifest.output_elems() as u32);
        a.call("xla_exec");
        a.halt();
        let (vm_code, imports) = a.assemble();
        CodeImage { imports, vm_code, hlo: self.hlo_text.clone() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Dummy;
    impl IfuncLibrary for Dummy {
        fn name(&self) -> &str {
            "dummy"
        }
        fn payload_get_max_size(&self, a: &SourceArgs) -> usize {
            a.len()
        }
        fn payload_init(&self, p: &mut [u8], a: &SourceArgs) -> Result<usize> {
            p[..a.len()].copy_from_slice(a.as_bytes());
            Ok(a.len())
        }
        fn code(&self) -> CodeImage {
            let mut asm = Assembler::new();
            asm.halt();
            let (vm_code, imports) = asm.assemble();
            CodeImage { imports, vm_code, hlo: vec![] }
        }
    }

    #[test]
    fn installed_library_resolves() {
        let d = LibraryDir::new(PathBuf::from("/nonexistent"));
        d.install(Box::new(Dummy));
        assert_eq!(d.open("dummy").unwrap().name(), "dummy");
    }

    #[test]
    fn missing_library_errors() {
        let d = LibraryDir::new(PathBuf::from("/nonexistent"));
        let err = d.open("nope").err().expect("must fail");
        assert!(matches!(err, Error::NoSuchLibrary(_)));
    }

    #[test]
    fn source_args_f32_roundtrip() {
        let a = SourceArgs::f32s(&[1.0, -2.5, 3.25]);
        assert_eq!(a.len(), 12);
        assert_eq!(a.as_f32s(), vec![1.0, -2.5, 3.25]);
    }
}
