//! Pluggable ifunc delivery transports.
//!
//! The paper ships frames with one-sided RDMA PUTs into a target-managed
//! ring (§3.3) and names send-receive delivery as the successor (§5.1).
//! All three now exist behind one sender-side abstraction, so the
//! coordinator, the serve path, and the ablation benches are
//! transport-generic:
//!
//! * [`RingTransport`] — PUT frames through a [`SenderCursor`] into the
//!   worker's RWX ring, with wrap markers and byte-credit flow control,
//! * [`AmTransport`] — ship each frame as the payload of the reserved
//!   ifunc active message; the worker's `ucp_worker_progress` executes it,
//! * [`super::shm_transport::ShmTransport`] — the same ring protocol for
//!   a *colocated* worker (§1's SmartNIC/DPU/CSD on the host): frames are
//!   memcpy'd straight into the shared ring mapping through a
//!   [`PutSink::Shm`], skipping the `Endpoint::put_nbi` emulation, the
//!   NIC engine, and the wire model entirely.
//!
//! All take multi-frame batches through [`IfuncTransport::send_batch`]:
//! the ring protocol (fabric and shm alike) coalesces a batch into
//! **one** credit reservation (instead of one capacity wait per frame)
//! and one flush, and the AM path posts the whole batch before a single
//! flush — the seam `Dispatcher::scatter` delivers per-worker buckets
//! through. Collective invocations ride the same seam one frame at a
//! time: [`IfuncTransport::post_frame`] places a frame without flushing,
//! so `Dispatcher::invoke_multi` can post every member's frame first and
//! run one flush pass over the fan-out, letting per-link transfers
//! overlap.
//!
//! Every transport also owns the link's [`ReplyRing`] (the `invoke`
//! return path) and its [`ConsumedCounter`] (the `barrier` completion
//! credit). The two are deliberately separate: a streamed reply occupies
//! *k* reply seqs for one ingress frame, so "reply seq == frames sent" is
//! no longer a consumption signal — the worker instead advances the
//! consumed counter once per ingress frame it handles, executed or
//! rejected.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::fabric::{MemPerm, MemoryRegion, RKey};
use crate::ucp::{Context, Endpoint};
use crate::{Error, Result};

use super::am_transport::ifunc_msg_send_am;
use super::message::IfuncMsg;
use super::reply::ReplyRing;
use super::ring::{wrap_marker_word, SenderCursor};

/// Where a sender's one-sided puts land: through a fabric endpoint onto a
/// peer's rkey-registered region (the emulated-RDMA path, paying NIC
/// engine + wire model + completion tracking), or directly into a
/// process-shared mapping (the intra-node shm path — the same
/// data-before-signal ordering via [`MemoryRegion::put_local`], but no
/// rkey lookup, no posted operation, and a no-op flush). The ring
/// protocol, the reply writer, and the credit words are all written
/// against this seam, which is what lets `ShmTransport` reuse them
/// byte-for-byte.
#[derive(Clone)]
pub(crate) enum PutSink {
    /// Emulated fabric: `ep.put_nbi(rkey, ..)`, flushed for completion.
    Fabric { ep: Arc<Endpoint>, rkey: RKey },
    /// Same-address-space delivery into a shared mapping.
    Shm(Arc<MemoryRegion>),
}

impl PutSink {
    pub(crate) fn put(&self, offset: usize, data: &[u8]) -> Result<()> {
        match self {
            PutSink::Fabric { ep, rkey } => ep.put_nbi(*rkey, offset, data),
            PutSink::Shm(mr) => mr.put_local(offset, data),
        }
    }

    /// 8-byte signal put (release-stored on delivery on both paths).
    pub(crate) fn signal(&self, offset: usize, value: u64) -> Result<()> {
        match self {
            PutSink::Fabric { ep, rkey } => ep.qp().put_signal(*rkey, offset, value),
            PutSink::Shm(mr) => mr.store_u64_release(offset, value),
        }
    }

    /// Wait for completion of every posted put. Shm puts complete at the
    /// store itself, so there is nothing to wait for.
    pub(crate) fn flush(&self) -> Result<()> {
        match self {
            PutSink::Fabric { ep, .. } => ep.flush(),
            PutSink::Shm(_) => Ok(()),
        }
    }
}

/// Leader-side view of a link's **consumed-frame counter**: an 8-byte
/// word the worker advances (with the same signal-put the ring's byte
/// credit uses) once per ingress frame it has handled — executed or
/// rejected. `Dispatcher::barrier` waits on this instead of on reply
/// seqs, because a chunked reply advances the reply ring by more than one
/// slot per frame. Cheap to clone (the mapping is shared).
#[derive(Clone)]
pub struct ConsumedCounter {
    mr: Arc<MemoryRegion>,
    timeout: Option<Duration>,
}

impl ConsumedCounter {
    /// Map the counter word on `ctx` (the sender/leader side); `timeout`
    /// bounds [`ConsumedCounter::wait`] the same way the reply timeout
    /// bounds reply waits.
    pub fn new(ctx: &Context, timeout: Option<Duration>) -> Self {
        // A plain counter word: peers write and the owner reads — it
        // never needs the atomic bit, so no RWX grant (that stays with
        // the code ring alone).
        ConsumedCounter { mr: ctx.mem_map(64, MemPerm::RW), timeout }
    }

    /// The rkey the worker's signal-puts target.
    pub fn rkey(&self) -> RKey {
        self.mr.rkey()
    }

    /// The counter word itself, for a *colocated* worker that advances it
    /// with a release-store instead of a fabric signal-put (shm links).
    pub(crate) fn region(&self) -> Arc<MemoryRegion> {
        self.mr.clone()
    }

    /// Ingress frames the worker has reported consumed so far.
    pub fn frames(&self) -> Result<u64> {
        self.mr.load_u64_acquire(0)
    }

    /// Block until the worker has consumed `target` frames, invoking
    /// `progress` each spin (the streamed-reply path drains the link's
    /// reply collector there, so a worker parked on reply credit can
    /// never stall the barrier). The timeout is progress-based: any
    /// advance of the counter resets the deadline.
    pub fn wait(&self, target: u64, mut progress: impl FnMut() -> Result<()>) -> Result<()> {
        let mut deadline = self.timeout.map(|d| Instant::now() + d);
        let mut last = None;
        let mut i = 0u32;
        loop {
            let consumed = self.frames()?;
            if consumed >= target {
                return Ok(());
            }
            progress()?;
            if last != Some(consumed) {
                last = Some(consumed);
                deadline = self.timeout.map(|d| Instant::now() + d);
            }
            if let Some(d) = deadline {
                if Instant::now() > d {
                    return Err(Error::Transport(format!(
                        "worker consumed {consumed} of {target} frames with no progress \
                         for {:?} (dead or stalled?)",
                        self.timeout.unwrap_or_default()
                    )));
                }
            }
            crate::fabric::wire::backoff(i);
            i += 1;
        }
    }
}

/// A sender-side ifunc delivery channel to one worker.
pub trait IfuncTransport: Send {
    /// Flow-controlled, non-blocking delivery of one frame. Completion is
    /// observed via [`IfuncTransport::flush`]; execution via the replies.
    fn send_frame(&mut self, msg: &IfuncMsg) -> Result<()>;

    /// Post a batch of frames without waiting for completion, so batches
    /// to *different* links can overlap (a later [`IfuncTransport::flush`]
    /// observes completion). The default posts frame-at-a-time;
    /// transports override to amortize per-frame costs (the ring
    /// coalesces the batch's credit reservation into one wait).
    fn post_batch(&mut self, msgs: &[IfuncMsg]) -> Result<()> {
        for msg in msgs {
            self.send_frame(msg)?;
        }
        Ok(())
    }

    /// Post one frame without waiting for completion — the single-frame
    /// form of [`IfuncTransport::post_batch`]. This is the seam the
    /// dispatcher's collective fan-out (`invoke_multi` / `invoke_all`)
    /// delivers through: the same frame is posted on every targeted
    /// link, then one flush pass covers the whole fan-out, so the
    /// per-link transfers overlap instead of paying one completion
    /// round-trip per worker.
    fn post_frame(&mut self, msg: &IfuncMsg) -> Result<()> {
        self.post_batch(std::slice::from_ref(msg))
    }

    /// Deliver a batch of frames with one flush at the end:
    /// [`IfuncTransport::post_batch`] + [`IfuncTransport::flush`].
    fn send_batch(&mut self, msgs: &[IfuncMsg]) -> Result<()> {
        self.post_batch(msgs)?;
        self.flush()
    }

    /// Wait for local + remote completion of every posted send.
    fn flush(&self) -> Result<()>;

    /// Frames sent over this link so far (the seq of the last frame).
    fn frames_sent(&self) -> u64;

    /// The link's reply ring (reply frames, possibly several per consumed
    /// frame when replies stream).
    fn replies(&self) -> &ReplyRing;

    /// The link's consumed-frame counter (one tick per ingress frame).
    fn consumed(&self) -> &ConsumedCounter;

    /// Block until the worker has consumed — executed or rejected — every
    /// frame sent so far, per its consumed-frame counter. Callers that
    /// must keep a reply collector moving while they wait (the streamed
    /// dispatcher barrier) should wait on [`IfuncTransport::consumed`]
    /// directly with a drain hook.
    fn wait_consumed(&self) -> Result<()> {
        self.consumed().wait(self.frames_sent(), || Ok(()))
    }

    /// Fault-injection hook for the security tests: write raw bytes into
    /// the delivery channel's remote buffer, bypassing framing. Errors on
    /// transports without a raw remote buffer.
    #[doc(hidden)]
    fn debug_put_raw(&mut self, _offset: usize, _data: &[u8]) -> Result<()> {
        Err(Error::Other("raw ring access unsupported on this transport".into()))
    }
}

/// Ring-protocol frame delivery: the paper's §3 transport when its sink
/// is a fabric endpoint ([`RingTransport::new`]), and the intra-node shm
/// fast path when the sink is the shared ring mapping itself
/// ([`super::shm_transport::ShmTransport`] wraps that flavor). One
/// implementation, one wire format, one `SenderCursor`/wrap-marker
/// protocol — only where the bytes land differs.
pub struct RingTransport {
    /// Where frame/marker puts land (fabric endpoint or shared mapping).
    sink: PutSink,
    /// Worker ring placement cursor.
    cursor: SenderCursor,
    ring_bytes: usize,
    /// Bytes sent (frames + wrap markers).
    sent_bytes: u64,
    frames: u64,
    /// Sender-local word the worker writes its consumed-bytes count into.
    credit: Arc<MemoryRegion>,
    replies: ReplyRing,
    consumed: ConsumedCounter,
}

impl RingTransport {
    pub fn new(
        ep: Arc<Endpoint>,
        ring_rkey: RKey,
        ring_bytes: usize,
        credit: Arc<MemoryRegion>,
        replies: ReplyRing,
        consumed: ConsumedCounter,
    ) -> Self {
        Self::with_sink(
            PutSink::Fabric { ep, rkey: ring_rkey },
            ring_bytes,
            credit,
            replies,
            consumed,
        )
    }

    pub(crate) fn with_sink(
        sink: PutSink,
        ring_bytes: usize,
        credit: Arc<MemoryRegion>,
        replies: ReplyRing,
        consumed: ConsumedCounter,
    ) -> Self {
        RingTransport {
            sink,
            cursor: SenderCursor::new(ring_bytes),
            ring_bytes,
            sent_bytes: 0,
            frames: 0,
            credit,
            replies,
            consumed,
        }
    }

    /// Block until the ring can absorb `needed` more bytes. `needed` must
    /// count the *whole* cost of the upcoming send — on a wrap that is the
    /// skipped ring tail plus the frame, not just the frame (the tail is
    /// credited back by the worker's `rewind`). `needed` may not exceed
    /// the ring: when tail + frame would (a frame longer than the current
    /// ring offset), the frame at offset 0 overlaps the wrap marker, so
    /// the sender drains the ring and publishes the marker *before* the
    /// frame (see [`RingTransport::send_frame`]).
    ///
    /// The wait is deadline-bounded the same way `ConsumedCounter::wait`
    /// is: any advance of the worker's byte credit resets the clock, and a
    /// credit that never moves for the link's `reply_timeout` surfaces as
    /// [`Error::Transport`] — a worker that dies with a full ring fails
    /// the sender instead of hanging it forever. (This used to be the one
    /// wait in the codebase with no deadline.)
    fn wait_capacity(&self, needed: usize) -> Result<()> {
        let budget = self.ring_bytes.saturating_sub(needed) as u64;
        let timeout = self.replies.timeout;
        let mut deadline = timeout.map(|d| Instant::now() + d);
        let mut last = None;
        let mut i = 0u32;
        loop {
            let consumed = self.credit.load_u64_acquire(0)?;
            if self.sent_bytes.saturating_sub(consumed) <= budget {
                return Ok(());
            }
            if last != Some(consumed) {
                last = Some(consumed);
                deadline = timeout.map(|d| Instant::now() + d);
            }
            if let Some(d) = deadline {
                if Instant::now() > d {
                    return Err(Error::Transport(format!(
                        "no ring credit progress for {:?} while waiting for {needed} \
                         bytes of ring capacity (worker dead with a full ring?)",
                        timeout.unwrap_or_default()
                    )));
                }
            }
            crate::fabric::wire::backoff(i);
            i += 1;
        }
    }

    /// Place one frame at the cursor and PUT marker + frame, charging
    /// `sent_bytes`. Callers must have reserved the frame's
    /// [`placement_cost`] via [`RingTransport::wait_capacity`] first.
    fn put_frame(&mut self, msg: &IfuncMsg) -> Result<()> {
        let placement = self.cursor.place(msg.len())?;
        if let Some(at) = placement.wrap_marker_at {
            // The wrap consumes the ring tail through the marker.
            self.sink.put(at, &wrap_marker_word().to_le_bytes())?;
            self.sent_bytes += (self.ring_bytes - at) as u64;
        }
        self.sink.put(placement.offset, msg.frame())?;
        self.sent_bytes += msg.len() as u64;
        self.frames += 1;
        Ok(())
    }
}

/// Credit cost of placing a `frame_len`-byte frame with the sender cursor
/// in state `cursor`: the frame alone on the straight path, skipped tail +
/// frame on a wrap. `None` when the frame needs the drain-then-marker
/// special path (tail + frame exceed the ring, so the frame at offset 0
/// would overlap the wrap marker).
fn placement_cost(cursor: &SenderCursor, ring_bytes: usize, frame_len: usize) -> Option<usize> {
    let tail = cursor.remaining_before_wrap();
    if frame_len > tail && tail + frame_len > ring_bytes {
        return None;
    }
    Some(if frame_len > tail { tail + frame_len } else { frame_len })
}

impl IfuncTransport for RingTransport {
    fn send_frame(&mut self, msg: &IfuncMsg) -> Result<()> {
        if placement_cost(&self.cursor, self.ring_bytes, msg.len()).is_none() {
            // Wrap where skipped tail + frame exceed the ring: the frame at
            // offset 0 would overwrite the wrap marker before the parked
            // poller reads it. Drain the ring, publish the marker alone,
            // and wait for the poller's rewind credit before the frame.
            let tail = self.cursor.remaining_before_wrap();
            self.wait_capacity(self.ring_bytes)?;
            let at = self.ring_bytes - tail;
            self.sink.put(at, &wrap_marker_word().to_le_bytes())?;
            self.sent_bytes += tail as u64;
            self.sink.flush()?;
            self.wait_capacity(self.ring_bytes)?;
            self.cursor.reset();
        }
        // Seed bug (fixed in PR 1): this waited for `frame + 8` bytes of
        // room, but a frame that does not fit before the ring end also
        // consumes the wasted tail through the wrap marker — under load
        // the sender could lap the poller and overwrite an unconsumed
        // frame at offset 0. Reserve the exact placement cost (tail +
        // frame on a wrap) instead.
        let needed = placement_cost(&self.cursor, self.ring_bytes, msg.len())
            .unwrap_or(msg.len());
        self.wait_capacity(needed)?;
        self.put_frame(msg)
    }

    /// One credit reservation for the whole batch: simulate the cursor
    /// over the frames, sum their placement costs, wait for that much
    /// capacity once, then PUT every frame back-to-back. Falls back to
    /// frame-at-a-time when a frame needs the drain-then-marker path or
    /// the batch exceeds the ring.
    fn post_batch(&mut self, msgs: &[IfuncMsg]) -> Result<()> {
        let mut sim = self.cursor.clone();
        let mut total = 0usize;
        let mut coalesce = true;
        for msg in msgs {
            let cost = match placement_cost(&sim, self.ring_bytes, msg.len()) {
                Some(c) if total + c <= self.ring_bytes => c,
                _ => {
                    coalesce = false;
                    break;
                }
            };
            if sim.place(msg.len()).is_err() {
                coalesce = false;
                break;
            }
            total += cost;
        }
        if coalesce {
            self.wait_capacity(total)?;
            for msg in msgs {
                self.put_frame(msg)?;
            }
        } else {
            for msg in msgs {
                self.send_frame(msg)?;
            }
        }
        Ok(())
    }

    fn flush(&self) -> Result<()> {
        self.sink.flush()
    }

    fn frames_sent(&self) -> u64 {
        self.frames
    }

    fn replies(&self) -> &ReplyRing {
        &self.replies
    }

    fn consumed(&self) -> &ConsumedCounter {
        &self.consumed
    }

    fn debug_put_raw(&mut self, offset: usize, data: &[u8]) -> Result<()> {
        self.sink.put(offset, data)?;
        self.sink.flush()
    }
}

/// Send-receive delivery (§5.1): frames ride the reserved ifunc AM and the
/// worker executes them from `ucp_worker_progress`. No RWX ring, no rkey
/// consensus — and no in-place execution (the receive path pays a
/// copy-on-execute).
pub struct AmTransport {
    ep: Arc<Endpoint>,
    frames: u64,
    replies: ReplyRing,
    consumed: ConsumedCounter,
}

impl AmTransport {
    pub fn new(ep: Arc<Endpoint>, replies: ReplyRing, consumed: ConsumedCounter) -> Self {
        AmTransport { ep, frames: 0, replies, consumed }
    }
}

impl IfuncTransport for AmTransport {
    fn send_frame(&mut self, msg: &IfuncMsg) -> Result<()> {
        ifunc_msg_send_am(&self.ep, msg)?;
        self.frames += 1;
        Ok(())
    }

    /// Post the whole batch as back-to-back AM sends — completion waits
    /// (and rendezvous handshakes) amortize over the batch instead of
    /// serializing per frame; `send_batch`'s single flush observes them.
    fn post_batch(&mut self, msgs: &[IfuncMsg]) -> Result<()> {
        for msg in msgs {
            ifunc_msg_send_am(&self.ep, msg)?;
            self.frames += 1;
        }
        Ok(())
    }

    fn flush(&self) -> Result<()> {
        self.ep.flush()
    }

    fn frames_sent(&self) -> u64 {
        self.frames
    }

    fn replies(&self) -> &ReplyRing {
        &self.replies
    }

    fn consumed(&self) -> &ConsumedCounter {
        &self.consumed
    }
}

/// Which delivery transport a cluster (or bench) uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TransportKind {
    /// One-sided RDMA-PUT frames into per-worker rings (paper §3).
    #[default]
    Ring,
    /// Frames as active-message payloads (paper §5.1).
    Am,
    /// Intra-node shared memory: the ring protocol with frames memcpy'd
    /// directly into the colocated worker's ring mapping (the paper's §1
    /// SmartNIC/DPU/CSD-on-the-host deployment; no fabric emulation).
    Shm,
}

impl TransportKind {
    pub fn label(&self) -> &'static str {
        match self {
            TransportKind::Ring => "ring",
            TransportKind::Am => "am",
            TransportKind::Shm => "shm",
        }
    }

    /// Every delivery transport, for test/bench scenario matrices.
    pub const ALL: [TransportKind; 3] =
        [TransportKind::Ring, TransportKind::Am, TransportKind::Shm];
}

impl std::str::FromStr for TransportKind {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "ring" => Ok(TransportKind::Ring),
            "am" => Ok(TransportKind::Am),
            "shm" => Ok(TransportKind::Shm),
            other => Err(Error::Other(format!("unknown transport {other:?} (ring|am|shm)"))),
        }
    }
}
