//! ifunc message frames — Fig. 1 of the paper, realized.
//!
//! ```text
//!  | HEADER (incl. header check + trailer sig   | 72 B
//!  |         + hop metadata: origin seq/worker, |
//!  |         hop count, TTL, frame kind)        |
//!  | CODE  (GOT slot, import table, TCVM code,  | code_len
//!  |        optional HLO artifact blob)         |
//!  | PAYLOAD (aligned per IfuncMsgParams)       | payload_len
//!  | ...pad to 8...                             |
//!  | TRAILER SIGNAL                             | 8 B
//! ```
//!
//! The frame is delivered with a single one-sided put. The fabric (like
//! InfiniBand) writes the final 8 bytes last, so the poller's protocol is
//! exactly the paper's Fig. 2: validate the header via its check word,
//! then `wait_mem` on the trailer signal, then link + flush + invoke.
//!
//! The *code section* opens with the GOT-pointer slot — the "hidden global
//! variable" the paper's toolchain inserts (§3.4) — which ships as
//! `UNPATCHED` and is overwritten by the target with the id of the
//! reconstructed GOT before invocation.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::vm::AdmissionFacts;
use crate::{Error, Result};

/// First word of a live frame header.
pub const MAGIC: u32 = 0x1FC0_DE01;
/// First word of a wrap marker: "frame stream continues at ring offset 0".
pub const WRAP_MAGIC: u32 = 0x1FC0_DEFF;
pub const HEADER_BYTES: usize = 72;
pub const TRAILER_BYTES: usize = 8;
pub const NAME_BYTES: usize = 16;
/// Default hop budget for mesh-forwarded frames (`forward` host symbol):
/// each hop decrements it, and a frame arriving with TTL 0 may not be
/// forwarded again — a 2-cycle forward loop dies after at most 8 hops.
pub const DEFAULT_TTL: u8 = 8;
/// `Hop::origin_worker` sentinel: the frame came straight from the leader
/// and has never been forwarded.
pub const NO_ORIGIN_WORKER: u16 = 0xFFFF;
/// `Hop::kind`: a normal invocation frame (execute on arrival).
pub const HOP_KIND_INVOKE: u8 = 0;
/// `Hop::kind`: a mesh relay frame carrying a finished reply back to the
/// forwarding chain's origin worker — never executed.
pub const HOP_KIND_RELAY: u8 = 1;
/// Reserved name of relay frames (kind is authoritative; the name makes
/// relay frames self-describing in ring dumps).
pub const RELAY_NAME: &str = "__relay";
/// Value of the GOT slot before target-side patching.
pub const GOT_UNPATCHED: u32 = 0xFFFF_FFFF;
/// Reject frames bigger than this even if the ring could hold them
/// (§3.4: "messages that are ill-formed or too long will be rejected").
pub const MAX_FRAME_BYTES: usize = 64 << 20;

/// Trailer signals are salted per message so a frame landing over stale
/// ring bytes can never accidentally observe "arrived".
static TRAILER_SALT: AtomicU64 = AtomicU64::new(0x9E37_79B9_7F4A_7C15);

fn fresh_trailer_sig() -> u64 {
    // Never zero (zero means "not arrived") and never equal to a previous
    // salt with overwhelming probability.
    TRAILER_SALT.fetch_add(0x6C62_272E_07BB_0142, Ordering::Relaxed) | 1
}

/// Per-frame hop metadata — the mesh-forwarding extension. A frame fresh
/// off the leader carries the defaults; the first `forward` hop stamps the
/// origin (leader-ingress seq + worker index) so the *final* hop's reply
/// can route back to the leader's `ReplyCollector` under the seq the
/// leader registered, however many workers the frame visited in between.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Hop {
    /// Leader-ingress frame seq at the origin worker (reply attribution).
    pub origin_seq: u64,
    /// Worker the leader originally injected into ([`NO_ORIGIN_WORKER`]
    /// until the first forward hop stamps it).
    pub origin_worker: u16,
    /// Hops taken so far (0 = straight from the leader).
    pub hops: u8,
    /// Remaining hop budget; a frame with TTL 0 may not forward again.
    pub ttl: u8,
    /// [`HOP_KIND_INVOKE`] or [`HOP_KIND_RELAY`].
    pub kind: u8,
}

impl Default for Hop {
    fn default() -> Self {
        Hop {
            origin_seq: 0,
            origin_worker: NO_ORIGIN_WORKER,
            hops: 0,
            ttl: DEFAULT_TTL,
            kind: HOP_KIND_INVOKE,
        }
    }
}

/// Parsed frame header.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Header {
    pub frame_len: u32,
    pub trailer_sig: u64,
    pub code_offset: u32,
    pub code_len: u32,
    pub payload_offset: u32,
    pub payload_len: u32,
    pub got_offset: u32,
    pub hop: Hop,
    pub name: String,
}

impl Header {
    fn check_word(&self, name_bytes: &[u8; NAME_BYTES]) -> u32 {
        let mut x = MAGIC ^ self.frame_len ^ self.code_len ^ self.payload_len
            ^ self.payload_offset ^ self.code_offset ^ self.got_offset;
        x ^= (self.trailer_sig as u32) ^ ((self.trailer_sig >> 32) as u32);
        x ^= (self.hop.origin_seq as u32) ^ ((self.hop.origin_seq >> 32) as u32);
        x ^= (self.hop.origin_worker as u32)
            | ((self.hop.hops as u32) << 16)
            | ((self.hop.ttl as u32) << 24);
        x ^= self.hop.kind as u32;
        for chunk in name_bytes.chunks(4) {
            x ^= u32::from_le_bytes(chunk.try_into().unwrap());
        }
        x
    }

    pub fn encode(&self) -> [u8; HEADER_BYTES] {
        let mut name_bytes = [0u8; NAME_BYTES];
        let n = self.name.as_bytes();
        name_bytes[..n.len()].copy_from_slice(n);
        let mut out = [0u8; HEADER_BYTES];
        out[0..4].copy_from_slice(&MAGIC.to_le_bytes());
        out[4..8].copy_from_slice(&self.frame_len.to_le_bytes());
        out[8..16].copy_from_slice(&self.trailer_sig.to_le_bytes());
        out[16..20].copy_from_slice(&self.code_offset.to_le_bytes());
        out[20..24].copy_from_slice(&self.code_len.to_le_bytes());
        out[24..28].copy_from_slice(&self.payload_offset.to_le_bytes());
        out[28..32].copy_from_slice(&self.payload_len.to_le_bytes());
        out[32..36].copy_from_slice(&self.got_offset.to_le_bytes());
        out[36..40].copy_from_slice(&self.check_word(&name_bytes).to_le_bytes());
        out[40..48].copy_from_slice(&self.hop.origin_seq.to_le_bytes());
        out[48..50].copy_from_slice(&self.hop.origin_worker.to_le_bytes());
        out[50] = self.hop.hops;
        out[51] = self.hop.ttl;
        out[52] = self.hop.kind;
        // out[53..56] reserved (zero).
        out[56..72].copy_from_slice(&name_bytes);
        out
    }

    /// Parse + integrity-check a header (the paper's "header signal"
    /// verification). `Ok(None)` means "no message here" (magic is zero);
    /// `Err` means ill-formed.
    pub fn decode(bytes: &[u8]) -> Result<Option<Header>> {
        if bytes.len() < HEADER_BYTES {
            return Err(Error::InvalidMessage("short header".into()));
        }
        let word = |o: usize| u32::from_le_bytes(bytes[o..o + 4].try_into().unwrap());
        let magic = word(0);
        if magic == 0 {
            return Ok(None);
        }
        if magic != MAGIC {
            return Err(Error::InvalidMessage(format!("bad magic {magic:#010x}")));
        }
        let mut name_bytes = [0u8; NAME_BYTES];
        name_bytes.copy_from_slice(&bytes[56..72]);
        let h = Header {
            frame_len: word(4),
            trailer_sig: u64::from_le_bytes(bytes[8..16].try_into().unwrap()),
            code_offset: word(16),
            code_len: word(20),
            payload_offset: word(24),
            payload_len: word(28),
            got_offset: word(32),
            hop: Hop {
                origin_seq: u64::from_le_bytes(bytes[40..48].try_into().unwrap()),
                origin_worker: u16::from_le_bytes(bytes[48..50].try_into().unwrap()),
                hops: bytes[50],
                ttl: bytes[51],
                kind: bytes[52],
            },
            name: String::from_utf8_lossy(
                &name_bytes[..name_bytes.iter().position(|&b| b == 0).unwrap_or(NAME_BYTES)],
            )
            .into_owned(),
        };
        if h.check_word(&name_bytes) != word(36) {
            return Err(Error::InvalidMessage("header check mismatch".into()));
        }
        h.validate()?;
        Ok(Some(h))
    }

    /// Structural sanity: every section inside the frame, ordered, aligned.
    pub fn validate(&self) -> Result<()> {
        let fl = self.frame_len as usize;
        let bad = |m: &str| Err(Error::InvalidMessage(m.into()));
        if fl < HEADER_BYTES + TRAILER_BYTES || fl % 8 != 0 || fl > MAX_FRAME_BYTES {
            return bad("bad frame length");
        }
        if self.code_offset as usize != HEADER_BYTES {
            return bad("code section must follow header");
        }
        let code_end = self.code_offset as usize + self.code_len as usize;
        let pay_end = self.payload_offset as usize + self.payload_len as usize;
        if code_end > fl - TRAILER_BYTES || (self.payload_offset as usize) < code_end {
            return bad("code section out of range");
        }
        if pay_end > fl - TRAILER_BYTES {
            return bad("payload out of range");
        }
        if (self.got_offset as usize) < HEADER_BYTES
            || self.got_offset as usize + 4 > code_end
        {
            return bad("GOT slot outside code section");
        }
        if self.hop.kind > HOP_KIND_RELAY {
            return bad("unknown frame kind");
        }
        Ok(())
    }
}

/// The logical content of a code section.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct CodeImage {
    /// Imported symbol names, in GOT slot order.
    pub imports: Vec<String>,
    /// TCVM bytecode (entry `[name]_main`).
    pub vm_code: Vec<u8>,
    /// Optional AOT-compiled HLO artifact (text), carried with the message
    /// so the target needs no filesystem copy of the library — the paper's
    /// §5.1 "vision" transport where code is fully self-contained.
    pub hlo: Vec<u8>,
}

impl CodeImage {
    /// Serialize:
    /// `[got_slot u32][n_imports u16][pad u16]([len u8][name])*`
    /// `[vm_len u32][vm][hlo_len u32][hlo]`
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(
            16 + self.vm_code.len()
                + self.hlo.len()
                + self.imports.iter().map(|s| s.len() + 1).sum::<usize>(),
        );
        out.extend_from_slice(&GOT_UNPATCHED.to_le_bytes());
        out.extend_from_slice(&(self.imports.len() as u16).to_le_bytes());
        out.extend_from_slice(&[0u8; 2]);
        for name in &self.imports {
            assert!(name.len() <= u8::MAX as usize, "import name too long");
            out.push(name.len() as u8);
            out.extend_from_slice(name.as_bytes());
        }
        out.extend_from_slice(&(self.vm_code.len() as u32).to_le_bytes());
        out.extend_from_slice(&self.vm_code);
        out.extend_from_slice(&(self.hlo.len() as u32).to_le_bytes());
        out.extend_from_slice(&self.hlo);
        out
    }

    /// Borrowed decode — the poll hot path uses this to avoid copying the
    /// vm code and (potentially large) HLO blob out of the ring on every
    /// arrival (§Perf: the owned decode allocated 3 vectors per message).
    pub fn decode_ref(bytes: &[u8]) -> Result<(u32, CodeImageRef<'_>)> {
        let short = || Error::InvalidMessage("truncated code section".into());
        let mut off = 0usize;
        let take = |off: &mut usize, n: usize| -> Result<&[u8]> {
            let s = bytes.get(*off..*off + n).ok_or_else(short)?;
            *off += n;
            Ok(s)
        };
        let got_slot = u32::from_le_bytes(take(&mut off, 4)?.try_into().unwrap());
        let n_imports = u16::from_le_bytes(take(&mut off, 2)?.try_into().unwrap()) as usize;
        take(&mut off, 2)?;
        let mut imports = Vec::with_capacity(n_imports);
        for _ in 0..n_imports {
            let len = take(&mut off, 1)?[0] as usize;
            let name = std::str::from_utf8(take(&mut off, len)?)
                .map_err(|_| Error::InvalidMessage("non-utf8 import name".into()))?;
            imports.push(name);
        }
        let vm_len = u32::from_le_bytes(take(&mut off, 4)?.try_into().unwrap()) as usize;
        let vm_code = take(&mut off, vm_len)?;
        let hlo_len = u32::from_le_bytes(take(&mut off, 4)?.try_into().unwrap()) as usize;
        let hlo = take(&mut off, hlo_len)?;
        Ok((got_slot, CodeImageRef { imports, vm_code, hlo }))
    }

    pub fn decode(bytes: &[u8]) -> Result<(u32, CodeImage)> {
        let short = || Error::InvalidMessage("truncated code section".into());
        let mut off = 0usize;
        let take = |off: &mut usize, n: usize| -> Result<&[u8]> {
            let s = bytes.get(*off..*off + n).ok_or_else(short)?;
            *off += n;
            Ok(s)
        };
        let got_slot = u32::from_le_bytes(take(&mut off, 4)?.try_into().unwrap());
        let n_imports = u16::from_le_bytes(take(&mut off, 2)?.try_into().unwrap()) as usize;
        take(&mut off, 2)?;
        let mut imports = Vec::with_capacity(n_imports);
        for _ in 0..n_imports {
            let len = take(&mut off, 1)?[0] as usize;
            let name = std::str::from_utf8(take(&mut off, len)?)
                .map_err(|_| Error::InvalidMessage("non-utf8 import name".into()))?;
            imports.push(name.to_string());
        }
        let vm_len = u32::from_le_bytes(take(&mut off, 4)?.try_into().unwrap()) as usize;
        let vm_code = take(&mut off, vm_len)?.to_vec();
        let hlo_len = u32::from_le_bytes(take(&mut off, 4)?.try_into().unwrap()) as usize;
        let hlo = take(&mut off, hlo_len)?.to_vec();
        Ok((got_slot, CodeImage { imports, vm_code, hlo }))
    }
}

/// Borrowed view of a code section (see [`CodeImage::decode_ref`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CodeImageRef<'a> {
    pub imports: Vec<&'a str>,
    pub vm_code: &'a [u8],
    pub hlo: &'a [u8],
}

impl CodeImageRef<'_> {
    /// FNV-1a fingerprint of the executable content (vm code + HLO blob,
    /// length-delimited). The code cache stores this next to the verified
    /// program so a frame shipping *different* code under a cached name is
    /// detected and relinked rather than silently served the old program.
    pub fn fingerprint(&self) -> u64 {
        fn eat(mut h: u64, bytes: &[u8]) -> u64 {
            for &b in bytes {
                h = (h ^ b as u64).wrapping_mul(0x100_0000_01b3);
            }
            h
        }
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        h = eat(h, &(self.vm_code.len() as u64).to_le_bytes());
        h = eat(h, self.vm_code);
        h = eat(h, &(self.hlo.len() as u64).to_le_bytes());
        eat(h, self.hlo)
    }

    pub fn to_owned_image(&self) -> CodeImage {
        CodeImage {
            imports: self.imports.iter().map(|s| s.to_string()).collect(),
            vm_code: self.vm_code.to_vec(),
            hlo: self.hlo.to_vec(),
        }
    }
}

/// Frame-construction knobs (the §5.1 payload-alignment extension).
#[derive(Debug, Clone, Copy)]
pub struct IfuncMsgParams {
    /// Payload start alignment within the frame (power of two, >= 1).
    /// "We plan to allow the user to specify an alignment requirement on
    /// the payload buffer to better support vectorization" — implemented.
    pub payload_align: usize,
}

impl Default for IfuncMsgParams {
    fn default() -> Self {
        IfuncMsgParams { payload_align: 8 }
    }
}

/// A fully-built, sendable ifunc message (`ucp_ifunc_msg_t`). Reusable:
/// sending does not consume it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct IfuncMsg {
    frame: Vec<u8>,
    name: String,
    payload_offset: usize,
    payload_len: usize,
    /// Source-side static-analysis summary of the shipped code, stamped by
    /// `msg_create` when the local context could verify + analyze the
    /// program. Advisory: the dispatcher uses it to refuse doomed
    /// invocations (fuel floor above the target budget, capability
    /// mismatch) *before* fan-out; targets never trust it — they re-run
    /// the full verify → analyze pipeline on cache misses regardless.
    /// `None` on hand-assembled or relayed frames, which simply skip
    /// source-side admission.
    facts: Option<Arc<AdmissionFacts>>,
}

impl IfuncMsg {
    /// Assemble a frame, filling the payload **in place** via `init`
    /// (`payload_init`): the frame is allocated once for the library's
    /// declared max payload, `init` writes directly into it, and the frame
    /// is shrunk if fewer bytes were produced — no separate payload
    /// buffer, per §3.1.
    pub fn assemble_with(
        name: &str,
        code: &CodeImage,
        max_payload: usize,
        params: IfuncMsgParams,
        init: impl FnOnce(&mut [u8]) -> Result<usize>,
    ) -> Result<IfuncMsg> {
        let mut msg = Self::assemble_uninit(name, code, max_payload, params)?;
        let used = init(msg.payload_mut())?;
        if used > max_payload {
            return Err(Error::InvalidMessage(format!(
                "payload_init produced {used} bytes > declared max {max_payload}"
            )));
        }
        if used < max_payload {
            msg.shrink_payload(used);
        }
        Ok(msg)
    }

    /// Assemble a frame from a code image and an already-initialized
    /// payload (copies the payload; `assemble_with` avoids the copy).
    pub fn assemble(
        name: &str,
        code: &CodeImage,
        payload: &[u8],
        params: IfuncMsgParams,
    ) -> Result<IfuncMsg> {
        Self::assemble_with(name, code, payload.len(), params, |dst| {
            dst.copy_from_slice(payload);
            Ok(payload.len())
        })
    }

    /// Build a frame with a zeroed payload of exactly `payload_len` bytes.
    fn assemble_uninit(
        name: &str,
        code: &CodeImage,
        payload_len: usize,
        params: IfuncMsgParams,
    ) -> Result<IfuncMsg> {
        if name.is_empty() || name.len() > NAME_BYTES {
            return Err(Error::InvalidMessage(format!(
                "ifunc name must be 1..={NAME_BYTES} bytes"
            )));
        }
        if !params.payload_align.is_power_of_two() {
            return Err(Error::InvalidMessage("payload_align must be a power of two".into()));
        }
        let code_bytes = code.encode();
        let code_offset = HEADER_BYTES;
        let payload_offset =
            (code_offset + code_bytes.len()).next_multiple_of(params.payload_align.max(1));
        let trailer_offset = (payload_offset + payload_len).next_multiple_of(8);
        let frame_len = trailer_offset + TRAILER_BYTES;
        if frame_len > MAX_FRAME_BYTES {
            return Err(Error::InvalidMessage("frame too long".into()));
        }
        let header = Header {
            frame_len: frame_len as u32,
            trailer_sig: fresh_trailer_sig(),
            code_offset: code_offset as u32,
            code_len: code_bytes.len() as u32,
            payload_offset: payload_offset as u32,
            payload_len: payload_len as u32,
            // The GOT slot is the first word of the code section.
            got_offset: code_offset as u32,
            hop: Hop::default(),
            name: name.to_string(),
        };
        let mut frame = vec![0u8; frame_len];
        frame[..HEADER_BYTES].copy_from_slice(&header.encode());
        frame[code_offset..code_offset + code_bytes.len()].copy_from_slice(&code_bytes);
        frame[trailer_offset..].copy_from_slice(&header.trailer_sig.to_le_bytes());
        Ok(IfuncMsg { frame, name: name.to_string(), payload_offset, payload_len, facts: None })
    }

    /// Shrink the payload to `used` bytes, moving the trailer up and
    /// re-encoding the header.
    fn shrink_payload(&mut self, used: usize) {
        debug_assert!(used <= self.payload_len);
        let h = Header::decode(&self.frame).expect("own header").expect("nonempty");
        let trailer_offset = (self.payload_offset + used).next_multiple_of(8);
        let frame_len = trailer_offset + TRAILER_BYTES;
        let new_header = Header {
            frame_len: frame_len as u32,
            payload_len: used as u32,
            ..h
        };
        self.frame.truncate(frame_len);
        // Zero the alignment pad between payload end and trailer.
        for b in &mut self.frame[self.payload_offset + used..trailer_offset] {
            *b = 0;
        }
        self.frame[..HEADER_BYTES].copy_from_slice(&new_header.encode());
        self.frame[trailer_offset..].copy_from_slice(&new_header.trailer_sig.to_le_bytes());
        self.payload_len = used;
    }

    /// Rebuild a sendable message from an *executing* frame: copies the
    /// code section verbatim (resetting the GOT slot to `UNPATCHED` so the
    /// next hop relinks), installs `payload` as the new payload, and
    /// stamps `hop`. This is how the `forward` host symbol re-injects a
    /// frame to a peer — the poll loop consumes ring frames after
    /// execution, so the engine is the last holder of the frame bytes.
    pub fn reframe(src: &Header, src_frame: &[u8], payload: &[u8], hop: Hop) -> Result<IfuncMsg> {
        let code_start = src.code_offset as usize;
        let code_len = src.code_len as usize;
        let code_bytes = src_frame
            .get(code_start..code_start + code_len)
            .ok_or_else(|| Error::InvalidMessage("reframe: code section out of range".into()))?;
        let code_offset = HEADER_BYTES;
        let payload_offset = (code_offset + code_len).next_multiple_of(8);
        let trailer_offset = (payload_offset + payload.len()).next_multiple_of(8);
        let frame_len = trailer_offset + TRAILER_BYTES;
        if frame_len > MAX_FRAME_BYTES {
            return Err(Error::InvalidMessage("reframe: frame too long".into()));
        }
        let header = Header {
            frame_len: frame_len as u32,
            trailer_sig: fresh_trailer_sig(),
            code_offset: code_offset as u32,
            code_len: code_len as u32,
            payload_offset: payload_offset as u32,
            payload_len: payload.len() as u32,
            got_offset: (code_offset + (src.got_offset - src.code_offset) as usize) as u32,
            hop,
            name: src.name.clone(),
        };
        let mut frame = vec![0u8; frame_len];
        frame[..HEADER_BYTES].copy_from_slice(&header.encode());
        frame[code_offset..code_offset + code_len].copy_from_slice(code_bytes);
        let got = header.got_offset as usize;
        frame[got..got + 4].copy_from_slice(&GOT_UNPATCHED.to_le_bytes());
        frame[payload_offset..payload_offset + payload.len()].copy_from_slice(payload);
        frame[trailer_offset..].copy_from_slice(&header.trailer_sig.to_le_bytes());
        Ok(IfuncMsg {
            frame,
            name: header.name,
            payload_offset,
            payload_len: payload.len(),
            facts: None,
        })
    }

    /// Build a mesh relay frame: kind [`HOP_KIND_RELAY`], no code, payload
    /// `[ok u64][r0 u64][reply bytes…]`. The origin worker's mesh ingress
    /// pushes it into its leader-facing reply writer under
    /// `hop.origin_seq` instead of executing it.
    pub fn relay(ok: bool, r0: u64, reply: &[u8], hop: Hop) -> Result<IfuncMsg> {
        let mut payload = Vec::with_capacity(16 + reply.len());
        payload.extend_from_slice(&(ok as u64).to_le_bytes());
        payload.extend_from_slice(&r0.to_le_bytes());
        payload.extend_from_slice(reply);
        let mut msg =
            IfuncMsg::assemble(RELAY_NAME, &CodeImage::default(), &payload, Default::default())?;
        msg.set_hop(Hop { kind: HOP_KIND_RELAY, ..hop });
        Ok(msg)
    }

    /// Inverse of [`IfuncMsg::relay`]'s payload encoding.
    pub fn decode_relay_payload(payload: &[u8]) -> Result<(bool, u64, &[u8])> {
        if payload.len() < 16 {
            return Err(Error::InvalidMessage("short relay payload".into()));
        }
        let ok = u64::from_le_bytes(payload[0..8].try_into().unwrap()) != 0;
        let r0 = u64::from_le_bytes(payload[8..16].try_into().unwrap());
        Ok((ok, r0, &payload[16..]))
    }

    /// Static admission summary, if the source analyzed the code (see the
    /// field doc — advisory only, never trusted by targets).
    pub fn admission_facts(&self) -> Option<&AdmissionFacts> {
        self.facts.as_deref()
    }

    /// Stamp (or clear) the admission summary on this message.
    pub fn set_admission_facts(&mut self, facts: Option<Arc<AdmissionFacts>>) {
        self.facts = facts;
    }

    /// Hop metadata currently encoded in the frame header.
    pub fn hop(&self) -> Hop {
        Header::decode(&self.frame).expect("own header").expect("nonempty").hop
    }

    /// Re-stamp the hop metadata in place (trailer signal unchanged — the
    /// header check word is recomputed over the new hop fields).
    pub fn set_hop(&mut self, hop: Hop) {
        let h = Header::decode(&self.frame).expect("own header").expect("nonempty");
        let new_header = Header { hop, ..h };
        self.frame[..HEADER_BYTES].copy_from_slice(&new_header.encode());
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// The wire frame (header + code + payload + trailer).
    pub fn frame(&self) -> &[u8] {
        &self.frame
    }

    pub fn len(&self) -> usize {
        self.frame.len()
    }

    pub fn is_empty(&self) -> bool {
        false
    }

    /// Mutable view of the payload (e.g. to refresh data between resends).
    pub fn payload_mut(&mut self) -> &mut [u8] {
        &mut self.frame[self.payload_offset..self.payload_offset + self.payload_len]
    }

    pub fn payload(&self) -> &[u8] {
        &self.frame[self.payload_offset..self.payload_offset + self.payload_len]
    }

    /// `ucp_ifunc_msg_free` — explicit for API parity; dropping works too.
    pub fn free(self) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_code() -> CodeImage {
        CodeImage {
            imports: vec!["counter_add".into(), "log".into()],
            vm_code: vec![0u8; 64],
            hlo: b"HloModule m".to_vec(),
        }
    }

    #[test]
    fn header_roundtrip() {
        let msg = IfuncMsg::assemble("bench", &sample_code(), b"payload!", Default::default())
            .unwrap();
        let h = Header::decode(msg.frame()).unwrap().unwrap();
        assert_eq!(h.name, "bench");
        assert_eq!(h.payload_len, 8);
        assert_eq!(h.frame_len as usize, msg.len());
    }

    #[test]
    fn empty_slot_decodes_as_none() {
        assert!(Header::decode(&[0u8; HEADER_BYTES]).unwrap().is_none());
    }

    #[test]
    fn corrupt_header_rejected() {
        let msg = IfuncMsg::assemble("x", &sample_code(), b"p", Default::default()).unwrap();
        let mut bytes = msg.frame().to_vec();
        bytes[20] ^= 0xFF; // flip code_len
        assert!(Header::decode(&bytes).is_err());
    }

    #[test]
    fn bad_magic_rejected() {
        let mut bytes = [0u8; HEADER_BYTES];
        bytes[0..4].copy_from_slice(&0xDEAD_BEEFu32.to_le_bytes());
        assert!(Header::decode(&bytes).is_err());
    }

    #[test]
    fn code_image_roundtrip() {
        let code = sample_code();
        let bytes = code.encode();
        let (got, decoded) = CodeImage::decode(&bytes).unwrap();
        assert_eq!(got, GOT_UNPATCHED);
        assert_eq!(decoded, code);
    }

    #[test]
    fn truncated_code_image_rejected() {
        let bytes = sample_code().encode();
        assert!(CodeImage::decode(&bytes[..bytes.len() - 1]).is_err());
    }

    #[test]
    fn payload_alignment_honored() {
        for align in [1usize, 8, 64, 4096] {
            let msg = IfuncMsg::assemble(
                "a",
                &sample_code(),
                &[7u8; 100],
                IfuncMsgParams { payload_align: align },
            )
            .unwrap();
            let h = Header::decode(msg.frame()).unwrap().unwrap();
            assert_eq!(h.payload_offset as usize % align, 0, "align {align}");
            assert_eq!(msg.payload(), &[7u8; 100]);
        }
    }

    #[test]
    fn trailer_matches_header_sig() {
        let msg = IfuncMsg::assemble("t", &sample_code(), b"xyz", Default::default()).unwrap();
        let h = Header::decode(msg.frame()).unwrap().unwrap();
        let t = u64::from_le_bytes(
            msg.frame()[msg.len() - 8..].try_into().unwrap(),
        );
        assert_eq!(t, h.trailer_sig);
        assert_ne!(t, 0);
    }

    #[test]
    fn trailer_sigs_differ_between_messages() {
        let a = IfuncMsg::assemble("a", &sample_code(), b"", Default::default()).unwrap();
        let b = IfuncMsg::assemble("a", &sample_code(), b"", Default::default()).unwrap();
        let sig = |m: &IfuncMsg| u64::from_le_bytes(m.frame()[m.len() - 8..].try_into().unwrap());
        assert_ne!(sig(&a), sig(&b));
    }

    #[test]
    fn assemble_with_shrinks_to_used_bytes() {
        let msg = IfuncMsg::assemble_with("s", &sample_code(), 1024, Default::default(), |p| {
            p[..10].copy_from_slice(b"0123456789");
            Ok(10)
        })
        .unwrap();
        let h = Header::decode(msg.frame()).unwrap().unwrap();
        assert_eq!(h.payload_len, 10);
        assert_eq!(msg.payload(), b"0123456789");
        // Trailer still matches after the shrink re-encode.
        let t = u64::from_le_bytes(msg.frame()[msg.len() - 8..].try_into().unwrap());
        assert_eq!(t, h.trailer_sig);
    }

    #[test]
    fn assemble_with_overrun_rejected() {
        let r = IfuncMsg::assemble_with("s", &sample_code(), 4, Default::default(), |_| Ok(9));
        assert!(r.is_err());
    }

    #[test]
    fn fingerprint_tracks_code_content() {
        let a = sample_code();
        let ab = a.encode();
        let (_, ar) = CodeImage::decode_ref(&ab).unwrap();
        // Stable for identical content.
        let (_, ar2) = CodeImage::decode_ref(&ab).unwrap();
        assert_eq!(ar.fingerprint(), ar2.fingerprint());
        // Sensitive to vm code and to the hlo blob.
        let b = CodeImage { vm_code: vec![1u8; 64], ..sample_code() };
        let bb = b.encode();
        let (_, br) = CodeImage::decode_ref(&bb).unwrap();
        assert_ne!(ar.fingerprint(), br.fingerprint());
        let c = CodeImage { hlo: b"HloModule other".to_vec(), ..sample_code() };
        let cb = c.encode();
        let (_, cr) = CodeImage::decode_ref(&cb).unwrap();
        assert_ne!(ar.fingerprint(), cr.fingerprint());
    }

    #[test]
    fn hop_defaults_on_fresh_frames() {
        let msg = IfuncMsg::assemble("h", &sample_code(), b"p", Default::default()).unwrap();
        let hop = msg.hop();
        assert_eq!(hop, Hop::default());
        assert_eq!(hop.ttl, DEFAULT_TTL);
        assert_eq!(hop.origin_worker, NO_ORIGIN_WORKER);
        assert_eq!(hop.kind, HOP_KIND_INVOKE);
    }

    #[test]
    fn hop_roundtrips_through_set_hop() {
        let mut msg = IfuncMsg::assemble("h", &sample_code(), b"p", Default::default()).unwrap();
        let stamped = Hop { origin_seq: 42, origin_worker: 3, hops: 2, ttl: 6, kind: 0 };
        msg.set_hop(stamped);
        let h = Header::decode(msg.frame()).unwrap().unwrap();
        assert_eq!(h.hop, stamped);
        // set_hop keeps everything else intact: trailer still matches.
        let t = u64::from_le_bytes(msg.frame()[msg.len() - 8..].try_into().unwrap());
        assert_eq!(t, h.trailer_sig);
        assert_eq!(h.name, "h");
    }

    #[test]
    fn corrupt_hop_fields_rejected() {
        let mut msg = IfuncMsg::assemble("h", &sample_code(), b"p", Default::default()).unwrap();
        msg.set_hop(Hop { origin_seq: 7, origin_worker: 1, hops: 1, ttl: 4, kind: 0 });
        for byte in [40usize, 48, 50, 51, 52] {
            let mut bytes = msg.frame().to_vec();
            bytes[byte] ^= 0xFF;
            assert!(Header::decode(&bytes).is_err(), "flip at {byte} undetected");
        }
    }

    #[test]
    fn reframe_preserves_code_and_resets_got() {
        let src = IfuncMsg::assemble("fwd", &sample_code(), b"original", Default::default())
            .unwrap();
        let mut frame = src.frame().to_vec();
        let h = Header::decode(&frame).unwrap().unwrap();
        // Simulate target-side GOT patching before the forward.
        let got = h.got_offset as usize;
        frame[got..got + 4].copy_from_slice(&7u32.to_le_bytes());
        let hop = Hop { origin_seq: 9, origin_worker: 0, hops: 1, ttl: 7, kind: 0 };
        let fwd = IfuncMsg::reframe(&h, &frame, b"next-hop-payload", hop).unwrap();
        let fh = Header::decode(fwd.frame()).unwrap().unwrap();
        assert_eq!(fh.name, "fwd");
        assert_eq!(fh.hop, hop);
        assert_eq!(fwd.payload(), b"next-hop-payload");
        // Code section identical except the GOT slot, which is unpatched
        // again so the next hop relinks.
        let code = &fwd.frame()[fh.code_offset as usize..(fh.code_offset + fh.code_len) as usize];
        let (slot, img) = CodeImage::decode(code).unwrap();
        assert_eq!(slot, GOT_UNPATCHED);
        assert_eq!(img, sample_code());
        // Fresh trailer signal (stale ring bytes can't alias the new frame).
        assert_ne!(fh.trailer_sig, h.trailer_sig);
    }

    #[test]
    fn relay_frame_roundtrips() {
        let hop = Hop { origin_seq: 33, origin_worker: 2, hops: 3, ttl: 5, kind: 0 };
        let msg = IfuncMsg::relay(false, 0xDEAD, b"reply-bytes", hop).unwrap();
        let h = Header::decode(msg.frame()).unwrap().unwrap();
        assert_eq!(h.hop.kind, HOP_KIND_RELAY);
        assert_eq!(h.hop.origin_seq, 33);
        assert_eq!(h.name, RELAY_NAME);
        let (ok, r0, reply) = IfuncMsg::decode_relay_payload(msg.payload()).unwrap();
        assert!(!ok);
        assert_eq!(r0, 0xDEAD);
        assert_eq!(reply, b"reply-bytes");
    }

    #[test]
    fn unknown_frame_kind_rejected() {
        let mut msg = IfuncMsg::assemble("h", &sample_code(), b"p", Default::default()).unwrap();
        msg.set_hop(Hop { kind: HOP_KIND_RELAY + 1, ..Hop::default() });
        assert!(Header::decode(msg.frame()).is_err());
    }

    #[test]
    fn oversized_name_rejected() {
        let e = IfuncMsg::assemble(
            "name-way-too-long-for-frame",
            &sample_code(),
            b"",
            Default::default(),
        );
        assert!(e.is_err());
    }
}
