//! ifunc delivery rings.
//!
//! The paper's transport requires "the user to allocate special buffers
//! and a consensus about where the target processes expect the messages to
//! arrive" (§3.3): the target maps an RWX ring with `ucp_mem_map`, ships
//! the rkey out-of-band, and the source PUTs frames at offsets it manages
//! itself. [`IfuncRing`] is the target side (mapped region + read cursor);
//! [`SenderCursor`] is the source-side offset manager, emitting wrap
//! markers when a frame would run past the ring end.

use std::sync::Arc;

use crate::fabric::{MemPerm, MemoryRegion, RKey, RemoteKey};
use crate::ucp::Context;
use crate::{Error, Result};

use super::message::{HEADER_BYTES, TRAILER_BYTES, WRAP_MAGIC};

/// Minimum sensible ring: one max-header frame plus a wrap marker.
pub const MIN_RING_BYTES: usize = 4096;

/// Target-side ifunc ring buffer.
pub struct IfuncRing {
    mr: Arc<MemoryRegion>,
    node: Arc<crate::fabric::Node>,
    cursor: usize,
    size: usize,
    /// Frames consumed (telemetry + bench notifications).
    pub consumed: u64,
    /// Bytes consumed.
    pub consumed_bytes: u64,
}

impl IfuncRing {
    /// Allocate and map a ring of `size` bytes (power of 8 alignment;
    /// `MemPerm::RWX` because remote peers write frames and — in the
    /// paper's model — the region holds executable code).
    pub fn new(ctx: &Context, size: usize) -> Result<Self> {
        if size < MIN_RING_BYTES || size % 8 != 0 {
            return Err(Error::NoResource(format!(
                "ifunc ring must be >= {MIN_RING_BYTES} bytes and 8-aligned"
            )));
        }
        let mr = ctx.mem_map(size, MemPerm::RWX);
        Ok(IfuncRing {
            mr,
            node: ctx.node().clone(),
            cursor: 0,
            size,
            consumed: 0,
            consumed_bytes: 0,
        })
    }

    pub fn rkey(&self) -> RKey {
        self.mr.rkey()
    }

    /// Packed remote key to ship out-of-band.
    pub fn remote_key(&self) -> RemoteKey {
        RemoteKey { node: self.node.id(), rkey: self.mr.rkey(), len: self.size }
    }

    /// Base offset senders start writing at.
    pub fn remote_addr(&self) -> usize {
        0
    }

    pub fn size(&self) -> usize {
        self.size
    }

    pub(crate) fn mr(&self) -> &Arc<MemoryRegion> {
        &self.mr
    }

    /// The ring mapping itself, for a *colocated* sender: the intra-node
    /// shm transport writes frames into this region directly (the §3.3
    /// "consensus about where the target expects messages" degenerates,
    /// on one host, to sharing the mapping instead of shipping an rkey).
    pub fn region(&self) -> Arc<MemoryRegion> {
        self.mr.clone()
    }

    pub(crate) fn cursor(&self) -> usize {
        self.cursor
    }

    pub(crate) fn advance(&mut self, frame_len: usize) {
        self.cursor += frame_len;
        if self.cursor >= self.size {
            self.cursor = 0;
        }
        self.consumed += 1;
        self.consumed_bytes += frame_len as u64;
    }

    /// Handle a wrap marker at the cursor: the skipped ring tail counts as
    /// consumed bytes (keeps sender-side credit accounting in sync), and
    /// the cursor rewinds to 0.
    pub(crate) fn rewind(&mut self) {
        self.consumed_bytes += (self.size - self.cursor) as u64;
        self.cursor = 0;
    }

    /// Unmap the ring.
    pub fn destroy(self, ctx: &Context) {
        ctx.mem_unmap(&self.mr);
    }
}

/// Source-side write-offset manager, mirroring the target's read cursor.
///
/// Flow control is the caller's job (the paper's throughput benchmark
/// fills the ring, flushes, and waits for the target's consumed
/// notification before the next round) — this type only does placement.
#[derive(Debug, Clone)]
pub struct SenderCursor {
    size: usize,
    offset: usize,
}

/// Placement decision for one frame.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Placement {
    /// Byte offset to PUT the frame at.
    pub offset: usize,
    /// If set, an 8-byte wrap marker must be PUT at this offset *before*
    /// the frame (tells the poller the stream continues at offset 0).
    pub wrap_marker_at: Option<usize>,
}

impl SenderCursor {
    pub fn new(ring_size: usize) -> Self {
        SenderCursor { size: ring_size, offset: 0 }
    }

    /// Capacity check: the largest single frame this ring can take.
    pub fn max_frame(&self) -> usize {
        self.size - 8
    }

    /// Place a frame of `frame_len` bytes; errors if it can never fit.
    pub fn place(&mut self, frame_len: usize) -> Result<Placement> {
        if frame_len > self.max_frame() || frame_len < HEADER_BYTES + TRAILER_BYTES {
            return Err(Error::NoResource(format!(
                "frame of {frame_len} bytes cannot fit ring of {} bytes",
                self.size
            )));
        }
        let mut wrap = None;
        if self.offset + frame_len > self.size {
            // Not enough room before the end: drop a wrap marker and start
            // over at 0. (The cursor can never be closer than 8 bytes to
            // the end because frames and markers are 8-aligned.)
            wrap = Some(self.offset);
            self.offset = 0;
        }
        let at = self.offset;
        self.offset += frame_len;
        if self.offset >= self.size {
            self.offset = 0;
        }
        Ok(Placement { offset: at, wrap_marker_at: wrap })
    }

    /// Bytes from the current offset to the ring end (diagnostics).
    pub fn remaining_before_wrap(&self) -> usize {
        self.size - self.offset
    }

    pub fn reset(&mut self) {
        self.offset = 0;
    }
}

/// The 8-byte wrap-marker word (low 32 bits = `WRAP_MAGIC`).
pub fn wrap_marker_word() -> u64 {
    WRAP_MAGIC as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn placement_is_sequential() {
        let mut c = SenderCursor::new(4096);
        let a = c.place(512).unwrap();
        let b = c.place(512).unwrap();
        assert_eq!(a, Placement { offset: 0, wrap_marker_at: None });
        assert_eq!(b, Placement { offset: 512, wrap_marker_at: None });
    }

    #[test]
    fn wrap_marker_on_overflow() {
        let mut c = SenderCursor::new(4096);
        c.place(3072).unwrap();
        let p = c.place(2048).unwrap();
        assert_eq!(p.wrap_marker_at, Some(3072));
        assert_eq!(p.offset, 0);
    }

    #[test]
    fn exact_fit_wraps_cursor_to_zero() {
        let mut c = SenderCursor::new(4096);
        c.place(4088).unwrap();
        let p = c.place(128).unwrap();
        // 4088 leaves 8 bytes — next frame needs a wrap marker there.
        assert_eq!(p.wrap_marker_at, Some(4088));
        assert_eq!(p.offset, 0);
    }

    #[test]
    fn oversized_frame_rejected() {
        let mut c = SenderCursor::new(4096);
        assert!(c.place(4090).is_err());
    }
}
