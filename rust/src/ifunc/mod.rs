//! The ifunc API — the paper's contribution (§3).
//!
//! Mirrors Listing 1.1 on rust types:
//!
//! | paper                      | here                                   |
//! |----------------------------|----------------------------------------|
//! | `ucp_register_ifunc`       | [`crate::ucp::Context::register_ifunc`]|
//! | `ucp_deregister_ifunc`     | [`crate::ucp::Context::deregister_ifunc`]|
//! | `ucp_ifunc_msg_create`     | [`IfuncHandle::msg_create`]            |
//! | `ucp_ifunc_msg_free`       | [`IfuncMsg::free`] (or drop)           |
//! | `ucp_ifunc_msg_send_nbix`  | [`crate::ucp::Endpoint::ifunc_msg_send_nbix`]|
//! | `ucp_poll_ifunc`           | [`crate::ucp::Context::poll_ifunc`]    |
//!
//! and Listing 1.2 as the [`IfuncLibrary`] trait
//! (`payload_get_max_size` / `payload_init` / `main`-as-code-image).
//!
//! Beyond Listing 1.1, the execution path is split the way §5.1 points:
//!
//! * [`engine`] — the *transport-independent* target half of
//!   `ucp_poll_ifunc` (decode → cache → link → verify → compile → HLO
//!   ensure → invoke), shared by every delivery path and returning a
//!   structured [`ExecOutcome`],
//! * [`transport`] — the sender half behind [`IfuncTransport`]:
//!   [`RingTransport`] is the paper's §3.3 RDMA-PUT ring,
//!   [`AmTransport`] is the §5.1 send-receive successor, and
//!   [`ShmTransport`] is the intra-node colocated path (§1's
//!   DPU/CSD-on-the-host deployment: the same ring protocol delivered by
//!   direct memcpy into the shared mapping, no fabric emulation at all);
//!   all take multi-frame batches through [`IfuncTransport::send_batch`],
//! * [`reply`] — a per-worker ring of payload-carrying reply *frames*
//!   (`[payload][frame_seq][r0][total_len][payload_len][status][seq]`,
//!   seq written last — the same §3.4 trailer-signal ordering data frames
//!   use), upgrading fire-and-forget injection to invocation: injected
//!   code fills the payload through the `reply_put` / `db_get` host
//!   symbols — **any size**: payloads past one frame stream as
//!   `STATUS_MORE` chunk frames that the leader-side `ReplyCollector`
//!   reassembles — and the sender collects it via `Dispatcher::invoke_one`
//!   / `PendingReply::wait`. Collective invocations compose the same
//!   parts: `Dispatcher::invoke_all` posts one frame per link through
//!   [`IfuncTransport::post_frame`], runs one flush pass over the
//!   fan-out, and merges each worker's reply stream into a
//!   `MultiReply` with per-worker attribution (the paper's closing
//!   motivation — moving one query to every shard of data too big for
//!   one device). Execution can also *continue* on another worker: the
//!   `forward(worker, off, len)` host symbol re-injects the running
//!   frame to a peer over the worker↔worker mesh — sPIN's
//!   forward-onward handler model, and the paper's closing vision of
//!   apps that "dynamically choose where code runs as the application
//!   progresses" — with hop metadata in the frame header (origin
//!   seq/worker, hop count, TTL) so the final hop's reply relays back
//!   to the origin's leader-facing reply stream and intermediate hops
//!   reply nothing,
//! * [`cache`] — §3.4's hash table, extended to cache the *compiled
//!   program* (threaded-dispatch form, see [`crate::vm::compile`]) so
//!   repeat injections skip the bytecode verifier *and* the compiler
//!   entirely.

pub mod am_transport;
pub mod builtin;
pub mod cache;
pub mod engine;
pub mod icache;
pub mod library;
pub mod message;
pub mod poll;
pub mod registry;
pub mod reply;
pub mod ring;
pub mod send;
pub mod shm_transport;
pub mod transport;

pub use engine::{ExecOutcome, ForwardOutcome};
pub use library::{HloIfuncLibrary, IfuncLibrary, LibraryDir, SourceArgs};
pub use message::{CodeImage, Hop, IfuncMsg, IfuncMsgParams, DEFAULT_TTL, NO_ORIGIN_WORKER};
pub use poll::{MeshPollResult, PollResult};
pub use registry::IfuncHandle;
pub use reply::{
    Reply, ReplyCollector, ReplyRing, ReplyWriter, REPLY_INLINE_CAP, REPLY_SLOTS,
};
pub use ring::{IfuncRing, SenderCursor};
pub use shm_transport::ShmTransport;
pub use transport::{
    AmTransport, ConsumedCounter, IfuncTransport, RingTransport, TransportKind,
};

use std::any::Any;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::log;
use crate::vm::SymbolTable;

/// What the `forward` host symbol recorded for the current invocation:
/// continue on `worker`, shipping `payload[off..off+len]` as the next
/// hop's payload. The engine turns it into [`ForwardOutcome`] after a
/// successful `HALT`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ForwardSpec {
    pub worker: usize,
    pub off: usize,
    pub len: usize,
}

/// Target-process arguments handed to every invoked ifunc
/// (`void *target_args` in Listing 1.1), plus the per-invocation bindings
/// `ucp_poll_ifunc` stamps in (the HLO artifact name for `xla_exec`, the
/// reply-payload accumulator behind `reply_put`).
pub struct TargetArgs {
    /// Application state (e.g. the `db_handler` of Listing 1.3).
    pub user: Box<dyn Any + Send>,
    /// Name of the HLO artifact bound to the current invocation.
    pub(crate) hlo_name: Option<String>,
    /// `r0` of the last executed ifunc (diagnostics / tests).
    pub last_return: Option<u64>,
    /// Reply-payload accumulator for the *current* invocation: host
    /// symbols append here ([`TargetArgs::push_reply`]) and the engine
    /// drains it into [`ExecOutcome::reply`] after `HALT`, from where the
    /// worker's reply writer ships it inline to the sender.
    pub(crate) reply: Vec<u8>,
    /// Forward request of the *current* invocation (at most one — the
    /// `forward` host symbol errors on a second call); cleared by the
    /// engine before each run and taken into [`ExecOutcome::forward`].
    pub(crate) forward: Option<ForwardSpec>,
}

impl TargetArgs {
    /// No application state.
    pub fn none() -> Self {
        Self::new(Box::new(()))
    }

    pub fn new(user: Box<dyn Any + Send>) -> Self {
        TargetArgs {
            user,
            hlo_name: None,
            last_return: None,
            reply: Vec::new(),
            forward: None,
        }
    }

    /// Downcast the application state.
    pub fn user_as<T: 'static>(&mut self) -> Option<&mut T> {
        self.user.downcast_mut::<T>()
    }

    /// Append bytes to the current invocation's reply payload (what the
    /// `reply_put` and `db_get` host symbols call). Bytes accumulate
    /// across calls within one invocation with **no size cap**: the reply
    /// writer ships whatever fits one frame inline and streams anything
    /// larger as chunk frames.
    pub fn push_reply(&mut self, bytes: &[u8]) {
        self.reply.extend_from_slice(bytes);
    }
}

/// The target process's linkable surface: a [`SymbolTable`] plus the
/// standard bindings every context starts with. Injected code can only
/// reach the world through these (and any the application installs).
#[derive(Clone)]
pub struct Symbols {
    table: SymbolTable,
    counter: Arc<AtomicU64>,
    results: Arc<AtomicU64>,
}

impl Symbols {
    /// Standard bindings:
    /// * `counter_add(n)` — the §4.1 benchmark counter,
    /// * `record_result(v)` — stores `v` (checksums etc.),
    /// * `reply_put(off, len)` — append `payload[off..off+len]` to the
    ///   invocation's reply payload (shipped inline in the reply frame),
    /// * `forward(worker, off, len)` — continue this invocation on
    ///   `worker` over the worker↔worker mesh, shipping
    ///   `payload[off..off+len]` as the next hop's payload (at most one
    ///   per invocation; the final hop's reply relays to the origin),
    /// * `log(v)` — debug logging,
    /// * `xla_exec(...)` — run the current ifunc's HLO artifact via PJRT.
    pub fn with_builtins() -> Self {
        let table = SymbolTable::new();
        let counter = Arc::new(AtomicU64::new(0));
        let results = Arc::new(AtomicU64::new(0));
        let c = counter.clone();
        table.install_fn("counter_add", move |_, args| {
            Ok(c.fetch_add(args[0], Ordering::Relaxed) + args[0])
        });
        table.install_fn("reply_put", |ctx, [off, len, _, _]| {
            let (off, len) = (off as usize, len as usize);
            let end = off
                .checked_add(len)
                .filter(|&e| e <= ctx.payload.len())
                .ok_or_else(|| format!(
                    "reply_put: {len} bytes at {off} outside payload of {}",
                    ctx.payload.len()
                ))?;
            let ta = ctx
                .user
                .downcast_mut::<TargetArgs>()
                .ok_or_else(|| "reply_put: target args are not ifunc TargetArgs".to_string())?;
            ta.reply.extend_from_slice(&ctx.payload[off..end]);
            Ok(ta.reply.len() as u64)
        });
        table.install_fn("forward", |ctx, [worker, off, len, _]| {
            let (off, len) = (off as usize, len as usize);
            let end = off
                .checked_add(len)
                .filter(|&e| e <= ctx.payload.len())
                .ok_or_else(|| format!(
                    "forward: {len} bytes at {off} outside payload of {}",
                    ctx.payload.len()
                ))?;
            let ta = ctx
                .user
                .downcast_mut::<TargetArgs>()
                .ok_or_else(|| "forward: target args are not ifunc TargetArgs".to_string())?;
            if ta.forward.is_some() {
                return Err("forward: at most one forward per invocation".to_string());
            }
            ta.forward = Some(ForwardSpec { worker: worker as usize, off, len: end - off });
            Ok(0)
        });
        let r = results.clone();
        table.install_fn("record_result", move |_, args| {
            r.store(args[0], Ordering::Relaxed);
            Ok(0)
        });
        table.install_fn("log", |_, args| {
            log::debug!("ifunc log: {:#x} {:#x} {:#x} {:#x}", args[0], args[1], args[2], args[3]);
            Ok(0)
        });
        table.install("xla_exec", crate::runtime::xla_exec_hostfn());
        Symbols { table, counter, results }
    }

    /// The raw symbol table (install application symbols here).
    pub fn table(&self) -> &SymbolTable {
        &self.table
    }

    /// Install a custom symbol.
    pub fn install_fn<F>(&self, name: &str, f: F)
    where
        F: Fn(&mut crate::vm::HostCtx, [u64; 4]) -> std::result::Result<u64, String>
            + Send
            + Sync
            + 'static,
    {
        self.table.install_fn(name, f);
    }

    /// Value of the benchmark counter (`counter_add` target).
    pub fn counter_value(&self) -> u64 {
        self.counter.load(Ordering::Acquire)
    }

    /// Handle to the benchmark counter (cross-thread waiting in benches).
    pub fn counter(&self) -> Arc<AtomicU64> {
        self.counter.clone()
    }

    /// Last `record_result` value.
    pub fn last_result(&self) -> u64 {
        self.results.load(Ordering::Acquire)
    }

    /// Back-compat sugar used in the crate quickstart: the counter is
    /// installed by default; this is a no-op kept for API clarity.
    pub fn install_counter(&self) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn symbols_builtin_counter() {
        let s = Symbols::with_builtins();
        assert!(s.table().contains("counter_add"));
        assert!(s.table().contains("reply_put"));
        assert!(s.table().contains("forward"));
        assert!(s.table().contains("xla_exec"));
        assert_eq!(s.counter_value(), 0);
    }

    #[test]
    fn target_args_downcast() {
        let mut ta = TargetArgs::new(Box::new(42u32));
        assert_eq!(*ta.user_as::<u32>().unwrap(), 42);
        assert!(ta.user_as::<String>().is_none());
    }
}
