//! The shared ifunc execution engine.
//!
//! One pipeline, two transports: both `ucp_poll_ifunc` (RDMA-PUT rings,
//! §3) and the AM receive path (§5.1 send-receive delivery) are thin
//! adapters over [`Context::execute_frame`], which owns the full
//! target-side sequence of Fig. 2:
//!
//! 1. **decode** the code section (borrowed — no copies),
//! 2. **code-cache lookup** ([`super::cache::CodeCache::lookup_matching`]:
//!    name + import table + code fingerprint),
//! 3. on a miss, **GOT link** (resolve imports against the local symbol
//!    table), **verify** the bytecode, **analyze** it
//!    ([`crate::vm::analyze`] — interval abstract interpretation), gate
//!    its reachable host-call surface against the context's
//!    [`crate::vm::CapabilityPolicy`], and **compile** the verified
//!    program into its threaded form ([`crate::vm::compile_analyzed`],
//!    which drops dynamic checks the analysis proved redundant); program
//!    *and* facts are cached alongside the GOT so repeat injections skip
//!    decode-side work entirely — this is the crate's only verifier,
//!    analyzer, and compiler call site,
//! 4. **HLO ensure**: hand the shipped artifact to this thread's PJRT
//!    runtime (memoized per thread — a cache entry created on another
//!    thread still compiles here on first use),
//! 5. patch the frame's GOT slot (the "alternative GOT pointer" of §3.4),
//! 6. `clear_cache` over the code section (§4.3's non-coherent I-cache),
//! 7. **invoke** `main(payload, payload_size, target_args)`.
//!
//! The frame is *in-place-mutable* on every default path: a ring slot
//! (the TCVM mutates the payload where it landed), an AM eager slot
//! (executed in place between signal acquire and release), or an AM
//! rendezvous fetch buffer (owned by the receiver). The engine sees one
//! mutable frame and returns a structured [`ExecOutcome`] — and because
//! the engine owns the error path, callers can consume a rejected frame
//! (decode/link/verify failure) exactly like an executed one instead of
//! spinning on it.

use crate::ucp::Context;
use crate::vm;
use crate::{Error, Result};

use super::icache;
use super::message::{CodeImage, Header, Hop, IfuncMsg, HOP_KIND_INVOKE};
use super::TargetArgs;

/// What the `forward(worker, off, len)` host symbol produced: the engine
/// consumed the frame (the poll loop reclaims its ring bytes), so the
/// *rebuilt* next-hop message rides the outcome and the caller's mesh
/// link ships it. Only present on successful execution — a faulting
/// invocation's failure reply wins over any forward it requested.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ForwardOutcome {
    /// Re-inject `msg` (code copied verbatim, GOT unpatched, payload =
    /// the requested slice, hop count +1 / TTL −1) to `worker`.
    Forward { worker: usize, msg: IfuncMsg },
    /// The frame arrived with TTL 0 and asked to forward again: the
    /// caller must fail the invocation back to the origin instead.
    TtlExhausted { worker: usize },
}

/// Structured result of executing one ifunc frame.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ExecOutcome {
    /// `r0` of the injected main at `HALT` — the function's return value
    /// (what the reply path carries back to the sender).
    pub ret: u64,
    /// Instructions retired by the TCVM.
    pub steps: u64,
    /// Whether the compiled-program cache satisfied this frame (link,
    /// verify, and compile all skipped).
    pub cache_hit: bool,
    /// Bytes the injected function queued for the reply through the
    /// `reply_put` / `db_get` host symbols (empty when it pushed
    /// nothing). The worker's reply writer ships these back to the
    /// sender — one reply frame when they fit, a chunked stream when
    /// they do not; there is no size cap here.
    pub reply: Vec<u8>,
    /// Set when the invocation called the `forward` host symbol: the
    /// execution *continues* on another worker and no reply is due yet
    /// from this hop (the final hop relays one back to the origin).
    pub forward: Option<ForwardOutcome>,
}

impl Context {
    /// Run the decode → cache → link → verify → compile → HLO-ensure →
    /// invoke pipeline over one fully-arrived frame. `frame` spans header through
    /// trailer and must match `header` (which the caller has already
    /// integrity-checked via [`Header::decode`]).
    pub fn execute_frame(
        &self,
        header: &Header,
        frame: &mut [u8],
        target_args: &mut TargetArgs,
    ) -> Result<ExecOutcome> {
        if header.frame_len as usize != frame.len() {
            return Err(Error::InvalidMessage(format!(
                "frame slice of {} bytes does not match header frame_len {}",
                frame.len(),
                header.frame_len
            )));
        }
        let code_start = header.code_offset as usize;
        let code_end = code_start + header.code_len as usize;

        // Stages 1-4: decode, cache lookup, (re)link + verify + compile
        // on miss, per-thread HLO ensure.
        let (linked, cache_hit) = {
            let (_slot, image) = CodeImage::decode_ref(&frame[code_start..code_end])?;
            let (entry, cache_hit) = match self.cache.lookup_matching(&header.name, &image) {
                Some(entry) => (entry, true),
                None => {
                    // First-seen type (or changed code/imports under the
                    // name): reconstruct the GOT from the local symbol
                    // table, then verify + analyze + compile the shipped
                    // bytecode once.
                    let got =
                        self.symbols().table().resolve_iter(image.imports.iter().copied())?;
                    let owned: Vec<String> =
                        image.imports.iter().map(|s| s.to_string()).collect();
                    let instrs = vm::verify(image.vm_code, image.imports.len())?;
                    let facts = vm::analyze(&instrs);
                    // Capability gate: only CALLs the analysis proved
                    // reachable count — dead imports are harmless.
                    let caps = &self.config().caps;
                    if let Some(denied) = caps.first_denied(&facts.reachable_syms(&owned)) {
                        self.analysis_stats()
                            .cap_denials
                            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        return Err(Error::Verify(format!(
                            "capability denied: reachable host call `{denied}` \
                             is outside this context's allowlist"
                        )));
                    }
                    let prog = vm::compile_analyzed(instrs, &facts);
                    self.analysis_stats()
                        .elided_checks
                        .fetch_add(facts.elided_ops as u64, std::sync::atomic::Ordering::Relaxed);
                    let entry = self.cache.insert(
                        &header.name,
                        owned,
                        got,
                        prog,
                        image.fingerprint(),
                        !image.hlo.is_empty(),
                        std::sync::Arc::new(facts),
                    );
                    (entry, false)
                }
            };
            if entry.has_hlo {
                // The PJRT runtime is thread-local: ensure *this* thread
                // has the artifact compiled (no-op after the first time).
                crate::runtime::with_runtime(|rt| {
                    rt.ensure_compiled(&header.name, image.hlo)
                })?;
            }
            (entry, cache_hit)
        };

        // Stage 5: patch the frame's GOT slot (the hidden-global
        // indirection of §3.4) with the cache entry id.
        let got_off = header.got_offset as usize;
        frame[got_off..got_off + 4].copy_from_slice(&linked.id.to_le_bytes());

        // Stage 6: I-cache flush over the code section.
        icache::clear_cache(
            &self.config().icache,
            header.code_len as usize,
            self.icache_stats(),
        );

        // Stage 7: invoke main(payload, payload_size, target_args). The
        // reply accumulator starts empty per invocation; whatever the
        // injected code pushed (via `reply_put` / `db_get`) is drained
        // into the outcome for the caller's reply writer.
        let pay_start = header.payload_offset as usize;
        let pay_end = pay_start + header.payload_len as usize;
        target_args.hlo_name = linked.has_hlo.then(|| header.name.clone());
        target_args.reply.clear();
        target_args.forward = None;
        let outcome = linked.prog.run(
            &linked.got,
            &mut frame[pay_start..pay_end],
            target_args,
            &self.config().vm,
        );
        target_args.hlo_name = None;
        target_args.last_return = outcome.as_ref().map(|o| o.ret).ok();
        let reply = std::mem::take(&mut target_args.reply);
        let fwd_spec = target_args.forward.take();
        // `outcome?` before the forward build: a faulting invocation
        // drops any forward it requested — the failure reply wins.
        let o = outcome?;
        let forward = match fwd_spec {
            None => None,
            Some(spec) if header.hop.ttl == 0 => {
                Some(ForwardOutcome::TtlExhausted { worker: spec.worker })
            }
            Some(spec) => {
                let data = frame
                    .get(pay_start + spec.off..pay_start + spec.off + spec.len)
                    .ok_or_else(|| {
                        Error::InvalidMessage("forward slice out of payload range".into())
                    })?;
                let hop = Hop {
                    origin_seq: header.hop.origin_seq,
                    origin_worker: header.hop.origin_worker,
                    hops: header.hop.hops + 1,
                    ttl: header.hop.ttl - 1,
                    kind: HOP_KIND_INVOKE,
                };
                let msg = IfuncMsg::reframe(header, frame, data, hop)?;
                Some(ForwardOutcome::Forward { worker: spec.worker, msg })
            }
        };
        Ok(ExecOutcome { ret: o.ret, steps: o.steps, cache_hit, reply, forward })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{Fabric, WireConfig};
    use crate::ifunc::builtin::CounterIfunc;
    use crate::ifunc::library::IfuncLibrary;
    use crate::ifunc::message::IfuncMsg;
    use crate::ucp::ContextConfig;
    use std::sync::atomic::Ordering;
    use std::sync::Arc;

    fn ctx() -> Arc<Context> {
        let f = Fabric::new(1, WireConfig::off());
        Context::new(f.node(0), ContextConfig::default()).unwrap()
    }

    fn frame_for(code: &CodeImage, payload: &[u8]) -> (Header, Vec<u8>) {
        let msg = IfuncMsg::assemble("t", code, payload, Default::default()).unwrap();
        let h = Header::decode(msg.frame()).unwrap().unwrap();
        (h, msg.frame().to_vec())
    }

    #[test]
    fn verified_program_cache_hits_after_first_injection() {
        let c = ctx();
        let code = CounterIfunc::default().code();
        let (h, mut frame) = frame_for(&code, &[0u8; 32]);
        let mut args = TargetArgs::none();

        let first = c.execute_frame(&h, &mut frame.clone(), &mut args).unwrap();
        assert!(!first.cache_hit, "first injection links + verifies");
        let second = c.execute_frame(&h, &mut frame, &mut args).unwrap();
        assert!(second.cache_hit, "repeat injection skips verify");
        assert_eq!(c.ifunc_cache().hits.load(Ordering::Relaxed), 1);
        assert_eq!(c.ifunc_cache().misses.load(Ordering::Relaxed), 1);
        assert_eq!(c.symbols().counter_value(), 2);
    }

    #[test]
    fn changed_code_under_same_name_is_reverified() {
        let c = ctx();
        let (h1, mut f1) = frame_for(&CounterIfunc::default().code(), &[0u8; 8]);
        let mut args = TargetArgs::none();
        assert!(!c.execute_frame(&h1, &mut f1, &mut args).unwrap().cache_hit);

        // Same name, different code section (padded body): must miss the
        // program cache and run the *new* code, not the cached one.
        let (h2, mut f2) = frame_for(&CounterIfunc::with_code_padding(4).code(), &[0u8; 8]);
        let out = c.execute_frame(&h2, &mut f2, &mut args).unwrap();
        assert!(!out.cache_hit, "changed code relinks");
        assert_eq!(c.symbols().counter_value(), 2);
    }

    #[test]
    fn exec_outcome_carries_reply_payload() {
        use crate::ifunc::builtin::EchoIfunc;
        let c = ctx();
        let code = EchoIfunc.code();
        let payload = *b"echo me back";
        let (h, mut frame) = frame_for(&code, &payload);
        let mut args = TargetArgs::none();
        let out = c.execute_frame(&h, &mut frame, &mut args).unwrap();
        assert_eq!(out.reply, payload.to_vec());
        assert_eq!(out.ret, payload.len() as u64);
        // The accumulator was drained into the outcome, not left behind.
        assert!(args.reply.is_empty());
        // A following non-replying frame must not inherit stale bytes.
        let (h2, mut f2) = frame_for(&CounterIfunc::default().code(), &[0u8; 8]);
        let out2 = c.execute_frame(&h2, &mut f2, &mut args).unwrap();
        assert!(out2.reply.is_empty());
    }

    #[test]
    fn forward_symbol_produces_next_hop_message() {
        use crate::ifunc::builtin::HopIfunc;
        let c = ctx();
        let code = HopIfunc.code();
        let payload = HopIfunc::payload(&[2], b"carried-data");
        let (h, mut frame) = frame_for(&code, &payload);
        let mut args = TargetArgs::none();
        let out = c.execute_frame(&h, &mut frame, &mut args).unwrap();
        assert!(out.reply.is_empty(), "forwarding hop replies nothing");
        let Some(ForwardOutcome::Forward { worker, msg }) = out.forward else {
            panic!("expected a forward outcome, got {:?}", out.forward);
        };
        assert_eq!(worker, 2);
        let hop = msg.hop();
        assert_eq!(hop.hops, 1);
        assert_eq!(hop.ttl, crate::ifunc::DEFAULT_TTL - 1);
        // The itinerary index advanced in place before the reframe.
        assert_eq!(&msg.payload()[0..8], &1u64.to_le_bytes());
        assert_eq!(&msg.payload()[16 + 8..], b"carried-data");
        // The rebuilt frame executes at the "next worker": end of the
        // itinerary, so it replies with the data and forwards nothing.
        let h2 = Header::decode(msg.frame()).unwrap().unwrap();
        let mut f2 = msg.frame().to_vec();
        let out2 = c.execute_frame(&h2, &mut f2, &mut args).unwrap();
        assert!(out2.forward.is_none());
        assert_eq!(out2.reply, b"carried-data");
    }

    #[test]
    fn forward_with_exhausted_ttl_reports_not_builds() {
        use crate::ifunc::builtin::HopIfunc;
        use crate::ifunc::message::Hop;
        let c = ctx();
        let code = HopIfunc.code();
        let payload = HopIfunc::payload(&[1], b"x");
        let mut msg =
            crate::ifunc::IfuncMsg::assemble("hop", &code, &payload, Default::default()).unwrap();
        msg.set_hop(Hop { origin_seq: 5, origin_worker: 0, hops: 8, ttl: 0, kind: 0 });
        let h = Header::decode(msg.frame()).unwrap().unwrap();
        let mut frame = msg.frame().to_vec();
        let mut args = TargetArgs::none();
        let out = c.execute_frame(&h, &mut frame, &mut args).unwrap();
        assert_eq!(out.forward, Some(ForwardOutcome::TtlExhausted { worker: 1 }));
    }

    #[test]
    fn capability_gate_rejects_reachable_call_outside_allowlist() {
        let f = Fabric::new(1, WireConfig::off());
        let cfg = ContextConfig {
            caps: crate::vm::CapabilityPolicy::only(["log"]),
            ..Default::default()
        };
        let c = Context::new(f.node(0), cfg).unwrap();
        // CounterIfunc's only CALL reaches `counter_add` — outside the
        // allowlist, so the link is refused before compilation.
        let code = CounterIfunc::default().code();
        let (h, mut frame) = frame_for(&code, &[0u8; 8]);
        let mut args = TargetArgs::none();
        let err = c.execute_frame(&h, &mut frame, &mut args).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("capability denied"), "{msg}");
        assert!(msg.contains("counter_add"), "{msg}");
        assert_eq!(c.analysis_stats().snapshot().1, 1, "denial counted");
        assert!(c.ifunc_cache().is_empty(), "rejected frame is not cached");
        assert_eq!(c.symbols().counter_value(), 0, "nothing executed");

        // Code whose reachable surface stays inside the allowlist (here:
        // no calls at all) still links and runs under the same policy.
        let mut a = crate::vm::Assembler::new();
        a.ldi(0, 7).halt();
        let (vm_code, imports) = a.assemble();
        let image = CodeImage { imports, vm_code, hlo: vec![] };
        let (h2, mut f2) = frame_for(&image, &[0u8; 8]);
        let out = c.execute_frame(&h2, &mut f2, &mut args).unwrap();
        assert_eq!(out.ret, 7);
    }

    #[test]
    fn elided_checks_counted_once_per_link_not_per_run() {
        let c = ctx();
        // Constant-index 8-byte load at payload offset 0: provably in
        // bounds under the analysis' payload assumption → elided.
        let mut a = crate::vm::Assembler::new();
        a.ldw(0, 0, crate::vm::isa::SPACE_PAYLOAD, 0).halt();
        let (vm_code, imports) = a.assemble();
        let image = CodeImage { imports, vm_code, hlo: vec![] };
        let (h, frame) = frame_for(&image, &42u64.to_le_bytes());
        let mut args = TargetArgs::none();
        let out = c.execute_frame(&h, &mut frame.clone(), &mut args).unwrap();
        assert_eq!(out.ret, 42);
        assert_eq!(c.analysis_stats().snapshot().0, 1, "one load elided");
        // A cache hit reuses the facts — the tally does not grow per run.
        c.execute_frame(&h, &mut frame.clone(), &mut args).unwrap();
        assert_eq!(c.analysis_stats().snapshot().0, 1);
    }

    #[test]
    fn exec_outcome_carries_r0() {
        let c = ctx();
        let code = CounterIfunc::default().code();
        let (h, mut frame) = frame_for(&code, &[0u8; 8]);
        let mut args = TargetArgs::none();
        let out = c.execute_frame(&h, &mut frame, &mut args).unwrap();
        // counter_add(1) returns the post-increment counter value in r0.
        assert_eq!(out.ret, 1);
        assert_eq!(args.last_return, Some(1));
    }
}
