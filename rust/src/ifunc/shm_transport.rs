//! Intra-node shared-memory ifunc delivery — the colocated fast path.
//!
//! The paper's primary deployment picture (§1) dispatches functions to
//! engines *on the same host* as the leader: a SmartNIC/DPU on the PCIe
//! bus or a computational storage drive. Both existing transports still
//! pay the full emulated-fabric PUT path for that case — rkey lookup, a
//! posted `NetOp` handed to the target's NIC engine thread, completion
//! counting, and the modeled wire cost — even though leader and worker
//! share an address space. [`ShmTransport`] removes all of it: frames are
//! memcpy'd straight into the worker's ring mapping with the same
//! data-before-signal ordering the NIC engine would apply
//! ([`crate::fabric::MemoryRegion::put_local`]), and the return channel
//! (reply frames, byte credit, consumed-frame counter) travels back
//! through plain process-shared release/acquire words.
//!
//! Everything *protocol-shaped* is unchanged, on purpose:
//!
//! * the **wire format** is the §3.3/§3.4 frame layout byte-for-byte —
//!   header, payload, trailer signal written last — so the worker runs
//!   the identical `ucp_poll_ifunc` loop and execution engine,
//! * placement is the same [`crate::ifunc::SenderCursor`] + wrap-marker
//!   protocol with byte-credit flow control ([`ShmTransport`] simply
//!   *wraps* the ring-protocol core with a
//!   [`super::transport::PutSink::Shm`] sink, so the two cannot drift),
//! * replies stream through the same [`crate::ifunc::ReplyRing`] /
//!   `ReplyCollector` machinery, and barriers wait on the same
//!   [`super::transport::ConsumedCounter`] — the worker just advances
//!   them with release-stores instead of fabric signal-puts.
//!
//! This is the §5.1 argument run in the opposite direction: where the AM
//! transport trades the RWX-ring consensus for simplicity at the cost of
//! a copy-on-execute, shm keeps in-place ring execution and deletes the
//! fabric round trip — the cheapest possible delivery when "remote" is a
//! bus hop, not a network. Abl H measures exactly that delta.

use std::sync::Arc;

use crate::fabric::MemoryRegion;
use crate::Result;

use super::message::IfuncMsg;
use super::reply::ReplyRing;
use super::transport::{ConsumedCounter, IfuncTransport, PutSink, RingTransport};

/// The third [`IfuncTransport`]: ring-protocol delivery into a shared
/// mapping. Construct with the worker's ring region
/// ([`crate::ifunc::IfuncRing::region`]) and a leader-side byte-credit
/// word the colocated worker advances with release-stores.
pub struct ShmTransport {
    /// The ring-protocol core, pointed at the shared mapping instead of a
    /// fabric endpoint. Same cursor, same wrap markers, same credit
    /// arithmetic, same bounded capacity wait.
    core: RingTransport,
}

impl ShmTransport {
    /// `ring` is the worker's ifunc ring mapping, shared directly (the
    /// intra-node rkey "consensus" of §3.3 degenerates to handing over
    /// the mapping); `credit` is the leader-side consumed-bytes word the
    /// worker's poll loop stores into.
    pub fn new(
        ring: Arc<MemoryRegion>,
        credit: Arc<MemoryRegion>,
        replies: ReplyRing,
        consumed: ConsumedCounter,
    ) -> Self {
        let ring_bytes = ring.len();
        ShmTransport {
            core: RingTransport::with_sink(
                PutSink::Shm(ring),
                ring_bytes,
                credit,
                replies,
                consumed,
            ),
        }
    }
}

impl IfuncTransport for ShmTransport {
    fn send_frame(&mut self, msg: &IfuncMsg) -> Result<()> {
        self.core.send_frame(msg)
    }

    fn post_batch(&mut self, msgs: &[IfuncMsg]) -> Result<()> {
        self.core.post_batch(msgs)
    }

    /// Shm puts complete at the store itself; nothing to wait for.
    fn flush(&self) -> Result<()> {
        self.core.flush()
    }

    fn frames_sent(&self) -> u64 {
        self.core.frames_sent()
    }

    fn replies(&self) -> &ReplyRing {
        self.core.replies()
    }

    fn consumed(&self) -> &ConsumedCounter {
        self.core.consumed()
    }

    fn debug_put_raw(&mut self, offset: usize, data: &[u8]) -> Result<()> {
        self.core.debug_put_raw(offset, data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{Fabric, WireConfig};
    use crate::ifunc::builtin::CounterIfunc;
    use crate::ifunc::{IfuncRing, SourceArgs, TargetArgs};
    use crate::ucp::{Context, ContextConfig};

    /// Drive one frame sender → ring → poll entirely without endpoints:
    /// the whole transport is two mappings and the shared protocol.
    #[test]
    fn shm_frames_execute_without_any_endpoint() {
        let f = Fabric::new(1, WireConfig::off());
        let ctx = Context::new(f.node(0), ContextConfig::default()).unwrap();
        ctx.library_dir().install(Box::new(CounterIfunc::default()));
        let mut ring = IfuncRing::new(&ctx, 1 << 16).unwrap();
        let credit = ctx.mem_map(64, crate::fabric::MemPerm::RW);
        let replies = ReplyRing::new(&ctx, None);
        let consumed = ConsumedCounter::new(&ctx, None);
        let mut t =
            ShmTransport::new(ring.region(), credit.clone(), replies, consumed);

        let h = ctx.register_ifunc("counter").unwrap();
        let msg = h.msg_create(&SourceArgs::bytes(vec![0u8; 700])).unwrap();
        let mut args = TargetArgs::none();
        // Enough frames to wrap the 64 KiB ring several times; the poll
        // side pushes byte credit exactly like the worker loop does.
        for i in 0..300u64 {
            t.send_frame(&msg).unwrap();
            ctx.poll_ifunc_blocking(&mut ring, &mut args).unwrap();
            credit.store_u64_release(0, ring.consumed_bytes).unwrap();
            assert_eq!(ctx.symbols().counter_value(), i + 1);
        }
        assert_eq!(t.frames_sent(), 300);
    }

    /// A batch coalesces through the same single-reservation path as the
    /// fabric ring transport.
    #[test]
    fn shm_post_batch_delivers_all_frames() {
        let f = Fabric::new(1, WireConfig::off());
        let ctx = Context::new(f.node(0), ContextConfig::default()).unwrap();
        ctx.library_dir().install(Box::new(CounterIfunc::default()));
        let mut ring = IfuncRing::new(&ctx, 1 << 16).unwrap();
        let credit = ctx.mem_map(64, crate::fabric::MemPerm::RW);
        let replies = ReplyRing::new(&ctx, None);
        let consumed = ConsumedCounter::new(&ctx, None);
        let mut t =
            ShmTransport::new(ring.region(), credit.clone(), replies, consumed);

        let h = ctx.register_ifunc("counter").unwrap();
        let batch: Vec<IfuncMsg> = (0..8)
            .map(|i| h.msg_create(&SourceArgs::bytes(vec![0u8; 64 + i * 32])).unwrap())
            .collect();
        t.send_batch(&batch).unwrap();
        let mut args = TargetArgs::none();
        for _ in 0..batch.len() {
            ctx.poll_ifunc_blocking(&mut ring, &mut args).unwrap();
            credit.store_u64_release(0, ring.consumed_bytes).unwrap();
        }
        assert_eq!(ctx.symbols().counter_value(), batch.len() as u64);
    }

    /// The bounded capacity wait fires on shm exactly as on the fabric
    /// ring: nobody polling + a full ring = a transport error naming the
    /// stalled credit, not an infinite spin.
    #[test]
    fn shm_full_ring_with_no_poller_errors_not_hangs() {
        let f = Fabric::new(1, WireConfig::off());
        let ctx = Context::new(f.node(0), ContextConfig::default()).unwrap();
        ctx.library_dir().install(Box::new(CounterIfunc::default()));
        let ring = IfuncRing::new(&ctx, 4096).unwrap();
        let credit = ctx.mem_map(64, crate::fabric::MemPerm::RW);
        let replies = ReplyRing::new(&ctx, Some(std::time::Duration::from_millis(50)));
        let consumed = ConsumedCounter::new(&ctx, None);
        let mut t = ShmTransport::new(ring.region(), credit, replies, consumed);

        let h = ctx.register_ifunc("counter").unwrap();
        let msg = h.msg_create(&SourceArgs::bytes(vec![0u8; 512])).unwrap();
        let err = (0..64)
            .find_map(|_| t.send_frame(&msg).err())
            .expect("a 4 KiB ring with no poller must run out of credit");
        assert!(err.to_string().contains("no ring credit progress"), "{err}");
    }
}
