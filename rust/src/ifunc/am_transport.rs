//! ifuncs over send/receive semantics — the paper's §5.1 future work,
//! implemented as a thin adapter over the shared execution engine.
//!
//! "We are also working on switching the underlying implementation of
//! *Two-Chains* to use UCX's send-receive semantics instead of RDMA Puts.
//! This change will enable a simpler API because the user would not have
//! to worry about setting up a RWX-enabled buffer on the target process
//! ... ifuncs will be progressed with other UCX operations by calling
//! `ucp_worker_progress`."
//!
//! Here an ifunc frame travels as the payload of a reserved active
//! message; the target's normal [`crate::ucp::Worker::progress`] invokes
//! it — no ring, no rkey consensus, no special polling call. The trade-off
//! the paper predicts is visible in the ablation benches: AM delivery
//! buffers are not executable-in-place, so the frame pays a
//! **copy-on-execute** before [`crate::ucp::Context::execute_frame`] can
//! patch the GOT slot and mutate the payload (the cost the PUT transport's
//! in-place frames avoid).

use std::sync::{Arc, Mutex};

use crate::log;
use crate::ucp::{Context, Endpoint, Worker};
use crate::{Error, Result};

use super::engine::ExecOutcome;
use super::message::{Header, IfuncMsg};
use super::TargetArgs;

/// Reserved AM id for the ifunc-over-AM transport.
pub const IFUNC_AM_ID: u16 = 0x1FC0;

/// Install the ifunc-over-AM receive path on `worker`. All ifuncs arriving
/// on [`IFUNC_AM_ID`] execute against `target_args`.
pub fn install_am_ifunc(worker: &Arc<Worker>, target_args: Arc<Mutex<TargetArgs>>) {
    let ctx = worker.context().clone();
    worker.set_am_handler(IFUNC_AM_ID, move |_, frame| {
        if let Err(e) = execute_am_frame(&ctx, frame, &target_args) {
            log::error!("am-transport ifunc failed: {e}");
        }
    });
}

/// Send an ifunc message over the AM transport (the simpler API: no
/// remote_addr, no rkey).
pub fn ifunc_msg_send_am(ep: &Endpoint, msg: &IfuncMsg) -> Result<()> {
    ep.am_send(IFUNC_AM_ID, msg.frame())
}

/// Execute a frame delivered in an AM buffer: decode + integrity-check the
/// header, copy the frame out of the UCX-owned immutable buffer, then run
/// the shared engine pipeline on the copy.
pub fn execute_am_frame(
    ctx: &Context,
    frame: &[u8],
    target_args: &Arc<Mutex<TargetArgs>>,
) -> Result<ExecOutcome> {
    let header = Header::decode(frame)?
        .ok_or_else(|| Error::InvalidMessage("empty ifunc frame over AM".into()))?;
    if header.frame_len as usize != frame.len() {
        return Err(Error::InvalidMessage("frame length mismatch over AM".into()));
    }
    // Copy-on-execute: the engine patches the GOT slot and the injected
    // code mutates the payload in place, neither of which the AM delivery
    // buffer permits.
    let mut owned = frame.to_vec();
    let mut ta = target_args.lock().unwrap();
    ctx.execute_frame(&header, &mut owned, &mut ta)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{Fabric, WireConfig};
    use crate::ifunc::builtin::{ChecksumIfunc, CounterIfunc};
    use crate::ifunc::library::SourceArgs;
    use crate::ucp::ContextConfig;

    #[test]
    fn ifunc_over_am_executes() {
        let f = Fabric::new(2, WireConfig::off());
        let src = crate::ucp::Context::new(f.node(0), ContextConfig::default()).unwrap();
        let dst = crate::ucp::Context::new(f.node(1), ContextConfig::default()).unwrap();
        src.library_dir().install(Box::new(CounterIfunc::default()));
        let wa = Worker::new(&src);
        let wb = Worker::new(&dst);
        let ep = wa.connect(&wb).unwrap();
        install_am_ifunc(&wb, Arc::new(Mutex::new(TargetArgs::none())));

        let h = src.register_ifunc("counter").unwrap();
        let msg = h.msg_create(&SourceArgs::bytes(vec![0u8; 32])).unwrap();
        for _ in 0..5 {
            ifunc_msg_send_am(&ep, &msg).unwrap();
        }
        ep.flush().unwrap();
        wb.progress_until(|| dst.symbols().counter_value() == 5);
        // Repeat deliveries of one type hit the shared code cache.
        use std::sync::atomic::Ordering;
        assert_eq!(dst.ifunc_cache().misses.load(Ordering::Relaxed), 1);
        assert_eq!(dst.ifunc_cache().hits.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn am_transport_large_payload_checksum() {
        let f = Fabric::new(2, WireConfig::off());
        let src = crate::ucp::Context::new(f.node(0), ContextConfig::default()).unwrap();
        let dst = crate::ucp::Context::new(f.node(1), ContextConfig::default()).unwrap();
        src.library_dir().install(Box::new(ChecksumIfunc));
        let wa = Worker::new(&src);
        let wb = Worker::new(&dst);
        let ep = wa.connect(&wb).unwrap();
        install_am_ifunc(&wb, Arc::new(Mutex::new(TargetArgs::none())));

        // Rendezvous-sized frame (payload > rndv threshold).
        let payload = vec![1u8; 100_000];
        let h = src.register_ifunc("checksum").unwrap();
        let msg = h.msg_create(&SourceArgs::bytes(payload)).unwrap();
        ifunc_msg_send_am(&ep, &msg).unwrap();
        let wb2 = wb.clone();
        let t = std::thread::spawn(move || {
            wb2.progress_until(|| wb2.am_processed.load(std::sync::atomic::Ordering::SeqCst) >= 1)
        });
        ep.flush().unwrap();
        t.join().unwrap();
        assert_eq!(dst.symbols().last_result(), 100_000);
    }
}
