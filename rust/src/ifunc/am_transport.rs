//! ifuncs over send/receive semantics — the paper's §5.1 future work,
//! implemented as a thin adapter over the shared execution engine.
//!
//! "We are also working on switching the underlying implementation of
//! *Two-Chains* to use UCX's send-receive semantics instead of RDMA Puts.
//! This change will enable a simpler API because the user would not have
//! to worry about setting up a RWX-enabled buffer on the target process
//! ... ifuncs will be progressed with other UCX operations by calling
//! `ucp_worker_progress`."
//!
//! Here an ifunc frame travels as the payload of a reserved active
//! message; the target's normal [`crate::ucp::Worker::progress`] invokes
//! it — no ring, no rkey consensus, no special polling call. The
//! trade-off the paper predicts (§5.1) used to show up here as a
//! **copy-on-execute** per delivery; that cost is now gone on the default
//! path. The AM adapter registers a *mutable* handler
//! ([`crate::ucp::Worker::set_am_handler_mut`]), so eager frames execute
//! in place in the ring slot (exclusively owned between signal acquire
//! and release) and rendezvous frames execute in the owned fetch buffer —
//! the same in-place contract the RDMA-PUT transport's frames have always
//! had. The copying wrapper survives as [`execute_am_frame`] for callers
//! that only hold an immutable view (and as the "copy" column of Abl J).

use std::sync::{Arc, Mutex};

use crate::log;
use crate::ucp::{Context, Endpoint, Worker};
use crate::util::sync::lock_recover;
use crate::{Error, Result};

use super::engine::ExecOutcome;
use super::message::{Header, IfuncMsg};
use super::TargetArgs;

/// Reserved AM id for the ifunc-over-AM transport.
pub const IFUNC_AM_ID: u16 = 0x1FC0;

/// Install the ifunc-over-AM receive path on `worker`. All ifuncs arriving
/// on [`IFUNC_AM_ID`] execute against `target_args`, in place in the
/// delivery buffer (no per-frame copy).
pub fn install_am_ifunc(worker: &Arc<Worker>, target_args: Arc<Mutex<TargetArgs>>) {
    let ctx = worker.context().clone();
    worker.set_am_handler_mut(IFUNC_AM_ID, move |_, frame| {
        if let Err(e) = execute_am_frame_in_place(&ctx, frame, &target_args) {
            log::error!("am-transport ifunc failed: {e}");
        }
    });
}

/// Send an ifunc message over the AM transport (the simpler API: no
/// remote_addr, no rkey).
pub fn ifunc_msg_send_am(ep: &Endpoint, msg: &IfuncMsg) -> Result<()> {
    ep.am_send(IFUNC_AM_ID, msg.frame())
}

/// Execute a frame delivered in a mutable AM buffer: decode +
/// integrity-check the header, then run the shared engine pipeline
/// directly on the buffer — the engine patches the GOT slot and the
/// injected code mutates the payload where it landed.
pub fn execute_am_frame_in_place(
    ctx: &Context,
    frame: &mut [u8],
    target_args: &Arc<Mutex<TargetArgs>>,
) -> Result<ExecOutcome> {
    let header = Header::decode(frame)?
        .ok_or_else(|| Error::InvalidMessage("empty ifunc frame over AM".into()))?;
    if header.frame_len as usize != frame.len() {
        return Err(Error::InvalidMessage("frame length mismatch over AM".into()));
    }
    // Poison-tolerant like every other dispatch-path lock (PR 5): an
    // earlier panicked invocation must not wedge the AM progress loop.
    let mut ta = lock_recover(target_args);
    ctx.execute_frame(&header, frame, &mut ta)
}

/// Copying fallback for callers that only hold an immutable view of the
/// frame: pays one `to_vec` and delegates to
/// [`execute_am_frame_in_place`]. Not used on the default receive path.
pub fn execute_am_frame(
    ctx: &Context,
    frame: &[u8],
    target_args: &Arc<Mutex<TargetArgs>>,
) -> Result<ExecOutcome> {
    let mut owned = frame.to_vec();
    execute_am_frame_in_place(ctx, &mut owned, target_args)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{Fabric, WireConfig};
    use crate::ifunc::builtin::{ChecksumIfunc, CounterIfunc};
    use crate::ifunc::library::SourceArgs;
    use crate::ucp::ContextConfig;

    #[test]
    fn ifunc_over_am_executes() {
        let f = Fabric::new(2, WireConfig::off());
        let src = crate::ucp::Context::new(f.node(0), ContextConfig::default()).unwrap();
        let dst = crate::ucp::Context::new(f.node(1), ContextConfig::default()).unwrap();
        src.library_dir().install(Box::new(CounterIfunc::default()));
        let wa = Worker::new(&src);
        let wb = Worker::new(&dst);
        let ep = wa.connect(&wb).unwrap();
        install_am_ifunc(&wb, Arc::new(Mutex::new(TargetArgs::none())));

        let h = src.register_ifunc("counter").unwrap();
        let msg = h.msg_create(&SourceArgs::bytes(vec![0u8; 32])).unwrap();
        for _ in 0..5 {
            ifunc_msg_send_am(&ep, &msg).unwrap();
        }
        ep.flush().unwrap();
        wb.progress_until(|| dst.symbols().counter_value() == 5);
        // Repeat deliveries of one type hit the shared code cache.
        use std::sync::atomic::Ordering;
        assert_eq!(dst.ifunc_cache().misses.load(Ordering::Relaxed), 1);
        assert_eq!(dst.ifunc_cache().hits.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn am_transport_large_payload_checksum() {
        let f = Fabric::new(2, WireConfig::off());
        let src = crate::ucp::Context::new(f.node(0), ContextConfig::default()).unwrap();
        let dst = crate::ucp::Context::new(f.node(1), ContextConfig::default()).unwrap();
        src.library_dir().install(Box::new(ChecksumIfunc));
        let wa = Worker::new(&src);
        let wb = Worker::new(&dst);
        let ep = wa.connect(&wb).unwrap();
        install_am_ifunc(&wb, Arc::new(Mutex::new(TargetArgs::none())));

        // Rendezvous-sized frame (payload > rndv threshold).
        let payload = vec![1u8; 100_000];
        let h = src.register_ifunc("checksum").unwrap();
        let msg = h.msg_create(&SourceArgs::bytes(payload)).unwrap();
        ifunc_msg_send_am(&ep, &msg).unwrap();
        let wb2 = wb.clone();
        let t = std::thread::spawn(move || {
            wb2.progress_until(|| wb2.am_processed.load(std::sync::atomic::Ordering::SeqCst) >= 1)
        });
        ep.flush().unwrap();
        t.join().unwrap();
        assert_eq!(dst.symbols().last_result(), 100_000);
    }

    /// The copying wrapper and the in-place path must agree — and the
    /// in-place path must have patched the frame's GOT slot (proof it
    /// really executed in the caller's buffer, not a hidden copy).
    #[test]
    fn in_place_execute_mutates_callers_frame() {
        let f = Fabric::new(1, WireConfig::off());
        let ctx = crate::ucp::Context::new(f.node(0), ContextConfig::default()).unwrap();
        ctx.library_dir().install(Box::new(CounterIfunc::default()));
        let h = ctx.register_ifunc("counter").unwrap();
        let msg = h.msg_create(&SourceArgs::bytes(vec![0u8; 16])).unwrap();
        let ta = Arc::new(Mutex::new(TargetArgs::none()));

        let mut frame = msg.frame().to_vec();
        let before = frame.clone();
        let out = execute_am_frame_in_place(&ctx, &mut frame, &ta).unwrap();
        assert_eq!(out.ret, 1);
        assert_ne!(frame, before, "GOT patch must land in the caller's buffer");

        // The copying wrapper leaves the original untouched but executes
        // the same pipeline.
        let frame2 = msg.frame().to_vec();
        let out2 = execute_am_frame(&ctx, &frame2, &ta).unwrap();
        assert_eq!(out2.ret, 2);
        assert_eq!(frame2, msg.frame().to_vec());
    }
}
