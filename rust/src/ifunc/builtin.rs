//! Built-in ifunc libraries.
//!
//! * [`CounterIfunc`] — the paper's microbenchmark function: "the ifunc
//!   main function simply increases a counter on the target process used
//!   to count the number of executed messages" (§4.1). Used by the Fig. 3
//!   and Fig. 4 harnesses.
//! * [`XorIfunc`] — a pure-bytecode payload transform (no imports): proves
//!   injected code runs with an *empty* GOT.
//! * [`ChecksumIfunc`] — sums payload bytes in bytecode and reports the
//!   result through a GOT call (`record_result`).
//! * [`EchoIfunc`] — pushes its payload into the reply frame via
//!   `reply_put`: the smallest payload-returning invocation.
//! * [`HopIfunc`] — follows a payload-embedded itinerary through the
//!   worker↔worker mesh via `forward`, replying only at the last hop.

use crate::vm::Assembler;
use crate::Result;

use super::library::{IfuncLibrary, SourceArgs};
use super::message::CodeImage;

/// Copy-through payload helpers shared by the builtins: max size = args
/// size, init = memcpy (the benchmark payload content is arbitrary).
fn copy_payload(payload: &mut [u8], source_args: &SourceArgs) -> Result<usize> {
    payload[..source_args.len()].copy_from_slice(source_args.as_bytes());
    Ok(source_args.len())
}

/// The benchmark counter ifunc. `main` calls `counter_add(1)` through the
/// GOT; the target's [`crate::ifunc::Symbols`] binds it to a per-context
/// atomic counter.
#[derive(Default)]
pub struct CounterIfunc {
    /// Extra padding instructions, to study code-section-size effects
    /// (the paper: "the code sent in the ifunc messages dominate the
    /// message size" for small payloads). 0 = the minimal ~5-instruction
    /// body, matching a tiny C function's .text.
    pub pad_instrs: usize,
}

impl CounterIfunc {
    pub fn with_code_padding(pad_instrs: usize) -> Self {
        CounterIfunc { pad_instrs }
    }
}

impl IfuncLibrary for CounterIfunc {
    fn name(&self) -> &str {
        "counter"
    }

    fn payload_get_max_size(&self, source_args: &SourceArgs) -> usize {
        source_args.len()
    }

    fn payload_init(&self, payload: &mut [u8], source_args: &SourceArgs) -> Result<usize> {
        copy_payload(payload, source_args)
    }

    fn code(&self) -> CodeImage {
        let mut a = Assembler::new();
        for _ in 0..self.pad_instrs {
            a.nop();
        }
        a.ldi(1, 1); // r1 = increment
        a.call("counter_add");
        a.halt();
        let (vm_code, imports) = a.assemble();
        CodeImage { imports, vm_code, hlo: vec![] }
    }
}

/// XOR every payload byte with a key — a self-contained injected transform
/// with no external symbols (empty GOT).
pub struct XorIfunc {
    pub key: u8,
}

impl IfuncLibrary for XorIfunc {
    fn name(&self) -> &str {
        "xor"
    }

    fn payload_get_max_size(&self, source_args: &SourceArgs) -> usize {
        source_args.len()
    }

    fn payload_init(&self, payload: &mut [u8], source_args: &SourceArgs) -> Result<usize> {
        copy_payload(payload, source_args)
    }

    fn code(&self) -> CodeImage {
        let mut a = Assembler::new();
        let top = a.label();
        let done = a.label();
        a.paylen(3); // r3 = len
        a.ldi(2, 0); // r2 = i
        a.ldi(4, self.key as u32); // r4 = key
        a.bind(top);
        a.sltu(5, 2, 3);
        a.jz(5, done);
        a.ldb(6, 2, 0, 0);
        a.xor(6, 6, 4);
        a.stb(6, 2, 0, 0);
        a.addi(2, 2, 1);
        a.jmp(top);
        a.bind(done);
        a.halt();
        let (vm_code, imports) = a.assemble();
        CodeImage { imports, vm_code, hlo: vec![] }
    }
}

/// Sum payload bytes, then `record_result(sum)` through the GOT.
#[derive(Default)]
pub struct ChecksumIfunc;

impl IfuncLibrary for ChecksumIfunc {
    fn name(&self) -> &str {
        "checksum"
    }

    fn payload_get_max_size(&self, source_args: &SourceArgs) -> usize {
        source_args.len()
    }

    fn payload_init(&self, payload: &mut [u8], source_args: &SourceArgs) -> Result<usize> {
        copy_payload(payload, source_args)
    }

    fn code(&self) -> CodeImage {
        let mut a = Assembler::new();
        let top = a.label();
        let done = a.label();
        a.paylen(3);
        a.ldi(2, 0);
        a.ldi(7, 0); // r7 = acc
        a.bind(top);
        a.sltu(5, 2, 3);
        a.jz(5, done);
        a.ldb(6, 2, 0, 0);
        a.add(7, 7, 6);
        a.addi(2, 2, 1);
        a.jmp(top);
        a.bind(done);
        a.mov(1, 7);
        a.call("record_result");
        a.halt();
        let (vm_code, imports) = a.assemble();
        CodeImage { imports, vm_code, hlo: vec![] }
    }
}

/// Echo the whole payload back through the reply frame: `main` calls
/// `reply_put(0, payload_len)` through the GOT, so the invocation's reply
/// carries the payload bytes inline and `r0` is the reply length. The
/// minimal payload-carrying *invocation* (vs the fire-and-forget
/// builtins above) — used by the pipelined-invoke tests and benches to
/// check per-seq payload integrity under concurrency.
#[derive(Default)]
pub struct EchoIfunc;

impl IfuncLibrary for EchoIfunc {
    fn name(&self) -> &str {
        "echo"
    }

    fn payload_get_max_size(&self, source_args: &SourceArgs) -> usize {
        source_args.len()
    }

    fn payload_init(&self, payload: &mut [u8], source_args: &SourceArgs) -> Result<usize> {
        copy_payload(payload, source_args)
    }

    fn code(&self) -> CodeImage {
        let mut a = Assembler::new();
        a.ldi(1, 0); // r1 = payload offset
        a.paylen(2); // r2 = length
        a.call("reply_put"); // r0 = accumulated reply bytes
        a.halt();
        let (vm_code, imports) = a.assemble();
        CodeImage { imports, vm_code, hlo: vec![] }
    }
}

/// Multi-hop pipeline ifunc: the payload opens with an itinerary
/// (`[idx u64][n u64][peer u64; n]`) followed by opaque data. While
/// `idx < n` the invocation advances `idx` in place and calls
/// `forward(peers[idx], 0, payload_len)` — the whole (updated) payload
/// continues on the next worker over the mesh. At the end of the
/// itinerary it calls `reply_put` over the data region instead, so the
/// *final* hop's reply (just the data, no itinerary) relays back to the
/// leader. The canonical mesh-forwarding test/bench body.
#[derive(Default)]
pub struct HopIfunc;

impl HopIfunc {
    /// Assemble the payload for a chain visiting `peers` in order (after
    /// the leader's initial injection target), carrying `data`.
    pub fn payload(peers: &[usize], data: &[u8]) -> Vec<u8> {
        let mut p = Vec::with_capacity(16 + 8 * peers.len() + data.len());
        p.extend_from_slice(&0u64.to_le_bytes());
        p.extend_from_slice(&(peers.len() as u64).to_le_bytes());
        for &peer in peers {
            p.extend_from_slice(&(peer as u64).to_le_bytes());
        }
        p.extend_from_slice(data);
        p
    }
}

impl IfuncLibrary for HopIfunc {
    fn name(&self) -> &str {
        "hop"
    }

    fn payload_get_max_size(&self, source_args: &SourceArgs) -> usize {
        source_args.len()
    }

    fn payload_init(&self, payload: &mut [u8], source_args: &SourceArgs) -> Result<usize> {
        copy_payload(payload, source_args)
    }

    fn code(&self) -> CodeImage {
        let mut a = Assembler::new();
        let reply = a.label();
        a.paylen(7); // r7 = payload len
        a.ldi(6, 0); // r6 = 0 (base register for itinerary loads)
        a.ldw(2, 6, 0, 0); // r2 = idx
        a.ldw(3, 6, 0, 8); // r3 = n
        a.sltu(5, 2, 3);
        a.jz(5, reply);
        // Forward leg: r4 = byte offset of peers[idx].
        a.ldi(4, 8);
        a.mul(4, 2, 4);
        a.addi(4, 4, 16);
        a.ldw(1, 4, 0, 0); // r1 = next worker
        a.addi(2, 2, 1); // idx += 1, persisted for the next hop
        a.stw(2, 6, 0, 0);
        a.ldi(2, 0); // forward(worker, off=0, len=payload_len)
        a.mov(3, 7);
        a.call("forward");
        a.halt();
        // Reply leg: data starts at 16 + 8n.
        a.bind(reply);
        a.ldi(4, 8);
        a.mul(4, 3, 4);
        a.addi(4, 4, 16);
        a.mov(1, 4); // reply_put(off = data start, len = rest)
        a.sub(2, 7, 4);
        a.call("reply_put");
        a.halt();
        let (vm_code, imports) = a.assemble();
        CodeImage { imports, vm_code, hlo: vec![] }
    }
}

/// A deliberately hostile "library": its code tries to read past the
/// payload. Used by security tests to prove the verifier/interpreter
/// contains it (§3.5).
pub struct OutOfBoundsIfunc;

impl IfuncLibrary for OutOfBoundsIfunc {
    fn name(&self) -> &str {
        "oob"
    }

    fn payload_get_max_size(&self, source_args: &SourceArgs) -> usize {
        source_args.len()
    }

    fn payload_init(&self, payload: &mut [u8], source_args: &SourceArgs) -> Result<usize> {
        copy_payload(payload, source_args)
    }

    fn code(&self) -> CodeImage {
        let mut a = Assembler::new();
        a.paylen(2);
        a.ldb(0, 2, 0, 1024); // read payload[len + 1024] — always OOB
        a.halt();
        let (vm_code, imports) = a.assemble();
        CodeImage { imports, vm_code, hlo: vec![] }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_code_is_small() {
        // The paper's point: the benchmark ifunc's code is a few hundred
        // bytes that dominate small messages.
        let code = CounterIfunc::default().code();
        assert!(code.vm_code.len() <= 64, "counter code should be tiny");
        assert_eq!(code.imports, vec!["counter_add".to_string()]);
    }

    #[test]
    fn padding_grows_code_section() {
        let small = CounterIfunc::default().code();
        let big = CounterIfunc::with_code_padding(100).code();
        assert_eq!(big.vm_code.len(), small.vm_code.len() + 100 * 8);
    }

    #[test]
    fn xor_has_empty_imports() {
        assert!(XorIfunc { key: 0x5A }.code().imports.is_empty());
    }
}
