//! `ucp_poll_ifunc` — the target-side receive loop for ring delivery
//! (Fig. 2), as a thin adapter over the shared execution engine.
//!
//! Per delivered frame, in order:
//!
//! 1. read the header word; zero → `NoMessage`, wrap marker → rewind,
//! 2. validate the header via its check word ("the integrity of the
//!    header is verified using the header signal, and messages that are
//!    ill-formed or too long will be rejected", §3.4),
//! 3. `wait_mem` on the trailer signal (the `WFE` busy-wait of §3.2),
//! 4. hand the frame — **in place in the ring** — to
//!    [`crate::ucp::Context::execute_frame`] (decode → cache → link →
//!    verify → compile → HLO ensure → invoke; see `ifunc::engine`),
//! 5. consume: zero header + trailer words, advance the cursor — whether
//!    the frame executed *or was rejected*. Any frame that passes header
//!    validation is consumed even when it fails before invoke
//!    (code-decode/verify/link error), so a hostile-but-well-framed
//!    message can never wedge the poll loop.
//!
//! Frames that fail *header* validation (check-word mismatch, or a
//! trailer signal that never arrives) cannot be consumed: the frame
//! length itself is untrusted, so skipping by it could corrupt the
//! stream. Those remain errors at an unchanged cursor — the paper's
//! model (§3.5) leaves senders that can write garbage to an
//! rkey-authorized ring outside the threat model.

use std::time::{Duration, Instant};

use crate::ucp::Context;
use crate::{Error, Result};

use super::engine::ExecOutcome;
use super::message::{Header, Hop, HEADER_BYTES, HOP_KIND_RELAY, MAGIC, WRAP_MAGIC};
use super::ring::IfuncRing;
use super::TargetArgs;

/// Result of one poll call (`ucs_status_t`: `UCS_OK` vs `UCS_ERR_NO_MESSAGE`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PollResult {
    /// A message was received, linked, and executed; the outcome carries
    /// `r0` and any reply payload the injected function pushed.
    Executed(ExecOutcome),
    /// No complete message at the cursor.
    NoMessage,
}

/// Result of one mesh-ingress poll call ([`Context::poll_ifunc_mesh`]).
/// Unlike the leader path, a mesh ring carries two frame kinds, and each
/// consumed frame's hop metadata must travel out with the outcome — the
/// caller needs the origin to route the reply and the hop count / TTL to
/// report a broken chain.
#[derive(Debug)]
pub enum MeshPollResult {
    /// An invoke-kind frame was consumed. `outcome` is the execution
    /// result — `Err` for a frame that was consumed but failed
    /// (decode/verify/runtime), which on the mesh must still produce a
    /// failure relay to the origin rather than silence.
    Executed { hop: Hop, outcome: Result<ExecOutcome> },
    /// A relay-kind frame (a finished chain's reply in transit to its
    /// origin) was consumed: the payload is `IfuncMsg::relay` encoding,
    /// never executable code.
    Relay { hop: Hop, payload: Vec<u8> },
    /// No complete message at the cursor.
    NoMessage,
}

/// How long poll waits for a trailer after a valid header before declaring
/// the frame corrupt. Generous: covers the wire model's worst case.
const TRAILER_TIMEOUT: Duration = Duration::from_secs(10);

impl Context {
    /// Poll `ring` for one ifunc message; if present, execute it with
    /// `target_args` and return [`PollResult::Executed`].
    pub fn poll_ifunc(
        &self,
        ring: &mut IfuncRing,
        target_args: &mut TargetArgs,
    ) -> Result<PollResult> {
        loop {
            let cursor = ring.cursor();
            let word = ring.mr().load_u64_acquire(cursor)?;
            if word == 0 {
                return Ok(PollResult::NoMessage);
            }
            if word as u32 == WRAP_MAGIC {
                // Stream continues at offset 0.
                ring.mr().store_u64_release(cursor, 0)?;
                ring.rewind();
                continue;
            }
            if word as u32 != MAGIC {
                return Err(Error::InvalidMessage(format!(
                    "bad header word {word:#018x} at ring offset {cursor}"
                )));
            }
            return self.receive_one(ring, target_args);
        }
    }

    /// Wait out the frame at the cursor: re-read the header until its
    /// check word passes (the fabric orders only the final word of the
    /// put), bound it against the ring, then spin on the trailer signal
    /// (Fig. 2's WFE-style wait). Returns the validated header; the frame
    /// bytes are fully arrived on `Ok`. Shared by the leader and mesh
    /// receive paths.
    fn await_frame(&self, ring: &IfuncRing) -> Result<Header> {
        let cursor = ring.cursor();
        let deadline = Instant::now() + TRAILER_TIMEOUT;
        let header = loop {
            match Header::decode(&ring.mr().local_slice()[cursor..cursor + HEADER_BYTES]) {
                Ok(Some(h)) => break h,
                Ok(None) => unreachable!("caller saw nonzero magic"),
                Err(e) => {
                    if Instant::now() > deadline {
                        return Err(e);
                    }
                    crate::fabric::wire::backoff(0);
                }
            }
        };
        let frame_len = header.frame_len as usize;
        if cursor + frame_len > ring.size() {
            return Err(Error::InvalidMessage(format!(
                "frame of {frame_len} bytes overruns ring (cursor {cursor}, ring {})",
                ring.size()
            )));
        }
        let trailer_off = cursor + frame_len - 8;
        let mut trailer_spins = 0u32;
        loop {
            let t = ring.mr().load_u64_acquire(trailer_off)?;
            if t == header.trailer_sig {
                return Ok(header);
            }
            if Instant::now() > deadline {
                return Err(Error::InvalidMessage(
                    "trailer signal never arrived (truncated frame?)".into(),
                ));
            }
            crate::fabric::wire::backoff(trailer_spins);
            trailer_spins += 1;
        }
    }

    /// Zero the frame's header + trailer words and advance the cursor.
    fn consume_frame(&self, ring: &mut IfuncRing, frame_len: usize) -> Result<()> {
        let cursor = ring.cursor();
        ring.mr().store_u64_release(cursor, 0)?;
        ring.mr().store_u64_release(cursor + frame_len - 8, 0)?;
        ring.advance(frame_len);
        Ok(())
    }

    fn receive_one(
        &self,
        ring: &mut IfuncRing,
        target_args: &mut TargetArgs,
    ) -> Result<PollResult> {
        let header = self.await_frame(ring)?;
        let cursor = ring.cursor();
        let frame_len = header.frame_len as usize;

        // The frame has fully arrived: execute it in place in the ring.
        let outcome = {
            // SAFETY-equivalent contract: the frame slice is inside the
            // consumed region; the sender will not rewrite it until the
            // consumption protocol says so.
            let frame = &mut ring.mr().local_slice_mut()[cursor..cursor + frame_len];
            self.execute_frame(&header, frame, target_args)
        };

        // Consume-on-reject: the frame is consumed whether it executed or
        // was rejected (decode/link/verify/runtime failure) — errors are
        // reported to the caller but never leave the frame in the ring.
        self.consume_frame(ring, frame_len)?;
        Ok(PollResult::Executed(outcome?))
    }

    /// Poll a **mesh-ingress** ring for one frame. Same wire protocol as
    /// [`Context::poll_ifunc`] (header word → validate → trailer spin →
    /// consume), but kind-aware: a relay frame — a finished chain's reply
    /// in transit to its origin — carries an *empty* code section and
    /// must never reach the execution engine; its payload is copied out
    /// and handed back instead. Errors that consumed the frame (a bad
    /// invoke) are folded into [`MeshPollResult::Executed`] so the hop
    /// metadata survives for the failure relay; header-integrity errors
    /// stay non-consuming `Err`s at an unchanged cursor, exactly like the
    /// leader path.
    pub fn poll_ifunc_mesh(
        &self,
        ring: &mut IfuncRing,
        target_args: &mut TargetArgs,
    ) -> Result<MeshPollResult> {
        loop {
            let cursor = ring.cursor();
            let word = ring.mr().load_u64_acquire(cursor)?;
            if word == 0 {
                return Ok(MeshPollResult::NoMessage);
            }
            if word as u32 == WRAP_MAGIC {
                ring.mr().store_u64_release(cursor, 0)?;
                ring.rewind();
                continue;
            }
            if word as u32 != MAGIC {
                return Err(Error::InvalidMessage(format!(
                    "bad header word {word:#018x} at mesh ring offset {cursor}"
                )));
            }
            let header = self.await_frame(ring)?;
            let frame_len = header.frame_len as usize;
            let hop = header.hop;
            if hop.kind == HOP_KIND_RELAY {
                let pay_start = cursor + header.payload_offset as usize;
                let payload =
                    ring.mr().local_slice()[pay_start..pay_start + header.payload_len as usize]
                        .to_vec();
                self.consume_frame(ring, frame_len)?;
                return Ok(MeshPollResult::Relay { hop, payload });
            }
            let outcome = {
                let frame = &mut ring.mr().local_slice_mut()[cursor..cursor + frame_len];
                self.execute_frame(&header, frame, target_args)
            };
            self.consume_frame(ring, frame_len)?;
            return Ok(MeshPollResult::Executed { hop, outcome });
        }
    }

    /// Blocking receive helper: poll until one message executes
    /// (`ucs_arch_wait_mem`-assisted loop of §3.2).
    pub fn poll_ifunc_blocking(
        &self,
        ring: &mut IfuncRing,
        target_args: &mut TargetArgs,
    ) -> Result<()> {
        let mut idle = 0u32;
        loop {
            match self.poll_ifunc(ring, target_args)? {
                PollResult::Executed(_) => return Ok(()),
                PollResult::NoMessage => {
                    crate::fabric::wire::backoff(idle);
                    idle += 1;
                }
            }
        }
    }
}
