//! `ucp_poll_ifunc` — the target-side receive/link/invoke loop (Fig. 2).
//!
//! Per delivered frame, in order:
//!
//! 1. read the header word; zero → `NoMessage`, wrap marker → rewind,
//! 2. validate the header via its check word ("the integrity of the
//!    header is verified using the header signal, and messages that are
//!    ill-formed or too long will be rejected", §3.4),
//! 3. `wait_mem` on the trailer signal (the `WFE` busy-wait of §3.2),
//! 4. **auto-register** the ifunc type on first sight: resolve the shipped
//!    import table against the local symbol table into a GOT, verify the
//!    bytecode, and — if the frame carries an HLO artifact — compile it on
//!    this thread's PJRT runtime; cache everything by name (§3.4),
//! 5. patch the frame's GOT slot with the cache entry id (the "alternative
//!    GOT pointer" patch of §3.4),
//! 6. `clear_cache` over the code section (§4.3's non-coherent I-cache),
//! 7. invoke `main(payload, payload_size, target_args)` — the TCVM runs
//!    the code *in place in the ring*,
//! 8. zero header + trailer words, advance the cursor.

use std::time::{Duration, Instant};

use crate::ucp::Context;
use crate::vm;
use crate::{Error, Result};

use super::icache;
use super::message::{CodeImage, Header, HEADER_BYTES, MAGIC, WRAP_MAGIC};
use super::ring::IfuncRing;
use super::TargetArgs;

/// Result of one poll call (`ucs_status_t`: `UCS_OK` vs `UCS_ERR_NO_MESSAGE`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PollResult {
    /// A message was received, linked, and executed.
    Executed,
    /// No complete message at the cursor.
    NoMessage,
}

/// How long poll waits for a trailer after a valid header before declaring
/// the frame corrupt. Generous: covers the wire model's worst case.
const TRAILER_TIMEOUT: Duration = Duration::from_secs(10);

impl Context {
    /// Poll `ring` for one ifunc message; if present, execute it with
    /// `target_args` and return [`PollResult::Executed`].
    pub fn poll_ifunc(
        &self,
        ring: &mut IfuncRing,
        target_args: &mut TargetArgs,
    ) -> Result<PollResult> {
        loop {
            let cursor = ring.cursor();
            let word = ring.mr().load_u64_acquire(cursor)?;
            if word == 0 {
                return Ok(PollResult::NoMessage);
            }
            if word as u32 == WRAP_MAGIC {
                // Stream continues at offset 0.
                ring.mr().store_u64_release(cursor, 0)?;
                ring.rewind();
                continue;
            }
            if word as u32 != MAGIC {
                return Err(Error::InvalidMessage(format!(
                    "bad header word {word:#018x} at ring offset {cursor}"
                )));
            }
            return self.receive_one(ring, target_args);
        }
    }

    fn receive_one(
        &self,
        ring: &mut IfuncRing,
        target_args: &mut TargetArgs,
    ) -> Result<PollResult> {
        let cursor = ring.cursor();
        // The header may still be streaming in (the fabric orders only the
        // final word of the put); re-read until its check word passes.
        let deadline = Instant::now() + TRAILER_TIMEOUT;
        let header = loop {
            match Header::decode(&ring.mr().local_slice()[cursor..cursor + HEADER_BYTES]) {
                Ok(Some(h)) => break h,
                Ok(None) => unreachable!("caller saw nonzero magic"),
                Err(e) => {
                    if Instant::now() > deadline {
                        return Err(e);
                    }
                    crate::fabric::wire::backoff(0);
                }
            }
        };
        let frame_len = header.frame_len as usize;
        if cursor + frame_len > ring.size() {
            return Err(Error::InvalidMessage(format!(
                "frame of {frame_len} bytes overruns ring (cursor {cursor}, ring {})",
                ring.size()
            )));
        }

        // Fig. 2: wait for the trailer signal (WFE-style spin).
        let trailer_off = cursor + frame_len - 8;
        let mut trailer_spins = 0u32;
        loop {
            let t = ring.mr().load_u64_acquire(trailer_off)?;
            if t == header.trailer_sig {
                break;
            }
            if Instant::now() > deadline {
                return Err(Error::InvalidMessage(
                    "trailer signal never arrived (truncated frame?)".into(),
                ));
            }
            crate::fabric::wire::backoff(trailer_spins);
            trailer_spins += 1;
        }

        // Decode the code section (borrowed — no copies of the vm code or
        // HLO blob) and link (auto-registration on miss).
        let code_start = cursor + header.code_offset as usize;
        let code_end = code_start + header.code_len as usize;
        let (_got_slot, image) =
            CodeImage::decode_ref(&ring.mr().local_slice()[code_start..code_end])?;
        let cached = self.cache.lookup(&header.name);
        let linked = match cached {
            Some(entry)
                if entry.imports.iter().map(String::as_str).eq(image.imports.iter().copied()) =>
            {
                entry
            }
            _ => {
                // First-seen type (or changed import table): reconstruct
                // the GOT from the local symbol table, and compile the
                // shipped HLO artifact if any — no filesystem involved.
                let got = self.symbols().table().resolve_iter(image.imports.iter().copied())?;
                let has_hlo = !image.hlo.is_empty();
                if has_hlo {
                    crate::runtime::with_runtime(|rt| {
                        rt.ensure_compiled(&header.name, image.hlo)
                    })?;
                }
                let owned: Vec<String> = image.imports.iter().map(|s| s.to_string()).collect();
                self.cache.insert(&header.name, owned, got, has_hlo)
            }
        };

        // Patch the frame's GOT slot (the hidden-global indirection of
        // §3.4) with the cache entry id.
        let got_off = cursor + header.got_offset as usize;
        ring.mr().local_slice_mut()[got_off..got_off + 4]
            .copy_from_slice(&linked.id.to_le_bytes());

        // Verify the shipped bytecode (per arrival: the code in *this*
        // message is what runs), then clear the I-cache over it.
        let prog = vm::verify(image.vm_code, image.imports.len())?;
        icache::clear_cache(
            &self.config().icache,
            header.code_len as usize,
            self.icache_stats(),
        );

        // Invoke main(payload, payload_size, target_args), in place.
        let pay_start = cursor + header.payload_offset as usize;
        let pay_end = pay_start + header.payload_len as usize;
        target_args.hlo_name = if linked.has_hlo { Some(header.name.clone()) } else { None };
        let outcome = {
            // SAFETY-equivalent contract: the payload slice is inside the
            // consumed frame; the sender will not rewrite it until the
            // consumption protocol says so.
            let payload: &mut [u8] = &mut ring.mr().local_slice_mut()[pay_start..pay_end];
            vm::run(&prog, &linked.got, payload, target_args, &self.config().vm)
        };
        target_args.hlo_name = None;
        target_args.last_return = outcome.as_ref().map(|o| o.ret).ok();

        // Consume: zero header + trailer words, advance.
        ring.mr().store_u64_release(cursor, 0)?;
        ring.mr().store_u64_release(trailer_off, 0)?;
        ring.advance(frame_len);
        outcome?;
        Ok(PollResult::Executed)
    }

    /// Blocking receive helper: poll until one message executes
    /// (`ucs_arch_wait_mem`-assisted loop of §3.2).
    pub fn poll_ifunc_blocking(
        &self,
        ring: &mut IfuncRing,
        target_args: &mut TargetArgs,
    ) -> Result<()> {
        let mut idle = 0u32;
        loop {
            match self.poll_ifunc(ring, target_args)? {
                PollResult::Executed => return Ok(()),
                PollResult::NoMessage => {
                    crate::fabric::wire::backoff(idle);
                    idle += 1;
                }
            }
        }
    }
}
