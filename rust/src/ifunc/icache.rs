//! Instruction-cache model.
//!
//! The paper's testbed does **not** have a coherent I-cache (§4.3): after
//! the fabric confirms an ifunc's code bytes have arrived, the target must
//! run `clear_cache` over the code region before invoking it, or it may
//! execute stale instructions. The authors identify this flush as the main
//! reason ifuncs lose to AMs at small payload sizes, and list evaluating a
//! coherent-I-cache machine as future work.
//!
//! We model it as an explicit per-arrival cost charged in `ucp_poll_ifunc`:
//! a fixed barrier (`DSB`/`ISB` + branch-predictor maintenance) plus a
//! per-64-byte-line cost over the *code* section (glibc's
//! `__aarch64_sync_cache_range` walks `IC IVAU` line by line). A coherent
//! configuration skips the walk entirely, the same way glibc elides the
//! flush after reading `CTR_EL0.DIC/IDC` — giving us the paper's "future
//! work" ablation (Abl A).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::fabric::spin_for;

/// Cache line size assumed by the flush walk.
pub const LINE_BYTES: usize = 64;

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct IcacheConfig {
    /// If true, `clear_cache` is a no-op (CTR_EL0 reports DIC+IDC).
    pub coherent: bool,
    /// Fixed cost per flush call: barriers + kernel-assisted IC maintenance.
    pub barrier_ns: u64,
    /// Cost per flushed 64-byte line (`DC CVAU` + `IC IVAU` + refetch miss).
    pub line_ns: u64,
}

impl IcacheConfig {
    /// The paper's testbed (§4.2/§4.3): non-coherent, so every arrival pays.
    /// Costs calibrated so the injected-code flush lands in the
    /// half-microsecond range for a ~600-byte code section — consistent
    /// with the latency gap the paper attributes to `clear_cache`.
    pub fn non_coherent() -> Self {
        IcacheConfig { coherent: false, barrier_ns: 250, line_ns: 35 }
    }

    /// The "machine that has a coherent I-cache" of §5.1 (Abl A).
    pub fn coherent() -> Self {
        IcacheConfig { coherent: true, barrier_ns: 0, line_ns: 0 }
    }

    /// Modeled cost of flushing `code_bytes` of newly-arrived code.
    pub fn flush_cost(&self, code_bytes: usize) -> Duration {
        if self.coherent {
            return Duration::ZERO;
        }
        let lines = code_bytes.div_ceil(LINE_BYTES) as u64;
        Duration::from_nanos(self.barrier_ns + lines * self.line_ns)
    }
}

impl Default for IcacheConfig {
    fn default() -> Self {
        IcacheConfig::non_coherent()
    }
}

/// Runtime stats: how much time the poll loop spent in simulated flushes.
#[derive(Default)]
pub struct IcacheStats {
    pub flushes: AtomicU64,
    pub flushed_bytes: AtomicU64,
    pub flush_ns: AtomicU64,
}

/// Charge a `clear_cache(code)` — called by `ucp_poll_ifunc` once per
/// delivered ifunc message, after the trailer signal confirms arrival and
/// before invocation (paper §4.3).
pub fn clear_cache(cfg: &IcacheConfig, code_bytes: usize, stats: &IcacheStats) {
    let cost = cfg.flush_cost(code_bytes);
    if !cost.is_zero() {
        spin_for(cost);
    }
    stats.flushes.fetch_add(1, Ordering::Relaxed);
    stats.flushed_bytes.fetch_add(code_bytes as u64, Ordering::Relaxed);
    stats.flush_ns.fetch_add(cost.as_nanos() as u64, Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coherent_flush_is_free() {
        assert_eq!(IcacheConfig::coherent().flush_cost(1 << 20), Duration::ZERO);
    }

    #[test]
    fn cost_scales_with_code_lines() {
        let c = IcacheConfig::non_coherent();
        assert!(c.flush_cost(4096) > c.flush_cost(64));
        assert_eq!(
            c.flush_cost(640),
            Duration::from_nanos(c.barrier_ns + 10 * c.line_ns)
        );
    }

    #[test]
    fn stats_accumulate() {
        let stats = IcacheStats::default();
        clear_cache(&IcacheConfig::coherent(), 128, &stats);
        clear_cache(&IcacheConfig::coherent(), 128, &stats);
        assert_eq!(stats.flushes.load(Ordering::Relaxed), 2);
        assert_eq!(stats.flushed_bytes.load(Ordering::Relaxed), 256);
    }
}
