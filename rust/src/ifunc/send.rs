//! `ucp_ifunc_msg_send_nbix` — one-sided frame delivery (Listing 1.1).
//!
//! The entire frame (header + code + payload + trailer) is written with a
//! single `ucp_put_nbi` into the target's mapped ring. The fabric, like
//! InfiniBand, writes the final 8 bytes last, so the trailer signal is the
//! arrival barrier the target's poll waits on (Fig. 2).

use crate::fabric::RKey;
use crate::ucp::Endpoint;
use crate::Result;

use super::message::IfuncMsg;
use super::ring::{wrap_marker_word, Placement, SenderCursor};

impl Endpoint {
    /// Non-blocking injected-function send: PUT `msg`'s frame at
    /// `remote_addr` within the region named by `rkey`. Completion is
    /// observed with [`Endpoint::flush`]; consumption is the application's
    /// protocol (the paper's benchmarks use a consumed-count notification).
    pub fn ifunc_msg_send_nbix(
        &self,
        msg: &IfuncMsg,
        remote_addr: usize,
        rkey: RKey,
    ) -> Result<()> {
        self.put_nbi(rkey, remote_addr, msg.frame())
    }

    /// Place-and-send through a [`SenderCursor`]: emits the wrap marker
    /// when needed, then sends the frame at the cursor-chosen offset.
    /// Returns the placement used.
    pub fn ifunc_msg_send_cursor(
        &self,
        msg: &IfuncMsg,
        cursor: &mut SenderCursor,
        rkey: RKey,
    ) -> Result<Placement> {
        let placement = cursor.place(msg.len())?;
        if let Some(at) = placement.wrap_marker_at {
            self.put_nbi(rkey, at, &wrap_marker_word().to_le_bytes())?;
        }
        self.put_nbi(rkey, placement.offset, msg.frame())?;
        Ok(placement)
    }
}

#[cfg(test)]
mod tests {
    use crate::fabric::{Fabric, WireConfig};
    use crate::ifunc::builtin::CounterIfunc;
    use crate::ifunc::library::SourceArgs;
    use crate::ifunc::message::{Header, MAGIC};
    use crate::ifunc::ring::IfuncRing;
    use crate::ucp::{Context, ContextConfig, Worker};

    #[test]
    fn frame_lands_in_ring_with_trailer() {
        let f = Fabric::new(2, WireConfig::off());
        let src = Context::new(f.node(0), ContextConfig::default()).unwrap();
        let dst = Context::new(f.node(1), ContextConfig::default()).unwrap();
        src.library_dir().install(Box::new(CounterIfunc::default()));
        let ring = IfuncRing::new(&dst, 1 << 16).unwrap();
        let wa = Worker::new(&src);
        let wb = Worker::new(&dst);
        let ep = wa.connect(&wb).unwrap();

        let h = src.register_ifunc("counter").unwrap();
        let msg = h.msg_create(&SourceArgs::bytes(vec![1, 2, 3, 4])).unwrap();
        ep.ifunc_msg_send_nbix(&msg, ring.remote_addr(), ring.rkey()).unwrap();
        ep.flush().unwrap();

        let bytes = ring.mr().local_slice();
        let hdr = Header::decode(bytes).unwrap().unwrap();
        assert_eq!(hdr.name, "counter");
        assert_eq!(&bytes[..4], &MAGIC.to_le_bytes());
        let t = u64::from_le_bytes(
            bytes[hdr.frame_len as usize - 8..hdr.frame_len as usize].try_into().unwrap(),
        );
        assert_eq!(t, hdr.trailer_sig);
    }
}
