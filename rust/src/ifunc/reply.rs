//! The injection reply path: a small per-worker reply ring carrying
//! `(seq, status, r0)` back to the sender.
//!
//! The paper's ifuncs are fire-and-forget; anything the injected function
//! computes stays on the target. This module adds the missing half of an
//! *invocation*: after the execution engine finishes frame `seq` (the
//! `seq`-th frame delivered on the link, counting executed **and**
//! rejected frames), the worker writes one fixed-size slot into a
//! leader-mapped reply region with a one-sided put — the same mechanism
//! frames travel by, just pointed back at the sender. The slot layout is
//!
//! ```text
//!  | r0     | 8 B   injected main's return value (0 when rejected)
//!  | status | 8 B   1 = executed, 2 = rejected
//!  | seq    | 8 B   frame sequence number, written last
//! ```
//!
//! `seq` is the arrival barrier: the fabric delivers the final word of a
//! put last (the trailer-signal property of §3.4), so once the reader
//! observes `seq` in a slot, `r0` and `status` are valid. Slots are reused
//! modulo [`REPLY_SLOTS`]; because the full 64-bit seq is stored, a reader
//! that waited too long detects the overwrite instead of misreading.
//!
//! Both transports share this channel — it doubles as the completion
//! credit `Dispatcher::barrier` waits on (the reply for the last frame
//! sent implies, by in-order delivery, that every frame was consumed).

use std::sync::Arc;

use crate::fabric::{MemPerm, MemoryRegion, RKey};
use crate::ucp::{Context, Endpoint};
use crate::{Error, Result};

/// Slots in a reply ring. Replies are read promptly (an `invoke` waits for
/// its own seq; `barrier` waits for the last), so a small ring suffices.
pub const REPLY_SLOTS: usize = 256;
/// Bytes per slot: `[r0 u64][status u64][seq u64]`.
pub const REPLY_SLOT_BYTES: usize = 24;
/// Total reply-region bytes.
pub const REPLY_REGION_BYTES: usize = REPLY_SLOTS * REPLY_SLOT_BYTES;

/// Frame executed to completion; `r0` is the injected main's return value.
pub const STATUS_OK: u64 = 1;
/// Frame consumed but rejected (decode/link/verify/runtime failure).
pub const STATUS_FAILED: u64 = 2;

/// One injection's reply.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Reply {
    /// Sequence number of the frame this reply answers (1-based).
    pub seq: u64,
    /// Whether the injected function ran to completion.
    pub ok: bool,
    /// `r0` at `HALT` (0 when the frame was rejected).
    pub r0: u64,
}

fn slot_off(seq: u64) -> usize {
    ((seq - 1) as usize % REPLY_SLOTS) * REPLY_SLOT_BYTES
}

/// Sender-side reply ring: a mapped region the worker puts slots into.
pub struct ReplyRing {
    mr: Arc<MemoryRegion>,
}

impl ReplyRing {
    /// Map a reply region on `ctx` (the sender/leader side).
    pub fn new(ctx: &Context) -> Self {
        ReplyRing { mr: ctx.mem_map(REPLY_REGION_BYTES, MemPerm::RWX) }
    }

    /// The rkey the worker-side [`ReplyWriter`] puts into.
    pub fn rkey(&self) -> RKey {
        self.mr.rkey()
    }

    /// Spin until the reply for frame `seq` (1-based) arrives. Errors if
    /// the slot was already overwritten by a later lap of the ring.
    pub fn wait(&self, seq: u64) -> Result<Reply> {
        debug_assert!(seq > 0, "frame seqs are 1-based");
        let off = slot_off(seq);
        let mut i = 0u32;
        loop {
            // seq occupies the slot's final word, so it lands last.
            let got = self.mr.load_u64_acquire(off + 16)?;
            if got == seq {
                let r0 = self.mr.load_u64_acquire(off)?;
                let status = self.mr.load_u64_acquire(off + 8)?;
                return Ok(Reply { seq, ok: status == STATUS_OK, r0 });
            }
            if got > seq {
                return Err(Error::Transport(format!(
                    "reply for frame {seq} overwritten (slot now holds seq {got})"
                )));
            }
            crate::fabric::wire::backoff(i);
            i += 1;
        }
    }
}

/// Worker-side reply writer bound to one sender's reply ring.
pub struct ReplyWriter {
    ep: Arc<Endpoint>,
    rkey: RKey,
    seq: u64,
}

impl ReplyWriter {
    /// `ep` is a worker → sender endpoint; `rkey` names the sender's
    /// reply region.
    pub fn new(ep: Arc<Endpoint>, rkey: RKey) -> Self {
        ReplyWriter { ep, rkey, seq: 0 }
    }

    /// Record the outcome of the next consumed frame; returns its seq.
    pub fn push(&mut self, ok: bool, r0: u64) -> Result<u64> {
        self.seq += 1;
        let mut slot = [0u8; REPLY_SLOT_BYTES];
        slot[0..8].copy_from_slice(&r0.to_le_bytes());
        slot[8..16]
            .copy_from_slice(&(if ok { STATUS_OK } else { STATUS_FAILED }).to_le_bytes());
        slot[16..24].copy_from_slice(&self.seq.to_le_bytes());
        self.ep.put_nbi(self.rkey, slot_off(self.seq), &slot)?;
        Ok(self.seq)
    }

    /// Frames replied to so far.
    pub fn seq(&self) -> u64 {
        self.seq
    }

    /// Local completion of all pushed replies.
    pub fn flush(&self) -> Result<()> {
        self.ep.qp().flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::{Fabric, WireConfig};
    use crate::ucp::{ContextConfig, Worker};

    fn pair() -> (ReplyRing, ReplyWriter) {
        let f = Fabric::new(2, WireConfig::off());
        let leader = Context::new(f.node(0), ContextConfig::default()).unwrap();
        let worker = Context::new(f.node(1), ContextConfig::default()).unwrap();
        let wl = Worker::new(&leader);
        let ww = Worker::new(&worker);
        let ring = ReplyRing::new(&leader);
        let ep = ww.connect(&wl).unwrap();
        let rkey = ring.rkey();
        (ring, ReplyWriter::new(ep, rkey))
    }

    #[test]
    fn reply_roundtrip_preserves_r0_and_status() {
        let (ring, mut w) = pair();
        w.push(true, 42).unwrap();
        w.push(false, 0).unwrap();
        assert_eq!(ring.wait(1).unwrap(), Reply { seq: 1, ok: true, r0: 42 });
        assert_eq!(ring.wait(2).unwrap(), Reply { seq: 2, ok: false, r0: 0 });
    }

    #[test]
    fn slots_wrap_and_overwrite_is_detected() {
        let (ring, mut w) = pair();
        // Two full laps: seq N and N + REPLY_SLOTS share a slot.
        for i in 0..(2 * REPLY_SLOTS as u64) {
            w.push(true, i).unwrap();
        }
        w.flush().unwrap();
        let last = 2 * REPLY_SLOTS as u64;
        assert_eq!(ring.wait(last).unwrap().r0, last - 1);
        // The first lap's replies are gone; waiting for one must error,
        // not hand back the second lap's payload.
        assert!(ring.wait(1).is_err());
    }
}
